//! Streaming registration service demo — the coordinator as a long-
//! running system component: a LiDAR source thread produces frames at a
//! configurable rate, the alignment thread keeps the device busy, and a
//! stats thread reports throughput / latency percentiles / backpressure,
//! the way the FPPS host process would run inside a perception stack.
//!
//!   cargo run --release --example registration_server -- [--frames 30]

use anyhow::Result;
use fpps::cli::Parser;
use fpps::coordinator::{fit_to_capacity, preprocess, PipelineConfig};
use fpps::dataset::{lidar::LidarConfig, sequence_specs, Sequence};
use fpps::fpps_api::{FppsIcp, KernelBackend};
use fpps::math::Mat4;
use fpps::metrics::TimingStats;
use fpps::pointcloud::PointCloud;
use std::path::Path;
use std::sync::mpsc::sync_channel;
use std::time::{Duration, Instant};

struct Request {
    frame_index: usize,
    source: PointCloud,
    target: PointCloud,
    enqueued: Instant,
}

struct Response {
    frame_index: usize,
    transform: Mat4,
    rmse: f64,
    queue_wait: Duration,
    service: Duration,
}

fn serve<B: KernelBackend>(mut icp: FppsIcp<B>, frames: usize) -> Result<()> {
    let spec = sequence_specs()[5].clone(); // 05: urban loop
    let seq = Sequence::synthetic(
        spec,
        frames,
        99,
        LidarConfig {
            beams: 48,
            azimuth_steps: 900,
            ..Default::default()
        },
    );
    let cfg = PipelineConfig::default();

    // Bounded request queue — depth 2 = device double buffering; the
    // producer blocks when the device falls behind (backpressure).
    let (req_tx, req_rx) = sync_channel::<Request>(2);
    let (rsp_tx, rsp_rx) = sync_channel::<Response>(64);

    let mut wait_stats = TimingStats::new();
    let mut service_stats = TimingStats::new();
    let mut pose = Mat4::IDENTITY;
    let mut prev_rel = Mat4::IDENTITY;
    let served_t0 = Instant::now();
    let mut served = 0usize;

    std::thread::scope(|scope| -> Result<()> {
        // Producer: LiDAR acquisition + preprocessing. Owns the request
        // sender so the service loop sees a clean hang-up at stream end.
        let seq = &seq;
        scope.spawn(move || -> Result<()> {
            let req_tx = req_tx;
            let mut prev: Option<PointCloud> = None;
            for i in 0..seq.len() {
                let cloud = preprocess(&seq.frame(i)?, &cfg);
                let mut rng = fpps::rng::Pcg32::substream(cfg.seed, i as u64);
                let sample = cloud.random_sample(cfg.source_sample, &mut rng);
                let full = fit_to_capacity(cloud, cfg.target_capacity);
                if let Some(target) = prev.take() {
                    req_tx
                        .send(Request {
                            frame_index: i,
                            source: sample,
                            target,
                            enqueued: Instant::now(),
                        })
                        .ok();
                }
                prev = Some(full);
            }
            Ok(())
        });

        // Service loop: the device-facing worker.
        while let Ok(req) = req_rx.recv() {
            let queue_wait = req.enqueued.elapsed();
            let t0 = Instant::now();
            icp.set_input_source(req.source);
            icp.set_input_target(req.target);
            icp.set_transformation_matrix(prev_rel);
            let res = icp.align()?;
            let service = t0.elapsed();
            prev_rel = if res.has_converged() {
                res.transformation
            } else {
                Mat4::IDENTITY
            };
            pose = pose.mul_mat(&res.transformation);
            served += 1;
            wait_stats.record(queue_wait);
            service_stats.record(service);
            rsp_tx
                .send(Response {
                    frame_index: req.frame_index,
                    transform: res.transformation,
                    rmse: res.rmse,
                    queue_wait,
                    service,
                })
                .ok();
        }
        Ok(())
    })?;
    drop(rsp_tx);
    let wall = served_t0.elapsed();

    // Drain and print a few responses as a service log.
    println!("\nservice log (last 5):");
    let responses: Vec<Response> = rsp_rx.try_iter().collect();
    for r in responses.iter().rev().take(5).rev() {
        println!(
            "  frame {:>3}  rmse {:.3} m  wait {:>6.1} ms  service {:>7.1} ms  |t| {:.2} m",
            r.frame_index,
            r.rmse,
            r.queue_wait.as_secs_f64() * 1e3,
            r.service.as_secs_f64() * 1e3,
            r.transform.translation().norm(),
        );
    }

    println!("\nserver summary ({} backend):", icp.backend().name());
    println!(
        "  served {} alignments in {:.1} s  ->  {:.2} frames/s",
        served,
        wall.as_secs_f64(),
        served as f64 / wall.as_secs_f64()
    );
    println!(
        "  service latency: mean {:.1} ms  p50 {:.1}  p99 {:.1}",
        service_stats.mean_ms(),
        service_stats.percentile_ms(50.0),
        service_stats.percentile_ms(99.0)
    );
    println!(
        "  queue wait (backpressure): mean {:.1} ms  max {:.1} ms",
        wait_stats.mean_ms(),
        wait_stats.max_ms()
    );
    println!("  final pose |t| = {:.2} m", pose.translation().norm());
    println!("\nregistration_server OK");
    Ok(())
}

fn main() -> Result<()> {
    let p = Parser::new("registration_server", "streaming coordinator demo")
        .opt("frames", "frames to stream", Some("30"));
    let a = p.parse_env(1)?;
    let frames: usize = a.get_or("frames", 30)?;
    let artifacts = Path::new("artifacts");
    if artifacts.join("manifest.txt").exists() {
        serve(FppsIcp::hardware_initialize(artifacts)?, frames)
    } else {
        eprintln!("note: artifacts/ missing, using NativeSim");
        serve(FppsIcp::native_sim(), frames)
    }
}
