//! Multi-client registration service demo — the coordinator's lane pool
//! as a long-running system component: M concurrent client streams
//! (each a LiDAR source producing frame pairs at its own rate) are
//! multiplexed over K worker lanes, each lane owning its own backend
//! instance, the way the FPPS host process would serve several
//! perception stacks from one shared accelerator.
//!
//! Reports aggregate throughput, p50/p99 service latency, queue-wait
//! backpressure, and per-lane / per-stream breakdowns.
//!
//!   cargo run --release --example registration_server -- \
//!       [--streams 4] [--lanes 2] [--frames 10] [--backend native-sim]

use std::time::Duration;

use anyhow::{Context, Result};
use fpps::cli::{backend_selection, Parser};
use fpps::coordinator::{
    run_supervised_lane_pool, sequence_pair_jobs, LaneIcpConfig, PipelineConfig, SupervisorConfig,
};
use fpps::dataset::{lidar::LidarConfig, sequence_specs, Sequence};
use fpps::fpps_api::{BackendHandle, FailoverChain};
use fpps::report::Table;

fn main() -> Result<()> {
    let p = Parser::new("registration_server", "multi-client lane-pool demo")
        .opt("streams", "concurrent client streams", Some("4"))
        .opt("frames", "frames per stream", Some("10"))
        .opt("sample", "source sample size", Some("1024"))
        .opt("capacity", "target buffer capacity", Some("8192"))
        .lane_opts("2")
        .backend_opts()
        .supervision_opts();
    let a = p.parse_env(1)?;
    let streams: usize = a.get_or("streams", 4)?;
    let frames: usize = a.get_or("frames", 10)?;
    let lanes: usize = a.get_or("lanes", 2)?;
    let queue_depth: usize = a.get_or("queue-depth", 4)?;
    let sample: usize = a.get_or("sample", 1024)?;
    let capacity: usize = a.get_or("capacity", 8192)?;
    let (kind, artifacts) = backend_selection(&a)?;
    let artifacts = artifacts.as_path();
    // Fault-tolerance knobs: a service puts an SLO on every job and
    // survives a flaky device (see README "Fault tolerance").
    let deadline_ms: u64 = a.get_or("deadline-ms", 0)?;
    let retries: u32 = a.get_or("retries", 0)?;
    let failover: FailoverChain = a
        .get_parsed("failover")?
        .unwrap_or_else(|| FailoverChain::single(kind));
    let sup = SupervisorConfig {
        deadline: (deadline_ms > 0).then(|| Duration::from_millis(deadline_ms)),
        max_retries: retries,
        ..Default::default()
    };

    // One synthetic sequence per client, cycling through the paper's
    // sequence characters so the streams are genuinely heterogeneous.
    let specs = sequence_specs();
    let sequences: Vec<Sequence> = (0..streams)
        .map(|s| {
            Sequence::synthetic(
                specs[s % specs.len()].clone(),
                frames,
                1000 + s as u64,
                LidarConfig {
                    beams: 32,
                    azimuth_steps: 500,
                    ..Default::default()
                },
            )
        })
        .collect();
    println!(
        "serving {streams} client streams x {frames} frames over {lanes} lane(s), \
         queue depth {queue_depth}"
    );

    // Producer side: one thread per client stream. Acquisition (raycast +
    // sample + downsample) runs concurrently with alignment on the lanes,
    // and the bounded queue applies backpressure to fast clients.
    let sequences_ref = &sequences;
    let failover_ref = &failover;
    let report = run_supervised_lane_pool(
        lanes,
        queue_depth,
        LaneIcpConfig::default(),
        sup,
        |_lane, tier| BackendHandle::create(failover_ref.kind_for_tier(tier), artifacts),
        move |tx| {
            std::thread::scope(|scope| -> Result<()> {
                let mut handles = Vec::new();
                for (stream, seq) in sequences_ref.iter().enumerate() {
                    let tx = tx.clone();
                    handles.push(scope.spawn(move || -> Result<()> {
                        let cfg = PipelineConfig {
                            source_sample: sample,
                            target_capacity: capacity,
                            seed: 7 + stream as u64,
                            ..Default::default()
                        };
                        // Acquisition (raycast + sample + downsample) for
                        // this stream happens here, concurrent with the
                        // other streams and with alignment on the lanes.
                        let jobs = sequence_pair_jobs(seq, frames, stream, &cfg)
                            .with_context(|| format!("stream {stream} acquisition"))?;
                        for mut job in jobs {
                            job.mark_submitted(); // queue wait starts at send
                            if tx.send(job).is_err() {
                                return Ok(()); // pool shut down
                            }
                        }
                        Ok(())
                    }));
                }
                drop(tx);
                // A panicked client thread must surface as a nonzero
                // exit naming the stream — not vanish into a generic
                // producer error (or worse, a truncated-but-zero run).
                for (stream, h) in handles.into_iter().enumerate() {
                    match h.join() {
                        Ok(r) => r?,
                        Err(payload) => {
                            let msg = payload
                                .downcast_ref::<&str>()
                                .map(|s| (*s).to_string())
                                .or_else(|| payload.downcast_ref::<String>().cloned())
                                .unwrap_or_else(|| "non-string panic payload".to_string());
                            anyhow::bail!("client stream {stream} producer panicked: {msg}");
                        }
                    }
                }
                Ok(())
            })
        },
    )?;

    // ---- service log (last few responses) ----
    println!("\nservice log (last 5):");
    for o in report.outcomes.iter().rev().take(5).rev() {
        println!(
            "  stream {:>2} job {:>10}  lane {}  rmse {:.3} m  wait {:>6.1} ms  \
             service {:>7.1} ms  |t| {:.2} m",
            o.stream,
            o.id,
            o.lane,
            o.rmse,
            o.queue_wait_ms,
            o.service_ms,
            o.transform.translation().norm(),
        );
    }

    // ---- per-lane breakdown (merged into the aggregate below) ----
    report.lane_table("\nPer-lane breakdown").print();

    // ---- per-stream accounting ----
    let mut st = Table::new("\nPer-stream results").header(&[
        "stream", "sequence", "jobs", "mean rmse (m)", "mean service (ms)",
    ]);
    for stream in 0..streams {
        let (mut jobs, mut ok_jobs) = (0usize, 0usize);
        let (mut rmse_sum, mut service_sum) = (0.0f64, 0.0f64);
        for o in report.outcomes.iter().filter(|o| o.stream == stream) {
            jobs += 1;
            service_sum += o.service_ms;
            // Contained failures carry NaN rmse; keep them out of the
            // mean instead of letting one bad job poison the column.
            if !o.is_failed() {
                ok_jobs += 1;
                rmse_sum += o.rmse;
            }
        }
        // An all-failed stream shows NaN, never a perfect-looking 0.000.
        let mean_rmse = if ok_jobs == 0 {
            f64::NAN
        } else {
            rmse_sum / ok_jobs as f64
        };
        st.row(vec![
            stream.to_string(),
            sequences[stream].spec.name.to_string(),
            jobs.to_string(),
            format!("{mean_rmse:.3}"),
            format!("{:.1}", service_sum / jobs.max(1) as f64),
        ]);
    }
    st.print();

    // ---- aggregate summary ----
    println!("\nserver summary:");
    println!(
        "  served {} alignments in {:.1} s  ->  {:.2} jobs/s aggregate",
        report.outcomes.len(),
        report.wall_ms / 1e3,
        report.jobs_per_s()
    );
    println!(
        "  service latency: mean {:.1} ms  p50 {:.1}  p99 {:.1}",
        report.service.mean_ms(),
        report.service.percentile_ms(50.0),
        report.service.percentile_ms(99.0)
    );
    println!(
        "  queue wait (backpressure): mean {:.1} ms  max {:.1} ms",
        report.queue_wait.mean_ms(),
        report.queue_wait.max_ms()
    );
    anyhow::ensure!(
        report.outcomes.len() == streams * frames.saturating_sub(1),
        "dropped jobs: served {} of {}",
        report.outcomes.len(),
        streams * frames.saturating_sub(1)
    );
    anyhow::ensure!(
        report.failed_jobs() == 0,
        "{} jobs failed (contained per lane; see RegistrationOutcome::error)",
        report.failed_jobs()
    );
    println!("\nregistration_server OK");
    Ok(())
}
