//! Multi-client registration service demo — the serving tier as a
//! long-running system component: M concurrent client streams (each a
//! LiDAR source producing frame pairs at its own rate) are multiplexed
//! over K worker lanes through non-blocking submission handles, the way
//! the FPPS host process would serve several perception stacks from one
//! shared accelerator.
//!
//! The old thread-per-client pattern is gone: a bounded pool of driver
//! threads (at most 8) fans the streams out over per-client
//! `ClientStream`s with bounded backpressure — a full stream parks the
//! driver briefly instead of blocking a lane.
//!
//! Reports aggregate throughput, p50/p99 service latency, queue-wait
//! backpressure, and per-lane / per-stream breakdowns.
//!
//!   cargo run --release --example registration_server -- \
//!       [--streams 4] [--lanes 2] [--frames 10] [--backend native-sim]

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::Duration;

use anyhow::{Context, Result};
use fpps::cli::{backend_selection, Parser};
use fpps::coordinator::{
    sequence_pair_jobs, CompletionHandle, LaneIcpConfig, PipelineConfig, ServingConfig,
    ServingPool, SloClass, Submission, SupervisorConfig,
};
use fpps::dataset::{lidar::LidarConfig, sequence_specs, Sequence};
use fpps::fpps_api::{BackendHandle, FailoverChain};
use fpps::report::Table;

fn main() -> Result<()> {
    let p = Parser::new("registration_server", "multi-client serving-tier demo")
        .opt("streams", "concurrent client streams", Some("4"))
        .opt("frames", "frames per stream", Some("10"))
        .opt("sample", "source sample size", Some("1024"))
        .opt("capacity", "target buffer capacity", Some("8192"))
        .opt(
            "slo",
            "SLO class: latency-critical | standard | best-effort",
            Some("standard"),
        )
        .opt(
            "stream-depth",
            "per-client in-flight bound before park/shed",
            Some("4"),
        )
        .lane_opts("2")
        .backend_opts()
        .supervision_opts();
    let a = p.parse_env(1)?;
    let streams: usize = a.get_or("streams", 4)?;
    let frames: usize = a.get_or("frames", 10)?;
    let lanes: usize = a.get_or("lanes", 2)?;
    let queue_depth: usize = a.get_or("queue-depth", 4)?;
    let sample: usize = a.get_or("sample", 1024)?;
    let capacity: usize = a.get_or("capacity", 8192)?;
    let slo: SloClass = a.get_or("slo", SloClass::Standard)?;
    let stream_depth: usize = a.get_or("stream-depth", 4)?;
    let (kind, artifacts) = backend_selection(&a)?;
    // Fault-tolerance knobs: a service puts an SLO on every job and
    // survives a flaky device (see README "Fault tolerance").
    let deadline_ms: u64 = a.get_or("deadline-ms", 0)?;
    let retries: u32 = a.get_or("retries", 0)?;
    let failover: FailoverChain = a
        .get_parsed("failover")?
        .unwrap_or_else(|| FailoverChain::single(kind));
    let sup = SupervisorConfig {
        deadline: (deadline_ms > 0).then(|| Duration::from_millis(deadline_ms)),
        max_retries: retries,
        ..Default::default()
    };

    // One synthetic sequence per client, cycling through the paper's
    // sequence characters so the streams are genuinely heterogeneous.
    let specs = sequence_specs();
    let sequences: Vec<Sequence> = (0..streams)
        .map(|s| {
            Sequence::synthetic(
                specs[s % specs.len()].clone(),
                frames,
                1000 + s as u64,
                LidarConfig {
                    beams: 32,
                    azimuth_steps: 500,
                    ..Default::default()
                },
            )
        })
        .collect();
    println!(
        "serving {streams} client streams x {frames} frames over {lanes} lane(s), \
         queue depth {queue_depth}, stream depth {stream_depth}"
    );

    let pool = ServingPool::start(
        lanes,
        queue_depth,
        LaneIcpConfig::default(),
        sup,
        ServingConfig {
            stream_depth,
            ..Default::default()
        },
        move |_lane, tier| BackendHandle::create(failover.kind_for_tier(tier), &artifacts),
    )?;

    // Driver side: a bounded pool of threads (≤ 8, however many streams
    // there are) fans the client streams out over submission handles.
    // Acquisition (raycast + sample + downsample) runs on the drivers,
    // concurrent with alignment on the lanes; a stream at its in-flight
    // depth parks its driver for a beat instead of blocking anything.
    let drivers = streams.min(8);
    // Each driver owns the `ClientStream`s of the streams it serves —
    // handed over by move, so nothing is shared but the pool internals.
    let mut per_driver: Vec<Vec<(usize, fpps::coordinator::ClientStream)>> =
        (0..drivers).map(|_| Vec::new()).collect();
    for stream in 0..streams {
        per_driver[stream % drivers].push((stream, pool.client()));
    }
    let sequences_ref = &sequences;
    let handles: Vec<CompletionHandle> = std::thread::scope(|scope| -> Result<Vec<_>> {
        let mut joins = Vec::new();
        for assigned in per_driver {
            joins.push(scope.spawn(move || -> Result<Vec<CompletionHandle>> {
                let mut collected = Vec::new();
                for (stream, client) in assigned {
                    let seq = &sequences_ref[stream];
                    // Acquisition for this stream, preserving the panic
                    // contract of the old thread-per-client producers: a
                    // panicked client surfaces as a nonzero exit naming
                    // the stream — not a torn-down driver thread.
                    let jobs = match catch_unwind(AssertUnwindSafe(|| {
                        let cfg = PipelineConfig {
                            source_sample: sample,
                            target_capacity: capacity,
                            seed: 7 + stream as u64,
                            ..Default::default()
                        };
                        sequence_pair_jobs(seq, frames, stream, &cfg)
                    })) {
                        Ok(r) => r.with_context(|| format!("stream {stream} acquisition"))?,
                        Err(payload) => {
                            let msg = payload
                                .downcast_ref::<&str>()
                                .map(|s| (*s).to_string())
                                .or_else(|| payload.downcast_ref::<String>().cloned())
                                .unwrap_or_else(|| "non-string panic payload".to_string());
                            anyhow::bail!("client stream {stream} producer panicked: {msg}");
                        }
                    };
                    for job in jobs {
                        let mut job = job.with_slo(slo);
                        loop {
                            match client.try_submit(job)? {
                                Submission::Accepted(h) | Submission::Shed(h) => {
                                    collected.push(h);
                                    break;
                                }
                                Submission::Parked(parked) => {
                                    job = parked;
                                    std::thread::sleep(Duration::from_micros(200));
                                }
                            }
                        }
                    }
                }
                Ok(collected)
            }));
        }
        let mut all = Vec::new();
        for j in joins {
            match j.join() {
                Ok(r) => all.extend(r?),
                Err(_) => anyhow::bail!("driver thread panicked"),
            }
        }
        Ok(all)
    })?;

    let report = pool.shutdown()?;
    assert!(
        handles.iter().all(|h| h.is_complete()),
        "shutdown resolves every handle"
    );

    // ---- service log (last few responses) ----
    println!("\nservice log (last 5):");
    for o in report.lane_report.outcomes.iter().rev().take(5).rev() {
        println!(
            "  stream {:>2} job {:>10}  lane {}  rmse {:.3} m  wait {:>6.1} ms  \
             service {:>7.1} ms  |t| {:.2} m",
            o.stream,
            o.id,
            o.lane,
            o.rmse,
            o.queue_wait_ms,
            o.service_ms,
            o.transform.translation().norm(),
        );
    }

    // ---- per-lane and per-class breakdowns ----
    report.lane_report.lane_table("\nPer-lane breakdown").print();
    report.class_table().print();

    // ---- per-stream accounting ----
    let mut st = Table::new("\nPer-stream results").header(&[
        "stream", "sequence", "jobs", "mean rmse (m)", "mean service (ms)",
    ]);
    for stream in 0..streams {
        let (mut jobs, mut ok_jobs) = (0usize, 0usize);
        let (mut rmse_sum, mut service_sum) = (0.0f64, 0.0f64);
        for o in report
            .lane_report
            .outcomes
            .iter()
            .filter(|o| o.stream == stream)
        {
            jobs += 1;
            service_sum += o.service_ms;
            // Contained failures carry NaN rmse; keep them out of the
            // mean instead of letting one bad job poison the column.
            if !o.is_failed() {
                ok_jobs += 1;
                rmse_sum += o.rmse;
            }
        }
        // An all-failed stream shows NaN, never a perfect-looking 0.000.
        let mean_rmse = if ok_jobs == 0 {
            f64::NAN
        } else {
            rmse_sum / ok_jobs as f64
        };
        st.row(vec![
            stream.to_string(),
            sequences[stream].spec.name.to_string(),
            jobs.to_string(),
            format!("{mean_rmse:.3}"),
            format!("{:.1}", service_sum / jobs.max(1) as f64),
        ]);
    }
    st.print();

    // ---- aggregate summary ----
    println!("\nserver summary:");
    println!(
        "  served {} alignments in {:.1} s  ->  {:.2} jobs/s aggregate",
        report.lane_report.outcomes.len(),
        report.lane_report.wall_ms / 1e3,
        report.lane_report.jobs_per_s()
    );
    println!(
        "  service latency: mean {:.1} ms  p50 {:.1}  p99 {:.1}",
        report.lane_report.service.mean_ms(),
        report.lane_report.service.percentile_ms(50.0),
        report.lane_report.service.percentile_ms(99.0)
    );
    println!(
        "  queue wait (backpressure): mean {:.1} ms  max {:.1} ms",
        report.lane_report.queue_wait.mean_ms(),
        report.lane_report.queue_wait.max_ms()
    );
    anyhow::ensure!(
        report.lane_report.outcomes.len() + report.total_shed()
            == streams * frames.saturating_sub(1),
        "dropped jobs: served {} + shed {} of {}",
        report.lane_report.outcomes.len(),
        report.total_shed(),
        streams * frames.saturating_sub(1)
    );
    anyhow::ensure!(
        report.contained_failures() == 0,
        "{} jobs failed (contained per lane; see RegistrationOutcome::error)",
        report.contained_failures()
    );
    println!("\nregistration_server OK");
    Ok(())
}
