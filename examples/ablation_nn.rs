//! NN-strategy ablation (the §V design discussion, quantified):
//! compare correspondence-estimation strategies on the same workload —
//!
//!   * kd-tree (PCL baseline; sequential traversal, data-dependent)
//!   * CPU brute force, 1 thread and N threads
//!   * the blocked kernel dataflow (NativeSim mirror of the PE array)
//!   * projected FPGA systolic array latency (hwmodel)
//!   * projected TPU Pallas latency structure (tpu_estimate)
//!
//! plus a Pallas block-shape sweep showing where VMEM/MXU trade off —
//! the L1 §Perf structural target — and a voxel-grid sweep (cell size ×
//! ring budget) quantifying the ISSUE 8 approximate-NN backend against
//! bounded kd-tree search: a covering budget is exact by construction,
//! tighter budgets trade recall for throughput.
//!
//!   cargo run --release --example ablation_nn

use fpps::hwmodel::{latency, tpu_estimate, AcceleratorConfig};
use fpps::kdtree::KdTree;
use fpps::nn;
use fpps::pointcloud::PointCloud;
use fpps::report::Table;
use fpps::rng::Pcg32;
use fpps::voxelgrid::VoxelGrid;
use std::time::Instant;

fn random_cloud(n: usize, seed: u64) -> PointCloud {
    let mut rng = Pcg32::new(seed);
    let mut c = PointCloud::with_capacity(n);
    for _ in 0..n {
        c.push([
            rng.range(-60.0, 60.0),
            rng.range(-60.0, 60.0),
            rng.range(-2.0, 6.0),
        ]);
    }
    c
}

fn main() {
    let n_src = 4096;
    let n_tgt = 32_768;
    let queries = random_cloud(n_src, 1);
    let targets = random_cloud(n_tgt, 2);
    println!("workload: {n_src} queries x {n_tgt} targets (one NN pass)\n");

    let mut t = Table::new("NN strategy ablation").header(&[
        "strategy",
        "time (ms)",
        "vs kd-tree",
        "notes",
    ]);

    // kd-tree (build + query, like one ICP iteration does).
    let t0 = Instant::now();
    let tree = KdTree::build(&targets);
    let build_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t0 = Instant::now();
    let mut checksum = 0u64;
    for q in queries.iter() {
        checksum += tree.nearest(q).unwrap().index as u64;
    }
    let kd_ms = t0.elapsed().as_secs_f64() * 1e3;
    t.row(vec![
        "kd-tree (PCL baseline)".into(),
        format!("{kd_ms:.1}"),
        "1.00x".into(),
        format!("+{build_ms:.1} ms build; depth-dependent latency"),
    ]);

    // Brute force single thread.
    let t0 = Instant::now();
    for q in queries.iter() {
        checksum += nn::nearest_brute(&targets, q).unwrap().0 as u64;
    }
    let brute_ms = t0.elapsed().as_secs_f64() * 1e3;
    t.row(vec![
        "brute force, 1 thread".into(),
        format!("{brute_ms:.1}"),
        format!("{:.2}x", kd_ms / brute_ms),
        "deterministic, O(N*M)".into(),
    ]);

    // Brute force multithreaded.
    let threads = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(4);
    let t0 = Instant::now();
    let res = nn::nearest_brute_parallel(&targets, &queries, threads);
    let par_ms = t0.elapsed().as_secs_f64() * 1e3;
    checksum += res[0].0 as u64;
    t.row(vec![
        format!("brute force, {threads} threads"),
        format!("{par_ms:.1}"),
        format!("{:.2}x", kd_ms / par_ms),
        "the intro's multi-core scaling path".into(),
    ]);

    // Kernel-mirror blocked dataflow (what the device executes).
    let cfg = nn::KernelConfig::default();
    let (ps, _) = nn::pad_cloud(&queries.xyz, cfg.block_n);
    let (pt, mask) = nn::pad_cloud(&targets.xyz, cfg.block_m);
    let t0 = Instant::now();
    let r = nn::kernel_mirror(&ps, &pt, &mask, cfg);
    let mirror_ms = t0.elapsed().as_secs_f64() * 1e3;
    checksum += r.index[0] as u64;
    t.row(vec![
        "blocked PE dataflow (NativeSim)".into(),
        format!("{mirror_ms:.1}"),
        format!("{:.2}x", kd_ms / mirror_ms),
        "bit-faithful kernel mirror on CPU".into(),
    ]);

    // Projected FPGA systolic array.
    let hw = AcceleratorConfig::default();
    let fpga_ms = latency::nn_search_cycles(&hw, n_src, n_tgt) as f64 * hw.cycle_s() * 1e3;
    t.row(vec![
        format!("FPGA {}x{} PE array (model)", hw.pe_rows, hw.pe_cols),
        format!("{fpga_ms:.1}"),
        format!("{:.2}x", kd_ms / fpga_ms),
        format!("deterministic @ {} MHz", hw.clock_mhz),
    ]);
    t.print();
    println!("(checksum {checksum})\n");

    // ---- Voxel-grid sweep: cell size x ring budget (ISSUE 8) ----
    // Bounded correspondence search (r = 3 m), the shape ICP actually
    // issues. The kd-tree bounded pass is the 1.00x baseline; a budget
    // with cell*ring >= r answers every query identically.
    let max_dist = 3.0f32;
    let max_d2 = max_dist * max_dist;
    let t0 = Instant::now();
    let exact_bounded: Vec<_> = queries
        .iter()
        .map(|q| tree.nearest(q).filter(|nb| nb.dist_sq < max_d2))
        .collect();
    let kd_bounded_ms = t0.elapsed().as_secs_f64() * 1e3;
    let mut grid_sweep = Table::new(format!(
        "voxel-grid sweep (bounded NN, r = {max_dist} m; kd-tree {kd_bounded_ms:.1} ms)"
    ))
    .header(&["cell (m)", "ring", "budget", "time (ms)", "vs kd-tree", "found", "exact"]);
    for cell in [1.0f32, 2.0, 3.0] {
        for ring in [1usize, 2, 3] {
            let grid = VoxelGrid::build(&targets, cell, ring);
            let t0 = Instant::now();
            let mut found = 0usize;
            let mut exact = 0usize;
            for (q, base) in queries.iter().zip(&exact_bounded) {
                let got = grid.nearest(&targets, q, max_d2);
                if got.is_some() {
                    found += 1;
                }
                match (got, base) {
                    (Some(g), Some(b)) if g.dist_sq.to_bits() == b.dist_sq.to_bits() => exact += 1,
                    (None, None) => exact += 1,
                    _ => {}
                }
            }
            let g_ms = t0.elapsed().as_secs_f64() * 1e3;
            let covering = cell * ring as f32 >= max_dist;
            if covering {
                assert_eq!(exact, n_src, "covering budget must answer exactly");
            }
            grid_sweep.row(vec![
                format!("{cell:.1}"),
                ring.to_string(),
                if covering { "covering" } else { "tight" }.into(),
                format!("{g_ms:.1}"),
                format!("{:.2}x", kd_bounded_ms / g_ms),
                format!("{:.1}%", 100.0 * found as f64 / n_src as f64),
                format!("{:.1}%", 100.0 * exact as f64 / n_src as f64),
            ]);
        }
    }
    grid_sweep.print();
    println!();

    // ---- Pallas block-shape sweep (L1 structural perf target) ----
    let core = tpu_estimate::TpuCore::default();
    let mut sweep = Table::new("Pallas block-shape sweep (TPU structural estimate)")
        .header(&["BN", "BM", "VMEM (KiB)", "MXU util", "flops/byte", "grid cycles (M)"]);
    for bn in [32usize, 64, 128, 256, 512] {
        for bm in [256usize, 512, 1024, 2048] {
            if n_src % bn != 0 || n_tgt % bm != 0 {
                continue;
            }
            let blk = tpu_estimate::BlockConfig {
                block_n: bn,
                block_m: bm,
            };
            let e = tpu_estimate::estimate(&core, &blk);
            if e.vmem_bytes > core.vmem_bytes {
                continue;
            }
            let steps = (n_src / bn) * (n_tgt / bm);
            sweep.row(vec![
                bn.to_string(),
                bm.to_string(),
                format!("{}", e.vmem_bytes / 1024),
                format!("{:.3}", e.mxu_utilization),
                format!("{:.1}", e.flops_per_byte),
                format!("{:.2}", e.cycles * steps as f64 / 1e6),
            ]);
        }
    }
    sweep.print();
    let (best, e) = tpu_estimate::best_blocks(&core, n_src, n_tgt);
    println!(
        "\nbest blocks by total cycles: BN={} BM={} (VMEM {} KiB, MXU {:.3})",
        best.block_n,
        best.block_m,
        e.vmem_bytes / 1024,
        e.mxu_utilization
    );
    println!("ablation_nn OK");
}
