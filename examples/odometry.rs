//! End-to-end driver (DESIGN.md §5): scan-to-scan LiDAR odometry over a
//! synthetic KITTI-like sequence, run twice —
//!
//!   1. CPU baseline: PCL-equivalent ICP (kd-tree, full source cloud),
//!      the paper's software-only configuration;
//!   2. FPPS hybrid: 4096-point source sample through the AOT device
//!      kernel (PJRT) with the host SVD loop;
//!
//! and reports per-frame latency, registration RMSE, trajectory ATE and
//! the projected Alveo-U50 frame latency from the hardware model — the
//! quantities of Tables III/IV. Results are recorded in EXPERIMENTS.md.
//!
//!   cargo run --release --example odometry -- [--sequence 03] [--frames 8]

use anyhow::Result;
use fpps::cli::{backend_selection, Parser};
use fpps::coordinator::{run_odometry, PipelineConfig};
use fpps::dataset::{lidar::LidarConfig, sequence_specs, Sequence};
use fpps::fpps_api::{FppsIcp, KernelBackend};
use fpps::hwmodel::{latency, AcceleratorConfig};
use fpps::icp::{IcpParams, SearchStrategy};
use fpps::math::Mat4;
use fpps::metrics::{absolute_trajectory_error, TimingStats};
use fpps::report::Table;

fn main() -> Result<()> {
    let p = Parser::new("odometry", "end-to-end odometry driver")
        .opt("sequence", "sequence 00..09", Some("03"))
        .opt("frames", "frames to process", Some("8"))
        .opt("seed", "dataset seed", Some("2026"))
        .backend_opts();
    let a = p.parse_env(1)?;
    let name = a.get("sequence").unwrap().to_string();
    let frames: usize = a.get_or("frames", 8)?;
    let seed: u64 = a.get_or("seed", 2026)?;

    let spec = sequence_specs()
        .into_iter()
        .find(|s| s.name == name)
        .expect("unknown sequence");
    println!(
        "sequence {name} ({:?}), {frames} frames, full 64-beam LiDAR",
        spec.kind
    );
    let seq = Sequence::synthetic(spec, frames, seed, LidarConfig::default());
    let cfg = PipelineConfig {
        seed,
        ..Default::default()
    };

    // ---------- CPU baseline: full cloud through kd-tree ICP ----------
    println!("\n[1/2] CPU baseline (PCL-equivalent, full source cloud)…");
    let params = IcpParams {
        search: SearchStrategy::KdTree,
        ..Default::default()
    };
    let mut cpu_stats = TimingStats::new();
    let mut cpu_rmse = Vec::new();
    let mut cpu_poses = vec![Mat4::IDENTITY];
    let mut prev: Option<fpps::pointcloud::PointCloud> = None;
    let mut prev_rel = Mat4::IDENTITY;
    for i in 0..frames {
        // The paper's software baseline registers the FULL cloud (the
        // 4096-point sample is the accelerated path's trick), so no
        // front end here beyond what both sides share.
        let cloud = seq.frame(i)?;
        if let Some(target) = prev.take() {
            let t0 = std::time::Instant::now();
            let res = fpps::icp::align(&cloud, &target, &prev_rel, &params);
            cpu_stats.record(t0.elapsed());
            cpu_rmse.push(res.rmse);
            let pose = cpu_poses.last().unwrap().mul_mat(&res.transformation);
            cpu_poses.push(pose);
            prev_rel = if res.has_converged() {
                res.transformation
            } else {
                Mat4::IDENTITY
            };
        }
        prev = Some(cloud);
    }

    // ---------- FPPS hybrid through the selected device backend ----------
    println!("[2/2] FPPS hybrid (4096-pt sample on the device kernel)…");
    let (kind, artifacts) = backend_selection(&a)?;
    let mut icp = FppsIcp::with_kind(kind, &artifacts)?;
    println!("        backend: {}", icp.backend().name());
    let fpps_res = run_odometry(&seq, frames, cfg, &mut icp)?;

    // ---------- comparison ----------
    let gt0 = seq.ground_truth[0];
    let gt: Vec<Mat4> = seq
        .ground_truth
        .iter()
        .map(|g| gt0.inverse_rigid().mul_mat(g))
        .collect();
    let cpu_ate = absolute_trajectory_error(&cpu_poses, &gt[..cpu_poses.len()]);
    let fpps_ate =
        absolute_trajectory_error(&fpps_res.poses, &gt[..fpps_res.poses.len()]);
    let cpu_mean_rmse = cpu_rmse.iter().sum::<f64>() / cpu_rmse.len().max(1) as f64;

    // Projected Alveo U50 latency for the same workload (hwmodel).
    let hw = AcceleratorConfig::default();
    let mean_iters = fpps_res
        .records
        .iter()
        .map(|r| r.iterations as f64)
        .sum::<f64>()
        / fpps_res.records.len().max(1) as f64;
    let fpga_frame =
        latency::frame_latency(&hw, 4096, hw.target_capacity, mean_iters.round() as u32);

    let mut t = Table::new("\nEnd-to-end odometry summary").header(&[
        "metric",
        "CPU baseline",
        "FPPS hybrid",
    ]);
    t.row(vec![
        "frames aligned".into(),
        cpu_rmse.len().to_string(),
        fpps_res.records.len().to_string(),
    ]);
    t.row(vec![
        "mean registration RMSE (m)".into(),
        format!("{cpu_mean_rmse:.3}"),
        format!("{:.3}", fpps_res.mean_rmse()),
    ]);
    t.row(vec![
        "trajectory ATE (m)".into(),
        format!("{cpu_ate:.3}"),
        format!("{fpps_ate:.3}"),
    ]);
    t.row(vec![
        "mean frame latency, this host (ms)".into(),
        format!("{:.1}", cpu_stats.mean_ms()),
        format!("{:.1}", fpps_res.align_stats.mean_ms()),
    ]);
    t.row(vec![
        "p99 frame latency, this host (ms)".into(),
        format!("{:.1}", cpu_stats.percentile_ms(99.0)),
        format!("{:.1}", fpps_res.align_stats.percentile_ms(99.0)),
    ]);
    t.row(vec![
        "projected U50 frame latency (ms)".into(),
        "-".into(),
        format!("{:.1}", fpga_frame.total_s * 1e3),
    ]);
    t.row(vec![
        "projected speedup vs this CPU".into(),
        "1.00x".into(),
        format!("{:.2}x", cpu_stats.mean_ms() / (fpga_frame.total_s * 1e3)),
    ]);
    t.print();

    println!(
        "\nRMSE delta CPU vs FPPS: {:.4} m (paper Table III: within 0.01 m of\n\
         each other except seq 00; sampling differences explain the gap)",
        (cpu_mean_rmse - fpps_res.mean_rmse()).abs()
    );
    println!("odometry example OK");
    Ok(())
}
