//! Scan-to-map localization demo — the resident-target path end to end:
//! one map stays device-resident while M scans align against it, so the
//! per-scan upload (and, on the kd-tree backend, the index build) is
//! paid once per lane instead of once per scan. This is the workload
//! the `upload_target` / `upload_source` split exists for: odometry
//! re-targets every frame, localization re-targets (almost) never.
//! `--tiles N` switches to the tile-crossing variant: N submaps
//! interleave A,B,…,A,B,… and the backends' LRU residency slots absorb
//! the ping-pong (uploads bounded by tiles × lanes, not scans).
//!
//!   cargo run --release --example localization -- \
//!       [--scans 16] [--lanes 2] [--backend kdtree] [--tiles 2]

use anyhow::{Context, Result};
use fpps::cli::{backend_selection, Parser};
use fpps::coordinator::{
    run_localization, run_tiled_localization, AdmissionPolicy, LaneIcpConfig, PipelineConfig,
};
use fpps::dataset::{lidar::LidarConfig, sequence_specs, Sequence};
use fpps::fpps_api::{BackendHandle, KernelBackend};

fn main() -> Result<()> {
    let p = Parser::new("localization", "scan-to-map localization demo")
        .opt("sequence", "sequence name 00..09", Some("03"))
        .opt("scans", "scans to localize", Some("16"))
        .opt("sample", "source sample size per scan", Some("1024"))
        .opt("capacity", "map buffer capacity", Some("8192"))
        .opt("seed", "dataset seed", Some("2026"))
        .lane_opts("2")
        .residency_opts()
        .backend_opts();
    let a = p.parse_env(1)?;
    let name = a.get("sequence").unwrap().to_string();
    let spec = sequence_specs()
        .into_iter()
        .find(|s| s.name == name)
        .with_context(|| format!("unknown sequence {name}"))?;
    let scans: usize = a.get_or("scans", 16)?;
    let seed: u64 = a.get_or("seed", 2026)?;
    let lanes: usize = a.get_or("lanes", 2)?;
    let queue_depth: usize = a.get_or("queue-depth", 4)?;
    let (kind, artifacts) = backend_selection(&a)?;
    let artifacts = artifacts.as_path();

    let seq = Sequence::synthetic(
        spec,
        scans,
        seed,
        LidarConfig {
            beams: 32,
            azimuth_steps: 400,
            ..Default::default()
        },
    );
    let cfg = PipelineConfig {
        source_sample: a.get_or("sample", 1024)?,
        target_capacity: a.get_or("capacity", 8192)?,
        seed,
        admission: a.get_or("admission", AdmissionPolicy::DownsampleToFit)?,
        ..Default::default()
    };
    let tiles: usize = a.get_or("tiles", 1)?;
    let slots: usize = a.get_or("slots", 0)?;
    println!("localizing {scans} scans over {lanes} lane(s), backend {kind:?}");

    let make_backend = |_lane: usize| -> Result<BackendHandle> {
        let mut b = BackendHandle::create(kind, artifacts)?;
        if slots > 0 {
            b.set_residency_slots(slots);
        }
        Ok(b)
    };

    if tiles > 1 {
        let res = run_tiled_localization(
            &seq,
            scans,
            tiles,
            &cfg,
            lanes,
            queue_depth,
            LaneIcpConfig::default(),
            make_backend,
        )?;
        res.report.lane_table("\nPer-lane breakdown").print();
        let uploads: usize = res.report.lanes.iter().map(|l| l.target_uploads).sum();
        let hits: usize = res.report.lanes.iter().map(|l| l.target_hits).sum();
        println!(
            "\ntile residency: {} submaps, {uploads} upload(s), {hits} cache hit(s); \
             localization error mean {:.3} m",
            res.map_points.len(),
            res.mean_translation_error()
        );
        anyhow::ensure!(
            res.report.failed_jobs() == 0,
            "{} scans failed (contained per lane)",
            res.report.failed_jobs()
        );
        anyhow::ensure!(
            uploads + hits == res.report.outcomes.len(),
            "upload/hit accounting does not cover every scan"
        );
        println!("\ntiled localization OK");
        return Ok(());
    }

    let res = run_localization(
        &seq,
        scans,
        &cfg,
        lanes,
        queue_depth,
        LaneIcpConfig::default(),
        make_backend,
    )?;

    if res.admission.downsampled() {
        println!(
            "admission ({}): map {} pts -> {} pts to fit the {}-pt residency slot",
            res.admission.policy,
            res.admission.original_points,
            res.admission.admitted_points,
            res.admission.slot_capacity
        );
    }
    println!(
        "map: {} points resident; {} scans localized in {:.1} ms ({:.2} jobs/s)",
        res.map_points,
        res.report.outcomes.len(),
        res.report.wall_ms,
        res.report.jobs_per_s()
    );
    res.report.lane_table("\nPer-lane breakdown").print();

    let uploads: usize = res.report.lanes.iter().map(|l| l.target_uploads).sum();
    let hits: usize = res.report.lanes.iter().map(|l| l.target_hits).sum();
    println!(
        "\nmap residency: {uploads} upload(s), {hits} cache hit(s) \
         — shipped per lane, not per scan"
    );
    println!(
        "localization error: mean {:.3} m, max {:.3} m",
        res.mean_translation_error(),
        res.max_translation_error()
    );

    anyhow::ensure!(
        res.report.failed_jobs() == 0,
        "{} scans failed (contained per lane)",
        res.report.failed_jobs()
    );
    // The whole point of the resident-target path: the map is uploaded
    // at most once per lane, never once per scan.
    anyhow::ensure!(
        uploads <= lanes.max(1),
        "map re-uploaded {uploads} times over {lanes} lanes"
    );
    anyhow::ensure!(
        uploads + hits == res.report.outcomes.len(),
        "upload/hit accounting does not cover every scan"
    );
    anyhow::ensure!(
        res.mean_translation_error() < 0.5,
        "localization drifted: mean error {:.3} m",
        res.mean_translation_error()
    );
    println!("\nlocalization OK");
    Ok(())
}
