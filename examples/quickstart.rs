//! Quickstart: the full Table I API surface on a realistic two-cloud
//! registration — the Fig. 1 scenario.
//!
//!   cargo run --release --example quickstart
//!
//! Loads the AOT artifacts when present (`make artifacts`), otherwise
//! falls back to the NativeSim device mirror so the example always runs
//! (the `Auto` backend kind resolves this at runtime).

use fpps::fpps_api::{BackendKind, FppsIcp, KernelBackend};
use fpps::math::{Mat3, Mat4, Vec3};
use fpps::pointcloud::PointCloud;
use fpps::rng::Pcg32;
use std::path::Path;

/// Build a small "street corner": ground patch, two walls, a car-ish
/// box and a pole — enough structure to pin down all six DoF.
fn street_corner(n: usize, seed: u64) -> PointCloud {
    let mut rng = Pcg32::new(seed);
    let mut c = PointCloud::with_capacity(n);
    for i in 0..n {
        match i % 5 {
            0 => {
                // ground with a little texture
                let x = rng.range(-8.0, 8.0);
                let y = rng.range(-8.0, 8.0);
                c.push([x, y, 0.02 * (x * 1.3).sin() * (y * 1.7).cos()]);
            }
            1 => c.push([rng.range(-8.0, 8.0), 8.0, rng.range(0.0, 4.0)]),
            2 => c.push([-8.0, rng.range(-8.0, 8.0), rng.range(0.0, 4.0)]),
            3 => {
                // parked car
                c.push([
                    2.0 + rng.range(0.0, 4.2),
                    -3.0 + rng.range(0.0, 1.8),
                    rng.range(0.0, 1.5),
                ]);
            }
            _ => {
                // pole
                let a = rng.range(0.0, std::f32::consts::TAU);
                c.push([5.0 + 0.1 * a.cos(), 5.0 + 0.1 * a.sin(), rng.range(0.0, 6.0)]);
            }
        }
    }
    c
}

fn run<B: KernelBackend>(mut icp: FppsIcp<B>) -> anyhow::Result<()> {
    // The "map" (target) and a scan of the same scene taken after the
    // sensor moved: rotate 2.3° and translate (0.4, −0.15, 0.02) m.
    let target = street_corner(6000, 42);
    let true_motion = Mat4::from_rt(Mat3::rot_z(0.04), Vec3::new(0.4, -0.15, 0.02));
    let mut source = target.transformed(&true_motion.inverse_rigid());
    let mut rng = Pcg32::new(7);
    source.add_noise(0.01, &mut rng); // 1 cm sensor noise
    // The paper samples 4096 source points per frame (§IV.A) — also the
    // device's source-buffer capacity.
    let source = source.random_sample(4096, &mut rng);

    println!("backend: {}", icp.backend().name());
    println!(
        "source {} pts, target {} pts, true motion |t| = {:.3} m",
        source.len(),
        target.len(),
        true_motion.translation().norm()
    );

    // ----- the Table I API, call for call -----
    icp.set_transformation_matrix(Mat4::IDENTITY); // initial guess
    icp.set_input_source(source);
    icp.set_input_target(target);
    icp.set_max_correspondence_distance(1.0); // paper §IV.A
    icp.set_max_iteration_count(50);
    icp.set_transformation_epsilon(1e-5);
    let result = icp.align()?; // performs the alignment

    println!(
        "\naligned in {} iterations ({:?}), rmse {:.4} m",
        result.iterations, result.stop, result.rmse
    );
    println!(
        "total {:.1} ms (device {:.1} ms)",
        result.total_time.as_secs_f64() * 1e3,
        result.device_time.as_secs_f64() * 1e3
    );
    let est = &result.transformation;
    println!("estimated transform:");
    for i in 0..4 {
        println!(
            "  [{:+.5} {:+.5} {:+.5} {:+.5}]",
            est.m[i][0], est.m[i][1], est.m[i][2], est.m[i][3]
        );
    }
    let rot_err = est.rotation().rotation_angle_to(&true_motion.rotation());
    let trans_err = (est.translation() - true_motion.translation()).norm();
    println!(
        "error vs truth: rotation {:.4} deg, translation {:.4} m",
        rot_err.to_degrees(),
        trans_err
    );
    anyhow::ensure!(trans_err < 0.05, "alignment diverged");
    println!("\nquickstart OK");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    run(FppsIcp::with_kind(BackendKind::Auto, Path::new("artifacts"))?)
}
