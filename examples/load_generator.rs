//! Serving-tier load generator — tens of thousands of simulated clients
//! from a handful of OS threads.
//!
//! Each "client" is a `ClientStream` with its own bounded in-flight
//! window; a small pool of driver threads (at most 8) multiplexes the
//! whole population, the way an async reactor would. Clients cycle
//! through the three SLO classes (`--slo` pins all of them to one), and
//! every job draws from a shared set of canonical frame pairs so the
//! generator spends its time exercising admission, backpressure, and
//! shedding — not synthesizing point clouds.
//!
//! The report is the per-class table: submitted / completed / ok / shed
//! counts and p50/p99/p999 end-to-end latency per SLO class.
//!
//!   cargo run --release --example load_generator -- \
//!       [--clients 10000] [--lanes 4] [--stream-depth 4] \
//!       [--slo latency-critical] [--deadline-ms 50]

use std::sync::Arc;
use std::time::Duration;

use anyhow::{ensure, Result};
use fpps::cli::{backend_selection, Parser};
use fpps::coordinator::{
    ClientStream, CompletionHandle, LaneIcpConfig, RegistrationJob, ServingConfig, ServingPool,
    SloClass, Submission, SupervisorConfig,
};
use fpps::fpps_api::{BackendHandle, FailoverChain};
use fpps::math::{Mat3, Mat4, Vec3};
use fpps::pointcloud::PointCloud;
use fpps::rng::Pcg32;

/// One canonical frame pair, shared by every client that draws it.
struct CanonicalPair {
    key: u64,
    source: Arc<PointCloud>,
    target: Arc<PointCloud>,
}

fn structured_cloud(n: usize, seed: u64) -> PointCloud {
    let mut rng = Pcg32::new(seed);
    let mut c = PointCloud::with_capacity(n);
    for i in 0..n {
        match i % 3 {
            0 => c.push([rng.range(-5.0, 5.0), rng.range(-5.0, 5.0), 0.0]),
            1 => c.push([rng.range(-5.0, 5.0), 5.0, rng.range(0.0, 3.0)]),
            _ => c.push([-5.0, rng.range(-5.0, 5.0), rng.range(0.0, 3.0)]),
        }
    }
    c
}

fn main() -> Result<()> {
    let p = Parser::new(
        "load_generator",
        "serving-tier load generator: many clients, few threads",
    )
    .opt("jobs-per-client", "alignments each client submits", Some("1"))
    .opt("pairs", "distinct canonical frame pairs", Some("64"))
    .opt("points", "points per canonical cloud", Some("320"))
    .lane_opts("4")
    .backend_opts()
    .supervision_opts()
    .serving_opts();
    let a = p.parse_env(1)?;
    let clients: usize = a.get_or("clients", 10_000)?;
    let jobs_per_client: usize = a.get_or("jobs-per-client", 1)?;
    let pairs: usize = a.get_or("pairs", 64)?;
    let points: usize = a.get_or("points", 320)?;
    let lanes: usize = a.get_or("lanes", 4)?;
    let queue_depth: usize = a.get_or("queue-depth", 4)?;
    let stream_depth: usize = a.get_or("stream-depth", 4)?;
    // No --slo: clients cycle through all three classes. With it: the
    // whole population submits under the one given class.
    let slo_override: Option<SloClass> = a.get_parsed("slo")?;
    let (kind, artifacts) = backend_selection(&a)?;
    let deadline_ms: u64 = a.get_or("deadline-ms", 0)?;
    let retries: u32 = a.get_or("retries", 0)?;
    let failover: FailoverChain = a
        .get_parsed("failover")?
        .unwrap_or_else(|| FailoverChain::single(kind));
    let sup = SupervisorConfig {
        deadline: (deadline_ms > 0).then(|| Duration::from_millis(deadline_ms)),
        max_retries: retries,
        ..Default::default()
    };
    ensure!(clients > 0 && pairs > 0 && jobs_per_client > 0, "nothing to do");

    let canonical: Vec<CanonicalPair> = (0..pairs)
        .map(|k| {
            let target = Arc::new(structured_cloud(points, 100 + k as u64));
            let gt = Mat4::from_rt(
                Mat3::rot_z(0.005 * (k as f64 + 1.0)),
                Vec3::new(0.05 + 0.01 * (k % 8) as f64, -0.03, 0.01),
            );
            let source = Arc::new(target.transformed(&gt.inverse_rigid()));
            CanonicalPair {
                key: k as u64,
                source,
                target,
            }
        })
        .collect();

    let total_jobs = clients * jobs_per_client;
    println!(
        "load: {clients} clients x {jobs_per_client} job(s) over {lanes} lane(s), \
         stream depth {stream_depth}, {pairs} canonical pairs"
    );

    let pool = ServingPool::start(
        lanes,
        queue_depth,
        LaneIcpConfig::default(),
        sup,
        ServingConfig {
            stream_depth,
            ..Default::default()
        },
        move |_lane, tier| BackendHandle::create(failover.kind_for_tier(tier), &artifacts),
    )?;

    // The whole client population rides on ≤ 8 driver threads; each
    // driver owns the `ClientStream`s of the clients it serves.
    let drivers = 8usize.min(clients);
    assert!(drivers <= 8, "clients multiplex over a handful of OS threads");
    let mut per_driver: Vec<Vec<(usize, ClientStream)>> =
        (0..drivers).map(|_| Vec::new()).collect();
    for c in 0..clients {
        per_driver[c % drivers].push((c, pool.client()));
    }

    let canonical_ref = &canonical;
    let (handles, park_retries) =
        std::thread::scope(|scope| -> Result<(Vec<CompletionHandle>, usize)> {
            let mut joins = Vec::new();
            for assigned in per_driver {
                joins.push(scope.spawn(
                    move || -> Result<(Vec<CompletionHandle>, usize)> {
                        let mut collected = Vec::new();
                        let mut parks = 0usize;
                        for (client_id, stream) in assigned {
                            let class = slo_override
                                .unwrap_or_else(|| SloClass::all()[client_id % 3]);
                            for k in 0..jobs_per_client {
                                let pair = &canonical_ref[(client_id + k) % pairs];
                                let mut job = RegistrationJob::new_keyed(
                                    (client_id * jobs_per_client + k) as u64,
                                    client_id,
                                    Arc::clone(&pair.source),
                                    Arc::clone(&pair.target),
                                    pair.key,
                                    Mat4::IDENTITY,
                                )
                                .with_slo(class);
                                loop {
                                    match stream.try_submit(job)? {
                                        Submission::Accepted(h) | Submission::Shed(h) => {
                                            collected.push(h);
                                            break;
                                        }
                                        Submission::Parked(back) => {
                                            job = back;
                                            parks += 1;
                                            std::thread::sleep(Duration::from_micros(100));
                                        }
                                    }
                                }
                            }
                        }
                        Ok((collected, parks))
                    },
                ));
            }
            let mut all = Vec::new();
            let mut parks = 0usize;
            for j in joins {
                match j.join() {
                    Ok(r) => {
                        let (h, p) = r?;
                        all.extend(h);
                        parks += p;
                    }
                    Err(_) => anyhow::bail!("driver thread panicked"),
                }
            }
            Ok((all, parks))
        })?;

    let report = pool.shutdown()?;
    assert!(
        handles.iter().all(|h| h.is_complete()),
        "shutdown resolves every handle"
    );
    ensure!(
        handles.len() == total_jobs,
        "every job ends in a handle: {} of {total_jobs}",
        handles.len()
    );

    // ---- per-class latency: the point of the exercise ----
    report.class_table().print();
    report.lane_report.lane_table("\nPer-lane breakdown").print();

    let served = report.lane_report.outcomes.len();
    let shed = report.total_shed();
    println!("\nload summary:");
    println!("  {clients} clients on {drivers} driver thread(s)");
    println!(
        "  served {served} + shed {shed} of {total_jobs} in {:.1} s  ->  {:.1} jobs/s",
        report.lane_report.wall_ms / 1e3,
        report.lane_report.jobs_per_s()
    );
    println!("  park retries (bounded backpressure): {park_retries}");
    ensure!(
        served + shed == total_jobs,
        "dropped jobs: served {served} + shed {shed} of {total_jobs}"
    );
    ensure!(
        report.contained_failures() == 0,
        "{} jobs failed (contained per lane)",
        report.contained_failures()
    );
    println!("\nload_generator OK");
    Ok(())
}
