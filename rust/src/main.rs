//! `fpps` — command-line launcher for the FPPS point cloud processing
//! system.
//!
//! Subcommands:
//! * `align`     — register two point cloud files (KITTI .bin)
//! * `odometry`  — run scan-to-scan odometry on a synthetic sequence
//! * `batch`     — multi-lane batched registration over frame pairs
//! * `localize`  — scan-to-map localization against one resident map,
//!   or `--tiles N` submaps ping-ponging across the LRU residency slots
//! * `serve`     — event-driven serving tier: simulated client streams
//!   submitting through non-blocking handles with SLO-classed admission
//! * `resources` — print the Table II resource report
//! * `power`     — print the §IV.D power/efficiency report
//! * `pipesim`   — run the Fig. 3 cycle-level pipeline simulation
//! * `info`      — artifact manifest + runtime platform
//!
//! Every device-facing subcommand takes `--backend auto|xla|native-sim|
//! kdtree` (runtime selection via `fpps_api::BackendHandle`); `auto`
//! falls back to the bit-faithful NativeSim mirror when no AOT artifacts
//! are present, so the CLI works from a fresh checkout.

use anyhow::{bail, Context, Result};
use fpps::cli::{backend_selection, Parser};
use fpps::config::{KvConfig, RunConfig};
use fpps::coordinator::{
    run_localization_supervised, run_odometry, run_registration_batch_supervised,
    run_tiled_localization_supervised, sequence_pair_jobs, LaneIcpConfig, PipelineConfig,
    RegistrationJob, ServingConfig, ServingPool, Submission, SupervisorConfig,
};
use fpps::dataset::{lidar::LidarConfig, sequence_specs, Sequence};
use fpps::fpps_api::{BackendHandle, BackendKind, FailoverChain, FppsIcp, KernelBackend};
use fpps::hwmodel::{latency, power, resources, AcceleratorConfig};
use fpps::math::Mat4;
use fpps::pointcloud::io;
use fpps::report::{self, Table};
use std::sync::Arc;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let cmd = std::env::args().nth(1).unwrap_or_default();
    match cmd.as_str() {
        "align" => cmd_align(),
        "odometry" => cmd_odometry(),
        "batch" => cmd_batch(),
        "localize" => cmd_localize(),
        "serve" => cmd_serve(),
        "resources" => cmd_resources(),
        "power" => cmd_power(),
        "pipesim" => cmd_pipesim(),
        "info" => cmd_info(),
        "" | "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => {
            print_usage();
            bail!("unknown subcommand {other:?}");
        }
    }
}

fn print_usage() {
    println!(
        "fpps — FPGA-based point cloud processing system (reproduction)\n\n\
         Usage: fpps <subcommand> [options]\n\n\
         Subcommands:\n\
         \x20 align      register two KITTI .bin clouds (--source, --target)\n\
         \x20 odometry   scan-to-scan odometry over a synthetic sequence\n\
         \x20 batch      multi-lane batched registration (--lanes, --pairs)\n\
         \x20 localize   scan-to-map localization on resident maps (--scans, --tiles)\n\
         \x20 serve      serving tier with simulated clients (--clients, --slo, --stream-depth)\n\
         \x20 resources  Table II resource utilisation report\n\
         \x20 power      power / energy-efficiency report (§IV.D)\n\
         \x20 pipesim    Fig. 3 NN-pipeline cycle simulation\n\
         \x20 info       artifact manifest + PJRT platform\n\n\
         Run `fpps <subcommand> --help` for options."
    );
}

/// Per-job failures are contained by the lane pool (the rest of the
/// batch still completes and is reported above); at the CLI boundary
/// they must still fail the run loudly, like the pre-containment
/// behavior did.
fn fail_on_contained_errors(report: &fpps::coordinator::LaneReport) -> Result<()> {
    // Count from the outcomes — the same source the printed list draws
    // from — so the gate and the list cannot diverge (the lane-stats
    // counters are a per-lane view, not the authority on job failure).
    let failed = report.outcomes.iter().filter(|o| o.is_failed()).count();
    if failed == 0 {
        return Ok(());
    }
    for o in report.outcomes.iter().filter(|o| o.is_failed()) {
        eprintln!("failed: {}", o.error.as_deref().unwrap_or("unknown error"));
    }
    bail!(
        "{failed} of {} jobs failed (remaining jobs completed; see above)",
        report.outcomes.len()
    );
}

/// Resolve the lane-supervision knobs: CLI flags override config-file
/// values (`deadline_ms=`, `retries=`, `failover=`), which override the
/// inert defaults. Without an explicit chain the failover degenerates to
/// the selected backend alone (restarts retry the same tier).
fn supervision_selection(
    a: &fpps::cli::Args,
    rc: &RunConfig,
    kind: BackendKind,
) -> Result<(SupervisorConfig, FailoverChain)> {
    let deadline_ms: u64 = a.get_or("deadline-ms", rc.deadline_ms)?;
    let retries: u32 = a.get_or("retries", rc.retries)?;
    let failover = match a.get_parsed::<FailoverChain>("failover")? {
        Some(chain) => chain,
        None => rc
            .failover
            .clone()
            .unwrap_or_else(|| FailoverChain::single(kind)),
    };
    let sup = SupervisorConfig {
        deadline: (deadline_ms > 0).then(|| std::time::Duration::from_millis(deadline_ms)),
        max_retries: retries,
        ..Default::default()
    };
    Ok((sup, failover))
}

/// One line of supervision context when any knob is engaged, so a run
/// with deadlines/retries/failover is visibly different from a plain one.
fn print_supervision(sup: &SupervisorConfig, failover: &FailoverChain) {
    if sup.deadline.is_none() && sup.max_retries == 0 && failover.tiers() <= 1 {
        return;
    }
    let deadline = match sup.deadline {
        Some(d) => format!("{} ms", d.as_millis()),
        None => "off".to_string(),
    };
    println!(
        "supervision: deadline {deadline}, retries {}, failover {failover}",
        sup.max_retries
    );
}

/// Surface an admission decision — silent shrinking was the old
/// behavior; a map that had to be downsampled to fit its residency slot
/// is now reported, with the hwmodel footprint that forced it.
fn print_admission(label: &str, adm: &fpps::coordinator::AdmissionDecision) {
    if adm.downsampled() {
        println!(
            "admission ({}): {label} of {} pts exceeded the {}-pt residency slot \
             (padded footprint {} KiB) — downsampled to {} pts",
            adm.policy,
            adm.original_points,
            adm.slot_capacity,
            adm.footprint.bytes / 1024,
            adm.admitted_points,
        );
    }
}

fn cmd_align() -> Result<()> {
    let p = Parser::new("fpps align", "register source onto target")
        .opt("source", "source cloud (.bin)", None)
        .opt("target", "target cloud (.bin)", None)
        .opt("max-iterations", "ICP iteration cap", Some("50"))
        .opt("max-dist", "max correspondence distance (m)", Some("1.0"))
        .opt("epsilon", "transformation epsilon", Some("1e-5"))
        .backend_opts();
    let a = p.parse_env(2)?;
    let src = io::read_kitti_bin(
        a.get("source").context("--source required")?.as_ref(),
    )?;
    let tgt = io::read_kitti_bin(
        a.get("target").context("--target required")?.as_ref(),
    )?;
    println!("source: {} pts, target: {} pts", src.len(), tgt.len());

    let max_it: u32 = a.get_or("max-iterations", 50)?;
    let max_d: f32 = a.get_or("max-dist", 1.0)?;
    let eps: f64 = a.get_or("epsilon", 1e-5)?;
    let (kind, artifacts) = backend_selection(&a)?;

    let mut icp = FppsIcp::with_kind(kind, &artifacts)?;
    println!("backend: {}", icp.backend().name());
    icp.set_input_source(src)
        .set_input_target(tgt)
        .set_max_correspondence_distance(max_d)
        .set_max_iteration_count(max_it)
        .set_transformation_epsilon(eps);
    let res = icp.align()?;
    println!(
        "converged={:?} iterations={} rmse={:.4} m total={:.1} ms device={:.1} ms",
        res.stop,
        res.iterations,
        res.rmse,
        res.total_time.as_secs_f64() * 1e3,
        res.device_time.as_secs_f64() * 1e3,
    );
    println!("T =");
    for i in 0..4 {
        println!(
            "  [{:+.6} {:+.6} {:+.6} {:+.6}]",
            res.transformation.m[i][0],
            res.transformation.m[i][1],
            res.transformation.m[i][2],
            res.transformation.m[i][3]
        );
    }
    Ok(())
}

fn cmd_odometry() -> Result<()> {
    let p = Parser::new("fpps odometry", "synthetic-sequence odometry")
        .opt("sequence", "sequence name 00..09", Some("00"))
        .opt("frames", "frames to process", Some("20"))
        .opt("sample", "source sample size", Some("4096"))
        .opt("capacity", "target buffer capacity", Some("16384"))
        .opt("seed", "dataset seed", Some("2026"))
        .flag("full-lidar", "full-resolution 64-beam scan")
        .backend_opts();
    let a = p.parse_env(2)?;
    let name = a.get("sequence").unwrap().to_string();
    let spec = sequence_specs()
        .into_iter()
        .find(|s| s.name == name)
        .with_context(|| format!("unknown sequence {name}"))?;
    let frames: usize = a.get_or("frames", 20)?;
    let seed: u64 = a.get_or("seed", 2026)?;
    let lidar = if a.flag("full-lidar") {
        LidarConfig::default()
    } else {
        LidarConfig {
            beams: 32,
            azimuth_steps: 300,
            ..Default::default()
        }
    };
    let seq = Sequence::synthetic(spec, frames, seed, lidar);
    let cfg = PipelineConfig {
        source_sample: a.get_or("sample", 4096)?,
        target_capacity: a.get_or("capacity", 16_384)?,
        seed,
        ..Default::default()
    };

    let (kind, artifacts) = backend_selection(&a)?;
    let mut icp = FppsIcp::with_kind(kind, &artifacts)?;
    println!("backend: {}", icp.backend().name());
    let res = run_odometry(&seq, frames, cfg, &mut icp)?;
    let gt0 = seq.ground_truth[0];
    let gt: Vec<Mat4> = seq
        .ground_truth
        .iter()
        .map(|p| gt0.inverse_rigid().mul_mat(p))
        .collect();
    let ate = fpps::metrics::absolute_trajectory_error(&res.poses, &gt[..res.poses.len()]);
    println!(
        "sequence {name}: {} frames aligned, mean rmse {:.3} m, ATE {:.3} m",
        res.records.len(),
        res.mean_rmse(),
        ate
    );
    println!(
        "align latency: mean {:.1} ms, p99 {:.1} ms, total {:.1} ms (starvation {:.1} ms)",
        res.align_stats.mean_ms(),
        res.align_stats.percentile_ms(99.0),
        res.align_stats.total_ms(),
        res.starvation_ms
    );
    Ok(())
}

fn cmd_batch() -> Result<()> {
    let p = Parser::new(
        "fpps batch",
        "multi-lane batched registration over synthetic frame pairs",
    )
    .opt("sequence", "sequence name 00..09", Some("05"))
    .opt("pairs", "frame pairs to register", Some("16"))
    .opt("sample", "source sample size", Some("2048"))
    .opt("capacity", "target buffer capacity", Some("8192"))
    .opt("seed", "dataset seed", Some("2026"))
    .lane_opts("1")
    .backend_opts()
    .supervision_opts();
    let a = p.parse_env(2)?;
    let name = a.get("sequence").unwrap().to_string();
    let spec = sequence_specs()
        .into_iter()
        .find(|s| s.name == name)
        .with_context(|| format!("unknown sequence {name}"))?;
    let pairs: usize = a.get_or("pairs", 16)?;
    let seed: u64 = a.get_or("seed", 2026)?;
    let lanes: usize = a.get_or("lanes", 1)?;
    let queue_depth: usize = a.get_or("queue-depth", 4)?;
    let (kind, artifacts) = backend_selection(&a)?;
    let (sup, failover) = supervision_selection(&a, &RunConfig::default(), kind)?;

    let seq = Sequence::synthetic(
        spec,
        pairs + 1,
        seed,
        LidarConfig {
            beams: 32,
            azimuth_steps: 400,
            ..Default::default()
        },
    );
    let cfg = PipelineConfig {
        source_sample: a.get_or("sample", 2048)?,
        target_capacity: a.get_or("capacity", 8192)?,
        seed,
        ..Default::default()
    };
    let jobs = sequence_pair_jobs(&seq, pairs + 1, 0, &cfg)?;
    println!(
        "registering {} frame pairs over {lanes} lane(s), queue depth {queue_depth}",
        jobs.len()
    );
    print_supervision(&sup, &failover);
    let icp_cfg = LaneIcpConfig {
        pool_capacity: a.get_or("pool-capacity", fpps::pool::DEFAULT_RETAIN)?,
        ..Default::default()
    };

    let artifacts = artifacts.as_path();
    let report = run_registration_batch_supervised(
        jobs,
        lanes,
        queue_depth,
        icp_cfg,
        sup,
        |_lane, tier| BackendHandle::create(failover.kind_for_tier(tier), artifacts),
    )?;

    report.lane_table("Per-lane summary").print();
    println!(
        "aggregate: {} jobs in {:.1} ms -> {:.2} jobs/s; service p50 {:.1} ms, p99 {:.1} ms; \
         queue wait mean {:.1} ms",
        report.outcomes.len(),
        report.wall_ms,
        report.jobs_per_s(),
        report.service.percentile_ms(50.0),
        report.service.percentile_ms(99.0),
        report.queue_wait.mean_ms(),
    );
    fail_on_contained_errors(&report)
}

fn cmd_localize() -> Result<()> {
    let p = Parser::new(
        "fpps localize",
        "scan-to-map localization: M scans against one resident map",
    )
    .opt("config", "key=value run config supplying defaults", None)
    .opt("sequence", "sequence name 00..09", Some("03"))
    .opt("scans", "scans to localize (default: config `scans`, 16)", None)
    .opt("sample", "source sample size (default: config `source_sample`)", None)
    .opt("capacity", "map capacity (default: config `target_capacity`)", None)
    .opt("seed", "dataset seed (default: config `seed`)", None)
    .opt("lanes", "worker lanes (default: config `lanes`)", None)
    .opt("queue-depth", "bounded job-queue depth", Some("4"))
    .opt(
        "pool-capacity",
        "staging buffers retained per capacity class (default: config `pool_capacity`)",
        None,
    )
    .residency_opts()
    .backend_opts()
    .supervision_opts();
    let a = p.parse_env(2)?;
    let name = a.get("sequence").unwrap().to_string();
    let spec = sequence_specs()
        .into_iter()
        .find(|s| s.name == name)
        .with_context(|| format!("unknown sequence {name}"))?;
    // Config file (if any) supplies the defaults; CLI flags override.
    let rc = match a.get("config") {
        Some(path) => RunConfig::from_kv(&KvConfig::load(std::path::Path::new(path))?)?,
        None => RunConfig::default(),
    };
    let scans: usize = a.get_or("scans", rc.scans)?;
    let seed: u64 = a.get_or("seed", rc.seed)?;
    let lanes: usize = a.get_or("lanes", rc.lanes)?;
    let queue_depth: usize = a.get_or("queue-depth", 4)?;
    let tiles: usize = a.get_or("tiles", rc.tiles)?;
    let slots: usize = a.get_or("slots", rc.residency_slots)?;
    // Oversized-map policy: CLI flag > config `admission=` > default
    // (explicit downsample-to-fit).
    let admission = a.get_or("admission", rc.admission)?;
    // NN index selection: CLI flag > config `nn_strategy=` > default
    // (exact kd-tree, bit-identical to the pre-grid path).
    let nn_strategy = a.get_or("nn-strategy", rc.nn_strategy)?;
    let (kind, artifacts) = backend_selection(&a)?;
    let (sup, failover) = supervision_selection(&a, &rc, kind)?;

    let seq = Sequence::synthetic(
        spec,
        scans,
        seed,
        LidarConfig {
            beams: 32,
            azimuth_steps: 400,
            ..Default::default()
        },
    );
    let cfg = PipelineConfig {
        source_sample: a.get_or("sample", rc.source_sample)?,
        target_capacity: a.get_or("capacity", rc.target_capacity)?,
        seed,
        admission,
        ..Default::default()
    };
    let icp_cfg = LaneIcpConfig {
        max_correspondence_distance: rc.max_correspondence_distance,
        max_iteration_count: rc.max_iterations,
        transformation_epsilon: rc.transformation_epsilon,
        pool_capacity: a.get_or("pool-capacity", rc.pool_capacity)?,
    };

    let artifacts = artifacts.as_path();
    print_supervision(&sup, &failover);
    if nn_strategy != fpps::voxelgrid::NnStrategy::Exact {
        println!("nn strategy: {nn_strategy}");
    }
    // Per-lane backends; `--slots` overrides the hwmodel-derived
    // residency slot count (0 keeps the default), `--nn-strategy`
    // selects the per-target NN index, and the failover chain picks the
    // backend kind for the lane's current degradation tier.
    let failover_ref = &failover;
    let make_backend = |_lane: usize, tier: usize| -> anyhow::Result<BackendHandle> {
        let mut b = BackendHandle::create(failover_ref.kind_for_tier(tier), artifacts)?;
        if slots > 0 {
            b.set_residency_slots(slots);
        }
        b.set_nn_strategy(nn_strategy);
        Ok(b)
    };

    if tiles > 1 {
        // Tile-crossing scenario: submaps interleave A,B,…,A,B,… so a
        // single-slot backend re-uploads every job while the LRU
        // residency set uploads each submap once per serving lane.
        let res = run_tiled_localization_supervised(
            &seq, scans, tiles, &cfg, lanes, queue_depth, icp_cfg, sup, make_backend,
        )?;
        for (t, adm) in res.admissions.iter().enumerate() {
            print_admission(&format!("tile {t} submap"), adm);
        }
        println!(
            "localized {} scans across {} interleaved submap tiles ({} pts) over {lanes} lane(s)",
            res.report.outcomes.len(),
            res.map_points.len(),
            res.map_points
                .iter()
                .map(usize::to_string)
                .collect::<Vec<_>>()
                .join("+"),
        );
        res.report.lane_table("Per-lane summary").print();
        let uploads: usize = res.report.lanes.iter().map(|l| l.target_uploads).sum();
        let hits: usize = res.report.lanes.iter().map(|l| l.target_hits).sum();
        println!(
            "tile residency: {uploads} upload(s), {hits} cache hit(s) for {} boundary-\
             crossing scans — uploads bounded by tiles x lanes, not by scans",
            res.report.outcomes.len()
        );
        println!(
            "localization error: mean {:.3} m, max {:.3} m",
            res.mean_translation_error(),
            res.max_translation_error()
        );
        return fail_on_contained_errors(&res.report);
    }

    let res = run_localization_supervised(
        &seq, scans, &cfg, lanes, queue_depth, icp_cfg, sup, make_backend,
    )?;

    print_admission("map", &res.admission);
    println!(
        "localized {} scans against a {}-point resident map over {lanes} lane(s)",
        res.report.outcomes.len(),
        res.map_points,
    );
    res.report.lane_table("Per-lane summary").print();
    let uploads: usize = res.report.lanes.iter().map(|l| l.target_uploads).sum();
    let hits: usize = res.report.lanes.iter().map(|l| l.target_hits).sum();
    println!(
        "map residency: {uploads} upload(s), {hits} cache hit(s) — the map is shipped \
         per lane, not per scan"
    );
    println!(
        "aggregate: {:.2} jobs/s; service p50 {:.1} ms, p99 {:.1} ms; queue wait mean {:.1} ms",
        res.report.jobs_per_s(),
        res.report.service.percentile_ms(50.0),
        res.report.service.percentile_ms(99.0),
        res.report.queue_wait.mean_ms(),
    );
    println!(
        "localization error: mean {:.3} m, max {:.3} m",
        res.mean_translation_error(),
        res.max_translation_error()
    );
    fail_on_contained_errors(&res.report)
}

fn cmd_serve() -> Result<()> {
    let p = Parser::new(
        "fpps serve",
        "event-driven serving tier: simulated client streams over submission handles",
    )
    .opt("config", "key=value run config supplying defaults", None)
    .opt("sequence", "sequence name 00..09", Some("05"))
    .opt("pairs", "distinct frame pairs shared by all clients", Some("8"))
    .opt("jobs-per-client", "jobs each client submits", Some("1"))
    .opt("sample", "source sample size", Some("1024"))
    .opt("capacity", "target buffer capacity", Some("4096"))
    .opt("seed", "dataset seed", Some("2026"))
    .lane_opts("2")
    .backend_opts()
    .supervision_opts()
    .serving_opts();
    let a = p.parse_env(2)?;
    let rc = match a.get("config") {
        Some(path) => RunConfig::from_kv(&KvConfig::load(std::path::Path::new(path))?)?,
        None => RunConfig::default(),
    };
    let name = a.get("sequence").unwrap().to_string();
    let spec = sequence_specs()
        .into_iter()
        .find(|s| s.name == name)
        .with_context(|| format!("unknown sequence {name}"))?;
    let pairs: usize = a.get_or("pairs", 8)?;
    let jobs_per_client: usize = a.get_or("jobs-per-client", 1)?;
    let seed: u64 = a.get_or("seed", rc.seed)?;
    let lanes: usize = a.get_or("lanes", 2)?;
    let queue_depth: usize = a.get_or("queue-depth", 4)?;
    let clients: usize = a.get_or("clients", rc.clients)?;
    let slo: fpps::coordinator::SloClass = a.get_or("slo", rc.slo)?;
    let stream_depth: usize = a.get_or("stream-depth", rc.stream_depth)?;
    let (kind, artifacts) = backend_selection(&a)?;
    let (sup, failover) = supervision_selection(&a, &rc, kind)?;

    let seq = Sequence::synthetic(
        spec,
        pairs + 1,
        seed,
        LidarConfig {
            beams: 32,
            azimuth_steps: 300,
            ..Default::default()
        },
    );
    let cfg = PipelineConfig {
        source_sample: a.get_or("sample", 1024)?,
        target_capacity: a.get_or("capacity", 4096)?,
        seed,
        ..Default::default()
    };
    // One shared pool of prepared frame pairs; clients submit jobs that
    // reference them by `Arc`, so 10k clients don't mean 10k clouds.
    let base = sequence_pair_jobs(&seq, pairs + 1, 0, &cfg)?;
    println!(
        "serving {clients} client stream(s) x {jobs_per_client} job(s) ({slo}) over {lanes} \
         lane(s), stream depth {stream_depth}"
    );
    print_supervision(&sup, &failover);
    let icp_cfg = LaneIcpConfig {
        pool_capacity: a.get_or("pool-capacity", rc.pool_capacity)?,
        ..Default::default()
    };

    let chain = failover.clone();
    let pool = ServingPool::start(
        lanes,
        queue_depth,
        icp_cfg,
        sup,
        ServingConfig {
            stream_depth,
            ..Default::default()
        },
        move |_lane, tier| BackendHandle::create(chain.kind_for_tier(tier), &artifacts),
    )?;

    let streams: Vec<_> = (0..clients).map(|_| pool.client()).collect();
    let mut handles = Vec::with_capacity(clients * jobs_per_client);
    for k in 0..jobs_per_client {
        for (c, stream) in streams.iter().enumerate() {
            let b = &base[(c + k) % base.len()];
            let mut job = RegistrationJob::new_keyed(
                (c * jobs_per_client + k) as u64,
                c,
                Arc::clone(&b.source),
                Arc::clone(&b.target),
                b.target_key,
                b.initial,
            )
            .with_slo(slo);
            loop {
                match stream.try_submit(job)? {
                    Submission::Accepted(h) | Submission::Shed(h) => {
                        handles.push(h);
                        break;
                    }
                    Submission::Parked(parked) => {
                        // Backpressure: the stream is at depth. Retry
                        // after a beat — lanes drain in the background.
                        job = parked;
                        std::thread::sleep(std::time::Duration::from_micros(200));
                    }
                }
            }
        }
    }
    let report = pool.shutdown()?;
    assert!(
        handles.iter().all(|h| h.is_complete()),
        "shutdown resolves every handle"
    );
    report.class_table().print();
    report.lane_report.lane_table("Per-lane summary").print();
    println!(
        "aggregate: {} completed + {} shed of {} submissions -> {:.2} jobs/s; \
         service p50 {:.1} ms, p99 {:.1} ms",
        report.lane_report.outcomes.len(),
        report.total_shed(),
        handles.len(),
        report.lane_report.jobs_per_s(),
        report.lane_report.service.percentile_ms(50.0),
        report.lane_report.service.percentile_ms(99.0),
    );
    fail_on_contained_errors(&report.lane_report)
}

fn cmd_resources() -> Result<()> {
    let cfg = AcceleratorConfig::default();
    let rep = resources::report(&cfg);
    let mut t = Table::new("TABLE II: FPGA resource usage summary (model)").header(&[
        "Resource",
        "Usage",
        "Utilization on SLR0",
        "Overall Utilization",
        "Paper",
    ]);
    let util = resources::utilisation(&rep.total, &resources::U50);
    let paper = resources::PAPER_TABLE2;
    let rows = [
        ("LUT", rep.total.lut, util[0], paper.lut),
        ("FF", rep.total.ff, util[1], paper.ff),
        ("Block RAM", rep.total.bram_36k, util[2], paper.bram_36k),
        ("DSP", rep.total.dsp, util[3], paper.dsp),
    ];
    for (name, usage, (slr, all), pval) in rows {
        t.row(vec![
            name.into(),
            usage.to_string(),
            report::pct(slr),
            report::pct(all),
            pval.to_string(),
        ]);
    }
    t.print();

    let mut b = Table::new("\nFloorplan breakdown (Fig. 4 substitute)").header(&[
        "Block", "LUT", "FF", "BRAM", "DSP",
    ]);
    for (name, u) in &rep.items {
        b.row(vec![
            name.clone(),
            u.lut.to_string(),
            u.ff.to_string(),
            u.bram_36k.to_string(),
            u.dsp.to_string(),
        ]);
    }
    b.print();
    Ok(())
}

fn cmd_power() -> Result<()> {
    let cfg = AcceleratorConfig::default();
    let rep = power::power_report(&cfg);
    let pm = power::PowerModel::default();
    println!(
        "FPGA static {:.1} W + dynamic {:.1} W (model) + host {:.1} W = {:.1} W total",
        rep.static_w,
        rep.dynamic_w,
        rep.host_w,
        rep.total_w()
    );
    println!("CPU baseline: {:.1} W", pm.cpu_baseline_w);
    let f = latency::frame_latency(&cfg, 4096, 131_072, 20);
    println!(
        "modelled frame: upload {:.2} ms, kernel {:.1} ms, host-svd {:.2} ms -> {:.1} ms",
        f.upload_s * 1e3,
        f.kernel_s * 1e3,
        f.host_svd_s * 1e3,
        f.total_s * 1e3
    );
    for speedup in [4.82, 15.95, 35.36] {
        println!(
            "speedup {speedup:>6.2}x -> efficiency gain {:.2}x (paper: 8.58x @ 15.95x)",
            pm.efficiency_gain(speedup)
        );
    }
    Ok(())
}

fn cmd_pipesim() -> Result<()> {
    let p = Parser::new("fpps pipesim", "Fig. 3 pipeline simulation")
        .opt("source", "source points", Some("4096"))
        .opt("target", "target points", Some("131072"));
    let a = p.parse_env(2)?;
    let n: usize = a.get_or("source", 4096)?;
    let m: usize = a.get_or("target", 131_072)?;
    let cfg = AcceleratorConfig::default();
    let sim = fpps::pipesim::simulate(&cfg, n, m);
    println!(
        "{n} source x {m} target on {}x{} PEs @ {} MHz",
        cfg.pe_rows, cfg.pe_cols, cfg.clock_mhz
    );
    println!(
        "total {} cycles = {:.3} ms (closed-form model: {} cycles)",
        sim.total_cycles,
        sim.seconds(&cfg) * 1e3,
        latency::nn_search_cycles(&cfg, n, m)
    );
    let names = ["read", "distance", "compare", "accumulate"];
    for (name, s) in names.iter().zip(sim.stages.iter()) {
        println!(
            "  {name:<10} busy {:>5.1}%  stall {:>5.1}%  idle {:>5.1}%",
            100.0 * s.busy_cycles as f64 / sim.total_cycles as f64,
            100.0 * s.stall_cycles as f64 / sim.total_cycles as f64,
            100.0 * s.idle_cycles as f64 / sim.total_cycles as f64,
        );
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    let p = Parser::new("fpps info", "artifact + runtime info")
        .opt("artifacts", "artifact directory", Some("artifacts"));
    let a = p.parse_env(2)?;
    let dir: &std::path::Path = a.get("artifacts").unwrap().as_ref();
    match fpps::runtime::Engine::load(dir) {
        Ok(engine) => {
            println!("platform: {}", engine.platform());
            println!("variants:");
            for v in &engine.manifest().variants {
                println!(
                    "  {:<24} n={:<6} m={:<7} blocks {}x{}  {}",
                    v.name,
                    v.n,
                    v.m,
                    v.block_n,
                    v.block_m,
                    v.file.display()
                );
            }
        }
        Err(e) => {
            println!("no artifacts loaded from {}: {e:#}", dir.display());
            println!("run `make artifacts` first, or use --backend native-sim paths");
        }
    }
    Ok(())
}
