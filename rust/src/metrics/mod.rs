//! Measurement utilities: timing statistics, latency histograms, and the
//! trajectory / registration error metrics reported in the paper's
//! evaluation (per-frame latency, registration RMSE, trajectory error).

use crate::math::Mat4;
use std::time::Duration;

/// Online mean/min/max/percentile collector for latencies.
#[derive(Clone, Debug, Default)]
pub struct TimingStats {
    samples_ms: Vec<f64>,
}

impl TimingStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, d: Duration) {
        self.samples_ms.push(d.as_secs_f64() * 1e3);
    }

    pub fn record_ms(&mut self, ms: f64) {
        self.samples_ms.push(ms);
    }

    pub fn count(&self) -> usize {
        self.samples_ms.len()
    }

    pub fn mean_ms(&self) -> f64 {
        if self.samples_ms.is_empty() {
            return 0.0;
        }
        self.samples_ms.iter().sum::<f64>() / self.samples_ms.len() as f64
    }

    pub fn min_ms(&self) -> f64 {
        self.samples_ms.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max_ms(&self) -> f64 {
        self.samples_ms
            .iter()
            .copied()
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// Percentile via nearest-rank on a sorted copy (p in [0, 100]).
    pub fn percentile_ms(&self, p: f64) -> f64 {
        if self.samples_ms.is_empty() {
            return 0.0;
        }
        let mut s = self.samples_ms.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * (s.len() as f64 - 1.0)).round() as usize;
        s[rank.min(s.len() - 1)]
    }

    /// Sum of all samples (total runtime) — used for the paper's
    /// runtime-weighted average speedup (abstract: 15.95×).
    pub fn total_ms(&self) -> f64 {
        self.samples_ms.iter().sum()
    }

    /// Fold another collector's samples into this one — used by the
    /// multi-lane coordinator to merge per-lane stats into the aggregate
    /// report (percentiles stay exact: samples are kept, not summarised).
    pub fn merge(&mut self, other: &TimingStats) {
        self.samples_ms.extend_from_slice(&other.samples_ms);
    }
}

/// Absolute trajectory error: RMS of translational distance between
/// estimated and ground-truth poses (after both start at identity).
pub fn absolute_trajectory_error(estimate: &[Mat4], ground_truth: &[Mat4]) -> f64 {
    assert_eq!(estimate.len(), ground_truth.len());
    if estimate.is_empty() {
        return 0.0;
    }
    let mut sum = 0.0;
    for (e, g) in estimate.iter().zip(ground_truth.iter()) {
        let d = (e.translation() - g.translation()).norm();
        sum += d * d;
    }
    (sum / estimate.len() as f64).sqrt()
}

/// Relative pose error over `delta`-frame intervals: RMS translational
/// drift per interval — the standard KITTI odometry drift metric.
pub fn relative_pose_error(estimate: &[Mat4], ground_truth: &[Mat4], delta: usize) -> f64 {
    assert_eq!(estimate.len(), ground_truth.len());
    if estimate.len() <= delta {
        return 0.0;
    }
    let mut sum = 0.0;
    let mut n = 0usize;
    for i in 0..estimate.len() - delta {
        let e_rel = estimate[i].inverse_rigid().mul_mat(&estimate[i + delta]);
        let g_rel = ground_truth[i]
            .inverse_rigid()
            .mul_mat(&ground_truth[i + delta]);
        let err = g_rel.inverse_rigid().mul_mat(&e_rel);
        let d = err.translation().norm();
        sum += d * d;
        n += 1;
    }
    (sum / n as f64).sqrt()
}

/// Speedup helpers for Table IV.
pub fn speedup(cpu_ms: f64, accel_ms: f64) -> f64 {
    cpu_ms / accel_ms
}

/// Runtime-weighted average speedup across sequences — the abstract's
/// "runtime-weighted average of 15.95×": total CPU time / total
/// accelerated time (so long sequences weigh more).
pub fn runtime_weighted_speedup(cpu_ms: &[f64], accel_ms: &[f64]) -> f64 {
    assert_eq!(cpu_ms.len(), accel_ms.len());
    let num: f64 = cpu_ms.iter().sum();
    let den: f64 = accel_ms.iter().sum();
    num / den
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::{Mat3, Vec3};

    #[test]
    fn timing_stats_basic() {
        let mut t = TimingStats::new();
        for ms in [1.0, 2.0, 3.0, 4.0, 10.0] {
            t.record_ms(ms);
        }
        assert_eq!(t.count(), 5);
        assert!((t.mean_ms() - 4.0).abs() < 1e-12);
        assert_eq!(t.min_ms(), 1.0);
        assert_eq!(t.max_ms(), 10.0);
        assert_eq!(t.percentile_ms(50.0), 3.0);
        assert_eq!(t.percentile_ms(100.0), 10.0);
        assert!((t.total_ms() - 20.0).abs() < 1e-12);
    }

    #[test]
    fn timing_stats_empty() {
        let t = TimingStats::new();
        assert_eq!(t.mean_ms(), 0.0);
        assert_eq!(t.percentile_ms(99.0), 0.0);
    }

    #[test]
    fn percentiles_on_empty_input_are_zero() {
        // The serving report reads p50/p99/p999 for classes that may
        // have no completions — all must be a clean 0.0, never a panic.
        let t = TimingStats::new();
        for p in [0.0, 50.0, 99.0, 99.9, 100.0] {
            assert_eq!(t.percentile_ms(p), 0.0, "p{p} on empty input");
        }
    }

    #[test]
    fn percentiles_on_single_sample_return_it() {
        let mut t = TimingStats::new();
        t.record_ms(7.5);
        for p in [0.0, 50.0, 99.0, 99.9, 100.0] {
            assert_eq!(t.percentile_ms(p), 7.5, "p{p} of one sample");
        }
    }

    #[test]
    fn percentiles_on_tied_values_return_the_tie() {
        let mut t = TimingStats::new();
        for _ in 0..100 {
            t.record_ms(3.0);
        }
        for p in [50.0, 99.0, 99.9] {
            assert_eq!(t.percentile_ms(p), 3.0, "p{p} of 100 tied samples");
        }
        // One outlier: the tail percentiles find it, the median ignores it.
        t.record_ms(42.0);
        assert_eq!(t.percentile_ms(50.0), 3.0);
        assert_eq!(t.percentile_ms(99.9), 42.0);
        assert_eq!(t.percentile_ms(100.0), 42.0);
    }

    #[test]
    fn tail_percentiles_use_nearest_rank() {
        // 0, 1, …, 999 ms: nearest-rank on (p/100)·(n−1) — p50 rounds
        // 499.5 up to index 500, p99 hits 989.01 → 989, p99.9 hits
        // 998.001 → 998.
        let mut t = TimingStats::new();
        for ms in 0..1000 {
            t.record_ms(ms as f64);
        }
        assert_eq!(t.percentile_ms(0.0), 0.0);
        assert_eq!(t.percentile_ms(50.0), 500.0);
        assert_eq!(t.percentile_ms(99.0), 989.0);
        assert_eq!(t.percentile_ms(99.9), 998.0);
        assert_eq!(t.percentile_ms(100.0), 999.0);
    }

    #[test]
    fn merge_preserves_exact_percentiles() {
        let mut a = TimingStats::new();
        let mut b = TimingStats::new();
        for ms in [1.0, 5.0, 9.0] {
            a.record_ms(ms);
        }
        for ms in [2.0, 3.0] {
            b.record_ms(ms);
        }
        a.merge(&b);
        assert_eq!(a.count(), 5);
        assert_eq!(a.percentile_ms(50.0), 3.0);
        assert_eq!(a.max_ms(), 9.0);
        assert!((a.total_ms() - 20.0).abs() < 1e-12);
        // Merging an empty collector is a no-op.
        a.merge(&TimingStats::new());
        assert_eq!(a.count(), 5);
    }

    #[test]
    fn ate_zero_for_identical() {
        let traj: Vec<Mat4> = (0..10)
            .map(|i| Mat4::from_rt(Mat3::rot_z(0.01 * i as f64), Vec3::new(i as f64, 0.0, 0.0)))
            .collect();
        assert_eq!(absolute_trajectory_error(&traj, &traj), 0.0);
        assert_eq!(relative_pose_error(&traj, &traj, 1), 0.0);
    }

    #[test]
    fn ate_constant_offset() {
        let gt: Vec<Mat4> = (0..5)
            .map(|i| Mat4::from_rt(Mat3::IDENTITY, Vec3::new(i as f64, 0.0, 0.0)))
            .collect();
        let est: Vec<Mat4> = gt
            .iter()
            .map(|t| Mat4::from_rt(Mat3::IDENTITY, t.translation() + Vec3::new(0.0, 3.0, 4.0)))
            .collect();
        // Each pose off by 5 → RMS is 5.
        assert!((absolute_trajectory_error(&est, &gt) - 5.0).abs() < 1e-12);
        // But relative error is zero (constant offset cancels).
        assert!(relative_pose_error(&est, &gt, 1) < 1e-12);
    }

    #[test]
    fn rpe_catches_drift() {
        let gt: Vec<Mat4> = (0..10)
            .map(|i| Mat4::from_rt(Mat3::IDENTITY, Vec3::new(i as f64, 0.0, 0.0)))
            .collect();
        // Estimated trajectory drifts 0.1 m per frame laterally.
        let est: Vec<Mat4> = (0..10)
            .map(|i| Mat4::from_rt(Mat3::IDENTITY, Vec3::new(i as f64, 0.1 * i as f64, 0.0)))
            .collect();
        let rpe = relative_pose_error(&est, &gt, 1);
        assert!((rpe - 0.1).abs() < 1e-9, "rpe={rpe}");
    }

    #[test]
    fn weighted_speedup_matches_paper_semantics() {
        // Two sequences: one long slow, one short fast.
        let cpu = [1000.0, 100.0];
        let acc = [100.0, 50.0];
        let w = runtime_weighted_speedup(&cpu, &acc);
        assert!((w - 1100.0 / 150.0).abs() < 1e-12);
        assert_eq!(speedup(100.0, 10.0), 10.0);
    }
}
