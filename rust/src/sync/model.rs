//! In-repo loom-style model checker backing the `--cfg loom` build of
//! [`crate::sync`].
//!
//! The checker executes a closure many times under a deterministic
//! token-passing scheduler: model threads are real OS threads, but exactly
//! one holds the "token" at any instant, and every synchronization
//! operation (atomic access, mutex lock/unlock, condvar wait/notify,
//! `UnsafeCell` access, spawn/join) is a *schedule point* where the token
//! may move. The driver records the choice made at each schedule point and
//! backtracks depth-first, so the interleaving space is explored
//! exhaustively up to a preemption bound (default 2, the empirically
//! effective bound from context-bounded model checking; raise it per-model
//! via [`Builder`]).
//!
//! Happens-before is tracked with vector clocks following the usual
//! release/acquire rules (Relaxed stores break release sequences; RMWs
//! extend them; SeqCst folds through a global fence clock). Every access
//! through [`UnsafeCell::with`]/[`UnsafeCell::with_mut`] is checked
//! against the recorded reader/writer clocks and panics with a
//! `data race detected` message when unordered.
//!
//! **What this proves / does not prove.** Execution is sequentially
//! consistent: the checker detects *missing happens-before edges* (the
//! bug class behind torn reads and missed wakeups) via the race detector,
//! and checks exactly-once / no-lost-item invariants over all bounded
//! interleavings, but it does not simulate weak-memory *value* reordering
//! the way the external loom crate's C11 model does. Non-SeqCst fences
//! are treated as SeqCst (conservative for the ring, whose only fence is
//! SeqCst). `compare_exchange_weak` never fails spuriously. If a vendored
//! loom checkout is ever added, `crate::sync` can re-point at it without
//! touching the models.
//!
//! Deadlocks (no runnable thread, no timed waiter) and livelocks (step
//! budget exceeded) abort the execution with a descriptive panic.
//! `Condvar::wait_timeout` deadlines fire only at quiescence — when no
//! other thread can run — which keeps the schedule space small while
//! still letting backstop-timeout code paths execute.

use std::any::Any;
use std::cell::RefCell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::AtomicBool as RealAtomicBool;
use std::sync::atomic::AtomicU64 as RealAtomicU64;
use std::sync::atomic::AtomicUsize as RealAtomicUsize;
use std::sync::{Arc as StdArc, Condvar as StdCondvar, Mutex as StdMutex};
use std::sync::{LockResult, MutexGuard as StdMutexGuard};
use std::time::Duration;

pub use std::sync::atomic::Ordering;

/// Lock a std mutex, ignoring poison (an aborted model execution may have
/// panicked while holding internal metadata locks; the data is still
/// consistent because only one model thread runs at a time).
fn plock<T>(m: &StdMutex<T>) -> StdMutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

// ---------------------------------------------------------------------------
// Vector clocks
// ---------------------------------------------------------------------------

/// Per-thread vector clock; index = model thread id.
#[derive(Clone, Debug, Default)]
struct Clock(Vec<u64>);

impl Clock {
    const fn new_const() -> Self {
        Clock(Vec::new())
    }

    fn get(&self, tid: usize) -> u64 {
        self.0.get(tid).copied().unwrap_or(0)
    }

    fn reserve_tid(&mut self, tid: usize) {
        if self.0.len() <= tid {
            self.0.resize(tid + 1, 0);
        }
    }

    fn bump(&mut self, tid: usize) {
        self.reserve_tid(tid);
        self.0[tid] += 1;
    }

    fn set_max(&mut self, tid: usize, v: u64) {
        self.reserve_tid(tid);
        if v > self.0[tid] {
            self.0[tid] = v;
        }
    }

    fn join(&mut self, other: &Clock) {
        if self.0.len() < other.0.len() {
            self.0.resize(other.0.len(), 0);
        }
        for (i, v) in other.0.iter().enumerate() {
            if *v > self.0[i] {
                self.0[i] = *v;
            }
        }
    }

    /// `self` happens-before-or-equal `other` (component-wise <=).
    fn le(&self, other: &Clock) -> bool {
        self.0.iter().enumerate().all(|(i, v)| *v <= other.get(i))
    }

    fn clear(&mut self) {
        self.0.clear();
    }
}

// ---------------------------------------------------------------------------
// Scheduler state
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Wake {
    Notified,
    TimedOut,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Run {
    Runnable,
    BlockedMutex(usize),
    BlockedCondvar { cv: usize, timed: bool },
    BlockedJoin(usize),
    Finished,
}

#[derive(Debug)]
struct ThreadMeta {
    run: Run,
    clock: Clock,
    wake: Option<Wake>,
}

#[derive(Debug, Default)]
struct SchedState {
    /// Thread currently holding the token; `None` while the scheduler picks.
    active: Option<usize>,
    threads: Vec<ThreadMeta>,
    /// Global SeqCst fence clock (all SeqCst ops fold through it).
    fence_clock: Clock,
    aborted: bool,
    panic_payload: Option<Box<dyn Any + Send>>,
    steps_taken: usize,
    max_steps: usize,
}

struct ExecCtx {
    state: StdMutex<SchedState>,
    cv: StdCondvar,
}

/// Sentinel panic payload used to unwind model threads during abort
/// teardown; never surfaced to the user.
struct ModelAbort;

thread_local! {
    static CTX: RefCell<Option<(StdArc<ExecCtx>, usize)>> = const { RefCell::new(None) };
}

/// Current model context, or `None` outside a model run or while the
/// thread is unwinding (all model ops fall back to plain `std` behavior
/// in both cases, so guard/buffer `Drop`s during teardown stay sound).
fn cur_ctx() -> Option<(StdArc<ExecCtx>, usize)> {
    if std::thread::panicking() {
        return None;
    }
    CTX.with(|c| c.borrow().clone())
}

fn with_state<R>(ctx: &ExecCtx, f: impl FnOnce(&mut SchedState) -> R) -> R {
    f(&mut plock(&ctx.state))
}

fn abort_panic() -> ! {
    std::panic::panic_any(ModelAbort)
}

/// Charge one step against the livelock budget; abort when exhausted.
fn charge_step(ctx: &ExecCtx, st: &mut SchedState) {
    st.steps_taken += 1;
    if st.steps_taken > st.max_steps {
        st.aborted = true;
        if st.panic_payload.is_none() {
            st.panic_payload = Some(Box::new(format!(
                "model: step budget ({}) exceeded — livelock or unbounded spin in model",
                st.max_steps
            )));
        }
        ctx.cv.notify_all();
    }
}

/// Schedule point: bump the caller's clock, hand the token back to the
/// scheduler, and wait to be granted it again.
fn sched_point(ctx: &ExecCtx, me: usize) {
    let mut st = plock(&ctx.state);
    if st.aborted {
        drop(st);
        abort_panic();
    }
    st.threads[me].clock.bump(me);
    charge_step(ctx, &mut st);
    if st.aborted {
        drop(st);
        abort_panic();
    }
    st.active = None;
    ctx.cv.notify_all();
    loop {
        st = ctx.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        if st.aborted {
            drop(st);
            abort_panic();
        }
        if st.active == Some(me) {
            return;
        }
    }
}

/// Like [`sched_point`] but parks the caller in `run` (a blocked state)
/// until another thread wakes it and the scheduler grants the token.
fn block_current(ctx: &ExecCtx, me: usize, run: Run) {
    let mut st = plock(&ctx.state);
    if st.aborted {
        drop(st);
        abort_panic();
    }
    st.threads[me].clock.bump(me);
    charge_step(ctx, &mut st);
    if st.aborted {
        drop(st);
        abort_panic();
    }
    st.threads[me].run = run;
    st.active = None;
    ctx.cv.notify_all();
    loop {
        st = ctx.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        if st.aborted {
            drop(st);
            abort_panic();
        }
        if st.active == Some(me) {
            return;
        }
    }
}

/// Schedule point if inside a model run, no-op otherwise.
fn model_point() {
    if let Some((ctx, me)) = cur_ctx() {
        sched_point(&ctx, me);
    }
}

// ---------------------------------------------------------------------------
// Atomics
// ---------------------------------------------------------------------------

#[derive(Clone, Copy)]
enum AtomKind {
    Load,
    Store,
    Rmw,
}

// ordering: classification helpers — these lines name every ordering
// variant to route it to the right vector-clock rule, not to perform an
// access themselves.
fn is_acquire(order: Ordering) -> bool {
    matches!(order, Ordering::Acquire | Ordering::AcqRel | Ordering::SeqCst)
}

// ordering: see is_acquire above.
fn is_release(order: Ordering) -> bool {
    matches!(order, Ordering::Release | Ordering::AcqRel | Ordering::SeqCst)
}

/// Apply the vector-clock happens-before rules for one atomic access.
/// Does NOT take a schedule point (callers do that first, so composite
/// ops like compare-exchange stay one schedule point).
fn atomic_hb(sync: &StdMutex<Clock>, order: Ordering, kind: AtomKind) {
    let Some((ctx, me)) = cur_ctx() else { return };
    let mut tc = with_state(&ctx, |st| st.threads[me].clock.clone());
    if matches!(order, Ordering::SeqCst) {
        // SeqCst: fold through the global fence clock both ways.
        with_state(&ctx, |st| {
            tc.join(&st.fence_clock);
            st.fence_clock.join(&tc);
        });
    }
    {
        let mut sc = plock(sync);
        match kind {
            AtomKind::Load => {
                if is_acquire(order) {
                    tc.join(&sc);
                }
            }
            AtomKind::Store => {
                if is_release(order) {
                    *sc = tc.clone();
                } else {
                    // Relaxed store: breaks the release sequence.
                    sc.clear();
                }
            }
            AtomKind::Rmw => {
                if is_acquire(order) {
                    tc.join(&sc);
                }
                if is_release(order) {
                    // Join (not replace): an RMW extends the release
                    // sequence of the store it read from.
                    sc.join(&tc);
                }
            }
        }
    }
    with_state(&ctx, |st| st.threads[me].clock = tc);
}

macro_rules! model_atomic_int {
    ($(#[$doc:meta])* $name:ident, $ty:ty, $real:ty) => {
        $(#[$doc])*
        ///
        /// Values live in a real (SeqCst) atomic so teardown-time accesses
        /// from unwinding threads stay sound; ordering arguments feed the
        /// vector-clock happens-before tracking only.
        #[derive(Debug, Default)]
        pub struct $name {
            v: $real,
            sync: StdMutex<Clock>,
        }

        impl $name {
            /// Creates a new atomic with the given initial value.
            pub const fn new(v: $ty) -> Self {
                Self {
                    v: <$real>::new(v),
                    sync: StdMutex::new(Clock::new_const()),
                }
            }

            /// Model-checked `load`.
            pub fn load(&self, order: Ordering) -> $ty {
                model_point();
                atomic_hb(&self.sync, order, AtomKind::Load);
                self.v.load(Ordering::SeqCst)
            }

            /// Model-checked `store`.
            pub fn store(&self, val: $ty, order: Ordering) {
                model_point();
                atomic_hb(&self.sync, order, AtomKind::Store);
                self.v.store(val, Ordering::SeqCst)
            }

            /// Model-checked `swap`.
            pub fn swap(&self, val: $ty, order: Ordering) -> $ty {
                model_point();
                atomic_hb(&self.sync, order, AtomKind::Rmw);
                self.v.swap(val, Ordering::SeqCst)
            }

            /// Model-checked `fetch_add`.
            pub fn fetch_add(&self, val: $ty, order: Ordering) -> $ty {
                model_point();
                atomic_hb(&self.sync, order, AtomKind::Rmw);
                self.v.fetch_add(val, Ordering::SeqCst)
            }

            /// Model-checked `fetch_sub`.
            pub fn fetch_sub(&self, val: $ty, order: Ordering) -> $ty {
                model_point();
                atomic_hb(&self.sync, order, AtomKind::Rmw);
                self.v.fetch_sub(val, Ordering::SeqCst)
            }

            /// Model-checked `compare_exchange`.
            pub fn compare_exchange(
                &self,
                current: $ty,
                new: $ty,
                success: Ordering,
                failure: Ordering,
            ) -> Result<$ty, $ty> {
                if cur_ctx().is_some() {
                    model_point();
                    let prev = self.v.load(Ordering::SeqCst);
                    if prev == current {
                        self.v.store(new, Ordering::SeqCst);
                        atomic_hb(&self.sync, success, AtomKind::Rmw);
                        Ok(prev)
                    } else {
                        atomic_hb(&self.sync, failure, AtomKind::Load);
                        Err(prev)
                    }
                } else {
                    self.v
                        .compare_exchange(current, new, Ordering::SeqCst, Ordering::SeqCst)
                }
            }

            /// Model-checked `compare_exchange_weak`. Never fails
            /// spuriously (documented model limitation; retry loops in
            /// production code tolerate the extra success schedules).
            pub fn compare_exchange_weak(
                &self,
                current: $ty,
                new: $ty,
                success: Ordering,
                failure: Ordering,
            ) -> Result<$ty, $ty> {
                self.compare_exchange(current, new, success, failure)
            }
        }
    };
}

model_atomic_int!(
    /// Model-checked stand-in for `std::sync::atomic::AtomicUsize`.
    AtomicUsize,
    usize,
    RealAtomicUsize
);
model_atomic_int!(
    /// Model-checked stand-in for `std::sync::atomic::AtomicU64`.
    AtomicU64,
    u64,
    RealAtomicU64
);
/// Model-checked stand-in for `std::sync::atomic::AtomicBool`.
///
/// Values live in a real (SeqCst) atomic so teardown-time accesses from
/// unwinding threads stay sound; ordering arguments feed the vector-clock
/// happens-before tracking only.
#[derive(Debug, Default)]
pub struct AtomicBool {
    v: RealAtomicBool,
    sync: StdMutex<Clock>,
}

impl AtomicBool {
    /// Creates a new atomic with the given initial value.
    pub const fn new(v: bool) -> Self {
        Self {
            v: RealAtomicBool::new(v),
            sync: StdMutex::new(Clock::new_const()),
        }
    }

    /// Model-checked `load`.
    pub fn load(&self, order: Ordering) -> bool {
        model_point();
        atomic_hb(&self.sync, order, AtomKind::Load);
        self.v.load(Ordering::SeqCst)
    }

    /// Model-checked `store`.
    pub fn store(&self, val: bool, order: Ordering) {
        model_point();
        atomic_hb(&self.sync, order, AtomKind::Store);
        self.v.store(val, Ordering::SeqCst)
    }

    /// Model-checked `swap`.
    pub fn swap(&self, val: bool, order: Ordering) -> bool {
        model_point();
        atomic_hb(&self.sync, order, AtomKind::Rmw);
        self.v.swap(val, Ordering::SeqCst)
    }

    /// Model-checked `compare_exchange`.
    pub fn compare_exchange(
        &self,
        current: bool,
        new: bool,
        success: Ordering,
        failure: Ordering,
    ) -> Result<bool, bool> {
        if cur_ctx().is_some() {
            model_point();
            let prev = self.v.load(Ordering::SeqCst);
            if prev == current {
                self.v.store(new, Ordering::SeqCst);
                atomic_hb(&self.sync, success, AtomKind::Rmw);
                Ok(prev)
            } else {
                atomic_hb(&self.sync, failure, AtomKind::Load);
                Err(prev)
            }
        } else {
            self.v
                .compare_exchange(current, new, Ordering::SeqCst, Ordering::SeqCst)
        }
    }

    /// Model-checked `compare_exchange_weak` (never fails spuriously).
    pub fn compare_exchange_weak(
        &self,
        current: bool,
        new: bool,
        success: Ordering,
        failure: Ordering,
    ) -> Result<bool, bool> {
        self.compare_exchange(current, new, success, failure)
    }
}

/// Model-checked stand-in for `std::sync::atomic::fence`.
///
/// All fences are treated as SeqCst (joining both ways with the global
/// fence clock) — conservative but exact for this codebase, whose only
/// fences are SeqCst.
pub fn fence(order: Ordering) {
    if let Some((ctx, me)) = cur_ctx() {
        sched_point(&ctx, me);
        with_state(&ctx, |st| {
            let mut tc = st.threads[me].clock.clone();
            tc.join(&st.fence_clock);
            st.fence_clock.join(&tc);
            st.threads[me].clock = tc;
        });
    } else {
        std::sync::atomic::fence(order);
    }
}

// ---------------------------------------------------------------------------
// Race-checked UnsafeCell
// ---------------------------------------------------------------------------

#[derive(Debug, Default)]
struct CellMeta {
    writer: Option<Clock>,
    readers: Clock,
}

/// Race-checked stand-in for `std::cell::UnsafeCell` with loom's
/// `with`/`with_mut` accessor API.
///
/// Every access records the accessing thread's vector clock; a read that
/// is not ordered after the last write, or a write not ordered after all
/// prior reads and the last write, panics with `data race detected`.
#[derive(Debug, Default)]
pub struct UnsafeCell<T> {
    data: std::cell::UnsafeCell<T>,
    meta: StdMutex<CellMeta>,
}

// SAFETY: accesses are serialized by the model's token-passing scheduler
// (exactly one model thread runs at a time) and cross-thread visibility
// is validated by the vector-clock race detector, which panics before an
// unordered access reaches the data. Teardown-time accesses only happen
// while unwinding after the execution has been aborted, when no other
// model thread is granted the token.
unsafe impl<T: Send> Send for UnsafeCell<T> {}
// SAFETY: see the `Send` impl above; the same serialization argument
// covers shared references.
unsafe impl<T: Send> Sync for UnsafeCell<T> {}

impl<T> UnsafeCell<T> {
    /// Wraps a value.
    pub const fn new(v: T) -> Self {
        Self {
            data: std::cell::UnsafeCell::new(v),
            meta: StdMutex::new(CellMeta {
                writer: None,
                readers: Clock::new_const(),
            }),
        }
    }

    /// Unwraps the value.
    pub fn into_inner(self) -> T {
        self.data.into_inner()
    }

    /// Calls `f` with a shared raw pointer to the contents, after
    /// checking the access races with no prior write.
    pub fn with<R>(&self, f: impl FnOnce(*const T) -> R) -> R {
        self.track(false);
        f(self.data.get())
    }

    /// Calls `f` with an exclusive raw pointer to the contents, after
    /// checking the access races with no prior read or write.
    pub fn with_mut<R>(&self, f: impl FnOnce(*mut T) -> R) -> R {
        self.track(true);
        f(self.data.get())
    }

    fn track(&self, write: bool) {
        let Some((ctx, me)) = cur_ctx() else { return };
        sched_point(&ctx, me);
        let tc = with_state(&ctx, |st| st.threads[me].clock.clone());
        let mut meta = plock(&self.meta);
        let write_ok = meta.writer.as_ref().is_none_or(|w| w.le(&tc));
        let reads_ok = !write || meta.readers.le(&tc);
        if !(write_ok && reads_ok) {
            drop(meta);
            panic!(
                "model: data race detected on UnsafeCell — prior {} is not \
                 ordered before this {}",
                if write_ok { "read" } else { "write" },
                if write { "write" } else { "read" },
            );
        }
        if write {
            meta.writer = Some(tc.clone());
            meta.readers.clear();
        } else {
            meta.readers.set_max(me, tc.get(me));
        }
    }
}

// ---------------------------------------------------------------------------
// Mutex + Condvar
// ---------------------------------------------------------------------------

/// Unique ids for mutexes/condvars (blocking bookkeeping). Monotonic per
/// process, so ids never collide across concurrently running models.
static NEXT_SYNC_ID: RealAtomicUsize = RealAtomicUsize::new(1);

fn next_sync_id() -> usize {
    // ordering: a plain unique-id counter — no data is published through
    // it, so no ordering is required.
    NEXT_SYNC_ID.fetch_add(1, Ordering::Relaxed)
}

#[derive(Debug, Default)]
struct MutexMeta {
    held: bool,
    clock: Clock,
}

/// Model-checked stand-in for `std::sync::Mutex`.
///
/// Logical mutual exclusion (who may hold the lock, in what order) is
/// decided by the model scheduler; the data itself additionally sits in a
/// real `std` mutex so teardown-time accesses from unwinding threads stay
/// sound.
#[derive(Debug)]
pub struct Mutex<T> {
    id: usize,
    meta: StdMutex<MutexMeta>,
    data: StdMutex<T>,
}

impl<T> Mutex<T> {
    /// Creates a new mutex.
    pub fn new(t: T) -> Self {
        Self {
            id: next_sync_id(),
            meta: StdMutex::new(MutexMeta::default()),
            data: StdMutex::new(t),
        }
    }

    /// Model-checked `lock`. Never returns `Err` (the model has no
    /// poisoning), but keeps the `LockResult` signature so call sites
    /// written against `std` compile unchanged.
    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        if let Some((ctx, me)) = cur_ctx() {
            loop {
                sched_point(&ctx, me);
                let acquired = {
                    let mut meta = plock(&self.meta);
                    if meta.held {
                        false
                    } else {
                        meta.held = true;
                        let clock = meta.clock.clone();
                        with_state(&ctx, |st| st.threads[me].clock.join(&clock));
                        true
                    }
                };
                if acquired {
                    break;
                }
                block_current(&ctx, me, Run::BlockedMutex(self.id));
            }
        }
        let inner = self.data.lock().unwrap_or_else(|e| e.into_inner());
        Ok(MutexGuard {
            lock: self,
            inner: Some(inner),
        })
    }

    /// Release bookkeeping shared by guard drop and condvar wait: clears
    /// the logical hold, transfers the releasing thread's clock onto the
    /// mutex, and wakes blocked lockers. No schedule point.
    fn release_logical(&self) {
        if let Some((ctx, me)) = cur_ctx() {
            {
                let mut meta = plock(&self.meta);
                meta.held = false;
                meta.clock = with_state(&ctx, |st| st.threads[me].clock.clone());
            }
            with_state(&ctx, |st| {
                for t in st.threads.iter_mut() {
                    if t.run == Run::BlockedMutex(self.id) {
                        t.run = Run::Runnable;
                    }
                }
            });
        }
    }
}

/// Guard returned by [`Mutex::lock`]; releases on drop like `std`'s.
#[derive(Debug)]
pub struct MutexGuard<'a, T> {
    lock: &'a Mutex<T>,
    inner: Option<StdMutexGuard<'a, T>>,
}

impl<T> std::ops::Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().expect("model guard already released")
    }
}

impl<T> std::ops::DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("model guard already released")
    }
}

impl<T> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        // Release the real lock first so unwinding threads can make
        // progress, then (outside unwinds) the logical one.
        self.inner.take();
        if std::thread::panicking() {
            return;
        }
        self.lock.release_logical();
        model_point();
    }
}

/// Result of a [`Condvar::wait_timeout`], mirroring `std`'s.
#[derive(Clone, Copy, Debug)]
pub struct WaitTimeoutResult(bool);

impl WaitTimeoutResult {
    /// True when the wait ended because the (model) timeout fired.
    pub fn timed_out(&self) -> bool {
        self.0
    }
}

/// Model-checked stand-in for `std::sync::Condvar`.
///
/// Timed waits have no real deadline: they are woken as `TimedOut` only
/// at quiescence, when no other model thread can run. Untimed waits that
/// are never notified surface as a model deadlock panic.
#[derive(Debug)]
pub struct Condvar {
    id: usize,
    clock: StdMutex<Clock>,
}

impl Default for Condvar {
    fn default() -> Self {
        Self::new()
    }
}

impl Condvar {
    /// Creates a new condition variable.
    pub fn new() -> Self {
        Self {
            id: next_sync_id(),
            clock: StdMutex::new(Clock::new_const()),
        }
    }

    fn wait_inner<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        timed: bool,
    ) -> (MutexGuard<'a, T>, WaitTimeoutResult) {
        let Some((ctx, me)) = cur_ctx() else {
            // Outside a model run (teardown): behave as a spurious wake /
            // immediate timeout.
            return (guard, WaitTimeoutResult(timed));
        };
        let lock = guard.lock;
        let mut guard = guard;
        // Atomically (no schedule point in between) drop the real lock,
        // release the logical lock, and park as a condvar waiter — so the
        // model cannot itself lose a wakeup between unlock and park.
        guard.inner.take();
        lock.release_logical();
        std::mem::forget(guard);
        block_current(&ctx, me, Run::BlockedCondvar { cv: self.id, timed });
        let reason = with_state(&ctx, |st| st.threads[me].wake.take());
        let timed_out = match reason {
            Some(Wake::Notified) => {
                let cvc = plock(&self.clock).clone();
                with_state(&ctx, |st| st.threads[me].clock.join(&cvc));
                false
            }
            _ => true,
        };
        let reacquired = lock.lock().unwrap_or_else(|e| e.into_inner());
        (reacquired, WaitTimeoutResult(timed_out))
    }

    /// Model-checked `wait`.
    pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
        let (g, _) = self.wait_inner(guard, false);
        Ok(g)
    }

    /// Model-checked `wait_timeout`; the duration is ignored (model time).
    pub fn wait_timeout<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        _dur: Duration,
    ) -> LockResult<(MutexGuard<'a, T>, WaitTimeoutResult)> {
        Ok(self.wait_inner(guard, true))
    }

    fn notify(&self, all: bool) {
        let Some((ctx, me)) = cur_ctx() else { return };
        {
            let tc = with_state(&ctx, |st| st.threads[me].clock.clone());
            plock(&self.clock).join(&tc);
        }
        with_state(&ctx, |st| {
            for t in st.threads.iter_mut() {
                if let Run::BlockedCondvar { cv, .. } = t.run {
                    if cv == self.id {
                        t.run = Run::Runnable;
                        t.wake = Some(Wake::Notified);
                        if !all {
                            break;
                        }
                    }
                }
            }
        });
        sched_point(&ctx, me);
    }

    /// Model-checked `notify_one` (wakes the lowest-tid waiter).
    pub fn notify_one(&self) {
        self.notify(false);
    }

    /// Model-checked `notify_all`.
    pub fn notify_all(&self) {
        self.notify(true);
    }
}

// ---------------------------------------------------------------------------
// Threads
// ---------------------------------------------------------------------------

/// Model-thread wrapper: installs the TLS context, waits for the first
/// token grant, runs `f` catching unwinds, then marks the thread finished
/// and wakes joiners. User panics abort the whole execution; the
/// [`ModelAbort`] sentinel (teardown) is swallowed.
fn run_model_thread(ctx: StdArc<ExecCtx>, me: usize, f: impl FnOnce()) {
    CTX.with(|c| *c.borrow_mut() = Some((ctx.clone(), me)));
    let skip = {
        let mut st = plock(&ctx.state);
        loop {
            if st.aborted {
                break true;
            }
            if st.active == Some(me) {
                break false;
            }
            st = ctx.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    };
    let payload = if skip {
        None
    } else {
        match catch_unwind(AssertUnwindSafe(f)) {
            Ok(()) => None,
            Err(p) if p.is::<ModelAbort>() => None,
            Err(p) => Some(p),
        }
    };
    {
        let mut st = plock(&ctx.state);
        if let Some(p) = payload {
            if st.panic_payload.is_none() {
                st.panic_payload = Some(p);
            }
            st.aborted = true;
        }
        st.threads[me].run = Run::Finished;
        for t in st.threads.iter_mut() {
            if t.run == Run::BlockedJoin(me) {
                t.run = Run::Runnable;
            }
        }
        if st.active == Some(me) {
            st.active = None;
        }
        ctx.cv.notify_all();
    }
    CTX.with(|c| *c.borrow_mut() = None);
}

/// Model-checked stand-ins for `std::thread` spawn/join/yield.
pub mod thread {
    use super::*;

    /// Handle to a model thread, mirroring `std::thread::JoinHandle`.
    pub struct JoinHandle<T> {
        tid: usize,
        real: Option<std::thread::JoinHandle<()>>,
        result: StdArc<StdMutex<Option<T>>>,
    }

    impl<T> JoinHandle<T> {
        /// Model-checked `join`: blocks (in model time) until the child
        /// finishes, then joins its clock into the caller's.
        pub fn join(mut self) -> std::thread::Result<T> {
            if let Some((ctx, me)) = cur_ctx() {
                loop {
                    sched_point(&ctx, me);
                    let finished =
                        with_state(&ctx, |st| st.threads[self.tid].run == Run::Finished);
                    if finished {
                        with_state(&ctx, |st| {
                            let c = st.threads[self.tid].clock.clone();
                            st.threads[me].clock.join(&c);
                        });
                        break;
                    }
                    block_current(&ctx, me, Run::BlockedJoin(self.tid));
                }
            }
            if let Some(r) = self.real.take() {
                let _ = r.join();
            }
            match plock(&self.result).take() {
                Some(v) => Ok(v),
                None => Err(Box::new("model thread produced no result".to_string())),
            }
        }
    }

    /// Spawn a model thread running `f`. Must be called inside a model.
    pub fn spawn<F, T>(f: F) -> JoinHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        let (ctx, me) = cur_ctx().expect("model thread API used outside a model run");
        sched_point(&ctx, me);
        let result = StdArc::new(StdMutex::new(None));
        let res2 = result.clone();
        let child = with_state(&ctx, |st| {
            let clock = st.threads[me].clock.clone();
            let tid = st.threads.len();
            st.threads.push(ThreadMeta {
                run: Run::Runnable,
                clock,
                wake: None,
            });
            tid
        });
        let ctx2 = ctx.clone();
        let real = std::thread::spawn(move || {
            run_model_thread(ctx2, child, move || {
                let v = f();
                *plock(&res2) = Some(v);
            });
        });
        JoinHandle {
            tid: child,
            real: Some(real),
            result,
        }
    }

    /// Model-checked `yield_now` (a pure schedule point).
    pub fn yield_now() {
        model_point();
    }
}

// ---------------------------------------------------------------------------
// DFS driver
// ---------------------------------------------------------------------------

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Configuration for a model run; see the module docs for semantics.
#[derive(Clone, Debug)]
pub struct Builder {
    /// Maximum involuntary context switches per execution (context-bounded
    /// search). Raising it grows the schedule space combinatorially.
    pub preemption_bound: usize,
    /// Panic if more than this many schedules are explored.
    pub max_schedules: usize,
    /// Abort an execution after this many schedule points (livelock).
    pub max_steps: usize,
}

impl Default for Builder {
    fn default() -> Self {
        Self::new()
    }
}

impl Builder {
    /// Defaults (2 / 100k / 50k), overridable via the
    /// `FPPS_MODEL_PREEMPTION_BOUND`, `FPPS_MODEL_MAX_SCHEDULES`, and
    /// `FPPS_MODEL_MAX_STEPS` environment variables.
    pub fn new() -> Self {
        Self {
            preemption_bound: env_usize("FPPS_MODEL_PREEMPTION_BOUND", 2),
            max_schedules: env_usize("FPPS_MODEL_MAX_SCHEDULES", 100_000),
            max_steps: env_usize("FPPS_MODEL_MAX_STEPS", 50_000),
        }
    }

    /// Explore every bounded interleaving of `f`, panicking on the first
    /// assertion failure, data race, deadlock, or livelock. Returns the
    /// number of schedules explored.
    pub fn check<F>(&self, f: F) -> usize
    where
        F: Fn() + Send + Sync + 'static,
    {
        let f = StdArc::new(f);
        let mut prefix: Vec<usize> = Vec::new();
        let mut schedules = 0usize;
        loop {
            schedules += 1;
            assert!(
                schedules <= self.max_schedules,
                "model: schedule budget ({}) exceeded — raise FPPS_MODEL_MAX_SCHEDULES \
                 or shrink the model",
                self.max_schedules
            );
            let (taken, opts, payload) = run_one(f.clone(), &prefix, self);
            if let Some(p) = payload {
                resume_unwind(p);
            }
            // Backtrack: bump the deepest decision that still has an
            // unexplored sibling; done when none remains.
            let mut next = None;
            for k in (0..taken.len()).rev() {
                if taken[k] + 1 < opts[k] {
                    next = Some(k);
                    break;
                }
            }
            match next {
                Some(k) => {
                    prefix.clear();
                    prefix.extend_from_slice(&taken[..k]);
                    prefix.push(taken[k] + 1);
                }
                None => return schedules,
            }
        }
    }
}

/// One execution: replay `prefix`, then take first-choice defaults.
/// Returns (choices taken, option counts per choice, abort payload).
#[allow(clippy::type_complexity)]
fn run_one<F>(
    f: StdArc<F>,
    prefix: &[usize],
    cfg: &Builder,
) -> (Vec<usize>, Vec<usize>, Option<Box<dyn Any + Send>>)
where
    F: Fn() + Send + Sync + 'static,
{
    let ctx = StdArc::new(ExecCtx {
        state: StdMutex::new(SchedState::default()),
        cv: StdCondvar::new(),
    });
    {
        let mut st = plock(&ctx.state);
        st.max_steps = cfg.max_steps;
        st.threads.push(ThreadMeta {
            run: Run::Runnable,
            clock: Clock::default(),
            wake: None,
        });
    }
    let ctx0 = ctx.clone();
    let root = std::thread::spawn(move || {
        run_model_thread(ctx0, 0, move || (f)());
    });
    let mut taken = Vec::new();
    let mut opts = Vec::new();
    let mut prev: Option<usize> = None;
    let mut preemptions = 0usize;
    let payload;
    let mut st = plock(&ctx.state);
    loop {
        while st.active.is_some() && !st.aborted {
            st = ctx.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        if st.aborted {
            // Teardown: keep prodding until every thread has unwound.
            ctx.cv.notify_all();
            while !st.threads.iter().all(|t| t.run == Run::Finished) {
                st = ctx.cv.wait(st).unwrap_or_else(|e| e.into_inner());
                ctx.cv.notify_all();
            }
            payload = st.panic_payload.take();
            break;
        }
        if st.threads.iter().all(|t| t.run == Run::Finished) {
            payload = st.panic_payload.take();
            break;
        }
        let runnable: Vec<usize> = st
            .threads
            .iter()
            .enumerate()
            .filter(|(_, t)| t.run == Run::Runnable)
            .map(|(i, _)| i)
            .collect();
        if runnable.is_empty() {
            // Quiescence: fire every pending timed wait; if none, the
            // remaining threads are deadlocked.
            let mut woke = false;
            for t in st.threads.iter_mut() {
                if matches!(t.run, Run::BlockedCondvar { timed: true, .. }) {
                    t.run = Run::Runnable;
                    t.wake = Some(Wake::TimedOut);
                    woke = true;
                }
            }
            if woke {
                continue;
            }
            st.aborted = true;
            if st.panic_payload.is_none() {
                let states: Vec<Run> = st.threads.iter().map(|t| t.run).collect();
                st.panic_payload = Some(Box::new(format!(
                    "model: deadlock detected — thread states: {states:?}"
                )));
            }
            ctx.cv.notify_all();
            continue;
        }
        // Option order: continuing the previous thread is index 0 (free);
        // any other runnable thread costs a preemption when the previous
        // one could have continued.
        let options: Vec<usize> = match prev {
            Some(p) if runnable.contains(&p) => {
                if preemptions >= cfg.preemption_bound {
                    vec![p]
                } else {
                    let mut v = vec![p];
                    v.extend(runnable.iter().copied().filter(|&t| t != p));
                    v
                }
            }
            _ => runnable.clone(),
        };
        let step = taken.len();
        let mut choice = if step < prefix.len() { prefix[step] } else { 0 };
        if choice >= options.len() {
            choice = options.len() - 1;
        }
        let tid = options[choice];
        if let Some(p) = prev {
            if runnable.contains(&p) && tid != p {
                preemptions += 1;
            }
        }
        taken.push(choice);
        opts.push(options.len());
        prev = Some(tid);
        st.active = Some(tid);
        ctx.cv.notify_all();
    }
    drop(st);
    let _ = root.join();
    (taken, opts, payload)
}

/// Explore every bounded interleaving of `f` with default [`Builder`]
/// settings; returns the number of schedules explored.
pub fn model<F>(f: F) -> usize
where
    F: Fn() + Send + Sync + 'static,
{
    Builder::new().check(f)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Run `f` under the model expecting an abort whose panic message
    /// contains `needle`.
    fn expect_model_panic<F>(f: F, needle: &str)
    where
        F: Fn() + Send + Sync + 'static,
    {
        let res = catch_unwind(AssertUnwindSafe(|| {
            Builder::new().check(f);
        }));
        let payload = res.expect_err("model should have panicked");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(
            msg.contains(needle),
            "panic message {msg:?} lacked {needle:?}"
        );
    }

    #[test]
    fn counter_under_mutex_is_exact() {
        let schedules = model(|| {
            let n = StdArc::new(Mutex::new(0u32));
            let mut handles = Vec::new();
            for _ in 0..2 {
                let n2 = n.clone();
                handles.push(thread::spawn(move || {
                    *n2.lock().unwrap() += 1;
                }));
            }
            for h in handles {
                h.join().unwrap();
            }
            assert_eq!(*n.lock().unwrap(), 2);
        });
        assert!(schedules >= 2, "expected multiple interleavings: {schedules}");
    }

    #[test]
    fn relaxed_publish_is_reported_as_race() {
        expect_model_panic(
            || {
                let cell = StdArc::new(UnsafeCell::new(0u32));
                let flag = StdArc::new(AtomicBool::new(false));
                let (c2, f2) = (cell.clone(), flag.clone());
                let t = thread::spawn(move || {
                    // SAFETY: exclusive access is the property under test;
                    // the race detector panics if it is violated.
                    c2.with_mut(|p| unsafe { *p = 1 });
                    // ordering: deliberately Relaxed — the missing release
                    // edge is the seeded bug this test must detect.
                    f2.store(true, Ordering::Relaxed);
                });
                // ordering: deliberately Relaxed, see above.
                if flag.load(Ordering::Relaxed) {
                    // SAFETY: guarded by the race detector (see above).
                    let v = cell.with(|p| unsafe { *p });
                    assert_eq!(v, 1);
                }
                t.join().unwrap();
            },
            "data race detected",
        );
    }

    #[test]
    fn release_acquire_publish_is_clean() {
        let schedules = model(|| {
            let cell = StdArc::new(UnsafeCell::new(0u32));
            let flag = StdArc::new(AtomicBool::new(false));
            let (c2, f2) = (cell.clone(), flag.clone());
            let t = thread::spawn(move || {
                // SAFETY: the Release store below publishes this write
                // before any Acquire reader can observe the flag.
                c2.with_mut(|p| unsafe { *p = 1 });
                // ordering: Release publishes the cell write to the
                // Acquire load on the reader side.
                f2.store(true, Ordering::Release);
            });
            // ordering: Acquire pairs with the Release store above.
            if flag.load(Ordering::Acquire) {
                // SAFETY: the Acquire load above synchronizes with the
                // writer's Release store, so the write happens-before.
                let v = cell.with(|p| unsafe { *p });
                assert_eq!(v, 1);
            }
            t.join().unwrap();
        });
        assert!(schedules >= 2, "expected multiple interleavings: {schedules}");
    }

    #[test]
    fn self_deadlock_is_reported() {
        expect_model_panic(
            || {
                let m = Mutex::new(());
                let _g = m.lock().unwrap();
                let _g2 = m.lock().unwrap();
            },
            "deadlock detected",
        );
    }

    #[test]
    fn timed_wait_fires_at_quiescence() {
        model(|| {
            let m = Mutex::new(());
            let cv = Condvar::new();
            let g = m.lock().unwrap();
            let (_g, r) = cv.wait_timeout(g, Duration::from_millis(1)).unwrap();
            assert!(r.timed_out());
        });
    }

    #[test]
    fn condvar_handoff_loses_no_wakeup() {
        model(|| {
            let pair = StdArc::new((Mutex::new(false), Condvar::new()));
            let p2 = pair.clone();
            let t = thread::spawn(move || {
                let (m, cv) = &*p2;
                *m.lock().unwrap() = true;
                cv.notify_all();
            });
            let (m, cv) = &*pair;
            let mut g = m.lock().unwrap();
            while !*g {
                g = cv.wait(g).unwrap();
            }
            drop(g);
            t.join().unwrap();
        });
    }
}




