//! Concurrency shim: `std::sync` in production, a model checker under test.
//!
//! Every concurrent primitive used by the lock-free data plane — atomics,
//! mutexes, condvars, and the `UnsafeCell` slots inside the SPSC ring — is
//! imported through this module instead of `std::sync` directly. The shim
//! compiles in one of two modes:
//!
//! - **Normal builds** (`cfg(not(loom))`): zero-cost re-exports of the
//!   `std::sync` types, plus a `#[repr(transparent)]` [`cell::UnsafeCell`]
//!   wrapper whose `with`/`with_mut` accessors compile down to a bare
//!   pointer handoff. Release binaries are bit-for-bit what they were when
//!   the code named `std::sync` directly.
//!
//! - **Model-checking builds** (`RUSTFLAGS="--cfg loom"`): the same names
//!   resolve to the in-repo model checker in [`model`], which executes the
//!   code under a deterministic scheduler, explores interleavings
//!   exhaustively (bounded DFS over preemption points), tracks
//!   happens-before with vector clocks, and panics on data races against
//!   the `UnsafeCell` slots. The loom-model tests in
//!   `rust/tests/loom_models.rs` only compile in this mode.
//!
//! The cfg name `loom` is kept so that the models are source-compatible
//! with the external [loom](https://docs.rs/loom) crate: if a vendored
//! loom checkout is ever added (the runtime dependency story stays
//! anyhow-only, so it cannot come from crates.io here), the re-exports
//! below can switch to it without touching any ported module. Until then
//! [`model`] provides the subset the data plane needs with the same API
//! surface. See README "Correctness tooling" for how to run the models.
//!
//! What the model checker does and does not prove is documented on
//! [`model`]; the headline caveat is that execution is sequentially
//! consistent (races and ordering-sensitive happens-before edges are
//! detected via vector clocks, but weak-memory value reordering is not
//! simulated).

pub mod model;

#[cfg(not(loom))]
mod imp {
    pub use std::sync::atomic;
    pub use std::sync::{Arc, Condvar, Mutex, MutexGuard, WaitTimeoutResult};

    /// Cell wrapper matching loom's `UnsafeCell` accessor API.
    pub mod cell {
        /// `std::cell::UnsafeCell` behind loom's `with`/`with_mut` API.
        ///
        /// In normal builds this is a transparent, zero-cost wrapper: the
        /// closures receive the raw pointer from the underlying cell and
        /// the caller remains responsible for aliasing discipline exactly
        /// as with `std::cell::UnsafeCell`. Under `--cfg loom` the same
        /// API performs vector-clock race detection on every access.
        #[derive(Debug, Default)]
        #[repr(transparent)]
        pub struct UnsafeCell<T>(std::cell::UnsafeCell<T>);

        impl<T> UnsafeCell<T> {
            /// Wraps a value.
            pub const fn new(v: T) -> Self {
                Self(std::cell::UnsafeCell::new(v))
            }

            /// Unwraps the value.
            pub fn into_inner(self) -> T {
                self.0.into_inner()
            }

            /// Calls `f` with a shared raw pointer to the contents.
            pub fn with<R>(&self, f: impl FnOnce(*const T) -> R) -> R {
                f(self.0.get())
            }

            /// Calls `f` with an exclusive raw pointer to the contents.
            pub fn with_mut<R>(&self, f: impl FnOnce(*mut T) -> R) -> R {
                f(self.0.get())
            }
        }
    }
}

#[cfg(loom)]
mod imp {
    pub use crate::sync::model::{Condvar, Mutex, MutexGuard, WaitTimeoutResult};
    pub use std::sync::Arc;

    /// Atomics routed through the model checker.
    pub mod atomic {
        pub use crate::sync::model::{fence, AtomicBool, AtomicU64, AtomicUsize, Ordering};
    }

    /// Race-checked cell routed through the model checker.
    pub mod cell {
        pub use crate::sync::model::UnsafeCell;
    }
}

pub use imp::*;
