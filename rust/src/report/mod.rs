//! ASCII table rendering for the benchmark harnesses — every bench
//! prints its table in the same row/column layout as the paper so
//! paper-vs-measured comparison in EXPERIMENTS.md is a visual diff.

/// A simple column-aligned table builder.
#[derive(Clone, Debug, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>) -> Self {
        Self {
            title: title.into(),
            ..Default::default()
        }
    }

    pub fn header(mut self, cols: &[&str]) -> Self {
        self.header = cols.iter().map(|s| s.to_string()).collect();
        self
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert!(
            self.header.is_empty() || cells.len() == self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncols = self
            .header
            .len()
            .max(self.rows.iter().map(|r| r.len()).max().unwrap_or(0));
        let mut widths = vec![0usize; ncols];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = widths[i].max(h.len());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (i, w) in widths.iter().copied().enumerate() {
                let c = cells.get(i).map(String::as_str).unwrap_or("");
                s.push_str(&format!(" {c:<w$} |"));
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("{}\n", self.title));
        }
        out.push_str(&sep);
        out.push('\n');
        if !self.header.is_empty() {
            out.push_str(&fmt_row(&self.header));
            out.push('\n');
            out.push_str(&sep);
            out.push('\n');
        }
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format helpers used across benches.
pub fn ms(v: f64) -> String {
    format!("{v:.1}")
}

pub fn times(v: f64) -> String {
    format!("{v:.2}x")
}

pub fn meters(v: f64) -> String {
    format!("{v:.3}")
}

pub fn pct(v: f64) -> String {
    format!("{:.2}%", v * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_table() {
        let mut t = Table::new("TABLE X: demo").header(&["Sequence", "CPU (ms)", "Accel"]);
        t.row(vec!["00".into(), "3714.5".into(), "22.84x".into()]);
        t.row(vec!["01".into(), "8640.1".into(), "16.07x".into()]);
        let s = t.render();
        assert!(s.contains("TABLE X: demo"));
        assert!(s.contains("| Sequence | CPU (ms) | Accel  |"));
        assert!(s.contains("| 00       | 3714.5   | 22.84x |"));
        // All data lines equal width.
        let widths: std::collections::HashSet<usize> = s
            .lines()
            .skip(1)
            .map(|l| l.len())
            .collect();
        assert_eq!(widths.len(), 1, "{s}");
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_ragged_rows() {
        let mut t = Table::new("t").header(&["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn format_helpers() {
        assert_eq!(ms(3714.53), "3714.5");
        assert_eq!(times(22.838), "22.84x");
        assert_eq!(meters(0.1984), "0.198");
        assert_eq!(pct(0.7194), "71.94%");
    }
}
