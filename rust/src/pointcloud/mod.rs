//! Point cloud container and operations.
//!
//! Clouds are stored SoA-flat (`xyz: Vec<f32>` of length 3·n, row-major
//! per point) — the exact wire layout both the device kernel and the
//! KITTI `.bin` format use, so uploads and file I/O are memcpy-shaped.

pub mod io;

use crate::math::{Mat4, Vec3};
use crate::rng::Pcg32;

/// A 3D point cloud (f32, SoA-flat).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PointCloud {
    /// Flat `[x0, y0, z0, x1, y1, z1, …]`, length `3 * len()`.
    pub xyz: Vec<f32>,
}

impl PointCloud {
    pub fn new() -> Self {
        Self { xyz: Vec::new() }
    }

    pub fn with_capacity(n: usize) -> Self {
        Self {
            xyz: Vec::with_capacity(3 * n),
        }
    }

    /// Build from a flat xyz buffer (must be a multiple of 3 long).
    pub fn from_xyz(xyz: Vec<f32>) -> Self {
        assert!(xyz.len() % 3 == 0, "xyz length {} not divisible by 3", xyz.len());
        Self { xyz }
    }

    pub fn from_points(pts: &[[f32; 3]]) -> Self {
        let mut xyz = Vec::with_capacity(pts.len() * 3);
        for p in pts {
            xyz.extend_from_slice(p);
        }
        Self { xyz }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.xyz.len() / 3
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.xyz.is_empty()
    }

    #[inline]
    pub fn get(&self, i: usize) -> [f32; 3] {
        [self.xyz[3 * i], self.xyz[3 * i + 1], self.xyz[3 * i + 2]]
    }

    #[inline]
    pub fn set(&mut self, i: usize, p: [f32; 3]) {
        self.xyz[3 * i] = p[0];
        self.xyz[3 * i + 1] = p[1];
        self.xyz[3 * i + 2] = p[2];
    }

    pub fn push(&mut self, p: [f32; 3]) {
        self.xyz.extend_from_slice(&p);
    }

    pub fn iter(&self) -> impl Iterator<Item = [f32; 3]> + '_ {
        self.xyz.chunks_exact(3).map(|c| [c[0], c[1], c[2]])
    }

    /// Apply a rigid transform, returning a new cloud (f32 math — this is
    /// what the device's point cloud transformer does).
    pub fn transformed(&self, t: &Mat4) -> PointCloud {
        let m = t.to_f32_row_major();
        let mut out = Vec::with_capacity(self.xyz.len());
        for p in self.iter() {
            out.push(m[0] * p[0] + m[1] * p[1] + m[2] * p[2] + m[3]);
            out.push(m[4] * p[0] + m[5] * p[1] + m[6] * p[2] + m[7]);
            out.push(m[8] * p[0] + m[9] * p[1] + m[10] * p[2] + m[11]);
        }
        PointCloud { xyz: out }
    }

    /// In-place rigid transform.
    pub fn transform_in_place(&mut self, t: &Mat4) {
        let m = t.to_f32_row_major();
        for c in self.xyz.chunks_exact_mut(3) {
            let (x, y, z) = (c[0], c[1], c[2]);
            c[0] = m[0] * x + m[1] * y + m[2] * z + m[3];
            c[1] = m[4] * x + m[5] * y + m[6] * z + m[7];
            c[2] = m[8] * x + m[9] * y + m[10] * z + m[11];
        }
    }

    pub fn centroid(&self) -> Vec3 {
        let mut s = Vec3::ZERO;
        for p in self.iter() {
            s = s + Vec3::from_f32(p);
        }
        if self.is_empty() {
            s
        } else {
            s * (1.0 / self.len() as f64)
        }
    }

    /// Axis-aligned bounds (min, max); `None` when empty.
    pub fn bounds(&self) -> Option<([f32; 3], [f32; 3])> {
        if self.is_empty() {
            return None;
        }
        let mut lo = self.get(0);
        let mut hi = lo;
        for p in self.iter() {
            for k in 0..3 {
                lo[k] = lo[k].min(p[k]);
                hi[k] = hi[k].max(p[k]);
            }
        }
        Some((lo, hi))
    }

    /// Random subsample of exactly `k` points (the paper samples 4096
    /// source points per frame). If `k >= len()`, returns a clone.
    pub fn random_sample(&self, k: usize, rng: &mut Pcg32) -> PointCloud {
        if k >= self.len() {
            return self.clone();
        }
        let idx = rng.sample_indices(self.len(), k);
        let mut out = PointCloud::with_capacity(k);
        for &i in &idx {
            out.push(self.get(i as usize));
        }
        out
    }

    /// Voxel-grid downsample: one representative (centroid) per occupied
    /// voxel of size `leaf` — PCL's `VoxelGrid` filter, used by mapping
    /// pipelines to control target cloud density.
    pub fn voxel_downsample(&self, leaf: f32) -> PointCloud {
        assert!(leaf > 0.0);
        use std::collections::HashMap;
        let inv = 1.0 / leaf;
        let mut cells: HashMap<(i32, i32, i32), ([f64; 3], u32)> = HashMap::new();
        for p in self.iter() {
            let key = (
                (p[0] * inv).floor() as i32,
                (p[1] * inv).floor() as i32,
                (p[2] * inv).floor() as i32,
            );
            let e = cells.entry(key).or_insert(([0.0; 3], 0));
            for k in 0..3 {
                e.0[k] += p[k] as f64;
            }
            e.1 += 1;
        }
        let mut keys: Vec<_> = cells.keys().copied().collect();
        keys.sort_unstable(); // deterministic output order
        let mut out = PointCloud::with_capacity(keys.len());
        for k in keys {
            let (s, n) = cells[&k];
            let inv_n = 1.0 / n as f64;
            out.push([
                (s[0] * inv_n) as f32,
                (s[1] * inv_n) as f32,
                (s[2] * inv_n) as f32,
            ]);
        }
        out
    }

    /// Content fingerprint over the exact f32 bit pattern (FNV-1a-64).
    /// Two clouds with equal `xyz` buffers always fingerprint equal, so
    /// this is the identity key of the cross-frame target cache: a job
    /// whose target fingerprints like the device-resident one can skip
    /// the re-upload (and the kd-tree rebuild) entirely.
    pub fn fingerprint(&self) -> u64 {
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        h ^= self.xyz.len() as u64;
        h = h.wrapping_mul(PRIME);
        for v in &self.xyz {
            h ^= v.to_bits() as u64;
            h = h.wrapping_mul(PRIME);
        }
        h
    }

    /// Append gaussian sensor noise (σ per axis).
    pub fn add_noise(&mut self, sigma: f32, rng: &mut Pcg32) {
        for v in self.xyz.iter_mut() {
            *v += rng.normal() * sigma;
        }
    }

    /// Copy this cloud's points into `out`, reusing `out`'s existing
    /// heap allocation when its capacity suffices. The in-place sibling
    /// of `clone()` for recycled buffers on the zero-copy data plane.
    pub fn copy_into(&self, out: &mut PointCloud) {
        out.xyz.clear();
        out.xyz.extend_from_slice(&self.xyz);
    }

    /// Root-mean-square distance between corresponding points of two
    /// equally-sized clouds (the paper's registration RMSE metric).
    pub fn rmse_to(&self, other: &PointCloud) -> f64 {
        assert_eq!(self.len(), other.len(), "rmse over unequal clouds");
        if self.is_empty() {
            return 0.0;
        }
        let mut s = 0.0f64;
        for (p, q) in self.iter().zip(other.iter()) {
            let dx = (p[0] - q[0]) as f64;
            let dy = (p[1] - q[1]) as f64;
            let dz = (p[2] - q[2]) as f64;
            s += dx * dx + dy * dy + dz * dz;
        }
        (s / self.len() as f64).sqrt()
    }
}

/// Pad a flat xyz buffer to `capacity` points **in place**: `out`
/// receives the points followed by zero padding (length `3·capacity`),
/// `mask` receives `1.0` per real point and `0.0` per padding slot
/// (length `capacity`). Both destinations are cleared and refilled, so
/// a buffer recycled from [`crate::pool`] stages a new cloud without
/// touching the heap once its capacity class is warm. Bit-identical to
/// building fresh `(padded, mask)` vectors.
///
/// Panics if the cloud does not fit (`xyz.len()/3 > capacity`) — wire
/// capacity is a hard device-side contract, not a hint.
pub fn pad_into(xyz: &[f32], capacity: usize, out: &mut Vec<f32>, mask: &mut Vec<f32>) {
    let n = xyz.len() / 3;
    assert!(n <= capacity, "cloud ({n}) exceeds capacity ({capacity})");
    out.clear();
    out.reserve(capacity * 3);
    out.extend_from_slice(xyz);
    out.resize(capacity * 3, 0.0);
    mask.clear();
    mask.reserve(capacity);
    mask.resize(n, 1.0);
    mask.resize(capacity, 0.0);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::{Mat3, Mat4, Vec3};
    use crate::prop::forall;
    use crate::rng::Pcg32;

    fn cloud(n: usize, seed: u64) -> PointCloud {
        let mut rng = Pcg32::new(seed);
        let mut c = PointCloud::with_capacity(n);
        for _ in 0..n {
            c.push([
                rng.range(-10.0, 10.0),
                rng.range(-10.0, 10.0),
                rng.range(-2.0, 2.0),
            ]);
        }
        c
    }

    #[test]
    fn fingerprint_is_content_identity() {
        let a = cloud(200, 1);
        let b = a.clone();
        assert_eq!(a.fingerprint(), b.fingerprint());
        // A single-ulp change in one coordinate changes the fingerprint.
        let mut c = a.clone();
        c.xyz[17] = f32::from_bits(c.xyz[17].to_bits() ^ 1);
        assert_ne!(a.fingerprint(), c.fingerprint());
        // Different lengths never collide trivially.
        let mut d = a.clone();
        d.push([0.0, 0.0, 0.0]);
        assert_ne!(a.fingerprint(), d.fingerprint());
        // Empty cloud has a stable fingerprint.
        let empty = PointCloud::new();
        assert_eq!(empty.fingerprint(), PointCloud::new().fingerprint());
    }

    #[test]
    fn basic_accessors() {
        let mut c = PointCloud::new();
        assert!(c.is_empty());
        c.push([1.0, 2.0, 3.0]);
        c.push([4.0, 5.0, 6.0]);
        assert_eq!(c.len(), 2);
        assert_eq!(c.get(1), [4.0, 5.0, 6.0]);
        c.set(0, [7.0, 8.0, 9.0]);
        assert_eq!(c.get(0), [7.0, 8.0, 9.0]);
    }

    #[test]
    #[should_panic(expected = "not divisible by 3")]
    fn from_xyz_validates_length() {
        let _ = PointCloud::from_xyz(vec![1.0, 2.0]);
    }

    #[test]
    fn transform_roundtrip() {
        forall(50, |g| {
            let c = cloud(g.usize_range(1, 200), g.case);
            let t = Mat4::from_rt(g.rotation(3.0), Vec3::from_f32(g.point(5.0)));
            let back = c.transformed(&t).transformed(&t.inverse_rigid());
            for (p, q) in c.iter().zip(back.iter()) {
                for k in 0..3 {
                    assert!((p[k] - q[k]).abs() < 1e-3, "case {}", g.case);
                }
            }
        });
    }

    #[test]
    fn transform_in_place_matches_transformed() {
        let c = cloud(100, 3);
        let t = Mat4::from_rt(Mat3::rot_z(0.4), Vec3::new(1.0, -2.0, 0.5));
        let a = c.transformed(&t);
        let mut b = c.clone();
        b.transform_in_place(&t);
        assert_eq!(a, b);
    }

    #[test]
    fn centroid_of_symmetric_cloud_is_origin() {
        let c = PointCloud::from_points(&[
            [1.0, 0.0, 0.0],
            [-1.0, 0.0, 0.0],
            [0.0, 2.0, 0.0],
            [0.0, -2.0, 0.0],
        ]);
        let ctr = c.centroid();
        assert!(ctr.norm() < 1e-9);
    }

    #[test]
    fn bounds_cover_all_points() {
        let c = cloud(500, 9);
        let (lo, hi) = c.bounds().unwrap();
        for p in c.iter() {
            for k in 0..3 {
                assert!(p[k] >= lo[k] && p[k] <= hi[k]);
            }
        }
        assert!(PointCloud::new().bounds().is_none());
    }

    #[test]
    fn random_sample_size_and_membership() {
        let c = cloud(1000, 5);
        let mut rng = Pcg32::new(77);
        let s = c.random_sample(128, &mut rng);
        assert_eq!(s.len(), 128);
        // Every sampled point exists in the source.
        let set: std::collections::HashSet<[u32; 3]> = c
            .iter()
            .map(|p| [p[0].to_bits(), p[1].to_bits(), p[2].to_bits()])
            .collect();
        for p in s.iter() {
            assert!(set.contains(&[p[0].to_bits(), p[1].to_bits(), p[2].to_bits()]));
        }
        // k >= n clones.
        assert_eq!(c.random_sample(2000, &mut rng).len(), 1000);
    }

    #[test]
    fn voxel_downsample_reduces_and_bounds_preserved() {
        let c = cloud(2000, 11);
        let d = c.voxel_downsample(1.0);
        assert!(d.len() < c.len());
        assert!(!d.is_empty());
        let (lo, hi) = c.bounds().unwrap();
        for p in d.iter() {
            for k in 0..3 {
                // Centroids stay within the original bounds.
                assert!(p[k] >= lo[k] - 1e-4 && p[k] <= hi[k] + 1e-4);
            }
        }
        // Coarser leaf → fewer points.
        assert!(c.voxel_downsample(4.0).len() <= d.len());
    }

    #[test]
    fn voxel_downsample_deterministic() {
        let c = cloud(500, 13);
        assert_eq!(c.voxel_downsample(0.7), c.voxel_downsample(0.7));
    }

    #[test]
    fn pad_into_matches_fresh_padding_and_reuses_capacity() {
        let c = cloud(100, 21);
        let mut out = Vec::new();
        let mut mask = Vec::new();
        pad_into(&c.xyz, 128, &mut out, &mut mask);
        assert_eq!(out.len(), 128 * 3);
        assert_eq!(mask.len(), 128);
        assert_eq!(&out[..c.xyz.len()], &c.xyz[..]);
        assert!(out[c.xyz.len()..].iter().all(|&v| v == 0.0));
        assert!(mask[..c.len()].iter().all(|&v| v == 1.0));
        assert!(mask[c.len()..].iter().all(|&v| v == 0.0));

        // Re-padding a different cloud into the same buffers reuses the
        // allocation (no growth) and produces the same bits as fresh.
        let (p_out, p_mask) = (out.as_ptr(), mask.as_ptr());
        let d = cloud(64, 22);
        pad_into(&d.xyz, 128, &mut out, &mut mask);
        assert_eq!(out.as_ptr(), p_out);
        assert_eq!(mask.as_ptr(), p_mask);
        assert_eq!(&out[..d.xyz.len()], &d.xyz[..]);
        assert!(out[d.xyz.len()..].iter().all(|&v| v == 0.0));
        assert!(mask[..d.len()].iter().all(|&v| v == 1.0));
        assert!(mask[d.len()..].iter().all(|&v| v == 0.0));
    }

    #[test]
    #[should_panic(expected = "exceeds capacity")]
    fn pad_into_rejects_oversized_cloud() {
        let c = cloud(10, 23);
        pad_into(&c.xyz, 4, &mut Vec::new(), &mut Vec::new());
    }

    #[test]
    fn copy_into_reuses_destination_allocation() {
        let a = cloud(50, 25);
        let mut dst = cloud(80, 26);
        let p = dst.xyz.as_ptr();
        a.copy_into(&mut dst);
        assert_eq!(a, dst);
        assert_eq!(dst.xyz.as_ptr(), p);
    }

    #[test]
    fn rmse_zero_on_identical() {
        let c = cloud(64, 17);
        assert_eq!(c.rmse_to(&c), 0.0);
        let mut d = c.clone();
        for v in d.xyz.iter_mut() {
            *v += 1.0;
        }
        // Uniform +1 shift in 3 axes → rmse = sqrt(3).
        assert!((c.rmse_to(&d) - 3f64.sqrt()).abs() < 1e-5);
    }
}
