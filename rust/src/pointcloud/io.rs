//! Point cloud file I/O.
//!
//! * KITTI velodyne `.bin`: little-endian f32 quadruples `x y z
//!   reflectance` — the format of the odometry benchmark the paper
//!   evaluates on. We read real KITTI files when present and write the
//!   same format from the synthetic generator, so the rest of the stack
//!   cannot tell the difference.
//! * ASCII PLY export for eyeballing clouds in external viewers.

use super::PointCloud;
use anyhow::{ensure, Context, Result};
use std::io::{BufWriter, Read, Write};
use std::path::Path;

/// Read a KITTI velodyne `.bin` (x, y, z, reflectance f32 LE records).
/// Reflectance is discarded; FPPS only registers geometry.
pub fn read_kitti_bin(path: &Path) -> Result<PointCloud> {
    let mut file = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let mut bytes = Vec::new();
    file.read_to_end(&mut bytes)?;
    ensure!(
        bytes.len() % 16 == 0,
        "{}: size {} is not a multiple of 16 (x,y,z,r f32 records)",
        path.display(),
        bytes.len()
    );
    let n = bytes.len() / 16;
    let mut xyz = Vec::with_capacity(n * 3);
    for rec in bytes.chunks_exact(16) {
        for k in 0..3 {
            let off = k * 4;
            xyz.push(f32::from_le_bytes([
                rec[off],
                rec[off + 1],
                rec[off + 2],
                rec[off + 3],
            ]));
        }
    }
    Ok(PointCloud { xyz })
}

/// Write a KITTI velodyne `.bin` with constant reflectance.
pub fn write_kitti_bin(cloud: &PointCloud, path: &Path) -> Result<()> {
    let file = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(file);
    for p in cloud.iter() {
        for v in p {
            w.write_all(&v.to_le_bytes())?;
        }
        w.write_all(&0.0f32.to_le_bytes())?; // reflectance
    }
    w.flush()?;
    Ok(())
}

/// Write ASCII PLY (for external viewers; not on any hot path).
pub fn write_ply(cloud: &PointCloud, path: &Path) -> Result<()> {
    let file = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(file);
    writeln!(w, "ply")?;
    writeln!(w, "format ascii 1.0")?;
    writeln!(w, "element vertex {}", cloud.len())?;
    writeln!(w, "property float x")?;
    writeln!(w, "property float y")?;
    writeln!(w, "property float z")?;
    writeln!(w, "end_header")?;
    for p in cloud.iter() {
        writeln!(w, "{} {} {}", p[0], p[1], p[2])?;
    }
    w.flush()?;
    Ok(())
}

/// Read KITTI ground-truth poses (`poses/XX.txt`): one 3×4 row-major
/// matrix per line, 12 whitespace-separated floats.
pub fn read_kitti_poses(path: &Path) -> Result<Vec<crate::math::Mat4>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("read {}", path.display()))?;
    let mut out = Vec::new();
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let vals: Vec<f64> = line
            .split_whitespace()
            .map(|t| t.parse::<f64>())
            .collect::<std::result::Result<_, _>>()
            .with_context(|| format!("{}:{}: bad float", path.display(), ln + 1))?;
        ensure!(
            vals.len() == 12,
            "{}:{}: expected 12 values, got {}",
            path.display(),
            ln + 1,
            vals.len()
        );
        let mut m = [[0.0f64; 4]; 4];
        for i in 0..3 {
            for j in 0..4 {
                m[i][j] = vals[i * 4 + j];
            }
        }
        m[3][3] = 1.0;
        out.push(crate::math::Mat4 { m });
    }
    Ok(out)
}

/// Write poses in the KITTI ground-truth format.
pub fn write_kitti_poses(poses: &[crate::math::Mat4], path: &Path) -> Result<()> {
    let file = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(file);
    for t in poses {
        let mut fields = Vec::with_capacity(12);
        for i in 0..3 {
            for j in 0..4 {
                fields.push(format!("{:e}", t.m[i][j]));
            }
        }
        writeln!(w, "{}", fields.join(" "))?;
    }
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::{Mat3, Mat4, Vec3};
    use crate::rng::Pcg32;

    fn tmpdir() -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("fpps_io_test_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn kitti_bin_roundtrip() {
        let mut rng = Pcg32::new(1);
        let mut c = PointCloud::new();
        for _ in 0..257 {
            c.push([rng.normal(), rng.normal(), rng.normal()]);
        }
        let path = tmpdir().join("cloud.bin");
        write_kitti_bin(&c, &path).unwrap();
        let back = read_kitti_bin(&path).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn kitti_bin_rejects_bad_size() {
        let path = tmpdir().join("bad.bin");
        std::fs::write(&path, [0u8; 15]).unwrap();
        assert!(read_kitti_bin(&path).is_err());
    }

    #[test]
    fn poses_roundtrip() {
        let poses: Vec<Mat4> = (0..10)
            .map(|i| {
                Mat4::from_rt(
                    Mat3::rot_z(i as f64 * 0.1),
                    Vec3::new(i as f64, -0.5 * i as f64, 0.01),
                )
            })
            .collect();
        let path = tmpdir().join("poses.txt");
        write_kitti_poses(&poses, &path).unwrap();
        let back = read_kitti_poses(&path).unwrap();
        assert_eq!(back.len(), poses.len());
        for (a, b) in poses.iter().zip(back.iter()) {
            for i in 0..4 {
                for j in 0..4 {
                    assert!((a.m[i][j] - b.m[i][j]).abs() < 1e-12);
                }
            }
        }
    }

    #[test]
    fn poses_reject_malformed() {
        let path = tmpdir().join("bad_poses.txt");
        std::fs::write(&path, "1 2 3\n").unwrap();
        assert!(read_kitti_poses(&path).is_err());
        std::fs::write(&path, "a b c d e f g h i j k l\n").unwrap();
        assert!(read_kitti_poses(&path).is_err());
    }

    #[test]
    fn ply_header_and_vertex_count() {
        let c = PointCloud::from_points(&[[1.0, 2.0, 3.0], [4.0, 5.0, 6.0]]);
        let path = tmpdir().join("cloud.ply");
        write_ply(&c, &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("ply\n"));
        assert!(text.contains("element vertex 2"));
        assert_eq!(text.lines().count(), 7 + 2);
    }
}
