//! Power and energy-efficiency model → §IV.D.
//!
//! The paper reports: FPGA board 28 W total (14 W static + 14 W dynamic)
//! plus 2.3 W host-side; CPU baseline 16.3 W (PowerTOP); and an 8.58×
//! *power efficiency* gain, defined as "the ratio of power consumption
//! against the execution speed" — i.e. energy per frame:
//!
//!   efficiency gain = (P_cpu · t_cpu) / (P_fpga · t_fpga)
//!                   = (16.3 · t_cpu) / (30.3 · t_fpga)
//!
//! With the runtime-weighted speedup t_cpu/t_fpga = 15.95× this gives
//! 15.95 · 16.3 / 30.3 = 8.58× — exactly the paper's number, which pins
//! down the definition.

use super::resources::{Usage, U50};
use super::AcceleratorConfig;

/// Power rails of the two platforms (watts).
#[derive(Clone, Copy, Debug)]
pub struct PowerModel {
    pub fpga_static_w: f64,
    pub fpga_dynamic_w: f64,
    /// Host CPU share while driving the accelerator.
    pub host_w: f64,
    /// Software baseline CPU package power.
    pub cpu_baseline_w: f64,
}

impl Default for PowerModel {
    fn default() -> Self {
        Self {
            fpga_static_w: 14.0,
            fpga_dynamic_w: 14.0,
            host_w: 2.3,
            cpu_baseline_w: 16.3,
        }
    }
}

impl PowerModel {
    /// Total accelerated-system power (paper: 28 + 2.3 = 30.3 W).
    pub fn accel_total_w(&self) -> f64 {
        self.fpga_static_w + self.fpga_dynamic_w + self.host_w
    }

    /// Energy (J) to process one frame.
    pub fn accel_energy_j(&self, frame_s: f64) -> f64 {
        self.accel_total_w() * frame_s
    }

    pub fn cpu_energy_j(&self, frame_s: f64) -> f64 {
        self.cpu_baseline_w * frame_s
    }

    /// The §IV.D efficiency gain for a given speedup.
    pub fn efficiency_gain(&self, speedup: f64) -> f64 {
        speedup * self.cpu_baseline_w / self.accel_total_w()
    }
}

/// Estimate dynamic power from resource usage + clock: a standard
/// first-order CV²f model with per-resource activity coefficients
/// (mW per unit at 300 MHz, calibrated so the default design ≈ 14 W).
pub fn dynamic_power_estimate(u: &Usage, clock_mhz: f64) -> f64 {
    let f_scale = clock_mhz / 300.0;
    let lut_mw = 0.012;
    let ff_mw = 0.004;
    let bram_mw = 7.5;
    let dsp_mw = 2.2;
    let mw = u.lut as f64 * lut_mw
        + u.ff as f64 * ff_mw
        + u.bram_36k as f64 * bram_mw
        + u.dsp as f64 * dsp_mw;
    mw * f_scale / 1000.0
}

/// HBM + shell static power floor on U50 (W).
pub const U50_STATIC_W: f64 = 14.0;

/// Full power report for a configuration.
#[derive(Clone, Copy, Debug)]
pub struct PowerReport {
    pub static_w: f64,
    pub dynamic_w: f64,
    pub host_w: f64,
}

impl PowerReport {
    pub fn total_w(&self) -> f64 {
        self.static_w + self.dynamic_w + self.host_w
    }
}

pub fn power_report(cfg: &AcceleratorConfig) -> PowerReport {
    let usage = super::resources::report(cfg).total;
    let _ = U50; // device capacity is implied by the static floor
    PowerReport {
        static_w: U50_STATIC_W,
        dynamic_w: dynamic_power_estimate(&usage, cfg.clock_mhz),
        host_w: PowerModel::default().host_w,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_efficiency_number_reproduced() {
        // Abstract: 15.95× runtime-weighted speedup → 8.58× efficiency.
        let pm = PowerModel::default();
        let gain = pm.efficiency_gain(15.95);
        assert!(
            (gain - 8.58).abs() < 0.01,
            "efficiency gain {gain}, paper says 8.58"
        );
        assert!((pm.accel_total_w() - 30.3).abs() < 1e-12);
    }

    #[test]
    fn energy_per_frame_favors_fpga_despite_higher_power() {
        let pm = PowerModel::default();
        // Sequence 00: CPU 3714.5 ms vs FPGA 162.6 ms (Table IV).
        let e_cpu = pm.cpu_energy_j(3.7145);
        let e_fpga = pm.accel_energy_j(0.1626);
        assert!(e_fpga < e_cpu / 8.0, "{e_fpga} vs {e_cpu}");
    }

    #[test]
    fn dynamic_estimate_close_to_paper_14w() {
        let usage = crate::hwmodel::resources::report(&AcceleratorConfig::default()).total;
        let p = dynamic_power_estimate(&usage, 300.0);
        assert!(
            (p - 14.0).abs() < 3.0,
            "dynamic power estimate {p} W too far from paper's 14 W"
        );
    }

    #[test]
    fn dynamic_power_scales_with_clock_and_resources() {
        let u = crate::hwmodel::resources::report(&AcceleratorConfig::default()).total;
        assert!(dynamic_power_estimate(&u, 150.0) < dynamic_power_estimate(&u, 300.0));
        let small = crate::hwmodel::resources::report(&AcceleratorConfig {
            pe_cols: 4,
            pe_rows: 4,
            ..Default::default()
        })
        .total;
        assert!(dynamic_power_estimate(&small, 300.0) < dynamic_power_estimate(&u, 300.0));
    }

    #[test]
    fn power_report_total() {
        let r = power_report(&AcceleratorConfig::default());
        assert!((r.total_w() - (r.static_w + r.dynamic_w + r.host_w)).abs() < 1e-12);
        // Ballpark of the paper's 30.3 W.
        assert!(r.total_w() > 25.0 && r.total_w() < 36.0, "{}", r.total_w());
    }
}
