//! Structural performance estimate of the Pallas NN kernel on a real
//! TPU — the L1 §Perf target (DESIGN.md §8).
//!
//! `interpret=True` on CPU gives no meaningful wallclock, so the L1
//! optimisation loop targets *structure*: VMEM footprint per grid step
//! must fit (≤ 16 MiB/core) and the MXU utilisation of the distance
//! matmul should be maximised given the 3-wide contraction (which pads
//! to the 8×128 systolic tile — the fundamental inefficiency the
//! hardware-adaptation section of DESIGN.md discusses).

/// TPU core parameters (v4-lite-ish defaults; ratios are what matter).
#[derive(Clone, Copy, Debug)]
pub struct TpuCore {
    pub vmem_bytes: usize,
    /// MXU systolic tile (rows × cols contraction granularity).
    pub mxu_k: usize,
    pub mxu_n: usize,
    /// Peak f32 MACs per cycle (one 128×128 MXU at f32 throughput).
    pub macs_per_cycle: usize,
    /// HBM bandwidth bytes/cycle (≈ 1.2 TB/s @ 940 MHz).
    pub hbm_bytes_per_cycle: f64,
}

impl Default for TpuCore {
    fn default() -> Self {
        Self {
            vmem_bytes: 16 << 20,
            mxu_k: 8,
            mxu_n: 128,
            macs_per_cycle: 16_384,
            hbm_bytes_per_cycle: 1300.0,
        }
    }
}

/// Pallas kernel block configuration (mirrors nn_search.py BlockSpecs).
#[derive(Clone, Copy, Debug)]
pub struct BlockConfig {
    pub block_n: usize,
    pub block_m: usize,
}

/// Structural estimate for one grid step.
#[derive(Clone, Copy, Debug)]
pub struct KernelEstimate {
    /// Bytes of VMEM live per grid step (inputs + distance tile + outs).
    pub vmem_bytes: usize,
    /// Fraction of MXU MACs doing useful work in the p·qᵀ matmul.
    pub mxu_utilization: f64,
    /// Arithmetic intensity (useful FLOPs per HBM byte).
    pub flops_per_byte: f64,
    /// Estimated cycles per grid step (max of compute and memory).
    pub cycles: f64,
    /// True if compute-bound (MXU is the bottleneck), else memory-bound.
    pub compute_bound: bool,
}

/// Estimate one (block_n × block_m) grid step of the NN kernel.
pub fn estimate(core: &TpuCore, blk: &BlockConfig) -> KernelEstimate {
    let f = 4; // f32
    let bn = blk.block_n;
    let bm = blk.block_m;
    // VMEM: p (bn×3), q (bm×3), mask (bm), distance tile (bn×bm),
    // running min/idx (bn each), double-buffered inputs (×2).
    let inputs = (bn * 3 + bm * 3 + bm) * f * 2;
    let tile = bn * bm * f;
    let outs = bn * 2 * f;
    let vmem = inputs + tile + outs;

    // Matmul p(bn×3) @ qᵀ(3×bm): contraction K=3 pads to mxu_k, and the
    // N dimension pads to mxu_n granularity.
    let k_pad = core.mxu_k.max(3);
    let n_pad = bm.div_ceil(core.mxu_n) * core.mxu_n;
    let useful_macs = (bn * 3 * bm) as f64;
    let issued_macs = (bn * k_pad * n_pad) as f64;
    let mxu_utilization = useful_macs / issued_macs;

    // Per step HBM traffic: the q/mask block is re-read for every source
    // block; p/outs amortise across the j loop. Conservatively count the
    // unique bytes touched this step.
    let hbm_bytes = ((bm * 3 + bm) * f + (bn * 3 + bn * 2) * f) as f64;
    // FLOPs: 2·bn·bm·3 (matmul) + ~6·bn·bm (norms/compare epilogue).
    let flops = (2 * bn * bm * 3 + 6 * bn * bm) as f64;
    let flops_per_byte = flops / hbm_bytes;

    let compute_cycles = issued_macs / core.macs_per_cycle as f64
        + (bn * bm) as f64 / (core.mxu_n as f64 * 8.0); // VPU epilogue
    let memory_cycles = hbm_bytes / core.hbm_bytes_per_cycle;
    // Fixed per-grid-step overhead: grid bookkeeping + DMA descriptor
    // setup + pipeline refill between steps (~30 cycles on TPU). This is
    // what makes very small tiles lose: same total MACs, more bubbles.
    let step_overhead = 30.0;
    let cycles = compute_cycles.max(memory_cycles) + step_overhead;

    KernelEstimate {
        vmem_bytes: vmem,
        mxu_utilization,
        flops_per_byte,
        cycles,
        compute_bound: compute_cycles >= memory_cycles,
    }
}

/// Whether a block configuration is feasible on the core.
pub fn fits(core: &TpuCore, blk: &BlockConfig) -> bool {
    estimate(core, blk).vmem_bytes <= core.vmem_bytes
}

/// Grid-search block shapes for max MXU utilisation subject to VMEM —
/// used by the L1 perf pass to pick BN/BM before re-lowering.
pub fn best_blocks(core: &TpuCore, n: usize, m: usize) -> (BlockConfig, KernelEstimate) {
    let mut best: Option<(BlockConfig, KernelEstimate, f64)> = None;
    let mut bn = 8;
    while bn <= n.min(2048) {
        let mut bm = 128;
        while bm <= m.min(16_384) {
            if n % bn == 0 && m % bm == 0 {
                let blk = BlockConfig {
                    block_n: bn,
                    block_m: bm,
                };
                let e = estimate(core, &blk);
                if e.vmem_bytes <= core.vmem_bytes {
                    // Fewest total cycles over the whole grid wins.
                    let total = e.cycles * ((n / bn) * (m / bm)) as f64;
                    if best.as_ref().map_or(true, |(_, _, bt)| total < *bt) {
                        best = Some((blk, e, total));
                    }
                }
            }
            bm *= 2;
        }
        bn *= 2;
    }
    let (blk, e, _) = best.expect("no feasible block config");
    (blk, e)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_blocks_fit_vmem() {
        let core = TpuCore::default();
        let blk = BlockConfig {
            block_n: 128,
            block_m: 512,
        };
        let e = estimate(&core, &blk);
        assert!(e.vmem_bytes < core.vmem_bytes, "{e:?}");
        // 3-wide contraction on an 8-deep MXU: utilisation is 3/8 at best.
        assert!(e.mxu_utilization <= 3.0 / 8.0 + 1e-12);
        assert!(e.mxu_utilization > 0.2);
    }

    #[test]
    fn vmem_grows_with_tile() {
        let core = TpuCore::default();
        let small = estimate(&core, &BlockConfig { block_n: 64, block_m: 256 });
        let big = estimate(&core, &BlockConfig { block_n: 256, block_m: 1024 });
        assert!(big.vmem_bytes > small.vmem_bytes);
    }

    #[test]
    fn oversized_tile_rejected() {
        let core = TpuCore::default();
        assert!(!fits(
            &core,
            &BlockConfig {
                block_n: 4096,
                block_m: 16_384
            }
        ));
    }

    #[test]
    fn best_blocks_feasible_and_divisible() {
        let core = TpuCore::default();
        let (blk, e) = best_blocks(&core, 4096, 16_384);
        assert_eq!(4096 % blk.block_n, 0);
        assert_eq!(16_384 % blk.block_m, 0);
        assert!(e.vmem_bytes <= core.vmem_bytes);
        // Larger bm amortises the epilogue → expect bm ≥ 512.
        assert!(blk.block_m >= 512, "{blk:?}");
    }

    #[test]
    fn arithmetic_intensity_improves_with_block_n() {
        // Re-reading q for every source block is the big traffic term;
        // larger bn amortises it.
        let core = TpuCore::default();
        let a = estimate(&core, &BlockConfig { block_n: 32, block_m: 512 });
        let b = estimate(&core, &BlockConfig { block_n: 256, block_m: 512 });
        assert!(b.flops_per_byte > a.flops_per_byte);
    }
}
