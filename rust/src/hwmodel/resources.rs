//! FPGA resource model → Table II and the Fig. 4 floorplan report.
//!
//! Per-block resource costs are standard Vitis HLS f32 operator costs on
//! UltraScale+ (DSP48E2): an f32 mul is 3 DSPs, an f32 add/sub 2 DSPs,
//! an f32 compare is LUT-only. The constants were then calibrated so the
//! default [`AcceleratorConfig`] reproduces the paper's Table II within
//! a few percent; the point of the model is that resources *scale
//! correctly with the architecture parameters* (PE count, buffer sizes),
//! which is what the ablation benches exercise.

use super::AcceleratorConfig;

/// Alveo U50 totals (UltraScale+ XCU50, from the AMD data sheet).
#[derive(Clone, Copy, Debug)]
pub struct DeviceCapacity {
    pub lut: u64,
    pub ff: u64,
    pub bram_36k: u64,
    pub dsp: u64,
    /// Number of SLRs; the design occupies SLR0 only (HBM access, §IV.B).
    pub slrs: u64,
}

pub const U50: DeviceCapacity = DeviceCapacity {
    lut: 870_000,
    ff: 1_740_000,
    bram_36k: 2_688,
    dsp: 5_940,
    slrs: 2,
};

/// Resource usage of one subsystem.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Usage {
    pub lut: u64,
    pub ff: u64,
    pub bram_36k: u64,
    pub dsp: u64,
}

impl Usage {
    pub fn add(&self, o: &Usage) -> Usage {
        Usage {
            lut: self.lut + o.lut,
            ff: self.ff + o.ff,
            bram_36k: self.bram_36k + o.bram_36k,
            dsp: self.dsp + o.dsp,
        }
    }

    pub fn scale(&self, k: u64) -> Usage {
        Usage {
            lut: self.lut * k,
            ff: self.ff * k,
            bram_36k: self.bram_36k * k,
            dsp: self.dsp * k,
        }
    }
}

/// Itemised breakdown (printed as the Fig. 4 floorplan substitute).
#[derive(Clone, Debug)]
pub struct ResourceReport {
    pub items: Vec<(String, Usage)>,
    pub total: Usage,
}

/// f32 operator costs (Vitis HLS, fully pipelined, UltraScale+).
const DSP_PER_FMUL: u64 = 3;
const DSP_PER_FADD: u64 = 2;
const LUT_PER_FMUL: u64 = 120;
const LUT_PER_FADD: u64 = 220;
const LUT_PER_FCMP: u64 = 70;
const FF_PER_FMUL: u64 = 180;
const FF_PER_FADD: u64 = 340;
/// Pipeline/control overhead per PE (state machine, FIFO glue).
const LUT_PE_CTRL: u64 = 160;
const FF_PE_CTRL: u64 = 260;

/// One Distance block: ||p−q||² = 3 subs, 3 muls, 2 adds. HLS maps the
/// subtractors to LUT fabric (`-hls fpo` low-latency adders) and keeps
/// DSPs for the multipliers and the accumulation adds.
fn distance_block() -> Usage {
    Usage {
        lut: 3 * LUT_PER_FADD + 3 * LUT_PER_FMUL + 2 * LUT_PER_FADD,
        ff: 3 * FF_PER_FADD + 3 * FF_PER_FMUL + 2 * FF_PER_FADD,
        bram_36k: 0,
        dsp: 3 * DSP_PER_FMUL + 2 * DSP_PER_FADD,
    }
}

/// One MIN block: compare + two registers (distance, index).
fn min_block() -> Usage {
    Usage {
        lut: LUT_PER_FCMP + 90,
        ff: 2 * 64,
        bram_36k: 0,
        dsp: 0,
    }
}

/// Comparison tree over `cols` columns: cols−1 comparators.
fn cmp_tree(cols: u64) -> Usage {
    Usage {
        lut: (LUT_PER_FCMP + 120) * (cols - 1),
        ff: 96 * (cols - 1),
        bram_36k: 0,
        dsp: 0,
    }
}

/// Point cloud transformer: 4×4 · [x y z 1] per cycle = 9 muls + 9 adds
/// (rotation) fully unrolled, ×`rows` lanes.
fn transformer(rows: u64) -> Usage {
    Usage {
        lut: (9 * LUT_PER_FMUL + 9 * LUT_PER_FADD) * rows,
        ff: (9 * FF_PER_FMUL + 9 * FF_PER_FADD) * rows,
        bram_36k: 0,
        dsp: (9 * DSP_PER_FMUL + 9 * DSP_PER_FADD) * rows,
    }
}

/// Result accumulator: 9 MACs for Σp·qᵀ + 6 adders for Σp, Σq + 1 for Σd,
/// double-buffered f64 accumulation (2 DSP per f64 add lane approximated).
fn accumulator() -> Usage {
    Usage {
        lut: 9 * (LUT_PER_FMUL + LUT_PER_FADD) + 7 * LUT_PER_FADD + 2600,
        ff: 9 * (FF_PER_FMUL + FF_PER_FADD) + 7 * FF_PER_FADD + 3400,
        bram_36k: 4,
        dsp: 9 * (DSP_PER_FMUL + DSP_PER_FADD) + 7 * DSP_PER_FADD,
    }
}

/// Target cloud buffer: capacity × 3 × f32, partitioned into `cols`
/// banks, each 36Kb BRAM = 1024 × 32b.
fn target_buffer(capacity: u64, cols: u64) -> Usage {
    let words = capacity * 3;
    let words_per_bank = words.div_ceil(cols);
    let brams_per_bank = words_per_bank.div_ceil(1024);
    Usage {
        lut: 220 * cols, // bank mux / broadcast bus
        ff: 180 * cols,
        bram_36k: brams_per_bank * cols,
        dsp: 0,
    }
}

/// Source register buffer + staging: rows × 3 × f32 registers plus a
/// BRAM-backed staging area for the 4096-point sample.
fn source_buffer(capacity: u64, rows: u64) -> Usage {
    Usage {
        lut: 150 * rows,
        ff: rows * 3 * 32,
        bram_36k: (capacity * 3 * 4).div_ceil(4608), // bytes / 36Kbit
        dsp: 0,
    }
}

/// Host interface: HBM AXI masters, DMA engines, control regs. Fixed
/// cost measured from a Vitis shell + 2 AXI-HBM channels.
fn host_interface() -> Usage {
    Usage {
        lut: 58_000,
        ff: 96_000,
        bram_36k: 120,
        dsp: 4,
    }
}

/// FIFO glue between the four pipeline stages (Fig. 3).
fn stage_fifos() -> Usage {
    Usage {
        lut: 9_000,
        ff: 14_000,
        bram_36k: 24,
        dsp: 0,
    }
}

/// Full design report for a configuration.
pub fn report(cfg: &AcceleratorConfig) -> ResourceReport {
    let pes = cfg.pe_count() as u64;
    let cols = cfg.pe_cols as u64;
    let rows = cfg.pe_rows as u64;
    let mut items: Vec<(String, Usage)> = Vec::new();
    items.push((
        format!("distance PEs ({}x{})", cfg.pe_rows, cfg.pe_cols),
        distance_block().add(&min_block()).add(&Usage {
            lut: LUT_PE_CTRL,
            ff: FF_PE_CTRL,
            bram_36k: 0,
            dsp: 0,
        })
        .scale(pes),
    ));
    items.push((format!("comparison tree ({cols} cols)"), cmp_tree(cols).scale(rows)));
    items.push(("point cloud transformer".into(), transformer(rows)));
    items.push(("result accumulator".into(), accumulator()));
    items.push((
        format!("target buffer ({} pts)", cfg.target_capacity),
        target_buffer(cfg.target_capacity as u64, cols),
    ));
    items.push((
        format!("source buffer ({} pts)", cfg.source_capacity),
        source_buffer(cfg.source_capacity as u64, rows),
    ));
    items.push(("stage FIFOs".into(), stage_fifos()));
    items.push(("host interface (HBM/DMA)".into(), host_interface()));

    let mut total = Usage::default();
    for (_, u) in &items {
        total = total.add(u);
    }
    ResourceReport { items, total }
}

/// Utilisation fractions vs one SLR and vs the whole device — the two
/// percentage columns of Table II.
pub fn utilisation(u: &Usage, dev: &DeviceCapacity) -> [(f64, f64); 4] {
    let slr = |x: u64, cap: u64| (x as f64) / (cap as f64 / dev.slrs as f64);
    let all = |x: u64, cap: u64| (x as f64) / cap as f64;
    [
        (slr(u.lut, dev.lut), all(u.lut, dev.lut)),
        (slr(u.ff, dev.ff), all(u.ff, dev.ff)),
        (slr(u.bram_36k, dev.bram_36k), all(u.bram_36k, dev.bram_36k)),
        (slr(u.dsp, dev.dsp), all(u.dsp, dev.dsp)),
    ]
}

/// Paper's Table II reference values for comparison printing.
pub const PAPER_TABLE2: Usage = Usage {
    lut: 313_542,
    ff: 441_273,
    bram_36k: 613,
    dsp: 2_384,
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_close_to_paper_table2() {
        let rep = report(&AcceleratorConfig::default());
        let t = rep.total;
        let close = |got: u64, want: u64, tol: f64| {
            let rel = (got as f64 - want as f64).abs() / want as f64;
            assert!(
                rel < tol,
                "got {got}, paper {want}, rel err {rel:.3}"
            );
        };
        // The model is calibrated: each resource within 20% of Table II.
        close(t.lut, PAPER_TABLE2.lut, 0.20);
        close(t.ff, PAPER_TABLE2.ff, 0.20);
        close(t.bram_36k, PAPER_TABLE2.bram_36k, 0.20);
        close(t.dsp, PAPER_TABLE2.dsp, 0.20);
    }

    #[test]
    fn fits_in_one_slr() {
        // §IV.B: the design occupies one of the two SLRs.
        let rep = report(&AcceleratorConfig::default());
        for (slr_frac, _) in utilisation(&rep.total, &U50) {
            assert!(slr_frac < 1.0, "does not fit in SLR0: {slr_frac}");
        }
    }

    #[test]
    fn resources_scale_with_pe_array() {
        let small = report(&AcceleratorConfig {
            pe_cols: 8,
            pe_rows: 4,
            ..Default::default()
        });
        let big = report(&AcceleratorConfig::default());
        assert!(big.total.dsp > small.total.dsp);
        assert!(big.total.lut > small.total.lut);
    }

    #[test]
    fn bram_scales_with_target_capacity() {
        let small = report(&AcceleratorConfig {
            target_capacity: 16_384,
            ..Default::default()
        });
        let big = report(&AcceleratorConfig::default());
        assert!(big.total.bram_36k > small.total.bram_36k);
    }

    #[test]
    fn utilisation_slr_is_twice_overall() {
        let rep = report(&AcceleratorConfig::default());
        for (slr, all) in utilisation(&rep.total, &U50) {
            assert!((slr - 2.0 * all).abs() < 1e-12);
        }
    }

    #[test]
    fn breakdown_sums_to_total() {
        let rep = report(&AcceleratorConfig::default());
        let mut sum = Usage::default();
        for (_, u) in &rep.items {
            sum = sum.add(u);
        }
        assert_eq!(sum, rep.total);
    }
}
