//! Kernel latency model → the CPU+FPGA rows of Table IV.
//!
//! The NN searcher streams `n_source` points against `n_target`
//! candidates through a `pe_rows × pe_cols` array: each cycle, one batch
//! of `pe_cols` target points is broadcast to `pe_rows` resident source
//! points (paper §III.B: "a batch of points can be read and broadcast to
//! the distance computation array in parallel"). The four stages (read,
//! distance, compare, accumulate) are FIFO-coupled and overlap, so
//! steady-state throughput is set by the distance stage and the others
//! contribute only pipeline fill/drain. The cycle-level simulator in
//! `pipesim` validates this closed form (see `pipesim_fig3` bench).

use super::AcceleratorConfig;

/// Latency breakdown for one ICP iteration on the device (seconds).
#[derive(Clone, Copy, Debug, Default)]
pub struct IterationLatency {
    pub transform_s: f64,
    pub nn_search_s: f64,
    pub accumulate_s: f64,
    /// Pipeline fill/drain overhead.
    pub overhead_s: f64,
}

impl IterationLatency {
    pub fn total_s(&self) -> f64 {
        self.transform_s + self.nn_search_s + self.accumulate_s + self.overhead_s
    }
}

/// Per-frame latency breakdown (seconds).
#[derive(Clone, Copy, Debug, Default)]
pub struct FrameLatency {
    /// Host→card transfer of both clouds over PCIe + HBM write.
    pub upload_s: f64,
    /// Sum over ICP iterations of the kernel time.
    pub kernel_s: f64,
    /// Accumulator readback + host SVD per iteration.
    pub host_svd_s: f64,
    /// Total.
    pub total_s: f64,
}

/// Cycles for one pass of the NN searcher over the point sets.
pub fn nn_search_cycles(cfg: &AcceleratorConfig, n_source: usize, n_target: usize) -> u64 {
    let rows = cfg.pe_rows as u64;
    let cols = cfg.pe_cols as u64;
    let src_blocks = (n_source as u64).div_ceil(rows);
    let tgt_batches = (n_target as u64).div_ceil(cols);
    // Each source block holds the array for all target batches; the
    // compare-tree reduction (log2(cols) deep) drains per block and the
    // per-block register reload costs `rows` cycles.
    let cmp_drain = (cols as f64).log2().ceil() as u64 + 2;
    src_blocks * (tgt_batches + rows + cmp_drain)
}

/// One device ICP iteration: transform + NN + accumulate, overlapped.
pub fn iteration_latency(
    cfg: &AcceleratorConfig,
    n_source: usize,
    n_target: usize,
) -> IterationLatency {
    let cyc = cfg.cycle_s();
    // Transform stage is fully pipelined at `rows` points/cycle and
    // overlaps the NN search of the previous block; only the first block
    // is exposed.
    let transform_s = (cfg.pe_rows as f64) * cyc;
    let nn_cycles = nn_search_cycles(cfg, n_source, n_target);
    let nn_search_s = nn_cycles as f64 * cyc;
    // Result accumulation consumes one (p, q) pair per cycle, fully
    // overlapped with the search; exposed cost is the final drain.
    let accumulate_s = 32.0 * cyc;
    // Kernel launch / control handshake (XRT ~10 µs per enqueue).
    let overhead_s = 10e-6;
    IterationLatency {
        transform_s,
        nn_search_s,
        accumulate_s,
        overhead_s,
    }
}

/// Host-side SVD + loop bookkeeping per iteration. 3×3 Jacobi SVD is
/// microseconds; the dominant term is the OpenCL/XRT readback of the
/// 17-float accumulator buffer (~20 µs round trip).
pub const HOST_SVD_S: f64 = 25e-6;

/// Full frame: upload once, iterate `iterations` times.
pub fn frame_latency(
    cfg: &AcceleratorConfig,
    n_source: usize,
    n_target: usize,
    iterations: u32,
) -> FrameLatency {
    let bytes = ((n_source + n_target) * 3 * 4) as f64;
    // PCIe to card, then HBM into the kernel buffers (write once).
    let upload_s = bytes / (cfg.pcie_gbps * 1e9) + bytes / (cfg.hbm_gbps * 1e9);
    let it = iteration_latency(cfg, n_source, n_target);
    let kernel_s = it.total_s() * iterations as f64;
    let host_svd_s = HOST_SVD_S * iterations as f64;
    FrameLatency {
        upload_s,
        kernel_s,
        host_svd_s,
        total_s: upload_s + kernel_s + host_svd_s,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_scale_frame_latency_in_table4_range() {
        // Paper Table IV CPU+FPGA: 136–537 ms/frame at 4096×~130k, ≤50
        // iterations. One iteration at defaults:
        let cfg = AcceleratorConfig::default();
        let it = iteration_latency(&cfg, 4096, 131_072);
        // 512 src blocks × 8192 batches ≈ 4.2M cycles @300 MHz ≈ 14 ms.
        assert!(it.total_s() > 5e-3 && it.total_s() < 30e-3, "{it:?}");
        let f = frame_latency(&cfg, 4096, 131_072, 20);
        assert!(
            f.total_s > 0.1 && f.total_s < 0.7,
            "frame {} s out of Table IV range",
            f.total_s
        );
    }

    #[test]
    fn nn_cycles_scale_linearly_in_both_clouds() {
        let cfg = AcceleratorConfig::default();
        let base = nn_search_cycles(&cfg, 4096, 65_536);
        let double_tgt = nn_search_cycles(&cfg, 4096, 131_072);
        let double_src = nn_search_cycles(&cfg, 8192, 65_536);
        let r_t = double_tgt as f64 / base as f64;
        let r_s = double_src as f64 / base as f64;
        assert!((r_t - 2.0).abs() < 0.05, "target scaling {r_t}");
        assert!((r_s - 2.0).abs() < 0.05, "source scaling {r_s}");
    }

    #[test]
    fn more_pes_is_faster() {
        let small = AcceleratorConfig {
            pe_cols: 8,
            pe_rows: 4,
            ..Default::default()
        };
        let big = AcceleratorConfig::default();
        let ls = iteration_latency(&small, 4096, 131_072).total_s();
        let lb = iteration_latency(&big, 4096, 131_072).total_s();
        assert!(lb < ls / 2.0, "{lb} vs {ls}");
    }

    #[test]
    fn upload_cost_reasonable() {
        let cfg = AcceleratorConfig::default();
        let f = frame_latency(&cfg, 4096, 131_072, 1);
        // ~1.6 MB over 12 GB/s + HBM ≈ 160 µs; must be well under kernel.
        assert!(f.upload_s < 1e-3);
        assert!(f.upload_s > 1e-5);
        assert!(f.kernel_s > f.upload_s);
    }

    #[test]
    fn frame_latency_monotone_in_iterations() {
        let cfg = AcceleratorConfig::default();
        let a = frame_latency(&cfg, 4096, 131_072, 10).total_s;
        let b = frame_latency(&cfg, 4096, 131_072, 20).total_s;
        assert!(b > a);
        // Roughly linear: fixed upload + per-iteration kernel.
        let per_it = (b - a) / 10.0;
        let c = frame_latency(&cfg, 4096, 131_072, 30).total_s;
        assert!(((c - b) / 10.0 - per_it).abs() / per_it < 0.01);
    }
}
