//! Analytical model of the FPPS accelerator on the Alveo U50.
//!
//! The physical FPGA is not available in this environment, so the
//! resource / latency / power numbers of the paper's evaluation are
//! regenerated from an analytical model of the architecture the paper
//! describes (Figs. 2–3): a PE array NN searcher fed by partitioned
//! BRAM, a pipelined point-cloud transformer, and a result accumulator,
//! behind an HBM host interface. Calibration constants are documented
//! next to each formula; DESIGN.md §3 records the substitution.
//!
//! Submodules:
//! * [`resources`] — LUT/FF/BRAM/DSP counts → Table II + Fig. 4 floorplan
//! * [`latency`]   — per-frame kernel/transfer cycle model → Table IV
//! * [`power`]     — static/dynamic/host power and energy → §IV.D
//! * [`tpu_estimate`] — VMEM/MXU occupancy of the Pallas mapping (the
//!   §Perf structural target for L1)

pub mod latency;
pub mod power;
pub mod resources;
pub mod tpu_estimate;

/// Architecture parameters of the accelerator instance. Defaults are
/// reverse-fitted to the paper's Table II utilisation on SLR0.
#[derive(Clone, Copy, Debug)]
pub struct AcceleratorConfig {
    /// PE array: columns of parallel distance units ("processing array"
    /// of Fig. 3). Each column owns one target-cloud BRAM partition.
    pub pe_cols: usize,
    /// PE array rows: source points processed concurrently (the local
    /// register buffer depth).
    pub pe_rows: usize,
    /// Kernel clock (MHz). Vitis HLS on U50 typically closes 250–300 MHz.
    pub clock_mhz: f64,
    /// Capacity of the on-chip target ("destination") cloud buffer in
    /// points — the paper's "around 130k NN candidates".
    pub target_capacity: usize,
    /// Capacity of the source buffer (the paper samples 4096 per frame).
    pub source_capacity: usize,
    /// HBM effective bandwidth to the kernel (GB/s). U50: 316 GB/s peak,
    /// one SLR + AXI overheads → ~60 GB/s sustained for this design.
    pub hbm_gbps: f64,
    /// PCIe host→card effective bandwidth (GB/s), Gen3 x16 ≈ 12 GB/s.
    pub pcie_gbps: f64,
    /// HBM slice (MiB) reserved for *resident* reference clouds — the
    /// target half of the Fig. 2 DMA stays on the card between
    /// alignments, and this pool bounds how many distinct targets can
    /// stay resident at once (see [`AcceleratorConfig::resident_target_slots`]).
    /// The U50 has 8 GiB of HBM, but the pool is kept small: every
    /// resident target also needs its BRAM-partitioned copy streamed in
    /// on activation, so a large pool only helps as far as the driver's
    /// slot bookkeeping can exploit it.
    pub hbm_residency_mib: f64,
}

impl Default for AcceleratorConfig {
    fn default() -> Self {
        Self {
            pe_cols: 16,
            pe_rows: 8,
            clock_mhz: 300.0,
            target_capacity: 131_072,
            source_capacity: 4096,
            hbm_gbps: 60.0,
            pcie_gbps: 12.0,
            hbm_residency_mib: 8.0,
        }
    }
}

/// Padded HBM footprint of one candidate resident target cloud — the
/// per-map cost model behind residency-aware admission: a map whose
/// footprint exceeds one residency slot is rejected or
/// downsampled-to-fit by an explicit policy
/// (see `coordinator::AdmissionPolicy`) instead of being silently
/// shrunk on upload.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TargetFootprint {
    /// Raw point count of the candidate cloud.
    pub points: usize,
    /// Points after padding to the kernel's target block size — what the
    /// device buffers (and the HBM slot) actually hold.
    pub padded_points: usize,
    /// HBM bytes of the padded cloud at 16 B/point (xyz f32 + mask).
    pub bytes: u64,
}

impl TargetFootprint {
    /// Does this target fit one residency slot of `slot_capacity`
    /// points? The admission bound is the slot's *point* capacity (slot
    /// capacities are block-aligned, so the padded cloud fits the
    /// slot's padded buffer exactly when the raw count fits); the
    /// padded byte figure reports what admitting it would cost in HBM.
    pub fn fits_slot(&self, slot_capacity: usize) -> bool {
        self.points <= slot_capacity
    }
}

/// Upper bound on simultaneously resident reference clouds, regardless
/// of how much HBM the residency pool would fit. Each slot adds a way
/// to the activation crossbar and a row of driver bookkeeping, so the
/// count is capped the way set-associative caches cap associativity.
pub const MAX_RESIDENT_TARGETS: usize = 8;

impl AcceleratorConfig {
    /// Total parallel distance lanes.
    pub fn pe_count(&self) -> usize {
        self.pe_cols * self.pe_rows
    }

    /// Seconds per kernel clock cycle.
    pub fn cycle_s(&self) -> f64 {
        1.0 / (self.clock_mhz * 1e6)
    }

    /// HBM bytes one resident target occupies at `points` capacity:
    /// xyz as 3 × f32 plus one f32 validity-mask word per point.
    pub fn resident_target_bytes(points: usize) -> u64 {
        points as u64 * 16
    }

    /// Footprint of a `points`-point reference cloud padded to the
    /// kernel target block `block_m` (an empty cloud still occupies one
    /// block: the slot is allocated, not packed).
    pub fn target_footprint(&self, points: usize, block_m: usize) -> TargetFootprint {
        let block = block_m.max(1);
        let padded_points = points.div_ceil(block).max(1) * block;
        TargetFootprint {
            points,
            padded_points,
            bytes: Self::resident_target_bytes(padded_points),
        }
    }

    /// How many reference clouds of `target_capacity` points fit in the
    /// HBM residency pool — the physically grounded default for the
    /// backends' LRU target slots. Always ≥ 1 (the active target must
    /// fit) and capped at [`MAX_RESIDENT_TARGETS`].
    pub fn resident_target_slots(&self, target_capacity: usize) -> usize {
        let budget = (self.hbm_residency_mib * (1u64 << 20) as f64) as u64;
        let per = Self::resident_target_bytes(target_capacity.max(1));
        ((budget / per.max(1)).max(1) as usize).min(MAX_RESIDENT_TARGETS)
    }
}

/// Residency slot count of the default accelerator instance at its own
/// target capacity — what backends use when the caller does not pick a
/// slot count explicitly.
pub fn default_residency_slots() -> usize {
    let c = AcceleratorConfig::default();
    c.resident_target_slots(c.target_capacity)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_matches_paper_scale() {
        let c = AcceleratorConfig::default();
        assert_eq!(c.pe_count(), 128);
        // "around 130k NN candidates for each cloud point"
        assert!(c.target_capacity >= 130_000);
        assert_eq!(c.source_capacity, 4096);
        assert!((c.cycle_s() - 1.0 / 300e6).abs() < 1e-18);
    }

    #[test]
    fn target_footprint_pads_to_the_block() {
        let c = AcceleratorConfig::default();
        let f = c.target_footprint(5000, 2048);
        assert_eq!(f.points, 5000);
        assert_eq!(f.padded_points, 6144);
        assert_eq!(f.bytes, 6144 * 16);
        assert!(f.fits_slot(16_384));
        assert!(!c.target_footprint(20_000, 2048).fits_slot(16_384));
        // Boundary: exactly the slot capacity still fits.
        assert!(c.target_footprint(16_384, 2048).fits_slot(16_384));
        assert!(!c.target_footprint(16_385, 2048).fits_slot(16_384));
        // An empty cloud still occupies one block.
        assert_eq!(c.target_footprint(0, 2048).padded_points, 2048);
        // Degenerate block never divides by zero.
        assert_eq!(c.target_footprint(7, 0).padded_points, 7);
    }

    #[test]
    fn residency_slots_follow_the_hbm_budget() {
        let c = AcceleratorConfig::default();
        // 8 MiB pool / (131072 pts × 16 B) = 4 slots at the default
        // capacity — enough for tile ping-pong, far below the cap.
        assert_eq!(c.resident_target_slots(c.target_capacity), 4);
        assert_eq!(default_residency_slots(), 4);
        // Smaller targets fit more, up to the crossbar cap…
        assert_eq!(c.resident_target_slots(4096), MAX_RESIDENT_TARGETS);
        // …and a target bigger than the pool still gets its one slot.
        assert_eq!(c.resident_target_slots(10_000_000), 1);
        assert_eq!(c.resident_target_slots(0), MAX_RESIDENT_TARGETS);
        // The budget scales: half the pool at default capacity → 2 slots.
        let half = AcceleratorConfig {
            hbm_residency_mib: 4.0,
            ..Default::default()
        };
        assert_eq!(half.resident_target_slots(half.target_capacity), 2);
    }
}
