//! Analytical model of the FPPS accelerator on the Alveo U50.
//!
//! The physical FPGA is not available in this environment, so the
//! resource / latency / power numbers of the paper's evaluation are
//! regenerated from an analytical model of the architecture the paper
//! describes (Figs. 2–3): a PE array NN searcher fed by partitioned
//! BRAM, a pipelined point-cloud transformer, and a result accumulator,
//! behind an HBM host interface. Calibration constants are documented
//! next to each formula; DESIGN.md §3 records the substitution.
//!
//! Submodules:
//! * [`resources`] — LUT/FF/BRAM/DSP counts → Table II + Fig. 4 floorplan
//! * [`latency`]   — per-frame kernel/transfer cycle model → Table IV
//! * [`power`]     — static/dynamic/host power and energy → §IV.D
//! * [`tpu_estimate`] — VMEM/MXU occupancy of the Pallas mapping (the
//!   §Perf structural target for L1)

pub mod latency;
pub mod power;
pub mod resources;
pub mod tpu_estimate;

/// Architecture parameters of the accelerator instance. Defaults are
/// reverse-fitted to the paper's Table II utilisation on SLR0.
#[derive(Clone, Copy, Debug)]
pub struct AcceleratorConfig {
    /// PE array: columns of parallel distance units ("processing array"
    /// of Fig. 3). Each column owns one target-cloud BRAM partition.
    pub pe_cols: usize,
    /// PE array rows: source points processed concurrently (the local
    /// register buffer depth).
    pub pe_rows: usize,
    /// Kernel clock (MHz). Vitis HLS on U50 typically closes 250–300 MHz.
    pub clock_mhz: f64,
    /// Capacity of the on-chip target ("destination") cloud buffer in
    /// points — the paper's "around 130k NN candidates".
    pub target_capacity: usize,
    /// Capacity of the source buffer (the paper samples 4096 per frame).
    pub source_capacity: usize,
    /// HBM effective bandwidth to the kernel (GB/s). U50: 316 GB/s peak,
    /// one SLR + AXI overheads → ~60 GB/s sustained for this design.
    pub hbm_gbps: f64,
    /// PCIe host→card effective bandwidth (GB/s), Gen3 x16 ≈ 12 GB/s.
    pub pcie_gbps: f64,
}

impl Default for AcceleratorConfig {
    fn default() -> Self {
        Self {
            pe_cols: 16,
            pe_rows: 8,
            clock_mhz: 300.0,
            target_capacity: 131_072,
            source_capacity: 4096,
            hbm_gbps: 60.0,
            pcie_gbps: 12.0,
        }
    }
}

impl AcceleratorConfig {
    /// Total parallel distance lanes.
    pub fn pe_count(&self) -> usize {
        self.pe_cols * self.pe_rows
    }

    /// Seconds per kernel clock cycle.
    pub fn cycle_s(&self) -> f64 {
        1.0 / (self.clock_mhz * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_matches_paper_scale() {
        let c = AcceleratorConfig::default();
        assert_eq!(c.pe_count(), 128);
        // "around 130k NN candidates for each cloud point"
        assert!(c.target_capacity >= 130_000);
        assert_eq!(c.source_capacity, 4096);
        assert!((c.cycle_s() - 1.0 / 300e6).abs() < 1e-18);
    }
}
