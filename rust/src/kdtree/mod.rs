//! Exact 3-D kd-tree — the correspondence-search structure of the PCL
//! baseline (paper §IV setup) and the subject of the §V discussion on
//! why tree search maps poorly onto the FPGA pipeline.
//!
//! Implementation notes:
//! * Implicit binary heap layout over a reordered index array — no
//!   per-node allocations, cache-friendly traversal.
//! * Median split on the widest-spread axis (sliding-midpoint is not
//!   needed at LiDAR densities; PCL/FLANN uses mean-split but median
//!   keeps the tree balanced deterministically, which matters for the
//!   latency-determinism discussion in §V).
//! * Exact NN with backtracking ("backward tracing" in the paper's
//!   words), kNN with a bounded max-heap, and radius search.

use crate::pointcloud::PointCloud;

/// One flattened node. Leaves hold a contiguous range of reordered
/// point indices; internal nodes split `axis` at `split`.
#[derive(Clone, Debug)]
enum Node {
    Internal {
        axis: u8,
        split: f32,
        left: u32,
        right: u32,
    },
    Leaf {
        start: u32,
        end: u32,
    },
}

/// Exact kd-tree over a borrowed cloud.
pub struct KdTree<'a> {
    cloud: &'a PointCloud,
    nodes: Vec<Node>,
    /// Point indices reordered so each leaf owns a contiguous slice.
    order: Vec<u32>,
    leaf_size: usize,
}

/// Result of a nearest-neighbour query.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Neighbor {
    pub index: u32,
    pub dist_sq: f32,
}

impl<'a> KdTree<'a> {
    /// Build with the default leaf size (16, comparable to FLANN's).
    pub fn build(cloud: &'a PointCloud) -> Self {
        Self::build_with_leaf_size(cloud, 16)
    }

    pub fn build_with_leaf_size(cloud: &'a PointCloud, leaf_size: usize) -> Self {
        assert!(leaf_size >= 1);
        let mut order: Vec<u32> = (0..cloud.len() as u32).collect();
        let mut nodes = Vec::new();
        if !cloud.is_empty() {
            let n = order.len();
            build_rec(cloud, &mut nodes, &mut order, 0, n, leaf_size);
        }
        Self {
            cloud,
            nodes,
            order,
            leaf_size,
        }
    }

    pub fn len(&self) -> usize {
        self.cloud.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cloud.is_empty()
    }

    pub fn leaf_size(&self) -> usize {
        self.leaf_size
    }

    /// Exact nearest neighbour; `None` on an empty tree.
    pub fn nearest(&self, q: [f32; 3]) -> Option<Neighbor> {
        if self.nodes.is_empty() {
            return None;
        }
        let mut best = Neighbor {
            index: u32::MAX,
            dist_sq: f32::INFINITY,
        };
        self.nearest_rec(0, q, &mut best);
        (best.index != u32::MAX).then_some(best)
    }

    /// Exact nearest neighbour within `max_dist`; `None` if nothing is
    /// that close (the ICP max-correspondence-distance rejection, pushed
    /// into the search the way PCL does it).
    pub fn nearest_within(&self, q: [f32; 3], max_dist: f32) -> Option<Neighbor> {
        if self.nodes.is_empty() {
            return None;
        }
        let mut best = Neighbor {
            index: u32::MAX,
            dist_sq: max_dist * max_dist,
        };
        self.nearest_rec(0, q, &mut best);
        (best.index != u32::MAX).then_some(best)
    }

    fn nearest_rec(&self, node: u32, q: [f32; 3], best: &mut Neighbor) {
        nearest_rec_impl(self.cloud, &self.nodes, &self.order, node, q, best);
    }

    /// *Approximate* nearest neighbour with a bounded leaf-visit budget —
    /// the Greenspan & Yurick style search the paper's §V discussion
    /// evaluates ("approximate k-d tree search can reduce computational
    /// complexity but often leads to degraded convergence in ICP").
    /// `max_leaf_visits = usize::MAX` degenerates to exact search;
    /// `1` is a pure greedy descent (FLANN `checks=1`).
    pub fn nearest_approximate(
        &self,
        q: [f32; 3],
        max_leaf_visits: usize,
    ) -> Option<Neighbor> {
        if self.nodes.is_empty() || max_leaf_visits == 0 {
            return None;
        }
        let mut best = Neighbor {
            index: u32::MAX,
            dist_sq: f32::INFINITY,
        };
        let mut budget = max_leaf_visits;
        self.nearest_approx_rec(0, q, &mut best, &mut budget);
        (best.index != u32::MAX).then_some(best)
    }

    fn nearest_approx_rec(
        &self,
        node: u32,
        q: [f32; 3],
        best: &mut Neighbor,
        budget: &mut usize,
    ) {
        if *budget == 0 {
            return;
        }
        match &self.nodes[node as usize] {
            Node::Leaf { start, end } => {
                *budget -= 1;
                for &i in &self.order[*start as usize..*end as usize] {
                    let d = dist_sq(self.cloud.get(i as usize), q);
                    if d < best.dist_sq {
                        *best = Neighbor {
                            index: i,
                            dist_sq: d,
                        };
                    }
                }
            }
            Node::Internal {
                axis,
                split,
                left,
                right,
            } => {
                let delta = q[*axis as usize] - split;
                let (near, far) = if delta <= 0.0 {
                    (*left, *right)
                } else {
                    (*right, *left)
                };
                self.nearest_approx_rec(near, q, best, budget);
                // Backtrack only while budget remains — the truncated
                // "backward tracing" that makes the search approximate.
                if *budget > 0 && delta * delta < best.dist_sq {
                    self.nearest_approx_rec(far, q, best, budget);
                }
            }
        }
    }

    /// Exact k nearest neighbours, ascending by distance.
    pub fn knn(&self, q: [f32; 3], k: usize) -> Vec<Neighbor> {
        if self.nodes.is_empty() || k == 0 {
            return Vec::new();
        }
        let mut heap = BoundedMaxHeap::new(k);
        self.knn_rec(0, q, &mut heap);
        heap.into_sorted()
    }

    fn knn_rec(&self, node: u32, q: [f32; 3], heap: &mut BoundedMaxHeap) {
        match &self.nodes[node as usize] {
            Node::Leaf { start, end } => {
                for &i in &self.order[*start as usize..*end as usize] {
                    heap.push(Neighbor {
                        index: i,
                        dist_sq: dist_sq(self.cloud.get(i as usize), q),
                    });
                }
            }
            Node::Internal {
                axis,
                split,
                left,
                right,
            } => {
                let delta = q[*axis as usize] - split;
                let (near, far) = if delta <= 0.0 {
                    (*left, *right)
                } else {
                    (*right, *left)
                };
                self.knn_rec(near, q, heap);
                if !heap.full() || delta * delta < heap.worst() {
                    self.knn_rec(far, q, heap);
                }
            }
        }
    }

    /// All points within `radius` of `q`, ascending by distance.
    pub fn radius(&self, q: [f32; 3], radius: f32) -> Vec<Neighbor> {
        let mut out = Vec::new();
        if self.nodes.is_empty() {
            return out;
        }
        let r2 = radius * radius;
        self.radius_rec(0, q, r2, &mut out);
        out.sort_by(|a, b| a.dist_sq.partial_cmp(&b.dist_sq).unwrap());
        out
    }

    fn radius_rec(&self, node: u32, q: [f32; 3], r2: f32, out: &mut Vec<Neighbor>) {
        match &self.nodes[node as usize] {
            Node::Leaf { start, end } => {
                for &i in &self.order[*start as usize..*end as usize] {
                    let d = dist_sq(self.cloud.get(i as usize), q);
                    if d <= r2 {
                        out.push(Neighbor {
                            index: i,
                            dist_sq: d,
                        });
                    }
                }
            }
            Node::Internal {
                axis,
                split,
                left,
                right,
            } => {
                let delta = q[*axis as usize] - split;
                let (near, far) = if delta <= 0.0 {
                    (*left, *right)
                } else {
                    (*right, *left)
                };
                self.radius_rec(near, q, r2, out);
                if delta * delta <= r2 {
                    self.radius_rec(far, q, r2, out);
                }
            }
        }
    }

    /// Tree statistics (depth, node count) — consumed by the §V latency
    /// discussion bench to show traversal-depth variance.
    pub fn stats(&self) -> TreeStats {
        let mut s = TreeStats::default();
        s.nodes = self.nodes.len();
        if !self.nodes.is_empty() {
            self.stats_rec(0, 1, &mut s);
        }
        s
    }

    fn stats_rec(&self, node: u32, depth: usize, s: &mut TreeStats) {
        match &self.nodes[node as usize] {
            Node::Leaf { start, end } => {
                s.leaves += 1;
                s.max_depth = s.max_depth.max(depth);
                s.total_leaf_depth += depth;
                s.max_leaf_points = s.max_leaf_points.max((end - start) as usize);
            }
            Node::Internal { left, right, .. } => {
                self.stats_rec(*left, depth + 1, s);
                self.stats_rec(*right, depth + 1, s);
            }
        }
    }
}

#[derive(Clone, Copy, Debug, Default)]
pub struct TreeStats {
    pub nodes: usize,
    pub leaves: usize,
    pub max_depth: usize,
    pub total_leaf_depth: usize,
    pub max_leaf_points: usize,
}

impl TreeStats {
    pub fn mean_leaf_depth(&self) -> f64 {
        if self.leaves == 0 {
            0.0
        } else {
            self.total_leaf_depth as f64 / self.leaves as f64
        }
    }
}

/// Exact NN descent shared by the borrowing [`KdTree`] and the owning
/// [`OwnedKdTree`].
fn nearest_rec_impl(
    cloud: &PointCloud,
    nodes: &[Node],
    order: &[u32],
    node: u32,
    q: [f32; 3],
    best: &mut Neighbor,
) {
    match &nodes[node as usize] {
        Node::Leaf { start, end } => {
            for &i in &order[*start as usize..*end as usize] {
                let d = dist_sq(cloud.get(i as usize), q);
                // `<` (not `<=`): ties keep the earliest-found point;
                // combined with left-first descent this is stable.
                if d < best.dist_sq {
                    *best = Neighbor {
                        index: i,
                        dist_sq: d,
                    };
                }
            }
        }
        Node::Internal {
            axis,
            split,
            left,
            right,
        } => {
            let delta = q[*axis as usize] - split;
            let (near, far) = if delta <= 0.0 {
                (*left, *right)
            } else {
                (*right, *left)
            };
            nearest_rec_impl(cloud, nodes, order, near, q, best);
            // Backtrack only if the splitting plane is closer than
            // the current best ("backward tracing", §V).
            if delta * delta < best.dist_sq {
                nearest_rec_impl(cloud, nodes, order, far, q, best);
            }
        }
    }
}

/// A kd-tree that owns its cloud — for callers that must persist the
/// index across calls (the borrow-based [`KdTree`] cannot be stored next
/// to the cloud it borrows). Built once per target upload by the
/// `KdTreeCpuBackend`, queried every ICP iteration — and *kept*: the
/// backend holds a bounded LRU set of these (one per resident target
/// key), so an alternating-map workload builds each map's index once
/// instead of once per switch.
pub struct OwnedKdTree {
    cloud: PointCloud,
    nodes: Vec<Node>,
    order: Vec<u32>,
}

impl OwnedKdTree {
    /// Build with the default leaf size (16, matching [`KdTree::build`] so
    /// the owned and borrowing trees traverse identically — a requirement
    /// for the map-reuse path to stay bit-identical to per-call builds).
    pub fn build(cloud: PointCloud) -> Self {
        Self::build_with_leaf_size(cloud, 16)
    }

    pub fn build_with_leaf_size(cloud: PointCloud, leaf_size: usize) -> Self {
        assert!(leaf_size >= 1);
        let mut order: Vec<u32> = (0..cloud.len() as u32).collect();
        let mut nodes = Vec::new();
        if !cloud.is_empty() {
            let n = order.len();
            build_rec(&cloud, &mut nodes, &mut order, 0, n, leaf_size);
        }
        Self {
            cloud,
            nodes,
            order,
        }
    }

    pub fn cloud(&self) -> &PointCloud {
        &self.cloud
    }

    pub fn len(&self) -> usize {
        self.cloud.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cloud.is_empty()
    }

    /// Exact nearest neighbour with squared distance < `max_dist_sq`;
    /// `None` if nothing is that close (or the tree is empty). Same
    /// strict-bound semantics as [`KdTree::nearest_within`], so the
    /// `KdTreeCpuBackend` rejects correspondences exactly like the
    /// `icp` CPU baseline does.
    pub fn nearest_within_sq(&self, q: [f32; 3], max_dist_sq: f32) -> Option<Neighbor> {
        if self.nodes.is_empty() {
            return None;
        }
        let mut best = Neighbor {
            index: u32::MAX,
            dist_sq: max_dist_sq,
        };
        nearest_rec_impl(&self.cloud, &self.nodes, &self.order, 0, q, &mut best);
        (best.index != u32::MAX).then_some(best)
    }
}

#[inline]
fn dist_sq(a: [f32; 3], b: [f32; 3]) -> f32 {
    let dx = a[0] - b[0];
    let dy = a[1] - b[1];
    let dz = a[2] - b[2];
    dx * dx + dy * dy + dz * dz
}

/// Recursive median-split build over `order[start..end]`; returns node id.
fn build_rec(
    cloud: &PointCloud,
    nodes: &mut Vec<Node>,
    order: &mut [u32],
    start: usize,
    end: usize,
    leaf_size: usize,
) -> u32 {
    let id = nodes.len() as u32;
    if end - start <= leaf_size {
        nodes.push(Node::Leaf {
            start: start as u32,
            end: end as u32,
        });
        return id;
    }
    // Widest-spread axis over this range.
    let slice = &order[start..end];
    let mut lo = [f32::INFINITY; 3];
    let mut hi = [f32::NEG_INFINITY; 3];
    for &i in slice {
        let p = cloud.get(i as usize);
        for k in 0..3 {
            lo[k] = lo[k].min(p[k]);
            hi[k] = hi[k].max(p[k]);
        }
    }
    let mut axis = 0;
    for k in 1..3 {
        if hi[k] - lo[k] > hi[axis] - lo[axis] {
            axis = k;
        }
    }
    if hi[axis] - lo[axis] == 0.0 {
        // All points identical along every axis → cannot split.
        nodes.push(Node::Leaf {
            start: start as u32,
            end: end as u32,
        });
        return id;
    }
    let mid = start + (end - start) / 2;
    order[start..end].select_nth_unstable_by(mid - start, |&a, &b| {
        let pa = cloud.get(a as usize)[axis];
        let pb = cloud.get(b as usize)[axis];
        pa.partial_cmp(&pb).unwrap_or(std::cmp::Ordering::Equal)
    });
    let split = cloud.get(order[mid] as usize)[axis];

    nodes.push(Node::Internal {
        axis: axis as u8,
        split,
        left: 0,
        right: 0,
    }); // patched below
    let left = build_rec(cloud, nodes, order, start, mid, leaf_size);
    let right = build_rec(cloud, nodes, order, mid, end, leaf_size);
    if let Node::Internal {
        left: l, right: r, ..
    } = &mut nodes[id as usize]
    {
        *l = left;
        *r = right;
    }
    id
}

/// Fixed-capacity max-heap keeping the k smallest distances.
struct BoundedMaxHeap {
    k: usize,
    items: Vec<Neighbor>,
}

impl BoundedMaxHeap {
    fn new(k: usize) -> Self {
        Self {
            k,
            items: Vec::with_capacity(k),
        }
    }

    fn full(&self) -> bool {
        self.items.len() == self.k
    }

    fn worst(&self) -> f32 {
        self.items.first().map_or(f32::INFINITY, |n| n.dist_sq)
    }

    fn push(&mut self, n: Neighbor) {
        if self.items.len() < self.k {
            self.items.push(n);
            self.sift_up(self.items.len() - 1);
        } else if n.dist_sq < self.worst() {
            self.items[0] = n;
            self.sift_down(0);
        }
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if self.items[i].dist_sq > self.items[parent].dist_sq {
                self.items.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut largest = i;
            if l < self.items.len() && self.items[l].dist_sq > self.items[largest].dist_sq {
                largest = l;
            }
            if r < self.items.len() && self.items[r].dist_sq > self.items[largest].dist_sq {
                largest = r;
            }
            if largest == i {
                break;
            }
            self.items.swap(i, largest);
            i = largest;
        }
    }

    fn into_sorted(mut self) -> Vec<Neighbor> {
        self.items
            .sort_by(|a, b| a.dist_sq.partial_cmp(&b.dist_sq).unwrap());
        self.items
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::{default_cases, forall};
    use crate::rng::Pcg32;

    fn random_cloud(n: usize, seed: u64) -> PointCloud {
        let mut rng = Pcg32::new(seed);
        let mut c = PointCloud::with_capacity(n);
        for _ in 0..n {
            c.push([
                rng.range(-50.0, 50.0),
                rng.range(-50.0, 50.0),
                rng.range(-5.0, 5.0),
            ]);
        }
        c
    }

    fn brute_nearest(c: &PointCloud, q: [f32; 3]) -> Neighbor {
        let mut best = Neighbor {
            index: u32::MAX,
            dist_sq: f32::INFINITY,
        };
        for (i, p) in c.iter().enumerate() {
            let d = dist_sq(p, q);
            if d < best.dist_sq {
                best = Neighbor {
                    index: i as u32,
                    dist_sq: d,
                };
            }
        }
        best
    }

    #[test]
    fn empty_tree() {
        let c = PointCloud::new();
        let t = KdTree::build(&c);
        assert!(t.nearest([0.0, 0.0, 0.0]).is_none());
        assert!(t.knn([0.0, 0.0, 0.0], 3).is_empty());
        assert!(t.radius([0.0, 0.0, 0.0], 1.0).is_empty());
    }

    #[test]
    fn single_point() {
        let c = PointCloud::from_points(&[[1.0, 2.0, 3.0]]);
        let t = KdTree::build(&c);
        let n = t.nearest([0.0, 0.0, 0.0]).unwrap();
        assert_eq!(n.index, 0);
        assert!((n.dist_sq - 14.0).abs() < 1e-6);
    }

    #[test]
    fn nearest_matches_brute_force() {
        forall(default_cases(40), |g| {
            let n = g.usize_range(1, 800);
            let c = random_cloud(n, g.case + 100);
            let t = KdTree::build_with_leaf_size(&c, g.usize_range(1, 32));
            for _ in 0..20 {
                let q = [
                    g.f32_range(-60.0, 60.0),
                    g.f32_range(-60.0, 60.0),
                    g.f32_range(-6.0, 6.0),
                ];
                let kd = t.nearest(q).unwrap();
                let bf = brute_nearest(&c, q);
                assert_eq!(kd.dist_sq, bf.dist_sq, "case {}", g.case);
            }
        });
    }

    #[test]
    fn nearest_within_respects_max_dist() {
        let c = random_cloud(300, 7);
        let t = KdTree::build(&c);
        forall(50, |g| {
            let q = [
                g.f32_range(-60.0, 60.0),
                g.f32_range(-60.0, 60.0),
                g.f32_range(-6.0, 6.0),
            ];
            let max_d = g.f32_range(0.1, 10.0);
            match t.nearest_within(q, max_d) {
                Some(n) => {
                    assert!(n.dist_sq < max_d * max_d);
                    assert_eq!(n.dist_sq, brute_nearest(&c, q).dist_sq);
                }
                None => {
                    assert!(brute_nearest(&c, q).dist_sq >= max_d * max_d);
                }
            }
        });
    }

    #[test]
    fn knn_matches_sorted_brute_force() {
        forall(default_cases(20), |g| {
            let c = random_cloud(g.usize_range(10, 400), g.case + 999);
            let t = KdTree::build(&c);
            let q = [g.f32_range(-50.0, 50.0), g.f32_range(-50.0, 50.0), 0.0];
            let k = g.usize_range(1, 12).min(c.len());
            let got = t.knn(q, k);
            let mut all: Vec<Neighbor> = c
                .iter()
                .enumerate()
                .map(|(i, p)| Neighbor {
                    index: i as u32,
                    dist_sq: dist_sq(p, q),
                })
                .collect();
            all.sort_by(|a, b| a.dist_sq.partial_cmp(&b.dist_sq).unwrap());
            assert_eq!(got.len(), k);
            for (a, b) in got.iter().zip(all.iter()) {
                assert_eq!(a.dist_sq, b.dist_sq);
            }
        });
    }

    #[test]
    fn knn_k_larger_than_cloud() {
        let c = random_cloud(5, 3);
        let t = KdTree::build(&c);
        let got = t.knn([0.0; 3], 10);
        assert_eq!(got.len(), 5);
    }

    #[test]
    fn radius_matches_brute_force() {
        forall(default_cases(20), |g| {
            let c = random_cloud(g.usize_range(10, 500), g.case + 4242);
            let t = KdTree::build(&c);
            let q = [g.f32_range(-50.0, 50.0), g.f32_range(-50.0, 50.0), 0.0];
            let r = g.f32_range(1.0, 20.0);
            let got = t.radius(q, r);
            let expect: usize = c.iter().filter(|&p| dist_sq(p, q) <= r * r).count();
            assert_eq!(got.len(), expect, "case {}", g.case);
            // Sorted ascending and all within r.
            for w in got.windows(2) {
                assert!(w[0].dist_sq <= w[1].dist_sq);
            }
            for n in &got {
                assert!(n.dist_sq <= r * r);
            }
        });
    }

    #[test]
    fn duplicate_points_handled() {
        // All points identical — degenerate split must not recurse forever.
        let c = PointCloud::from_points(&[[1.0, 1.0, 1.0]; 100]);
        let t = KdTree::build_with_leaf_size(&c, 4);
        let n = t.nearest([1.0, 1.0, 1.0]).unwrap();
        assert_eq!(n.dist_sq, 0.0);
        assert_eq!(t.radius([1.0, 1.0, 1.0], 0.1).len(), 100);
    }

    #[test]
    fn approximate_with_unbounded_budget_is_exact() {
        let c = random_cloud(500, 31);
        let t = KdTree::build(&c);
        forall(40, |g| {
            let q = [
                g.f32_range(-60.0, 60.0),
                g.f32_range(-60.0, 60.0),
                g.f32_range(-6.0, 6.0),
            ];
            let exact = t.nearest(q).unwrap();
            let approx = t.nearest_approximate(q, usize::MAX).unwrap();
            assert_eq!(exact.dist_sq, approx.dist_sq);
        });
    }

    #[test]
    fn approximate_budget_trades_accuracy() {
        // Greedy descent (budget 1) must return *a* neighbour, never a
        // better-than-exact one, and with enough budget converges to
        // the exact answer — the §V accuracy/latency trade-off.
        let c = random_cloud(2000, 33);
        let t = KdTree::build_with_leaf_size(&c, 8);
        let mut greedy_misses = 0;
        let mut big_budget_misses = 0;
        let trials = 200;
        let mut rng = Pcg32::new(7);
        for _ in 0..trials {
            let q = [
                rng.range(-60.0, 60.0),
                rng.range(-60.0, 60.0),
                rng.range(-6.0, 6.0),
            ];
            let exact = t.nearest(q).unwrap();
            let g1 = t.nearest_approximate(q, 1).unwrap();
            let g32 = t.nearest_approximate(q, 32).unwrap();
            assert!(g1.dist_sq >= exact.dist_sq);
            assert!(g32.dist_sq >= exact.dist_sq);
            assert!(g32.dist_sq <= g1.dist_sq + 1e-12);
            if g1.dist_sq > exact.dist_sq {
                greedy_misses += 1;
            }
            if g32.dist_sq > exact.dist_sq {
                big_budget_misses += 1;
            }
        }
        // Greedy descent misses on uniform data; a 32-leaf budget is
        // near-exact.
        assert!(greedy_misses > 0, "greedy descent should miss sometimes");
        assert!(
            big_budget_misses < greedy_misses,
            "more budget must reduce misses ({big_budget_misses} vs {greedy_misses})"
        );
    }

    #[test]
    fn approximate_zero_budget_returns_none() {
        let c = random_cloud(10, 35);
        let t = KdTree::build(&c);
        assert!(t.nearest_approximate([0.0; 3], 0).is_none());
    }

    #[test]
    fn owned_tree_matches_borrowed_tree() {
        let c = random_cloud(600, 41);
        let borrowed = KdTree::build(&c);
        let owned = OwnedKdTree::build(c.clone());
        assert!(!owned.is_empty());
        assert_eq!(owned.cloud().len(), 600);
        forall(40, |g| {
            let q = [
                g.f32_range(-60.0, 60.0),
                g.f32_range(-60.0, 60.0),
                g.f32_range(-6.0, 6.0),
            ];
            let max_d = g.f32_range(0.5, 15.0);
            let a = borrowed.nearest_within(q, max_d);
            let b = owned.nearest_within_sq(q, max_d * max_d);
            match (a, b) {
                (Some(x), Some(y)) => {
                    assert_eq!(x.index, y.index);
                    assert_eq!(x.dist_sq, y.dist_sq);
                }
                (None, None) => {}
                other => panic!("owned/borrowed disagree: {other:?}"),
            }
        });
        // Empty tree behaves.
        let empty = OwnedKdTree::build(PointCloud::new());
        assert!(empty.is_empty());
        assert!(empty.nearest_within_sq([0.0; 3], 1.0).is_none());
    }

    #[test]
    fn stats_sane() {
        let c = random_cloud(1000, 21);
        let t = KdTree::build_with_leaf_size(&c, 8);
        let s = t.stats();
        assert!(s.leaves > 0);
        assert!(s.max_leaf_points <= 8);
        // Median-split balanced tree: depth ≈ log2(n/leaf) + O(1).
        assert!(s.max_depth <= 14, "depth {}", s.max_depth);
    }
}
