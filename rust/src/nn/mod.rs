//! Brute-force nearest-neighbour search — the CPU mirror of the FPGA
//! NN searcher (paper Fig. 3).
//!
//! Two flavours:
//!
//! * [`nearest_brute`] / [`nearest_brute_parallel`] — straightforward
//!   exact NN used as baselines and test oracles.
//! * [`kernel_mirror`] — a *bit-faithful* re-implementation of the device
//!   kernel's dataflow (blockwise distance tiles, running argmin with
//!   strict `<` update, masked targets at +1e30) so the FPPS API can run
//!   without artifacts (NativeSim backend) and so tests can pin down the
//!   exact semantics the Pallas kernel must match.

use crate::pointcloud::PointCloud;

/// Distance used everywhere: squared euclidean in f32 — exactly what the
/// PE array's Distance block computes.
#[inline(always)]
pub fn dist_sq(a: [f32; 3], b: [f32; 3]) -> f32 {
    let dx = a[0] - b[0];
    let dy = a[1] - b[1];
    let dz = a[2] - b[2];
    dx * dx + dy * dy + dz * dz
}

/// Exact NN of `q` in `cloud` by linear scan. Ties resolve to the lowest
/// index (first strict improvement), matching the kernel and the kd-tree.
pub fn nearest_brute(cloud: &PointCloud, q: [f32; 3]) -> Option<(u32, f32)> {
    let mut best_i = u32::MAX;
    let mut best_d = f32::INFINITY;
    for (i, p) in cloud.iter().enumerate() {
        let d = dist_sq(p, q);
        if d < best_d {
            best_d = d;
            best_i = i as u32;
        }
    }
    (best_i != u32::MAX).then_some((best_i, best_d))
}

/// Brute-force NN for every point of `queries` against `targets`,
/// sharded across `threads` std threads. This is the honest CPU
/// comparison point for the §V "parallel NN on CPU" discussion.
pub fn nearest_brute_parallel(
    targets: &PointCloud,
    queries: &PointCloud,
    threads: usize,
) -> Vec<(u32, f32)> {
    let threads = threads.max(1).min(queries.len().max(1));
    if queries.is_empty() || targets.is_empty() {
        return Vec::new();
    }
    let n = queries.len();
    let chunk = n.div_ceil(threads);
    let mut out = vec![(u32::MAX, f32::INFINITY); n];
    std::thread::scope(|scope| {
        for (tid, slot) in out.chunks_mut(chunk).enumerate() {
            let start = tid * chunk;
            scope.spawn(move || {
                for (k, s) in slot.iter_mut().enumerate() {
                    let q = queries.get(start + k);
                    *s = nearest_brute(targets, q).unwrap();
                }
            });
        }
    });
    out
}

/// Configuration of the kernel-mirror dataflow. Must match the Pallas
/// BlockSpec constants in `python/compile/kernels/nn_search.py` for the
/// mirror to be bit-faithful.
#[derive(Clone, Copy, Debug)]
pub struct KernelConfig {
    /// Source block (the local register buffer of Fig. 3).
    pub block_n: usize,
    /// Target block (the BRAM partition batch broadcast per cycle).
    pub block_m: usize,
}

impl Default for KernelConfig {
    fn default() -> Self {
        // Mirrors DEFAULT_BN/DEFAULT_BM in nn_search.py. Chosen in the
        // §Perf L1 sweep: fewest grid steps that keep the VMEM tile
        // ≈4 MiB (EXPERIMENTS.md §Perf).
        Self {
            block_n: 512,
            block_m: 2048,
        }
    }
}

/// Distance the kernel assigns to masked (padding) targets.
pub const MASKED_DIST: f32 = 1e30;

/// Output of one NN pass over a (padded) source block set.
#[derive(Clone, Debug, Default)]
pub struct NnResult {
    pub dist_sq: Vec<f32>,
    pub index: Vec<u32>,
}

/// Reusable intermediate buffers for [`kernel_mirror_into`]: the
/// hoisted source norms and target norm+mask penalties. Grown on first
/// use per capacity, then recycled — a warm scratch makes every
/// subsequent mirror pass allocation-free.
#[derive(Debug, Default)]
pub struct MirrorScratch {
    pn: Vec<f32>,
    qn_pen: Vec<f32>,
}

/// Bit-faithful mirror of the device NN kernel: for each source point
/// (padded to a multiple of `block_n`) find the masked argmin over
/// targets (padded to a multiple of `block_m`).
///
/// The iteration order reproduces the Pallas grid: for each source block
/// i, target blocks j ascending, within a tile the tie-break is the
/// lowest target index, and cross-tile updates use strict `<` — so the
/// result is the *global first argmin*, identical to `nearest_brute` on
/// unpadded data.
pub fn kernel_mirror(
    src: &[f32],
    tgt: &[f32],
    tgt_mask: &[f32],
    cfg: KernelConfig,
) -> NnResult {
    let mut scratch = MirrorScratch::default();
    let mut out = NnResult::default();
    kernel_mirror_into(src, tgt, tgt_mask, cfg, &mut scratch, &mut out);
    out
}

/// [`kernel_mirror`] writing into caller-owned buffers: `out` and
/// `scratch` are cleared and refilled, reusing their allocations. The
/// zero-copy hot path ([`crate::fpps_api::NativeSimBackend`]) keeps one
/// scratch/result pair per backend so every ICP iteration after the
/// first runs heap-free. Results are bit-identical to [`kernel_mirror`].
pub fn kernel_mirror_into(
    src: &[f32],
    tgt: &[f32],
    tgt_mask: &[f32],
    cfg: KernelConfig,
    scratch: &mut MirrorScratch,
    out: &mut NnResult,
) {
    assert!(src.len() % 3 == 0 && tgt.len() % 3 == 0);
    let n = src.len() / 3;
    let m = tgt.len() / 3;
    assert_eq!(tgt_mask.len(), m);
    assert!(
        n % cfg.block_n == 0,
        "source not padded to block_n={}",
        cfg.block_n
    );
    assert!(
        m % cfg.block_m == 0,
        "target not padded to block_m={}",
        cfg.block_m
    );
    // Precompute norms and mask penalties once — value-identical to the
    // per-pair computation (no accumulation-order change), just hoisted.
    scratch.pn.clear();
    scratch.pn.extend((0..n).map(|i| {
        let p = &src[3 * i..3 * i + 3];
        p[0] * p[0] + p[1] * p[1] + p[2] * p[2]
    }));
    scratch.qn_pen.clear();
    scratch.qn_pen.extend((0..m).map(|j| {
        let q = &tgt[3 * j..3 * j + 3];
        q[0] * q[0] + q[1] * q[1] + q[2] * q[2] + (1.0 - tgt_mask[j]) * MASKED_DIST
    }));
    let (pn, qn_pen) = (&scratch.pn, &scratch.qn_pen);
    out.dist_sq.clear();
    out.dist_sq.resize(n, f32::INFINITY);
    out.index.clear();
    out.index.resize(n, 0u32);
    let (dist, index) = (&mut out.dist_sq, &mut out.index);
    for ib in 0..n / cfg.block_n {
        for jb in 0..m / cfg.block_m {
            for ii in 0..cfg.block_n {
                let i = ib * cfg.block_n + ii;
                let (px, py, pz) = (src[3 * i], src[3 * i + 1], src[3 * i + 2]);
                let pni = pn[i];
                // Tile-local argmin (the CMP TR reduction). Distance in
                // the matmul-identity form so float rounding matches the
                // Pallas kernel; the masked +1e30 penalty is folded into
                // qn_pen (value-identical).
                let mut local_d = f32::INFINITY;
                let mut local_j = 0u32;
                let j0 = jb * cfg.block_m;
                for jj in 0..cfg.block_m {
                    let j = j0 + jj;
                    let pq = px * tgt[3 * j] + py * tgt[3 * j + 1] + pz * tgt[3 * j + 2];
                    let d = pni - 2.0 * pq + qn_pen[j];
                    if d < local_d {
                        local_d = d;
                        local_j = j as u32;
                    }
                }
                // Cross-tile MIN-register update (strict <).
                if jb == 0 || local_d < dist[i] {
                    dist[i] = local_d;
                    index[i] = local_j;
                }
            }
        }
    }
}

/// Pad a flat xyz buffer to `multiple` points; returns (padded, mask).
/// Padding entries sit at the origin and are masked out.
pub fn pad_cloud(xyz: &[f32], multiple: usize) -> (Vec<f32>, Vec<f32>) {
    assert!(xyz.len() % 3 == 0);
    let n = xyz.len() / 3;
    let padded_n = n.div_ceil(multiple).max(1) * multiple;
    let mut out = Vec::with_capacity(padded_n * 3);
    out.extend_from_slice(xyz);
    out.resize(padded_n * 3, 0.0);
    let mut mask = vec![1.0f32; n];
    mask.resize(padded_n, 0.0);
    (out, mask)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::{default_cases, forall};
    use crate::rng::Pcg32;

    fn random_cloud(n: usize, seed: u64) -> PointCloud {
        let mut rng = Pcg32::new(seed);
        let mut c = PointCloud::with_capacity(n);
        for _ in 0..n {
            c.push([
                rng.range(-20.0, 20.0),
                rng.range(-20.0, 20.0),
                rng.range(-3.0, 3.0),
            ]);
        }
        c
    }

    #[test]
    fn brute_empty() {
        assert!(nearest_brute(&PointCloud::new(), [0.0; 3]).is_none());
    }

    #[test]
    fn brute_parallel_matches_serial() {
        let tgt = random_cloud(777, 1);
        let q = random_cloud(123, 2);
        let par = nearest_brute_parallel(&tgt, &q, 4);
        for (i, &(idx, d)) in par.iter().enumerate() {
            let (bi, bd) = nearest_brute(&tgt, q.get(i)).unwrap();
            assert_eq!(idx, bi);
            assert_eq!(d, bd);
        }
    }

    #[test]
    fn kernel_mirror_matches_brute_on_padded_data() {
        forall(default_cases(30), |g| {
            let n = g.usize_range(1, 300);
            let m = g.usize_range(1, 900);
            let src = random_cloud(n, g.case * 2 + 1);
            let tgt = random_cloud(m, g.case * 2 + 2);
            let cfg = KernelConfig {
                block_n: 64,
                block_m: 128,
            };
            let (ps, _) = pad_cloud(&src.xyz, cfg.block_n);
            let (pt, mask) = pad_cloud(&tgt.xyz, cfg.block_m);
            let res = kernel_mirror(&ps, &pt, &mask, cfg);
            for i in 0..n {
                let q = src.get(i);
                let (bi, _bd) = nearest_brute(&tgt, q).unwrap();
                // Indices must agree exactly (both are first-argmin) as
                // long as the winning distance is unique; distances may
                // differ in the last ulp due to the matmul-identity form,
                // so compare against a recomputed identity-form distance.
                let p = q;
                let t = tgt.get(res.index[i] as usize);
                let pn = p[0] * p[0] + p[1] * p[1] + p[2] * p[2];
                let tn = t[0] * t[0] + t[1] * t[1] + t[2] * t[2];
                let pt_ = p[0] * t[0] + p[1] * t[1] + p[2] * t[2];
                let ident_d = pn - 2.0 * pt_ + tn;
                assert!(
                    (res.dist_sq[i] - ident_d).abs() <= 1e-3,
                    "dist mismatch case {} i={i}",
                    g.case
                );
                // The chosen neighbour must be as close as the brute one
                // up to identity-form rounding.
                let bd_pt = tgt.get(bi as usize);
                let true_best = dist_sq(q, bd_pt);
                let got = dist_sq(q, t);
                assert!(
                    got <= true_best + 1e-3,
                    "suboptimal NN case {} i={i}: got {got} best {true_best}",
                    g.case
                );
            }
        });
    }

    #[test]
    fn kernel_mirror_ignores_masked_targets() {
        // Nearest target is masked out → kernel must pick the second.
        let src = vec![0.0f32, 0.0, 0.0];
        let mut tgt = vec![0.1f32, 0.0, 0.0]; // nearest but masked
        tgt.extend_from_slice(&[1.0, 0.0, 0.0]); // real NN
        let cfg = KernelConfig {
            block_n: 4,
            block_m: 4,
        };
        let (ps, _) = pad_cloud(&src, cfg.block_n);
        let (pt, mut mask) = pad_cloud(&tgt, cfg.block_m);
        mask[0] = 0.0;
        let res = kernel_mirror(&ps, &pt, &mask, cfg);
        assert_eq!(res.index[0], 1);
        assert!((res.dist_sq[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn kernel_mirror_all_masked_gives_big_distance() {
        let src = vec![0.0f32; 3];
        let tgt = vec![0.0f32; 3];
        let cfg = KernelConfig {
            block_n: 1,
            block_m: 1,
        };
        let (ps, _) = pad_cloud(&src, cfg.block_n);
        let (pt, mut mask) = pad_cloud(&tgt, cfg.block_m);
        mask[0] = 0.0;
        let res = kernel_mirror(&ps, &pt, &mask, cfg);
        assert!(res.dist_sq[0] >= MASKED_DIST * 0.5);
    }

    #[test]
    fn kernel_mirror_into_is_bit_identical_and_reuses_buffers() {
        let src = random_cloud(200, 41);
        let tgt = random_cloud(500, 42);
        let cfg = KernelConfig {
            block_n: 64,
            block_m: 128,
        };
        let (ps, _) = pad_cloud(&src.xyz, cfg.block_n);
        let (pt, mask) = pad_cloud(&tgt.xyz, cfg.block_m);
        let fresh = kernel_mirror(&ps, &pt, &mask, cfg);
        let mut scratch = MirrorScratch::default();
        let mut out = NnResult::default();
        for _ in 0..2 {
            kernel_mirror_into(&ps, &pt, &mask, cfg, &mut scratch, &mut out);
            assert_eq!(out.index, fresh.index);
            let same_bits = out
                .dist_sq
                .iter()
                .zip(fresh.dist_sq.iter())
                .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same_bits, "into-variant must be bit-identical");
        }
        // Second pass reused the warm buffers.
        let (pd, pi) = (out.dist_sq.as_ptr(), out.index.as_ptr());
        kernel_mirror_into(&ps, &pt, &mask, cfg, &mut scratch, &mut out);
        assert_eq!(out.dist_sq.as_ptr(), pd);
        assert_eq!(out.index.as_ptr(), pi);
    }

    #[test]
    fn pad_cloud_shapes() {
        let (p, m) = pad_cloud(&[1.0, 2.0, 3.0], 8);
        assert_eq!(p.len(), 24);
        assert_eq!(m.len(), 8);
        assert_eq!(m[0], 1.0);
        assert_eq!(m[1], 0.0);
        // Already aligned stays put.
        let xyz: Vec<f32> = (0..24).map(|i| i as f32).collect();
        let (p2, m2) = pad_cloud(&xyz, 8);
        assert_eq!(p2.len(), 24);
        assert_eq!(m2.iter().filter(|&&v| v == 1.0).count(), 8);
        // Empty cloud pads to one full block.
        let (p3, m3) = pad_cloud(&[], 4);
        assert_eq!(p3.len(), 12);
        assert!(m3.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn tie_break_lowest_index() {
        // Two identical targets: kernel and brute must both pick index 0.
        let src = vec![0.0f32, 0.0, 0.0];
        let tgt = vec![1.0f32, 0.0, 0.0, 1.0, 0.0, 0.0];
        let cfg = KernelConfig {
            block_n: 1,
            block_m: 2,
        };
        let (ps, _) = pad_cloud(&src, cfg.block_n);
        let (pt, mask) = pad_cloud(&tgt, cfg.block_m);
        let res = kernel_mirror(&ps, &pt, &mask, cfg);
        assert_eq!(res.index[0], 0);
        let c = PointCloud::from_xyz(tgt);
        assert_eq!(nearest_brute(&c, [0.0, 0.0, 0.0]).unwrap().0, 0);
    }
}
