//! Bounded lock-free SPSC job ring for the lane data plane.
//!
//! Each lane's queue is a fixed array of sequence-numbered slots
//! (Vyukov-style bounded ring): the producer writes a slot and
//! publishes it by bumping the slot's sequence; a consumer claims the
//! head slot by CAS on the dequeue cursor. No mutex is ever held around
//! the job hand-off, and — because jobs carry their cloud payloads by
//! `Arc` — pushing a job never copies or allocates.
//!
//! The protocol is **single-producer** (only the dispatcher routes jobs
//! into a lane) but deliberately **multi-consumer**: the lane worker
//! pops, while the deadline watchdog (and the lane itself on a fatal
//! backend error) may [`SpscRing::drain`] the ring concurrently to
//! re-route queued jobs off a wedged lane. The consumer-side CAS is
//! what keeps that race exactly-once.
//!
//! Closing is a flag, not a lock, so `close()` + `drain()` is *not*
//! atomic against a concurrent push: a job the producer was mid-push
//! during the close can land after the closer's drain. The supervision
//! protocol closes that window at the source — the dispatcher is the
//! sole producer, so when it learns a lane is dead it performs the
//! authoritative final drain itself, after which no further push can
//! race (see `coordinator::dispatch_supervised`).
//!
//! Blocking [`SpscRing::pop`] parks on a condvar only when the ring is
//! empty; the producer takes that (uncontended) lock only when a
//! sleeper is registered, and sleepers re-arm with a bounded
//! `wait_timeout` so a lost wakeup can cost milliseconds, never a
//! deadlock.
//!
//! All synchronization goes through [`crate::sync`], so the ring runs
//! unchanged under the `--cfg loom` model checker; the exactly-once and
//! no-lost-job properties are model-checked in `tests/loom_models.rs`.

use crate::sync::atomic::{fence, AtomicBool, AtomicUsize, Ordering};
use crate::sync::cell::UnsafeCell;
use crate::sync::{Condvar, Mutex};
use std::mem::MaybeUninit;
use std::time::Duration;

/// How long a sleeping consumer waits before re-checking the ring on
/// its own (backstop against a theoretically lost wakeup).
const PARK_BACKSTOP: Duration = Duration::from_millis(10);

struct Slot<T> {
    /// Vyukov sequence: `pos` ⇒ free for the push at `pos`;
    /// `pos + 1` ⇒ holds the value pushed at `pos`;
    /// `pos + ring_size` ⇒ consumed, free for the next lap.
    seq: AtomicUsize,
    val: UnsafeCell<MaybeUninit<T>>,
}

/// Bounded lock-free job ring (see the module docs for the protocol).
pub struct SpscRing<T> {
    slots: Box<[Slot<T>]>,
    mask: usize,
    /// Logical capacity (may be below the power-of-two slot count).
    cap: usize,
    /// Enqueue cursor — written only by the single producer.
    head: AtomicUsize,
    /// Dequeue cursor — claimed by CAS (worker and watchdog may race).
    tail: AtomicUsize,
    closed: AtomicBool,
    /// Consumers parked (or about to park) on the condvar. The producer
    /// only touches the park mutex when this is non-zero, so the push
    /// hot path stays lock-free while a busy lane keeps up.
    sleeper_count: AtomicUsize,
    /// Pairs with `wake`; held around the park re-check so a notify
    /// cannot slip between a consumer's empty check and its wait.
    park: Mutex<()>,
    wake: Condvar,
}

// SAFETY: values move producer -> exactly one consumer; the sequence
// protocol (Acquire/Release on `seq`) orders every slot access — the
// producer writes a slot only after observing the consumers' "slot
// free" sequence, and a consumer reads it only after observing the
// producer's "slot published" sequence — and the tail CAS makes the
// claimant unique, so a slot is never read and written concurrently.
// `T: Send` is required because values cross threads by move.
unsafe impl<T: Send> Send for SpscRing<T> {}
// SAFETY: `&SpscRing` only exposes the atomic cursors and the
// CAS-claimed slot protocol justified above; no `&self` method hands
// out a reference into a slot, so sharing the ring across threads adds
// no access the `Send` justification does not already cover.
unsafe impl<T: Send> Sync for SpscRing<T> {}

impl<T> SpscRing<T> {
    /// A ring holding at most `cap` items (`cap` is clamped to ≥ 1).
    pub fn new(cap: usize) -> Self {
        let cap = cap.max(1);
        let size = cap.next_power_of_two();
        let slots = (0..size)
            .map(|i| Slot {
                seq: AtomicUsize::new(i),
                val: UnsafeCell::new(MaybeUninit::uninit()),
            })
            .collect();
        Self {
            slots,
            mask: size - 1,
            cap,
            head: AtomicUsize::new(0),
            tail: AtomicUsize::new(0),
            closed: AtomicBool::new(false),
            sleeper_count: AtomicUsize::new(0),
            park: Mutex::new(()),
            wake: Condvar::new(),
        }
    }

    /// Non-blocking push; hands the value back when full or closed.
    /// Must only be called from the single producer thread.
    pub fn try_push(&self, v: T) -> Result<(), T> {
        if self.closed.load(Ordering::SeqCst) {
            return Err(v);
        }
        // ordering: Relaxed — `head` is written only by this (single
        // producer) thread, so its own last store is always visible.
        let pos = self.head.load(Ordering::Relaxed);
        // Logical-capacity bound (tail only advances, so this check is
        // conservative: at worst we report full a beat late).
        // ordering: Acquire pairs with the consumers' AcqRel CAS so the
        // fullness check never runs ahead of a claimed slot.
        if pos.wrapping_sub(self.tail.load(Ordering::Acquire)) >= self.cap {
            return Err(v);
        }
        let slot = &self.slots[pos & self.mask];
        // A consumer that claimed this slot a lap ago may still be
        // reading it; its sequence bump is the all-clear.
        // ordering: Acquire pairs with the consumer's Release sequence
        // bump, ordering its read-out before our overwrite.
        if slot.seq.load(Ordering::Acquire) != pos {
            return Err(v);
        }
        // SAFETY: the sequence check above proved the slot is free for
        // the push at `pos` (every prior consumer finished reading it),
        // and we are the single producer, so no other thread writes this
        // slot until the Release store below hands it to a consumer.
        slot.val.with_mut(|p| unsafe { (*p).write(v) });
        // ordering: Release publishes the slot write above to the
        // consumer's Acquire sequence load.
        slot.seq.store(pos.wrapping_add(1), Ordering::Release);
        // ordering: Release so `is_empty`/`len` observers see the slot
        // publish no later than the cursor move.
        self.head.store(pos.wrapping_add(1), Ordering::Release);
        // Dekker-style handshake with `pop`: publish-then-check against
        // its register-then-recheck, so either we see the sleeper or it
        // sees our item.
        fence(Ordering::SeqCst);
        if self.sleeper_count.load(Ordering::SeqCst) > 0 {
            let _g = self.park.lock().unwrap();
            self.wake.notify_all();
        }
        Ok(())
    }

    /// Non-blocking pop. Safe to call from multiple threads.
    pub fn try_pop(&self) -> Option<T> {
        // ordering: Relaxed — the CAS below (re)validates the cursor; a
        // stale read only costs a retry.
        let mut pos = self.tail.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[pos & self.mask];
            // ordering: Acquire pairs with the producer's Release publish
            // so the slot value is visible before we claim it.
            let seq = slot.seq.load(Ordering::Acquire);
            let expect = pos.wrapping_add(1);
            if seq == expect {
                // Slot is readable: claim it or chase the winner.
                // ordering: AcqRel — Acquire so the winner's slot read
                // starts after the producer's publish; Release so our
                // claim is visible to the producer's fullness check.
                // Failure is Relaxed: we just retry with the fresh value.
                match self.tail.compare_exchange_weak(
                    pos,
                    expect,
                    Ordering::AcqRel,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        // SAFETY: the CAS made this thread the unique
                        // claimant of `pos`, and the Acquire sequence
                        // load above synchronized with the producer's
                        // Release publish, so the slot holds an
                        // initialized value no other thread will touch
                        // until the sequence bump below.
                        let v = slot.val.with_mut(|p| unsafe { (*p).assume_init_read() });
                        // Free the slot for the producer's next lap.
                        // ordering: Release orders our read-out before
                        // the producer's next-lap overwrite.
                        slot.seq
                            .store(pos.wrapping_add(self.mask + 1), Ordering::Release);
                        return Some(v);
                    }
                    Err(now) => pos = now,
                }
            } else if seq.wrapping_sub(expect) as isize > 0 {
                // Another consumer already took this slot; re-read tail.
                // ordering: Relaxed — revalidated by the seq/CAS protocol
                // on the next iteration.
                pos = self.tail.load(Ordering::Relaxed);
            } else {
                // seq == pos: empty at this cursor.
                return None;
            }
        }
    }

    /// Blocking pop; `None` once the ring is closed *and* empty.
    pub fn pop(&self) -> Option<T> {
        loop {
            if let Some(v) = self.try_pop() {
                return Some(v);
            }
            if self.closed.load(Ordering::SeqCst) {
                // Sweep anything a racing push published before it could
                // observe the close.
                return self.try_pop();
            }
            let guard = self.park.lock().unwrap();
            self.sleeper_count.fetch_add(1, Ordering::SeqCst);
            // Re-check after registering (the producer's fence + sleeper
            // check pairs with this) so its notify cannot slip between
            // our empty check and the wait.
            fence(Ordering::SeqCst);
            if !self.is_empty() || self.closed.load(Ordering::SeqCst) {
                self.sleeper_count.fetch_sub(1, Ordering::SeqCst);
                continue;
            }
            let (guard, _) = self.wake.wait_timeout(guard, PARK_BACKSTOP).unwrap();
            self.sleeper_count.fetch_sub(1, Ordering::SeqCst);
            drop(guard);
        }
    }

    /// Take every queued job in FIFO order (watchdog re-route of a
    /// wedged lane). The ring stays usable afterwards.
    pub fn drain(&self) -> Vec<T> {
        let mut out = Vec::new();
        while let Some(v) = self.try_pop() {
            out.push(v);
        }
        out
    }

    /// Close the ring: pushes start failing, blocked consumers wake,
    /// and `pop` returns `None` once the backlog is consumed.
    pub fn close(&self) {
        self.closed.store(true, Ordering::SeqCst);
        let _g = self.park.lock().unwrap();
        self.wake.notify_all();
    }

    /// Whether [`SpscRing::close`] has been called.
    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::SeqCst)
    }

    /// Snapshot emptiness (racy, advisory only).
    pub fn is_empty(&self) -> bool {
        // ordering: Acquire on both cursors keeps the snapshot no older
        // than the caller's last synchronization point; the result is
        // advisory either way.
        let tail = self.tail.load(Ordering::Acquire);
        self.head.load(Ordering::Acquire) == tail
    }

    /// Snapshot occupancy (racy, advisory only).
    pub fn len(&self) -> usize {
        // ordering: Acquire, as in `is_empty` — advisory snapshot.
        let tail = self.tail.load(Ordering::Acquire);
        self.head.load(Ordering::Acquire).wrapping_sub(tail)
    }
}

impl<T> Drop for SpscRing<T> {
    fn drop(&mut self) {
        while self.try_pop().is_some() {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    // Miri executes these loops ~100x slower than native; shrink the
    // iteration counts there while keeping the native sizes honest.
    #[cfg(miri)]
    const FIFO_ITEMS: u64 = 300;
    #[cfg(not(miri))]
    const FIFO_ITEMS: u64 = 1000;
    #[cfg(miri)]
    const RACE_ITEMS: u64 = 300;
    #[cfg(not(miri))]
    const RACE_ITEMS: u64 = 10_000;

    #[test]
    fn fifo_and_wraparound() {
        // Capacity 4: push/pop far more items than slots so every slot
        // is reused many laps with sequence numbers wrapping the ring.
        let r = SpscRing::new(4);
        let mut next_out = 0u64;
        for i in 0..FIFO_ITEMS {
            r.try_push(i).unwrap();
            if i % 3 == 0 {
                while let Some(v) = r.try_pop() {
                    assert_eq!(v, next_out);
                    next_out += 1;
                }
            }
        }
        while let Some(v) = r.try_pop() {
            assert_eq!(v, next_out);
            next_out += 1;
        }
        assert_eq!(next_out, FIFO_ITEMS);
    }

    #[test]
    fn full_and_empty_bounds() {
        let r = SpscRing::new(3); // non-power-of-two logical cap
        assert!(r.try_pop().is_none(), "empty ring pops nothing");
        assert!(r.is_empty());
        for i in 0..3 {
            r.try_push(i).unwrap();
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.try_push(99).unwrap_err(), 99, "full ring hands back");
        assert_eq!(r.try_pop(), Some(0));
        r.try_push(3).unwrap(); // slot freed -> push succeeds again
        assert_eq!(r.drain(), vec![1, 2, 3]);
        assert!(r.is_empty());
    }

    #[test]
    fn drain_then_restart() {
        // A watchdog drain mid-stream must leave the ring fully usable:
        // same capacity, FIFO order preserved for new pushes.
        let r = SpscRing::new(4);
        for i in 0..3 {
            r.try_push(i).unwrap();
        }
        assert_eq!(r.drain(), vec![0, 1, 2]);
        for i in 10..14 {
            r.try_push(i).unwrap();
        }
        assert!(r.try_push(99).is_err(), "capacity intact after drain");
        assert_eq!(r.drain(), vec![10, 11, 12, 13]);
    }

    #[test]
    fn close_semantics() {
        let r = SpscRing::new(4);
        r.try_push(1).unwrap();
        r.close();
        assert!(r.is_closed());
        assert_eq!(r.try_push(2).unwrap_err(), 2, "closed ring rejects pushes");
        assert_eq!(r.pop(), Some(1), "backlog still drains after close");
        assert_eq!(r.pop(), None, "closed + empty -> None");
    }

    #[test]
    fn blocking_pop_wakes_on_push_and_close() {
        let r = Arc::new(SpscRing::new(8));
        let c = Arc::clone(&r);
        let consumer = std::thread::spawn(move || {
            let mut got = Vec::new();
            while let Some(v) = c.pop() {
                got.push(v);
            }
            got
        });
        for i in 0..100u64 {
            while r.try_push(i).is_err() {
                std::thread::yield_now();
            }
        }
        r.close();
        let got = consumer.join().unwrap();
        assert_eq!(got, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn concurrent_drain_races_are_exactly_once() {
        // One producer, one popping worker, one draining "watchdog":
        // every item is seen exactly once across both consumers.
        let r = Arc::new(SpscRing::new(8));
        let total = RACE_ITEMS;
        let worker = {
            let r = Arc::clone(&r);
            std::thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(v) = r.pop() {
                    got.push(v);
                }
                got
            })
        };
        let watchdog = {
            let r = Arc::clone(&r);
            std::thread::spawn(move || {
                let mut got = Vec::new();
                while !r.is_closed() || !r.is_empty() {
                    got.extend(r.drain());
                    std::thread::yield_now();
                }
                got.extend(r.drain());
                got
            })
        };
        for i in 0..total {
            while r.try_push(i).is_err() {
                std::thread::yield_now();
            }
        }
        r.close();
        let mut all = worker.join().unwrap();
        all.extend(watchdog.join().unwrap());
        assert_eq!(all.len() as u64, total, "no loss, no duplication");
        all.sort_unstable();
        for (i, v) in all.iter().enumerate() {
            assert_eq!(*v, i as u64);
        }
    }

    #[test]
    fn drop_releases_queued_items() {
        // Arc strong counts prove queued items are dropped with the ring.
        let payload = Arc::new(0u32);
        let r = SpscRing::new(4);
        r.try_push(Arc::clone(&payload)).unwrap();
        r.try_push(Arc::clone(&payload)).unwrap();
        assert_eq!(Arc::strong_count(&payload), 3);
        drop(r);
        assert_eq!(Arc::strong_count(&payload), 1);
    }
}
