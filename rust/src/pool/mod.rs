//! Zero-copy data plane: recycled staging buffers and lock-free lane
//! rings.
//!
//! The paper's pipeline keeps point data resident on the device and
//! streams it through **fixed, pre-allocated buffers** — the host never
//! allocates per frame. This module is the software reproduction of
//! that discipline:
//!
//! * [`BufferPool`] — an arena of recycled `Vec<f32>` staging buffers,
//!   shelved by power-of-two capacity class. [`BufferPool::acquire`]
//!   hands out a [`PooledBuf`] guard; dropping the guard returns the
//!   buffer (cleared, allocation intact) to its shelf instead of the
//!   heap. Once every capacity class in a workload is warm, staging a
//!   cloud costs zero allocations: [`crate::pointcloud::pad_into`]
//!   refills the recycled buffer in place.
//! * [`ring::SpscRing`] — the bounded lock-free job ring each lane
//!   worker consumes from (see its module docs for the supervision
//!   drain protocol).
//!
//! The pool lock is only ever touched on **cold** paths — staging a
//! target the engine has never seen, or evicting one past the residency
//! slot count. The per-job hot path (source re-pad, resident-target
//! hit, kernel iterations) runs entirely on buffers it already owns.
//!
//! [`PoolStats`] counts what the pool did: `acquires` (buffers handed
//! out), `recycles` (served from a shelf — the steady-state case),
//! `grows` (fresh heap allocations, because the shelf was empty), and
//! `discards` (returned buffers dropped because the shelf was full,
//! bounded by the retention knob — `--pool-capacity` / config
//! `pool_capacity=`).

pub mod ring;

use crate::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use crate::sync::{Arc, Mutex};
use std::collections::HashMap;
use std::ops::{Deref, DerefMut};

/// Smallest capacity class (in `f32` elements). Tiny acquires all share
/// one shelf instead of fragmenting across classes.
const MIN_CLASS: usize = 64;

/// Default number of buffers retained per capacity class.
pub const DEFAULT_RETAIN: usize = 8;

/// Cumulative pool activity counters (monotonic).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Buffers handed out by [`BufferPool::acquire`].
    pub acquires: u64,
    /// Acquires served from a shelf (no heap traffic).
    pub recycles: u64,
    /// Acquires that had to allocate because the class shelf was empty.
    pub grows: u64,
    /// Returned buffers dropped because the class shelf was full.
    pub discards: u64,
}

struct PoolInner {
    /// Shelves of cleared, capacity-intact buffers keyed by class size.
    shelves: Mutex<HashMap<usize, Vec<Vec<f32>>>>,
    /// Max buffers retained per class; extra returns are freed.
    retain: AtomicUsize,
    acquires: AtomicU64,
    recycles: AtomicU64,
    grows: AtomicU64,
    discards: AtomicU64,
}

/// Cloneable handle to a shared arena of recycled `Vec<f32>` buffers,
/// shelved by power-of-two capacity class (see the module docs).
#[derive(Clone)]
pub struct BufferPool {
    inner: Arc<PoolInner>,
}

impl Default for BufferPool {
    fn default() -> Self {
        Self::new(DEFAULT_RETAIN)
    }
}

impl BufferPool {
    /// A pool retaining at most `retain` buffers per capacity class
    /// (`0` disables recycling entirely — every return is freed).
    pub fn new(retain: usize) -> Self {
        Self {
            inner: Arc::new(PoolInner {
                shelves: Mutex::new(HashMap::new()),
                retain: AtomicUsize::new(retain),
                acquires: AtomicU64::new(0),
                recycles: AtomicU64::new(0),
                grows: AtomicU64::new(0),
                discards: AtomicU64::new(0),
            }),
        }
    }

    /// Round `capacity` up to its class size.
    fn class_of(capacity: usize) -> usize {
        capacity.max(MIN_CLASS).next_power_of_two()
    }

    /// Hand out an empty buffer with at least `capacity` elements of
    /// spare room. Served from the class shelf when one is available
    /// (zero heap traffic), freshly allocated otherwise.
    pub fn acquire(&self, capacity: usize) -> PooledBuf {
        let class = Self::class_of(capacity);
        // ordering: Relaxed — monotonic statistics counters; readers only
        // need eventual totals, never cross-thread publication.
        self.inner.acquires.fetch_add(1, Ordering::Relaxed);
        let recycled = self
            .inner
            .shelves
            .lock()
            .unwrap()
            .get_mut(&class)
            .and_then(Vec::pop);
        let buf = match recycled {
            Some(b) => {
                // ordering: Relaxed — statistics counter, as above.
                self.inner.recycles.fetch_add(1, Ordering::Relaxed);
                b
            }
            None => {
                // ordering: Relaxed — statistics counter, as above.
                self.inner.grows.fetch_add(1, Ordering::Relaxed);
                Vec::with_capacity(class)
            }
        };
        PooledBuf {
            buf,
            class,
            pool: Arc::clone(&self.inner),
        }
    }

    /// Change how many buffers each class shelf retains. Shrinking does
    /// not free already-shelved buffers eagerly; they are trimmed as
    /// they cycle.
    pub fn set_retain(&self, retain: usize) {
        // ordering: Relaxed — an advisory knob; a return that reads the
        // old value a beat late only shelves/frees one extra buffer.
        self.inner.retain.store(retain, Ordering::Relaxed);
    }

    /// Snapshot of the cumulative activity counters.
    pub fn stats(&self) -> PoolStats {
        // ordering: Relaxed — monotonic counters read for reporting; no
        // data is published through them.
        PoolStats {
            acquires: self.inner.acquires.load(Ordering::Relaxed),
            recycles: self.inner.recycles.load(Ordering::Relaxed),
            grows: self.inner.grows.load(Ordering::Relaxed),
            discards: self.inner.discards.load(Ordering::Relaxed),
        }
    }
}

/// A buffer checked out of a [`BufferPool`]. Dereferences to its
/// `Vec<f32>`; dropping it clears the contents and returns the
/// allocation to the pool shelf (or frees it when the shelf is full).
pub struct PooledBuf {
    buf: Vec<f32>,
    class: usize,
    pool: Arc<PoolInner>,
}

impl Deref for PooledBuf {
    type Target = Vec<f32>;
    fn deref(&self) -> &Vec<f32> {
        &self.buf
    }
}

impl DerefMut for PooledBuf {
    fn deref_mut(&mut self) -> &mut Vec<f32> {
        &mut self.buf
    }
}

impl std::fmt::Debug for PooledBuf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PooledBuf")
            .field("len", &self.buf.len())
            .field("capacity", &self.buf.capacity())
            .field("class", &self.class)
            .finish()
    }
}

impl Drop for PooledBuf {
    fn drop(&mut self) {
        let mut buf = std::mem::take(&mut self.buf);
        buf.clear();
        // ordering: Relaxed — advisory retention knob (see `set_retain`).
        let retain = self.pool.retain.load(Ordering::Relaxed);
        if retain > 0 {
            let mut shelves = self.pool.shelves.lock().unwrap();
            let shelf = shelves.entry(self.class).or_default();
            if shelf.len() < retain {
                shelf.push(buf);
                return;
            }
        }
        // ordering: Relaxed — statistics counter (see `acquire`).
        self.pool.discards.fetch_add(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acquire_recycles_the_same_allocation() {
        let pool = BufferPool::new(4);
        let ptr = {
            let mut b = pool.acquire(100);
            b.extend_from_slice(&[1.0, 2.0, 3.0]);
            b.as_ptr()
        }; // drop returns to shelf
        let b = pool.acquire(100);
        assert_eq!(b.as_ptr(), ptr, "same class must recycle the buffer");
        assert!(b.is_empty(), "recycled buffers come back cleared");
        assert!(b.capacity() >= 100);
        let s = pool.stats();
        assert_eq!(s.acquires, 2);
        assert_eq!(s.grows, 1);
        assert_eq!(s.recycles, 1);
        assert_eq!(s.discards, 0);
    }

    #[test]
    fn capacity_classes_are_power_of_two_and_shared() {
        // 100 and 120 share the 128 class; 200 lands on 256.
        let pool = BufferPool::new(4);
        let p100 = {
            let b = pool.acquire(100);
            b.as_ptr()
        };
        assert_eq!(pool.acquire(120).as_ptr(), p100);
        drop(pool.acquire(200));
        assert_eq!(pool.stats().grows, 2, "two classes, two fresh allocations");
        // Tiny acquires share the floor class.
        let p1 = {
            let b = pool.acquire(1);
            b.as_ptr()
        };
        assert_eq!(pool.acquire(MIN_CLASS).as_ptr(), p1);
    }

    #[test]
    fn retention_bounds_the_shelf() {
        let pool = BufferPool::new(1);
        let a = pool.acquire(64);
        let b = pool.acquire(64);
        drop(a); // shelved
        drop(b); // shelf full -> freed
        let s = pool.stats();
        assert_eq!(s.discards, 1);
        // retain = 0 disables recycling.
        let none = BufferPool::new(0);
        drop(none.acquire(64));
        let s = none.stats();
        assert_eq!(s.discards, 1);
        drop(none.acquire(64));
        assert_eq!(none.stats().grows, 2);
    }

    #[test]
    fn steady_state_is_grow_free() {
        let pool = BufferPool::new(8);
        for _ in 0..100 {
            let mut b = pool.acquire(1000);
            b.resize(1000, 0.5);
        }
        let s = pool.stats();
        assert_eq!(s.acquires, 100);
        assert_eq!(s.grows, 1, "only the first acquire allocates");
        assert_eq!(s.recycles, 99);
    }

    #[test]
    fn pool_handle_is_shared_across_clones() {
        let pool = BufferPool::new(4);
        let clone = pool.clone();
        drop(pool.acquire(64));
        drop(clone.acquire(64));
        assert_eq!(pool.stats(), clone.stats());
        assert_eq!(pool.stats().recycles, 1);
    }
}
