//! Run configuration: a small key=value format (one pair per line,
//! `#` comments) parsed into typed run configs. Also the format of the
//! artifact manifest written by `python/compile/aot.py`, keeping the
//! build-time python → runtime rust interchange free of serde/JSON.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Parsed key=value document (ordered for deterministic rendering).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct KvConfig {
    map: BTreeMap<String, String>,
}

impl KvConfig {
    pub fn parse(text: &str) -> Result<Self> {
        let mut map = BTreeMap::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("line {}: expected key=value, got {line:?}", ln + 1);
            };
            let key = k.trim().to_string();
            if key.is_empty() {
                bail!("line {}: empty key", ln + 1);
            }
            map.insert(key, v.trim().to_string());
        }
        Ok(Self { map })
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parse {}", path.display()))
    }

    pub fn set(&mut self, key: &str, value: impl ToString) {
        self.map.insert(key.to_string(), value.to_string());
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(String::as_str)
    }

    pub fn require(&self, key: &str) -> Result<&str> {
        self.get(key)
            .with_context(|| format!("missing required key {key:?}"))
    }

    pub fn get_parsed<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(None),
            Some(v) => match v.parse::<T>() {
                Ok(x) => Ok(Some(x)),
                Err(e) => bail!("key {key:?}: cannot parse {v:?}: {e}"),
            },
        }
    }

    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.get_parsed(key)?.unwrap_or(default))
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(String::as_str)
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        for (k, v) in &self.map {
            s.push_str(k);
            s.push('=');
            s.push_str(v);
            s.push('\n');
        }
        s
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.render())
            .with_context(|| format!("write {}", path.display()))
    }
}

/// Top-level run configuration for the odometry pipeline and benches.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// ICP parameters (paper §IV.A fixed configuration).
    pub max_iterations: u32,
    pub max_correspondence_distance: f32,
    pub transformation_epsilon: f64,
    /// Source sample size per frame (paper: 4096).
    pub source_sample: usize,
    /// Target cloud cap fed to the device (capacity of the NN buffers).
    pub target_capacity: usize,
    /// Frames per synthetic sequence.
    pub frames: usize,
    /// Dataset seed.
    pub seed: u64,
    /// Artifact directory for the XLA backend.
    pub artifacts_dir: String,
    /// Worker lanes for batched registration (one backend instance per
    /// lane; see `coordinator::run_lane_pool`).
    pub lanes: usize,
    /// Scans per localization run (`fpps localize`; see
    /// `coordinator::run_localization`).
    pub scans: usize,
    /// Submap tiles for the tile-crossing localization scenario
    /// (`fpps localize --tiles`; see
    /// `coordinator::run_tiled_localization`). 1 = single shared map.
    pub tiles: usize,
    /// Resident-target slots per backend; 0 = derive from the `hwmodel`
    /// HBM residency budget (the default).
    pub residency_slots: usize,
    /// Recycled staging buffers retained per capacity class in each
    /// lane engine's [`crate::pool::BufferPool`] (the zero-copy data
    /// plane). Also reachable as `--pool-capacity` on the lane
    /// subcommands.
    pub pool_capacity: usize,
    /// How maps whose padded footprint exceeds one residency slot are
    /// admitted: `reject` (structured error) or `downsample` (explicit
    /// downsample-to-fit, the default). See
    /// [`crate::coordinator::admit_map`].
    pub admission: crate::coordinator::AdmissionPolicy,
    /// Per-job deadline in milliseconds, measured from submission;
    /// 0 disables deadline enforcement (the default — no behavior
    /// change unless a run opts into an SLO). See
    /// [`crate::coordinator::SupervisorConfig`].
    pub deadline_ms: u64,
    /// Transient-failure retry budget per job (errors and lane panics;
    /// 0 = first failure is final, the historical behavior).
    pub retries: u32,
    /// Backend failover chain walked as a lane accumulates restarts
    /// (e.g. `xla,native-sim,kdtree`); `None` = respawn the configured
    /// backend kind forever. See [`crate::fpps_api::FailoverChain`].
    pub failover: Option<crate::fpps_api::FailoverChain>,
    /// Per-target NN index selection: `exact` (kd-tree, the historical
    /// behavior), `approx[:CELL,RING]` (voxel grid), or `auto` (grid
    /// for city-scale maps only). Reachable as `--nn-strategy` /
    /// `nn_strategy=`. See [`crate::voxelgrid::NnStrategy`].
    pub nn_strategy: crate::voxelgrid::NnStrategy,
    /// Per-client-stream in-flight bound of the serving tier (`fpps
    /// serve --stream-depth` / `stream_depth=`); a stream at its depth
    /// parks or sheds instead of queueing deeper. See
    /// [`crate::coordinator::ServingConfig`].
    pub stream_depth: usize,
    /// Simulated client count for `fpps serve` (`--clients` /
    /// `clients=`).
    pub clients: usize,
    /// Default SLO class jobs are submitted under (`--slo` / `slo=`):
    /// `latency-critical | standard | best-effort`. See
    /// [`crate::coordinator::SloClass`].
    pub slo: crate::coordinator::SloClass,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            max_iterations: 50,
            max_correspondence_distance: 1.0,
            transformation_epsilon: 1e-5,
            source_sample: 4096,
            target_capacity: 16384,
            frames: 20,
            seed: 2026,
            artifacts_dir: "artifacts".to_string(),
            lanes: 1,
            scans: 16,
            tiles: 1,
            residency_slots: 0,
            pool_capacity: crate::pool::DEFAULT_RETAIN,
            admission: crate::coordinator::AdmissionPolicy::DownsampleToFit,
            deadline_ms: 0,
            retries: 0,
            failover: None,
            nn_strategy: crate::voxelgrid::NnStrategy::Exact,
            stream_depth: 4,
            clients: 64,
            slo: crate::coordinator::SloClass::Standard,
        }
    }
}

impl RunConfig {
    pub fn from_kv(kv: &KvConfig) -> Result<Self> {
        let d = Self::default();
        Ok(Self {
            max_iterations: kv.get_or("max_iterations", d.max_iterations)?,
            max_correspondence_distance: kv
                .get_or("max_correspondence_distance", d.max_correspondence_distance)?,
            transformation_epsilon: kv
                .get_or("transformation_epsilon", d.transformation_epsilon)?,
            source_sample: kv.get_or("source_sample", d.source_sample)?,
            target_capacity: kv.get_or("target_capacity", d.target_capacity)?,
            frames: kv.get_or("frames", d.frames)?,
            seed: kv.get_or("seed", d.seed)?,
            artifacts_dir: kv
                .get("artifacts_dir")
                .unwrap_or(&d.artifacts_dir)
                .to_string(),
            lanes: kv.get_or("lanes", d.lanes)?,
            scans: kv.get_or("scans", d.scans)?,
            tiles: kv.get_or("tiles", d.tiles)?,
            residency_slots: kv.get_or("residency_slots", d.residency_slots)?,
            pool_capacity: kv.get_or("pool_capacity", d.pool_capacity)?,
            admission: kv.get_or("admission", d.admission)?,
            deadline_ms: kv.get_or("deadline_ms", d.deadline_ms)?,
            retries: kv.get_or("retries", d.retries)?,
            failover: kv.get_parsed("failover")?,
            nn_strategy: kv.get_or("nn_strategy", d.nn_strategy)?,
            stream_depth: kv.get_or("stream_depth", d.stream_depth)?,
            clients: kv.get_or("clients", d.clients)?,
            slo: kv.get_or("slo", d.slo)?,
        })
    }

    /// The lane-pool supervision policy this config describes
    /// (`deadline_ms`/`retries` over the inert defaults).
    pub fn supervisor(&self) -> crate::coordinator::SupervisorConfig {
        crate::coordinator::SupervisorConfig {
            deadline: (self.deadline_ms > 0)
                .then(|| std::time::Duration::from_millis(self.deadline_ms)),
            max_retries: self.retries,
            ..Default::default()
        }
    }

    pub fn icp_params(&self) -> crate::icp::IcpParams {
        crate::icp::IcpParams {
            max_iterations: self.max_iterations,
            max_correspondence_distance: self.max_correspondence_distance,
            transformation_epsilon: self.transformation_epsilon,
            search: crate::icp::SearchStrategy::KdTree,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic() {
        let kv = KvConfig::parse("a=1\n# comment\n\n b = hello world \n").unwrap();
        assert_eq!(kv.get("a"), Some("1"));
        assert_eq!(kv.get("b"), Some("hello world"));
        assert_eq!(kv.get("missing"), None);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(KvConfig::parse("novalue\n").is_err());
        assert!(KvConfig::parse("=x\n").is_err());
    }

    #[test]
    fn typed_accessors() {
        let kv = KvConfig::parse("n=42\nf=2.5\nbad=xyz\n").unwrap();
        assert_eq!(kv.get_parsed::<u32>("n").unwrap(), Some(42));
        assert_eq!(kv.get_or::<f32>("f", 0.0).unwrap(), 2.5);
        assert_eq!(kv.get_or::<u32>("missing", 7).unwrap(), 7);
        assert!(kv.get_parsed::<u32>("bad").is_err());
        assert!(kv.require("missing").is_err());
    }

    #[test]
    fn render_roundtrip() {
        let mut kv = KvConfig::default();
        kv.set("z_last", 3);
        kv.set("a_first", "v");
        let text = kv.render();
        // BTreeMap → deterministic, sorted output.
        assert_eq!(text, "a_first=v\nz_last=3\n");
        assert_eq!(KvConfig::parse(&text).unwrap(), kv);
    }

    #[test]
    fn run_config_defaults_and_overrides() {
        use crate::coordinator::AdmissionPolicy;
        let kv = KvConfig::parse(
            "max_iterations=10\nsource_sample=1024\nlanes=4\nscans=8\ntiles=3\n\
             residency_slots=2\npool_capacity=4\nadmission=reject\n",
        )
        .unwrap();
        let rc = RunConfig::from_kv(&kv).unwrap();
        assert_eq!(rc.max_iterations, 10);
        assert_eq!(rc.source_sample, 1024);
        assert_eq!(rc.lanes, 4);
        assert_eq!(rc.scans, 8);
        assert_eq!(rc.tiles, 3);
        assert_eq!(rc.residency_slots, 2);
        assert_eq!(rc.pool_capacity, 4);
        assert_eq!(rc.admission, AdmissionPolicy::Reject);
        // Both spellings parse; garbage errors loudly.
        let kv = KvConfig::parse("admission=downsample-to-fit\n").unwrap();
        assert_eq!(
            RunConfig::from_kv(&kv).unwrap().admission,
            AdmissionPolicy::DownsampleToFit
        );
        let kv = KvConfig::parse("admission=shrinkwrap\n").unwrap();
        assert!(RunConfig::from_kv(&kv).is_err());
        assert_eq!(RunConfig::from_kv(&KvConfig::default()).unwrap().scans, 16);
        // Untouched fields keep paper defaults.
        assert_eq!(rc.max_correspondence_distance, 1.0);
        assert_eq!(rc.transformation_epsilon, 1e-5);
        let defaults = RunConfig::from_kv(&KvConfig::default()).unwrap();
        assert_eq!(defaults.lanes, 1);
        assert_eq!(defaults.tiles, 1, "single shared map by default");
        assert_eq!(defaults.residency_slots, 0, "0 = hwmodel-derived");
        assert_eq!(
            defaults.pool_capacity,
            crate::pool::DEFAULT_RETAIN,
            "staging pool keeps the library default retention"
        );
        assert_eq!(
            defaults.admission,
            AdmissionPolicy::DownsampleToFit,
            "pre-admission behavior stays the default, now explicit"
        );
        let p = rc.icp_params();
        assert_eq!(p.max_iterations, 10);
    }

    #[test]
    fn supervision_keys_parse_and_default_inert() {
        use crate::fpps_api::{BackendKind, FailoverChain};
        // Defaults: supervision off — no deadline, no retries, no chain.
        let d = RunConfig::from_kv(&KvConfig::default()).unwrap();
        assert_eq!(d.deadline_ms, 0);
        assert_eq!(d.retries, 0);
        assert!(d.failover.is_none());
        assert!(d.supervisor().deadline.is_none());
        assert_eq!(d.supervisor().max_retries, 0);

        let kv = KvConfig::parse(
            "deadline_ms=250\nretries=2\nfailover=xla, native-sim ,kdtree\n",
        )
        .unwrap();
        let rc = RunConfig::from_kv(&kv).unwrap();
        assert_eq!(rc.deadline_ms, 250);
        assert_eq!(rc.retries, 2);
        let chain = rc.failover.expect("chain parsed");
        assert_eq!(chain.tiers(), 3);
        assert_eq!(chain.kind_for_tier(0), BackendKind::Xla);
        assert_eq!(chain.kind_for_tier(1), BackendKind::NativeSim);
        // Tiers past the end clamp to the most conservative entry.
        assert_eq!(chain.kind_for_tier(99), BackendKind::KdTreeCpu);
        let sup = rc.supervisor();
        assert_eq!(sup.deadline, Some(std::time::Duration::from_millis(250)));
        assert_eq!(sup.max_retries, 2);
        // Chains render/parse round-trip through the config format.
        let mut kv = KvConfig::default();
        kv.set("failover", &chain);
        let reparsed: FailoverChain = KvConfig::parse(&kv.render())
            .unwrap()
            .get_parsed("failover")
            .unwrap()
            .unwrap();
        assert_eq!(reparsed, chain);
        // Garbage chains error loudly instead of silently degrading.
        let kv = KvConfig::parse("failover=fpga\n").unwrap();
        assert!(RunConfig::from_kv(&kv).is_err());
    }

    #[test]
    fn serving_keys_parse_and_default() {
        use crate::coordinator::SloClass;
        // Defaults: shallow per-stream depth, standard class.
        let d = RunConfig::from_kv(&KvConfig::default()).unwrap();
        assert_eq!(d.stream_depth, 4);
        assert_eq!(d.clients, 64);
        assert_eq!(d.slo, SloClass::Standard);

        let kv = KvConfig::parse("stream_depth=2\nclients=5000\nslo=latency-critical\n").unwrap();
        let rc = RunConfig::from_kv(&kv).unwrap();
        assert_eq!(rc.stream_depth, 2);
        assert_eq!(rc.clients, 5000);
        assert_eq!(rc.slo, SloClass::LatencyCritical);
        // Display round-trips through the config format.
        let mut kv = KvConfig::default();
        kv.set("slo", rc.slo);
        let reparsed = RunConfig::from_kv(&KvConfig::parse(&kv.render()).unwrap()).unwrap();
        assert_eq!(reparsed.slo, SloClass::LatencyCritical);
        // Garbage errors loudly.
        let kv = KvConfig::parse("slo=realtime\n").unwrap();
        assert!(RunConfig::from_kv(&kv).is_err());
    }

    #[test]
    fn nn_strategy_key_parses_and_defaults_exact() {
        use crate::voxelgrid::NnStrategy;
        // Default: the historical exact kd-tree path, bit for bit.
        let d = RunConfig::from_kv(&KvConfig::default()).unwrap();
        assert_eq!(d.nn_strategy, NnStrategy::Exact);

        let kv = KvConfig::parse("nn_strategy=approx:0.5,3\n").unwrap();
        let rc = RunConfig::from_kv(&kv).unwrap();
        assert_eq!(
            rc.nn_strategy,
            NnStrategy::Approx {
                cell_size: 0.5,
                max_ring: 3
            }
        );
        let kv = KvConfig::parse("nn_strategy=auto\n").unwrap();
        assert_eq!(
            RunConfig::from_kv(&kv).unwrap().nn_strategy,
            NnStrategy::Auto
        );
        // Display round-trips through the config format.
        let mut kv = KvConfig::default();
        kv.set("nn_strategy", rc.nn_strategy);
        let reparsed = RunConfig::from_kv(&KvConfig::parse(&kv.render()).unwrap()).unwrap();
        assert_eq!(reparsed.nn_strategy, rc.nn_strategy);
        // Garbage errors loudly.
        let kv = KvConfig::parse("nn_strategy=grid\n").unwrap();
        assert!(RunConfig::from_kv(&kv).is_err());
    }
}
