//! Tiny argument parser for the `fpps` CLI and examples (clap is not
//! available offline). Supports `--key value`, `--key=value`, boolean
//! `--flag`, and positional arguments, with generated usage text — plus
//! the shared `--backend`/`--artifacts`/`--lanes` (and, for
//! localization, `--tiles`/`--slots` residency) option blocks every
//! device-facing subcommand and example uses.

use crate::fpps_api::BackendKind;
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::path::PathBuf;

/// Declarative option spec.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

/// Parsed arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(String::as_str)
    }

    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(None),
            Some(v) => match v.parse() {
                Ok(x) => Ok(Some(x)),
                Err(e) => bail!("--{name}: cannot parse {v:?}: {e}"),
            },
        }
    }

    pub fn get_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.get_parsed(name)?.unwrap_or(default))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

/// Command parser: specs + usage rendering.
pub struct Parser {
    program: &'static str,
    about: &'static str,
    specs: Vec<OptSpec>,
}

impl Parser {
    pub fn new(program: &'static str, about: &'static str) -> Self {
        Self {
            program,
            about,
            specs: Vec::new(),
        }
    }

    pub fn opt(mut self, name: &'static str, help: &'static str, default: Option<&'static str>) -> Self {
        self.specs.push(OptSpec {
            name,
            help,
            takes_value: true,
            default,
        });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(OptSpec {
            name,
            help,
            takes_value: false,
            default: None,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.program, self.about);
        for spec in &self.specs {
            let arg = if spec.takes_value {
                format!("--{} <value>", spec.name)
            } else {
                format!("--{}", spec.name)
            };
            s.push_str(&format!("  {arg:<34} {}", spec.help));
            if let Some(d) = spec.default {
                s.push_str(&format!(" [default: {d}]"));
            }
            s.push('\n');
        }
        s.push_str("  --help                             show this help\n");
        s
    }

    /// Parse a raw token list (without argv[0]).
    pub fn parse(&self, tokens: &[String]) -> Result<Args> {
        let mut args = Args::default();
        // Seed defaults.
        for spec in &self.specs {
            if let Some(d) = spec.default {
                args.opts.insert(spec.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < tokens.len() {
            let tok = &tokens[i];
            if tok == "--help" || tok == "-h" {
                bail!("{}", self.usage());
            }
            if let Some(rest) = tok.strip_prefix("--") {
                let (name, inline_val) = match rest.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (rest, None),
                };
                let Some(spec) = self.specs.iter().find(|s| s.name == name) else {
                    bail!("unknown option --{name}\n\n{}", self.usage());
                };
                if spec.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            if i >= tokens.len() {
                                bail!("--{name} requires a value");
                            }
                            tokens[i].clone()
                        }
                    };
                    args.opts.insert(name.to_string(), val);
                } else {
                    if inline_val.is_some() {
                        bail!("--{name} does not take a value");
                    }
                    args.flags.push(name.to_string());
                }
            } else {
                args.positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    /// Parse `std::env::args().skip(2)` style iterators.
    pub fn parse_env(&self, skip: usize) -> Result<Args> {
        let tokens: Vec<String> = std::env::args().skip(skip).collect();
        self.parse(&tokens)
    }

    /// Attach the shared device-selection options: `--backend`,
    /// `--artifacts`, and the legacy `--native-sim` shorthand.
    pub fn backend_opts(self) -> Self {
        self.opt(
            "backend",
            "device backend: auto | xla | native-sim | kdtree",
            Some("auto"),
        )
        .opt("artifacts", "artifact directory", Some("artifacts"))
        .flag("native-sim", "shorthand for --backend native-sim")
    }

    /// Attach the lane-count options for multi-lane subcommands.
    /// `--pool-capacity` has no parser default so a config file's
    /// `pool_capacity=` can supply it.
    pub fn lane_opts(self, default_lanes: &'static str) -> Self {
        self.opt(
            "lanes",
            "worker lanes (one backend instance each)",
            Some(default_lanes),
        )
        .opt("queue-depth", "bounded job-queue depth", Some("4"))
        .opt(
            "pool-capacity",
            "staging buffers retained per capacity class per lane",
            None,
        )
    }

    /// Attach the target-residency options shared by the localization
    /// subcommand/example: `--tiles` (submap ping-pong scenario),
    /// `--slots` (resident-target slots per backend, 0 = hwmodel
    /// default), `--admission` (policy for maps whose footprint exceeds
    /// one residency slot), and `--nn-strategy` (exact kd-tree vs
    /// voxel-grid NN per resident target). None have parser defaults so
    /// a config file can supply them.
    pub fn residency_opts(self) -> Self {
        self.opt(
            "tiles",
            "submap tiles; >1 interleaves tile-crossing jobs",
            None,
        )
        .opt(
            "slots",
            "resident-target slots per backend (0 = hwmodel budget)",
            None,
        )
        .opt(
            "admission",
            "oversized-map policy: reject | downsample (default)",
            None,
        )
        .opt(
            "nn-strategy",
            "NN index: exact | approx[:CELL,RING] | auto",
            None,
        )
    }

    /// Attach the serving-tier options of `fpps serve` and the
    /// load-generator example: `--slo` (default submission class),
    /// `--clients` (simulated client streams), and `--stream-depth`
    /// (per-stream in-flight bound — a full stream parks or sheds, it
    /// never queues deeper). No parser defaults so a config file's
    /// `slo=`/`clients=`/`stream_depth=` can supply them.
    pub fn serving_opts(self) -> Self {
        self.opt(
            "slo",
            "SLO class: latency-critical | standard | best-effort",
            None,
        )
        .opt("clients", "simulated client streams", None)
        .opt(
            "stream-depth",
            "per-client in-flight bound before park/shed",
            None,
        )
    }

    /// Attach the lane-supervision options shared by the multi-lane
    /// subcommands/examples: `--deadline-ms` (per-job deadline from
    /// submission, 0 = off), `--retries` (transient-failure retry
    /// budget), and `--failover` (comma-separated backend chain walked
    /// on repeated lane restarts, e.g. `xla,native-sim,kdtree`). No
    /// parser defaults so a config file can supply them.
    pub fn supervision_opts(self) -> Self {
        self.opt(
            "deadline-ms",
            "per-job deadline in ms from submission (0 = no deadline)",
            None,
        )
        .opt(
            "retries",
            "retry budget per job for transient failures",
            None,
        )
        .opt(
            "failover",
            "backend failover chain, e.g. xla,native-sim,kdtree",
            None,
        )
    }
}

/// Resolve the backend selection added by [`Parser::backend_opts`].
pub fn backend_selection(a: &Args) -> Result<(BackendKind, PathBuf)> {
    let kind = if a.flag("native-sim") {
        BackendKind::NativeSim
    } else {
        a.get("backend").unwrap_or("auto").parse()?
    };
    let dir = PathBuf::from(a.get("artifacts").unwrap_or("artifacts"));
    Ok((kind, dir))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parser() -> Parser {
        Parser::new("demo", "test parser")
            .opt("frames", "frame count", Some("20"))
            .opt("seed", "rng seed", None)
            .flag("verbose", "chatty output")
    }

    fn toks(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = parser().parse(&toks(&[])).unwrap();
        assert_eq!(a.get_or::<u32>("frames", 0).unwrap(), 20);
        assert!(a.get("seed").is_none());
        let a = parser().parse(&toks(&["--frames", "7", "--seed=99"])).unwrap();
        assert_eq!(a.get_or::<u32>("frames", 0).unwrap(), 7);
        assert_eq!(a.get_or::<u64>("seed", 0).unwrap(), 99);
    }

    #[test]
    fn flags_and_positional() {
        let a = parser()
            .parse(&toks(&["pos1", "--verbose", "pos2"]))
            .unwrap();
        assert!(a.flag("verbose"));
        assert!(!a.flag("other"));
        assert_eq!(a.positional, vec!["pos1", "pos2"]);
    }

    #[test]
    fn residency_opts_parse() {
        use crate::coordinator::AdmissionPolicy;
        let p = Parser::new("demo", "test").residency_opts();
        let a = p.parse(&toks(&[])).unwrap();
        assert_eq!(a.get_or::<usize>("tiles", 1).unwrap(), 1);
        assert_eq!(a.get_or::<usize>("slots", 0).unwrap(), 0);
        // No parser default: the config-file value wins when the flag is
        // absent.
        assert_eq!(
            a.get_or("admission", AdmissionPolicy::Reject).unwrap(),
            AdmissionPolicy::Reject
        );
        let a = p
            .parse(&toks(&["--tiles", "3", "--slots=2", "--admission", "reject"]))
            .unwrap();
        assert_eq!(a.get_or::<usize>("tiles", 1).unwrap(), 3);
        assert_eq!(a.get_or::<usize>("slots", 0).unwrap(), 2);
        assert_eq!(
            a.get_or("admission", AdmissionPolicy::DownsampleToFit)
                .unwrap(),
            AdmissionPolicy::Reject
        );
        let a = p.parse(&toks(&["--admission", "shrinkwrap"])).unwrap();
        assert!(a.get_parsed::<AdmissionPolicy>("admission").is_err());
    }

    #[test]
    fn nn_strategy_opt_parses() {
        use crate::voxelgrid::NnStrategy;
        let p = Parser::new("demo", "test").residency_opts();
        // No parser default: the config-file value wins when absent.
        let a = p.parse(&toks(&[])).unwrap();
        assert!(a.get("nn-strategy").is_none());
        assert_eq!(
            a.get_or("nn-strategy", NnStrategy::Auto).unwrap(),
            NnStrategy::Auto
        );
        let a = p.parse(&toks(&["--nn-strategy", "approx:0.5,2"])).unwrap();
        assert_eq!(
            a.get_or("nn-strategy", NnStrategy::Exact).unwrap(),
            NnStrategy::Approx {
                cell_size: 0.5,
                max_ring: 2
            }
        );
        let a = p.parse(&toks(&["--nn-strategy=grid"])).unwrap();
        assert!(a.get_parsed::<NnStrategy>("nn-strategy").is_err());
    }

    #[test]
    fn supervision_opts_parse() {
        use crate::fpps_api::FailoverChain;
        let p = Parser::new("demo", "test").supervision_opts();
        // No parser defaults: config-file values win when flags are absent.
        let a = p.parse(&toks(&[])).unwrap();
        assert_eq!(a.get_or::<u64>("deadline-ms", 0).unwrap(), 0);
        assert!(a.get("retries").is_none());
        assert!(a.get("failover").is_none());
        let a = p
            .parse(&toks(&[
                "--deadline-ms",
                "250",
                "--retries=2",
                "--failover",
                "native-sim,kdtree",
            ]))
            .unwrap();
        assert_eq!(a.get_or::<u64>("deadline-ms", 0).unwrap(), 250);
        assert_eq!(a.get_or::<u32>("retries", 0).unwrap(), 2);
        let chain: FailoverChain = a.get_parsed("failover").unwrap().unwrap();
        assert_eq!(chain.tiers(), 2);
        // A garbage chain errors instead of silently falling back.
        let a = p.parse(&toks(&["--failover", "fpga,asic"])).unwrap();
        assert!(a.get_parsed::<FailoverChain>("failover").is_err());
    }

    #[test]
    fn serving_opts_parse() {
        use crate::coordinator::SloClass;
        let p = Parser::new("demo", "test").serving_opts();
        // No parser defaults: config-file values win when flags are absent.
        let a = p.parse(&toks(&[])).unwrap();
        assert!(a.get("slo").is_none());
        assert!(a.get("clients").is_none());
        assert_eq!(a.get_or("slo", SloClass::Standard).unwrap(), SloClass::Standard);
        let a = p
            .parse(&toks(&[
                "--slo",
                "latency-critical",
                "--clients=5000",
                "--stream-depth",
                "2",
            ]))
            .unwrap();
        assert_eq!(
            a.get_or("slo", SloClass::Standard).unwrap(),
            SloClass::LatencyCritical
        );
        assert_eq!(a.get_or::<usize>("clients", 0).unwrap(), 5000);
        assert_eq!(a.get_or::<usize>("stream-depth", 0).unwrap(), 2);
        // Garbage class errors instead of silently defaulting.
        let a = p.parse(&toks(&["--slo", "realtime"])).unwrap();
        assert!(a.get_parsed::<SloClass>("slo").is_err());
    }

    #[test]
    fn backend_and_lane_opts() {
        let p = Parser::new("demo", "test").backend_opts().lane_opts("1");
        let a = p.parse(&toks(&[])).unwrap();
        let (kind, dir) = backend_selection(&a).unwrap();
        assert_eq!(kind, BackendKind::Auto);
        assert_eq!(dir, PathBuf::from("artifacts"));
        assert_eq!(a.get_or::<usize>("lanes", 0).unwrap(), 1);

        let a = p
            .parse(&toks(&["--backend", "kdtree", "--lanes", "4", "--artifacts", "x"]))
            .unwrap();
        let (kind, dir) = backend_selection(&a).unwrap();
        assert_eq!(kind, BackendKind::KdTreeCpu);
        assert_eq!(dir, PathBuf::from("x"));
        assert_eq!(a.get_or::<usize>("lanes", 0).unwrap(), 4);

        // Legacy flag wins over the default.
        let a = p.parse(&toks(&["--native-sim"])).unwrap();
        assert_eq!(backend_selection(&a).unwrap().0, BackendKind::NativeSim);
        // Bad backend name errors.
        let a = p.parse(&toks(&["--backend", "fpga"])).unwrap();
        assert!(backend_selection(&a).is_err());
    }

    #[test]
    fn errors() {
        assert!(parser().parse(&toks(&["--nope"])).is_err());
        assert!(parser().parse(&toks(&["--seed"])).is_err());
        assert!(parser().parse(&toks(&["--verbose=1"])).is_err());
        assert!(parser().parse(&toks(&["--frames", "abc"])).unwrap().get_parsed::<u32>("frames").is_err());
        let help = parser().parse(&toks(&["--help"])).unwrap_err().to_string();
        assert!(help.contains("--frames"));
        assert!(help.contains("[default: 20]"));
    }
}
