//! The FPPS public API — Table I of the paper, PCL-style.
//!
//! ```no_run
//! use fpps::fpps_api::FppsIcp;
//! use fpps::pointcloud::PointCloud;
//!
//! let mut icp = FppsIcp::hardware_initialize("artifacts".as_ref()).unwrap();
//! icp.set_input_source(PointCloud::new());
//! icp.set_input_target(PointCloud::new());
//! icp.set_max_correspondence_distance(1.0);
//! icp.set_max_iteration_count(50);
//! icp.set_transformation_epsilon(1e-5);
//! let result = icp.align().unwrap();
//! println!("T = {:?}", result.transformation);
//! ```
//!
//! | Paper (Table I)                  | Here                                |
//! |----------------------------------|-------------------------------------|
//! | `hardwareInitialize()`           | [`FppsIcp::hardware_initialize`]    |
//! | `setTransformationMatrix()`      | [`FppsIcp::set_transformation_matrix`] |
//! | `setInputSource()`               | [`FppsIcp::set_input_source`]       |
//! | `setInputTarget()`               | [`FppsIcp::set_input_target`]       |
//! | `setMaxCorrespondenceDistance()` | [`FppsIcp::set_max_correspondence_distance`] |
//! | `setMaxIterationCount()`         | [`FppsIcp::set_max_iteration_count`]|
//! | `setTransformationEpsilon()`     | [`FppsIcp::set_transformation_epsilon`] |
//! | `align()`                        | [`FppsIcp::align`]                  |
//!
//! The device is abstracted behind [`KernelBackend`]: [`XlaBackend`]
//! runs the AOT artifact on PJRT (the production path),
//! [`NativeSimBackend`] is a bit-faithful pure-rust mirror used for
//! tests and artifact-less environments, and [`KdTreeCpuBackend`] is the
//! exact kd-tree CPU path behind the same interface. Backends are
//! selectable at *runtime* through [`BackendHandle`] / [`BackendKind`]
//! (the multi-lane coordinator instantiates one backend per lane), so
//! nothing above this layer is monomorphised to a single device.
//! Long-running services sit one layer up again: the serving tier
//! ([`crate::coordinator::serving`]) multiplexes many client streams
//! over these per-lane backends through non-blocking submission
//! handles, with SLO-classed admission deciding what parks or sheds
//! when the lanes saturate.
//!
//! # Residency protocol
//!
//! Uploads are split the way a target-resident device behaves: the
//! reference cloud ships once via [`KernelBackend::upload_target_keyed`]
//! and stays on the card; only the per-alignment source re-ships. Each
//! backend keeps an **LRU set of N resident targets** (N from the
//! `hwmodel` HBM residency budget, see
//! [`crate::hwmodel::AcceleratorConfig::resident_target_slots`]) keyed
//! by the caller's target key, so workloads that alternate between maps
//! — tile-crossing localization above all — re-activate a still-resident
//! target ([`KernelBackend::activate_target`]) instead of paying the DMA
//! and, on the kd-tree backend, the index rebuild. Every actual upload
//! mints a fresh [`TargetEpoch`]; [`FppsIcp`] stages padded targets
//! per key and skips the upload whenever the epoch it staged under is
//! still resident. Uploading past capacity evicts the least-recently
//! used slot. Residency is a pure caching layer: hit or miss, the
//! alignment numerics are bit-identical.

use crate::icp::StopReason;
use crate::kdtree::OwnedKdTree;
use crate::math::{kabsch_from_sums, Mat4, Vec3};
use crate::nn::{self, KernelConfig};
use crate::pointcloud::{pad_into, PointCloud};
use crate::pool::{BufferPool, PooledBuf};
use crate::runtime::{Engine, StepAccumulators};
use crate::voxelgrid::{NnStrategy, VoxelGrid};
use anyhow::{bail, Context, Result};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Identity of one resident-target upload. Every actual
/// [`KernelBackend::upload_target_keyed`] mints a fresh epoch, so a
/// caller that remembers the epoch it uploaded under can later compare
/// it against [`KernelBackend::activate_target`]'s answer to learn
/// whether its target is still resident — if so, the re-upload (and,
/// for the kd-tree backend, the index rebuild) is skipped entirely.
/// Epochs are scoped to one backend instance and never reused within
/// it, across all of its residency slots.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TargetEpoch(u64);

impl TargetEpoch {
    fn mint(counter: &mut u64) -> Self {
        *counter += 1;
        TargetEpoch(*counter)
    }
}

/// Fixed query-block size for chunked NN scans: the CPU backends answer
/// [`KernelBackend::step`]'s correspondence search in blocks of this
/// many source points, checking the installed [`CancelToken`] between
/// blocks — so the lane-pool watchdog can cut a city-scale step off
/// mid-scan instead of waiting out the whole map (the one-shot scan's
/// failure mode on million-point targets).
pub const NN_QUERY_CHUNK: usize = 2048;

/// Residency key used by the unkeyed [`KernelBackend::upload_target`]
/// convenience: all anonymous uploads share one slot, reproducing the
/// pre-LRU single-slot semantics without spilling into the keyed set.
pub const ANONYMOUS_TARGET_KEY: u64 = 0x414E_4F4E_5F54_4754; // "ANON_TGT"

/// Bounded LRU set of resident targets shared by every backend: each
/// entry pairs a caller key with the backend's device-side payload (raw
/// buffers, a kd-tree, PJRT buffers) and the epoch it was uploaded
/// under. The most-recently-used entry is the *active* target that
/// [`KernelBackend::step`] runs against.
struct ResidentSlots<T> {
    /// (key, payload, epoch); LRU first, MRU (= active) last.
    entries: Vec<(u64, T, TargetEpoch)>,
    slots: usize,
    epochs: u64,
    /// LRU entries dropped under capacity pressure (upload past the slot
    /// count, or a shrinking [`Self::set_slots`]) — the eviction tally
    /// the pool residency coordinator reads back per lane.
    evictions: u64,
}

impl<T> ResidentSlots<T> {
    fn new(slots: usize) -> Self {
        Self {
            entries: Vec::new(),
            slots: Self::clamp_slots(slots),
            epochs: 0,
            evictions: 0,
        }
    }

    /// Slot counts are bounded by the hwmodel's physical cap: modelling
    /// more residency than the device's activation crossbar supports
    /// would produce upload/hit numbers no hardware could reproduce.
    fn clamp_slots(slots: usize) -> usize {
        slots.clamp(1, crate::hwmodel::MAX_RESIDENT_TARGETS)
    }

    fn slots(&self) -> usize {
        self.slots
    }

    /// Shrink/grow the slot count, evicting LRU entries that no longer fit.
    fn set_slots(&mut self, slots: usize) {
        self.slots = Self::clamp_slots(slots);
        while self.entries.len() > self.slots {
            self.entries.remove(0);
            self.evictions += 1;
        }
    }

    /// Upload: (re)place `key`'s payload, make it active, mint an epoch,
    /// evict the LRU entry on capacity pressure. Re-uploading a resident
    /// key replaces in place and is not an eviction.
    fn insert(&mut self, key: u64, payload: T) -> TargetEpoch {
        self.entries.retain(|(k, ..)| *k != key);
        let epoch = TargetEpoch::mint(&mut self.epochs);
        self.entries.push((key, payload, epoch));
        while self.entries.len() > self.slots {
            self.entries.remove(0);
            self.evictions += 1;
        }
        epoch
    }

    /// Evictions performed so far (capacity pressure + slot shrinks).
    fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Make `key`'s entry active (MRU) if resident; `None` leaves the
    /// active target unchanged.
    fn activate(&mut self, key: u64) -> Option<TargetEpoch> {
        let i = self.entries.iter().position(|(k, ..)| *k == key)?;
        let entry = self.entries.remove(i);
        let epoch = entry.2;
        self.entries.push(entry);
        Some(epoch)
    }

    /// Payload of the active (MRU) entry.
    fn active(&self) -> Option<&T> {
        self.entries.last().map(|(_, p, _)| p)
    }

    fn active_epoch(&self) -> Option<TargetEpoch> {
        self.entries.last().map(|(.., e)| *e)
    }

    /// (key, epoch) of every resident entry, MRU first.
    fn resident_epochs(&self) -> Vec<(u64, TargetEpoch)> {
        self.entries.iter().rev().map(|(k, _, e)| (*k, *e)).collect()
    }
}

/// Shared cancellation flag between a lane-pool watchdog and a backend:
/// the watchdog raises it when a job's deadline expires mid-call, and a
/// cooperative backend (one whose long operations poll
/// [`CancelToken::is_cancelled`]) abandons the operation with an error
/// instead of wedging its lane until the call returns on its own.
#[derive(Clone, Debug, Default)]
pub struct CancelToken {
    cancelled: Arc<std::sync::atomic::AtomicBool>,
}

impl CancelToken {
    pub fn new() -> Self {
        Self::default()
    }

    /// Raise the flag (idempotent).
    pub fn cancel(&self) {
        self.cancelled
            .store(true, std::sync::atomic::Ordering::SeqCst);
    }

    pub fn is_cancelled(&self) -> bool {
        self.cancelled.load(std::sync::atomic::Ordering::SeqCst)
    }

    /// Lower the flag — the lane pool resets its per-lane token before
    /// each job so a cancellation aimed at one job cannot leak into the
    /// next.
    pub fn reset(&self) {
        self.cancelled
            .store(false, std::sync::atomic::Ordering::SeqCst);
    }
}

/// Device abstraction: one ICP step (transform → NN → accumulate) on
/// padded, fixed-capacity buffers.
///
/// The upload path is split the way the paper's Fig. 2 DMA actually
/// behaves on a target-resident device: [`Self::upload_target_keyed`]
/// ships the reference cloud once into one of
/// [`Self::residency_slots`] LRU slots and keeps it resident
/// (scan-to-map callers reuse it across thousands of alignments,
/// tile-crossing callers ping-pong between slots via
/// [`Self::activate_target`]), while [`Self::upload_source`] ships the
/// per-alignment query cloud.
pub trait KernelBackend {
    /// Human-readable backend name (for logs / benches).
    fn name(&self) -> &'static str;

    /// Capacity selection: (n_capacity, m_capacity, block_n, block_m)
    /// for a workload of (n_source, n_target); error if it cannot fit.
    fn select_capacity(&self, n_source: usize, n_target: usize)
        -> Result<(usize, usize, usize, usize)>;

    /// Number of target residency slots this backend keeps (≥ 1); the
    /// default comes from the `hwmodel` HBM residency budget.
    fn residency_slots(&self) -> usize;

    /// Change the residency slot count at runtime; shrinking evicts
    /// least-recently-used targets until the new capacity holds. The
    /// count is clamped to `1..=hwmodel::MAX_RESIDENT_TARGETS` — no
    /// backend may model residency the hardware budget rules out.
    fn set_residency_slots(&mut self, slots: usize);

    /// Upload the padded target cloud + mask into the residency slot
    /// keyed by `key` — the target half of the host→HBM DMA — and make
    /// it the *active* target that [`Self::step`] runs against. It stays
    /// resident (surviving uploads of *other* keys, up to
    /// [`Self::residency_slots`] of them, LRU-evicted under capacity
    /// pressure) across any number of [`Self::upload_source`] /
    /// [`Self::step`] cycles. Returns the freshly minted resident epoch.
    fn upload_target_keyed(
        &mut self,
        key: u64,
        tgt: &[f32],
        tgt_mask: &[f32],
    ) -> Result<TargetEpoch>;

    /// Unkeyed upload — single-slot convenience for one-shot callers;
    /// every anonymous upload replaces the [`ANONYMOUS_TARGET_KEY`] slot.
    fn upload_target(&mut self, tgt: &[f32], tgt_mask: &[f32]) -> Result<TargetEpoch> {
        self.upload_target_keyed(ANONYMOUS_TARGET_KEY, tgt, tgt_mask)
    }

    /// Make the resident target with `key` active for subsequent
    /// [`Self::step`] calls, returning its epoch — the cache-hit path:
    /// no DMA, no index rebuild. `None` means the key is not resident
    /// (never uploaded, or LRU-evicted); the active target is then left
    /// unchanged and the caller must re-upload.
    fn activate_target(&mut self, key: u64) -> Option<TargetEpoch>;

    /// Epoch of the currently *active* target, if any.
    fn target_epoch(&self) -> Option<TargetEpoch>;

    /// `(key, epoch)` of every resident target, most recently used
    /// first — the driver-visible residency table.
    fn resident_epochs(&self) -> Vec<(u64, TargetEpoch)>;

    /// How many resident targets this backend has LRU-evicted under
    /// capacity pressure so far — the eviction half of the
    /// slot-occupancy telemetry the pool residency coordinator uses to
    /// verify its routing avoided avoidable evictions.
    fn target_evictions(&self) -> u64;

    /// Residency slots currently unoccupied — free capacity a pool-wide
    /// coordinator can fill with a cold target without evicting anything.
    fn free_slots(&self) -> usize {
        self.residency_slots()
            .saturating_sub(self.resident_epochs().len())
    }

    /// Upload the padded source cloud + mask — the per-alignment half of
    /// the DMA. Buffer sizes must match a capacity from
    /// [`Self::select_capacity`].
    fn upload_source(&mut self, src: &[f32], src_mask: &[f32]) -> Result<()>;

    /// One ICP iteration over the uploaded clouds: only the cumulative
    /// transform + threshold travel to the device.
    fn step(&mut self, transform: &Mat4, max_dist_sq: f32) -> Result<StepAccumulators>;

    /// Convenience: upload target + source in one call (the pre-split
    /// `begin()`; one-shot callers that never reuse a target).
    fn begin(
        &mut self,
        src: &[f32],
        tgt: &[f32],
        src_mask: &[f32],
        tgt_mask: &[f32],
    ) -> Result<()> {
        self.upload_target(tgt, tgt_mask)?;
        self.upload_source(src, src_mask)
    }

    /// Convenience: `begin` + one `step` (tests, one-shot callers).
    #[allow(clippy::too_many_arguments)]
    fn icp_step(
        &mut self,
        src: &[f32],
        tgt: &[f32],
        src_mask: &[f32],
        tgt_mask: &[f32],
        transform: &Mat4,
        max_dist_sq: f32,
    ) -> Result<StepAccumulators> {
        self.begin(src, tgt, src_mask, tgt_mask)?;
        self.step(transform, max_dist_sq)
    }

    /// Cumulative device-side execution time (telemetry).
    fn device_time(&self) -> Duration;

    /// Install a [`CancelToken`] the backend should poll during long
    /// operations (uploads, steps) so a supervising watchdog can abandon
    /// a wedged call instead of waiting it out. Default: ignored — a
    /// backend that never blocks for long needs no cancellation support.
    fn set_cancel_token(&mut self, _token: CancelToken) {}

    /// Select which NN index answers [`Self::step`]'s correspondence
    /// search for targets uploaded *after* this call (already-resident
    /// targets keep the index they were built with, so set the strategy
    /// before the first upload). Default: ignored — a backend with a
    /// single NN path has no knob. See [`NnStrategy`].
    fn set_nn_strategy(&mut self, _strategy: NnStrategy) {}

    /// The currently selected NN strategy ([`NnStrategy::Exact`] for
    /// backends without the knob).
    fn nn_strategy(&self) -> NnStrategy {
        NnStrategy::Exact
    }
}

/// Production backend: AOT artifact on the PJRT CPU client. Keeps an
/// LRU cache of [`crate::runtime::PreparedTarget`]s — device-resident
/// reference-cloud buffers — so alternating-map workloads re-activate
/// instead of re-shipping.
pub struct XlaBackend {
    engine: Engine,
    targets: ResidentSlots<crate::runtime::PreparedTarget>,
    source: Option<crate::runtime::PreparedSource>,
    device_time: Duration,
}

impl XlaBackend {
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        if !artifacts_dir.join("manifest.txt").exists() {
            bail!(
                "no artifact manifest at {}/manifest.txt — the AOT compile step is \
                 python-side: run `python python/compile/aot.py` first, or use the \
                 native-sim backend, which needs no artifacts",
                artifacts_dir.display()
            );
        }
        Ok(Self {
            engine: Engine::load(artifacts_dir).with_context(|| {
                format!(
                    "initialise the PJRT engine from {} (hardwareInitialize)",
                    artifacts_dir.display()
                )
            })?,
            targets: ResidentSlots::new(crate::hwmodel::default_residency_slots()),
            source: None,
            device_time: Duration::ZERO,
        })
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }
}

impl KernelBackend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla-pjrt"
    }

    fn select_capacity(
        &self,
        n_source: usize,
        n_target: usize,
    ) -> Result<(usize, usize, usize, usize)> {
        let v = self
            .engine
            .manifest()
            .select(n_source, n_target)
            .with_context(|| {
                format!("no artifact variant fits {n_source} source x {n_target} target points")
            })?;
        Ok((v.n, v.m, v.block_n, v.block_m))
    }

    fn residency_slots(&self) -> usize {
        self.targets.slots()
    }

    fn set_residency_slots(&mut self, slots: usize) {
        self.targets.set_slots(slots);
    }

    fn upload_target_keyed(
        &mut self,
        key: u64,
        tgt: &[f32],
        tgt_mask: &[f32],
    ) -> Result<TargetEpoch> {
        // DMA the reference cloud into device-resident buffers; it stays
        // there across alignments until LRU-evicted.
        let prep = self.engine.prepare_target(tgt, tgt_mask)?;
        Ok(self.targets.insert(key, prep))
    }

    fn activate_target(&mut self, key: u64) -> Option<TargetEpoch> {
        self.targets.activate(key)
    }

    fn target_epoch(&self) -> Option<TargetEpoch> {
        self.targets.active_epoch()
    }

    fn resident_epochs(&self) -> Vec<(u64, TargetEpoch)> {
        self.targets.resident_epochs()
    }

    fn target_evictions(&self) -> u64 {
        self.targets.evictions()
    }

    fn upload_source(&mut self, src: &[f32], src_mask: &[f32]) -> Result<()> {
        self.source = Some(self.engine.prepare_source(src, src_mask)?);
        Ok(())
    }

    fn step(&mut self, transform: &Mat4, max_dist_sq: f32) -> Result<StepAccumulators> {
        let tgt = self
            .targets
            .active()
            .context("step() before upload_target(): no target on device")?;
        let src = self
            .source
            .as_ref()
            .context("step() before upload_source(): no source on device")?;
        let engine = &mut self.engine;
        let (acc, timing) = engine.execute_resident(tgt, src, transform, max_dist_sq)?;
        self.device_time += timing.execute;
        Ok(acc)
    }

    fn device_time(&self) -> Duration {
        self.device_time
    }
}

/// Bit-faithful software mirror of the device kernel (see
/// [`nn::kernel_mirror`]); pads to the same block structure and applies
/// the same accumulation semantics.
pub struct NativeSimBackend {
    cfg: KernelConfig,
    device_time: Duration,
    /// Resident targets (the mirror of the HBM reference-cloud slots).
    targets: ResidentSlots<SimTarget>,
    /// Per-alignment source (the mirror of the query-cloud buffers).
    source: Option<SimSource>,
    /// Transformed-source scratch (stage 1 output), recycled per step.
    scratch_p: Vec<f32>,
    /// Hoisted-norm scratch for the NN mirror, recycled per step.
    nn_scratch: nn::MirrorScratch,
    /// NN result buffers, recycled per step.
    nn_out: nn::NnResult,
    /// NN strategy applied to targets uploaded after it was set (see
    /// [`KernelBackend::set_nn_strategy`]).
    nn_strategy: NnStrategy,
    /// Watchdog cancellation flag, polled between NN query chunks.
    cancel: Option<CancelToken>,
    /// Chunked-query progress: NN query blocks completed across all
    /// steps (telemetry; see [`NN_QUERY_CHUNK`]).
    nn_chunks: u64,
}

struct SimTarget {
    tgt: Vec<f32>,
    tgt_mask: Vec<f32>,
    /// Voxel-grid sibling of the padded mirror buffers, present when
    /// the NN strategy chose the approximate path for this target: the
    /// unmasked points (grid indices refer to them) plus the grid.
    grid: Option<(PointCloud, VoxelGrid)>,
}

struct SimSource {
    src: Vec<f32>,
    src_mask: Vec<f32>,
}

impl NativeSimBackend {
    pub fn new() -> Self {
        Self {
            cfg: KernelConfig::default(),
            device_time: Duration::ZERO,
            targets: ResidentSlots::new(crate::hwmodel::default_residency_slots()),
            source: None,
            scratch_p: Vec::new(),
            nn_scratch: nn::MirrorScratch::default(),
            nn_out: nn::NnResult::default(),
            nn_strategy: NnStrategy::default(),
            cancel: None,
            nn_chunks: 0,
        }
    }

    /// NN query blocks completed so far across all steps (the
    /// chunked-scan progress counter).
    pub fn nn_chunks_completed(&self) -> u64 {
        self.nn_chunks
    }

    pub fn with_blocks(block_n: usize, block_m: usize) -> Self {
        Self {
            cfg: KernelConfig { block_n, block_m },
            ..Self::new()
        }
    }

    /// Like [`Self::new`] with an explicit residency slot count
    /// (`1` reproduces the pre-LRU single-slot device).
    pub fn with_residency_slots(slots: usize) -> Self {
        let mut b = Self::new();
        b.targets.set_slots(slots);
        b
    }
}

impl Default for NativeSimBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl KernelBackend for NativeSimBackend {
    fn name(&self) -> &'static str {
        "native-sim"
    }

    fn select_capacity(
        &self,
        n_source: usize,
        n_target: usize,
    ) -> Result<(usize, usize, usize, usize)> {
        let n = n_source.div_ceil(self.cfg.block_n).max(1) * self.cfg.block_n;
        let m = n_target.div_ceil(self.cfg.block_m).max(1) * self.cfg.block_m;
        Ok((n, m, self.cfg.block_n, self.cfg.block_m))
    }

    fn residency_slots(&self) -> usize {
        self.targets.slots()
    }

    fn set_residency_slots(&mut self, slots: usize) {
        self.targets.set_slots(slots);
    }

    fn upload_target_keyed(
        &mut self,
        key: u64,
        tgt: &[f32],
        tgt_mask: &[f32],
    ) -> Result<TargetEpoch> {
        let m = tgt.len() / 3;
        if tgt_mask.len() != m {
            bail!("target mask has {} entries for {m} points", tgt_mask.len());
        }
        // Grid sibling (cold path): built over the unmasked points only,
        // when the strategy picks the approximate index for a map of
        // this size.
        let kept_count = tgt_mask.iter().filter(|&&w| w > 0.0).count();
        let grid = if self.nn_strategy.wants_grid(kept_count) {
            let mut kept = PointCloud::with_capacity(kept_count);
            for j in 0..m {
                if tgt_mask[j] > 0.0 {
                    kept.push([tgt[3 * j], tgt[3 * j + 1], tgt[3 * j + 2]]);
                }
            }
            let (cell, ring) = self.nn_strategy.grid_params();
            let g = VoxelGrid::build(&kept, cell, ring);
            Some((kept, g))
        } else {
            None
        };
        Ok(self.targets.insert(
            key,
            SimTarget {
                tgt: tgt.to_vec(),
                tgt_mask: tgt_mask.to_vec(),
                grid,
            },
        ))
    }

    fn activate_target(&mut self, key: u64) -> Option<TargetEpoch> {
        self.targets.activate(key)
    }

    fn target_epoch(&self) -> Option<TargetEpoch> {
        self.targets.active_epoch()
    }

    fn resident_epochs(&self) -> Vec<(u64, TargetEpoch)> {
        self.targets.resident_epochs()
    }

    fn target_evictions(&self) -> u64 {
        self.targets.evictions()
    }

    fn upload_source(&mut self, src: &[f32], src_mask: &[f32]) -> Result<()> {
        let n = src.len() / 3;
        if src_mask.len() != n {
            bail!("source mask has {} entries for {n} points", src_mask.len());
        }
        // Refill the existing mirror buffers in place: once warm, the
        // per-alignment source DMA costs no heap traffic.
        let s = self.source.get_or_insert_with(|| SimSource {
            src: Vec::new(),
            src_mask: Vec::new(),
        });
        s.src.clear();
        s.src.extend_from_slice(src);
        s.src_mask.clear();
        s.src_mask.extend_from_slice(src_mask);
        Ok(())
    }

    fn step(&mut self, transform: &Mat4, max_dist_sq: f32) -> Result<StepAccumulators> {
        let target = self
            .targets
            .active()
            .context("step() before upload_target(): no target uploaded")?;
        let source = self
            .source
            .as_ref()
            .context("step() before upload_source(): no source uploaded")?;
        let (src, tgt, src_mask, tgt_mask) = (
            &source.src,
            &target.tgt,
            &source.src_mask,
            &target.tgt_mask,
        );
        let t0 = Instant::now();
        let n = src.len() / 3;
        // Stage 1: point cloud transformer (f32, like the device),
        // writing into the recycled scratch buffer.
        let tm = transform.to_f32_row_major();
        self.scratch_p.clear();
        self.scratch_p.resize(src.len(), 0.0);
        let p = &mut self.scratch_p;
        for i in 0..n {
            let (x, y, z) = (src[3 * i], src[3 * i + 1], src[3 * i + 2]);
            p[3 * i] = tm[0] * x + tm[1] * y + tm[2] * z + tm[3];
            p[3 * i + 1] = tm[4] * x + tm[5] * y + tm[6] * z + tm[7];
            p[3 * i + 2] = tm[8] * x + tm[9] * y + tm[10] * z + tm[11];
        }
        let p = &self.scratch_p;
        let cancel = self.cancel.clone();
        if let Some((kept, grid)) = &target.grid {
            // Approximate stages 2–4: per-point voxel-grid probes
            // instead of the blockwise mirror, in fixed-size query
            // chunks with the cancellation flag checked between them
            // (see [`NN_QUERY_CHUNK`]). Accumulation stays f32 partials
            // like the wire format, so only the NN answers differ from
            // the exact mirror — by the grid's bounded ring budget.
            let mut count = 0f32;
            let mut sum_p = [0f32; 3];
            let mut sum_q = [0f32; 3];
            let mut sum_pq = [0f32; 9];
            let mut sum_d = 0f32;
            let mut chunks = 0u64;
            let mut start = 0usize;
            while start < n {
                if cancel.as_ref().is_some_and(|c| c.is_cancelled()) {
                    self.nn_chunks += chunks;
                    bail!(
                        "native-sim step cancelled between NN query chunks \
                         ({chunks} of {} blocks done)",
                        n.div_ceil(NN_QUERY_CHUNK)
                    );
                }
                let end = (start + NN_QUERY_CHUNK).min(n);
                for i in start..end {
                    let w = src_mask[i];
                    if w == 0.0 {
                        continue;
                    }
                    let pi = [p[3 * i], p[3 * i + 1], p[3 * i + 2]];
                    let Some(nb) = grid.nearest(kept, pi, max_dist_sq) else {
                        continue;
                    };
                    let qj = kept.get(nb.index as usize);
                    count += w;
                    for a in 0..3 {
                        sum_p[a] += w * pi[a];
                        sum_q[a] += w * qj[a];
                        for b in 0..3 {
                            sum_pq[a * 3 + b] += w * pi[a] * qj[b];
                        }
                    }
                    sum_d += w * nb.dist_sq;
                }
                chunks += 1;
                start = end;
            }
            self.nn_chunks += chunks;
            let mut wire = [0f32; 17];
            wire[0] = count;
            wire[1..4].copy_from_slice(&sum_p);
            wire[4..7].copy_from_slice(&sum_q);
            wire[7..16].copy_from_slice(&sum_pq);
            wire[16] = sum_d;
            self.device_time += t0.elapsed();
            return StepAccumulators::from_wire(&wire);
        }
        // The exact mirror is one blockwise call; honour a cancellation
        // raised before it starts (a mid-mirror cut is the chunked grid
        // path's job — the mirror's padded capacity bounds its runtime).
        if cancel.as_ref().is_some_and(|c| c.is_cancelled()) {
            bail!("native-sim step cancelled before the NN mirror");
        }
        // Stage 2+3: NN search (blockwise mirror, recycled buffers).
        nn::kernel_mirror_into(
            p,
            tgt,
            tgt_mask,
            self.cfg,
            &mut self.nn_scratch,
            &mut self.nn_out,
        );
        let res = &self.nn_out;
        // Stage 4: result accumulation (f32 partials like the jnp sums).
        let mut count = 0f32;
        let mut sum_p = [0f32; 3];
        let mut sum_q = [0f32; 3];
        let mut sum_pq = [0f32; 9];
        let mut sum_d = 0f32;
        for i in 0..n {
            let w = src_mask[i] * if res.dist_sq[i] <= max_dist_sq { 1.0 } else { 0.0 };
            if w == 0.0 {
                continue;
            }
            let j = res.index[i] as usize;
            let pi = [p[3 * i], p[3 * i + 1], p[3 * i + 2]];
            let qj = [tgt[3 * j], tgt[3 * j + 1], tgt[3 * j + 2]];
            count += w;
            for a in 0..3 {
                sum_p[a] += w * pi[a];
                sum_q[a] += w * qj[a];
                for b in 0..3 {
                    sum_pq[a * 3 + b] += w * pi[a] * qj[b];
                }
            }
            sum_d += w * res.dist_sq[i];
        }
        // Fixed-size wire record (the 17-float DMA readback), on the
        // stack like the device's result FIFO.
        let mut wire = [0f32; 17];
        wire[0] = count;
        wire[1..4].copy_from_slice(&sum_p);
        wire[4..7].copy_from_slice(&sum_q);
        wire[7..16].copy_from_slice(&sum_pq);
        wire[16] = sum_d;
        self.device_time += t0.elapsed();
        StepAccumulators::from_wire(&wire)
    }

    fn device_time(&self) -> Duration {
        self.device_time
    }

    fn set_cancel_token(&mut self, token: CancelToken) {
        self.cancel = Some(token);
    }

    fn set_nn_strategy(&mut self, strategy: NnStrategy) {
        self.nn_strategy = strategy;
    }

    fn nn_strategy(&self) -> NnStrategy {
        self.nn_strategy
    }
}

/// Exact kd-tree CPU path behind the [`KernelBackend`] interface — the
/// PCL-style correspondence search as a third selectable device. Unlike
/// [`NativeSimBackend`] it accumulates in f64 (host precision) and needs
/// no padding, so its numerics match the `icp` CPU baseline rather than
/// the FPGA wire format; Table III shows the two agree to < 0.01 m.
pub struct KdTreeCpuBackend {
    device_time: Duration,
    /// Resident NN indexes, one [`KdSlot`] per target key: built once
    /// per upload, queried every step of every alignment that reuses
    /// the target — and *kept* across alternating targets up to the
    /// slot count. Each slot carries the exact kd-tree and, when the
    /// active [`NnStrategy`] asks for it, a [`VoxelGrid`] sibling over
    /// the same kept points.
    targets: ResidentSlots<KdSlot>,
    source: Option<KdSource>,
    builds: u64,
    /// Optional cross-instance build counter (lane-pool tests sum the
    /// builds of every lane's backend through one shared counter).
    shared_builds: Option<Arc<AtomicU64>>,
    /// Exact / approximate NN selection applied at the *next* target
    /// upload (resident slots keep the index they were built with).
    nn_strategy: NnStrategy,
    /// Watchdog cancellation flag, checked between NN query chunks.
    cancel: Option<CancelToken>,
    /// Completed [`NN_QUERY_CHUNK`]-sized query blocks across all steps.
    nn_chunks: u64,
    /// Steps cut off between chunks by a raised cancellation token.
    nn_cancels: u64,
}

/// One resident target's NN indexes: the exact kd-tree always, plus the
/// voxel grid when the upload-time [`NnStrategy`] selected it.
struct KdSlot {
    tree: OwnedKdTree,
    grid: Option<VoxelGrid>,
}

struct KdSource {
    src: Vec<f32>,
    src_mask: Vec<f32>,
}

impl KdTreeCpuBackend {
    pub fn new() -> Self {
        Self {
            device_time: Duration::ZERO,
            targets: ResidentSlots::new(crate::hwmodel::default_residency_slots()),
            source: None,
            builds: 0,
            shared_builds: None,
            nn_strategy: NnStrategy::default(),
            cancel: None,
            nn_chunks: 0,
            nn_cancels: 0,
        }
    }

    /// Like [`Self::new`] with an explicit residency slot count
    /// (`1` reproduces the pre-LRU single-slot device).
    pub fn with_residency_slots(slots: usize) -> Self {
        let mut b = Self::new();
        b.targets.set_slots(slots);
        b
    }

    /// Like [`Self::new`], but every kd-tree build also increments
    /// `counter` — lets a test (or a report) count builds across the
    /// backends of a whole lane pool.
    pub fn with_shared_build_counter(counter: Arc<AtomicU64>) -> Self {
        Self {
            shared_builds: Some(counter),
            ..Self::new()
        }
    }

    /// How many times this instance has built its kd-tree — with target
    /// caching, K alignments against one unchanged target build exactly
    /// once, and with N residency slots an N-map ping-pong builds once
    /// *per map*.
    pub fn tree_builds(&self) -> u64 {
        self.builds
    }

    /// Chunked-query progress: `(completed chunks, cancelled steps)`.
    /// Chunks advance once per [`NN_QUERY_CHUNK`] queries; a watchdog
    /// cut-off between chunks bumps the cancel count, so a partial step
    /// is visible as `chunks > 0 && cancels > 0`.
    pub fn nn_progress(&self) -> (u64, u64) {
        (self.nn_chunks, self.nn_cancels)
    }

    /// Whether the *active* resident target carries a voxel-grid index
    /// (i.e. the strategy at its upload selected the approximate path).
    pub fn active_target_uses_grid(&self) -> bool {
        self.targets.active().is_some_and(|s| s.grid.is_some())
    }
}

impl Default for KdTreeCpuBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl KernelBackend for KdTreeCpuBackend {
    fn name(&self) -> &'static str {
        "kdtree-cpu"
    }

    fn select_capacity(
        &self,
        n_source: usize,
        n_target: usize,
    ) -> Result<(usize, usize, usize, usize)> {
        // No block structure: exact capacities, no padding.
        Ok((n_source.max(1), n_target.max(1), 1, 1))
    }

    fn residency_slots(&self) -> usize {
        self.targets.slots()
    }

    fn set_residency_slots(&mut self, slots: usize) {
        self.targets.set_slots(slots);
    }

    fn upload_target_keyed(
        &mut self,
        key: u64,
        tgt: &[f32],
        tgt_mask: &[f32],
    ) -> Result<TargetEpoch> {
        let m = tgt.len() / 3;
        if tgt_mask.len() != m {
            bail!("target mask has {} entries for {m} points", tgt_mask.len());
        }
        // Index over the unmasked target points only (masked padding is
        // dropped at upload).
        let mut kept = PointCloud::with_capacity(m);
        for j in 0..m {
            if tgt_mask[j] > 0.0 {
                kept.push([tgt[3 * j], tgt[3 * j + 1], tgt[3 * j + 2]]);
            }
        }
        self.builds += 1;
        if let Some(c) = &self.shared_builds {
            // ordering: Relaxed — test-observability build counter; no
            // data is published through it.
            c.fetch_add(1, Ordering::Relaxed);
        }
        let tree = OwnedKdTree::build(kept);
        let grid = if self.nn_strategy.wants_grid(tree.cloud().len()) {
            let (cell, ring) = self.nn_strategy.grid_params();
            Some(VoxelGrid::build(tree.cloud(), cell, ring))
        } else {
            None
        };
        Ok(self.targets.insert(key, KdSlot { tree, grid }))
    }

    fn activate_target(&mut self, key: u64) -> Option<TargetEpoch> {
        self.targets.activate(key)
    }

    fn target_epoch(&self) -> Option<TargetEpoch> {
        self.targets.active_epoch()
    }

    fn resident_epochs(&self) -> Vec<(u64, TargetEpoch)> {
        self.targets.resident_epochs()
    }

    fn target_evictions(&self) -> u64 {
        self.targets.evictions()
    }

    fn upload_source(&mut self, src: &[f32], src_mask: &[f32]) -> Result<()> {
        let n = src.len() / 3;
        if src_mask.len() != n {
            bail!("source mask has {} entries for {n} points", src_mask.len());
        }
        // Refill the existing buffers in place (no per-alignment heap
        // traffic once the capacity is warm).
        let s = self.source.get_or_insert_with(|| KdSource {
            src: Vec::new(),
            src_mask: Vec::new(),
        });
        s.src.clear();
        s.src.extend_from_slice(src);
        s.src_mask.clear();
        s.src_mask.extend_from_slice(src_mask);
        Ok(())
    }

    fn step(&mut self, transform: &Mat4, max_dist_sq: f32) -> Result<StepAccumulators> {
        let slot = self
            .targets
            .active()
            .context("step() before upload_target(): no target uploaded")?;
        let state = self
            .source
            .as_ref()
            .context("step() before upload_source(): no source uploaded")?;
        let t0 = Instant::now();
        let n = state.src.len() / 3;
        // Transform in f32, like the device's point cloud transformer.
        let tm = transform.to_f32_row_major();
        let mut acc = StepAccumulators::default();
        // Fixed-size query chunks with the cancellation flag checked
        // between them: on city-scale maps the watchdog's deadline
        // containment cuts a step off at a chunk boundary instead of
        // waiting out the full one-shot scan. The per-point math is
        // untouched by the restructuring, so chunking is bit-invisible.
        let cancel = self.cancel.clone();
        let mut chunks = 0u64;
        let mut start = 0usize;
        while start < n {
            if cancel.as_ref().is_some_and(|c| c.is_cancelled()) {
                self.nn_cancels += 1;
                self.nn_chunks += chunks;
                bail!(
                    "kdtree-cpu step cancelled between NN query chunks \
                     ({chunks} of {} blocks done)",
                    n.div_ceil(NN_QUERY_CHUNK)
                );
            }
            let end = (start + NN_QUERY_CHUNK).min(n);
            for i in start..end {
                if state.src_mask[i] == 0.0 {
                    continue;
                }
                let (x, y, z) = (
                    state.src[3 * i],
                    state.src[3 * i + 1],
                    state.src[3 * i + 2],
                );
                let p = [
                    tm[0] * x + tm[1] * y + tm[2] * z + tm[3],
                    tm[4] * x + tm[5] * y + tm[6] * z + tm[7],
                    tm[8] * x + tm[9] * y + tm[10] * z + tm[11],
                ];
                // Bounded search: the threshold prunes the descent, and
                // the strict bound matches the `icp` CPU baseline's
                // rejection. The grid sibling (when built) answers the
                // same bounded query within its ring budget.
                let nb = match &slot.grid {
                    Some(grid) => grid.nearest(slot.tree.cloud(), p, max_dist_sq),
                    None => slot.tree.nearest_within_sq(p, max_dist_sq),
                };
                let Some(nb) = nb else {
                    continue;
                };
                let q = slot.tree.cloud().get(nb.index as usize);
                let pv = Vec3::from_f32(p);
                let qv = Vec3::from_f32(q);
                acc.count += 1.0;
                acc.sum_p = acc.sum_p + pv;
                acc.sum_q = acc.sum_q + qv;
                for a in 0..3 {
                    for b in 0..3 {
                        let pa = [pv.x, pv.y, pv.z][a];
                        let qb = [qv.x, qv.y, qv.z][b];
                        acc.sum_pq.m[a][b] += pa * qb;
                    }
                }
                acc.sum_sq_dist += nb.dist_sq as f64;
            }
            chunks += 1;
            start = end;
        }
        self.nn_chunks += chunks;
        self.device_time += t0.elapsed();
        Ok(acc)
    }

    fn set_cancel_token(&mut self, token: CancelToken) {
        self.cancel = Some(token);
    }

    fn set_nn_strategy(&mut self, strategy: NnStrategy) {
        self.nn_strategy = strategy;
    }

    fn nn_strategy(&self) -> NnStrategy {
        self.nn_strategy
    }

    fn device_time(&self) -> Duration {
        self.device_time
    }
}

/// Which device implementation to run — parsed from `--backend` and from
/// `backend=` config keys, resolved by [`BackendHandle::create`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// XLA when artifacts load, otherwise fall back to NativeSim.
    Auto,
    Xla,
    NativeSim,
    KdTreeCpu,
}

impl std::str::FromStr for BackendKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "auto" => BackendKind::Auto,
            "xla" | "xla-pjrt" => BackendKind::Xla,
            "native-sim" | "sim" => BackendKind::NativeSim,
            "kdtree" | "kdtree-cpu" | "cpu" => BackendKind::KdTreeCpu,
            other => bail!(
                "unknown backend {other:?} (expected auto | xla | native-sim | kdtree)"
            ),
        })
    }
}

/// Ordered backend degradation chain for the lane-pool supervisor
/// (e.g. `xla → native-sim → kdtree-cpu`): a lane that keeps crashing on
/// tier *t* is respawned on tier *t+1*, trading accelerator performance
/// for availability instead of dying. Parsed from `--failover` /
/// `failover=` as a comma-separated [`BackendKind`] list; tiers past
/// the end of the chain clamp to the last (most conservative) entry.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FailoverChain(pub Vec<BackendKind>);

impl FailoverChain {
    /// A single-tier "chain": no degradation, every respawn recreates
    /// the same backend kind.
    pub fn single(kind: BackendKind) -> Self {
        Self(vec![kind])
    }

    /// The backend kind to use at failover tier `tier` (0 = primary),
    /// clamped to the last chain entry.
    pub fn kind_for_tier(&self, tier: usize) -> BackendKind {
        *self
            .0
            .get(tier.min(self.0.len().saturating_sub(1)))
            .unwrap_or(&BackendKind::Auto)
    }

    pub fn tiers(&self) -> usize {
        self.0.len().max(1)
    }
}

impl std::str::FromStr for FailoverChain {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        let kinds: Vec<BackendKind> = s
            .split(',')
            .map(str::trim)
            .filter(|p| !p.is_empty())
            .map(str::parse)
            .collect::<Result<_>>()?;
        if kinds.is_empty() {
            bail!("empty failover chain (expected e.g. \"xla,native-sim,kdtree\")");
        }
        Ok(Self(kinds))
    }
}

impl std::fmt::Display for FailoverChain {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let names: Vec<&str> = self
            .0
            .iter()
            .map(|k| match k {
                BackendKind::Auto => "auto",
                BackendKind::Xla => "xla",
                BackendKind::NativeSim => "native-sim",
                BackendKind::KdTreeCpu => "kdtree-cpu",
            })
            .collect();
        write!(f, "{}", names.join(","))
    }
}

/// Runtime-selectable backend: one enum over every [`KernelBackend`]
/// implementation, so `FppsIcp<BackendHandle>` can switch devices per
/// process — or per *lane* in the multi-lane coordinator — without
/// monomorphising the whole stack per backend.
pub enum BackendHandle {
    Xla(Box<XlaBackend>),
    NativeSim(NativeSimBackend),
    KdTreeCpu(KdTreeCpuBackend),
}

impl BackendHandle {
    /// Resolve a [`BackendKind`] into a live backend. `Auto` prefers the
    /// AOT artifact path and falls back (with a note) to the bit-faithful
    /// NativeSim mirror when artifacts are absent or PJRT is unavailable,
    /// so artifact-less checkouts always work.
    pub fn create(kind: BackendKind, artifacts_dir: &Path) -> Result<BackendHandle> {
        match kind {
            BackendKind::Xla => Ok(BackendHandle::Xla(Box::new(XlaBackend::load(
                artifacts_dir,
            )?))),
            BackendKind::NativeSim => Ok(BackendHandle::NativeSim(NativeSimBackend::new())),
            BackendKind::KdTreeCpu => Ok(BackendHandle::KdTreeCpu(KdTreeCpuBackend::new())),
            BackendKind::Auto => {
                if artifacts_dir.join("manifest.txt").exists() {
                    match XlaBackend::load(artifacts_dir) {
                        Ok(b) => return Ok(BackendHandle::Xla(Box::new(b))),
                        Err(e) => eprintln!(
                            "note: XLA backend unavailable ({e:#}); using native-sim"
                        ),
                    }
                }
                Ok(BackendHandle::NativeSim(NativeSimBackend::new()))
            }
        }
    }
}

impl KernelBackend for BackendHandle {
    fn name(&self) -> &'static str {
        match self {
            BackendHandle::Xla(b) => b.name(),
            BackendHandle::NativeSim(b) => b.name(),
            BackendHandle::KdTreeCpu(b) => b.name(),
        }
    }

    fn select_capacity(
        &self,
        n_source: usize,
        n_target: usize,
    ) -> Result<(usize, usize, usize, usize)> {
        match self {
            BackendHandle::Xla(b) => b.select_capacity(n_source, n_target),
            BackendHandle::NativeSim(b) => b.select_capacity(n_source, n_target),
            BackendHandle::KdTreeCpu(b) => b.select_capacity(n_source, n_target),
        }
    }

    fn residency_slots(&self) -> usize {
        match self {
            BackendHandle::Xla(b) => b.residency_slots(),
            BackendHandle::NativeSim(b) => b.residency_slots(),
            BackendHandle::KdTreeCpu(b) => b.residency_slots(),
        }
    }

    fn set_residency_slots(&mut self, slots: usize) {
        match self {
            BackendHandle::Xla(b) => b.set_residency_slots(slots),
            BackendHandle::NativeSim(b) => b.set_residency_slots(slots),
            BackendHandle::KdTreeCpu(b) => b.set_residency_slots(slots),
        }
    }

    fn upload_target_keyed(
        &mut self,
        key: u64,
        tgt: &[f32],
        tgt_mask: &[f32],
    ) -> Result<TargetEpoch> {
        match self {
            BackendHandle::Xla(b) => b.upload_target_keyed(key, tgt, tgt_mask),
            BackendHandle::NativeSim(b) => b.upload_target_keyed(key, tgt, tgt_mask),
            BackendHandle::KdTreeCpu(b) => b.upload_target_keyed(key, tgt, tgt_mask),
        }
    }

    fn activate_target(&mut self, key: u64) -> Option<TargetEpoch> {
        match self {
            BackendHandle::Xla(b) => b.activate_target(key),
            BackendHandle::NativeSim(b) => b.activate_target(key),
            BackendHandle::KdTreeCpu(b) => b.activate_target(key),
        }
    }

    fn target_epoch(&self) -> Option<TargetEpoch> {
        match self {
            BackendHandle::Xla(b) => b.target_epoch(),
            BackendHandle::NativeSim(b) => b.target_epoch(),
            BackendHandle::KdTreeCpu(b) => b.target_epoch(),
        }
    }

    fn resident_epochs(&self) -> Vec<(u64, TargetEpoch)> {
        match self {
            BackendHandle::Xla(b) => b.resident_epochs(),
            BackendHandle::NativeSim(b) => b.resident_epochs(),
            BackendHandle::KdTreeCpu(b) => b.resident_epochs(),
        }
    }

    fn target_evictions(&self) -> u64 {
        match self {
            BackendHandle::Xla(b) => b.target_evictions(),
            BackendHandle::NativeSim(b) => b.target_evictions(),
            BackendHandle::KdTreeCpu(b) => b.target_evictions(),
        }
    }

    fn upload_source(&mut self, src: &[f32], src_mask: &[f32]) -> Result<()> {
        match self {
            BackendHandle::Xla(b) => b.upload_source(src, src_mask),
            BackendHandle::NativeSim(b) => b.upload_source(src, src_mask),
            BackendHandle::KdTreeCpu(b) => b.upload_source(src, src_mask),
        }
    }

    fn step(&mut self, transform: &Mat4, max_dist_sq: f32) -> Result<StepAccumulators> {
        match self {
            BackendHandle::Xla(b) => b.step(transform, max_dist_sq),
            BackendHandle::NativeSim(b) => b.step(transform, max_dist_sq),
            BackendHandle::KdTreeCpu(b) => b.step(transform, max_dist_sq),
        }
    }

    fn device_time(&self) -> Duration {
        match self {
            BackendHandle::Xla(b) => b.device_time(),
            BackendHandle::NativeSim(b) => b.device_time(),
            BackendHandle::KdTreeCpu(b) => b.device_time(),
        }
    }

    fn set_cancel_token(&mut self, token: CancelToken) {
        match self {
            // XLA keeps the trait default (no cooperative cut-points in
            // the AOT graph); the CPU paths honour it between chunks.
            BackendHandle::Xla(b) => b.set_cancel_token(token),
            BackendHandle::NativeSim(b) => b.set_cancel_token(token),
            BackendHandle::KdTreeCpu(b) => b.set_cancel_token(token),
        }
    }

    fn set_nn_strategy(&mut self, strategy: NnStrategy) {
        match self {
            BackendHandle::Xla(b) => b.set_nn_strategy(strategy),
            BackendHandle::NativeSim(b) => b.set_nn_strategy(strategy),
            BackendHandle::KdTreeCpu(b) => b.set_nn_strategy(strategy),
        }
    }

    fn nn_strategy(&self) -> NnStrategy {
        match self {
            BackendHandle::Xla(b) => b.nn_strategy(),
            BackendHandle::NativeSim(b) => b.nn_strategy(),
            BackendHandle::KdTreeCpu(b) => b.nn_strategy(),
        }
    }
}

/// Per-iteration record of an FPPS alignment.
#[derive(Clone, Copy, Debug)]
pub struct FppsIterationStat {
    pub correspondences: f64,
    pub rmse: f64,
    pub delta: f64,
}

/// Result of [`FppsIcp::align`].
#[derive(Clone, Debug)]
pub struct FppsResult {
    pub transformation: Mat4,
    pub rmse: f64,
    pub iterations: u32,
    pub stop: StopReason,
    pub stats: Vec<FppsIterationStat>,
    pub total_time: Duration,
    /// Time spent inside the kernel backend.
    pub device_time: Duration,
}

impl FppsResult {
    pub fn has_converged(&self) -> bool {
        matches!(
            self.stop,
            StopReason::Converged | StopReason::MaxIterations
        )
    }
}

/// The FPPS ICP object (Table I).
pub struct FppsIcp<B: KernelBackend> {
    backend: B,
    /// Shared (like the target) so the lane pool can hand one sampled
    /// cloud to every retry attempt without cloning points.
    source: Option<Arc<PointCloud>>,
    /// Shared so scan-to-map callers can hand the same map to thousands
    /// of alignments without cloning it (`Arc::ptr_eq` is also the fast
    /// path of the unchanged-target check).
    target: Option<Arc<PointCloud>>,
    initial_transform: Mat4,
    max_correspondence_distance: f32,
    max_iteration_count: u32,
    transformation_epsilon: f64,
    /// Per-key padded targets staged for the device, LRU order (MRU
    /// last), bounded by the backend's residency slot count — the
    /// host-side mirror of the device's resident-target set.
    staged_targets: Vec<StagedTarget>,
    target_uploads: u64,
    target_cache_hits: u64,
    /// Staged targets re-padded **in place** because only the selected
    /// capacity changed (the buffer is recycled, not rebuilt).
    target_repads: u64,
    /// Arena the staging buffers are drawn from (and returned to, when
    /// a staged target is evicted) — see [`crate::pool`].
    pool: BufferPool,
    /// Recycled per-alignment source staging `(padded, mask)`: refilled
    /// in place by [`crate::pointcloud::pad_into`] every `align()`.
    src_stage: Option<(PooledBuf, PooledBuf)>,
    /// Recycled iteration-stat buffer: `align()` takes it, the result
    /// hands it back through [`Self::recycle_stats`].
    stats_scratch: Vec<FppsIterationStat>,
    /// Cooperative deadline: [`Self::align`] checks it between
    /// iterations and stops with [`StopReason::DeadlineExceeded`] once
    /// passed (a hang *inside* one backend call is the lane-pool
    /// watchdog's job; this bounds the many-iterations case).
    deadline: Option<Instant>,
}

struct StagedTarget {
    /// The cloud this staging was built from — its identity for the
    /// unchanged-target check (`Arc` pointer first, exact content
    /// second; a fingerprint alone could collide and corrupt results).
    cloud: Arc<PointCloud>,
    /// Residency key handed to the backend (content fingerprint).
    key: u64,
    /// Padded wire buffers, pooled: evicting this staging returns them
    /// to the arena for the next cold target of the same class.
    tgt: PooledBuf,
    tgt_mask: PooledBuf,
    /// Target capacity the padding was built for (re-padded **in
    /// place** if capacity selection changes, e.g. a different artifact
    /// variant).
    cap_m: usize,
    /// Epoch this staging was uploaded under; `None` = not yet uploaded.
    epoch: Option<TargetEpoch>,
}

impl FppsIcp<XlaBackend> {
    /// `hardwareInitialize()`: open the device and load the bitstream
    /// (here: create the PJRT client and compile the AOT artifacts).
    pub fn hardware_initialize(artifacts_dir: &Path) -> Result<Self> {
        Ok(Self::with_backend(XlaBackend::load(artifacts_dir)?))
    }
}

impl FppsIcp<NativeSimBackend> {
    /// FPPS over the software device mirror (no artifacts needed).
    pub fn native_sim() -> Self {
        Self::with_backend(NativeSimBackend::new())
    }
}

impl FppsIcp<KdTreeCpuBackend> {
    /// FPPS over the exact kd-tree CPU path.
    pub fn kdtree_cpu() -> Self {
        Self::with_backend(KdTreeCpuBackend::new())
    }
}

impl FppsIcp<BackendHandle> {
    /// FPPS over a runtime-selected backend (see [`BackendHandle::create`]).
    pub fn with_kind(kind: BackendKind, artifacts_dir: &Path) -> Result<Self> {
        Ok(Self::with_backend(BackendHandle::create(
            kind,
            artifacts_dir,
        )?))
    }
}

impl<B: KernelBackend> FppsIcp<B> {
    pub fn with_backend(backend: B) -> Self {
        Self {
            backend,
            source: None,
            target: None,
            initial_transform: Mat4::IDENTITY,
            max_correspondence_distance: 1.0,
            max_iteration_count: 50,
            transformation_epsilon: 1e-5,
            staged_targets: Vec::new(),
            target_uploads: 0,
            target_cache_hits: 0,
            target_repads: 0,
            pool: BufferPool::default(),
            src_stage: None,
            stats_scratch: Vec::new(),
            deadline: None,
        }
    }

    pub fn backend(&self) -> &B {
        &self.backend
    }

    /// `(uploads, cache hits, re-pads)` of the resident-target path:
    /// how many `align()` calls actually shipped the target to the
    /// device vs. found it already resident, and how many reused a
    /// staged buffer in place because only the selected capacity
    /// changed (a re-pad costs a refill + re-upload, never a rebuild).
    pub fn target_cache_stats(&self) -> (u64, u64, u64) {
        (
            self.target_uploads,
            self.target_cache_hits,
            self.target_repads,
        )
    }

    /// Replace the staging-buffer arena (e.g. to share one pool across
    /// engines, or to apply a `--pool-capacity` retention knob). Only
    /// affects buffers staged after the call.
    pub fn set_buffer_pool(&mut self, pool: BufferPool) -> &mut Self {
        self.pool = pool;
        self
    }

    /// The staging-buffer arena (stats are read through it).
    pub fn buffer_pool(&self) -> &BufferPool {
        &self.pool
    }

    /// Hand an iteration-stat buffer (from a consumed [`FppsResult`])
    /// back for reuse by the next `align()` — the last allocation on
    /// the per-job hot path once staging is warm.
    pub fn recycle_stats(&mut self, mut stats: Vec<FppsIterationStat>) {
        stats.clear();
        self.stats_scratch = stats;
    }

    /// `setTransformationMatrix()`: initial transform applied before the
    /// first iteration.
    pub fn set_transformation_matrix(&mut self, t: Mat4) -> &mut Self {
        self.initial_transform = t;
        self
    }

    /// `setInputSource()`. Accepts an owned cloud or a shared
    /// `Arc<PointCloud>`; the lane pool passes the same `Arc` to every
    /// retry attempt so resubmission never copies points.
    pub fn set_input_source(&mut self, cloud: impl Into<Arc<PointCloud>>) -> &mut Self {
        self.source = Some(cloud.into());
        self
    }

    /// `setInputTarget()`. Accepts an owned cloud or a shared
    /// `Arc<PointCloud>` (map reuse). Targets are staged per key: as
    /// long as a cloud (by `Arc` pointer or exact content) was seen
    /// within the last [`KernelBackend::residency_slots`] distinct
    /// targets, the next `align()` against it skips the re-upload —
    /// including after *other* targets were aligned in between (the
    /// tile ping-pong case the single-slot cache thrashed on).
    pub fn set_input_target(&mut self, cloud: impl Into<Arc<PointCloud>>) -> &mut Self {
        self.target = Some(cloud.into());
        self
    }

    /// `setMaxCorrespondenceDistance()` (meters).
    pub fn set_max_correspondence_distance(&mut self, d: f32) -> &mut Self {
        assert!(d > 0.0, "max correspondence distance must be positive");
        self.max_correspondence_distance = d;
        self
    }

    /// `setMaxIterationCount()`.
    pub fn set_max_iteration_count(&mut self, n: u32) -> &mut Self {
        self.max_iteration_count = n;
        self
    }

    /// `setTransformationEpsilon()`.
    pub fn set_transformation_epsilon(&mut self, eps: f64) -> &mut Self {
        assert!(eps >= 0.0);
        self.transformation_epsilon = eps;
        self
    }

    /// Absolute deadline for the *next* [`Self::align`] call (`None`
    /// disables). Checked between iterations: once passed, the loop
    /// stops with [`StopReason::DeadlineExceeded`] rather than running
    /// its remaining iteration budget. The lane pool sets this per job
    /// from [`crate::coordinator::RegistrationJob`] deadlines.
    pub fn set_deadline(&mut self, deadline: Option<Instant>) -> &mut Self {
        self.deadline = deadline;
        self
    }

    /// `align()`: run the hybrid ICP loop and return the final transform.
    ///
    /// Host/device split per iteration (paper Fig. 2):
    /// * device: transform source by the *cumulative* T, NN search,
    ///   correspondence filtering, accumulator reduction;
    /// * host: Kabsch/SVD on the 3×3 covariance, convergence check,
    ///   T ← T_j·T.
    pub fn align(&mut self) -> Result<FppsResult> {
        let t_start = Instant::now();
        // Cheap `Arc` clones so the borrows don't pin `self` (staging
        // below mutates other fields); no points are copied.
        let source = Arc::clone(self.source.as_ref().context("setInputSource not called")?);
        let target = Arc::clone(self.target.as_ref().context("setInputTarget not called")?);
        if source.is_empty() || target.is_empty() {
            bail!("source/target cloud is empty");
        }

        // Capacity selection is per-workload (the artifact variant can
        // change with the source size), but a staged target only depends
        // on the target capacity — an unchanged (target, cap_m) pair
        // survives across alignments with different sources.
        let (cap_n, cap_m, ..) = self.backend.select_capacity(source.len(), target.len())?;

        // Find (or build) the staged entry for this target cloud.
        // Pointer equality first (free for shared maps), full content
        // compare otherwise — a false "changed" only costs a re-upload,
        // but a false "unchanged" would corrupt results, so content
        // equality is exact; the fingerprint is only the residency key.
        let pos = self
            .staged_targets
            .iter()
            .position(|s| Arc::ptr_eq(&s.cloud, &target) || *s.cloud == *target);
        let mut entry = match pos {
            Some(i) => self.staged_targets.remove(i),
            None => {
                // Cold target: draw staging buffers from the arena (a
                // buffer recycled from an evicted staging of the same
                // class costs no allocation) and pad in place.
                let mut tgt = self.pool.acquire(cap_m * 3);
                let mut tgt_mask = self.pool.acquire(cap_m);
                pad_into(&target.xyz, cap_m, &mut tgt, &mut tgt_mask);
                StagedTarget {
                    cloud: Arc::clone(&target),
                    key: target.fingerprint(),
                    tgt,
                    tgt_mask,
                    cap_m,
                    epoch: None,
                }
            }
        };
        if entry.cap_m != cap_m {
            // Capacity selection changed (e.g. a different artifact
            // variant): refill the staged buffers in place instead of
            // dropping and rebuilding them.
            pad_into(&target.xyz, cap_m, &mut entry.tgt, &mut entry.tgt_mask);
            entry.cap_m = cap_m;
            entry.epoch = None;
            self.target_repads += 1;
        }

        // Target half of the Fig. 2 DMA: skipped when the device still
        // holds this exact (key, epoch) resident — re-activating a
        // cached slot costs nothing. Scan-to-map localization uploads
        // its map once; a tile ping-pong uploads once per tile (up to
        // the backend's slot count) instead of once per alignment.
        match entry.epoch {
            Some(e) if self.backend.activate_target(entry.key) == Some(e) => {
                self.target_cache_hits += 1;
            }
            _ => {
                entry.epoch = Some(self.backend.upload_target_keyed(
                    entry.key,
                    &entry.tgt,
                    &entry.tgt_mask,
                )?);
                self.target_uploads += 1;
            }
        }

        // MRU staging order mirrors the backend's LRU set; staged
        // paddings past the slot count can never hit again, so drop them.
        self.staged_targets.push(entry);
        let slots = self.backend.residency_slots().max(1);
        if self.staged_targets.len() > slots {
            let excess = self.staged_targets.len() - slots;
            self.staged_targets.drain(0..excess);
        }

        // Source half: once per alignment; iterations then only ship the
        // 4×4 transform + threshold. The staging pair persists across
        // alignments and is refilled in place — zero heap traffic once
        // its capacity class is warm.
        if self.src_stage.is_none() {
            self.src_stage = Some((self.pool.acquire(cap_n * 3), self.pool.acquire(cap_n)));
        }
        {
            let (src, src_mask) = self.src_stage.as_mut().expect("staged above");
            pad_into(&source.xyz, cap_n, src, src_mask);
            self.backend.upload_source(src, src_mask)?;
        }

        let max_d2 = self.max_correspondence_distance * self.max_correspondence_distance;
        let mut cumulative = self.initial_transform;
        // Recycled via `recycle_stats` by hot-loop callers; empty (but
        // capacity-bearing) after `take`.
        let mut stats = std::mem::take(&mut self.stats_scratch);
        let mut stop = StopReason::MaxIterations;
        let mut rmse = f64::NAN;
        let mut iterations = 0;
        for _ in 0..self.max_iteration_count {
            if self.deadline.is_some_and(|d| Instant::now() >= d) {
                stop = StopReason::DeadlineExceeded;
                break;
            }
            iterations += 1;
            let acc = self.backend.step(&cumulative, max_d2)?;
            // A non-finite accumulator is device/transport corruption,
            // never a data-quality signal: NaN sums would otherwise leak
            // through as a bogus TooFewCorrespondences stop (the Kabsch
            // guards reject NaN covariance), silently misclassifying an
            // infrastructure fault. Fail the alignment so the caller can
            // contain or retry it.
            if !acc.is_finite() {
                bail!(
                    "backend {} returned non-finite step accumulators \
                     (corrupted transform/reduction)",
                    self.backend.name()
                );
            }
            if acc.count < 3.0 {
                stop = StopReason::TooFewCorrespondences;
                break;
            }
            rmse = acc.rmse();
            let Some(est) = kabsch_from_sums(acc.count, acc.sum_p, acc.sum_q, &acc.sum_pq)
            else {
                stop = StopReason::TooFewCorrespondences;
                break;
            };
            let t_j = est.to_mat4();
            cumulative = t_j.mul_mat(&cumulative);
            let delta = t_j.delta_from_identity();
            stats.push(FppsIterationStat {
                correspondences: acc.count,
                rmse,
                delta,
            });
            if delta < self.transformation_epsilon {
                stop = StopReason::Converged;
                break;
            }
        }

        Ok(FppsResult {
            transformation: cumulative,
            rmse,
            iterations,
            stop,
            stats,
            total_time: t_start.elapsed(),
            device_time: self.backend.device_time(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::{Mat3, Vec3};
    use crate::rng::Pcg32;

    fn structured_cloud(n: usize, seed: u64) -> PointCloud {
        let mut rng = Pcg32::new(seed);
        let mut c = PointCloud::with_capacity(n);
        for i in 0..n {
            match i % 3 {
                0 => c.push([rng.range(-5.0, 5.0), rng.range(-5.0, 5.0), 0.0]),
                1 => c.push([rng.range(-5.0, 5.0), 5.0, rng.range(0.0, 3.0)]),
                _ => c.push([-5.0, rng.range(-5.0, 5.0), rng.range(0.0, 3.0)]),
            }
        }
        c
    }

    #[test]
    fn native_sim_recovers_transform() {
        let target = structured_cloud(900, 1);
        let gt = Mat4::from_rt(Mat3::rot_z(0.04), Vec3::new(0.2, -0.1, 0.02));
        let source = target.transformed(&gt.inverse_rigid());
        let mut icp = FppsIcp::native_sim();
        icp.set_input_source(source)
            .set_input_target(target)
            .set_max_correspondence_distance(1.0)
            .set_max_iteration_count(50)
            .set_transformation_epsilon(1e-5);
        let res = icp.align().unwrap();
        assert!(res.has_converged());
        let rerr = res.transformation.rotation().rotation_angle_to(&gt.rotation());
        let terr = (res.transformation.translation() - gt.translation()).norm();
        assert!(rerr < 2e-3, "rotation err {rerr}");
        assert!(terr < 2e-2, "translation err {terr}");
    }

    #[test]
    fn matches_cpu_baseline_within_001m() {
        // Table III claim: FPGA vs CPU RMSE differs < 0.01 m.
        let target = structured_cloud(1000, 2);
        let gt = Mat4::from_rt(Mat3::rot_z(-0.03), Vec3::new(-0.15, 0.25, 0.01));
        let mut source = target.transformed(&gt.inverse_rigid());
        let mut rng = Pcg32::new(3);
        source.add_noise(0.01, &mut rng);

        let cpu = crate::icp::align(
            &source,
            &target,
            &Mat4::IDENTITY,
            &crate::icp::IcpParams::default(),
        );
        let mut icp = FppsIcp::native_sim();
        icp.set_input_source(source).set_input_target(target);
        let fpps = icp.align().unwrap();
        assert!(
            (cpu.rmse - fpps.rmse).abs() < 0.01,
            "cpu {} vs fpps {}",
            cpu.rmse,
            fpps.rmse
        );
        let dt = (cpu.transformation.translation() - fpps.transformation.translation()).norm();
        assert!(dt < 0.01, "translation differs {dt}");
    }

    #[test]
    fn initial_transform_honored() {
        let target = structured_cloud(600, 4);
        let gt = Mat4::from_rt(Mat3::rot_z(0.05), Vec3::new(0.3, 0.0, 0.0));
        let source = target.transformed(&gt.inverse_rigid());
        let mut icp = FppsIcp::native_sim();
        icp.set_input_source(source)
            .set_input_target(target)
            .set_transformation_matrix(gt);
        let res = icp.align().unwrap();
        assert!(res.iterations <= 2, "should converge from the answer");
    }

    #[test]
    fn api_validates_inputs() {
        let mut icp = FppsIcp::native_sim();
        assert!(icp.align().is_err(), "no clouds set");
        icp.set_input_source(structured_cloud(10, 5));
        assert!(icp.align().is_err(), "no target set");
        icp.set_input_target(PointCloud::new());
        assert!(icp.align().is_err(), "empty target");
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn rejects_nonpositive_distance() {
        FppsIcp::native_sim().set_max_correspondence_distance(0.0);
    }

    #[test]
    fn disjoint_clouds_flagged() {
        let a = structured_cloud(100, 6);
        let mut b = structured_cloud(100, 7);
        for v in b.xyz.iter_mut() {
            *v += 500.0;
        }
        let mut icp = FppsIcp::native_sim();
        icp.set_input_source(a).set_input_target(b);
        let res = icp.align().unwrap();
        assert_eq!(res.stop, StopReason::TooFewCorrespondences);
    }

    #[test]
    fn kdtree_cpu_backend_recovers_transform() {
        let target = structured_cloud(900, 21);
        let gt = Mat4::from_rt(Mat3::rot_z(0.03), Vec3::new(0.15, -0.2, 0.01));
        let source = target.transformed(&gt.inverse_rigid());
        let mut icp = FppsIcp::kdtree_cpu();
        icp.set_input_source(source).set_input_target(target);
        let res = icp.align().unwrap();
        assert!(res.has_converged());
        assert_eq!(icp.backend().name(), "kdtree-cpu");
        let terr = (res.transformation.translation() - gt.translation()).norm();
        assert!(terr < 2e-2, "translation err {terr}");
    }

    #[test]
    fn kdtree_and_native_sim_agree_within_table3_margin() {
        let target = structured_cloud(800, 22);
        let gt = Mat4::from_rt(Mat3::rot_z(-0.02), Vec3::new(0.1, 0.15, 0.0));
        let mut source = target.transformed(&gt.inverse_rigid());
        let mut rng = Pcg32::new(23);
        source.add_noise(0.01, &mut rng);

        let mut a = FppsIcp::kdtree_cpu();
        a.set_input_source(source.clone()).set_input_target(target.clone());
        let ra = a.align().unwrap();
        let mut b = FppsIcp::native_sim();
        b.set_input_source(source).set_input_target(target);
        let rb = b.align().unwrap();
        assert!((ra.rmse - rb.rmse).abs() < 0.01, "{} vs {}", ra.rmse, rb.rmse);
        let dt = (ra.transformation.translation() - rb.transformation.translation()).norm();
        assert!(dt < 0.01, "translations differ by {dt}");
    }

    #[test]
    fn backend_kind_parses() {
        assert_eq!("auto".parse::<BackendKind>().unwrap(), BackendKind::Auto);
        assert_eq!("xla".parse::<BackendKind>().unwrap(), BackendKind::Xla);
        assert_eq!(
            "native-sim".parse::<BackendKind>().unwrap(),
            BackendKind::NativeSim
        );
        assert_eq!(
            "kdtree".parse::<BackendKind>().unwrap(),
            BackendKind::KdTreeCpu
        );
        assert!("fpga".parse::<BackendKind>().is_err());
    }

    #[test]
    fn backend_handle_auto_falls_back_without_artifacts() {
        let dir = Path::new("definitely/not/an/artifact/dir");
        let handle = BackendHandle::create(BackendKind::Auto, dir).unwrap();
        assert_eq!(handle.name(), "native-sim");
        // Explicit XLA request must error with an actionable message.
        let err = BackendHandle::create(BackendKind::Xla, dir).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("manifest"), "{msg}");
    }

    #[test]
    fn backend_handle_aligns_like_its_inner_backend() {
        let target = structured_cloud(700, 24);
        let gt = Mat4::from_rt(Mat3::rot_z(0.02), Vec3::new(0.2, 0.0, 0.0));
        let source = target.transformed(&gt.inverse_rigid());

        let mut via_handle = FppsIcp::with_backend(
            BackendHandle::create(BackendKind::NativeSim, Path::new("artifacts")).unwrap(),
        );
        via_handle
            .set_input_source(source.clone())
            .set_input_target(target.clone());
        let a = via_handle.align().unwrap();

        let mut direct = FppsIcp::native_sim();
        direct.set_input_source(source).set_input_target(target);
        let b = direct.align().unwrap();

        // Same backend, same inputs → bit-identical outputs.
        assert_eq!(a.transformation.m, b.transformation.m);
        assert_eq!(a.rmse.to_bits(), b.rmse.to_bits());
        assert_eq!(a.iterations, b.iterations);
    }

    #[test]
    fn unchanged_target_skips_reupload_and_matches_fresh() {
        let target = structured_cloud(700, 30);
        let gt = Mat4::from_rt(Mat3::rot_z(0.02), Vec3::new(0.1, -0.05, 0.0));
        let sources: Vec<PointCloud> = (0..4)
            .map(|k| {
                let mut rng = Pcg32::new(40 + k);
                let mut s = target.transformed(&gt.inverse_rigid());
                s.add_noise(0.005, &mut rng);
                s
            })
            .collect();

        // Cached: one session, same target across all aligns.
        let mut cached = FppsIcp::native_sim();
        let mut cached_results = Vec::new();
        for s in &sources {
            cached.set_input_source(s.clone());
            cached.set_input_target(target.clone());
            cached_results.push(cached.align().unwrap());
        }
        let (uploads, hits, repads) = cached.target_cache_stats();
        assert_eq!(uploads, 1, "one upload for an unchanged target");
        assert_eq!(hits, 3);
        assert_eq!(repads, 0);

        // Fresh: a new session per align (always re-uploads).
        for (s, c) in sources.iter().zip(&cached_results) {
            let mut fresh = FppsIcp::native_sim();
            fresh.set_input_source(s.clone());
            fresh.set_input_target(target.clone());
            let f = fresh.align().unwrap();
            assert_eq!(f.transformation.m, c.transformation.m);
            assert_eq!(f.rmse.to_bits(), c.rmse.to_bits());
            assert_eq!(f.iterations, c.iterations);
        }
    }

    #[test]
    fn kdtree_builds_once_per_target_epoch() {
        let target_a = structured_cloud(600, 31);
        let target_b = structured_cloud(600, 32);
        let source = target_a.transformed(
            &Mat4::from_rt(Mat3::rot_z(0.01), Vec3::new(0.05, 0.0, 0.0)).inverse_rigid(),
        );
        let mut icp = FppsIcp::kdtree_cpu();
        assert!(
            icp.backend().residency_slots() >= 2,
            "hwmodel budget grants multi-target residency by default"
        );
        for _ in 0..3 {
            icp.set_input_source(source.clone());
            icp.set_input_target(target_a.clone());
            icp.align().unwrap();
        }
        assert_eq!(icp.backend().tree_builds(), 1, "built once");
        // A genuinely different target builds its own resident tree.
        icp.set_input_source(source.clone());
        icp.set_input_target(target_b.clone());
        icp.align().unwrap();
        assert_eq!(icp.backend().tree_builds(), 2);
        // Returning to A re-activates the still-resident slot — no
        // rebuild (the pre-LRU single-slot backend paid a third build).
        icp.set_input_source(source.clone());
        icp.set_input_target(target_a.clone());
        icp.align().unwrap();
        assert_eq!(icp.backend().tree_builds(), 2, "LRU keeps A resident");

        // A single-slot backend reproduces the old thrash exactly.
        let mut single = FppsIcp::with_backend(KdTreeCpuBackend::with_residency_slots(1));
        for tgt in [&target_a, &target_b, &target_a] {
            single.set_input_source(source.clone());
            single.set_input_target(tgt.clone());
            single.align().unwrap();
        }
        assert_eq!(single.backend().tree_builds(), 3, "one slot: every switch rebuilds");
    }

    #[test]
    fn lru_evicts_least_recently_used_target() {
        // Three targets through a two-slot backend: uploading C evicts A
        // (LRU), so returning to A re-uploads while B and C stay hits.
        let targets: Vec<PointCloud> =
            (0..3).map(|k| structured_cloud(400, 34 + k)).collect();
        let source = targets[0].transformed(
            &Mat4::from_rt(Mat3::rot_z(0.01), Vec3::new(0.05, 0.0, 0.0)).inverse_rigid(),
        );
        let mut icp = FppsIcp::with_backend(NativeSimBackend::with_residency_slots(2));
        let run = |icp: &mut FppsIcp<NativeSimBackend>, t: &PointCloud| {
            icp.set_input_source(source.clone());
            icp.set_input_target(t.clone());
            icp.align().unwrap();
        };
        run(&mut icp, &targets[0]); // upload A          resident {A}
        run(&mut icp, &targets[1]); // upload B          resident {A,B}
        run(&mut icp, &targets[1]); // hit B             resident {A,B}
        run(&mut icp, &targets[2]); // upload C, evict A resident {B,C}
        run(&mut icp, &targets[1]); // hit B             resident {C,B}
        run(&mut icp, &targets[0]); // A was evicted → re-upload, evict C
        let (uploads, hits, _) = icp.target_cache_stats();
        assert_eq!((uploads, hits), (4, 2));
        let resident: Vec<u64> = icp
            .backend()
            .resident_epochs()
            .iter()
            .map(|(k, _)| *k)
            .collect();
        assert_eq!(resident.len(), 2);
        assert_eq!(resident[0], targets[0].fingerprint(), "A is MRU");
        assert_eq!(resident[1], targets[1].fingerprint(), "B still resident");
    }

    #[test]
    fn resident_set_is_keyed_and_bounded() {
        let mut b = NativeSimBackend::with_residency_slots(2);
        assert_eq!(b.residency_slots(), 2);
        assert!(b.resident_epochs().is_empty());
        let tgt = vec![0.25f32; 4 * 3];
        let mask = vec![1f32; 4];
        let ea = b.upload_target_keyed(1, &tgt, &mask).unwrap();
        let eb = b.upload_target_keyed(2, &tgt, &mask).unwrap();
        assert_eq!(b.target_epoch(), Some(eb), "upload activates its key");
        // Re-activating key 1 is free and makes it MRU again.
        assert_eq!(b.activate_target(1), Some(ea));
        assert_eq!(b.target_epoch(), Some(ea));
        // Capacity pressure evicts the LRU key (2, not 1).
        let _ec = b.upload_target_keyed(3, &tgt, &mask).unwrap();
        assert_eq!(b.activate_target(2), None, "evicted");
        assert_eq!(b.activate_target(1), Some(ea), "survivor");
        assert_eq!(
            b.resident_epochs().iter().map(|(k, _)| *k).collect::<Vec<_>>(),
            vec![1, 3]
        );
        // Unknown key leaves the active target untouched.
        assert_eq!(b.activate_target(99), None);
        assert_eq!(b.target_epoch(), Some(ea));
        // Shrinking to one slot keeps only the MRU entry.
        b.set_residency_slots(1);
        assert_eq!(b.activate_target(3), None);
        assert_eq!(b.target_epoch(), Some(ea));
    }

    #[test]
    fn eviction_and_free_slot_telemetry() {
        let mut b = NativeSimBackend::with_residency_slots(2);
        assert_eq!(b.free_slots(), 2);
        assert_eq!(b.target_evictions(), 0);
        let tgt = vec![0.25f32; 4 * 3];
        let mask = vec![1f32; 4];
        b.upload_target_keyed(1, &tgt, &mask).unwrap();
        assert_eq!(b.free_slots(), 1);
        b.upload_target_keyed(2, &tgt, &mask).unwrap();
        assert_eq!((b.free_slots(), b.target_evictions()), (0, 0));
        // Capacity pressure evicts and counts.
        b.upload_target_keyed(3, &tgt, &mask).unwrap();
        assert_eq!((b.free_slots(), b.target_evictions()), (0, 1));
        // Re-uploading a resident key replaces in place — no eviction.
        b.upload_target_keyed(3, &tgt, &mask).unwrap();
        assert_eq!(b.target_evictions(), 1);
        // Shrinking the slot count evicts (and counts) the overflow.
        b.set_residency_slots(1);
        assert_eq!((b.free_slots(), b.target_evictions()), (0, 2));
    }

    #[test]
    fn shared_map_via_arc_hits_pointer_fast_path() {
        let map = Arc::new(structured_cloud(800, 33));
        let mut icp = FppsIcp::native_sim();
        for k in 0..3u64 {
            let source = map.random_sample(400, &mut Pcg32::new(50 + k));
            icp.set_input_source(source);
            icp.set_input_target(Arc::clone(&map));
            icp.align().unwrap();
        }
        let (uploads, hits, _) = icp.target_cache_stats();
        assert_eq!((uploads, hits), (1, 2));
    }

    /// NativeSim wrapper whose `cap_m` depends on the *source* size —
    /// modelling the XLA artifact-variant switch that changes capacity
    /// selection for an unchanged target (the staged re-pad path).
    struct VariantCapBackend(NativeSimBackend);

    impl KernelBackend for VariantCapBackend {
        fn name(&self) -> &'static str {
            "variant-cap-sim"
        }
        fn select_capacity(
            &self,
            n_source: usize,
            n_target: usize,
        ) -> Result<(usize, usize, usize, usize)> {
            let (cap_n, _, block_n, block_m) = self.0.select_capacity(n_source, n_target)?;
            // Small sources pick a tighter target quantum than large
            // ones, like per-variant padded shapes in the AOT manifest.
            // Both quanta are multiples of the sim's block_m so the
            // mirror's shape contract holds.
            let quantum = if n_source <= 256 { 64 } else { 192 };
            Ok((cap_n, n_target.div_ceil(quantum) * quantum, block_n, block_m))
        }
        fn residency_slots(&self) -> usize {
            self.0.residency_slots()
        }
        fn set_residency_slots(&mut self, slots: usize) {
            self.0.set_residency_slots(slots)
        }
        fn upload_target_keyed(
            &mut self,
            key: u64,
            tgt: &[f32],
            tgt_mask: &[f32],
        ) -> Result<TargetEpoch> {
            self.0.upload_target_keyed(key, tgt, tgt_mask)
        }
        fn activate_target(&mut self, key: u64) -> Option<TargetEpoch> {
            self.0.activate_target(key)
        }
        fn target_epoch(&self) -> Option<TargetEpoch> {
            self.0.target_epoch()
        }
        fn resident_epochs(&self) -> Vec<(u64, TargetEpoch)> {
            self.0.resident_epochs()
        }
        fn target_evictions(&self) -> u64 {
            self.0.target_evictions()
        }
        fn upload_source(&mut self, src: &[f32], src_mask: &[f32]) -> Result<()> {
            self.0.upload_source(src, src_mask)
        }
        fn step(&mut self, transform: &Mat4, max_dist_sq: f32) -> Result<StepAccumulators> {
            self.0.step(transform, max_dist_sq)
        }
        fn device_time(&self) -> Duration {
            self.0.device_time()
        }
    }

    #[test]
    fn capacity_change_repads_staged_target_in_place() {
        let target = Arc::new(structured_cloud(500, 60));
        // 500 target points: quantum 64 → cap_m 512; quantum 192 → 576.
        let small = target.random_sample(200, &mut Pcg32::new(61));
        let big = target.random_sample(400, &mut Pcg32::new(62));

        let mut icp =
            FppsIcp::with_backend(VariantCapBackend(NativeSimBackend::with_blocks(64, 64)));
        icp.set_input_source(small.clone());
        icp.set_input_target(Arc::clone(&target));
        icp.align().unwrap();
        assert_eq!(icp.target_cache_stats(), (1, 0, 0));

        // Same target, bigger source → different variant → new cap_m:
        // the staged buffers are refilled in place (counted as a
        // re-pad, not a rebuild), then re-uploaded under a fresh epoch.
        icp.set_input_source(big.clone());
        icp.set_input_target(Arc::clone(&target));
        let repadded = icp.align().unwrap();
        assert_eq!(icp.target_cache_stats(), (2, 0, 1));

        // Ping back to the small variant: re-pad again.
        icp.set_input_source(small);
        icp.set_input_target(Arc::clone(&target));
        icp.align().unwrap();
        assert_eq!(icp.target_cache_stats(), (3, 0, 2));

        // Re-pads preserve numerics: a fresh session at the same
        // capacity produces bit-identical results.
        let mut fresh =
            FppsIcp::with_backend(VariantCapBackend(NativeSimBackend::with_blocks(64, 64)));
        fresh.set_input_source(big);
        fresh.set_input_target(Arc::clone(&target));
        let f = fresh.align().unwrap();
        assert_eq!(f.transformation.m, repadded.transformation.m);
        assert_eq!(f.rmse.to_bits(), repadded.rmse.to_bits());

        // In-place refills draw nothing new from the arena: the pool
        // only ever served the four initial stagings (tgt + mask,
        // src + mask) and never grew again across the variant flips.
        let stats = icp.buffer_pool().stats();
        assert_eq!(stats.acquires, 4);
        assert_eq!(stats.grows, 4);
        assert_eq!(stats.recycles, 0);
    }

    #[test]
    fn epoch_tracks_actual_uploads() {
        let mut b = NativeSimBackend::with_blocks(4, 4);
        assert!(b.target_epoch().is_none());
        let tgt = vec![0.5f32; 4 * 3];
        let mask = vec![1f32; 4];
        let e1 = b.upload_target(&tgt, &mask).unwrap();
        assert_eq!(b.target_epoch(), Some(e1));
        let e2 = b.upload_target(&tgt, &mask).unwrap();
        assert_ne!(e1, e2, "every upload mints a fresh epoch");
        assert_eq!(b.target_epoch(), Some(e2));
    }

    #[test]
    fn iteration_stats_populated() {
        let target = structured_cloud(500, 8);
        let gt = Mat4::from_rt(Mat3::rot_z(0.02), Vec3::new(0.1, 0.1, 0.0));
        let source = target.transformed(&gt.inverse_rigid());
        let mut icp = FppsIcp::native_sim();
        icp.set_input_source(source).set_input_target(target);
        let res = icp.align().unwrap();
        assert_eq!(res.stats.len() as u32, res.iterations);
        for s in &res.stats {
            assert!(s.correspondences >= 3.0);
            assert!(s.rmse.is_finite());
        }
        assert!(res.device_time > Duration::ZERO);
    }
}
