//! The FPPS public API — Table I of the paper, PCL-style.
//!
//! ```no_run
//! use fpps::fpps_api::FppsIcp;
//! use fpps::pointcloud::PointCloud;
//!
//! let mut icp = FppsIcp::hardware_initialize("artifacts".as_ref()).unwrap();
//! icp.set_input_source(PointCloud::new());
//! icp.set_input_target(PointCloud::new());
//! icp.set_max_correspondence_distance(1.0);
//! icp.set_max_iteration_count(50);
//! icp.set_transformation_epsilon(1e-5);
//! let result = icp.align().unwrap();
//! println!("T = {:?}", result.transformation);
//! ```
//!
//! | Paper (Table I)                  | Here                                |
//! |----------------------------------|-------------------------------------|
//! | `hardwareInitialize()`           | [`FppsIcp::hardware_initialize`]    |
//! | `setTransformationMatrix()`      | [`FppsIcp::set_transformation_matrix`] |
//! | `setInputSource()`               | [`FppsIcp::set_input_source`]       |
//! | `setInputTarget()`               | [`FppsIcp::set_input_target`]       |
//! | `setMaxCorrespondenceDistance()` | [`FppsIcp::set_max_correspondence_distance`] |
//! | `setMaxIterationCount()`         | [`FppsIcp::set_max_iteration_count`]|
//! | `setTransformationEpsilon()`     | [`FppsIcp::set_transformation_epsilon`] |
//! | `align()`                        | [`FppsIcp::align`]                  |
//!
//! The device is abstracted behind [`KernelBackend`]: [`XlaBackend`]
//! runs the AOT artifact on PJRT (the production path),
//! [`NativeSimBackend`] is a bit-faithful pure-rust mirror used for
//! tests and artifact-less environments, and [`KdTreeCpuBackend`] is the
//! exact kd-tree CPU path behind the same interface. Backends are
//! selectable at *runtime* through [`BackendHandle`] / [`BackendKind`]
//! (the multi-lane coordinator instantiates one backend per lane), so
//! nothing above this layer is monomorphised to a single device.

use crate::icp::StopReason;
use crate::kdtree::OwnedKdTree;
use crate::math::{kabsch_from_sums, Mat4, Vec3};
use crate::nn::{self, KernelConfig};
use crate::pointcloud::PointCloud;
use crate::runtime::{Engine, StepAccumulators};
use anyhow::{bail, Context, Result};
use std::path::Path;
use std::time::{Duration, Instant};

/// Device abstraction: one ICP step (transform → NN → accumulate) on
/// padded, fixed-capacity buffers.
pub trait KernelBackend {
    /// Human-readable backend name (for logs / benches).
    fn name(&self) -> &'static str;

    /// Capacity selection: (n_capacity, m_capacity, block_n, block_m)
    /// for a workload of (n_source, n_target); error if it cannot fit.
    fn select_capacity(&self, n_source: usize, n_target: usize)
        -> Result<(usize, usize, usize, usize)>;

    /// Upload one alignment's padded clouds + masks to the device —
    /// the paper's host→HBM DMA, done once per `align()` call. Buffer
    /// sizes must match a capacity from [`Self::select_capacity`].
    fn begin(
        &mut self,
        src: &[f32],
        tgt: &[f32],
        src_mask: &[f32],
        tgt_mask: &[f32],
    ) -> Result<()>;

    /// One ICP iteration over the clouds uploaded by [`Self::begin`]:
    /// only the cumulative transform + threshold travel to the device.
    fn step(&mut self, transform: &Mat4, max_dist_sq: f32) -> Result<StepAccumulators>;

    /// Convenience: `begin` + one `step` (tests, one-shot callers).
    #[allow(clippy::too_many_arguments)]
    fn icp_step(
        &mut self,
        src: &[f32],
        tgt: &[f32],
        src_mask: &[f32],
        tgt_mask: &[f32],
        transform: &Mat4,
        max_dist_sq: f32,
    ) -> Result<StepAccumulators> {
        self.begin(src, tgt, src_mask, tgt_mask)?;
        self.step(transform, max_dist_sq)
    }

    /// Cumulative device-side execution time (telemetry).
    fn device_time(&self) -> Duration;
}

/// Production backend: AOT artifact on the PJRT CPU client.
pub struct XlaBackend {
    engine: Engine,
    prepared: Option<crate::runtime::PreparedClouds>,
    device_time: Duration,
}

impl XlaBackend {
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        if !artifacts_dir.join("manifest.txt").exists() {
            bail!(
                "no artifact manifest at {}/manifest.txt — the AOT compile step is \
                 python-side: run `python python/compile/aot.py` first, or use the \
                 native-sim backend, which needs no artifacts",
                artifacts_dir.display()
            );
        }
        Ok(Self {
            engine: Engine::load(artifacts_dir).with_context(|| {
                format!(
                    "initialise the PJRT engine from {} (hardwareInitialize)",
                    artifacts_dir.display()
                )
            })?,
            prepared: None,
            device_time: Duration::ZERO,
        })
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }
}

impl KernelBackend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla-pjrt"
    }

    fn select_capacity(
        &self,
        n_source: usize,
        n_target: usize,
    ) -> Result<(usize, usize, usize, usize)> {
        let v = self
            .engine
            .manifest()
            .select(n_source, n_target)
            .with_context(|| {
                format!("no artifact variant fits {n_source} source x {n_target} target points")
            })?;
        Ok((v.n, v.m, v.block_n, v.block_m))
    }

    fn begin(
        &mut self,
        src: &[f32],
        tgt: &[f32],
        src_mask: &[f32],
        tgt_mask: &[f32],
    ) -> Result<()> {
        // Re-resolve the variant for the padded shape (cheap lookup),
        // then DMA the clouds into device-resident buffers once.
        let n = src.len() / 3;
        let m = tgt.len() / 3;
        let vi = self
            .engine
            .manifest()
            .variants
            .iter()
            .position(|v| v.n == n && v.m == m)
            .with_context(|| format!("no variant with exact capacity {n}x{m}"))?;
        self.prepared = Some(self.engine.prepare(vi, src, tgt, src_mask, tgt_mask)?);
        Ok(())
    }

    fn step(&mut self, transform: &Mat4, max_dist_sq: f32) -> Result<StepAccumulators> {
        let prep = self
            .prepared
            .as_ref()
            .context("step() before begin(): no clouds on device")?;
        let (acc, timing) = self.engine.execute_prepared(prep, transform, max_dist_sq)?;
        self.device_time += timing.execute;
        Ok(acc)
    }

    fn device_time(&self) -> Duration {
        self.device_time
    }
}

/// Bit-faithful software mirror of the device kernel (see
/// [`nn::kernel_mirror`]); pads to the same block structure and applies
/// the same accumulation semantics.
pub struct NativeSimBackend {
    cfg: KernelConfig,
    device_time: Duration,
    /// Clouds "uploaded" by begin() (the mirror of the HBM buffers).
    state: Option<SimClouds>,
}

struct SimClouds {
    src: Vec<f32>,
    tgt: Vec<f32>,
    src_mask: Vec<f32>,
    tgt_mask: Vec<f32>,
}

impl NativeSimBackend {
    pub fn new() -> Self {
        Self {
            cfg: KernelConfig::default(),
            device_time: Duration::ZERO,
            state: None,
        }
    }

    pub fn with_blocks(block_n: usize, block_m: usize) -> Self {
        Self {
            cfg: KernelConfig { block_n, block_m },
            device_time: Duration::ZERO,
            state: None,
        }
    }
}

impl Default for NativeSimBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl KernelBackend for NativeSimBackend {
    fn name(&self) -> &'static str {
        "native-sim"
    }

    fn select_capacity(
        &self,
        n_source: usize,
        n_target: usize,
    ) -> Result<(usize, usize, usize, usize)> {
        let n = n_source.div_ceil(self.cfg.block_n).max(1) * self.cfg.block_n;
        let m = n_target.div_ceil(self.cfg.block_m).max(1) * self.cfg.block_m;
        Ok((n, m, self.cfg.block_n, self.cfg.block_m))
    }

    fn begin(
        &mut self,
        src: &[f32],
        tgt: &[f32],
        src_mask: &[f32],
        tgt_mask: &[f32],
    ) -> Result<()> {
        self.state = Some(SimClouds {
            src: src.to_vec(),
            tgt: tgt.to_vec(),
            src_mask: src_mask.to_vec(),
            tgt_mask: tgt_mask.to_vec(),
        });
        Ok(())
    }

    fn step(&mut self, transform: &Mat4, max_dist_sq: f32) -> Result<StepAccumulators> {
        let state = self
            .state
            .take()
            .context("step() before begin(): no clouds uploaded")?;
        let (src, tgt, src_mask, tgt_mask) =
            (&state.src, &state.tgt, &state.src_mask, &state.tgt_mask);
        let t0 = Instant::now();
        let n = src.len() / 3;
        // Stage 1: point cloud transformer (f32, like the device).
        let tm = transform.to_f32_row_major();
        let mut p = vec![0f32; src.len()];
        for i in 0..n {
            let (x, y, z) = (src[3 * i], src[3 * i + 1], src[3 * i + 2]);
            p[3 * i] = tm[0] * x + tm[1] * y + tm[2] * z + tm[3];
            p[3 * i + 1] = tm[4] * x + tm[5] * y + tm[6] * z + tm[7];
            p[3 * i + 2] = tm[8] * x + tm[9] * y + tm[10] * z + tm[11];
        }
        // Stage 2+3: NN search (blockwise mirror).
        let res = nn::kernel_mirror(&p, tgt, tgt_mask, self.cfg);
        // Stage 4: result accumulation (f32 partials like the jnp sums).
        let mut count = 0f32;
        let mut sum_p = [0f32; 3];
        let mut sum_q = [0f32; 3];
        let mut sum_pq = [0f32; 9];
        let mut sum_d = 0f32;
        for i in 0..n {
            let w = src_mask[i] * if res.dist_sq[i] <= max_dist_sq { 1.0 } else { 0.0 };
            if w == 0.0 {
                continue;
            }
            let j = res.index[i] as usize;
            let pi = [p[3 * i], p[3 * i + 1], p[3 * i + 2]];
            let qj = [tgt[3 * j], tgt[3 * j + 1], tgt[3 * j + 2]];
            count += w;
            for a in 0..3 {
                sum_p[a] += w * pi[a];
                sum_q[a] += w * qj[a];
                for b in 0..3 {
                    sum_pq[a * 3 + b] += w * pi[a] * qj[b];
                }
            }
            sum_d += w * res.dist_sq[i];
        }
        let mut wire = Vec::with_capacity(17);
        wire.push(count);
        wire.extend_from_slice(&sum_p);
        wire.extend_from_slice(&sum_q);
        wire.extend_from_slice(&sum_pq);
        wire.push(sum_d);
        self.device_time += t0.elapsed();
        let acc = StepAccumulators::from_wire(&wire);
        self.state = Some(state);
        acc
    }

    fn device_time(&self) -> Duration {
        self.device_time
    }
}

/// Exact kd-tree CPU path behind the [`KernelBackend`] interface — the
/// PCL-style correspondence search as a third selectable device. Unlike
/// [`NativeSimBackend`] it accumulates in f64 (host precision) and needs
/// no padding, so its numerics match the `icp` CPU baseline rather than
/// the FPGA wire format; Table III shows the two agree to < 0.01 m.
pub struct KdTreeCpuBackend {
    device_time: Duration,
    state: Option<KdClouds>,
}

struct KdClouds {
    src: Vec<f32>,
    src_mask: Vec<f32>,
    /// Index over the unmasked target points only (masked padding is
    /// dropped at upload); built once per `begin()`, queried every step.
    tree: OwnedKdTree,
}

impl KdTreeCpuBackend {
    pub fn new() -> Self {
        Self {
            device_time: Duration::ZERO,
            state: None,
        }
    }
}

impl Default for KdTreeCpuBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl KernelBackend for KdTreeCpuBackend {
    fn name(&self) -> &'static str {
        "kdtree-cpu"
    }

    fn select_capacity(
        &self,
        n_source: usize,
        n_target: usize,
    ) -> Result<(usize, usize, usize, usize)> {
        // No block structure: exact capacities, no padding.
        Ok((n_source.max(1), n_target.max(1), 1, 1))
    }

    fn begin(
        &mut self,
        src: &[f32],
        tgt: &[f32],
        src_mask: &[f32],
        tgt_mask: &[f32],
    ) -> Result<()> {
        let m = tgt.len() / 3;
        if tgt_mask.len() != m || src_mask.len() != src.len() / 3 {
            bail!("mask sizes do not match cloud sizes");
        }
        let mut kept = PointCloud::with_capacity(m);
        for j in 0..m {
            if tgt_mask[j] > 0.0 {
                kept.push([tgt[3 * j], tgt[3 * j + 1], tgt[3 * j + 2]]);
            }
        }
        self.state = Some(KdClouds {
            src: src.to_vec(),
            src_mask: src_mask.to_vec(),
            tree: OwnedKdTree::build(kept),
        });
        Ok(())
    }

    fn step(&mut self, transform: &Mat4, max_dist_sq: f32) -> Result<StepAccumulators> {
        let state = self
            .state
            .as_ref()
            .context("step() before begin(): no clouds uploaded")?;
        let t0 = Instant::now();
        let n = state.src.len() / 3;
        // Transform in f32, like the device's point cloud transformer.
        let tm = transform.to_f32_row_major();
        let mut acc = StepAccumulators::default();
        for i in 0..n {
            if state.src_mask[i] == 0.0 {
                continue;
            }
            let (x, y, z) = (
                state.src[3 * i],
                state.src[3 * i + 1],
                state.src[3 * i + 2],
            );
            let p = [
                tm[0] * x + tm[1] * y + tm[2] * z + tm[3],
                tm[4] * x + tm[5] * y + tm[6] * z + tm[7],
                tm[8] * x + tm[9] * y + tm[10] * z + tm[11],
            ];
            // Bounded search: the threshold prunes the descent, and the
            // strict bound matches the `icp` CPU baseline's rejection.
            let Some(nb) = state.tree.nearest_within_sq(p, max_dist_sq) else {
                continue;
            };
            let q = state.tree.cloud().get(nb.index as usize);
            let pv = Vec3::from_f32(p);
            let qv = Vec3::from_f32(q);
            acc.count += 1.0;
            acc.sum_p = acc.sum_p + pv;
            acc.sum_q = acc.sum_q + qv;
            for a in 0..3 {
                for b in 0..3 {
                    let pa = [pv.x, pv.y, pv.z][a];
                    let qb = [qv.x, qv.y, qv.z][b];
                    acc.sum_pq.m[a][b] += pa * qb;
                }
            }
            acc.sum_sq_dist += nb.dist_sq as f64;
        }
        self.device_time += t0.elapsed();
        Ok(acc)
    }

    fn device_time(&self) -> Duration {
        self.device_time
    }
}

/// Which device implementation to run — parsed from `--backend` and from
/// `backend=` config keys, resolved by [`BackendHandle::create`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// XLA when artifacts load, otherwise fall back to NativeSim.
    Auto,
    Xla,
    NativeSim,
    KdTreeCpu,
}

impl std::str::FromStr for BackendKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "auto" => BackendKind::Auto,
            "xla" | "xla-pjrt" => BackendKind::Xla,
            "native-sim" | "sim" => BackendKind::NativeSim,
            "kdtree" | "kdtree-cpu" | "cpu" => BackendKind::KdTreeCpu,
            other => bail!(
                "unknown backend {other:?} (expected auto | xla | native-sim | kdtree)"
            ),
        })
    }
}

/// Runtime-selectable backend: one enum over every [`KernelBackend`]
/// implementation, so `FppsIcp<BackendHandle>` can switch devices per
/// process — or per *lane* in the multi-lane coordinator — without
/// monomorphising the whole stack per backend.
pub enum BackendHandle {
    Xla(Box<XlaBackend>),
    NativeSim(NativeSimBackend),
    KdTreeCpu(KdTreeCpuBackend),
}

impl BackendHandle {
    /// Resolve a [`BackendKind`] into a live backend. `Auto` prefers the
    /// AOT artifact path and falls back (with a note) to the bit-faithful
    /// NativeSim mirror when artifacts are absent or PJRT is unavailable,
    /// so artifact-less checkouts always work.
    pub fn create(kind: BackendKind, artifacts_dir: &Path) -> Result<BackendHandle> {
        match kind {
            BackendKind::Xla => Ok(BackendHandle::Xla(Box::new(XlaBackend::load(
                artifacts_dir,
            )?))),
            BackendKind::NativeSim => Ok(BackendHandle::NativeSim(NativeSimBackend::new())),
            BackendKind::KdTreeCpu => Ok(BackendHandle::KdTreeCpu(KdTreeCpuBackend::new())),
            BackendKind::Auto => {
                if artifacts_dir.join("manifest.txt").exists() {
                    match XlaBackend::load(artifacts_dir) {
                        Ok(b) => return Ok(BackendHandle::Xla(Box::new(b))),
                        Err(e) => eprintln!(
                            "note: XLA backend unavailable ({e:#}); using native-sim"
                        ),
                    }
                }
                Ok(BackendHandle::NativeSim(NativeSimBackend::new()))
            }
        }
    }
}

impl KernelBackend for BackendHandle {
    fn name(&self) -> &'static str {
        match self {
            BackendHandle::Xla(b) => b.name(),
            BackendHandle::NativeSim(b) => b.name(),
            BackendHandle::KdTreeCpu(b) => b.name(),
        }
    }

    fn select_capacity(
        &self,
        n_source: usize,
        n_target: usize,
    ) -> Result<(usize, usize, usize, usize)> {
        match self {
            BackendHandle::Xla(b) => b.select_capacity(n_source, n_target),
            BackendHandle::NativeSim(b) => b.select_capacity(n_source, n_target),
            BackendHandle::KdTreeCpu(b) => b.select_capacity(n_source, n_target),
        }
    }

    fn begin(
        &mut self,
        src: &[f32],
        tgt: &[f32],
        src_mask: &[f32],
        tgt_mask: &[f32],
    ) -> Result<()> {
        match self {
            BackendHandle::Xla(b) => b.begin(src, tgt, src_mask, tgt_mask),
            BackendHandle::NativeSim(b) => b.begin(src, tgt, src_mask, tgt_mask),
            BackendHandle::KdTreeCpu(b) => b.begin(src, tgt, src_mask, tgt_mask),
        }
    }

    fn step(&mut self, transform: &Mat4, max_dist_sq: f32) -> Result<StepAccumulators> {
        match self {
            BackendHandle::Xla(b) => b.step(transform, max_dist_sq),
            BackendHandle::NativeSim(b) => b.step(transform, max_dist_sq),
            BackendHandle::KdTreeCpu(b) => b.step(transform, max_dist_sq),
        }
    }

    fn device_time(&self) -> Duration {
        match self {
            BackendHandle::Xla(b) => b.device_time(),
            BackendHandle::NativeSim(b) => b.device_time(),
            BackendHandle::KdTreeCpu(b) => b.device_time(),
        }
    }
}

/// Per-iteration record of an FPPS alignment.
#[derive(Clone, Copy, Debug)]
pub struct FppsIterationStat {
    pub correspondences: f64,
    pub rmse: f64,
    pub delta: f64,
}

/// Result of [`FppsIcp::align`].
#[derive(Clone, Debug)]
pub struct FppsResult {
    pub transformation: Mat4,
    pub rmse: f64,
    pub iterations: u32,
    pub stop: StopReason,
    pub stats: Vec<FppsIterationStat>,
    pub total_time: Duration,
    /// Time spent inside the kernel backend.
    pub device_time: Duration,
}

impl FppsResult {
    pub fn has_converged(&self) -> bool {
        !matches!(self.stop, StopReason::TooFewCorrespondences)
    }
}

/// The FPPS ICP object (Table I).
pub struct FppsIcp<B: KernelBackend> {
    backend: B,
    source: Option<PointCloud>,
    target: Option<PointCloud>,
    initial_transform: Mat4,
    max_correspondence_distance: f32,
    max_iteration_count: u32,
    transformation_epsilon: f64,
    /// Prepared (padded) target + mask, rebuilt when the target changes.
    prepared_target: Option<PreparedTarget>,
}

struct PreparedTarget {
    tgt: Vec<f32>,
    tgt_mask: Vec<f32>,
    capacity: (usize, usize, usize, usize),
    n_source_hint: usize,
}

impl FppsIcp<XlaBackend> {
    /// `hardwareInitialize()`: open the device and load the bitstream
    /// (here: create the PJRT client and compile the AOT artifacts).
    pub fn hardware_initialize(artifacts_dir: &Path) -> Result<Self> {
        Ok(Self::with_backend(XlaBackend::load(artifacts_dir)?))
    }
}

impl FppsIcp<NativeSimBackend> {
    /// FPPS over the software device mirror (no artifacts needed).
    pub fn native_sim() -> Self {
        Self::with_backend(NativeSimBackend::new())
    }
}

impl FppsIcp<KdTreeCpuBackend> {
    /// FPPS over the exact kd-tree CPU path.
    pub fn kdtree_cpu() -> Self {
        Self::with_backend(KdTreeCpuBackend::new())
    }
}

impl FppsIcp<BackendHandle> {
    /// FPPS over a runtime-selected backend (see [`BackendHandle::create`]).
    pub fn with_kind(kind: BackendKind, artifacts_dir: &Path) -> Result<Self> {
        Ok(Self::with_backend(BackendHandle::create(
            kind,
            artifacts_dir,
        )?))
    }
}

impl<B: KernelBackend> FppsIcp<B> {
    pub fn with_backend(backend: B) -> Self {
        Self {
            backend,
            source: None,
            target: None,
            initial_transform: Mat4::IDENTITY,
            max_correspondence_distance: 1.0,
            max_iteration_count: 50,
            transformation_epsilon: 1e-5,
            prepared_target: None,
        }
    }

    pub fn backend(&self) -> &B {
        &self.backend
    }

    /// `setTransformationMatrix()`: initial transform applied before the
    /// first iteration.
    pub fn set_transformation_matrix(&mut self, t: Mat4) -> &mut Self {
        self.initial_transform = t;
        self
    }

    /// `setInputSource()`.
    pub fn set_input_source(&mut self, cloud: PointCloud) -> &mut Self {
        self.source = Some(cloud);
        self
    }

    /// `setInputTarget()`.
    pub fn set_input_target(&mut self, cloud: PointCloud) -> &mut Self {
        self.target = Some(cloud);
        self.prepared_target = None;
        self
    }

    /// `setMaxCorrespondenceDistance()` (meters).
    pub fn set_max_correspondence_distance(&mut self, d: f32) -> &mut Self {
        assert!(d > 0.0, "max correspondence distance must be positive");
        self.max_correspondence_distance = d;
        self
    }

    /// `setMaxIterationCount()`.
    pub fn set_max_iteration_count(&mut self, n: u32) -> &mut Self {
        self.max_iteration_count = n;
        self
    }

    /// `setTransformationEpsilon()`.
    pub fn set_transformation_epsilon(&mut self, eps: f64) -> &mut Self {
        assert!(eps >= 0.0);
        self.transformation_epsilon = eps;
        self
    }

    /// `align()`: run the hybrid ICP loop and return the final transform.
    ///
    /// Host/device split per iteration (paper Fig. 2):
    /// * device: transform source by the *cumulative* T, NN search,
    ///   correspondence filtering, accumulator reduction;
    /// * host: Kabsch/SVD on the 3×3 covariance, convergence check,
    ///   T ← T_j·T.
    pub fn align(&mut self) -> Result<FppsResult> {
        let t_start = Instant::now();
        let source = self.source.as_ref().context("setInputSource not called")?;
        let target = self.target.as_ref().context("setInputTarget not called")?;
        if source.is_empty() || target.is_empty() {
            bail!("source/target cloud is empty");
        }

        // Prepare padded device buffers (upload happens per step in the
        // PJRT backend; a real FPGA would DMA once — see coordinator's
        // double-buffering for where that matters).
        if self
            .prepared_target
            .as_ref()
            .map(|p| p.n_source_hint != source.len())
            .unwrap_or(true)
        {
            let capacity = self.backend.select_capacity(source.len(), target.len())?;
            let (tgt, tgt_mask) = pad_to(&target.xyz, capacity.1);
            self.prepared_target = Some(PreparedTarget {
                tgt,
                tgt_mask,
                capacity,
                n_source_hint: source.len(),
            });
        }
        let prep = self.prepared_target.as_ref().unwrap();
        let (cap_n, _cap_m, _bn, _bm) = prep.capacity;
        let (src, src_mask) = pad_to(&source.xyz, cap_n);

        let max_d2 = self.max_correspondence_distance * self.max_correspondence_distance;
        let mut cumulative = self.initial_transform;
        let mut stats = Vec::new();
        let mut stop = StopReason::MaxIterations;
        let mut rmse = f64::NAN;
        let mut iterations = 0;

        // Host→device DMA once per alignment (the Fig. 2 upload);
        // iterations then only ship the 4×4 transform + threshold.
        self.backend
            .begin(&src, &prep.tgt, &src_mask, &prep.tgt_mask)?;
        for _ in 0..self.max_iteration_count {
            iterations += 1;
            let acc = self.backend.step(&cumulative, max_d2)?;
            if acc.count < 3.0 {
                stop = StopReason::TooFewCorrespondences;
                break;
            }
            rmse = acc.rmse();
            let Some(est) = kabsch_from_sums(acc.count, acc.sum_p, acc.sum_q, &acc.sum_pq)
            else {
                stop = StopReason::TooFewCorrespondences;
                break;
            };
            let t_j = est.to_mat4();
            cumulative = t_j.mul_mat(&cumulative);
            let delta = t_j.delta_from_identity();
            stats.push(FppsIterationStat {
                correspondences: acc.count,
                rmse,
                delta,
            });
            if delta < self.transformation_epsilon {
                stop = StopReason::Converged;
                break;
            }
        }

        Ok(FppsResult {
            transformation: cumulative,
            rmse,
            iterations,
            stop,
            stats,
            total_time: t_start.elapsed(),
            device_time: self.backend.device_time(),
        })
    }
}

fn pad_to(xyz: &[f32], capacity: usize) -> (Vec<f32>, Vec<f32>) {
    let n = xyz.len() / 3;
    assert!(n <= capacity, "cloud ({n}) exceeds capacity ({capacity})");
    let mut out = Vec::with_capacity(capacity * 3);
    out.extend_from_slice(xyz);
    out.resize(capacity * 3, 0.0);
    let mut mask = vec![1.0f32; n];
    mask.resize(capacity, 0.0);
    (out, mask)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::{Mat3, Vec3};
    use crate::rng::Pcg32;

    fn structured_cloud(n: usize, seed: u64) -> PointCloud {
        let mut rng = Pcg32::new(seed);
        let mut c = PointCloud::with_capacity(n);
        for i in 0..n {
            match i % 3 {
                0 => c.push([rng.range(-5.0, 5.0), rng.range(-5.0, 5.0), 0.0]),
                1 => c.push([rng.range(-5.0, 5.0), 5.0, rng.range(0.0, 3.0)]),
                _ => c.push([-5.0, rng.range(-5.0, 5.0), rng.range(0.0, 3.0)]),
            }
        }
        c
    }

    #[test]
    fn native_sim_recovers_transform() {
        let target = structured_cloud(900, 1);
        let gt = Mat4::from_rt(Mat3::rot_z(0.04), Vec3::new(0.2, -0.1, 0.02));
        let source = target.transformed(&gt.inverse_rigid());
        let mut icp = FppsIcp::native_sim();
        icp.set_input_source(source)
            .set_input_target(target)
            .set_max_correspondence_distance(1.0)
            .set_max_iteration_count(50)
            .set_transformation_epsilon(1e-5);
        let res = icp.align().unwrap();
        assert!(res.has_converged());
        let rerr = res.transformation.rotation().rotation_angle_to(&gt.rotation());
        let terr = (res.transformation.translation() - gt.translation()).norm();
        assert!(rerr < 2e-3, "rotation err {rerr}");
        assert!(terr < 2e-2, "translation err {terr}");
    }

    #[test]
    fn matches_cpu_baseline_within_001m() {
        // Table III claim: FPGA vs CPU RMSE differs < 0.01 m.
        let target = structured_cloud(1000, 2);
        let gt = Mat4::from_rt(Mat3::rot_z(-0.03), Vec3::new(-0.15, 0.25, 0.01));
        let mut source = target.transformed(&gt.inverse_rigid());
        let mut rng = Pcg32::new(3);
        source.add_noise(0.01, &mut rng);

        let cpu = crate::icp::align(
            &source,
            &target,
            &Mat4::IDENTITY,
            &crate::icp::IcpParams::default(),
        );
        let mut icp = FppsIcp::native_sim();
        icp.set_input_source(source).set_input_target(target);
        let fpps = icp.align().unwrap();
        assert!(
            (cpu.rmse - fpps.rmse).abs() < 0.01,
            "cpu {} vs fpps {}",
            cpu.rmse,
            fpps.rmse
        );
        let dt = (cpu.transformation.translation() - fpps.transformation.translation()).norm();
        assert!(dt < 0.01, "translation differs {dt}");
    }

    #[test]
    fn initial_transform_honored() {
        let target = structured_cloud(600, 4);
        let gt = Mat4::from_rt(Mat3::rot_z(0.05), Vec3::new(0.3, 0.0, 0.0));
        let source = target.transformed(&gt.inverse_rigid());
        let mut icp = FppsIcp::native_sim();
        icp.set_input_source(source)
            .set_input_target(target)
            .set_transformation_matrix(gt);
        let res = icp.align().unwrap();
        assert!(res.iterations <= 2, "should converge from the answer");
    }

    #[test]
    fn api_validates_inputs() {
        let mut icp = FppsIcp::native_sim();
        assert!(icp.align().is_err(), "no clouds set");
        icp.set_input_source(structured_cloud(10, 5));
        assert!(icp.align().is_err(), "no target set");
        icp.set_input_target(PointCloud::new());
        assert!(icp.align().is_err(), "empty target");
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn rejects_nonpositive_distance() {
        FppsIcp::native_sim().set_max_correspondence_distance(0.0);
    }

    #[test]
    fn disjoint_clouds_flagged() {
        let a = structured_cloud(100, 6);
        let mut b = structured_cloud(100, 7);
        for v in b.xyz.iter_mut() {
            *v += 500.0;
        }
        let mut icp = FppsIcp::native_sim();
        icp.set_input_source(a).set_input_target(b);
        let res = icp.align().unwrap();
        assert_eq!(res.stop, StopReason::TooFewCorrespondences);
    }

    #[test]
    fn kdtree_cpu_backend_recovers_transform() {
        let target = structured_cloud(900, 21);
        let gt = Mat4::from_rt(Mat3::rot_z(0.03), Vec3::new(0.15, -0.2, 0.01));
        let source = target.transformed(&gt.inverse_rigid());
        let mut icp = FppsIcp::kdtree_cpu();
        icp.set_input_source(source).set_input_target(target);
        let res = icp.align().unwrap();
        assert!(res.has_converged());
        assert_eq!(icp.backend().name(), "kdtree-cpu");
        let terr = (res.transformation.translation() - gt.translation()).norm();
        assert!(terr < 2e-2, "translation err {terr}");
    }

    #[test]
    fn kdtree_and_native_sim_agree_within_table3_margin() {
        let target = structured_cloud(800, 22);
        let gt = Mat4::from_rt(Mat3::rot_z(-0.02), Vec3::new(0.1, 0.15, 0.0));
        let mut source = target.transformed(&gt.inverse_rigid());
        let mut rng = Pcg32::new(23);
        source.add_noise(0.01, &mut rng);

        let mut a = FppsIcp::kdtree_cpu();
        a.set_input_source(source.clone()).set_input_target(target.clone());
        let ra = a.align().unwrap();
        let mut b = FppsIcp::native_sim();
        b.set_input_source(source).set_input_target(target);
        let rb = b.align().unwrap();
        assert!((ra.rmse - rb.rmse).abs() < 0.01, "{} vs {}", ra.rmse, rb.rmse);
        let dt = (ra.transformation.translation() - rb.transformation.translation()).norm();
        assert!(dt < 0.01, "translations differ by {dt}");
    }

    #[test]
    fn backend_kind_parses() {
        assert_eq!("auto".parse::<BackendKind>().unwrap(), BackendKind::Auto);
        assert_eq!("xla".parse::<BackendKind>().unwrap(), BackendKind::Xla);
        assert_eq!(
            "native-sim".parse::<BackendKind>().unwrap(),
            BackendKind::NativeSim
        );
        assert_eq!(
            "kdtree".parse::<BackendKind>().unwrap(),
            BackendKind::KdTreeCpu
        );
        assert!("fpga".parse::<BackendKind>().is_err());
    }

    #[test]
    fn backend_handle_auto_falls_back_without_artifacts() {
        let dir = Path::new("definitely/not/an/artifact/dir");
        let handle = BackendHandle::create(BackendKind::Auto, dir).unwrap();
        assert_eq!(handle.name(), "native-sim");
        // Explicit XLA request must error with an actionable message.
        let err = BackendHandle::create(BackendKind::Xla, dir).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("manifest"), "{msg}");
    }

    #[test]
    fn backend_handle_aligns_like_its_inner_backend() {
        let target = structured_cloud(700, 24);
        let gt = Mat4::from_rt(Mat3::rot_z(0.02), Vec3::new(0.2, 0.0, 0.0));
        let source = target.transformed(&gt.inverse_rigid());

        let mut via_handle = FppsIcp::with_backend(
            BackendHandle::create(BackendKind::NativeSim, Path::new("artifacts")).unwrap(),
        );
        via_handle
            .set_input_source(source.clone())
            .set_input_target(target.clone());
        let a = via_handle.align().unwrap();

        let mut direct = FppsIcp::native_sim();
        direct.set_input_source(source).set_input_target(target);
        let b = direct.align().unwrap();

        // Same backend, same inputs → bit-identical outputs.
        assert_eq!(a.transformation.m, b.transformation.m);
        assert_eq!(a.rmse.to_bits(), b.rmse.to_bits());
        assert_eq!(a.iterations, b.iterations);
    }

    #[test]
    fn iteration_stats_populated() {
        let target = structured_cloud(500, 8);
        let gt = Mat4::from_rt(Mat3::rot_z(0.02), Vec3::new(0.1, 0.1, 0.0));
        let source = target.transformed(&gt.inverse_rigid());
        let mut icp = FppsIcp::native_sim();
        icp.set_input_source(source).set_input_target(target);
        let res = icp.align().unwrap();
        assert_eq!(res.stats.len() as u32, res.iterations);
        for s in &res.stats {
            assert!(s.correspondences >= 3.0);
            assert!(s.rmse.is_finite());
        }
        assert!(res.device_time > Duration::ZERO);
    }
}
