//! Voxel-bucketed approximate nearest-neighbor index — the IVF-style
//! coarse-quantization path for city-scale maps.
//!
//! [`VoxelGrid`] buckets the indexed points of a [`PointCloud`] into a
//! **flat hash of fixed-size cells** (open addressing over packed
//! integer cell coordinates, CSR-style point storage — two dense
//! arrays, no per-cell allocation). [`VoxelGrid::nearest`] walks the
//! query's cell neighborhood **ring by ring** outward (Chebyshev shells
//! around the query's own cell) and stops early once the current best
//! hit is provably closer than anything a farther ring could hold: a
//! point in ring `r` is at least `(r-1)·cell_size` away from the query,
//! so the scan terminates as soon as `best ≤ (r-1)·cell_size` — or when
//! the ring budget [`VoxelGrid::max_ring`] runs out.
//!
//! The budget is what makes the index *approximate*: a true nearest
//! neighbor farther than `max_ring` rings from the query cell is never
//! visited, and the query reports the best point inside the scanned
//! neighborhood instead (or `None` — a dropped correspondence, which
//! ICP's correspondence-distance rejection treats exactly like an
//! out-of-range match). With a ring budget that covers the search
//! radius (`max_ring·cell_size ≥ max_dist`), results are exact over the
//! bounded search: the same strictly-closer/first-found acceptance the
//! kd-tree path uses.
//!
//! Queries are **allocation-free** (pure loops over the CSR arrays), so
//! a resident grid can serve the warm engine path without breaking the
//! data plane's 0-allocations/job invariant; building the grid is a
//! cold-path (upload-time) cost, like the kd-tree build it sits next
//! to.
//!
//! [`NnStrategy`] is the caller-facing knob: `exact` keeps the kd-tree,
//! `approx(cell_size, max_ring)` forces the grid, and `auto` picks the
//! grid only for maps of at least [`AUTO_GRID_MIN_POINTS`] points —
//! below that the kd-tree is already fast enough that approximation
//! buys nothing.

use crate::kdtree::Neighbor;
use crate::pointcloud::PointCloud;
use anyhow::{bail, Context, Result};

/// Map size (in points) at which [`NnStrategy::Auto`] switches from the
/// exact kd-tree to the voxel grid. Below this the kd-tree answers a
/// bounded NN query in a microsecond or less and the grid's bounded
/// error buys nothing; above it the grid's O(points-per-neighborhood)
/// probe wins by a growing margin (see `benches/nn_scaling.rs`).
pub const AUTO_GRID_MIN_POINTS: usize = 200_000;

/// Default grid cell edge (meters) when the strategy does not name one.
/// Matches the engine's default max correspondence distance, so a
/// single ring already covers the default search radius.
pub const DEFAULT_CELL_SIZE: f32 = 1.0;

/// Default ring budget when the strategy does not name one.
pub const DEFAULT_MAX_RING: usize = 2;

/// Per-resident-target NN strategy: which index answers the
/// correspondence search of [`crate::fpps_api::KernelBackend::step`].
///
/// Parsed from `--nn-strategy` / the `nn_strategy=` config key:
/// `exact`, `auto`, `approx` (defaults), or `approx:CELL,RING`
/// (e.g. `approx:0.5,2`). `Display` round-trips the parse.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum NnStrategy {
    /// Always the exact kd-tree — bit-identical to the pre-grid path.
    #[default]
    Exact,
    /// Always the voxel grid, with an explicit cell edge (meters) and
    /// ring budget.
    Approx { cell_size: f32, max_ring: usize },
    /// Per-target choice by map size: grid for maps of at least
    /// [`AUTO_GRID_MIN_POINTS`] points (with the default cell/ring),
    /// exact kd-tree below.
    Auto,
}

impl NnStrategy {
    /// Whether a target of `n_points` should get a grid under this
    /// strategy (the per-residency-slot decision backends make at
    /// upload time).
    pub fn wants_grid(&self, n_points: usize) -> bool {
        match self {
            NnStrategy::Exact => false,
            NnStrategy::Approx { .. } => true,
            NnStrategy::Auto => n_points >= AUTO_GRID_MIN_POINTS,
        }
    }

    /// `(cell_size, max_ring)` to build the grid with (defaults unless
    /// the strategy names its own).
    pub fn grid_params(&self) -> (f32, usize) {
        match self {
            NnStrategy::Approx {
                cell_size,
                max_ring,
            } => (*cell_size, *max_ring),
            _ => (DEFAULT_CELL_SIZE, DEFAULT_MAX_RING),
        }
    }
}

impl std::str::FromStr for NnStrategy {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        let t = s.trim();
        match t {
            "exact" => return Ok(NnStrategy::Exact),
            "auto" => return Ok(NnStrategy::Auto),
            "approx" => {
                return Ok(NnStrategy::Approx {
                    cell_size: DEFAULT_CELL_SIZE,
                    max_ring: DEFAULT_MAX_RING,
                })
            }
            _ => {}
        }
        // approx:CELL,RING (accepting approx(CELL,RING) as well).
        let body = t
            .strip_prefix("approx:")
            .or_else(|| t.strip_prefix("approx(").and_then(|r| r.strip_suffix(')')));
        let Some(body) = body else {
            bail!(
                "unknown NN strategy {t:?} \
                 (expected exact | auto | approx[:CELL,RING])"
            );
        };
        let (cell, ring) = body.split_once(',').with_context(|| {
            format!("NN strategy {t:?} needs two parameters: approx:CELL,RING")
        })?;
        let cell_size: f32 = cell
            .trim()
            .parse()
            .with_context(|| format!("bad cell size {:?} in NN strategy {t:?}", cell.trim()))?;
        if !cell_size.is_finite() || cell_size <= 0.0 {
            bail!("cell size must be positive and finite, got {cell_size}");
        }
        let max_ring: usize = ring
            .trim()
            .parse()
            .with_context(|| format!("bad ring budget {:?} in NN strategy {t:?}", ring.trim()))?;
        Ok(NnStrategy::Approx {
            cell_size,
            max_ring,
        })
    }
}

impl std::fmt::Display for NnStrategy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NnStrategy::Exact => write!(f, "exact"),
            NnStrategy::Auto => write!(f, "auto"),
            NnStrategy::Approx {
                cell_size,
                max_ring,
            } => write!(f, "approx:{cell_size},{max_ring}"),
        }
    }
}

/// Hash-table sentinel: packed cell keys use 63 bits, so `u64::MAX`
/// can never collide with a real key.
const EMPTY: u64 = u64::MAX;

/// Cell coordinates are biased into 21 bits each before packing;
/// coordinates outside ±2²⁰ cells clamp (build and query clamp the same
/// way, so far-out outliers degrade gracefully instead of aliasing).
const COORD_BIAS: i64 = 1 << 20;

fn pack_cell(cx: i32, cy: i32, cz: i32) -> u64 {
    let clamp = |c: i32| ((c as i64).clamp(-COORD_BIAS, COORD_BIAS - 1) + COORD_BIAS) as u64;
    clamp(cx) | (clamp(cy) << 21) | (clamp(cz) << 42)
}

/// SplitMix64 finalizer — the probe-sequence scrambler for the flat
/// hash (packed neighbor cells differ in few bits; a plain modulo would
/// cluster them).
fn hash64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Voxel-bucketed NN index over one [`PointCloud`] (see the module
/// docs). The grid stores **indices only** — queries take the cloud it
/// was built from, so a backend can keep the grid next to the kd-tree
/// that owns the points without duplicating them.
pub struct VoxelGrid {
    cell_size: f32,
    inv_cell: f32,
    max_ring: usize,
    /// Number of indexed points (must match the query-time cloud).
    len: usize,
    /// Open-addressed table: packed cell key per slot ([`EMPTY`] = free).
    keys: Vec<u64>,
    /// Per-slot CSR range `(start, count)` into [`Self::order`].
    ranges: Vec<(u32, u32)>,
    /// Point indices grouped by cell, ascending within each cell (the
    /// deterministic first-found tie-break order).
    order: Vec<u32>,
    /// Table capacity − 1 (power-of-two probing).
    mask: usize,
}

impl VoxelGrid {
    /// Bucket `cloud` into cells of edge `cell_size`, with queries
    /// allowed to scan up to `max_ring` Chebyshev rings outward.
    pub fn build(cloud: &PointCloud, cell_size: f32, max_ring: usize) -> Self {
        assert!(
            cell_size.is_finite() && cell_size > 0.0,
            "cell size must be positive and finite, got {cell_size}"
        );
        let n = cloud.len();
        assert!(n < u32::MAX as usize, "voxel grid indexes at most 2^32-1 points");
        let mut g = Self {
            cell_size,
            inv_cell: 1.0 / cell_size,
            max_ring,
            len: n,
            keys: Vec::new(),
            ranges: Vec::new(),
            order: Vec::new(),
            mask: 0,
        };
        if n == 0 {
            return g;
        }
        // Sized for the worst case of one distinct cell per point, at
        // ≤ 50% load so probe chains stay short.
        let cap = (2 * n).next_power_of_two();
        g.mask = cap - 1;
        g.keys = vec![EMPTY; cap];
        g.ranges = vec![(0u32, 0u32); cap];
        // Pass 1: count points per distinct cell (memoizing each
        // point's slot so pass 3 probes nothing).
        let mut slot_of = vec![0u32; n];
        for i in 0..n {
            let slot = g.find_or_insert(g.key_of(cloud.get(i)));
            g.ranges[slot].1 += 1;
            slot_of[i] = slot as u32;
        }
        // Pass 2: prefix-sum the counts into CSR starts (count resets
        // to 0 and doubles as the pass-3 write cursor).
        let mut start = 0u32;
        for slot in 0..cap {
            if g.keys[slot] != EMPTY {
                let count = g.ranges[slot].1;
                g.ranges[slot] = (start, 0);
                start += count;
            }
        }
        // Pass 3: place point indices — ascending within each cell
        // because `i` ascends.
        g.order = vec![0u32; n];
        for (i, &slot) in slot_of.iter().enumerate() {
            let (st, cur) = g.ranges[slot as usize];
            g.order[(st + cur) as usize] = i as u32;
            g.ranges[slot as usize].1 = cur + 1;
        }
        g
    }

    /// Number of indexed points.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Cell edge length (meters).
    pub fn cell_size(&self) -> f32 {
        self.cell_size
    }

    /// Ring budget queries may scan.
    pub fn max_ring(&self) -> usize {
        self.max_ring
    }

    /// Number of occupied cells (telemetry / ablation reporting).
    pub fn occupied_cells(&self) -> usize {
        self.keys.iter().filter(|&&k| k != EMPTY).count()
    }

    fn key_of(&self, p: [f32; 3]) -> u64 {
        pack_cell(
            (p[0] * self.inv_cell).floor() as i32,
            (p[1] * self.inv_cell).floor() as i32,
            (p[2] * self.inv_cell).floor() as i32,
        )
    }

    /// Probe for `key`; claim a free slot if absent (build-time only).
    fn find_or_insert(&mut self, key: u64) -> usize {
        let mut slot = (hash64(key) as usize) & self.mask;
        loop {
            if self.keys[slot] == key {
                return slot;
            }
            if self.keys[slot] == EMPTY {
                self.keys[slot] = key;
                return slot;
            }
            slot = (slot + 1) & self.mask;
        }
    }

    fn lookup(&self, key: u64) -> Option<(u32, u32)> {
        if self.keys.is_empty() {
            return None;
        }
        let mut slot = (hash64(key) as usize) & self.mask;
        loop {
            let k = self.keys[slot];
            if k == key {
                return Some(self.ranges[slot]);
            }
            if k == EMPTY {
                return None;
            }
            slot = (slot + 1) & self.mask;
        }
    }

    /// Best point within `max_dist_sq` of `q` inside the scanned ring
    /// neighborhood — allocation-free, strictly-closer acceptance (the
    /// kd-tree's bounded-search semantics). `cloud` must be the cloud
    /// the grid was built from. `None` = nothing in range within the
    /// ring budget (a dropped correspondence in approx mode).
    pub fn nearest(&self, cloud: &PointCloud, q: [f32; 3], max_dist_sq: f32) -> Option<Neighbor> {
        debug_assert_eq!(cloud.len(), self.len, "grid queried against a different cloud");
        if self.len == 0 {
            return None;
        }
        let cx = (q[0] * self.inv_cell).floor() as i32;
        let cy = (q[1] * self.inv_cell).floor() as i32;
        let cz = (q[2] * self.inv_cell).floor() as i32;
        let mut best = Neighbor {
            index: 0,
            dist_sq: max_dist_sq,
        };
        let mut found = false;
        for r in 0..=(self.max_ring as i32) {
            if r >= 1 {
                // Everything in ring r (and beyond) is at least
                // (r-1)·cell away: q sits somewhere inside its own
                // cell, and ring-r cells start r-1 whole cells past its
                // boundary. Once the current bound can't be beaten,
                // farther rings are pointless.
                let lower = (r - 1) as f32 * self.cell_size;
                if best.dist_sq <= lower * lower {
                    break;
                }
            }
            // Hollow-shell walk of ring r, fixed order (z, y, x
            // ascending) for determinism; interior cells were scanned
            // by earlier rings.
            for dz in -r..=r {
                for dy in -r..=r {
                    let on_face = dz.abs() == r || dy.abs() == r;
                    let step = if on_face || r == 0 { 1 } else { 2 * r };
                    let mut dx = -r;
                    while dx <= r {
                        let cell = [cx + dx, cy + dy, cz + dz];
                        self.scan_cell(cloud, cell, q, &mut best, &mut found);
                        dx += step;
                    }
                }
            }
        }
        found.then_some(best)
    }

    fn scan_cell(
        &self,
        cloud: &PointCloud,
        cell: [i32; 3],
        q: [f32; 3],
        best: &mut Neighbor,
        found: &mut bool,
    ) {
        let Some((start, count)) = self.lookup(pack_cell(cell[0], cell[1], cell[2])) else {
            return;
        };
        for k in start..start + count {
            let i = self.order[k as usize];
            let p = cloud.get(i as usize);
            let dx = p[0] - q[0];
            let dy = p[1] - q[1];
            let dz = p[2] - q[2];
            let d2 = dx * dx + dy * dy + dz * dz;
            if d2 < best.dist_sq {
                *best = Neighbor { index: i, dist_sq: d2 };
                *found = true;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    fn random_cloud(n: usize, extent: f32, seed: u64) -> PointCloud {
        let mut rng = Pcg32::new(seed);
        let mut c = PointCloud::with_capacity(n);
        for _ in 0..n {
            c.push([
                rng.range(-extent, extent),
                rng.range(-extent, extent),
                rng.range(-extent, extent),
            ]);
        }
        c
    }

    fn brute(cloud: &PointCloud, q: [f32; 3], max_dist_sq: f32) -> Option<Neighbor> {
        let mut best = Neighbor {
            index: 0,
            dist_sq: max_dist_sq,
        };
        let mut found = false;
        for i in 0..cloud.len() {
            let p = cloud.get(i);
            let (dx, dy, dz) = (p[0] - q[0], p[1] - q[1], p[2] - q[2]);
            let d2 = dx * dx + dy * dy + dz * dz;
            if d2 < best.dist_sq {
                best = Neighbor {
                    index: i as u32,
                    dist_sq: d2,
                };
                found = true;
            }
        }
        found.then_some(best)
    }

    #[test]
    fn covering_ring_budget_matches_brute_force() {
        // With max_ring·cell ≥ max_dist the scanned neighborhood covers
        // the whole search ball, so the grid is exact over the bounded
        // query — same distance, and (ties aside) the same index.
        let cloud = random_cloud(600, 5.0, 11);
        let grid = VoxelGrid::build(&cloud, 1.0, 3);
        let max_d2 = 4.0; // radius 2: any in-range point sits within ring ⌊2/1⌋+1 = 3
        let mut rng = Pcg32::new(12);
        let mut hits = 0;
        for _ in 0..500 {
            let q = [
                rng.range(-6.0, 6.0),
                rng.range(-6.0, 6.0),
                rng.range(-6.0, 6.0),
            ];
            let a = grid.nearest(&cloud, q, max_d2);
            let b = brute(&cloud, q, max_d2);
            match (a, b) {
                (None, None) => {}
                (Some(x), Some(y)) => {
                    assert_eq!(x.dist_sq.to_bits(), y.dist_sq.to_bits(), "query {q:?}");
                    assert_eq!(x.index, y.index, "query {q:?}");
                    hits += 1;
                }
                (a, b) => panic!("grid {a:?} vs brute {b:?} for query {q:?}"),
            }
        }
        assert!(hits > 100, "workload too sparse to be meaningful: {hits}");
    }

    #[test]
    fn bounded_ring_returns_true_distances_and_respects_the_bound() {
        // A tight ring budget may miss the global nearest, but whatever
        // it returns must be a real in-range point, never closer than
        // the true nearest.
        let cloud = random_cloud(400, 8.0, 21);
        let grid = VoxelGrid::build(&cloud, 0.5, 1);
        let mut rng = Pcg32::new(22);
        for _ in 0..300 {
            let q = [
                rng.range(-9.0, 9.0),
                rng.range(-9.0, 9.0),
                rng.range(-9.0, 9.0),
            ];
            let max_d2 = 2.25;
            if let Some(nb) = grid.nearest(&cloud, q, max_d2) {
                assert!(nb.dist_sq < max_d2);
                let p = cloud.get(nb.index as usize);
                let d2 = (p[0] - q[0]).powi(2) + (p[1] - q[1]).powi(2) + (p[2] - q[2]).powi(2);
                assert_eq!(d2.to_bits(), nb.dist_sq.to_bits(), "reported distance is real");
                let truth = brute(&cloud, q, max_d2).expect("brute sees at least the grid's hit");
                assert!(truth.dist_sq <= nb.dist_sq, "grid can't beat the true nearest");
            }
        }
    }

    #[test]
    fn empty_and_out_of_range_queries_return_none() {
        let grid = VoxelGrid::build(&PointCloud::new(), 1.0, 4);
        assert!(grid.is_empty());
        assert!(grid.nearest(&PointCloud::new(), [0.0; 3], 1e9).is_none());

        let mut c = PointCloud::new();
        c.push([100.0, 100.0, 100.0]);
        let grid = VoxelGrid::build(&c, 1.0, 64);
        assert!(grid.nearest(&c, [0.0; 3], 1.0).is_none(), "out of range");
        let nb = grid.nearest(&c, [0.0; 3], 1e9).expect("big budget reaches it");
        assert_eq!(nb.index, 0);
    }

    #[test]
    fn ascending_index_tie_break_is_deterministic() {
        // Two coincident points: the lower index wins (same rule as the
        // brute-force and kd-tree first-found acceptance).
        let mut c = PointCloud::new();
        c.push([0.5, 0.5, 0.5]);
        c.push([0.25, 0.25, 0.25]);
        c.push([0.25, 0.25, 0.25]);
        let grid = VoxelGrid::build(&c, 1.0, 1);
        let nb = grid.nearest(&c, [0.25, 0.25, 0.25], 1.0).unwrap();
        assert_eq!(nb.index, 1, "lowest index wins exact ties");
        assert_eq!(nb.dist_sq, 0.0);
    }

    #[test]
    fn occupancy_telemetry_counts_cells() {
        let mut c = PointCloud::new();
        c.push([0.1, 0.1, 0.1]);
        c.push([0.9, 0.9, 0.9]); // same cell at cell_size 1
        c.push([5.5, 0.0, 0.0]); // different cell
        let grid = VoxelGrid::build(&c, 1.0, 1);
        assert_eq!(grid.len(), 3);
        assert_eq!(grid.occupied_cells(), 2);
        assert_eq!(grid.cell_size(), 1.0);
        assert_eq!(grid.max_ring(), 1);
    }

    #[test]
    fn strategy_parses_and_round_trips() {
        let cases = [
            ("exact", NnStrategy::Exact),
            ("auto", NnStrategy::Auto),
            (
                "approx",
                NnStrategy::Approx {
                    cell_size: DEFAULT_CELL_SIZE,
                    max_ring: DEFAULT_MAX_RING,
                },
            ),
            (
                "approx:0.5,2",
                NnStrategy::Approx {
                    cell_size: 0.5,
                    max_ring: 2,
                },
            ),
            (
                "approx(2.5,4)",
                NnStrategy::Approx {
                    cell_size: 2.5,
                    max_ring: 4,
                },
            ),
        ];
        for (s, want) in cases {
            let got: NnStrategy = s.parse().unwrap_or_else(|e| panic!("{s:?}: {e:#}"));
            assert_eq!(got, want, "{s:?}");
            let shown = got.to_string();
            let again: NnStrategy = shown.parse().unwrap();
            assert_eq!(again, got, "display {shown:?} must round-trip");
        }
        for bad in ["", "grid", "approx:1", "approx:0,2", "approx:-1,2", "approx:1,x"] {
            assert!(bad.parse::<NnStrategy>().is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn auto_strategy_flips_on_map_size() {
        assert!(!NnStrategy::Auto.wants_grid(AUTO_GRID_MIN_POINTS - 1));
        assert!(NnStrategy::Auto.wants_grid(AUTO_GRID_MIN_POINTS));
        assert!(!NnStrategy::Exact.wants_grid(usize::MAX));
        let approx = NnStrategy::Approx {
            cell_size: 0.5,
            max_ring: 3,
        };
        assert!(approx.wants_grid(1));
        assert_eq!(approx.grid_params(), (0.5, 3));
        assert_eq!(NnStrategy::Auto.grid_params(), (DEFAULT_CELL_SIZE, DEFAULT_MAX_RING));
        assert_eq!(NnStrategy::default(), NnStrategy::Exact, "inert default");
    }
}
