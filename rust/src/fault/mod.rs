//! Deterministic fault injection for chaos-testing the lane pool.
//!
//! [`FaultInjectingBackend`] wraps any [`KernelBackend`] and injects
//! failures on a fixed schedule — a [`FaultPlan`] mapping *align-attempt
//! ordinals* (one `upload_source` call per alignment attempt) to
//! [`FaultKind`]s. Plans are either scripted by hand or derived from a
//! seed via the crate's own [`Pcg32`], so a chaos run is exactly
//! reproducible: same seed, same faults, same recovery sequence.
//!
//! The four injected failure modes mirror the real-world hazards the
//! supervision layer must contain:
//!
//! * [`FaultKind::TransientError`] — the upload returns `Err` once; a
//!   retry succeeds. Models a recoverable DMA/transport hiccup.
//! * [`FaultKind::StallMs`] — the upload blocks for the given duration,
//!   polling its [`CancelToken`] so a watchdog can cut it off. Models a
//!   wedged device call (the silent multi-minute blocking NN query).
//! * [`FaultKind::CorruptTransform`] — the *next* [`KernelBackend::step`]
//!   returns NaN-poisoned accumulators. Models bit-rot on the result
//!   path; `FppsIcp::align` must detect it and fail the attempt rather
//!   than misreport it as a correspondence-count stop.
//! * [`FaultKind::Panic`] — the upload panics, killing the lane thread.
//!   Models a driver crash; the supervisor must respawn the lane.
//!
//! Injection happens strictly *around* the wrapped backend: a fault
//! either prevents the inner call or poisons its output, so an attempt
//! with no scheduled fault is bit-identical to running the inner
//! backend directly.

use crate::fpps_api::{CancelToken, KernelBackend, TargetEpoch};
use crate::math::Mat4;
use crate::rng::Pcg32;
use crate::runtime::StepAccumulators;
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::time::Duration;

/// One scheduled failure mode. See the module docs for what each models.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// The align attempt's `upload_source` fails with a retryable error.
    TransientError,
    /// The align attempt's `upload_source` blocks for this many
    /// milliseconds (cooperatively cancellable via [`CancelToken`]).
    StallMs(u64),
    /// The attempt's next `step` returns NaN-poisoned accumulators.
    CorruptTransform,
    /// The align attempt's `upload_source` panics, killing the lane.
    Panic,
}

/// A deterministic schedule of faults, keyed by align-attempt ordinal
/// (0-based count of `upload_source` calls on the wrapped backend).
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    faults: BTreeMap<u64, FaultKind>,
}

impl FaultPlan {
    /// A plan that injects nothing — the wrapper becomes a transparent
    /// pass-through (useful as the non-faulted arm of a chaos test).
    pub fn none() -> Self {
        Self::default()
    }

    /// A hand-written schedule: `(attempt ordinal, fault)` pairs.
    pub fn scripted(faults: impl IntoIterator<Item = (u64, FaultKind)>) -> Self {
        Self {
            faults: faults.into_iter().collect(),
        }
    }

    /// A seeded pseudo-random schedule over the first `attempts` align
    /// attempts: each attempt independently faults with probability
    /// `rate`, drawing uniformly among the four kinds (stalls use
    /// `stall_ms`). `lane` selects a decorrelated [`Pcg32`] substream so
    /// every lane of a pool gets its own schedule from one pool seed.
    pub fn seeded(seed: u64, lane: usize, attempts: u64, rate: f64, stall_ms: u64) -> Self {
        let mut rng = Pcg32::substream(seed, lane as u64);
        let mut faults = BTreeMap::new();
        for ordinal in 0..attempts {
            if rng.uniform_f64() < rate {
                let kind = match rng.below(4) {
                    0 => FaultKind::TransientError,
                    1 => FaultKind::StallMs(stall_ms),
                    2 => FaultKind::CorruptTransform,
                    _ => FaultKind::Panic,
                };
                faults.insert(ordinal, kind);
            }
        }
        Self { faults }
    }

    /// The fault scheduled for `ordinal`, if any.
    pub fn fault_for(&self, ordinal: u64) -> Option<FaultKind> {
        self.faults.get(&ordinal).copied()
    }

    /// Number of scheduled faults.
    pub fn len(&self) -> usize {
        self.faults.len()
    }

    /// True when the plan injects nothing.
    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }
}

/// Granularity of the cancellable stall sleep — short enough that a
/// watchdog cancellation is honoured promptly, long enough not to spin.
const STALL_SLICE: Duration = Duration::from_millis(2);

/// A [`KernelBackend`] decorator that injects the faults of a
/// [`FaultPlan`] around an inner backend. See the module docs.
pub struct FaultInjectingBackend<B> {
    inner: B,
    plan: FaultPlan,
    /// Count of align attempts (`upload_source` calls) so far.
    attempts: u64,
    /// Set when a [`FaultKind::CorruptTransform`] fault fired on the
    /// current attempt; poisons the next `step`'s accumulators.
    armed_corrupt: bool,
    cancel: CancelToken,
}

impl<B: KernelBackend> FaultInjectingBackend<B> {
    pub fn new(inner: B, plan: FaultPlan) -> Self {
        Self {
            inner,
            plan,
            attempts: 0,
            armed_corrupt: false,
            cancel: CancelToken::new(),
        }
    }

    /// Align attempts observed so far (fault-plan ordinals consumed).
    pub fn attempts(&self) -> u64 {
        self.attempts
    }

    /// The wrapped backend.
    pub fn inner(&self) -> &B {
        &self.inner
    }

    /// Block for `ms`, polling the cancel token; `Err` when cancelled.
    fn cancellable_stall(&self, ms: u64) -> Result<()> {
        let deadline = std::time::Instant::now() + Duration::from_millis(ms);
        while std::time::Instant::now() < deadline {
            if self.cancel.is_cancelled() {
                bail!("injected stall cut off by cancellation");
            }
            std::thread::sleep(STALL_SLICE.min(deadline - std::time::Instant::now()));
        }
        Ok(())
    }
}

impl<B: KernelBackend> KernelBackend for FaultInjectingBackend<B> {
    fn name(&self) -> &'static str {
        "fault-injecting"
    }

    fn select_capacity(
        &self,
        n_source: usize,
        n_target: usize,
    ) -> Result<(usize, usize, usize, usize)> {
        self.inner.select_capacity(n_source, n_target)
    }

    fn residency_slots(&self) -> usize {
        self.inner.residency_slots()
    }

    fn set_residency_slots(&mut self, slots: usize) {
        self.inner.set_residency_slots(slots);
    }

    fn upload_target_keyed(
        &mut self,
        key: u64,
        tgt: &[f32],
        tgt_mask: &[f32],
    ) -> Result<TargetEpoch> {
        self.inner.upload_target_keyed(key, tgt, tgt_mask)
    }

    fn activate_target(&mut self, key: u64) -> Option<TargetEpoch> {
        self.inner.activate_target(key)
    }

    fn target_epoch(&self) -> Option<TargetEpoch> {
        self.inner.target_epoch()
    }

    fn resident_epochs(&self) -> Vec<(u64, TargetEpoch)> {
        self.inner.resident_epochs()
    }

    fn target_evictions(&self) -> u64 {
        self.inner.target_evictions()
    }

    fn upload_source(&mut self, src: &[f32], src_mask: &[f32]) -> Result<()> {
        let ordinal = self.attempts;
        self.attempts += 1;
        self.armed_corrupt = false;
        match self.plan.fault_for(ordinal) {
            Some(FaultKind::TransientError) => {
                bail!("injected transient upload error (attempt {ordinal})")
            }
            Some(FaultKind::StallMs(ms)) => self.cancellable_stall(ms)?,
            Some(FaultKind::CorruptTransform) => self.armed_corrupt = true,
            Some(FaultKind::Panic) => panic!("injected lane panic (attempt {ordinal})"),
            None => {}
        }
        self.inner.upload_source(src, src_mask)
    }

    fn step(&mut self, transform: &Mat4, max_dist_sq: f32) -> Result<StepAccumulators> {
        let mut acc = self.inner.step(transform, max_dist_sq)?;
        if self.armed_corrupt {
            self.armed_corrupt = false;
            acc.count = f64::NAN;
            acc.sum_sq_dist = f64::NAN;
            acc.sum_pq.m[0][0] = f64::NAN;
        }
        Ok(acc)
    }

    fn device_time(&self) -> Duration {
        self.inner.device_time()
    }

    fn set_cancel_token(&mut self, token: CancelToken) {
        self.cancel = token.clone();
        self.inner.set_cancel_token(token);
    }

    fn set_nn_strategy(&mut self, strategy: crate::voxelgrid::NnStrategy) {
        self.inner.set_nn_strategy(strategy);
    }

    fn nn_strategy(&self) -> crate::voxelgrid::NnStrategy {
        self.inner.nn_strategy()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpps_api::NativeSimBackend;

    #[test]
    fn seeded_plans_are_reproducible_and_lane_decorrelated() {
        let a = FaultPlan::seeded(42, 0, 64, 0.25, 10);
        let b = FaultPlan::seeded(42, 0, 64, 0.25, 10);
        let c = FaultPlan::seeded(42, 1, 64, 0.25, 10);
        assert!(!a.is_empty(), "rate 0.25 over 64 attempts must fault");
        for ord in 0..64 {
            assert_eq!(a.fault_for(ord), b.fault_for(ord), "ordinal {ord}");
        }
        let differs = (0..64).any(|o| a.fault_for(o) != c.fault_for(o));
        assert!(differs, "lane substreams must decorrelate");
    }

    #[test]
    fn unfaulted_attempts_pass_through() {
        let plan = FaultPlan::scripted([(1, FaultKind::TransientError)]);
        let mut b = FaultInjectingBackend::new(NativeSimBackend::new(), plan);
        let src = vec![0.0f32; 3 * 8];
        let mask = vec![1.0f32; 8];
        b.upload_target(&src, &mask).unwrap();
        b.upload_source(&src, &mask).unwrap(); // attempt 0: clean
        let err = b.upload_source(&src, &mask).unwrap_err(); // attempt 1
        assert!(err.to_string().contains("injected transient"));
        b.upload_source(&src, &mask).unwrap(); // attempt 2: clean again
        assert_eq!(b.attempts(), 3);
    }

    #[test]
    fn corruption_poisons_exactly_one_step() {
        let plan = FaultPlan::scripted([(0, FaultKind::CorruptTransform)]);
        let mut b = FaultInjectingBackend::new(NativeSimBackend::new(), plan);
        let tgt: Vec<f32> = (0..24).map(|i| i as f32 * 0.1).collect();
        let mask = vec![1.0f32; 8];
        b.upload_target(&tgt, &mask).unwrap();
        b.upload_source(&tgt, &mask).unwrap();
        let poisoned = b.step(&Mat4::IDENTITY, 100.0).unwrap();
        assert!(!poisoned.is_finite(), "armed corruption must poison step");
        b.upload_source(&tgt, &mask).unwrap();
        let clean = b.step(&Mat4::IDENTITY, 100.0).unwrap();
        assert!(clean.is_finite(), "poison must not persist past one attempt");
    }

    #[test]
    fn stall_is_cut_off_by_cancellation() {
        let plan = FaultPlan::scripted([(0, FaultKind::StallMs(60_000))]);
        let mut b = FaultInjectingBackend::new(NativeSimBackend::new(), plan);
        let token = CancelToken::new();
        b.set_cancel_token(token.clone());
        token.cancel();
        let start = std::time::Instant::now();
        let err = b.upload_source(&[0.0; 24], &[1.0; 8]).unwrap_err();
        assert!(err.to_string().contains("cut off by cancellation"));
        assert!(start.elapsed() < Duration::from_secs(5));
    }
}
