//! Minimal property-based testing harness.
//!
//! `proptest` is not available offline, so this module provides the small
//! subset the test suite needs: seeded case generation with failure
//! reporting. There is deliberately no shrinking — cases carry their seed,
//! so a failure is replayed exactly by running the test again (the seed
//! is printed and stable).
//!
//! ```no_run
//! // (no_run: doctest binaries miss the xla rpath in this image)
//! use fpps::prop::{forall, Gen};
//! forall(100, |g: &mut Gen| {
//!     let x = g.f32_range(-10.0, 10.0);
//!     assert!((x.abs()).sqrt().powi(2) - x.abs() < 1e-3);
//! });
//! ```

use crate::rng::Pcg32;

/// Per-case generator handed to the property closure.
pub struct Gen {
    rng: Pcg32,
    /// Case index, exposed so properties can vary structure per case.
    pub case: u64,
}

impl Gen {
    pub fn rng(&mut self) -> &mut Pcg32 {
        &mut self.rng
    }

    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range(lo, hi)
    }

    pub fn usize_range(&mut self, lo: usize, hi_incl: usize) -> usize {
        lo + self.rng.below((hi_incl - lo + 1) as u32) as usize
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u32() & 1 == 1
    }

    /// A normally-distributed 3-vector (cloud point around origin).
    pub fn point(&mut self, scale: f32) -> [f32; 3] {
        [
            self.rng.normal() * scale,
            self.rng.normal() * scale,
            self.rng.normal() * scale,
        ]
    }

    /// `n` points with the given scale.
    pub fn points(&mut self, n: usize, scale: f32) -> Vec<[f32; 3]> {
        (0..n).map(|_| self.point(scale)).collect()
    }

    /// Random rotation matrix (uniform axis, bounded angle in radians).
    pub fn rotation(&mut self, max_angle: f32) -> crate::math::Mat3 {
        let axis = self.rng.unit_vector();
        let angle = self.rng.range(-max_angle, max_angle);
        crate::math::Mat3::axis_angle(axis, angle)
    }
}

/// Environment-tunable default case count: `FPPS_PROP_CASES`.
pub fn default_cases(fallback: u32) -> u32 {
    std::env::var("FPPS_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(fallback)
}

/// Run `property` over `cases` seeded generator states. Panics (with the
/// case seed in the message) on the first failing case.
pub fn forall(cases: u32, mut property: impl FnMut(&mut Gen)) {
    forall_seeded(0xF995_5EED, cases, &mut property);
}

/// Like [`forall`] but with an explicit base seed (printed on failure).
pub fn forall_seeded(seed: u64, cases: u32, property: &mut dyn FnMut(&mut Gen)) {
    for case in 0..cases as u64 {
        let mut g = Gen {
            rng: Pcg32::substream(seed, case),
            case,
        };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| property(&mut g)));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!("property failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_all_cases() {
        let mut n = 0;
        forall(25, |_| n += 1);
        assert_eq!(n, 25);
    }

    #[test]
    fn forall_reports_case_index() {
        let r = std::panic::catch_unwind(|| {
            forall(50, |g| {
                assert!(g.case < 10, "boom at {}", g.case);
            })
        });
        let msg = match r {
            Err(p) => p.downcast_ref::<String>().unwrap().clone(),
            Ok(_) => panic!("expected failure"),
        };
        assert!(msg.contains("case 10"), "{msg}");
    }

    #[test]
    fn gen_ranges_respected() {
        forall(100, |g| {
            let x = g.f32_range(2.0, 3.0);
            assert!((2.0..3.0).contains(&x));
            let u = g.usize_range(5, 9);
            assert!((5..=9).contains(&u));
        });
    }
}
