//! Cycle-level simulation of the Fig. 3 NN searcher pipeline.
//!
//! The paper describes a task-level pipeline of four concurrently
//! executing stages connected by FIFOs:
//!
//!   (1) data reading — BRAM → local source register buffer
//!   (2) distance computation — PE array, one target batch per cycle
//!   (3) distance comparison — group comparison tree (CMP TR)
//!   (4) result accumulation — streaming covariance accumulator
//!
//! This module simulates that dataflow cycle by cycle with bounded
//! FIFOs and per-stage occupancy counters. It serves two purposes:
//! validate `hwmodel::latency`'s closed-form cycle count (they must
//! agree within a few percent — asserted in tests and the
//! `pipesim_fig3` bench), and expose where stalls occur as the
//! architecture parameters change (the Fig. 3 "design-space" story).

use crate::hwmodel::AcceleratorConfig;

/// Bounded FIFO between stages.
#[derive(Clone, Debug)]
struct Fifo {
    depth: usize,
    occupancy: usize,
    /// Stall cycles caused by this FIFO being full (upstream blocked).
    full_stalls: u64,
    max_occupancy: usize,
}

impl Fifo {
    fn new(depth: usize) -> Self {
        Self {
            depth,
            occupancy: 0,
            full_stalls: 0,
            max_occupancy: 0,
        }
    }

    fn can_push(&self) -> bool {
        self.occupancy < self.depth
    }

    fn push(&mut self) {
        debug_assert!(self.can_push());
        self.occupancy += 1;
        self.max_occupancy = self.max_occupancy.max(self.occupancy);
    }

    fn can_pop(&self) -> bool {
        self.occupancy > 0
    }

    fn pop(&mut self) {
        debug_assert!(self.can_pop());
        self.occupancy -= 1;
    }
}

/// Per-stage activity statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct StageStats {
    pub busy_cycles: u64,
    pub stall_cycles: u64,
    pub idle_cycles: u64,
}

impl StageStats {
    pub fn utilization(&self, total: u64) -> f64 {
        self.busy_cycles as f64 / total as f64
    }
}

/// Simulation result.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub total_cycles: u64,
    /// read, distance, compare, accumulate.
    pub stages: [StageStats; 4],
    pub fifo_max_occupancy: [usize; 3],
    pub fifo_full_stalls: [u64; 3],
}

impl SimResult {
    pub fn seconds(&self, cfg: &AcceleratorConfig) -> f64 {
        self.total_cycles as f64 * cfg.cycle_s()
    }
}

/// Simulate one NN-search pass of `n_source` points against `n_target`
/// candidates on the configured PE array.
///
/// Work units:
/// * read stage: loads one source *block* (pe_rows points) per
///   `pe_rows` cycles (one point per cycle from BRAM).
/// * distance stage: for a resident block, consumes one target batch
///   (pe_cols points) per cycle; emits one compare job per block.
/// * compare stage: log2(pe_cols)+2-cycle tree reduction per block
///   (pipelined: initiation interval 1 batch/cycle, drain at block end).
/// * accumulate stage: pe_rows results per block, one per cycle.
pub fn simulate(cfg: &AcceleratorConfig, n_source: usize, n_target: usize) -> SimResult {
    let rows = cfg.pe_rows;
    let cols = cfg.pe_cols;
    let src_blocks = n_source.div_ceil(rows);
    let tgt_batches = n_target.div_ceil(cols);
    let cmp_latency = ((cols as f64).log2().ceil() as u64 + 2).max(1);

    // FIFOs: read→distance (double-buffered block slots),
    // distance→compare (per-block result sets), compare→accumulate.
    let mut f_rd = Fifo::new(2);
    let mut f_dc = Fifo::new(2);
    let mut f_ca = Fifo::new(4);

    let mut stats = [StageStats::default(); 4];

    // Stage state machines.
    let mut read_emitted = 0usize; // blocks fully read
    let mut read_progress = 0usize; // points of current block read
    let mut dist_block: Option<usize> = None; // batches consumed of current block
    let mut dist_done = 0usize;
    let mut cmp_busy: u64 = 0; // remaining cycles of current tree drain
    let mut acc_progress = 0usize; // results drained of current block
    let mut acc_block_ready = false;
    let mut acc_done = 0usize;

    let mut cycle: u64 = 0;
    let safety = (src_blocks as u64 + 4)
        * (tgt_batches as u64 + rows as u64 + cmp_latency + 8)
        + 10_000;

    while acc_done < src_blocks {
        cycle += 1;
        assert!(cycle < safety, "pipesim deadlock at cycle {cycle}");

        // ---- Stage 4: result accumulation (drains compare FIFO). ----
        if acc_block_ready {
            stats[3].busy_cycles += 1;
            acc_progress += 1;
            if acc_progress >= rows {
                acc_done += 1;
                acc_block_ready = false;
                acc_progress = 0;
            }
        } else if f_ca.can_pop() {
            f_ca.pop();
            acc_block_ready = true;
            stats[3].busy_cycles += 1;
            acc_progress = 1;
            if acc_progress >= rows {
                acc_done += 1;
                acc_block_ready = false;
                acc_progress = 0;
            }
        } else {
            stats[3].idle_cycles += 1;
        }

        // ---- Stage 3: comparison tree. ----
        if cmp_busy > 0 {
            stats[2].busy_cycles += 1;
            cmp_busy -= 1;
            if cmp_busy == 0 {
                if f_ca.can_push() {
                    f_ca.push();
                } else {
                    // Hold the result; retry next cycle.
                    cmp_busy = 1;
                    f_ca.full_stalls += 1;
                    stats[2].stall_cycles += 1;
                }
            }
        } else if f_dc.can_pop() {
            f_dc.pop();
            cmp_busy = cmp_latency;
            stats[2].busy_cycles += 1;
        } else {
            stats[2].idle_cycles += 1;
        }

        // ---- Stage 2: distance computation. ----
        match dist_block {
            Some(ref mut batches) => {
                stats[1].busy_cycles += 1;
                *batches += 1;
                if *batches >= tgt_batches {
                    if f_dc.can_push() {
                        f_dc.push();
                        dist_done += 1;
                        dist_block = None;
                    } else {
                        // Finished but output FIFO full: stall the array.
                        *batches -= 1; // re-issue last batch next cycle
                        f_dc.full_stalls += 1;
                        stats[1].stall_cycles += 1;
                    }
                }
            }
            None => {
                if f_rd.can_pop() && dist_done < src_blocks {
                    f_rd.pop();
                    dist_block = Some(0);
                    stats[1].busy_cycles += 1;
                } else {
                    stats[1].idle_cycles += 1;
                }
            }
        }

        // ---- Stage 1: data reading. ----
        if read_emitted < src_blocks {
            if read_progress < rows {
                read_progress += 1;
                stats[0].busy_cycles += 1;
            }
            if read_progress >= rows {
                if f_rd.can_push() {
                    f_rd.push();
                    read_emitted += 1;
                    read_progress = 0;
                } else {
                    f_rd.full_stalls += 1;
                    stats[0].stall_cycles += 1;
                }
            }
        } else {
            stats[0].idle_cycles += 1;
        }
    }

    SimResult {
        total_cycles: cycle,
        stages: stats,
        fifo_max_occupancy: [f_rd.max_occupancy, f_dc.max_occupancy, f_ca.max_occupancy],
        fifo_full_stalls: [f_rd.full_stalls, f_dc.full_stalls, f_ca.full_stalls],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwmodel::latency::nn_search_cycles;

    fn cfg() -> AcceleratorConfig {
        AcceleratorConfig::default()
    }

    #[test]
    fn terminates_and_processes_everything() {
        let r = simulate(&cfg(), 256, 4096);
        assert!(r.total_cycles > 0);
        // Each stage did some work.
        for s in &r.stages {
            assert!(s.busy_cycles > 0);
        }
    }

    #[test]
    fn agrees_with_closed_form_within_5_percent() {
        for (n, m) in [(256, 4096), (1024, 16_384), (4096, 65_536)] {
            let sim = simulate(&cfg(), n, m).total_cycles as f64;
            let model = nn_search_cycles(&cfg(), n, m) as f64;
            let rel = (sim - model).abs() / model;
            assert!(
                rel < 0.05,
                "sim {sim} vs model {model} at ({n},{m}): rel {rel:.3}"
            );
        }
    }

    #[test]
    fn distance_stage_dominates_at_steady_state() {
        // The architecture is designed so the distance stage is the
        // bottleneck (paper: "the most computationally intensive part").
        let r = simulate(&cfg(), 1024, 32_768);
        let dist_util = r.stages[1].utilization(r.total_cycles);
        assert!(dist_util > 0.95, "distance util {dist_util}");
        // Accumulate stage is mostly idle (rows << batches).
        let acc_util = r.stages[3].utilization(r.total_cycles);
        assert!(acc_util < 0.1, "accumulate util {acc_util}");
    }

    #[test]
    fn read_overlaps_distance() {
        // With double buffering, reading block i+1 overlaps computing
        // block i → total ≈ distance time, not read + distance.
        let r = simulate(&cfg(), 512, 8192);
        let c = cfg();
        let read_only = (512f64 / c.pe_rows as f64) * c.pe_rows as f64;
        let dist_only = (512f64 / c.pe_rows as f64) * (8192f64 / c.pe_cols as f64);
        assert!(
            (r.total_cycles as f64) < read_only + dist_only,
            "no overlap: {} >= {}",
            r.total_cycles,
            read_only + dist_only
        );
    }

    #[test]
    fn tiny_pipeline_exact_behaviour() {
        // 1 block, 1 batch: fill/drain dominated; just sanity-check
        // ordering (total > each stage's latency).
        let c = AcceleratorConfig {
            pe_rows: 4,
            pe_cols: 4,
            ..Default::default()
        };
        let r = simulate(&c, 4, 4);
        assert!(r.total_cycles >= 4 + 1 + 4 + 4);
        assert!(r.total_cycles < 40);
    }

    #[test]
    fn fifo_occupancy_bounded() {
        let r = simulate(&cfg(), 2048, 16_384);
        assert!(r.fifo_max_occupancy[0] <= 2);
        assert!(r.fifo_max_occupancy[1] <= 2);
        assert!(r.fifo_max_occupancy[2] <= 4);
    }

    #[test]
    fn utilization_partition() {
        // busy + stall + idle == total for every stage.
        let r = simulate(&cfg(), 512, 4096);
        for (i, s) in r.stages.iter().enumerate() {
            assert_eq!(
                s.busy_cycles + s.stall_cycles + s.idle_cycles,
                r.total_cycles,
                "stage {i}"
            );
        }
    }
}
