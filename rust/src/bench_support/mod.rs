//! Shared harness for the paper-table benches (Tables III/IV, §IV.D).
//!
//! Both sides of the comparison run per sequence:
//! * **CPU baseline** — the paper's software-only configuration: the
//!   full (raw) source cloud through PCL-equivalent kd-tree ICP.
//! * **FPPS hybrid** — the paper's accelerated configuration: a
//!   4096-point source sample through the device kernel, host SVD loop.
//!
//! The FPGA latency for Table IV comes from `hwmodel::latency` driven
//! by the *measured* per-frame iteration counts (the FPGA is
//! fixed-function: per-iteration time is capacity-determined, so only
//! the iteration count varies by sequence — visible in the paper's own
//! Table IV, where sequences share identical CPU+FPGA latencies).

use crate::coordinator::{run_odometry, PipelineConfig};
use crate::dataset::{lidar::LidarConfig, Sequence, SequenceSpec};
use crate::fpps_api::{
    BackendHandle, BackendKind, FppsIcp, KernelBackend, NativeSimBackend,
};
use crate::hwmodel::{latency, AcceleratorConfig};
use crate::icp::{IcpParams, SearchStrategy};
use crate::math::Mat4;
use anyhow::Result;
use std::path::Path;

/// Frames per sequence for the benches; keep small — every frame costs
/// a full 64-beam raycast + a full-cloud CPU ICP. Override with
/// `FPPS_BENCH_FRAMES`.
pub fn bench_frames() -> usize {
    std::env::var("FPPS_BENCH_FRAMES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(4)
}

/// LiDAR resolution for the benches: full 64 beams, reduced azimuth
/// (1200 steps ≈ 60–80k returns/frame) so a 10-sequence sweep stays in
/// bench-friendly time. `FPPS_BENCH_FULL_LIDAR=1` restores 2000 steps.
pub fn bench_lidar() -> LidarConfig {
    let full = std::env::var("FPPS_BENCH_FULL_LIDAR").as_deref() == Ok("1");
    LidarConfig {
        beams: 64,
        azimuth_steps: if full { 2000 } else { 1600 },
        ..Default::default()
    }
}

/// Per-sequence result of one pipeline run.
#[derive(Clone, Debug, Default)]
pub struct SeqResult {
    pub name: String,
    /// Mean registration RMSE over aligned frames (Table III metric).
    pub mean_rmse: f64,
    /// Mean measured per-frame latency on this host (ms).
    pub mean_latency_ms: f64,
    /// Mean ICP iteration count.
    pub mean_iterations: f64,
    pub frames: usize,
}

/// CPU baseline: full raw cloud, kd-tree correspondence (PCL-like).
pub fn run_cpu_baseline(seq: &Sequence, frames: usize) -> Result<SeqResult> {
    let params = IcpParams {
        search: SearchStrategy::KdTree,
        ..Default::default()
    };
    let mut rmse = Vec::new();
    let mut lat = Vec::new();
    let mut iters = Vec::new();
    let mut prev: Option<crate::pointcloud::PointCloud> = None;
    let mut prev_rel = Mat4::IDENTITY;
    for i in 0..frames.min(seq.len()) {
        let cloud = seq.frame(i)?;
        if let Some(target) = prev.take() {
            let t0 = std::time::Instant::now();
            let res = crate::icp::align(&cloud, &target, &prev_rel, &params);
            lat.push(t0.elapsed().as_secs_f64() * 1e3);
            rmse.push(res.rmse);
            iters.push(res.iterations as f64);
            prev_rel = if res.has_converged() {
                res.transformation
            } else {
                Mat4::IDENTITY
            };
        }
        prev = Some(cloud);
    }
    Ok(SeqResult {
        name: seq.spec.name.to_string(),
        mean_rmse: mean(&rmse),
        mean_latency_ms: mean(&lat),
        mean_iterations: mean(&iters),
        frames: rmse.len(),
    })
}

/// FPPS hybrid through the given backend.
pub fn run_fpps<B: KernelBackend>(
    seq: &Sequence,
    frames: usize,
    icp: &mut FppsIcp<B>,
) -> Result<SeqResult> {
    let cfg = PipelineConfig {
        // Keep the paper's raw-sampling semantics for comparability with
        // the CPU baseline above: same clouds, no front-end divergence,
        // identity initialisation (no multi-start — the paper aligns
        // scan-to-scan from the per-frame initial matrix only, which is
        // also why its Table III RMSE sits at 0.2–0.4 m).
        crop_range: 0.0,
        ground_z_min: f32::NEG_INFINITY,
        voxel_leaf: 0.0,
        bootstrap_seeds: 0,
        ..Default::default()
    };
    let res = run_odometry(seq, frames, cfg, icp)?;
    let rmse: Vec<f64> = res.records.iter().map(|r| r.rmse).collect();
    let lat: Vec<f64> = res.records.iter().map(|r| r.align_ms).collect();
    let iters: Vec<f64> = res.records.iter().map(|r| r.iterations as f64).collect();
    Ok(SeqResult {
        name: seq.spec.name.to_string(),
        mean_rmse: mean(&rmse),
        mean_latency_ms: mean(&lat),
        mean_iterations: mean(&iters),
        frames: res.records.len(),
    })
}

/// Projected CPU+FPGA per-frame latency (ms) at paper scale from the
/// measured iteration count (hwmodel; Table IV's accelerated rows).
pub fn projected_fpga_ms(mean_iterations: f64) -> f64 {
    let hw = AcceleratorConfig::default();
    let f = latency::frame_latency(
        &hw,
        hw.source_capacity,
        hw.target_capacity,
        mean_iterations.round().max(1.0) as u32,
    );
    f.total_s * 1e3
}

/// Preferred FPPS backend: the AOT artifact when present, else the
/// bit-faithful NativeSim mirror (identical numerics, no PJRT) — a thin
/// wrapper over the runtime-selectable `BackendHandle`.
pub struct AnyBackend {
    icp: FppsIcp<BackendHandle>,
}

impl AnyBackend {
    pub fn detect() -> AnyBackend {
        // `Auto` falls back to NativeSim internally and never errors.
        let icp = FppsIcp::with_kind(BackendKind::Auto, Path::new("artifacts"))
            .expect("Auto backend resolution is infallible");
        AnyBackend { icp }
    }

    /// NativeSim regardless of artifacts (used by benches where PJRT
    /// interpret-mode wall time would dominate the run for no signal).
    pub fn sim() -> AnyBackend {
        AnyBackend {
            icp: FppsIcp::with_backend(BackendHandle::NativeSim(NativeSimBackend::new())),
        }
    }

    pub fn name(&self) -> &'static str {
        self.icp.backend().name()
    }

    pub fn run(&mut self, seq: &Sequence, frames: usize) -> Result<SeqResult> {
        run_fpps(seq, frames, &mut self.icp)
    }
}

/// Build the synthetic stand-in for one paper sequence.
pub fn bench_sequence(spec: SequenceSpec, frames: usize) -> Sequence {
    Sequence::synthetic(spec, frames, 2026, bench_lidar())
}

pub fn mean(xs: &[f64]) -> f64 {
    let xs: Vec<f64> = xs.iter().copied().filter(|x| x.is_finite()).collect();
    if xs.is_empty() {
        f64::NAN
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::sequence_specs;

    #[test]
    fn cpu_and_fpps_run_one_small_sequence() {
        let spec = sequence_specs()[4].clone();
        let seq = Sequence::synthetic(
            spec,
            3,
            1,
            LidarConfig {
                beams: 32,
                azimuth_steps: 500,
                ..Default::default()
            },
        );
        let cpu = run_cpu_baseline(&seq, 3).unwrap();
        assert_eq!(cpu.frames, 2);
        assert!(cpu.mean_latency_ms > 0.0);
        let mut icp = FppsIcp::native_sim();
        let f = run_fpps(&seq, 3, &mut icp).unwrap();
        assert_eq!(f.frames, 2);
        assert!(f.mean_iterations >= 1.0);
        // Projected FPGA latency lands in the paper's Table IV range for
        // sane iteration counts.
        let ms = projected_fpga_ms(f.mean_iterations);
        assert!(ms > 10.0 && ms < 800.0, "{ms}");
    }

    #[test]
    fn mean_ignores_nan() {
        assert!((mean(&[1.0, f64::NAN, 3.0]) - 2.0).abs() < 1e-12);
        assert!(mean(&[]).is_nan());
    }
}
