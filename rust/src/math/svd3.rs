//! Singular value decomposition of 3×3 matrices via two-sided Jacobi.
//!
//! The host side of FPPS only ever decomposes the 3×3 cross-covariance
//! matrix H produced by the device's result accumulator, so a dedicated
//! 3×3 routine is both faster and easier to validate than a general
//! LAPACK-style driver. The algorithm:
//!
//! 1. One-sided Jacobi on A: repeatedly apply rotations J so that
//!    B = A·J has orthogonal columns (sweeps over the 3 column pairs
//!    until off-diagonal mass of BᵀB is negligible).
//! 2. Column norms of B are the singular values; U = B·diag(1/σ);
//!    V accumulates the Jacobi rotations.
//! 3. Sort σ descending, permute U/V, and fix signs so σᵢ ≥ 0.
//!
//! Degenerate columns (σ ≈ 0) get U columns completed via cross products
//! so U is always a full orthogonal matrix — required by the Kabsch
//! reflection guard, which inspects det(V·Uᵀ).

use super::Mat3;

/// SVD result: `a = u · diag(sigma) · vᵀ`, `sigma[0] ≥ sigma[1] ≥ sigma[2] ≥ 0`,
/// `u` and `v` orthogonal (not necessarily det +1).
#[derive(Clone, Copy, Debug)]
pub struct Svd3 {
    pub u: Mat3,
    pub sigma: [f64; 3],
    pub v: Mat3,
}

/// Compute the SVD of a 3×3 matrix. Always succeeds for finite input;
/// NaN/Inf inputs produce NaN outputs the caller should screen (see
/// `kabsch_from_sums`).
pub fn svd3(a: &Mat3) -> Svd3 {
    // Work on B = A (columns rotated in place), V accumulates rotations.
    let mut b = *a;
    let mut v = Mat3::IDENTITY;

    const MAX_SWEEPS: usize = 60;
    const EPS: f64 = 1e-15;

    for _sweep in 0..MAX_SWEEPS {
        let mut off = 0.0f64;
        // Column pairs (p, q): (0,1), (0,2), (1,2)
        for (p, q) in [(0usize, 1usize), (0, 2), (1, 2)] {
            // Gram entries of the current B.
            let mut app = 0.0;
            let mut aqq = 0.0;
            let mut apq = 0.0;
            for i in 0..3 {
                app += b.m[i][p] * b.m[i][p];
                aqq += b.m[i][q] * b.m[i][q];
                apq += b.m[i][p] * b.m[i][q];
            }
            off = off.max(apq.abs() / (app * aqq).sqrt().max(1e-300));
            if apq.abs() <= EPS * (app * aqq).sqrt() {
                continue;
            }
            // Jacobi rotation annihilating the (p,q) Gram entry.
            let tau = (aqq - app) / (2.0 * apq);
            let t = if tau >= 0.0 {
                1.0 / (tau + (1.0 + tau * tau).sqrt())
            } else {
                1.0 / (tau - (1.0 + tau * tau).sqrt())
            };
            let c = 1.0 / (1.0 + t * t).sqrt();
            let s = c * t;
            // B ← B·J, V ← V·J
            for i in 0..3 {
                let bp = b.m[i][p];
                let bq = b.m[i][q];
                b.m[i][p] = c * bp - s * bq;
                b.m[i][q] = s * bp + c * bq;
                let vp = v.m[i][p];
                let vq = v.m[i][q];
                v.m[i][p] = c * vp - s * vq;
                v.m[i][q] = s * vp + c * vq;
            }
        }
        if off < 1e-14 {
            break;
        }
    }

    // Singular values = column norms of B.
    let mut sigma = [0.0f64; 3];
    for j in 0..3 {
        let mut s = 0.0;
        for i in 0..3 {
            s += b.m[i][j] * b.m[i][j];
        }
        sigma[j] = s.sqrt();
    }

    // Sort descending, permuting B's and V's columns in lockstep.
    let mut order = [0usize, 1, 2];
    order.sort_by(|&i, &j| sigma[j].partial_cmp(&sigma[i]).unwrap());
    let (mut bs, mut vs, mut ss) = (Mat3::zero(), Mat3::zero(), [0.0f64; 3]);
    for (dst, &src) in order.iter().enumerate() {
        ss[dst] = sigma[src];
        for i in 0..3 {
            bs.m[i][dst] = b.m[i][src];
            vs.m[i][dst] = v.m[i][src];
        }
    }

    // U columns: normalised B columns; complete degenerate ones.
    let mut u = Mat3::zero();
    let tol = ss[0].max(1e-300) * 1e-12;
    let mut rank = 0;
    for j in 0..3 {
        if ss[j] > tol {
            for i in 0..3 {
                u.m[i][j] = bs.m[i][j] / ss[j];
            }
            rank = j + 1;
        }
    }
    complete_orthonormal(&mut u, rank);

    Svd3 {
        u,
        sigma: ss,
        v: vs,
    }
}

/// Fill columns `rank..3` of `u` so its columns form an orthonormal basis.
fn complete_orthonormal(u: &mut Mat3, rank: usize) {
    use super::Vec3;
    let mut cols: Vec<Vec3> = (0..rank).map(|j| u.col(j)).collect();
    while cols.len() < 3 {
        // Find a unit vector orthogonal to all current columns: start from
        // the least-aligned axis and Gram-Schmidt it.
        let mut best = Vec3::new(1.0, 0.0, 0.0);
        let mut best_res = -1.0f64;
        for axis in [
            Vec3::new(1.0, 0.0, 0.0),
            Vec3::new(0.0, 1.0, 0.0),
            Vec3::new(0.0, 0.0, 1.0),
        ] {
            let mut r = axis;
            for c in &cols {
                r = r - *c * c.dot(axis);
            }
            let n = r.norm();
            if n > best_res {
                best_res = n;
                best = r;
            }
        }
        cols.push(best.normalized());
    }
    for (j, c) in cols.iter().enumerate() {
        u.m[0][j] = c.x;
        u.m[1][j] = c.y;
        u.m[2][j] = c.z;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::Vec3;
    use crate::prop::forall;

    fn reconstruct(s: &Svd3) -> Mat3 {
        let mut sd = Mat3::zero();
        for i in 0..3 {
            sd.m[i][i] = s.sigma[i];
        }
        s.u.mul_mat(&sd).mul_mat(&s.v.transpose())
    }

    fn assert_orthogonal(m: &Mat3, tol: f64) {
        let g = m.transpose().mul_mat(m);
        assert!(
            g.max_abs_diff(&Mat3::IDENTITY) < tol,
            "not orthogonal: {m:?} gram {g:?}"
        );
    }

    #[test]
    fn identity() {
        let s = svd3(&Mat3::IDENTITY);
        assert!((s.sigma[0] - 1.0).abs() < 1e-14);
        assert!((s.sigma[2] - 1.0).abs() < 1e-14);
        assert!(reconstruct(&s).max_abs_diff(&Mat3::IDENTITY) < 1e-12);
    }

    #[test]
    fn diagonal_with_negatives() {
        let a = Mat3 {
            m: [[-3.0, 0.0, 0.0], [0.0, 2.0, 0.0], [0.0, 0.0, -0.5]],
        };
        let s = svd3(&a);
        assert!((s.sigma[0] - 3.0).abs() < 1e-12);
        assert!((s.sigma[1] - 2.0).abs() < 1e-12);
        assert!((s.sigma[2] - 0.5).abs() < 1e-12);
        assert!(reconstruct(&s).max_abs_diff(&a) < 1e-12);
        assert_orthogonal(&s.u, 1e-12);
        assert_orthogonal(&s.v, 1e-12);
    }

    #[test]
    fn random_matrices_reconstruct() {
        forall(200, |g| {
            let mut a = Mat3::zero();
            for i in 0..3 {
                for j in 0..3 {
                    a.m[i][j] = g.f32_range(-10.0, 10.0) as f64;
                }
            }
            let s = svd3(&a);
            let err = reconstruct(&s).max_abs_diff(&a);
            assert!(err < 1e-9 * (1.0 + s.sigma[0]), "err={err} case={}", g.case);
            assert_orthogonal(&s.u, 1e-9);
            assert_orthogonal(&s.v, 1e-9);
            assert!(s.sigma[0] >= s.sigma[1] && s.sigma[1] >= s.sigma[2]);
            assert!(s.sigma[2] >= 0.0);
        });
    }

    #[test]
    fn rank_one() {
        let u = Vec3::new(1.0, 2.0, 3.0);
        let v = Vec3::new(-1.0, 0.5, 2.0);
        let a = Mat3::outer(u, v);
        let s = svd3(&a);
        assert!((s.sigma[0] - u.norm() * v.norm()).abs() < 1e-10);
        assert!(s.sigma[1] < 1e-10);
        assert!(s.sigma[2] < 1e-10);
        assert!(reconstruct(&s).max_abs_diff(&a) < 1e-10);
        // U must still be fully orthogonal for the Kabsch det() guard.
        assert_orthogonal(&s.u, 1e-9);
        assert_orthogonal(&s.v, 1e-9);
    }

    #[test]
    fn rank_two() {
        // Two independent outer products → rank 2.
        let a = Mat3::outer(Vec3::new(1.0, 0.0, 0.0), Vec3::new(0.0, 2.0, 0.0));
        let b = Mat3::outer(Vec3::new(0.0, 1.0, 0.0), Vec3::new(3.0, 0.0, 0.0));
        let mut m = Mat3::zero();
        for i in 0..3 {
            for j in 0..3 {
                m.m[i][j] = a.m[i][j] + b.m[i][j];
            }
        }
        let s = svd3(&m);
        assert!(s.sigma[1] > 1.0);
        assert!(s.sigma[2] < 1e-10);
        assert!(reconstruct(&s).max_abs_diff(&m) < 1e-10);
        assert_orthogonal(&s.u, 1e-9);
    }

    #[test]
    fn zero_matrix() {
        let s = svd3(&Mat3::zero());
        assert_eq!(s.sigma, [0.0, 0.0, 0.0]);
        assert_orthogonal(&s.u, 1e-12);
        assert_orthogonal(&s.v, 1e-12);
    }

    #[test]
    fn near_singular_conditioning() {
        // σ spread over 12 orders of magnitude still reconstructs.
        let d = Mat3 {
            m: [[1e6, 0.0, 0.0], [0.0, 1.0, 0.0], [0.0, 0.0, 1e-6]],
        };
        let r1 = Mat3::axis_angle([1.0, 1.0, 0.0], 0.7);
        let r2 = Mat3::axis_angle([0.0, 1.0, 1.0], -0.4);
        let a = r1.mul_mat(&d).mul_mat(&r2);
        let s = svd3(&a);
        assert!((s.sigma[0] - 1e6).abs() / 1e6 < 1e-10);
        assert!((s.sigma[1] - 1.0).abs() < 1e-8);
        let err = reconstruct(&s).max_abs_diff(&a);
        assert!(err < 1e-6, "err={err}");
    }

    #[test]
    fn rotations_have_unit_singular_values() {
        forall(100, |g| {
            let r = g.rotation(3.1);
            let s = svd3(&r);
            for k in 0..3 {
                assert!((s.sigma[k] - 1.0).abs() < 1e-9, "sigma={:?}", s.sigma);
            }
        });
    }
}
