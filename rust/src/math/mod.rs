//! Small dense linear algebra for rigid registration.
//!
//! Everything the host side of FPPS needs: 3-vectors, 3×3 / 4×4 matrices,
//! a robust Jacobi SVD for 3×3 (in [`svd3`]), and the Kabsch/Umeyama
//! closed-form rigid transform estimation used in ICP's transformation
//! estimation step (paper §II, step 2).
//!
//! Host math is `f64` throughout — mirroring PCL, whose registration
//! pipeline accumulates in double — while clouds and the device kernel
//! are `f32`.

pub mod svd3;

pub use svd3::{svd3, Svd3};

/// 3-vector (f64; host math).
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub struct Vec3 {
    pub x: f64,
    pub y: f64,
    pub z: f64,
}

impl Vec3 {
    pub const ZERO: Vec3 = Vec3 { x: 0.0, y: 0.0, z: 0.0 };

    pub fn new(x: f64, y: f64, z: f64) -> Self {
        Self { x, y, z }
    }

    pub fn from_f32(p: [f32; 3]) -> Self {
        Self::new(p[0] as f64, p[1] as f64, p[2] as f64)
    }

    pub fn to_f32(self) -> [f32; 3] {
        [self.x as f32, self.y as f32, self.z as f32]
    }

    pub fn dot(self, o: Vec3) -> f64 {
        self.x * o.x + self.y * o.y + self.z * o.z
    }

    pub fn cross(self, o: Vec3) -> Vec3 {
        Vec3::new(
            self.y * o.z - self.z * o.y,
            self.z * o.x - self.x * o.z,
            self.x * o.y - self.y * o.x,
        )
    }

    pub fn norm2(self) -> f64 {
        self.dot(self)
    }

    pub fn norm(self) -> f64 {
        self.norm2().sqrt()
    }

    pub fn normalized(self) -> Vec3 {
        let n = self.norm();
        if n == 0.0 {
            Vec3::ZERO
        } else {
            self * (1.0 / n)
        }
    }

    pub fn scale(self, s: f64) -> Vec3 {
        self * s
    }
}

impl std::ops::Add for Vec3 {
    type Output = Vec3;
    fn add(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x + o.x, self.y + o.y, self.z + o.z)
    }
}

impl std::ops::Sub for Vec3 {
    type Output = Vec3;
    fn sub(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x - o.x, self.y - o.y, self.z - o.z)
    }
}

impl std::ops::Mul<f64> for Vec3 {
    type Output = Vec3;
    fn mul(self, s: f64) -> Vec3 {
        Vec3::new(self.x * s, self.y * s, self.z * s)
    }
}

impl std::ops::Neg for Vec3 {
    type Output = Vec3;
    fn neg(self) -> Vec3 {
        Vec3::new(-self.x, -self.y, -self.z)
    }
}

/// Row-major 3×3 matrix.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub struct Mat3 {
    pub m: [[f64; 3]; 3],
}

impl Mat3 {
    pub const IDENTITY: Mat3 = Mat3 {
        m: [[1.0, 0.0, 0.0], [0.0, 1.0, 0.0], [0.0, 0.0, 1.0]],
    };

    pub fn zero() -> Mat3 {
        Mat3 { m: [[0.0; 3]; 3] }
    }

    pub fn from_rows(r0: Vec3, r1: Vec3, r2: Vec3) -> Mat3 {
        Mat3 {
            m: [
                [r0.x, r0.y, r0.z],
                [r1.x, r1.y, r1.z],
                [r2.x, r2.y, r2.z],
            ],
        }
    }

    pub fn col(&self, j: usize) -> Vec3 {
        Vec3::new(self.m[0][j], self.m[1][j], self.m[2][j])
    }

    pub fn row(&self, i: usize) -> Vec3 {
        Vec3::new(self.m[i][0], self.m[i][1], self.m[i][2])
    }

    pub fn transpose(&self) -> Mat3 {
        let m = &self.m;
        Mat3 {
            m: [
                [m[0][0], m[1][0], m[2][0]],
                [m[0][1], m[1][1], m[2][1]],
                [m[0][2], m[1][2], m[2][2]],
            ],
        }
    }

    pub fn det(&self) -> f64 {
        let m = &self.m;
        m[0][0] * (m[1][1] * m[2][2] - m[1][2] * m[2][1])
            - m[0][1] * (m[1][0] * m[2][2] - m[1][2] * m[2][0])
            + m[0][2] * (m[1][0] * m[2][1] - m[1][1] * m[2][0])
    }

    pub fn trace(&self) -> f64 {
        self.m[0][0] + self.m[1][1] + self.m[2][2]
    }

    pub fn mul_vec(&self, v: Vec3) -> Vec3 {
        Vec3::new(
            self.row(0).dot(v),
            self.row(1).dot(v),
            self.row(2).dot(v),
        )
    }

    pub fn mul_mat(&self, o: &Mat3) -> Mat3 {
        let mut r = Mat3::zero();
        for i in 0..3 {
            for j in 0..3 {
                let mut s = 0.0;
                for k in 0..3 {
                    s += self.m[i][k] * o.m[k][j];
                }
                r.m[i][j] = s;
            }
        }
        r
    }

    pub fn scale(&self, s: f64) -> Mat3 {
        let mut r = *self;
        for i in 0..3 {
            for j in 0..3 {
                r.m[i][j] *= s;
            }
        }
        r
    }

    pub fn sub(&self, o: &Mat3) -> Mat3 {
        let mut r = *self;
        for i in 0..3 {
            for j in 0..3 {
                r.m[i][j] -= o.m[i][j];
            }
        }
        r
    }

    /// Outer product a·bᵀ.
    pub fn outer(a: Vec3, b: Vec3) -> Mat3 {
        Mat3 {
            m: [
                [a.x * b.x, a.x * b.y, a.x * b.z],
                [a.y * b.x, a.y * b.y, a.y * b.z],
                [a.z * b.x, a.z * b.y, a.z * b.z],
            ],
        }
    }

    /// Rotation about `axis` (need not be normalised) by `angle` rad —
    /// Rodrigues' formula.
    pub fn axis_angle(axis: [f32; 3], angle: f32) -> Mat3 {
        let a = Vec3::new(axis[0] as f64, axis[1] as f64, axis[2] as f64).normalized();
        let (s, c) = (angle as f64).sin_cos();
        let t = 1.0 - c;
        Mat3 {
            m: [
                [
                    t * a.x * a.x + c,
                    t * a.x * a.y - s * a.z,
                    t * a.x * a.z + s * a.y,
                ],
                [
                    t * a.x * a.y + s * a.z,
                    t * a.y * a.y + c,
                    t * a.y * a.z - s * a.x,
                ],
                [
                    t * a.x * a.z - s * a.y,
                    t * a.y * a.z + s * a.x,
                    t * a.z * a.z + c,
                ],
            ],
        }
    }

    /// Rotation about +Z (vehicle yaw).
    pub fn rot_z(angle: f64) -> Mat3 {
        let (s, c) = angle.sin_cos();
        Mat3 {
            m: [[c, -s, 0.0], [s, c, 0.0], [0.0, 0.0, 1.0]],
        }
    }

    /// Frobenius norm.
    pub fn frobenius(&self) -> f64 {
        let mut s = 0.0;
        for i in 0..3 {
            for j in 0..3 {
                s += self.m[i][j] * self.m[i][j];
            }
        }
        s.sqrt()
    }

    /// Max |mᵢⱼ − oᵢⱼ|.
    pub fn max_abs_diff(&self, o: &Mat3) -> f64 {
        let mut d: f64 = 0.0;
        for i in 0..3 {
            for j in 0..3 {
                d = d.max((self.m[i][j] - o.m[i][j]).abs());
            }
        }
        d
    }

    /// Is this a proper rotation (orthogonal, det ≈ +1)?
    pub fn is_rotation(&self, tol: f64) -> bool {
        let rtr = self.transpose().mul_mat(self);
        rtr.max_abs_diff(&Mat3::IDENTITY) < tol && (self.det() - 1.0).abs() < tol
    }

    /// Geodesic rotation angle between two rotations (radians).
    pub fn rotation_angle_to(&self, o: &Mat3) -> f64 {
        let r = self.transpose().mul_mat(o);
        let c = ((r.trace() - 1.0) * 0.5).clamp(-1.0, 1.0);
        c.acos()
    }
}

/// Row-major 4×4 rigid transform (R | t over 0 0 0 1) — the paper's
/// `T_j` of Eq. (2).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Mat4 {
    pub m: [[f64; 4]; 4],
}

impl Mat4 {
    pub const IDENTITY: Mat4 = Mat4 {
        m: [
            [1.0, 0.0, 0.0, 0.0],
            [0.0, 1.0, 0.0, 0.0],
            [0.0, 0.0, 1.0, 0.0],
            [0.0, 0.0, 0.0, 1.0],
        ],
    };

    /// Augment rotation + translation (Eq. 2).
    pub fn from_rt(r: Mat3, t: Vec3) -> Mat4 {
        Mat4 {
            m: [
                [r.m[0][0], r.m[0][1], r.m[0][2], t.x],
                [r.m[1][0], r.m[1][1], r.m[1][2], t.y],
                [r.m[2][0], r.m[2][1], r.m[2][2], t.z],
                [0.0, 0.0, 0.0, 1.0],
            ],
        }
    }

    pub fn rotation(&self) -> Mat3 {
        let m = &self.m;
        Mat3 {
            m: [
                [m[0][0], m[0][1], m[0][2]],
                [m[1][0], m[1][1], m[1][2]],
                [m[2][0], m[2][1], m[2][2]],
            ],
        }
    }

    pub fn translation(&self) -> Vec3 {
        Vec3::new(self.m[0][3], self.m[1][3], self.m[2][3])
    }

    pub fn mul_mat(&self, o: &Mat4) -> Mat4 {
        let mut r = Mat4 { m: [[0.0; 4]; 4] };
        for i in 0..4 {
            for j in 0..4 {
                let mut s = 0.0;
                for k in 0..4 {
                    s += self.m[i][k] * o.m[k][j];
                }
                r.m[i][j] = s;
            }
        }
        r
    }

    /// Apply to a point (w = 1).
    pub fn apply(&self, p: Vec3) -> Vec3 {
        let r = self.rotation().mul_vec(p);
        r + self.translation()
    }

    /// Rigid inverse: [Rᵀ | −Rᵀt].
    pub fn inverse_rigid(&self) -> Mat4 {
        let rt = self.rotation().transpose();
        let t = -rt.mul_vec(self.translation());
        Mat4::from_rt(rt, t)
    }

    /// Row-major f32 flattening — the wire format fed to the device
    /// kernel (the paper's `setTransformationMatrix` argument layout).
    pub fn to_f32_row_major(&self) -> [f32; 16] {
        let mut out = [0f32; 16];
        for i in 0..4 {
            for j in 0..4 {
                out[i * 4 + j] = self.m[i][j] as f32;
            }
        }
        out
    }

    pub fn from_f32_row_major(v: &[f32; 16]) -> Mat4 {
        let mut m = [[0.0f64; 4]; 4];
        for i in 0..4 {
            for j in 0..4 {
                m[i][j] = v[i * 4 + j] as f64;
            }
        }
        Mat4 { m }
    }

    /// Convergence metric used by PCL's `transformationEpsilon`: the max
    /// absolute element of (T − I), i.e. how far this incremental
    /// transform is from "no further motion" (paper §II step 4).
    pub fn delta_from_identity(&self) -> f64 {
        let mut d: f64 = 0.0;
        for i in 0..4 {
            for j in 0..4 {
                let target = if i == j { 1.0 } else { 0.0 };
                d = d.max((self.m[i][j] - target).abs());
            }
        }
        d
    }
}

/// Result of the closed-form rigid estimation.
#[derive(Clone, Copy, Debug)]
pub struct RigidEstimate {
    pub rotation: Mat3,
    pub translation: Vec3,
}

impl RigidEstimate {
    pub fn to_mat4(&self) -> Mat4 {
        Mat4::from_rt(self.rotation, self.translation)
    }
}

/// Kabsch/Umeyama rigid transform from correspondence accumulators.
///
/// This is the host half of the paper's "transformation estimation"
/// (§II step 2): the device accumulates `count`, `Σp`, `Σq`, `Σp·qᵀ`
/// (the *result accumulator* block of Fig. 2) and the host finishes with
/// the 3×3 SVD:
///
///   H = Σp·qᵀ − (Σp)(Σq)ᵀ/n,   H = UΣVᵀ,
///   R = V·diag(1,1,det(VUᵀ))·Uᵀ,   t = q̄ − R·p̄.
///
/// Returns `None` when there are too few correspondences (n < 3) or the
/// covariance is numerically degenerate.
pub fn kabsch_from_sums(
    count: f64,
    sum_p: Vec3,
    sum_q: Vec3,
    sum_pq: &Mat3,
) -> Option<RigidEstimate> {
    if count < 3.0 {
        return None;
    }
    let inv_n = 1.0 / count;
    let cp = sum_p * inv_n;
    let cq = sum_q * inv_n;
    // Cross-covariance H = Σ (p−p̄)(q−q̄)ᵀ = Σpqᵀ − n·p̄q̄ᵀ
    let h = sum_pq.sub(&Mat3::outer(sum_p, sum_q).scale(inv_n));
    if !h.frobenius().is_finite() {
        return None;
    }
    let Svd3 { u, sigma, v } = svd3(&h);
    // Guard against a degenerate (rank < 2) covariance: rotation is then
    // under-determined and ICP should reject the step.
    if sigma[1] <= 1e-12 * sigma[0].max(1e-300) {
        return None;
    }
    let d = v.mul_mat(&u.transpose()).det();
    let sign = if d < 0.0 { -1.0 } else { 1.0 };
    // R = V diag(1,1,sign) Uᵀ
    let mut v_fixed = v;
    for i in 0..3 {
        v_fixed.m[i][2] *= sign;
    }
    let r = v_fixed.mul_mat(&u.transpose());
    let t = cq - r.mul_vec(cp);
    Some(RigidEstimate {
        rotation: r,
        translation: t,
    })
}

/// Kabsch from explicit correspondence lists (used by the CPU baseline
/// and in tests as the oracle for the accumulator path).
pub fn kabsch_from_pairs(ps: &[Vec3], qs: &[Vec3]) -> Option<RigidEstimate> {
    assert_eq!(ps.len(), qs.len());
    let n = ps.len() as f64;
    if ps.len() < 3 {
        return None;
    }
    let mut sum_p = Vec3::ZERO;
    let mut sum_q = Vec3::ZERO;
    let mut sum_pq = Mat3::zero();
    for (&p, &q) in ps.iter().zip(qs.iter()) {
        sum_p = sum_p + p;
        sum_q = sum_q + q;
        for i in 0..3 {
            for j in 0..3 {
                let pi = [p.x, p.y, p.z][i];
                let qj = [q.x, q.y, q.z][j];
                sum_pq.m[i][j] += pi * qj;
            }
        }
    }
    kabsch_from_sums(n, sum_p, sum_q, &sum_pq)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::forall;

    fn assert_vec_close(a: Vec3, b: Vec3, tol: f64) {
        assert!(
            (a - b).norm() < tol,
            "vectors differ: {a:?} vs {b:?} (tol {tol})"
        );
    }

    #[test]
    fn mat3_identities() {
        let r = Mat3::axis_angle([0.3, -0.5, 0.8], 0.7);
        assert!(r.is_rotation(1e-12));
        let rt = r.transpose();
        assert!(r.mul_mat(&rt).max_abs_diff(&Mat3::IDENTITY) < 1e-12);
        assert!((r.det() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn rot_z_quarter_turn() {
        let r = Mat3::rot_z(std::f64::consts::FRAC_PI_2);
        let v = r.mul_vec(Vec3::new(1.0, 0.0, 0.0));
        assert_vec_close(v, Vec3::new(0.0, 1.0, 0.0), 1e-12);
    }

    #[test]
    fn mat4_rigid_inverse() {
        forall(50, |g| {
            let r = g.rotation(3.0);
            let t = Vec3::from_f32(g.point(5.0));
            let m = Mat4::from_rt(r, t);
            let inv = m.inverse_rigid();
            let prod = m.mul_mat(&inv);
            assert!(prod.delta_from_identity() < 1e-9, "{prod:?}");
        });
    }

    #[test]
    fn mat4_apply_matches_rt() {
        let r = Mat3::axis_angle([0.0, 0.0, 1.0], 0.5);
        let t = Vec3::new(1.0, 2.0, 3.0);
        let m = Mat4::from_rt(r, t);
        let p = Vec3::new(0.5, -0.25, 2.0);
        assert_vec_close(m.apply(p), r.mul_vec(p) + t, 1e-14);
    }

    #[test]
    fn mat4_f32_roundtrip() {
        let m = Mat4::from_rt(
            Mat3::axis_angle([1.0, 2.0, 3.0], 0.3),
            Vec3::new(0.1, 0.2, 0.3),
        );
        let rt = Mat4::from_f32_row_major(&m.to_f32_row_major());
        for i in 0..4 {
            for j in 0..4 {
                assert!((m.m[i][j] - rt.m[i][j]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn kabsch_recovers_known_transform() {
        forall(100, |g| {
            let r = g.rotation(3.0);
            let t = Vec3::from_f32(g.point(10.0));
            let n = g.usize_range(4, 64);
            let ps: Vec<Vec3> = g.points(n, 5.0).into_iter().map(Vec3::from_f32).collect();
            let qs: Vec<Vec3> = ps.iter().map(|&p| r.mul_vec(p) + t).collect();
            let est = kabsch_from_pairs(&ps, &qs).expect("estimate");
            assert!(
                est.rotation.max_abs_diff(&r) < 1e-6,
                "rotation mismatch case {}",
                g.case
            );
            assert_vec_close(est.translation, t, 1e-5);
        });
    }

    #[test]
    fn kabsch_handles_reflection_guard() {
        // Coplanar points whose best orthogonal alignment would be a
        // reflection; the det() guard must still return a rotation.
        let ps = vec![
            Vec3::new(1.0, 0.0, 0.0),
            Vec3::new(0.0, 1.0, 0.0),
            Vec3::new(-1.0, 0.0, 0.0),
            Vec3::new(0.0, -1.0, 0.0),
        ];
        // Mirror through the XY plane then rotate.
        let r = Mat3::rot_z(0.3);
        let qs: Vec<Vec3> = ps
            .iter()
            .map(|&p| r.mul_vec(Vec3::new(p.x, p.y, -p.z)))
            .collect();
        let est = kabsch_from_pairs(&ps, &qs).expect("estimate");
        assert!(est.rotation.is_rotation(1e-9), "must be proper rotation");
    }

    #[test]
    fn kabsch_rejects_degenerate() {
        // All points identical → rank-0 covariance.
        let ps = vec![Vec3::new(1.0, 1.0, 1.0); 5];
        let qs = vec![Vec3::new(2.0, 2.0, 2.0); 5];
        assert!(kabsch_from_pairs(&ps, &qs).is_none());
        // Fewer than 3 pairs.
        assert!(kabsch_from_pairs(&ps[..2], &qs[..2]).is_none());
    }

    #[test]
    fn kabsch_sums_match_pairs_path() {
        forall(50, |g| {
            let n = g.usize_range(3, 32);
            let ps: Vec<Vec3> = g.points(n, 2.0).into_iter().map(Vec3::from_f32).collect();
            let r = g.rotation(1.0);
            let t = Vec3::from_f32(g.point(1.0));
            let qs: Vec<Vec3> = ps
                .iter()
                .map(|&p| r.mul_vec(p) + t + Vec3::from_f32(g.point(0.01)))
                .collect();
            let a = kabsch_from_pairs(&ps, &qs);
            // Rebuild through the accumulator API.
            let mut sum_p = Vec3::ZERO;
            let mut sum_q = Vec3::ZERO;
            let mut sum_pq = Mat3::zero();
            for (&p, &q) in ps.iter().zip(qs.iter()) {
                sum_p = sum_p + p;
                sum_q = sum_q + q;
                sum_pq = Mat3 {
                    m: {
                        let o = Mat3::outer(p, q);
                        let mut m = sum_pq.m;
                        for i in 0..3 {
                            for j in 0..3 {
                                m[i][j] += o.m[i][j];
                            }
                        }
                        m
                    },
                };
            }
            let b = kabsch_from_sums(n as f64, sum_p, sum_q, &sum_pq);
            match (a, b) {
                (Some(a), Some(b)) => {
                    assert!(a.rotation.max_abs_diff(&b.rotation) < 1e-9);
                    assert_vec_close(a.translation, b.translation, 1e-9);
                }
                (None, None) => {}
                _ => panic!("paths disagree on degeneracy"),
            }
        });
    }

    #[test]
    fn rotation_angle_metric() {
        let a = Mat3::rot_z(0.0);
        let b = Mat3::rot_z(0.25);
        assert!((a.rotation_angle_to(&b) - 0.25).abs() < 1e-12);
    }
}
