//! Deterministic pseudo-random number generation.
//!
//! The crate is fully offline and self-contained, so instead of the `rand`
//! ecosystem we ship two small, well-known generators:
//!
//! * [`SplitMix64`] — used for seeding and cheap hashing-style streams.
//! * [`Pcg32`] — the main generator (PCG-XSH-RR 64/32), statistically
//!   strong enough for synthetic-data generation and property tests.
//!
//! All dataset generation, sampling and property tests derive from an
//! explicit `u64` seed so every experiment in EXPERIMENTS.md is exactly
//! reproducible.

/// SplitMix64: fast, full-period 64-bit generator; the standard seeder.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSH-RR 64/32: O'Neill's recommended small generator.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Seeds state and stream from `seed` via SplitMix64 (so nearby seeds
    /// still give uncorrelated streams).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let inc = sm.next_u64() | 1;
        let mut rng = Self {
            state: sm.next_u64(),
            inc,
        };
        rng.next_u32();
        rng
    }

    /// Independent sub-stream `i` of this generator's seed; used to give
    /// each frame / sequence / property-test case its own stream.
    pub fn substream(seed: u64, i: u64) -> Self {
        let mut sm = SplitMix64::new(seed ^ i.wrapping_mul(0xA24BAED4963EE407));
        Self::new(sm.next_u64())
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        // 24 mantissa bits → exactly representable, unbiased.
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [0, 1) with f64 precision (trajectory integration).
    #[inline]
    pub fn uniform_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (n as u64);
        let mut l = m as u32;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (n as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(f32::MIN_POSITIVE);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal()
    }

    /// Uniform point on the unit sphere.
    pub fn unit_vector(&mut self) -> [f32; 3] {
        loop {
            let x = self.range(-1.0, 1.0);
            let y = self.range(-1.0, 1.0);
            let z = self.range(-1.0, 1.0);
            let n2 = x * x + y * y + z * z;
            if n2 > 1e-6 && n2 <= 1.0 {
                let inv = n2.sqrt().recip();
                return [x * inv, y * inv, z * inv];
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<u32> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        let mut idx: Vec<u32> = (0..n as u32).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u32) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference values from the public-domain splitmix64.c with seed 0.
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xE220A8397B1DCDAF);
        assert_eq!(sm.next_u64(), 0x6E789E6AA1B965F4);
        assert_eq!(sm.next_u64(), 0x06C45D188009454F);
    }

    #[test]
    fn pcg_deterministic_and_seed_sensitive() {
        let a: Vec<u32> = {
            let mut r = Pcg32::new(42);
            (0..8).map(|_| r.next_u32()).collect()
        };
        let b: Vec<u32> = {
            let mut r = Pcg32::new(42);
            (0..8).map(|_| r.next_u32()).collect()
        };
        let c: Vec<u32> = {
            let mut r = Pcg32::new(43);
            (0..8).map(|_| r.next_u32()).collect()
        };
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn uniform_in_unit_interval_and_roughly_uniform() {
        let mut r = Pcg32::new(7);
        let n = 20_000;
        let mut mean = 0.0f64;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            mean += u as f64;
        }
        mean /= n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_over_small_range() {
        let mut r = Pcg32::new(11);
        let mut counts = [0u32; 7];
        let n = 70_000;
        for _ in 0..n {
            counts[r.below(7) as usize] += 1;
        }
        for &c in &counts {
            let expect = n as f64 / 7.0;
            assert!((c as f64 - expect).abs() < expect * 0.1, "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::new(13);
        let n = 50_000;
        let (mut m1, mut m2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            m1 += x;
            m2 += x * x;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!(m1.abs() < 0.02, "mean={m1}");
        assert!((m2 - 1.0).abs() < 0.05, "var={m2}");
    }

    #[test]
    fn unit_vectors_have_unit_norm() {
        let mut r = Pcg32::new(17);
        for _ in 0..100 {
            let [x, y, z] = r.unit_vector();
            let n = (x * x + y * y + z * z).sqrt();
            assert!((n - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Pcg32::new(19);
        let idx = r.sample_indices(1000, 128);
        assert_eq!(idx.len(), 128);
        let mut seen = std::collections::HashSet::new();
        for &i in &idx {
            assert!(i < 1000);
            assert!(seen.insert(i), "duplicate index {i}");
        }
    }

    #[test]
    fn substreams_are_uncorrelated() {
        let mut a = Pcg32::substream(42, 0);
        let mut b = Pcg32::substream(42, 1);
        let mut same = 0;
        for _ in 0..1000 {
            if a.next_u32() == b.next_u32() {
                same += 1;
            }
        }
        assert!(same < 3);
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Pcg32::new(23);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
