//! In-repo invariant linter for the lock-free data plane.
//!
//! Walks the crate sources (`rust/src` by default) and enforces the
//! concurrency-hygiene rules that `clippy` cannot express:
//!
//! - **R1 (safety-comments)** — every `unsafe` block or `unsafe impl`
//!   carries a `// SAFETY:` comment on the same line or in the eight
//!   lines above it. (`unsafe fn` *declarations* are exempt: the
//!   obligation sits at the call/impl site, matching
//!   `clippy::undocumented_unsafe_blocks`.)
//! - **R2 (ordering-comments)** — every non-`SeqCst` memory ordering
//!   (`Relaxed` / `Acquire` / `Release` / `AcqRel`) carries an
//!   `// ordering:` justification on the same line or in the eight
//!   lines above it. `SeqCst` is the self-explanatory default and needs
//!   no comment.
//! - **R3 (panic-free runtime)** — no `unwrap()` / `expect()` /
//!   `panic!` / `unreachable!` / `todo!` / `unimplemented!` on the
//!   coordinator / pool runtime paths. Lock acquisition
//!   (`.lock().unwrap()` — poisoning only follows a panic that already
//!   tore the pool down) and condvar waits are exempt, as is the
//!   explicit allowlist below; tests are always exempt.
//! - **R4 (documented surface)** — every `pub` fn / struct / enum /
//!   trait / type / const / static in `coordinator` / `pool` has a
//!   `///` doc comment (`pub mod` is covered by the module's own `//!`
//!   docs).
//!
//! R1/R2 apply to the whole tree; R3/R4 only to `src/coordinator` and
//! `src/pool` (the supervised data plane, where a stray panic kills a
//! lane). The trailing `#[cfg(test)] mod tests` of each file is
//! skipped — every file in this crate keeps its tests last.
//!
//! Usage: `cargo run --bin fpps_lint` (add a path argument to lint
//! another tree). Exits nonzero when any violation is found.
//! `--self-test` seeds one violation per rule through the same checker
//! and fails if any goes undetected — CI runs it before trusting the
//! clean pass.

use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// Deliberate panic sites on the supervised runtime paths: invariants
/// that are locally provable (and cheaper to prove than to thread
/// `Result` through the dispatcher). Keyed by path suffix + a needle
/// that must appear on the flagged line.
const PANIC_ALLOWLIST: &[(&str, &str)] = &[
    ("coordinator/supervise.rs", "created above"),
    ("coordinator/supervise.rs", "respawned above"),
    ("coordinator/supervise.rs", "every unclaimed job resolves"),
    ("coordinator/completion.rs", "completion outcome already consumed"),
    ("coordinator/pipeline.rs", "at least one bootstrap attempt"),
    ("coordinator/pipeline.rs", "poses.last().unwrap()"),
    ("coordinator/scenarios.rs", "each scan emitted once"),
];

/// Non-SeqCst orderings that need an `// ordering:` justification.
const WEAK_ORDERINGS: &[&str] = &[
    "Ordering::Relaxed",
    "Ordering::Acquire",
    "Ordering::Release",
    "Ordering::AcqRel",
];

/// Panic constructs banned from the coordinator/pool runtime paths.
const PANIC_PATTERNS: &[&str] = &[
    ".unwrap()",
    ".expect(",
    "panic!(",
    "unreachable!(",
    "todo!(",
    "unimplemented!(",
];

/// Pub-item keywords R4 requires docs for (`pub mod` / `pub use` /
/// `pub(crate)` / pub struct fields are out of scope).
const PUB_ITEMS: &[&str] = &[
    "pub fn ",
    "pub struct ",
    "pub enum ",
    "pub trait ",
    "pub type ",
    "pub const ",
    "pub static ",
];

/// How many lines above a flagged site a justifying comment may sit.
const COMMENT_WINDOW: usize = 8;

struct Finding {
    file: String,
    line: usize,
    rule: &'static str,
    msg: &'static str,
}

/// One source line split into its code part (string literals blanked,
/// comments removed) and its line-comment text.
struct Line<'a> {
    raw: &'a str,
    code: String,
    comment: String,
}

/// Split a line into code and comment, blanking string literals so
/// pattern text inside them cannot trigger (or suppress) a rule.
/// Handles escapes and char literals; block comments are rare in this
/// tree and treated as code.
fn split_line(raw: &str) -> (String, String) {
    let b = raw.as_bytes();
    let mut code = String::with_capacity(raw.len());
    let mut i = 0;
    while i < b.len() {
        let c = b[i];
        if c == b'"' {
            // Blank the string literal.
            code.push('"');
            i += 1;
            while i < b.len() {
                match b[i] {
                    b'\\' => i += 2,
                    b'"' => {
                        i += 1;
                        break;
                    }
                    _ => i += 1,
                }
            }
            code.push('"');
            continue;
        }
        if c == b'\'' {
            // Char literal ('x', '\n', '\'') vs lifetime ('a).
            if i + 2 < b.len() && b[i + 1] == b'\\' {
                if let Some(off) = b[i + 2..].iter().position(|&x| x == b'\'') {
                    code.push_str("' '");
                    i += off + 3;
                    continue;
                }
            } else if i + 2 < b.len() && b[i + 2] == b'\'' {
                code.push_str("' '");
                i += 3;
                continue;
            }
            // Lifetime: keep as-is.
            code.push('\'');
            i += 1;
            continue;
        }
        if c == b'/' && i + 1 < b.len() && b[i + 1] == b'/' {
            return (code, raw[i..].to_string());
        }
        code.push(c as char);
        i += 1;
    }
    (code, String::new())
}

/// Does any comment on this line or the `COMMENT_WINDOW` lines above it
/// contain `needle`?
fn comment_nearby(lines: &[Line<'_>], i: usize, needle: &str) -> bool {
    let lo = i.saturating_sub(COMMENT_WINDOW);
    lines[lo..=i].iter().any(|l| l.comment.contains(needle))
}

/// Does `code` contain an `unsafe` token needing a SAFETY comment — a
/// block or an `unsafe impl`, not an `unsafe fn` declaration?
fn has_unsafe_site(code: &str) -> bool {
    let mut rest = code;
    while let Some(pos) = rest.find("unsafe") {
        let prev_ok = match rest[..pos].bytes().last() {
            Some(c) => !c.is_ascii_alphanumeric() && c != b'_',
            None => true,
        };
        let next_ok = match rest.as_bytes().get(pos + 6) {
            Some(&c) => !c.is_ascii_alphanumeric() && c != b'_',
            None => true,
        };
        let after = rest[pos + 6..].trim_start();
        let is_decl = after.starts_with("fn ") || after.starts_with("fn(");
        if prev_ok && next_ok && !is_decl {
            return true;
        }
        rest = &rest[pos + 6..];
    }
    false
}

/// Lint one file's source. `strict` enables R3/R4 (the coordinator /
/// pool scope); R1/R2 always run.
fn lint_source(relpath: &str, src: &str, strict: bool) -> Vec<Finding> {
    let mut findings = Vec::new();
    let mut lines: Vec<Line<'_>> = Vec::new();
    for raw in src.lines() {
        if raw.trim() == "#[cfg(test)]" {
            break; // trailing test mod: out of scope for every rule
        }
        let (code, comment) = split_line(raw);
        lines.push(Line { raw, code, comment });
    }
    let mut push = |line: usize, rule: &'static str, msg: &'static str| {
        findings.push(Finding {
            file: relpath.to_string(),
            line: line + 1,
            rule,
            msg,
        });
    };
    for i in 0..lines.len() {
        let code = lines[i].code.as_str();
        // R1: SAFETY comments on unsafe blocks/impls.
        if has_unsafe_site(code) && !comment_nearby(&lines, i, "SAFETY:") {
            push(i, "R1", "unsafe block/impl without a nearby `// SAFETY:` comment");
        }
        // R2: ordering justifications on non-SeqCst atomics.
        if WEAK_ORDERINGS.iter().any(|p| code.contains(p))
            && !comment_nearby(&lines, i, "ordering:")
        {
            push(i, "R2", "non-SeqCst ordering without a nearby `// ordering:` comment");
        }
        if !strict {
            continue;
        }
        // R3: panic-free runtime paths.
        if PANIC_PATTERNS.iter().any(|p| code.contains(p)) {
            let lock_chain = code.contains(".lock()")
                || (code.trim() == ".unwrap()"
                    && i > 0
                    && lines[i - 1].code.trim_end().ends_with(".lock()"));
            let condvar = code.contains(".wait(") || code.contains(".wait_timeout(");
            let allowed = PANIC_ALLOWLIST
                .iter()
                .any(|(file, needle)| relpath.ends_with(file) && lines[i].raw.contains(needle));
            if !lock_chain && !condvar && !allowed {
                push(i, "R3", "panic construct on a coordinator/pool runtime path");
            }
        }
        // R4: documented pub surface.
        if PUB_ITEMS.iter().any(|k| code.trim_start().starts_with(k)) {
            let mut j = i;
            let mut documented = false;
            while j > 0 {
                j -= 1;
                let t = lines[j].raw.trim_start();
                if t.starts_with("#[") || t.starts_with("#![") {
                    continue; // attributes may sit between doc and item
                }
                documented = t.starts_with("///");
                break;
            }
            if !documented {
                push(i, "R4", "undocumented pub item in coordinator/pool");
            }
        }
    }
    findings
}

/// R3/R4 apply only to the supervised data plane.
fn strict_scope(relpath: &str) -> bool {
    relpath.contains("coordinator/") || relpath.contains("pool/")
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<_> = fs::read_dir(dir)?
        .filter_map(Result::ok)
        .map(|e| e.path())
        .collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

fn lint_tree(root: &Path) -> std::io::Result<Vec<Finding>> {
    let mut files = Vec::new();
    walk(root, &mut files)?;
    let mut findings = Vec::new();
    for p in &files {
        let rel = p.to_string_lossy().replace('\\', "/");
        let src = fs::read_to_string(p)?;
        findings.extend(lint_source(&rel, &src, strict_scope(&rel)));
    }
    Ok(findings)
}

/// Seed one violation per rule (plus a clean twin) through the checker;
/// any undetected seed is a linter bug and fails the run.
fn self_test() -> bool {
    let seed_r1 = "fn f(p: *mut u8) {\n    let _ = unsafe { *p };\n}\n";
    let seed_r1_clean =
        "fn f(p: *mut u8) {\n    // SAFETY: caller guarantees p is valid.\n    let _ = unsafe { *p };\n}\n";
    let seed_r2 = "fn f(a: &AtomicUsize) -> usize {\n    a.load(Ordering::Relaxed)\n}\n";
    let seed_r2_clean =
        "fn f(a: &AtomicUsize) -> usize {\n    // ordering: Relaxed — statistics counter.\n    a.load(Ordering::Relaxed)\n}\n";
    let seed_r3 = "/// Doc.\npub fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n";
    let seed_r3_clean = "/// Doc.\npub fn f(m: &Mutex<u32>) -> u32 {\n    *m.lock().unwrap()\n}\n";
    let seed_r4 = "pub fn f() {}\n";
    let seed_r4_clean = "/// Documented.\npub fn f() {}\n";
    let cases: &[(&str, &str, bool, &str, usize)] = &[
        ("seed_r1.rs", seed_r1, false, "R1", 1),
        ("seed_r1_clean.rs", seed_r1_clean, false, "R1", 0),
        ("seed_r2.rs", seed_r2, false, "R2", 1),
        ("seed_r2_clean.rs", seed_r2_clean, false, "R2", 0),
        ("coordinator/seed_r3.rs", seed_r3, true, "R3", 1),
        ("coordinator/seed_r3_clean.rs", seed_r3_clean, true, "R3", 0),
        ("coordinator/seed_r4.rs", seed_r4, true, "R4", 1),
        ("coordinator/seed_r4_clean.rs", seed_r4_clean, true, "R4", 0),
    ];
    let mut ok = true;
    for (name, src, strict, rule, expect) in cases {
        let got = lint_source(name, src, *strict)
            .iter()
            .filter(|f| f.rule == *rule)
            .count();
        if got != *expect {
            eprintln!("self-test FAILED: {name}: expected {expect} {rule} finding(s), got {got}");
            ok = false;
        }
    }
    if ok {
        println!("fpps_lint self-test: all seeded violations detected");
    }
    ok
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a.as_str() == "--self-test") {
        if self_test() {
            return ExitCode::SUCCESS;
        }
        return ExitCode::FAILURE;
    }
    // Workspace root and crate dir both work without arguments.
    let root = match args.first() {
        Some(p) => PathBuf::from(p),
        None if Path::new("rust/src").is_dir() => PathBuf::from("rust/src"),
        None => PathBuf::from("src"),
    };
    let findings = match lint_tree(&root) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("fpps_lint: cannot lint {}: {e}", root.display());
            return ExitCode::FAILURE;
        }
    };
    for f in &findings {
        println!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.msg);
    }
    if findings.is_empty() {
        println!("fpps_lint: clean ({})", root.display());
        ExitCode::SUCCESS
    } else {
        eprintln!("fpps_lint: {} violation(s)", findings.len());
        ExitCode::FAILURE
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_and_comments_are_stripped() {
        let (code, comment) = split_line("let x = \"unsafe .unwrap()\"; // trailing");
        assert_eq!(code, "let x = \"\"; ");
        assert_eq!(comment, "// trailing");
        let (code, _) = split_line("if b == b'\"' { toggle() }");
        assert!(!code.contains('"'), "char-literal quote must not leak: {code}");
    }

    #[test]
    fn unsafe_fn_declarations_are_exempt() {
        assert!(!has_unsafe_site("unsafe fn alloc(&self) -> *mut u8 {"));
        assert!(has_unsafe_site("unsafe impl Send for X {}"));
        assert!(has_unsafe_site("let v = cell.with(|p| unsafe { *p });"));
        assert!(!has_unsafe_site("let has_unsafe_site = 1;"));
    }

    #[test]
    fn trailing_test_mod_is_skipped() {
        let src =
            "fn ok() {}\n#[cfg(test)]\nmod tests {\n    fn t() { None::<u32>.unwrap(); }\n}\n";
        assert!(lint_source("coordinator/x.rs", src, true).is_empty());
    }

    #[test]
    fn allowlist_is_path_and_needle_scoped() {
        let src =
            "/// Doc.\npub fn f(x: Option<u32>) -> u32 {\n    x.expect(\"created above\")\n}\n";
        assert!(lint_source("coordinator/supervise.rs", src, true).is_empty());
        // Same needle in a different file still fails.
        assert_eq!(lint_source("coordinator/other.rs", src, true).len(), 1);
    }

    #[test]
    fn seeded_self_test_passes() {
        assert!(self_test());
    }
}
