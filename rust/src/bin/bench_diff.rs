//! `bench_diff` — CI regression gate over bench JSON files.
//!
//! Compares every numeric leaf of a fresh bench run against the
//! committed baseline and fails (exit 1) when a shared key drifts past
//! the tolerance. Baselines deliberately commit only deterministic
//! contract keys (counts, allocation rates); machine-dependent keys
//! (wall times, throughput) are absent from the baseline or skipped via
//! `--skip`, so the gate never flakes on runner speed.
//!
//!   bench_diff <baseline.json> <fresh.json> \
//!       [--tolerance 0.15] [--skip SUBSTRING]...
//!
//! Rules, per dotted key present in **both** files:
//! * baseline 0 ⇒ fresh must be exactly 0 (a zero contract, e.g.
//!   allocations/job, has no meaningful relative tolerance);
//! * otherwise |fresh − base| / |base| must be ≤ tolerance.
//!
//! A baseline key missing from the fresh run is itself a failure (the
//! bench stopped reporting a contract); extra fresh keys are ignored.
//! JSON parsing is hand-rolled like the benches' writer — the crate
//! keeps its no-serde dependency posture.

use anyhow::{bail, Context, Result};
use std::process::ExitCode;

/// Minimal JSON reader: collects `(dotted.path, value)` for every
/// numeric leaf; strings/bools/nulls are consumed and dropped.
struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn new(s: &'a str) -> Self {
        Self { s: s.as_bytes(), i: 0 }
    }

    fn skip_ws(&mut self) {
        while self.i < self.s.len() && self.s[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&mut self) -> Result<u8> {
        self.skip_ws();
        self.s
            .get(self.i)
            .copied()
            .context("unexpected end of JSON input")
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let got = self.peek()?;
        if got != b {
            bail!(
                "expected '{}' at byte {}, found '{}'",
                b as char,
                self.i,
                got as char
            );
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self, path: &str, out: &mut Vec<(String, f64)>) -> Result<()> {
        match self.peek()? {
            b'{' => self.object(path, out),
            b'[' => self.array(path, out),
            b'"' => self.string().map(|_| ()),
            b't' | b'f' | b'n' => self.keyword(),
            _ => {
                let v = self.number()?;
                out.push((path.to_string(), v));
                Ok(())
            }
        }
    }

    fn object(&mut self, path: &str, out: &mut Vec<(String, f64)>) -> Result<()> {
        self.expect(b'{')?;
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(());
        }
        loop {
            let key = self.string()?;
            self.expect(b':')?;
            let child = if path.is_empty() {
                key
            } else {
                format!("{path}.{key}")
            };
            self.value(&child, out)?;
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(());
                }
                c => bail!("expected ',' or '}}' in object, found '{}'", c as char),
            }
        }
    }

    fn array(&mut self, path: &str, out: &mut Vec<(String, f64)>) -> Result<()> {
        self.expect(b'[')?;
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(());
        }
        let mut idx = 0usize;
        loop {
            self.value(&format!("{path}[{idx}]"), out)?;
            idx += 1;
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(());
                }
                c => bail!("expected ',' or ']' in array, found '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        while let Some(&b) = self.s.get(self.i) {
            self.i += 1;
            match b {
                b'"' => return Ok(s),
                b'\\' => {
                    // The benches' writer only ever emits \", \\, \n:
                    // decode those and pass anything else through.
                    let esc = *self.s.get(self.i).context("dangling escape")?;
                    self.i += 1;
                    match esc {
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        other => s.push(other as char),
                    }
                }
                other => s.push(other as char),
            }
        }
        bail!("unterminated string")
    }

    fn keyword(&mut self) -> Result<()> {
        for kw in ["true", "false", "null"] {
            if self.s[self.i..].starts_with(kw.as_bytes()) {
                self.i += kw.len();
                return Ok(());
            }
        }
        bail!("unknown keyword at byte {}", self.i)
    }

    fn number(&mut self) -> Result<f64> {
        let start = self.i;
        while let Some(&b) = self.s.get(self.i) {
            if b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.s[start..self.i])
            .ok()
            .and_then(|t| t.parse().ok())
            .with_context(|| format!("invalid number at byte {start}"))
    }
}

fn numeric_leaves(path: &str) -> Result<Vec<(String, f64)>> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let mut out = Vec::new();
    let mut p = Parser::new(&text);
    p.value("", &mut out)
        .with_context(|| format!("parsing {path}"))?;
    Ok(out)
}

fn lookup(leaves: &[(String, f64)], key: &str) -> Option<f64> {
    leaves.iter().find(|(k, _)| k == key).map(|(_, v)| *v)
}

/// The gate itself, kept pure so the boundary semantics are unit-tested:
/// walks every baseline key not matched by a `--skip` substring and
/// returns `(keys_compared, failure_messages)`. A baseline key missing
/// from `fresh` fails; baseline 0 demands exactly 0; otherwise relative
/// drift strictly above `tolerance` fails (the boundary itself passes).
fn diff_leaves(
    base: &[(String, f64)],
    fresh: &[(String, f64)],
    tolerance: f64,
    skips: &[String],
) -> (usize, Vec<String>) {
    let skipped = |key: &str| skips.iter().any(|s| key.contains(s.as_str()));
    let mut failures = Vec::new();
    let mut compared = 0usize;
    for (key, b) in base {
        if skipped(key) {
            continue;
        }
        let Some(n) = lookup(fresh, key) else {
            failures.push(format!("{key}: present in baseline, missing from fresh run"));
            continue;
        };
        compared += 1;
        if *b == 0.0 {
            if n != 0.0 {
                failures.push(format!("{key}: baseline 0, fresh {n} (zero contract broken)"));
            }
        } else {
            let rel = (n - b).abs() / b.abs();
            if rel > tolerance {
                failures.push(format!(
                    "{key}: baseline {b}, fresh {n} ({:+.1}% > ±{:.0}%)",
                    (n / b - 1.0) * 100.0,
                    tolerance * 100.0
                ));
            }
        }
    }
    (compared, failures)
}

fn run() -> Result<bool> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut files = Vec::new();
    let mut tolerance = 0.15f64;
    let mut skips: Vec<String> = Vec::new();
    let mut it = args.into_iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--tolerance" => {
                tolerance = it
                    .next()
                    .context("--tolerance needs a value")?
                    .parse()
                    .context("--tolerance must be a number")?;
            }
            "--skip" => skips.push(it.next().context("--skip needs a substring")?),
            _ => files.push(a),
        }
    }
    let [baseline, fresh] = files.as_slice() else {
        bail!("usage: bench_diff <baseline.json> <fresh.json> [--tolerance T] [--skip SUB]...");
    };

    let base = numeric_leaves(baseline)?;
    let new = numeric_leaves(fresh)?;
    let (compared, failures) = diff_leaves(&base, &new, tolerance, &skips);

    println!(
        "bench_diff: {baseline} vs {fresh} — {compared} keys compared \
         (tolerance ±{:.0}%, {} skipped patterns)",
        tolerance * 100.0,
        skips.len()
    );
    if failures.is_empty() {
        println!("bench_diff: OK, no regressions");
        return Ok(true);
    }
    println!("bench_diff: {} regression(s):", failures.len());
    for f in &failures {
        println!("  FAIL {f}");
    }
    Ok(false)
}

fn main() -> ExitCode {
    match run() {
        Ok(true) => ExitCode::SUCCESS,
        Ok(false) => ExitCode::FAILURE,
        Err(e) => {
            eprintln!("bench_diff error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn leaves(json: &str) -> Vec<(String, f64)> {
        let mut out = Vec::new();
        Parser::new(json).value("", &mut out).expect("test JSON parses");
        out
    }

    fn diff(base: &str, fresh: &str, skips: &[&str]) -> (usize, Vec<String>) {
        let skips: Vec<String> = skips.iter().map(|s| s.to_string()).collect();
        diff_leaves(&leaves(base), &leaves(fresh), 0.15, &skips)
    }

    #[test]
    fn missing_baseline_key_fails() {
        let (compared, failures) = diff(r#"{"a": 1, "b": 2}"#, r#"{"a": 1}"#, &[]);
        assert_eq!(compared, 1);
        assert_eq!(failures.len(), 1);
        assert!(failures[0].contains("missing from fresh run"), "{failures:?}");
    }

    #[test]
    fn extra_fresh_keys_are_ignored() {
        let (compared, failures) = diff(r#"{"a": 1}"#, r#"{"a": 1, "extra": 99}"#, &[]);
        assert_eq!(compared, 1);
        assert!(failures.is_empty(), "{failures:?}");
    }

    #[test]
    fn zero_baseline_demands_exact_zero() {
        let (_, ok) = diff(r#"{"allocs": 0}"#, r#"{"allocs": 0}"#, &[]);
        assert!(ok.is_empty());
        // Even a drift far inside the relative tolerance breaks the
        // zero contract.
        let (_, bad) = diff(r#"{"allocs": 0}"#, r#"{"allocs": 0.0001}"#, &[]);
        assert_eq!(bad.len(), 1);
        assert!(bad[0].contains("zero contract"), "{bad:?}");
    }

    #[test]
    fn tolerance_boundary_is_inclusive() {
        // Exactly +15% on a base of 100 is 115: rel == tolerance passes.
        let (_, at) = diff(r#"{"k": 100}"#, r#"{"k": 115}"#, &[]);
        assert!(at.is_empty(), "{at:?}");
        let (_, under) = diff(r#"{"k": 100}"#, r#"{"k": 85}"#, &[]);
        assert!(under.is_empty(), "{under:?}");
        // Strictly past the boundary fails, in both directions.
        let (_, over) = diff(r#"{"k": 100}"#, r#"{"k": 115.1}"#, &[]);
        assert_eq!(over.len(), 1);
        let (_, below) = diff(r#"{"k": 100}"#, r#"{"k": 84.9}"#, &[]);
        assert_eq!(below.len(), 1);
    }

    #[test]
    fn skip_filters_by_substring_even_when_missing() {
        // `_ms` keys are machine-dependent: drift and absence both pass.
        let base = r#"{"upload_ms": 5, "count": 7}"#;
        let (compared, failures) = diff(base, r#"{"count": 7}"#, &["_ms"]);
        assert_eq!(compared, 1);
        assert!(failures.is_empty(), "{failures:?}");
        let (_, drift) = diff(base, r#"{"upload_ms": 50, "count": 7}"#, &["_ms"]);
        assert!(drift.is_empty(), "{drift:?}");
    }

    #[test]
    fn nested_paths_and_arrays_get_dotted_keys() {
        let base = r#"{"tiers": [{"points": 10}, {"points": 20}], "cfg": {"lanes": 2}}"#;
        let l = leaves(base);
        assert_eq!(lookup(&l, "tiers[0].points"), Some(10.0));
        assert_eq!(lookup(&l, "tiers[1].points"), Some(20.0));
        assert_eq!(lookup(&l, "cfg.lanes"), Some(2.0));
    }
}
