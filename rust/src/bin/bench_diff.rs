//! `bench_diff` — CI regression gate over bench JSON files.
//!
//! Compares every numeric leaf of a fresh bench run against the
//! committed baseline and fails (exit 1) when a shared key drifts past
//! the tolerance. Baselines deliberately commit only deterministic
//! contract keys (counts, allocation rates); machine-dependent keys
//! (wall times, throughput) are absent from the baseline or skipped via
//! `--skip`, so the gate never flakes on runner speed.
//!
//!   bench_diff <baseline.json> <fresh.json> \
//!       [--tolerance 0.15] [--skip SUBSTRING]...
//!
//! Rules, per dotted key present in **both** files:
//! * baseline 0 ⇒ fresh must be exactly 0 (a zero contract, e.g.
//!   allocations/job, has no meaningful relative tolerance);
//! * otherwise |fresh − base| / |base| must be ≤ tolerance.
//!
//! A baseline key missing from the fresh run is itself a failure (the
//! bench stopped reporting a contract); extra fresh keys are ignored.
//! JSON parsing is hand-rolled like the benches' writer — the crate
//! keeps its no-serde dependency posture.

use anyhow::{bail, Context, Result};
use std::process::ExitCode;

/// Minimal JSON reader: collects `(dotted.path, value)` for every
/// numeric leaf; strings/bools/nulls are consumed and dropped.
struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn new(s: &'a str) -> Self {
        Self { s: s.as_bytes(), i: 0 }
    }

    fn skip_ws(&mut self) {
        while self.i < self.s.len() && self.s[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&mut self) -> Result<u8> {
        self.skip_ws();
        self.s
            .get(self.i)
            .copied()
            .context("unexpected end of JSON input")
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let got = self.peek()?;
        if got != b {
            bail!(
                "expected '{}' at byte {}, found '{}'",
                b as char,
                self.i,
                got as char
            );
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self, path: &str, out: &mut Vec<(String, f64)>) -> Result<()> {
        match self.peek()? {
            b'{' => self.object(path, out),
            b'[' => self.array(path, out),
            b'"' => self.string().map(|_| ()),
            b't' | b'f' | b'n' => self.keyword(),
            _ => {
                let v = self.number()?;
                out.push((path.to_string(), v));
                Ok(())
            }
        }
    }

    fn object(&mut self, path: &str, out: &mut Vec<(String, f64)>) -> Result<()> {
        self.expect(b'{')?;
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(());
        }
        loop {
            let key = self.string()?;
            self.expect(b':')?;
            let child = if path.is_empty() {
                key
            } else {
                format!("{path}.{key}")
            };
            self.value(&child, out)?;
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(());
                }
                c => bail!("expected ',' or '}}' in object, found '{}'", c as char),
            }
        }
    }

    fn array(&mut self, path: &str, out: &mut Vec<(String, f64)>) -> Result<()> {
        self.expect(b'[')?;
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(());
        }
        let mut idx = 0usize;
        loop {
            self.value(&format!("{path}[{idx}]"), out)?;
            idx += 1;
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(());
                }
                c => bail!("expected ',' or ']' in array, found '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        while let Some(&b) = self.s.get(self.i) {
            self.i += 1;
            match b {
                b'"' => return Ok(s),
                b'\\' => {
                    // The benches' writer only ever emits \", \\, \n:
                    // decode those and pass anything else through.
                    let esc = *self.s.get(self.i).context("dangling escape")?;
                    self.i += 1;
                    match esc {
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        other => s.push(other as char),
                    }
                }
                other => s.push(other as char),
            }
        }
        bail!("unterminated string")
    }

    fn keyword(&mut self) -> Result<()> {
        for kw in ["true", "false", "null"] {
            if self.s[self.i..].starts_with(kw.as_bytes()) {
                self.i += kw.len();
                return Ok(());
            }
        }
        bail!("unknown keyword at byte {}", self.i)
    }

    fn number(&mut self) -> Result<f64> {
        let start = self.i;
        while let Some(&b) = self.s.get(self.i) {
            if b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.s[start..self.i])
            .ok()
            .and_then(|t| t.parse().ok())
            .with_context(|| format!("invalid number at byte {start}"))
    }
}

fn numeric_leaves(path: &str) -> Result<Vec<(String, f64)>> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let mut out = Vec::new();
    let mut p = Parser::new(&text);
    p.value("", &mut out)
        .with_context(|| format!("parsing {path}"))?;
    Ok(out)
}

fn lookup(leaves: &[(String, f64)], key: &str) -> Option<f64> {
    leaves.iter().find(|(k, _)| k == key).map(|(_, v)| *v)
}

fn run() -> Result<bool> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut files = Vec::new();
    let mut tolerance = 0.15f64;
    let mut skips: Vec<String> = Vec::new();
    let mut it = args.into_iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--tolerance" => {
                tolerance = it
                    .next()
                    .context("--tolerance needs a value")?
                    .parse()
                    .context("--tolerance must be a number")?;
            }
            "--skip" => skips.push(it.next().context("--skip needs a substring")?),
            _ => files.push(a),
        }
    }
    let [baseline, fresh] = files.as_slice() else {
        bail!("usage: bench_diff <baseline.json> <fresh.json> [--tolerance T] [--skip SUB]...");
    };

    let base = numeric_leaves(baseline)?;
    let new = numeric_leaves(fresh)?;
    let skipped = |key: &str| skips.iter().any(|s| key.contains(s.as_str()));

    let mut failures = Vec::new();
    let mut compared = 0usize;
    for (key, b) in &base {
        if skipped(key) {
            continue;
        }
        let Some(n) = lookup(&new, key) else {
            failures.push(format!("{key}: present in baseline, missing from fresh run"));
            continue;
        };
        compared += 1;
        if *b == 0.0 {
            if n != 0.0 {
                failures.push(format!("{key}: baseline 0, fresh {n} (zero contract broken)"));
            }
        } else {
            let rel = (n - b).abs() / b.abs();
            if rel > tolerance {
                failures.push(format!(
                    "{key}: baseline {b}, fresh {n} ({:+.1}% > ±{:.0}%)",
                    (n / b - 1.0) * 100.0,
                    tolerance * 100.0
                ));
            }
        }
    }

    println!(
        "bench_diff: {baseline} vs {fresh} — {compared} keys compared \
         (tolerance ±{:.0}%, {} skipped patterns)",
        tolerance * 100.0,
        skips.len()
    );
    if failures.is_empty() {
        println!("bench_diff: OK, no regressions");
        return Ok(true);
    }
    println!("bench_diff: {} regression(s):", failures.len());
    for f in &failures {
        println!("  FAIL {f}");
    }
    Ok(false)
}

fn main() -> ExitCode {
    match run() {
        Ok(true) => ExitCode::SUCCESS,
        Ok(false) => ExitCode::FAILURE,
        Err(e) => {
            eprintln!("bench_diff error: {e:#}");
            ExitCode::FAILURE
        }
    }
}
