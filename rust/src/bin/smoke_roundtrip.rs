//! Smoke test for the AOT round-trip: load the HLO text produced by
//! `python/compile/aot.py` (or the /tmp prototype), execute the icp_step
//! computation on the PJRT CPU client, and compare against the expected
//! accumulator values dumped by the python side.
//!
//! Usage: smoke_roundtrip [hlo_path] [expect_bin]
use anyhow::Result;

fn main() -> Result<()> {
    let hlo = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "/tmp/icp_step.hlo.txt".to_string());
    let expect_path = std::env::args()
        .nth(2)
        .unwrap_or_else(|| "/tmp/icp_step_expect.bin".to_string());

    let client = xla::PjRtClient::cpu()?;
    println!(
        "platform={} devices={}",
        client.platform_name(),
        client.device_count()
    );
    let proto = xla::HloModuleProto::from_text_file(&hlo)?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client.compile(&comp)?;
    println!("compiled {}", hlo);

    let (n, m) = (256usize, 1024usize);
    let src = read_f32("/tmp/icp_step_src.bin", n * 3)?;
    let tgt = read_f32("/tmp/icp_step_tgt.bin", m * 3)?;
    let smask = vec![1f32; n];
    let mut tmask = vec![1f32; m];
    for v in tmask[m - 7..].iter_mut() {
        *v = 0.0;
    }
    let mut t = vec![0f32; 16];
    for i in 0..4 {
        t[i * 4 + i] = 1.0;
    }
    t[3] = 0.1;
    t[7] = -0.2;
    t[11] = 0.05;

    let lits = vec![
        xla::Literal::vec1(&src).reshape(&[n as i64, 3])?,
        xla::Literal::vec1(&tgt).reshape(&[m as i64, 3])?,
        xla::Literal::vec1(&smask),
        xla::Literal::vec1(&tmask),
        xla::Literal::vec1(&t).reshape(&[4, 4])?,
        xla::Literal::scalar(1e30f32),
    ];
    let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
    let outs = result.to_tuple()?;
    println!("num outputs: {}", outs.len());
    let mut got = Vec::new();
    for o in &outs {
        got.extend(o.to_vec::<f32>()?);
    }
    let expect = read_f32(&expect_path, 17)?;
    let mut max_err = 0f32;
    for (g, e) in got.iter().zip(expect.iter()) {
        let err = (g - e).abs() / e.abs().max(1.0);
        max_err = max_err.max(err);
    }
    println!("got[0..5]={:?}", &got[..5.min(got.len())]);
    println!("max rel err vs python: {max_err:e}");
    assert!(max_err < 1e-4, "mismatch vs python expected values");
    println!("smoke_roundtrip OK");
    Ok(())
}

fn read_f32(path: &str, count: usize) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path)?;
    anyhow::ensure!(bytes.len() == count * 4, "{path}: wrong size");
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}
