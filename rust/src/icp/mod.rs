//! CPU baseline ICP — a from-scratch PCL-equivalent of
//! `pcl::IterativeClosestPoint`, the software baseline of the paper's
//! evaluation (§IV.A: "a software-only ICP implementation based on PCL").
//!
//! Algorithm (paper §II):
//! 1. correspondence estimation — exact NN in the target for every
//!    source point (kd-tree, like PCL, or brute force);
//! 2. correspondence rejection — drop pairs beyond
//!    `max_correspondence_distance`;
//! 3. transformation estimation — Umeyama/Kabsch closed form via SVD;
//! 4. update + convergence — apply `T_j`, accumulate `T = Π T_j`
//!    (Eq. 3), stop when `T_j` is within `transformation_epsilon` of
//!    identity or `max_iterations` is reached.

use crate::kdtree::{KdTree, OwnedKdTree};
use crate::math::{kabsch_from_pairs, Mat4, Vec3};
use crate::nn;
use crate::pointcloud::PointCloud;
use crate::voxelgrid::VoxelGrid;

/// Correspondence search strategy for the baseline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SearchStrategy {
    /// kd-tree (what PCL uses; the §V discussion's sequential traversal).
    KdTree,
    /// Approximate kd-tree with a bounded leaf-visit budget — the §V
    /// alternative that trades exactness for speed; the paper (and our
    /// `section5_approx_icp` test) observe degraded ICP convergence.
    KdTreeApproximate { max_leaf_visits: usize },
    /// Single-thread brute force.
    Brute,
    /// Multi-thread brute force (the "massive multi-core parallelism"
    /// CPU alternative the introduction mentions).
    BruteParallel { threads: usize },
}

/// ICP parameters — mirrors the paper's Table I knobs and its fixed
/// evaluation configuration (§IV.A: 50 iterations, 1.0 m, 1e-5).
#[derive(Clone, Copy, Debug)]
pub struct IcpParams {
    pub max_iterations: u32,
    pub max_correspondence_distance: f32,
    pub transformation_epsilon: f64,
    pub search: SearchStrategy,
}

impl Default for IcpParams {
    fn default() -> Self {
        Self {
            max_iterations: 50,
            max_correspondence_distance: 1.0,
            transformation_epsilon: 1e-5,
            search: SearchStrategy::KdTree,
        }
    }
}

/// Why the loop stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    Converged,
    MaxIterations,
    TooFewCorrespondences,
    /// The alignment itself errored (backend/infrastructure failure) and
    /// was contained by the caller. Only the lane pool constructs this —
    /// `align()` returns `Err` instead — so a data-quality signal like
    /// [`StopReason::TooFewCorrespondences`] is never conflated with an
    /// infrastructure error.
    Failed,
    /// The job's deadline expired before the alignment finished: either
    /// the cooperative check between ICP iterations fired (partial
    /// progress discarded, the initial transform is handed back), or the
    /// lane-pool watchdog cut off a wedged lane mid-step. A deadline is
    /// an SLO signal, distinct from both data quality and
    /// [`StopReason::Failed`] infrastructure errors.
    DeadlineExceeded,
    /// The serving tier refused the job before it ever reached a lane
    /// queue: admission (stream/pool backpressure) or the SLO policy
    /// decided the job would miss its deadline anyway. The alignment
    /// never ran — the outcome hands back the initial transform with a
    /// structured error, so latency-critical callers learn immediately
    /// instead of waiting out a doomed queue. Only
    /// `coordinator::serving` constructs this.
    Shed,
}

/// Per-iteration diagnostics (consumed by benches and EXPERIMENTS.md).
#[derive(Clone, Copy, Debug)]
pub struct IterationStat {
    pub correspondences: usize,
    /// RMS of matched correspondence distances (m).
    pub rmse: f64,
    /// max|T_j − I| convergence metric.
    pub delta: f64,
    /// Wall time of the correspondence-estimation stage.
    pub nn_time: std::time::Duration,
}

/// Alignment result.
#[derive(Clone, Debug)]
pub struct IcpResult {
    /// Final cumulative transform T = Π T_j mapping source → target.
    pub transformation: Mat4,
    /// Correspondence RMSE at the last iteration (paper Table III metric).
    pub rmse: f64,
    pub iterations: u32,
    pub stop: StopReason,
    pub stats: Vec<IterationStat>,
    pub total_time: std::time::Duration,
}

impl IcpResult {
    /// Did the alignment produce a usable transform?
    pub fn has_converged(&self) -> bool {
        matches!(
            self.stop,
            StopReason::Converged | StopReason::MaxIterations
        )
    }
}

/// Align `source` onto `target` starting from `initial_guess`.
///
/// This is the whole baseline pipeline; the hybrid FPPS path shares the
/// outer loop but offloads steps 1–3's heavy parts to the device (see
/// `fpps_api`).
pub fn align(
    source: &PointCloud,
    target: &PointCloud,
    initial_guess: &Mat4,
    params: &IcpParams,
) -> IcpResult {
    let tree = match params.search {
        SearchStrategy::KdTree | SearchStrategy::KdTreeApproximate { .. } => {
            Some(KdTree::build(target))
        }
        _ => None,
    };
    align_impl(
        source,
        target,
        &CorrSource::PerCall(tree.as_ref()),
        initial_guess,
        params,
    )
}

/// Align `source` onto a target already indexed by an [`OwnedKdTree`] —
/// the CPU baseline's map-reuse path. Localization-style callers build
/// the map index once and amortize it over many scans, mirroring the
/// device-side resident-target cache in `fpps_api`. Both trees use the
/// same build and traversal, so this produces results identical to
/// [`align`] with [`SearchStrategy::KdTree`] on `tree.cloud()`.
pub fn align_with_tree(
    source: &PointCloud,
    tree: &OwnedKdTree,
    initial_guess: &Mat4,
    params: &IcpParams,
) -> IcpResult {
    align_impl(
        source,
        tree.cloud(),
        &CorrSource::Resident(tree),
        initial_guess,
        params,
    )
}

/// Align `source` onto `target` through a caller-owned [`VoxelGrid`] —
/// the approximate sibling of [`align_with_tree`]. The grid must have
/// been built from `target`. With a ring budget covering the
/// correspondence distance (`max_ring·cell_size ≥
/// max_correspondence_distance`) the correspondences — and therefore
/// the whole alignment — are bit-identical to the kd-tree path; with a
/// tighter budget distant correspondences are dropped, trading a
/// bounded RMSE delta for the grid's throughput (see
/// `benches/nn_scaling.rs`).
pub fn align_with_grid(
    source: &PointCloud,
    target: &PointCloud,
    grid: &VoxelGrid,
    initial_guess: &Mat4,
    params: &IcpParams,
) -> IcpResult {
    align_impl(
        source,
        target,
        &CorrSource::Grid(grid),
        initial_guess,
        params,
    )
}

/// Where each iteration's correspondences come from: the per-call search
/// strategy (over a tree built for this alignment, if any), a
/// caller-owned resident index (map reuse), or a caller-owned voxel
/// grid (approximate map reuse).
enum CorrSource<'a> {
    PerCall(Option<&'a KdTree<'a>>),
    Resident(&'a OwnedKdTree),
    Grid(&'a VoxelGrid),
}

/// The shared ICP outer loop — one implementation for the per-call and
/// resident-index paths, so the two cannot drift apart (the map-reuse
/// bit-identity tests depend on that).
fn align_impl(
    source: &PointCloud,
    target: &PointCloud,
    corr: &CorrSource,
    initial_guess: &Mat4,
    params: &IcpParams,
) -> IcpResult {
    let t_start = std::time::Instant::now();
    let mut cumulative = *initial_guess;
    let mut current = source.transformed(initial_guess);
    let mut stats = Vec::new();
    let mut stop = StopReason::MaxIterations;
    let mut last_rmse = f64::NAN;
    let mut iterations = 0;

    for _ in 0..params.max_iterations {
        iterations += 1;
        // 1+2: correspondence estimation with rejection.
        let nn_start = std::time::Instant::now();
        let pairs = find_correspondences(&current, target, corr, params);
        let nn_time = nn_start.elapsed();

        let mut sum_sq = 0.0f64;
        let (mut ps, mut qs) = (
            Vec::with_capacity(pairs.len()),
            Vec::with_capacity(pairs.len()),
        );
        for &(si, ti, d) in &pairs {
            ps.push(Vec3::from_f32(current.get(si as usize)));
            qs.push(Vec3::from_f32(target.get(ti as usize)));
            sum_sq += d as f64;
        }
        if ps.len() < 3 {
            stop = StopReason::TooFewCorrespondences;
            stats.push(IterationStat {
                correspondences: ps.len(),
                rmse: f64::NAN,
                delta: f64::NAN,
                nn_time,
            });
            break;
        }
        last_rmse = (sum_sq / ps.len() as f64).sqrt();

        // 3: transformation estimation.
        let est = match kabsch_from_pairs(&ps, &qs) {
            Some(e) => e,
            None => {
                stop = StopReason::TooFewCorrespondences;
                break;
            }
        };
        let t_j = est.to_mat4();

        // 4: update + convergence (PCL semantics: epsilon on T_j).
        current.transform_in_place(&t_j);
        cumulative = t_j.mul_mat(&cumulative);
        let delta = t_j.delta_from_identity();
        stats.push(IterationStat {
            correspondences: ps.len(),
            rmse: last_rmse,
            delta,
            nn_time,
        });
        if delta < params.transformation_epsilon {
            stop = StopReason::Converged;
            break;
        }
    }

    IcpResult {
        transformation: cumulative,
        rmse: last_rmse,
        iterations,
        stop,
        stats,
        total_time: t_start.elapsed(),
    }
}

/// (source idx, target idx, squared distance) for all accepted pairs.
fn find_correspondences(
    current: &PointCloud,
    target: &PointCloud,
    corr: &CorrSource,
    params: &IcpParams,
) -> Vec<(u32, u32, f32)> {
    let max_d = params.max_correspondence_distance;
    let max_d2 = max_d * max_d;
    let mut out = Vec::with_capacity(current.len());
    let tree = match corr {
        CorrSource::Resident(tree) => {
            // Resident index: exact bounded NN with the same build and
            // traversal as the borrowing KdTree, so the pairs match
            // SearchStrategy::KdTree exactly.
            for (i, p) in current.iter().enumerate() {
                if let Some(n) = tree.nearest_within_sq(p, max_d2) {
                    out.push((i as u32, n.index, n.dist_sq));
                }
            }
            return out;
        }
        CorrSource::Grid(grid) => {
            // Voxel grid: bounded NN inside the scanned ring
            // neighborhood; same strictly-closer acceptance as the
            // kd-tree, so a covering ring budget reproduces its pairs
            // exactly.
            for (i, p) in current.iter().enumerate() {
                if let Some(n) = grid.nearest(target, p, max_d2) {
                    out.push((i as u32, n.index, n.dist_sq));
                }
            }
            return out;
        }
        CorrSource::PerCall(tree) => *tree,
    };
    match (params.search, tree) {
        (SearchStrategy::KdTree, Some(tree)) => {
            for (i, p) in current.iter().enumerate() {
                if let Some(n) = tree.nearest_within(p, max_d) {
                    out.push((i as u32, n.index, n.dist_sq));
                }
            }
        }
        (SearchStrategy::KdTreeApproximate { max_leaf_visits }, Some(tree)) => {
            for (i, p) in current.iter().enumerate() {
                if let Some(n) = tree.nearest_approximate(p, max_leaf_visits) {
                    if n.dist_sq <= max_d2 {
                        out.push((i as u32, n.index, n.dist_sq));
                    }
                }
            }
        }
        (SearchStrategy::Brute, _) => {
            for (i, p) in current.iter().enumerate() {
                if let Some((j, d)) = nn::nearest_brute(target, p) {
                    if d <= max_d2 {
                        out.push((i as u32, j, d));
                    }
                }
            }
        }
        (SearchStrategy::BruteParallel { threads }, _) => {
            let res = nn::nearest_brute_parallel(target, current, threads);
            for (i, &(j, d)) in res.iter().enumerate() {
                if d <= max_d2 {
                    out.push((i as u32, j, d));
                }
            }
        }
        (SearchStrategy::KdTree, None)
        | (SearchStrategy::KdTreeApproximate { .. }, None) => {
            unreachable!("tree built for kd-tree strategies")
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::Mat3;
    use crate::prop::{default_cases, forall};
    use crate::rng::Pcg32;

    /// Structured cloud (two walls + floor patch) — ICP needs geometry
    /// with constraints in all 6 DoF to converge.
    fn structured_cloud(n: usize, seed: u64) -> PointCloud {
        let mut rng = Pcg32::new(seed);
        let mut c = PointCloud::with_capacity(n);
        for i in 0..n {
            match i % 3 {
                0 => c.push([rng.range(-5.0, 5.0), rng.range(-5.0, 5.0), 0.0]),
                1 => c.push([rng.range(-5.0, 5.0), 5.0, rng.range(0.0, 3.0)]),
                _ => c.push([-5.0, rng.range(-5.0, 5.0), rng.range(0.0, 3.0)]),
            }
        }
        c
    }

    fn small_transform(rng: &mut Pcg32) -> Mat4 {
        let axis = [0.0, 0.0, 1.0];
        let r = Mat3::axis_angle(axis, rng.range(-0.05, 0.05));
        let t = Vec3::new(
            rng.range(-0.3, 0.3) as f64,
            rng.range(-0.3, 0.3) as f64,
            rng.range(-0.05, 0.05) as f64,
        );
        Mat4::from_rt(r, t)
    }

    fn recovers(params: &IcpParams, seed: u64) {
        let mut rng = Pcg32::new(seed);
        let target = structured_cloud(1200, seed);
        let gt = small_transform(&mut rng);
        // Source = target moved by gt⁻¹, so aligning source→target
        // should recover gt.
        let source = target.transformed(&gt.inverse_rigid());
        let res = align(&source, &target, &Mat4::IDENTITY, params);
        assert!(res.has_converged(), "stop={:?}", res.stop);
        let err = res.transformation.rotation().rotation_angle_to(&gt.rotation());
        let terr = (res.transformation.translation() - gt.translation()).norm();
        assert!(err < 2e-3, "rotation err {err} (seed {seed})");
        assert!(terr < 2e-2, "translation err {terr} (seed {seed})");
        assert!(res.rmse < 0.05, "rmse {}", res.rmse);
    }

    #[test]
    fn recovers_transform_kdtree() {
        recovers(&IcpParams::default(), 42);
    }

    #[test]
    fn recovers_transform_brute() {
        recovers(
            &IcpParams {
                search: SearchStrategy::Brute,
                ..Default::default()
            },
            43,
        );
    }

    #[test]
    fn recovers_transform_brute_parallel() {
        recovers(
            &IcpParams {
                search: SearchStrategy::BruteParallel { threads: 4 },
                ..Default::default()
            },
            44,
        );
    }

    #[test]
    fn strategies_agree() {
        // kd-tree and brute force must produce identical correspondences,
        // hence near-identical transforms.
        let target = structured_cloud(800, 7);
        let mut rng = Pcg32::new(8);
        let source = target.transformed(&small_transform(&mut rng).inverse_rigid());
        let a = align(&source, &target, &Mat4::IDENTITY, &IcpParams::default());
        let b = align(
            &source,
            &target,
            &Mat4::IDENTITY,
            &IcpParams {
                search: SearchStrategy::Brute,
                ..Default::default()
            },
        );
        assert!(
            a.transformation
                .rotation()
                .rotation_angle_to(&b.transformation.rotation())
                < 1e-6
        );
        assert!((a.transformation.translation() - b.transformation.translation()).norm() < 1e-5);
    }

    #[test]
    fn align_with_tree_matches_align_bitwise() {
        // Map-reuse path (prebuilt OwnedKdTree) vs per-call KdTree build:
        // same build + traversal → identical correspondences → identical
        // transforms, so amortizing the build cannot change results.
        let target = structured_cloud(900, 19);
        let mut rng = Pcg32::new(20);
        let gt = small_transform(&mut rng);
        let source = target.transformed(&gt.inverse_rigid());
        let a = align(&source, &target, &Mat4::IDENTITY, &IcpParams::default());
        let tree = OwnedKdTree::build(target.clone());
        let b = align_with_tree(&source, &tree, &Mat4::IDENTITY, &IcpParams::default());
        assert_eq!(a.transformation.m, b.transformation.m);
        assert_eq!(a.rmse.to_bits(), b.rmse.to_bits());
        assert_eq!(a.iterations, b.iterations);
        assert_eq!(a.stop, b.stop);
    }

    #[test]
    fn align_with_grid_covering_budget_matches_tree_bitwise() {
        // Grid-backed map reuse with a ring budget covering the whole
        // correspondence radius (2 rings × 1 m ≥ 1 m): identical
        // bounded-NN answers → identical pairs → identical transforms.
        let target = structured_cloud(900, 23);
        let mut rng = Pcg32::new(24);
        let gt = small_transform(&mut rng);
        let source = target.transformed(&gt.inverse_rigid());
        let tree = OwnedKdTree::build(target.clone());
        let a = align_with_tree(&source, &tree, &Mat4::IDENTITY, &IcpParams::default());
        let grid = crate::voxelgrid::VoxelGrid::build(&target, 1.0, 2);
        let b = align_with_grid(&source, &target, &grid, &Mat4::IDENTITY, &IcpParams::default());
        assert_eq!(a.transformation.m, b.transformation.m);
        assert_eq!(a.rmse.to_bits(), b.rmse.to_bits());
        assert_eq!(a.iterations, b.iterations);
        assert_eq!(a.stop, b.stop);
    }

    #[test]
    fn align_with_grid_tight_budget_still_recovers() {
        // A 1-ring budget at 0.5 m cells misses correspondences past
        // ~1 m, yet the alignment must still land close to ground truth
        // (the bounded-error regime the approx strategy promises).
        let target = structured_cloud(1200, 25);
        let mut rng = Pcg32::new(26);
        let gt = small_transform(&mut rng);
        let source = target.transformed(&gt.inverse_rigid());
        let grid = crate::voxelgrid::VoxelGrid::build(&target, 0.5, 1);
        let res = align_with_grid(&source, &target, &grid, &Mat4::IDENTITY, &IcpParams::default());
        assert!(res.has_converged(), "stop={:?}", res.stop);
        let terr = (res.transformation.translation() - gt.translation()).norm();
        assert!(terr < 0.05, "translation err {terr}");
    }

    #[test]
    fn identity_alignment_converges_immediately() {
        let c = structured_cloud(500, 9);
        let res = align(&c, &c, &Mat4::IDENTITY, &IcpParams::default());
        assert_eq!(res.stop, StopReason::Converged);
        assert!(res.iterations <= 2);
        assert!(res.rmse < 1e-6);
        assert!(res.transformation.delta_from_identity() < 1e-9);
    }

    #[test]
    fn initial_guess_is_honored() {
        let target = structured_cloud(800, 10);
        let mut rng = Pcg32::new(11);
        let gt = small_transform(&mut rng);
        let source = target.transformed(&gt.inverse_rigid());
        // Start exactly at the answer: should converge in ~1 iteration.
        let res = align(&source, &target, &gt, &IcpParams::default());
        assert_eq!(res.stop, StopReason::Converged);
        assert!(res.iterations <= 2, "iterations {}", res.iterations);
    }

    #[test]
    fn too_few_correspondences_flagged() {
        // Disjoint clouds far beyond max correspondence distance.
        let a = structured_cloud(100, 12);
        let mut b = structured_cloud(100, 13);
        for v in b.xyz.iter_mut() {
            *v += 1000.0;
        }
        let res = align(&a, &b, &Mat4::IDENTITY, &IcpParams::default());
        assert_eq!(res.stop, StopReason::TooFewCorrespondences);
        assert!(!res.has_converged());
    }

    #[test]
    fn max_iterations_respected() {
        let target = structured_cloud(400, 14);
        let mut rng = Pcg32::new(15);
        let source = target.transformed(&small_transform(&mut rng).inverse_rigid());
        let res = align(
            &source,
            &target,
            &Mat4::IDENTITY,
            &IcpParams {
                max_iterations: 3,
                transformation_epsilon: 0.0, // never converge on epsilon
                ..Default::default()
            },
        );
        assert_eq!(res.iterations, 3);
        assert_eq!(res.stop, StopReason::MaxIterations);
        assert_eq!(res.stats.len(), 3);
    }

    #[test]
    fn rmse_monotonically_improves_roughly() {
        let target = structured_cloud(1000, 16);
        let mut rng = Pcg32::new(17);
        let source = target.transformed(&small_transform(&mut rng).inverse_rigid());
        let res = align(&source, &target, &Mat4::IDENTITY, &IcpParams::default());
        let first = res.stats.first().unwrap().rmse;
        let last = res.stats.last().unwrap().rmse;
        assert!(
            last <= first + 1e-9,
            "rmse went up: {first} -> {last}"
        );
    }

    #[test]
    fn property_random_small_transforms_recovered() {
        forall(default_cases(10), |g| {
            let seed = g.case + 5000;
            recovers(&IcpParams::default(), seed);
        });
    }

    #[test]
    fn partial_overlap_with_noise() {
        // Source sees only part of the target and both carry noise —
        // the realistic odometry regime; require approximate recovery.
        let mut rng = Pcg32::new(18);
        let target = structured_cloud(2000, 18);
        let gt = small_transform(&mut rng);
        let mut source = target.transformed(&gt.inverse_rigid());
        // Keep 70% of points.
        source = source.random_sample(1400, &mut rng);
        source.add_noise(0.01, &mut rng);
        let res = align(&source, &target, &Mat4::IDENTITY, &IcpParams::default());
        assert!(res.has_converged());
        let terr = (res.transformation.translation() - gt.translation()).norm();
        assert!(terr < 0.1, "translation err {terr}");
    }
}
