//! Counting global allocator for allocation-regression tests and the
//! data-plane bench.
//!
//! [`CountingAlloc`] wraps [`std::alloc::System`] and counts every
//! `alloc`/`realloc` (frees are not counted — the zero-copy invariant
//! is about *new* heap traffic). It does nothing unless a test or bench
//! crate installs it as its global allocator:
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: fpps::alloc_counter::CountingAlloc = fpps::alloc_counter::CountingAlloc::new();
//! ```
//!
//! The library itself never installs it, so the production binary pays
//! nothing. `tests/alloc_regression.rs` and `benches/data_plane.rs` use
//! it to assert the steady-state hot path performs **zero** heap
//! allocations per job (see the README "Data plane" section).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

/// Allocations observed since process start (only meaningful in a
/// binary that installed [`CountingAlloc`]).
static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);
/// Bytes requested across those allocations.
static ALLOCATED_BYTES: AtomicU64 = AtomicU64::new(0);

/// `System`-backed allocator that counts allocation events.
pub struct CountingAlloc;

impl CountingAlloc {
    pub const fn new() -> Self {
        CountingAlloc
    }
}

impl Default for CountingAlloc {
    fn default() -> Self {
        Self::new()
    }
}

// SAFETY: defers entirely to `System` (which upholds the `GlobalAlloc`
// contract); the added counters are lock-free atomics and never
// allocate, so they are safe inside the allocator itself.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        // ordering: Relaxed — monotonic counters; snapshots are taken
        // from quiescent test code, never used for synchronization.
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        ALLOCATED_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        // ordering: Relaxed — monotonic counters (see `alloc`).
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        ALLOCATED_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

/// Counter snapshot, taken via [`snapshot`] and differenced with
/// [`AllocSnapshot::delta`] around the region under measurement.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AllocSnapshot {
    pub allocations: u64,
    pub bytes: u64,
}

impl AllocSnapshot {
    /// Allocation events and bytes since `self` (the earlier snapshot).
    pub fn delta(&self, later: &AllocSnapshot) -> AllocSnapshot {
        AllocSnapshot {
            allocations: later.allocations - self.allocations,
            bytes: later.bytes - self.bytes,
        }
    }
}

/// Snapshot the global counters (zeros unless [`CountingAlloc`] is
/// installed in this binary).
pub fn snapshot() -> AllocSnapshot {
    // ordering: Relaxed — monotonic counters read for reporting; tests
    // difference snapshots taken on one thread.
    AllocSnapshot {
        allocations: ALLOCATIONS.load(Ordering::Relaxed),
        bytes: ALLOCATED_BYTES.load(Ordering::Relaxed),
    }
}
