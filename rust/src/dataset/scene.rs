//! Procedural driving scenes for the synthetic LiDAR.
//!
//! A scene is a set of analytic surfaces the raycaster intersects:
//! ground plane, axis-aligned boxes (buildings, vehicles), vertical
//! cylinders (poles, trunks). Scenes are generated along a road corridor
//! so that consecutive frames overlap the way real KITTI scans do.

use crate::rng::Pcg32;

/// Axis-aligned box.
#[derive(Clone, Copy, Debug)]
pub struct Aabb {
    pub min: [f64; 3],
    pub max: [f64; 3],
}

impl Aabb {
    /// Ray/AABB slab test; returns the entry distance if hit in (tmin, tmax).
    pub fn raycast(&self, origin: [f64; 3], dir: [f64; 3], tmax: f64) -> Option<f64> {
        let mut t0 = 1e-6f64;
        let mut t1 = tmax;
        for k in 0..3 {
            if dir[k].abs() < 1e-12 {
                if origin[k] < self.min[k] || origin[k] > self.max[k] {
                    return None;
                }
                continue;
            }
            let inv = 1.0 / dir[k];
            let (mut ta, mut tb) = ((self.min[k] - origin[k]) * inv, (self.max[k] - origin[k]) * inv);
            if ta > tb {
                std::mem::swap(&mut ta, &mut tb);
            }
            t0 = t0.max(ta);
            t1 = t1.min(tb);
            if t0 > t1 {
                return None;
            }
        }
        Some(t0)
    }
}

/// Vertical cylinder (pole/trunk): center (x, y), radius, z range.
#[derive(Clone, Copy, Debug)]
pub struct Cylinder {
    pub cx: f64,
    pub cy: f64,
    pub radius: f64,
    pub z0: f64,
    pub z1: f64,
}

impl Cylinder {
    pub fn raycast(&self, origin: [f64; 3], dir: [f64; 3], tmax: f64) -> Option<f64> {
        // 2D circle intersection in XY.
        let ox = origin[0] - self.cx;
        let oy = origin[1] - self.cy;
        let a = dir[0] * dir[0] + dir[1] * dir[1];
        if a < 1e-12 {
            return None;
        }
        let b = 2.0 * (ox * dir[0] + oy * dir[1]);
        let c = ox * ox + oy * oy - self.radius * self.radius;
        let disc = b * b - 4.0 * a * c;
        if disc < 0.0 {
            return None;
        }
        let sq = disc.sqrt();
        for t in [(-b - sq) / (2.0 * a), (-b + sq) / (2.0 * a)] {
            if t > 1e-6 && t < tmax {
                let z = origin[2] + t * dir[2];
                if z >= self.z0 && z <= self.z1 {
                    return Some(t);
                }
            }
        }
        None
    }
}

/// A static world the LiDAR scans.
#[derive(Clone, Debug, Default)]
pub struct Scene {
    /// Ground height (z of the road plane).
    pub ground_z: f64,
    /// Terrain undulation amplitude (m). A perfectly flat plane makes
    /// scan-to-scan ICP degenerate — the concentric ground rings
    /// self-match at identity (ring locking) — whereas real roads have
    /// slope/camber/roughness that make the ground informative. 0
    /// disables the heightfield.
    pub terrain_amplitude: f64,
    /// Small-scale surface roughness amplitude (m), applied as a
    /// world-anchored displacement along each ray (~1 m wavelength).
    /// Real facades/asphalt/vegetation have ≥ 3–5 cm of texture; perfectly
    /// smooth analytic surfaces make the same-ray self-match of two scans
    /// artificially near-zero, which biases point-to-point ICP toward
    /// identity (see DESIGN.md §3 on dataset realism).
    pub surface_roughness: f64,
    pub boxes: Vec<Aabb>,
    pub cylinders: Vec<Cylinder>,
}

impl Scene {
    /// Deterministic two-scale terrain heightfield h(x, y):
    /// * low frequency (wavelengths 15–90 m) ≈ road grade/camber;
    /// * high frequency (wavelengths 2–5 m, ~25% of the amplitude) ≈
    ///   surface roughness, curbs, grass verges. The high-frequency term
    ///   is what breaks the scan-pattern self-similarity: on a perfectly
    ///   smooth plane, the sensor-frame ground rings are *identical*
    ///   from any viewpoint, so scan-to-scan ICP locks onto identity.
    pub fn terrain_height(&self, x: f64, y: f64) -> f64 {
        if self.terrain_amplitude == 0.0 {
            return self.ground_z;
        }
        let a = self.terrain_amplitude;
        let low = 0.55 * (0.071 * x + 0.3).sin() * (0.053 * y - 0.8).cos()
            + 0.30 * (0.23 * x - 1.1).sin()
            + 0.15 * (0.41 * y + 0.37 * x + 2.0).sin();
        let high = 0.14 * (1.9 * x + 0.7).sin() * (1.3 * y - 0.2).cos()
            + 0.11 * (2.7 * y + 1.3).sin() * (0.9 * x + 0.5).cos();
        self.ground_z + a * (low + high)
    }

    /// World-anchored roughness field in [−1, 1] (wavelengths ~0.7–1.5 m).
    /// Deterministic in world position → consistent across frames.
    pub fn roughness(&self, x: f64, y: f64, z: f64) -> f64 {
        0.5 * (7.3 * x + 1.0).sin() * (6.1 * y).cos()
            + 0.3 * (5.7 * z + 2.0).sin() * (8.3 * x + 0.4).cos()
            + 0.2 * (9.1 * y + 4.1 * z + 1.7).sin()
    }
}

/// Scene style knobs per sequence category (urban vs highway vs rural).
#[derive(Clone, Copy, Debug)]
pub struct SceneStyle {
    /// Building rows offset from the road center line (m).
    pub building_setback: f64,
    /// Mean gap between buildings along the road (m).
    pub building_gap: f64,
    /// Building presence probability per slot.
    pub building_density: f64,
    /// Poles (street lights, signs) per 100 m of road.
    pub poles_per_100m: f64,
    /// Parked/moving vehicles per 100 m.
    pub vehicles_per_100m: f64,
    /// Road half-width (m).
    pub road_half_width: f64,
    /// Small clutter objects (bushes, bins, curb segments, hydrants) per
    /// 100 m of road — dense high-frequency structure that anchors
    /// scan-to-scan registration the way real street furniture does.
    pub clutter_per_100m: f64,
}

impl SceneStyle {
    pub fn urban() -> Self {
        Self {
            building_setback: 8.0,
            building_gap: 18.0,
            building_density: 0.85,
            poles_per_100m: 6.0,
            vehicles_per_100m: 4.0,
            road_half_width: 7.0,
            clutter_per_100m: 40.0,
        }
    }

    pub fn residential() -> Self {
        Self {
            building_setback: 10.0,
            building_gap: 22.0,
            building_density: 0.6,
            poles_per_100m: 4.0,
            vehicles_per_100m: 2.5,
            road_half_width: 6.0,
            clutter_per_100m: 30.0,
        }
    }

    pub fn highway() -> Self {
        Self {
            building_setback: 30.0,
            building_gap: 80.0,
            building_density: 0.15,
            poles_per_100m: 2.0,
            vehicles_per_100m: 1.5,
            road_half_width: 12.0,
            clutter_per_100m: 8.0,
        }
    }

    pub fn country() -> Self {
        Self {
            building_setback: 20.0,
            building_gap: 60.0,
            building_density: 0.25,
            poles_per_100m: 1.0,
            vehicles_per_100m: 0.8,
            road_half_width: 5.0,
            clutter_per_100m: 15.0,
        }
    }
}

/// Generate a corridor of world geometry along the x-axis from
/// `x0` to `x1` (the trajectory module maps road-arclength to world
/// coordinates; scenes are built in road-local frame for simplicity and
/// the raycaster queries them in that frame).
pub fn generate_corridor(style: &SceneStyle, x0: f64, x1: f64, rng: &mut Pcg32) -> Scene {
    let mut scene = Scene {
        ground_z: 0.0,
        ..Default::default()
    };
    let length = x1 - x0;

    // Building rows on both sides.
    for side in [-1.0f64, 1.0] {
        let mut x = x0;
        while x < x1 {
            let w = rng.range(8.0, 20.0) as f64;
            let d = rng.range(6.0, 15.0) as f64;
            let h = rng.range(4.0, 18.0) as f64;
            if (rng.uniform() as f64) < style.building_density {
                let y0 = side * style.building_setback;
                let (ymin, ymax) = if side < 0.0 { (y0 - d, y0) } else { (y0, y0 + d) };
                scene.boxes.push(Aabb {
                    min: [x, ymin, 0.0],
                    max: [x + w, ymax, h],
                });
            }
            x += w + rng.range(0.3, 1.0) as f64 * style.building_gap;
        }
    }

    // Poles along the curb.
    let n_poles = (length / 100.0 * style.poles_per_100m).round() as usize;
    for _ in 0..n_poles {
        let x = rng.range(x0 as f32, x1 as f32) as f64;
        let side = if rng.uniform() < 0.5 { -1.0 } else { 1.0 };
        let y = side * (style.road_half_width + rng.range(0.5, 2.0) as f64);
        scene.cylinders.push(Cylinder {
            cx: x,
            cy: y,
            radius: rng.range(0.08, 0.2) as f64,
            z0: 0.0,
            z1: rng.range(3.0, 8.0) as f64,
        });
    }

    // Vehicles: boxes on the road shoulder / adjacent lane.
    let n_veh = (length / 100.0 * style.vehicles_per_100m).round() as usize;
    for _ in 0..n_veh {
        let x = rng.range(x0 as f32, x1 as f32) as f64;
        let side = if rng.uniform() < 0.5 { -1.0 } else { 1.0 };
        let y = side * rng.range(2.5, style.road_half_width as f32 - 0.5) as f64;
        let (l, w, h) = (
            rng.range(3.8, 5.2) as f64,
            rng.range(1.6, 2.0) as f64,
            rng.range(1.4, 2.1) as f64,
        );
        scene.boxes.push(Aabb {
            min: [x - l / 2.0, y - w / 2.0, 0.0],
            max: [x + l / 2.0, y + w / 2.0, h],
        });
    }

    scene
}

impl Scene {
    /// Closest hit among ground, boxes and cylinders; `None` beyond tmax.
    ///
    /// Ground intersection: solve against the flat plane, then refine
    /// once against the local terrain height (one Newton step along the
    /// ray — ample for ≤2% grades), so returned ground points lie on the
    /// world surface z = h(x, y) consistently across frames.
    pub fn raycast(&self, origin: [f64; 3], dir: [f64; 3], tmax: f64) -> Option<f64> {
        let mut best = tmax;
        let mut hit = false;
        if dir[2] < -1e-9 {
            let mut t = (self.ground_z - origin[2]) / dir[2];
            if self.terrain_amplitude != 0.0 && t > 1e-6 {
                for _ in 0..2 {
                    let x = origin[0] + t * dir[0];
                    let y = origin[1] + t * dir[1];
                    let h = self.terrain_height(x, y);
                    t = (h - origin[2]) / dir[2];
                    if t <= 1e-6 {
                        break;
                    }
                }
            }
            if t > 1e-6 && t < best {
                best = t;
                hit = true;
            }
        }
        for b in &self.boxes {
            if let Some(t) = b.raycast(origin, dir, best) {
                best = t;
                hit = true;
            }
        }
        for c in &self.cylinders {
            if let Some(t) = c.raycast(origin, dir, best) {
                best = t;
                hit = true;
            }
        }
        hit.then_some(best)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aabb_raycast_hits_front_face() {
        let b = Aabb {
            min: [5.0, -1.0, -1.0],
            max: [6.0, 1.0, 1.0],
        };
        let t = b.raycast([0.0, 0.0, 0.0], [1.0, 0.0, 0.0], 100.0).unwrap();
        assert!((t - 5.0).abs() < 1e-9);
        // Miss sideways.
        assert!(b.raycast([0.0, 5.0, 0.0], [1.0, 0.0, 0.0], 100.0).is_none());
        // Behind the origin.
        assert!(b.raycast([10.0, 0.0, 0.0], [1.0, 0.0, 0.0], 100.0).is_none());
    }

    #[test]
    fn cylinder_raycast() {
        let c = Cylinder {
            cx: 5.0,
            cy: 0.0,
            radius: 0.5,
            z0: 0.0,
            z1: 4.0,
        };
        let t = c
            .raycast([0.0, 0.0, 1.0], [1.0, 0.0, 0.0], 100.0)
            .unwrap();
        assert!((t - 4.5).abs() < 1e-9);
        // Above the cylinder top: the ray passes over it.
        assert!(c.raycast([0.0, 0.0, 5.0], [1.0, 0.0, 0.0], 100.0).is_none());
        // Vertical ray has no XY motion → no hit.
        assert!(c.raycast([0.0, 0.0, 0.0], [0.0, 0.0, 1.0], 100.0).is_none());
    }

    #[test]
    fn ground_hit() {
        let s = Scene {
            ground_z: 0.0,
            ..Default::default()
        };
        // LiDAR 1.73 m up, beam 10° down.
        let a = (-10.0f64).to_radians();
        let dir = [a.cos(), 0.0, a.sin()];
        let t = s.raycast([0.0, 0.0, 1.73], dir, 120.0).unwrap();
        let z = 1.73 + t * dir[2];
        assert!(z.abs() < 1e-9);
        // Upward beam never hits the ground.
        assert!(s.raycast([0.0, 0.0, 1.73], [1.0, 0.0, 0.1], 120.0).is_none());
    }

    #[test]
    fn nearest_surface_wins() {
        let mut s = Scene::default();
        s.boxes.push(Aabb {
            min: [10.0, -1.0, 0.0],
            max: [11.0, 1.0, 3.0],
        });
        s.cylinders.push(Cylinder {
            cx: 5.0,
            cy: 0.0,
            radius: 0.3,
            z0: 0.0,
            z1: 3.0,
        });
        let t = s.raycast([0.0, 0.0, 1.0], [1.0, 0.0, 0.0], 100.0).unwrap();
        assert!((t - 4.7).abs() < 1e-9, "cylinder in front of box, t={t}");
    }

    #[test]
    fn corridor_generation_is_deterministic_and_populated() {
        let mut r1 = crate::rng::Pcg32::new(5);
        let mut r2 = crate::rng::Pcg32::new(5);
        let a = generate_corridor(&SceneStyle::urban(), 0.0, 500.0, &mut r1);
        let b = generate_corridor(&SceneStyle::urban(), 0.0, 500.0, &mut r2);
        assert_eq!(a.boxes.len(), b.boxes.len());
        assert_eq!(a.cylinders.len(), b.cylinders.len());
        assert!(a.boxes.len() > 10, "urban corridor should have buildings");
        assert!(a.cylinders.len() > 10);
        // Highway is sparser than urban.
        let mut r3 = crate::rng::Pcg32::new(5);
        let hw = generate_corridor(&SceneStyle::highway(), 0.0, 500.0, &mut r3);
        assert!(hw.boxes.len() < a.boxes.len());
    }
}
