//! Synthetic KITTI-like odometry dataset.
//!
//! The paper evaluates on KITTI odometry sequences 00–09 (Velodyne
//! HDL-64E, 10 Hz). That data is not available here, so this module
//! builds the closest synthetic equivalent (see DESIGN.md §3): a
//! procedural world generated *along* a sequence-specific trajectory,
//! scanned by the LiDAR model of [`lidar`]. Real KITTI `.bin` + poses
//! can be dropped in via [`Sequence::from_kitti_dir`] and the rest of
//! the stack is oblivious to the difference.

pub mod lidar;
pub mod scene;
pub mod trajectory;

use crate::math::Mat4;
use crate::pointcloud::{io, PointCloud};
use crate::rng::Pcg32;
use anyhow::{Context, Result};
use lidar::LidarConfig;
use scene::{Scene, SceneStyle};
use trajectory::{Trajectory, TrajectoryProfile};

/// Category of a sequence (drives both scene style and trajectory).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SequenceKind {
    Urban,
    Highway,
    Residential,
    Country,
}

/// Descriptor of one synthetic sequence, mimicking the character of the
/// corresponding KITTI odometry sequence.
#[derive(Clone, Debug)]
pub struct SequenceSpec {
    pub id: usize,
    pub name: &'static str,
    pub kind: SequenceKind,
    /// Reference frame count (full KITTI length; benches usually run a
    /// truncated prefix for time).
    pub frames: usize,
}

/// The ten sequences of the paper's evaluation, with kinds chosen to
/// match the published KITTI sequence characteristics (00 urban loop,
/// 01 highway, 02 long suburb loop, 03–04 short country roads, 05–07
/// urban/residential loops, 08 suburb, 09 country loop).
pub fn sequence_specs() -> Vec<SequenceSpec> {
    use SequenceKind::*;
    vec![
        SequenceSpec { id: 0, name: "00", kind: Urban, frames: 4541 },
        SequenceSpec { id: 1, name: "01", kind: Highway, frames: 1101 },
        SequenceSpec { id: 2, name: "02", kind: Country, frames: 4661 },
        SequenceSpec { id: 3, name: "03", kind: Residential, frames: 801 },
        SequenceSpec { id: 4, name: "04", kind: Country, frames: 271 },
        SequenceSpec { id: 5, name: "05", kind: Urban, frames: 2761 },
        SequenceSpec { id: 6, name: "06", kind: Urban, frames: 1101 },
        SequenceSpec { id: 7, name: "07", kind: Residential, frames: 1101 },
        SequenceSpec { id: 8, name: "08", kind: Country, frames: 4071 },
        SequenceSpec { id: 9, name: "09", kind: Residential, frames: 1591 },
    ]
}

impl SequenceKind {
    pub fn scene_style(self) -> SceneStyle {
        match self {
            SequenceKind::Urban => SceneStyle::urban(),
            SequenceKind::Highway => SceneStyle::highway(),
            SequenceKind::Residential => SceneStyle::residential(),
            SequenceKind::Country => SceneStyle::country(),
        }
    }

    pub fn trajectory_profile(self) -> TrajectoryProfile {
        match self {
            SequenceKind::Urban => TrajectoryProfile::urban(),
            SequenceKind::Highway => TrajectoryProfile::highway(),
            SequenceKind::Residential => TrajectoryProfile::residential(),
            SequenceKind::Country => TrajectoryProfile::country(),
        }
    }
}

/// Place roadside geometry *along* a trajectory (buildings, poles,
/// vehicles offset perpendicular to the local heading) so turning paths
/// still drive through a coherent corridor.
pub fn generate_scene_along(
    traj: &Trajectory,
    style: &SceneStyle,
    rng: &mut Pcg32,
) -> Scene {
    let mut sc = Scene {
        ground_z: 0.0,
        // ~18 cm of road grade / camber / roughness — keeps the ground
        // informative for registration (see Scene::terrain_height).
        terrain_amplitude: 0.18,
        // ~4 cm of world-anchored surface texture (asphalt, facades).
        surface_roughness: 0.04,
        ..Default::default()
    };
    let mut arclen = 0.0f64;
    let mut next_building = 0.0f64;
    let mut next_pole = 0.0f64;
    let mut next_vehicle = 0.0f64;
    let mut next_clutter = 0.0f64;
    let pole_gap = 100.0 / style.poles_per_100m.max(0.1);
    let veh_gap = 100.0 / style.vehicles_per_100m.max(0.1);
    let clutter_gap = 100.0 / style.clutter_per_100m.max(0.1);

    for i in 0..traj.len().saturating_sub(1) {
        let p = traj.poses[i].translation();
        let q = traj.poses[i + 1].translation();
        let step = (q - p).norm();
        arclen += step;
        // Local heading and its left-normal.
        let dir = (q - p).normalized();
        let nrm = crate::math::Vec3::new(-dir.y, dir.x, 0.0);

        if arclen >= next_building {
            for side in [-1.0f64, 1.0] {
                if (rng.uniform() as f64) < style.building_density {
                    let w = rng.range(8.0, 20.0) as f64;
                    let d = rng.range(6.0, 15.0) as f64;
                    let h = rng.range(4.0, 18.0) as f64;
                    let center = p + nrm * (side * (style.building_setback + d / 2.0));
                    sc.boxes.push(scene::Aabb {
                        min: [center.x - w / 2.0, center.y - d / 2.0, 0.0],
                        max: [center.x + w / 2.0, center.y + d / 2.0, h],
                    });
                }
            }
            next_building = arclen + style.building_gap * (0.5 + rng.uniform() as f64);
        }
        if arclen >= next_pole {
            let side = if rng.uniform() < 0.5 { -1.0 } else { 1.0 };
            let c = p + nrm * (side * (style.road_half_width + rng.range(0.5, 2.0) as f64));
            sc.cylinders.push(scene::Cylinder {
                cx: c.x,
                cy: c.y,
                radius: rng.range(0.08, 0.2) as f64,
                z0: 0.0,
                z1: rng.range(3.0, 8.0) as f64,
            });
            next_pole = arclen + pole_gap * (0.5 + rng.uniform() as f64);
        }
        if arclen >= next_clutter {
            // Street furniture / bushes: small boxes near the roadside.
            let n = 1 + rng.below(3) as usize;
            for _ in 0..n {
                let side = if rng.uniform() < 0.5 { -1.0 } else { 1.0 };
                let lateral =
                    side * (style.road_half_width + rng.range(0.3, 6.0) as f64);
                let along = rng.range(-4.0, 4.0) as f64;
                let c = p + nrm * lateral + dir * along;
                let s = rng.range(0.3, 1.5) as f64;
                let h = rng.range(0.3, 1.8) as f64;
                sc.boxes.push(scene::Aabb {
                    min: [c.x - s / 2.0, c.y - s / 2.0, 0.0],
                    max: [c.x + s / 2.0, c.y + s / 2.0, h],
                });
            }
            next_clutter = arclen + clutter_gap * (0.5 + rng.uniform() as f64);
        }
        if arclen >= next_vehicle {
            let side = if rng.uniform() < 0.5 { -1.0 } else { 1.0 };
            let c = p + nrm * (side * rng.range(2.5, style.road_half_width as f32 - 0.5) as f64);
            let (l, w, h) = (
                rng.range(3.8, 5.2) as f64,
                rng.range(1.6, 2.0) as f64,
                rng.range(1.4, 2.1) as f64,
            );
            sc.boxes.push(scene::Aabb {
                min: [c.x - l / 2.0, c.y - w / 2.0, 0.0],
                max: [c.x + l / 2.0, c.y + w / 2.0, h],
            });
            next_vehicle = arclen + veh_gap * (0.5 + rng.uniform() as f64);
        }
    }
    sc
}

/// A sequence ready for the odometry pipeline: per-frame clouds are
/// generated lazily (scanning is the expensive part) via [`Sequence::frame`].
pub struct Sequence {
    pub spec: SequenceSpec,
    pub ground_truth: Vec<Mat4>,
    source: SequenceSource,
    pub lidar: LidarConfig,
    seed: u64,
}

enum SequenceSource {
    Synthetic { scene: Scene },
    Kitti { velodyne_dir: std::path::PathBuf },
}

impl Sequence {
    /// Generate the synthetic stand-in for KITTI sequence `spec`,
    /// truncated to `frames` frames.
    pub fn synthetic(spec: SequenceSpec, frames: usize, seed: u64, lidar: LidarConfig) -> Self {
        let frames = frames.min(spec.frames);
        let mut rng = Pcg32::substream(seed, spec.id as u64);
        let traj = trajectory::generate(&spec.kind.trajectory_profile(), frames, &mut rng);
        let scene = generate_scene_along(&traj, &spec.kind.scene_style(), &mut rng);
        Self {
            spec,
            ground_truth: traj.poses,
            source: SequenceSource::Synthetic { scene },
            lidar,
            seed,
        }
    }

    /// Load a real KITTI odometry sequence directory
    /// (`velodyne/NNNNNN.bin` + `poses.txt`). Used when actual data is
    /// mounted; the synthetic path covers CI.
    pub fn from_kitti_dir(
        spec: SequenceSpec,
        dir: &std::path::Path,
        max_frames: usize,
    ) -> Result<Self> {
        let poses = io::read_kitti_poses(&dir.join("poses.txt"))
            .with_context(|| format!("sequence {}", spec.name))?;
        let frames = poses.len().min(max_frames);
        Ok(Self {
            spec,
            ground_truth: poses[..frames].to_vec(),
            source: SequenceSource::Kitti {
                velodyne_dir: dir.join("velodyne"),
            },
            lidar: LidarConfig::default(),
            seed: 0,
        })
    }

    pub fn len(&self) -> usize {
        self.ground_truth.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ground_truth.is_empty()
    }

    /// The sensor-frame cloud of frame `i`.
    pub fn frame(&self, i: usize) -> Result<PointCloud> {
        match &self.source {
            SequenceSource::Synthetic { scene } => {
                // Per-frame substream → frames are independent of access
                // order and can be regenerated identically.
                let mut rng = Pcg32::substream(
                    self.seed ^ 0x5EC_0FF5E7,
                    (self.spec.id as u64) << 32 | i as u64,
                );
                Ok(lidar::scan(scene, &self.ground_truth[i], &self.lidar, &mut rng))
            }
            SequenceSource::Kitti { velodyne_dir } => {
                io::read_kitti_bin(&velodyne_dir.join(format!("{i:06}.bin")))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_cover_paper_sequences() {
        let specs = sequence_specs();
        assert_eq!(specs.len(), 10);
        assert_eq!(specs[0].name, "00");
        assert_eq!(specs[9].name, "09");
        assert_eq!(specs[1].kind, SequenceKind::Highway); // 01 is the highway
    }

    #[test]
    fn synthetic_sequence_frames_regenerate_identically() {
        let spec = sequence_specs()[3].clone();
        let seq = Sequence::synthetic(spec, 5, 99, LidarConfig::tiny());
        let a = seq.frame(2).unwrap();
        let b = seq.frame(2).unwrap();
        assert_eq!(a, b);
        assert!(!a.is_empty());
    }

    #[test]
    fn consecutive_frames_overlap() {
        // Two consecutive scans, expressed in world frame, must overlap
        // substantially — the precondition for scan-to-scan ICP.
        let spec = sequence_specs()[0].clone();
        let seq = Sequence::synthetic(spec, 3, 7, LidarConfig::tiny());
        let a_world = seq.frame(0).unwrap().transformed(&seq.ground_truth[0]);
        let b_world = seq.frame(1).unwrap().transformed(&seq.ground_truth[1]);
        let tree = crate::kdtree::KdTree::build(&a_world);
        let close = b_world
            .iter()
            .filter(|&p| tree.nearest_within(p, 0.5).is_some())
            .count();
        let frac = close as f64 / b_world.len() as f64;
        assert!(frac > 0.5, "overlap fraction {frac}");
    }

    #[test]
    fn scene_along_trajectory_surrounds_path() {
        let mut rng = Pcg32::new(1);
        let traj = trajectory::generate(&TrajectoryProfile::urban(), 200, &mut rng);
        let sc = generate_scene_along(&traj, &SceneStyle::urban(), &mut rng);
        assert!(!sc.boxes.is_empty());
        assert!(!sc.cylinders.is_empty());
        // Geometry should be near the path, not at infinity.
        let end = traj.poses.last().unwrap().translation();
        let maxr = end.norm() + 200.0;
        for b in &sc.boxes {
            let c = crate::math::Vec3::new(
                (b.min[0] + b.max[0]) / 2.0,
                (b.min[1] + b.max[1]) / 2.0,
                0.0,
            );
            assert!(c.norm() < maxr);
        }
    }

    #[test]
    fn truncation_respected() {
        let spec = sequence_specs()[4].clone(); // 04 has 271 frames
        let seq = Sequence::synthetic(spec.clone(), 10_000, 1, LidarConfig::tiny());
        assert_eq!(seq.len(), 271);
        let seq2 = Sequence::synthetic(spec, 5, 1, LidarConfig::tiny());
        assert_eq!(seq2.len(), 5);
    }
}
