//! Spinning multi-beam LiDAR model (Velodyne HDL-64E-like).
//!
//! 64 beams spread over [-24.8°, +2.0°] elevation, a configurable number
//! of azimuth steps per revolution, ~120 m range, gaussian range noise.
//! Rays are cast against the analytic [`Scene`](super::scene::Scene) and
//! returned in the *sensor frame* (exactly what a real `.bin` holds).

use super::scene::Scene;
use crate::math::Mat4;
use crate::pointcloud::PointCloud;
use crate::rng::Pcg32;

/// LiDAR intrinsics.
#[derive(Clone, Copy, Debug)]
pub struct LidarConfig {
    pub beams: usize,
    /// Azimuth steps per revolution. HDL-64E ≈ 2083 @10 Hz; we default
    /// lower to keep synthetic frames ~10–40k points (the registration
    /// working set after PCL's usual downsampling).
    pub azimuth_steps: usize,
    /// Elevation range (radians): min (down) to max (up).
    pub elev_min: f64,
    pub elev_max: f64,
    /// Max range (m).
    pub max_range: f64,
    /// 1σ range noise (m). HDL-64E datasheet: ~2 cm.
    pub range_noise: f64,
    /// Probability a return is dropped (dust, absorption).
    pub dropout: f64,
}

impl Default for LidarConfig {
    fn default() -> Self {
        Self {
            beams: 64,
            azimuth_steps: 600,
            elev_min: (-24.8f64).to_radians(),
            elev_max: 2.0f64.to_radians(),
            max_range: 120.0,
            range_noise: 0.02,
            dropout: 0.02,
        }
    }
}

impl LidarConfig {
    /// Smaller scan for fast tests.
    pub fn tiny() -> Self {
        Self {
            beams: 16,
            azimuth_steps: 90,
            ..Default::default()
        }
    }
}

/// Cast one full revolution from `pose` (sensor→world) and return the
/// cloud in the sensor frame.
pub fn scan(scene: &Scene, pose: &Mat4, cfg: &LidarConfig, rng: &mut Pcg32) -> PointCloud {
    let origin_v = pose.translation();
    let origin = [origin_v.x, origin_v.y, origin_v.z];
    let rot = pose.rotation();
    let inv = pose.inverse_rigid();

    let mut cloud = PointCloud::with_capacity(cfg.beams * cfg.azimuth_steps / 2);
    for az_i in 0..cfg.azimuth_steps {
        let az = 2.0 * std::f64::consts::PI * az_i as f64 / cfg.azimuth_steps as f64;
        let (saz, caz) = az.sin_cos();
        for b in 0..cfg.beams {
            let elev = cfg.elev_min
                + (cfg.elev_max - cfg.elev_min) * b as f64 / (cfg.beams - 1).max(1) as f64;
            let (sel, cel) = elev.sin_cos();
            // Sensor-frame direction, rotated to world by the pose.
            let d_sensor = crate::math::Vec3::new(cel * caz, cel * saz, sel);
            let d_world = rot.mul_vec(d_sensor);
            let dir = [d_world.x, d_world.y, d_world.z];
            if let Some(t) = scene.raycast(origin, dir, cfg.max_range) {
                if cfg.dropout > 0.0 && (rng.uniform() as f64) < cfg.dropout {
                    continue;
                }
                // World-anchored surface texture (consistent across
                // frames) + per-return sensor noise.
                let rough = if scene.surface_roughness > 0.0 {
                    let hx = origin[0] + t * dir[0];
                    let hy = origin[1] + t * dir[1];
                    let hz = origin[2] + t * dir[2];
                    scene.surface_roughness * scene.roughness(hx, hy, hz)
                } else {
                    0.0
                };
                let t_noisy =
                    t + rough + rng.normal_ms(0.0, cfg.range_noise as f32) as f64;
                let hit_world = crate::math::Vec3::new(
                    origin[0] + t_noisy * dir[0],
                    origin[1] + t_noisy * dir[1],
                    origin[2] + t_noisy * dir[2],
                );
                let hit_sensor = inv.apply(hit_world);
                cloud.push(hit_sensor.to_f32());
            }
        }
    }
    cloud
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::scene::{generate_corridor, SceneStyle};
    use crate::math::{Mat3, Vec3};

    fn flat_scene() -> Scene {
        Scene {
            ground_z: 0.0,
            ..Default::default()
        }
    }

    fn pose_at(x: f64, y: f64) -> Mat4 {
        Mat4::from_rt(Mat3::IDENTITY, Vec3::new(x, y, 1.73))
    }

    #[test]
    fn ground_only_scan_is_a_disc_below_sensor() {
        let mut rng = Pcg32::new(1);
        let cfg = LidarConfig {
            range_noise: 0.0,
            dropout: 0.0,
            ..LidarConfig::tiny()
        };
        let cloud = scan(&flat_scene(), &pose_at(0.0, 0.0), &cfg, &mut rng);
        assert!(!cloud.is_empty());
        for p in cloud.iter() {
            // Sensor frame: ground points sit 1.73 m below the origin.
            assert!((p[2] + 1.73).abs() < 1e-3, "z={}", p[2]);
            let r = (p[0] * p[0] + p[1] * p[1]).sqrt();
            assert!(r <= cfg.max_range as f32 + 1.0);
        }
        // Only downward beams return → fewer than beams*steps points.
        assert!(cloud.len() < cfg.beams * cfg.azimuth_steps);
    }

    #[test]
    fn scan_is_sensor_frame_invariant_on_flat_ground() {
        // On an infinite plane, scans from two positions (same heading)
        // are identical in the sensor frame (up to rng noise, disabled).
        let cfg = LidarConfig {
            range_noise: 0.0,
            dropout: 0.0,
            ..LidarConfig::tiny()
        };
        let a = scan(&flat_scene(), &pose_at(0.0, 0.0), &cfg, &mut Pcg32::new(2));
        let b = scan(&flat_scene(), &pose_at(50.0, -3.0), &cfg, &mut Pcg32::new(2));
        assert_eq!(a.len(), b.len());
        for (p, q) in a.iter().zip(b.iter()) {
            for k in 0..3 {
                assert!((p[k] - q[k]).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn walls_produce_vertical_structure() {
        let mut rng = Pcg32::new(3);
        let mut scene = flat_scene();
        scene.boxes.push(super::super::scene::Aabb {
            min: [10.0, -50.0, 0.0],
            max: [12.0, 50.0, 10.0],
        });
        let cfg = LidarConfig {
            range_noise: 0.0,
            dropout: 0.0,
            ..LidarConfig::tiny()
        };
        let cloud = scan(&scene, &pose_at(0.0, 0.0), &cfg, &mut rng);
        // Some returns must be above sensor-ground level (wall hits).
        let above = cloud.iter().filter(|p| p[2] > -1.0).count();
        assert!(above > 0, "no wall returns");
    }

    #[test]
    fn noise_and_dropout_change_output() {
        let mut scene = flat_scene();
        scene.boxes.push(super::super::scene::Aabb {
            min: [5.0, -5.0, 0.0],
            max: [6.0, 5.0, 3.0],
        });
        let cfg_clean = LidarConfig {
            range_noise: 0.0,
            dropout: 0.0,
            ..LidarConfig::tiny()
        };
        let cfg_noisy = LidarConfig {
            range_noise: 0.05,
            dropout: 0.3,
            ..LidarConfig::tiny()
        };
        let clean = scan(&scene, &pose_at(0.0, 0.0), &cfg_clean, &mut Pcg32::new(4));
        let noisy = scan(&scene, &pose_at(0.0, 0.0), &cfg_noisy, &mut Pcg32::new(4));
        assert!(noisy.len() < clean.len(), "dropout should remove returns");
    }

    #[test]
    fn realistic_corridor_scan_density() {
        let mut rng = Pcg32::new(5);
        let scene = generate_corridor(&SceneStyle::urban(), -60.0, 200.0, &mut rng);
        let cloud = scan(
            &scene,
            &pose_at(50.0, 0.0),
            &LidarConfig::default(),
            &mut rng,
        );
        // Urban scene at default resolution: tens of thousands of returns.
        assert!(cloud.len() > 10_000, "only {} returns", cloud.len());
    }
}
