//! Vehicle trajectory models for the ten synthetic sequences.
//!
//! Each KITTI odometry sequence has a distinct driving character that
//! directly shapes ICP cost (motion magnitude between frames → initial
//! misalignment → iterations to converge). The paper's Table IV speedups
//! vary 4.8×–35.4× across sequences largely because of this. We model
//! each sequence as a piecewise yaw-rate/speed profile integrated at the
//! sensor rate (10 Hz, like the Velodyne HDL-64E).

use crate::math::{Mat3, Mat4, Vec3};
use crate::rng::Pcg32;

/// Per-sequence driving profile.
#[derive(Clone, Debug)]
pub struct TrajectoryProfile {
    /// Mean speed (m/s).
    pub speed_mean: f64,
    /// Speed variation amplitude (m/s).
    pub speed_var: f64,
    /// Yaw rate changes: probability per frame of entering a turn.
    pub turn_prob: f64,
    /// Max yaw rate during a turn (rad/s).
    pub max_yaw_rate: f64,
    /// Typical turn duration (frames).
    pub turn_frames: usize,
}

impl TrajectoryProfile {
    /// Urban loop (KITTI 00/05/06/07-like): moderate speed, many turns.
    pub fn urban() -> Self {
        Self {
            speed_mean: 8.0,
            speed_var: 3.0,
            turn_prob: 0.04,
            max_yaw_rate: 0.5,
            turn_frames: 25,
        }
    }

    /// Highway (KITTI 01-like): fast, nearly straight.
    pub fn highway() -> Self {
        Self {
            speed_mean: 22.0,
            speed_var: 4.0,
            turn_prob: 0.005,
            max_yaw_rate: 0.08,
            turn_frames: 40,
        }
    }

    /// Residential (KITTI 03/09-like): slow with gentle curves.
    pub fn residential() -> Self {
        Self {
            speed_mean: 6.0,
            speed_var: 2.0,
            turn_prob: 0.03,
            max_yaw_rate: 0.35,
            turn_frames: 20,
        }
    }

    /// Country road (KITTI 02/04/08-like): medium speed, sweeping curves.
    pub fn country() -> Self {
        Self {
            speed_mean: 13.0,
            speed_var: 3.0,
            turn_prob: 0.02,
            max_yaw_rate: 0.2,
            turn_frames: 35,
        }
    }
}

/// Sensor frame rate (Hz) — Velodyne HDL-64E spins at 10 Hz.
pub const FRAME_RATE_HZ: f64 = 10.0;

/// A generated trajectory: one pose per frame (sensor → world).
#[derive(Clone, Debug)]
pub struct Trajectory {
    pub poses: Vec<Mat4>,
}

/// Integrate a yaw/speed random process into per-frame SE(3) poses.
/// z stays on the ground plane + small suspension bounce; pitch/roll are
/// ignored (dominant LiDAR odometry motion is planar).
pub fn generate(profile: &TrajectoryProfile, frames: usize, rng: &mut Pcg32) -> Trajectory {
    let dt = 1.0 / FRAME_RATE_HZ;
    let mut poses = Vec::with_capacity(frames);
    let mut x = 0.0f64;
    let mut y = 0.0f64;
    let mut yaw = 0.0f64;
    let mut yaw_rate = 0.0f64;
    let mut turn_left = 0usize;
    let mut speed = profile.speed_mean;

    for _ in 0..frames {
        // Speed follows a bounded random walk around the mean.
        speed += rng.normal_ms(0.0, 0.3) as f64;
        let lo = (profile.speed_mean - profile.speed_var).max(0.5);
        let hi = profile.speed_mean + profile.speed_var;
        speed = speed.clamp(lo, hi);

        // Turn state machine.
        if turn_left == 0 {
            if (rng.uniform() as f64) < profile.turn_prob {
                turn_left = profile.turn_frames + rng.below(10) as usize;
                let sign = if rng.uniform() < 0.5 { -1.0 } else { 1.0 };
                yaw_rate = sign * (rng.uniform() as f64) * profile.max_yaw_rate;
            } else {
                // Straight driving keeps a small heading jitter.
                yaw_rate = rng.normal_ms(0.0, 0.01) as f64;
            }
        } else {
            turn_left -= 1;
        }

        yaw += yaw_rate * dt;
        x += speed * dt * yaw.cos();
        y += speed * dt * yaw.sin();
        let z = 1.73 + rng.normal_ms(0.0, 0.005) as f64; // sensor height + bounce

        // Suspension pitch/roll wobble (±~0.4°). Real vehicles never
        // hold the sensor perfectly level; this frame-to-frame attitude
        // jitter is also what keeps the scan ray pattern from
        // self-matching between consecutive frames (see DESIGN.md §3).
        let pitch = rng.normal_ms(0.0, 0.004) as f64 + 0.003 * (0.13 * x).sin();
        let roll = rng.normal_ms(0.0, 0.004) as f64 + 0.003 * (0.11 * y + 1.0).sin();
        let rot = Mat3::rot_z(yaw)
            .mul_mat(&Mat3::axis_angle([0.0, 1.0, 0.0], pitch as f32))
            .mul_mat(&Mat3::axis_angle([1.0, 0.0, 0.0], roll as f32));

        poses.push(Mat4::from_rt(rot, Vec3::new(x, y, z)));
    }
    Trajectory { poses }
}

impl Trajectory {
    pub fn len(&self) -> usize {
        self.poses.len()
    }

    pub fn is_empty(&self) -> bool {
        self.poses.is_empty()
    }

    /// Relative motion from frame i to i+1 (used to seed ICP tests).
    pub fn relative(&self, i: usize) -> Mat4 {
        self.poses[i].inverse_rigid().mul_mat(&self.poses[i + 1])
    }

    /// Total arc length (m).
    pub fn length(&self) -> f64 {
        let mut s = 0.0;
        for w in self.poses.windows(2) {
            s += (w[1].translation() - w[0].translation()).norm();
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = generate(&TrajectoryProfile::urban(), 50, &mut Pcg32::new(3));
        let b = generate(&TrajectoryProfile::urban(), 50, &mut Pcg32::new(3));
        for (p, q) in a.poses.iter().zip(b.poses.iter()) {
            assert_eq!(p.m, q.m);
        }
    }

    #[test]
    fn poses_are_rigid() {
        let t = generate(&TrajectoryProfile::urban(), 100, &mut Pcg32::new(4));
        for p in &t.poses {
            assert!(p.rotation().is_rotation(1e-9));
        }
    }

    #[test]
    fn highway_is_faster_and_straighter_than_urban() {
        let hw = generate(&TrajectoryProfile::highway(), 300, &mut Pcg32::new(5));
        let ur = generate(&TrajectoryProfile::urban(), 300, &mut Pcg32::new(5));
        assert!(hw.length() > ur.length() * 1.5, "{} vs {}", hw.length(), ur.length());
        // Net heading change: urban should accumulate more.
        let yaw_span = |t: &Trajectory| {
            let mut max_angle = 0.0f64;
            for p in &t.poses {
                max_angle = max_angle.max(t.poses[0].rotation().rotation_angle_to(&p.rotation()));
            }
            max_angle
        };
        assert!(yaw_span(&ur) > yaw_span(&hw));
    }

    #[test]
    fn frame_to_frame_motion_bounded() {
        let t = generate(&TrajectoryProfile::highway(), 200, &mut Pcg32::new(6));
        for i in 0..t.len() - 1 {
            let rel = t.relative(i);
            let d = rel.translation().norm();
            // ≤ (22+4) m/s · 0.1 s plus slack.
            assert!(d < 3.0, "frame {i} moved {d} m");
        }
    }

    #[test]
    fn sensor_height_approx_constant() {
        let t = generate(&TrajectoryProfile::country(), 100, &mut Pcg32::new(7));
        for p in &t.poses {
            let z = p.translation().z;
            assert!((z - 1.73).abs() < 0.05, "z={z}");
        }
    }
}
