//! PJRT runtime — the host↔device interface of Fig. 2.
//!
//! Loads the AOT artifacts produced by `python/compile/aot.py` (HLO
//! *text*; see /opt/xla-example/README.md for why not serialized protos),
//! compiles them once on the PJRT CPU client, and executes the
//! `icp_step` computation from the ICP hot loop. This is the software
//! stand-in for the Alveo's xclbin load + kernel enqueue: python never
//! runs at request time, exactly as the FPGA bitstream is synthesised
//! offline.
//!
//! The PJRT client itself needs the `xla` bindings, which are an
//! optional dependency behind the `xla` cargo feature (see Cargo.toml).
//! Without the feature, [`Engine::load`] returns a contextful error and
//! the rest of the crate (manifest parsing, accumulator wire format)
//! still works — callers fall back to `fpps_api::NativeSimBackend`.
//!
//! Artifact layout (written by `make artifacts`):
//! ```text
//! artifacts/
//!   manifest.txt                 # key=value (config::KvConfig)
//!   icp_step_<N>x<M>.hlo.txt     # one per shape variant
//! ```
//! Manifest keys per variant `v`:
//! `variant.<v>.n`, `variant.<v>.m`, `variant.<v>.file`,
//! `variant.<v>.block_n`, `variant.<v>.block_m`.

use crate::config::KvConfig;
use crate::math::{Mat3, Vec3};
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};
use std::time::Duration;

pub use engine::{Engine, PreparedSource, PreparedTarget};

/// One fixed-shape compiled variant of the device program.
#[derive(Clone, Debug)]
pub struct VariantSpec {
    pub name: String,
    /// Source capacity (points).
    pub n: usize,
    /// Target capacity (points).
    pub m: usize,
    /// Kernel block sizes (must mirror nn_search.py for NativeSim parity).
    pub block_n: usize,
    pub block_m: usize,
    pub file: PathBuf,
}

/// The accumulators returned by one device ICP step — the output of the
/// paper's result accumulator block, consumed by the host SVD.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepAccumulators {
    /// Number of accepted correspondences (Σw).
    pub count: f64,
    /// Σw·p (transformed source points).
    pub sum_p: Vec3,
    /// Σw·q (matched target points).
    pub sum_q: Vec3,
    /// Σw·p·qᵀ.
    pub sum_pq: Mat3,
    /// Σw·‖p−q‖².
    pub sum_sq_dist: f64,
}

impl StepAccumulators {
    /// Parse the 17-float wire layout the artifact returns:
    /// [count, sum_p(3), sum_q(3), sum_pq(9 row-major), sum_sq_dist].
    pub fn from_wire(vals: &[f32]) -> Result<Self> {
        if vals.len() != 17 {
            bail!("expected 17 accumulator floats, got {}", vals.len());
        }
        let mut sum_pq = Mat3::zero();
        for i in 0..3 {
            for j in 0..3 {
                sum_pq.m[i][j] = vals[7 + i * 3 + j] as f64;
            }
        }
        Ok(Self {
            count: vals[0] as f64,
            sum_p: Vec3::new(vals[1] as f64, vals[2] as f64, vals[3] as f64),
            sum_q: Vec3::new(vals[4] as f64, vals[5] as f64, vals[6] as f64),
            sum_pq,
            sum_sq_dist: vals[16] as f64,
        })
    }

    /// True when every accumulator component is finite. A NaN/inf here
    /// means the device reduction (or its transport) is corrupted — the
    /// host SVD must treat the step as an infrastructure failure, not as
    /// a correspondence-count signal.
    pub fn is_finite(&self) -> bool {
        self.count.is_finite()
            && self.sum_sq_dist.is_finite()
            && [self.sum_p, self.sum_q]
                .iter()
                .all(|v| v.x.is_finite() && v.y.is_finite() && v.z.is_finite())
            && self.sum_pq.m.iter().flatten().all(|v| v.is_finite())
    }

    /// RMS correspondence distance (Table III metric, per iteration).
    pub fn rmse(&self) -> f64 {
        if self.count <= 0.0 {
            f64::NAN
        } else {
            (self.sum_sq_dist / self.count).sqrt()
        }
    }
}

/// Parsed artifact manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub variants: Vec<VariantSpec>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let kv = KvConfig::load(&dir.join("manifest.txt"))
            .with_context(|| format!("artifact manifest in {}", dir.display()))?;
        Self::from_kv(&kv, dir)
    }

    pub fn from_kv(kv: &KvConfig, dir: &Path) -> Result<Self> {
        let mut names: Vec<String> = Vec::new();
        for k in kv.keys() {
            if let Some(rest) = k.strip_prefix("variant.") {
                if let Some(name) = rest.strip_suffix(".n") {
                    names.push(name.to_string());
                }
            }
        }
        names.sort();
        if names.is_empty() {
            bail!("manifest has no variants");
        }
        let mut variants = Vec::new();
        for name in names {
            let get = |suffix: &str| -> Result<&str> {
                kv.require(&format!("variant.{name}.{suffix}"))
            };
            let variant = VariantSpec {
                n: get("n")?.parse().context("variant n")?,
                m: get("m")?.parse().context("variant m")?,
                block_n: get("block_n")?.parse().context("variant block_n")?,
                block_m: get("block_m")?.parse().context("variant block_m")?,
                file: dir.join(get("file")?),
                name: name.clone(),
            };
            if variant.n % variant.block_n != 0 || variant.m % variant.block_m != 0 {
                bail!("variant {name}: shape not divisible by blocks");
            }
            variants.push(variant);
        }
        // Smallest capacity first → selection picks the cheapest fit.
        variants.sort_by_key(|v| (v.n as u64) * (v.m as u64));
        Ok(Self { variants })
    }

    /// Smallest variant that fits (n_source, n_target).
    pub fn select(&self, n_source: usize, n_target: usize) -> Option<&VariantSpec> {
        self.variants
            .iter()
            .find(|v| v.n >= n_source && v.m >= n_target)
    }
}

/// Execution timing of one device step.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepTiming {
    pub upload: Duration,
    pub execute: Duration,
}

#[cfg(feature = "xla")]
mod engine {
    //! Real PJRT engine: client + per-variant compiled executables.

    use super::{Manifest, StepAccumulators, StepTiming};
    use crate::math::Mat4;
    use anyhow::{bail, Context, Result};
    use std::path::Path;
    use std::time::Instant;

    /// Target half of the device-resident cloud buffers — the paper's
    /// HBM-uploaded reference cloud. Uploaded once per *target*, not per
    /// alignment: scan-to-map callers keep one of these alive across
    /// thousands of queries, and `fpps_api::XlaBackend` holds an LRU
    /// *set* of them (one per target key, sized by the hwmodel HBM
    /// residency budget) so alternating-map workloads swap between
    /// still-resident buffers instead of re-shipping.
    pub struct PreparedTarget {
        m: usize,
        tgt: xla::PjRtBuffer,
        tgt_mask: xla::PjRtBuffer,
    }

    impl PreparedTarget {
        /// Padded target capacity (points).
        pub fn points(&self) -> usize {
            self.m
        }
    }

    /// Source half of the device-resident cloud buffers — uploaded once
    /// per alignment and reused across all ICP iterations (only the 4×4
    /// transform and the scalar threshold change per iteration).
    pub struct PreparedSource {
        n: usize,
        src: xla::PjRtBuffer,
        src_mask: xla::PjRtBuffer,
    }

    impl PreparedSource {
        /// Padded source capacity (points).
        pub fn points(&self) -> usize {
            self.n
        }
    }

    /// PJRT engine: client + per-variant compiled executables.
    pub struct Engine {
        client: xla::PjRtClient,
        manifest: Manifest,
        executables: Vec<Option<xla::PjRtLoadedExecutable>>,
        /// Cumulative executions (metrics).
        pub executions: u64,
    }

    impl Engine {
        /// `hardwareInitialize()` of Table I: create the client and load the
        /// "bitstream" (compile all HLO variants eagerly so the request path
        /// never compiles).
        pub fn load(artifacts_dir: &Path) -> Result<Self> {
            let manifest = Manifest::load(artifacts_dir)?;
            let client = xla::PjRtClient::cpu().map_err(xla_err)?;
            let mut executables = Vec::new();
            for v in &manifest.variants {
                let proto = xla::HloModuleProto::from_text_file(
                    v.file
                        .to_str()
                        .with_context(|| format!("non-utf8 path {:?}", v.file))?,
                )
                .map_err(xla_err)
                .with_context(|| format!("load HLO for variant {}", v.name))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .map_err(xla_err)
                    .with_context(|| format!("compile variant {}", v.name))?;
                executables.push(Some(exe));
            }
            Ok(Self {
                client,
                manifest,
                executables,
                executions: 0,
            })
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Execute one ICP step on variant `vi`.
        ///
        /// `src`/`tgt` must already be padded to the variant capacities and
        /// the masks sized accordingly (see `nn::pad_cloud`). `transform` is
        /// applied to the source *inside* the device program (the point
        /// cloud transformer stage).
        #[allow(clippy::too_many_arguments)]
        pub fn execute_step(
            &mut self,
            vi: usize,
            src: &[f32],
            tgt: &[f32],
            src_mask: &[f32],
            tgt_mask: &[f32],
            transform: &Mat4,
            max_dist_sq: f32,
        ) -> Result<(StepAccumulators, StepTiming)> {
            let v = &self.manifest.variants[vi];
            if src.len() != v.n * 3 || tgt.len() != v.m * 3 {
                bail!(
                    "variant {} expects {}x{} points, got {}x{}",
                    v.name,
                    v.n,
                    v.m,
                    src.len() / 3,
                    tgt.len() / 3
                );
            }
            if src_mask.len() != v.n || tgt_mask.len() != v.m {
                bail!("mask sizes do not match variant {}", v.name);
            }
            let t0 = Instant::now();
            let t_mat = transform.to_f32_row_major();
            let lits = vec![
                xla::Literal::vec1(src)
                    .reshape(&[v.n as i64, 3])
                    .map_err(xla_err)?,
                xla::Literal::vec1(tgt)
                    .reshape(&[v.m as i64, 3])
                    .map_err(xla_err)?,
                xla::Literal::vec1(src_mask),
                xla::Literal::vec1(tgt_mask),
                xla::Literal::vec1(&t_mat).reshape(&[4, 4]).map_err(xla_err)?,
                xla::Literal::scalar(max_dist_sq),
            ];
            let upload = t0.elapsed();

            let t1 = Instant::now();
            let exe = self.executables[vi]
                .as_ref()
                .expect("variant compiled at load");
            let result = exe.execute::<xla::Literal>(&lits).map_err(xla_err)?[0][0]
                .to_literal_sync()
                .map_err(xla_err)?;
            let execute = t1.elapsed();
            self.executions += 1;

            let outs = result.to_tuple().map_err(xla_err)?;
            let mut wire = Vec::with_capacity(17);
            for o in &outs {
                wire.extend(o.to_vec::<f32>().map_err(xla_err)?);
            }
            let acc = StepAccumulators::from_wire(&wire)?;
            Ok((acc, StepTiming { upload, execute }))
        }

        /// Upload the padded target cloud + mask to device buffers once
        /// (the target half of the Fig. 2 host→HBM DMA). The returned
        /// handle outlives any number of alignments against this target —
        /// pair it with fresh [`Engine::prepare_source`] uploads and
        /// execute via [`Engine::execute_resident`].
        pub fn prepare_target(&self, tgt: &[f32], tgt_mask: &[f32]) -> Result<PreparedTarget> {
            let m = tgt.len() / 3;
            if tgt_mask.len() != m {
                bail!("target mask has {} entries for {m} points", tgt_mask.len());
            }
            if !self.manifest.variants.iter().any(|v| v.m == m) {
                bail!("no artifact variant with target capacity {m}");
            }
            Ok(PreparedTarget {
                m,
                tgt: self
                    .client
                    .buffer_from_host_buffer(tgt, &[m, 3], None)
                    .map_err(xla_err)?,
                tgt_mask: self
                    .client
                    .buffer_from_host_buffer(tgt_mask, &[m], None)
                    .map_err(xla_err)?,
            })
        }

        /// Upload the padded source cloud + mask (the per-alignment half
        /// of the DMA).
        pub fn prepare_source(&self, src: &[f32], src_mask: &[f32]) -> Result<PreparedSource> {
            let n = src.len() / 3;
            if src_mask.len() != n {
                bail!("source mask has {} entries for {n} points", src_mask.len());
            }
            if !self.manifest.variants.iter().any(|v| v.n == n) {
                bail!("no artifact variant with source capacity {n}");
            }
            Ok(PreparedSource {
                n,
                src: self
                    .client
                    .buffer_from_host_buffer(src, &[n, 3], None)
                    .map_err(xla_err)?,
                src_mask: self
                    .client
                    .buffer_from_host_buffer(src_mask, &[n], None)
                    .map_err(xla_err)?,
            })
        }

        /// One ICP iteration over device-resident clouds: uploads only the
        /// 4×4 transform + threshold, executes buffer-to-buffer. The
        /// (source, target) capacities must name a compiled variant.
        pub fn execute_resident(
            &mut self,
            tgt: &PreparedTarget,
            src: &PreparedSource,
            transform: &Mat4,
            max_dist_sq: f32,
        ) -> Result<(StepAccumulators, StepTiming)> {
            let vi = self
                .manifest
                .variants
                .iter()
                .position(|v| v.n == src.n && v.m == tgt.m)
                .with_context(|| {
                    format!(
                        "no compiled variant with capacity {}x{} \
                         (resident target and uploaded source disagree?)",
                        src.n, tgt.m
                    )
                })?;
            let t0 = Instant::now();
            let t_mat = transform.to_f32_row_major();
            let t_buf = self
                .client
                .buffer_from_host_buffer(&t_mat, &[4, 4], None)
                .map_err(xla_err)?;
            let d_buf = self
                .client
                .buffer_from_host_buffer(&[max_dist_sq], &[], None)
                .map_err(xla_err)?;
            let upload = t0.elapsed();

            let t1 = Instant::now();
            let exe = self.executables[vi]
                .as_ref()
                .expect("variant compiled at load");
            let args = [
                &src.src,
                &tgt.tgt,
                &src.src_mask,
                &tgt.tgt_mask,
                &t_buf,
                &d_buf,
            ];
            let result = exe.execute_b::<&xla::PjRtBuffer>(&args).map_err(xla_err)?[0][0]
                .to_literal_sync()
                .map_err(xla_err)?;
            let execute = t1.elapsed();
            self.executions += 1;

            let outs = result.to_tuple().map_err(xla_err)?;
            let mut wire = Vec::with_capacity(17);
            for o in &outs {
                wire.extend(o.to_vec::<f32>().map_err(xla_err)?);
            }
            let acc = StepAccumulators::from_wire(&wire)?;
            Ok((acc, StepTiming { upload, execute }))
        }
    }

    /// The `xla` crate's error type does not implement `std::error::Error`
    /// for anyhow interop in all versions; stringify defensively.
    fn xla_err(e: xla::Error) -> anyhow::Error {
        anyhow::anyhow!("xla: {e:?}")
    }
}

#[cfg(not(feature = "xla"))]
mod engine {
    //! Stub engine compiled when the `xla` feature is off.
    //!
    //! [`Engine::load`] always fails with an actionable error, so the
    //! engine can never exist at runtime (every type here contains an
    //! uninhabited field); every method body is therefore unreachable and
    //! typechecks via the empty match. Callers such as
    //! `fpps_api::XlaBackend` and the CLI keep compiling unchanged and
    //! fall back to `NativeSimBackend`.

    use super::{Manifest, StepAccumulators, StepTiming};
    use crate::math::Mat4;
    use anyhow::{bail, Result};
    use std::path::Path;

    enum Never {}

    /// Stub for the device-resident target buffers (never constructed).
    pub struct PreparedTarget {
        never: Never,
    }

    impl PreparedTarget {
        pub fn points(&self) -> usize {
            match self.never {}
        }
    }

    /// Stub for the device-resident source buffers (never constructed).
    pub struct PreparedSource {
        never: Never,
    }

    impl PreparedSource {
        pub fn points(&self) -> usize {
            match self.never {}
        }
    }

    /// Stub PJRT engine (never constructed; `load` always errors).
    pub struct Engine {
        never: Never,
        /// Cumulative executions (metrics).
        pub executions: u64,
    }

    impl Engine {
        pub fn load(artifacts_dir: &Path) -> Result<Self> {
            bail!(
                "XLA/PJRT runtime not compiled in (crate built without the `xla` feature); \
                 cannot load artifacts from {}. Use the native-sim backend (bit-faithful \
                 software mirror, no artifacts needed), or vendor the xla-rs bindings and \
                 rebuild with `--features xla`",
                artifacts_dir.display()
            )
        }

        pub fn manifest(&self) -> &Manifest {
            match self.never {}
        }

        pub fn platform(&self) -> String {
            match self.never {}
        }

        #[allow(clippy::too_many_arguments)]
        pub fn execute_step(
            &mut self,
            _vi: usize,
            _src: &[f32],
            _tgt: &[f32],
            _src_mask: &[f32],
            _tgt_mask: &[f32],
            _transform: &Mat4,
            _max_dist_sq: f32,
        ) -> Result<(StepAccumulators, StepTiming)> {
            match self.never {}
        }

        pub fn prepare_target(&self, _tgt: &[f32], _tgt_mask: &[f32]) -> Result<PreparedTarget> {
            match self.never {}
        }

        pub fn prepare_source(&self, _src: &[f32], _src_mask: &[f32]) -> Result<PreparedSource> {
            match self.never {}
        }

        pub fn execute_resident(
            &mut self,
            _tgt: &PreparedTarget,
            _src: &PreparedSource,
            _transform: &Mat4,
            _max_dist_sq: f32,
        ) -> Result<(StepAccumulators, StepTiming)> {
            match self.never {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_kv(entries: &[(&str, usize, usize, usize, usize)]) -> KvConfig {
        let mut kv = KvConfig::default();
        for (name, n, m, bn, bm) in entries {
            kv.set(&format!("variant.{name}.n"), n);
            kv.set(&format!("variant.{name}.m"), m);
            kv.set(&format!("variant.{name}.block_n"), bn);
            kv.set(&format!("variant.{name}.block_m"), bm);
            kv.set(&format!("variant.{name}.file"), format!("{name}.hlo.txt"));
        }
        kv
    }

    #[test]
    fn manifest_parse_and_selection() {
        let kv = manifest_kv(&[
            ("icp_step_4096x16384", 4096, 16384, 128, 512),
            ("icp_step_256x1024", 256, 1024, 64, 256),
        ]);
        let m = Manifest::from_kv(&kv, Path::new("/tmp/a")).unwrap();
        // Sorted smallest-first.
        assert_eq!(m.variants[0].n, 256);
        // Selection takes the smallest fit.
        assert_eq!(m.select(100, 800).unwrap().n, 256);
        assert_eq!(m.select(300, 800).unwrap().n, 4096);
        assert_eq!(m.select(4096, 16384).unwrap().m, 16384);
        assert!(m.select(5000, 1).is_none());
        // File paths are joined onto the artifact dir.
        assert!(m.variants[0]
            .file
            .to_str()
            .unwrap()
            .starts_with("/tmp/a/"));
    }

    #[test]
    fn manifest_rejects_bad_blocks() {
        let kv = manifest_kv(&[("v", 100, 1000, 64, 256)]); // 100 % 64 != 0
        assert!(Manifest::from_kv(&kv, Path::new(".")).is_err());
    }

    #[test]
    fn manifest_rejects_empty() {
        let kv = KvConfig::default();
        assert!(Manifest::from_kv(&kv, Path::new(".")).is_err());
    }

    #[test]
    fn accumulator_wire_roundtrip() {
        let mut wire = vec![0f32; 17];
        wire[0] = 42.0;
        wire[1] = 1.0;
        wire[4] = 2.0;
        wire[7] = 3.0; // pq[0][0]
        wire[11] = 5.0; // pq[1][1]
        wire[16] = 168.0;
        let acc = StepAccumulators::from_wire(&wire).unwrap();
        assert_eq!(acc.count, 42.0);
        assert_eq!(acc.sum_p.x, 1.0);
        assert_eq!(acc.sum_q.x, 2.0);
        assert_eq!(acc.sum_pq.m[0][0], 3.0);
        assert_eq!(acc.sum_pq.m[1][1], 5.0);
        assert_eq!(acc.sum_sq_dist, 168.0);
        assert!((acc.rmse() - 2.0).abs() < 1e-12);
        assert!(StepAccumulators::from_wire(&wire[..16]).is_err());
    }

    #[test]
    fn rmse_nan_when_no_correspondences() {
        let acc = StepAccumulators::default();
        assert!(acc.rmse().is_nan());
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_engine_load_is_a_contextful_error() {
        let err = Engine::load(Path::new("artifacts")).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("xla"), "{msg}");
        assert!(msg.contains("native-sim"), "{msg}");
    }
}
