//! # FPPS — An FPGA-Based Point Cloud Processing System
//!
//! Reproduction of "FPPS: An FPGA-Based Point Cloud Processing System"
//! (Zhou, Du, Fan, Zhang — HKUST, 2026) as a three-layer rust + JAX +
//! Pallas stack:
//!
//! * **Layer 3 (this crate)** — the host-side coordinator: the PCL-like
//!   API of Table I ([`fpps_api`]), the ICP outer loop with SVD-based
//!   transform estimation ([`icp`], [`math`]), the frame-stream
//!   coordinator ([`coordinator`]), and the PJRT runtime that loads the
//!   AOT-compiled kernel ([`runtime`]).
//! * **Layer 2 (python/compile/model.py)** — the per-iteration ICP step
//!   (transform → NN search → correspondence accumulation) as a JAX
//!   graph, lowered once to HLO text in `artifacts/`.
//! * **Layer 1 (python/compile/kernels/nn_search.py)** — the paper's NN
//!   searcher (Fig. 3) as a Pallas kernel: a blockwise systolic
//!   distance-compute + running-argmin pipeline.
//!
//! The FPGA itself is modelled by two substrates: [`hwmodel`] (Alveo U50
//! resource / latency / power model regenerating Tables II and IV and the
//! §IV.D power-efficiency claim) and [`pipesim`] (a cycle-level simulator
//! of the Fig. 3 four-stage streaming NN pipeline).

pub mod alloc_counter;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod dataset;
pub mod fault;
pub mod fpps_api;
pub mod hwmodel;
pub mod icp;
pub mod kdtree;
pub mod math;
pub mod metrics;
pub mod nn;
pub mod pipesim;
pub mod pointcloud;
pub mod pool;
pub mod prop;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod sync;
pub mod voxelgrid;
pub mod bench_support;
