//! Frame pipeline: acquisition/preprocessing, capacity fitting,
//! residency-aware admission control, and the single-stream scan-to-scan
//! odometry driver.
//!
//! This is the data-preparation layer every scenario shares: frames are
//! sampled/padded ([`preprocess`], [`fit_to_capacity`]), oversized maps
//! hit an explicit [`AdmissionPolicy`] ([`admit_map`]) instead of a
//! silent shrink, and [`run_odometry`] implements the paper's two-stage
//! host pipeline (acquire frame i+1 while frame i aligns).

use crate::dataset::Sequence;
use crate::fpps_api::{FppsIcp, KernelBackend};
use crate::math::Mat4;
use crate::metrics::TimingStats;
use crate::pointcloud::PointCloud;
use crate::rng::Pcg32;
use anyhow::{bail, Result};
use std::sync::mpsc::{sync_channel, SyncSender};
use std::time::Instant;

/// Preprocessed frame ready for alignment.
pub struct PreparedFrame {
    pub index: usize,
    /// Sampled source cloud (the paper's 4096-point sample).
    pub source_sample: PointCloud,
    /// Full cloud (becomes the next frame's target).
    pub full: PointCloud,
}

/// Pipeline configuration.
///
/// The preprocessing knobs implement the standard LiDAR-odometry front
/// end (range crop, ground removal, voxel grid) that PCL-based
/// registration pipelines run before ICP. Point-to-point scan-to-scan
/// ICP on raw ring-structured scans is identity-biased (ground rings
/// self-match; see DESIGN.md §3 "dataset realism"), so the front end is
/// not optional for odometry-quality tracking — though the Table III /
/// IV benches can disable pieces of it, as they compare CPU vs device
/// under *identical* preprocessing.
#[derive(Clone, Copy, Debug)]
pub struct PipelineConfig {
    /// Per-frame source sample size (paper: 4096).
    pub source_sample: usize,
    /// Target cap; clouds larger than this are voxel-downsampled to fit
    /// the device target buffer.
    pub target_capacity: usize,
    /// Channel depth between acquisition and alignment (double
    /// buffering = 2, like the device's ping-pong BRAM buffers).
    pub queue_depth: usize,
    pub seed: u64,
    /// Range crop (m); 0 disables.
    pub crop_range: f32,
    /// Drop points below this sensor-frame z (ground removal; the
    /// sensor sits ~1.73 m up, so −1.2 keeps everything ≥ ~0.5 m above
    /// the road). `f32::NEG_INFINITY` disables.
    pub ground_z_min: f32,
    /// Voxel-grid leaf applied to both clouds (m); 0 disables.
    pub voxel_leaf: f32,
    /// Multi-start bootstrap: number of forward-translation seeds tried
    /// on the first frame (and after tracking loss). 0 = identity only.
    pub bootstrap_seeds: usize,
    /// Spacing between bootstrap seeds along +x (m).
    pub bootstrap_step: f32,
    /// How maps whose footprint exceeds one residency slot
    /// (`target_capacity` points) are admitted (see [`admit_map`]).
    pub admission: AdmissionPolicy,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self {
            source_sample: 4096,
            target_capacity: 16_384,
            queue_depth: 2,
            seed: 7,
            crop_range: 40.0,
            ground_z_min: -1.2,
            voxel_leaf: 0.15,
            bootstrap_seeds: 9,
            bootstrap_step: 0.3,
            admission: AdmissionPolicy::DownsampleToFit,
        }
    }
}

impl PipelineConfig {
    /// Paper-parity preprocessing: no front end at all (raw clouds),
    /// as in the paper's "4096 points randomly sampled from the source".
    pub fn raw() -> Self {
        Self {
            crop_range: 0.0,
            ground_z_min: f32::NEG_INFINITY,
            voxel_leaf: 0.0,
            bootstrap_seeds: 0,
            ..Default::default()
        }
    }
}

/// Front-end preprocessing shared by source and target.
pub fn preprocess(cloud: &PointCloud, cfg: &PipelineConfig) -> PointCloud {
    let mut out = PointCloud::with_capacity(cloud.len());
    let r2max = if cfg.crop_range > 0.0 {
        cfg.crop_range * cfg.crop_range
    } else {
        f32::INFINITY
    };
    for p in cloud.iter() {
        let r2 = p[0] * p[0] + p[1] * p[1];
        if r2 <= r2max && p[2] >= cfg.ground_z_min {
            out.push(p);
        }
    }
    if cfg.voxel_leaf > 0.0 {
        out = out.voxel_downsample(cfg.voxel_leaf);
    }
    out
}

/// Per-frame odometry record.
#[derive(Clone, Debug)]
pub struct FrameRecord {
    pub index: usize,
    /// Scan-to-scan transform estimated by ICP.
    pub relative: Mat4,
    /// Accumulated pose (world ← sensor_i).
    pub pose: Mat4,
    pub rmse: f64,
    pub iterations: u32,
    pub stop: StopReason,
    /// Wall time of the alignment (acquisition excluded — it overlaps).
    pub align_ms: f64,
}

/// Odometry run output.
#[derive(Debug)]
pub struct OdometryResult {
    pub records: Vec<FrameRecord>,
    pub poses: Vec<Mat4>,
    pub align_stats: TimingStats,
    /// Time the alignment thread spent blocked waiting for frames — a
    /// measure of how well acquisition hides behind alignment.
    pub starvation_ms: f64,
}

impl OdometryResult {
    /// Mean registration RMSE across frames (Table III row).
    pub fn mean_rmse(&self) -> f64 {
        let vals: Vec<f64> = self
            .records
            .iter()
            .filter(|r| r.rmse.is_finite())
            .map(|r| r.rmse)
            .collect();
        if vals.is_empty() {
            f64::NAN
        } else {
            vals.iter().sum::<f64>() / vals.len() as f64
        }
    }
}

/// Fit a cloud into the device target buffer: voxel-downsample with a
/// growing leaf until it fits (PCL pipelines do exactly this to bound
/// map density). `seed` drives the random-sample fallback, so different
/// pipeline seeds produce different fallback samples (a fixed internal
/// seed would silently make them identical).
pub fn fit_to_capacity(cloud: PointCloud, capacity: usize, seed: u64) -> PointCloud {
    if cloud.len() <= capacity {
        return cloud;
    }
    let mut leaf = 0.1f32;
    for _ in 0..12 {
        let down = cloud.voxel_downsample(leaf);
        if down.len() <= capacity {
            return down;
        }
        leaf *= 1.6;
    }
    // Fall back to random sampling at the last resort (substream keeps
    // it independent of the per-frame source-sampling streams).
    let mut rng = Pcg32::substream(seed, 0xF17);
    cloud.random_sample(capacity, &mut rng)
}

// ---------------------------------------------------------------------------
// Residency-aware admission
// ---------------------------------------------------------------------------

/// What to do with a candidate resident map whose footprint exceeds one
/// residency slot (`target_capacity` points). Parsed from the
/// `admission=` config key and `--admission` CLI option.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Fail the run with a structured [`AdmissionError`] carrying the
    /// `hwmodel` footprint — for serving setups where a silently
    /// degraded map is worse than a loud rejection.
    Reject,
    /// Voxel-downsample (growing leaf, random-sample fallback) until the
    /// map fits the slot, and record the decision — the pre-admission
    /// behavior, made explicit and visible.
    #[default]
    DownsampleToFit,
}

impl std::str::FromStr for AdmissionPolicy {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "reject" => AdmissionPolicy::Reject,
            "downsample" | "downsample-to-fit" => AdmissionPolicy::DownsampleToFit,
            other => bail!("unknown admission policy {other:?} (expected reject | downsample)"),
        })
    }
}

impl std::fmt::Display for AdmissionPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            AdmissionPolicy::Reject => "reject",
            AdmissionPolicy::DownsampleToFit => "downsample-to-fit",
        })
    }
}

/// Structured rejection of a map that does not fit one residency slot —
/// returned (through `anyhow`, downcastable) by [`admit_map`] under
/// [`AdmissionPolicy::Reject`].
#[derive(Clone, Copy, Debug)]
pub struct AdmissionError {
    /// Raw point count of the offending map.
    pub points: usize,
    /// Points after padding to the kernel target block.
    pub padded_points: usize,
    /// HBM bytes the padded map would occupy.
    pub footprint_bytes: u64,
    /// Point capacity of one residency slot (`target_capacity`).
    pub slot_capacity: usize,
    /// HBM bytes one slot provides at that capacity.
    pub slot_bytes: u64,
}

impl std::fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "map of {} points (padded {} = {} B HBM) exceeds the {}-point residency slot \
             ({} B); rerun with `--admission downsample` or raise target_capacity",
            self.points,
            self.padded_points,
            self.footprint_bytes,
            self.slot_capacity,
            self.slot_bytes
        )
    }
}

impl std::error::Error for AdmissionError {}

/// What admission decided for one candidate map (recorded on the
/// localization workloads so the decision is reportable, never silent).
#[derive(Clone, Copy, Debug)]
pub struct AdmissionDecision {
    pub policy: AdmissionPolicy,
    /// Point count before admission.
    pub original_points: usize,
    /// Point count actually admitted to the slot.
    pub admitted_points: usize,
    /// `hwmodel` footprint of the *original* cloud — what was asked of
    /// the slot.
    pub footprint: crate::hwmodel::TargetFootprint,
    /// Point capacity of one residency slot at admission time.
    pub slot_capacity: usize,
}

impl AdmissionDecision {
    /// Did admission have to shrink the map to fit?
    pub fn downsampled(&self) -> bool {
        self.admitted_points < self.original_points
    }
}

/// Residency-aware admission for one candidate resident map: estimate
/// its padded HBM footprint via
/// [`crate::hwmodel::AcceleratorConfig::target_footprint`], admit it
/// unchanged when it fits a `cfg.target_capacity`-point slot, and
/// otherwise apply `cfg.admission` — a structured rejection or an
/// explicit downsample-to-fit — instead of the old silent shrink.
pub fn admit_map(
    cloud: PointCloud,
    cfg: &PipelineConfig,
) -> Result<(PointCloud, AdmissionDecision)> {
    let hw = crate::hwmodel::AcceleratorConfig::default();
    let block_m = crate::nn::KernelConfig::default().block_m;
    let footprint = hw.target_footprint(cloud.len(), block_m);
    let original_points = cloud.len();
    let slot_capacity = cfg.target_capacity;
    if footprint.fits_slot(slot_capacity) {
        return Ok((
            cloud,
            AdmissionDecision {
                policy: cfg.admission,
                original_points,
                admitted_points: original_points,
                footprint,
                slot_capacity,
            },
        ));
    }
    match cfg.admission {
        AdmissionPolicy::Reject => Err(AdmissionError {
            points: original_points,
            padded_points: footprint.padded_points,
            footprint_bytes: footprint.bytes,
            slot_capacity,
            slot_bytes: crate::hwmodel::AcceleratorConfig::resident_target_bytes(slot_capacity),
        }
        .into()),
        AdmissionPolicy::DownsampleToFit => {
            let fitted = fit_to_capacity(cloud, slot_capacity, cfg.seed);
            let admitted_points = fitted.len();
            Ok((
                fitted,
                AdmissionDecision {
                    policy: cfg.admission,
                    original_points,
                    admitted_points,
                    footprint,
                    slot_capacity,
                },
            ))
        }
    }
}

/// Acquisition stage: generates/loads frames, samples the source, and
/// pushes prepared frames downstream. Runs on its own thread.
fn acquisition_thread(
    seq: &Sequence,
    frames: usize,
    cfg: PipelineConfig,
    tx: SyncSender<Result<PreparedFrame>>,
) {
    for i in 0..frames {
        let item = (|| -> Result<PreparedFrame> {
            let cloud = preprocess(&seq.frame(i)?, &cfg);
            let mut rng = Pcg32::substream(cfg.seed, i as u64);
            let source_sample = cloud.random_sample(cfg.source_sample, &mut rng);
            let full = fit_to_capacity(cloud, cfg.target_capacity, cfg.seed);
            Ok(PreparedFrame {
                index: i,
                source_sample,
                full,
            })
        })();
        // Receiver hung up → stop early.
        if tx.send(item).is_err() {
            return;
        }
    }
}

/// Run scan-to-scan odometry over the first `frames` frames of `seq`
/// using the FPPS API with the given backend.
///
/// Frame 0 initialises the map; each subsequent frame aligns its sample
/// against the previous frame's full cloud, seeding ICP with the
/// previous relative motion (constant-velocity prior — standard LiDAR
/// odometry practice that also matches the paper's per-frame "initial
/// transformation matrix" API).
pub fn run_odometry<B: KernelBackend>(
    seq: &Sequence,
    frames: usize,
    cfg: PipelineConfig,
    icp: &mut FppsIcp<B>,
) -> Result<OdometryResult> {
    let frames = frames.min(seq.len());
    let (tx, rx): (_, Receiver<Result<PreparedFrame>>) = sync_channel(cfg.queue_depth);

    std::thread::scope(|scope| {
        scope.spawn(|| acquisition_thread(seq, frames, cfg, tx));

        let mut records = Vec::new();
        let mut poses = vec![Mat4::IDENTITY];
        let mut align_stats = TimingStats::new();
        let mut starvation_ms = 0.0;
        let mut prev_full: Option<PointCloud> = None;
        let mut prev_relative = Mat4::IDENTITY;

        loop {
            let wait0 = std::time::Instant::now();
            let msg = match rx.recv() {
                Ok(m) => m,
                Err(_) => break, // acquisition finished
            };
            starvation_ms += wait0.elapsed().as_secs_f64() * 1e3;
            let frame = msg.context("frame acquisition")?;

            match prev_full.take() {
                None => {
                    // First frame: nothing to align against.
                    prev_full = Some(frame.full);
                }
                Some(target) => {
                    let t0 = std::time::Instant::now();
                    let bootstrap = records.is_empty()
                        || !matches!(
                            records.last().map(|r: &FrameRecord| r.stop),
                            Some(StopReason::Converged) | Some(StopReason::MaxIterations)
                        );
                    let res = if bootstrap && cfg.bootstrap_seeds > 0 {
                        // Multi-start global initialisation: the vehicle
                        // moves dominantly forward, so seed a fan of +x
                        // translations and keep the lowest-RMSE result.
                        let mut best: Option<crate::fpps_api::FppsResult> = None;
                        for k in 0..=cfg.bootstrap_seeds {
                            let seed_t = Mat4::from_rt(
                                crate::math::Mat3::IDENTITY,
                                crate::math::Vec3::new(
                                    (k as f64) * cfg.bootstrap_step as f64,
                                    0.0,
                                    0.0,
                                ),
                            );
                            icp.set_input_source(frame.source_sample.clone());
                            icp.set_input_target(target.clone());
                            icp.set_transformation_matrix(seed_t);
                            let r = icp.align()?;
                            let better = match &best {
                                None => true,
                                Some(b) => {
                                    r.has_converged()
                                        && (!b.has_converged() || r.rmse < b.rmse)
                                }
                            };
                            if better {
                                best = Some(r);
                            }
                        }
                        best.expect("at least one bootstrap attempt")
                    } else {
                        icp.set_input_source(frame.source_sample);
                        icp.set_input_target(target);
                        icp.set_transformation_matrix(prev_relative);
                        icp.align()?
                    };
                    let align_ms = t0.elapsed().as_secs_f64() * 1e3;
                    align_stats.record_ms(align_ms);

                    // T maps source (frame i) into target (frame i−1)
                    // coordinates — i.e. the relative motion.
                    let relative = res.transformation;
                    let pose = poses.last().unwrap().mul_mat(&relative);
                    poses.push(pose);
                    records.push(FrameRecord {
                        index: frame.index,
                        relative,
                        pose,
                        rmse: res.rmse,
                        iterations: res.iterations,
                        stop: res.stop,
                        align_ms,
                    });
                    prev_relative = if res.has_converged() {
                        relative
                    } else {
                        Mat4::IDENTITY
                    };
                    prev_full = Some(frame.full);
                }
            }
        }

        Ok(OdometryResult {
            records,
            poses,
            align_stats,
            starvation_ms,
        })
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{lidar::LidarConfig, sequence_specs, Sequence};
    use crate::metrics::absolute_trajectory_error;

    fn tiny_sequence(frames: usize) -> Sequence {
        let spec = sequence_specs()[3].clone(); // residential: gentle
        Sequence::synthetic(spec, frames, 11, LidarConfig::tiny())
    }

    #[test]
    fn fit_to_capacity_shrinks() {
        let mut rng = Pcg32::new(1);
        let mut c = PointCloud::with_capacity(5000);
        for _ in 0..5000 {
            c.push([rng.range(-40.0, 40.0), rng.range(-40.0, 40.0), rng.range(0.0, 5.0)]);
        }
        let f = fit_to_capacity(c.clone(), 1000, 7);
        assert!(f.len() <= 1000);
        assert!(f.len() > 100, "over-shrunk to {}", f.len());
        // Under capacity → untouched.
        assert_eq!(fit_to_capacity(c.clone(), 10_000, 7).len(), c.len());
    }

    #[test]
    fn fit_to_capacity_fallback_respects_seed() {
        // Force the random-sample fallback with a cloud too spread out
        // for 12 voxel passes to tame, and check the pipeline seed
        // actually reaches it (a fixed internal seed made all fallback
        // samples identical regardless of cfg.seed).
        let mut rng = Pcg32::new(2);
        let mut c = PointCloud::with_capacity(4000);
        for _ in 0..4000 {
            c.push([
                rng.range(-4.0e6, 4.0e6),
                rng.range(-4.0e6, 4.0e6),
                rng.range(-4.0e6, 4.0e6),
            ]);
        }
        let a = fit_to_capacity(c.clone(), 100, 1);
        let b = fit_to_capacity(c.clone(), 100, 1);
        let d = fit_to_capacity(c.clone(), 100, 2);
        assert_eq!(a.len(), 100);
        assert_eq!(a.xyz, b.xyz, "same seed must reproduce the sample");
        assert_ne!(a.xyz, d.xyz, "different seeds must differ");
    }

    #[test]
    fn odometry_runs_and_tracks() {
        let frames = 6;
        let seq = tiny_sequence(frames);
        let mut icp = FppsIcp::native_sim();
        icp.set_max_iteration_count(30);
        let cfg = PipelineConfig {
            source_sample: 1024,
            target_capacity: 8192,
            ..Default::default()
        };
        let res = run_odometry(&seq, frames, cfg, &mut icp).unwrap();
        assert_eq!(res.records.len(), frames - 1);
        assert_eq!(res.poses.len(), frames);
        // Ground truth relative to frame 0.
        let gt0 = seq.ground_truth[0];
        let gt_rel: Vec<Mat4> = seq
            .ground_truth
            .iter()
            .take(frames)
            .map(|p| gt0.inverse_rigid().mul_mat(p))
            .collect();
        let ate = absolute_trajectory_error(&res.poses, &gt_rel);
        assert!(ate < 0.6, "trajectory error too large: {ate}");
        assert!(res.align_stats.count() == frames - 1);
    }

    #[test]
    fn records_capture_convergence_info() {
        let frames = 4;
        let seq = tiny_sequence(frames);
        let mut icp = FppsIcp::native_sim();
        let res = run_odometry(&seq, frames, PipelineConfig {
            source_sample: 512,
            target_capacity: 4096,
            ..Default::default()
        }, &mut icp)
        .unwrap();
        for r in &res.records {
            assert!(r.iterations >= 1);
            assert!(r.align_ms > 0.0);
            assert!(r.rmse.is_finite());
        }
    }

    #[test]
    fn zero_and_one_frame_edge_cases() {
        let seq = tiny_sequence(2);
        let mut icp = FppsIcp::native_sim();
        let res = run_odometry(&seq, 1, PipelineConfig::default(), &mut icp).unwrap();
        assert!(res.records.is_empty());
        assert_eq!(res.poses.len(), 1);
    }

    #[test]
    fn admission_policy_parses_and_displays() {
        assert_eq!("reject".parse::<AdmissionPolicy>().unwrap(), AdmissionPolicy::Reject);
        assert_eq!(
            "downsample".parse::<AdmissionPolicy>().unwrap(),
            AdmissionPolicy::DownsampleToFit
        );
        assert_eq!(AdmissionPolicy::default(), AdmissionPolicy::DownsampleToFit);
        assert!("silent".parse::<AdmissionPolicy>().is_err());
        assert_eq!(AdmissionPolicy::Reject.to_string(), "reject");
        assert_eq!(
            AdmissionPolicy::DownsampleToFit.to_string(),
            "downsample-to-fit"
        );
    }
}
