//! Exactly-once claim arbitration between a worker and its watchdog.
//!
//! A lane publishes its in-flight job into a [`ClaimSlot`]; the deadline
//! watchdog may *claim* the job when its deadline passes. Whoever flips
//! the claimed flag first — always under the slot's mutex — owns the
//! job's outcome and feedback, so every job resolves exactly once no
//! matter how the lane and the watchdog race.
//!
//! The slot is generic and synchronizes through [`crate::sync`], so the
//! publish/claim/finish protocol is model-checked under `--cfg loom`
//! (see `tests/loom_models.rs`) with the same code that runs in
//! production inside `coordinator::supervise`.

use crate::sync::Mutex;

/// A published job plus the exactly-once arbitration flag.
struct Claimed<T> {
    job: T,
    claimed: bool,
}

/// Mutex-guarded slot holding at most one published job and the
/// claimed flag arbitrating its ownership (see the module docs).
pub struct ClaimSlot<T> {
    slot: Mutex<Option<Claimed<T>>>,
}

impl<T: Clone> Default for ClaimSlot<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: Clone> ClaimSlot<T> {
    /// An empty slot.
    pub fn new() -> Self {
        Self {
            slot: Mutex::new(None),
        }
    }

    /// Publish `job` as the in-flight work item. Returns `false` —
    /// without installing — when the previously published job is still
    /// claimed (the watchdog owns it; the caller must back off and
    /// recover). On success, `on_install` runs under the slot lock
    /// *before* the job becomes visible, so per-attempt state (e.g.
    /// resetting a cancellation token) cannot race a claim of the
    /// freshly published job.
    pub fn publish_with(&self, job: T, on_install: impl FnOnce()) -> bool {
        let mut g = self.slot.lock().unwrap();
        if g.as_ref().is_some_and(|a| a.claimed) {
            return false;
        }
        on_install();
        *g = Some(Claimed {
            job,
            claimed: false,
        });
        true
    }

    /// Watchdog side: claim the published job if `expired` says so.
    /// Returns a clone of the job exactly once — a second call (or a
    /// racing one) sees the claimed flag and returns `None`.
    pub fn try_claim(&self, expired: impl FnOnce(&T) -> bool) -> Option<T> {
        let mut g = self.slot.lock().unwrap();
        let a = g.as_mut()?;
        if a.claimed || !expired(&a.job) {
            return None;
        }
        a.claimed = true;
        Some(a.job.clone())
    }

    /// Worker side, after the attempt finished: resolve the claim race.
    /// Returns `true` when the watchdog claimed the job meanwhile — the
    /// slot is left occupied for the recovery path ([`ClaimSlot::clear`])
    /// and the caller must *not* emit an outcome. Returns `false` (and
    /// empties the slot) when the worker owns the resolution.
    pub fn finish(&self) -> bool {
        let mut g = self.slot.lock().unwrap();
        let claimed = g.as_ref().is_some_and(|a| a.claimed);
        if !claimed {
            *g = None;
        }
        claimed
    }

    /// Recovery: drop whatever is published (claimed or not).
    pub fn clear(&self) {
        let mut g = self.slot.lock().unwrap();
        *g = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn worker_owns_unclaimed_jobs() {
        let s = ClaimSlot::new();
        assert!(s.publish_with(7u32, || {}));
        assert_eq!(s.try_claim(|_| false), None, "not expired -> no claim");
        assert!(!s.finish(), "unclaimed job resolves on the worker side");
        assert_eq!(s.try_claim(|_| true), None, "slot already empty");
    }

    #[test]
    fn watchdog_claims_exactly_once() {
        let s = ClaimSlot::new();
        assert!(s.publish_with(7u32, || {}));
        assert_eq!(s.try_claim(|j| *j == 7), Some(7));
        assert_eq!(s.try_claim(|_| true), None, "second claim refused");
        assert!(s.finish(), "worker must defer to the watchdog");
        s.clear();
        assert!(s.publish_with(8u32, || {}), "cleared slot accepts again");
        assert!(!s.finish());
    }

    #[test]
    fn publish_refused_while_claimed() {
        let s = ClaimSlot::new();
        let mut installs = 0;
        assert!(s.publish_with(1u32, || installs += 1));
        assert_eq!(s.try_claim(|_| true), Some(1));
        assert!(
            !s.publish_with(2u32, || installs += 1),
            "claimed job blocks the next publish"
        );
        assert_eq!(installs, 1, "refused publish must not run on_install");
        s.clear();
        assert!(s.publish_with(2u32, || installs += 1));
        assert_eq!(installs, 2);
    }
}
