//! Job and report types of the multi-lane registration engine: the
//! [`RegistrationJob`] descriptor (with its [`SloClass`] serving class),
//! the per-job [`RegistrationOutcome`], per-lane [`LaneStats`], and the
//! aggregate [`LaneReport`].

use crate::icp::StopReason;
use crate::math::Mat4;
use crate::metrics::TimingStats;
use crate::pointcloud::PointCloud;
use std::sync::Arc;
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// Multi-lane batched registration engine
// ---------------------------------------------------------------------------

/// Service-level objective class a job is submitted under. Carried on
/// every [`RegistrationJob`] and interpreted by the serving tier
/// ([`super::serving`]): it decides what happens when the pool is
/// saturated or a deadline cannot be met — batch entry points ignore it.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum SloClass {
    /// Must complete by its deadline or not run at all: admission sheds
    /// the job (structured [`StopReason::Shed`] outcome, never queued)
    /// when the stream or pool is full, or when the estimated queue wait
    /// already exceeds the deadline budget.
    LatencyCritical,
    /// Default class: parked under backpressure (the caller retries),
    /// served with the pool-wide deadline policy.
    #[default]
    Standard,
    /// Throughput filler: parked under backpressure and only served with
    /// whatever capacity the other classes leave over (no deadline
    /// unless the job carries one).
    BestEffort,
}

impl SloClass {
    /// Kebab-case name, round-tripping with [`std::str::FromStr`]
    /// (`latency-critical | standard | best-effort`) — the `--slo` CLI
    /// flag and `slo=` run-config key both speak this spelling.
    pub fn name(self) -> &'static str {
        match self {
            SloClass::LatencyCritical => "latency-critical",
            SloClass::Standard => "standard",
            SloClass::BestEffort => "best-effort",
        }
    }

    /// All classes, in shedding-priority order (most latency-sensitive
    /// first) — handy for per-class report tables.
    pub fn all() -> [SloClass; 3] {
        [
            SloClass::LatencyCritical,
            SloClass::Standard,
            SloClass::BestEffort,
        ]
    }
}

impl std::fmt::Display for SloClass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for SloClass {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "latency-critical" => Ok(SloClass::LatencyCritical),
            "standard" => Ok(SloClass::Standard),
            "best-effort" => Ok(SloClass::BestEffort),
            other => Err(anyhow::anyhow!(
                "unknown SLO class {other:?} (expected latency-critical | standard | best-effort)"
            )),
        }
    }
}

/// One independent frame-pair registration request.
pub struct RegistrationJob {
    /// Caller-assigned id; results are returned sorted by it, so ids
    /// define the deterministic output order regardless of lane count.
    pub id: u64,
    /// Client/stream the job belongs to (multi-client bookkeeping).
    pub stream: usize,
    /// Target identity for affinity scheduling: jobs with equal keys are
    /// routed to the lane whose backend already holds that target, so
    /// the resident-target cache hits across jobs. [`Self::new`] derives
    /// it from the target's content fingerprint; [`Self::new_keyed`]
    /// takes it from the caller (e.g. one shared map, hashed once).
    pub target_key: u64,
    /// Shared (like `target`) so the retry path re-stages the same
    /// points by `Arc` clone — a retry never deep-copies the cloud.
    pub source: Arc<PointCloud>,
    /// Shared so map-reuse workloads submit M jobs against one cloud
    /// without M copies.
    pub target: Arc<PointCloud>,
    /// Initial transform (`setTransformationMatrix`).
    pub initial: Mat4,
    /// Per-job deadline override, measured from submission; `None`
    /// falls back to the pool-wide [`SupervisorConfig::deadline`](super::SupervisorConfig::deadline). A
    /// job past its deadline — queued, between retries, or mid-flight
    /// (cut off cooperatively between ICP iterations, or by the
    /// watchdog when the lane is wedged) — is contained as a
    /// [`StopReason::DeadlineExceeded`] outcome.
    pub deadline: Option<Duration>,
    /// Per-job retry-budget override for transient failures (errors,
    /// panics); `None` falls back to [`SupervisorConfig::max_retries`](super::SupervisorConfig::max_retries).
    pub max_retries: Option<u32>,
    /// Serving class (ignored by the batch entry points; see
    /// [`SloClass`]).
    pub slo: SloClass,
    pub(crate) submitted: Instant,
}

impl RegistrationJob {
    /// A standard-class job with no deadline and the pool-default retry
    /// budget; the target key is fingerprinted from `target`. Tune with
    /// the builder-style setters below.
    pub fn new(
        id: u64,
        stream: usize,
        source: impl Into<Arc<PointCloud>>,
        target: impl Into<Arc<PointCloud>>,
        initial: Mat4,
    ) -> Self {
        let target = target.into();
        Self {
            id,
            stream,
            target_key: target.fingerprint(),
            source: source.into(),
            target,
            initial,
            deadline: None,
            max_retries: None,
            slo: SloClass::Standard,
            submitted: Instant::now(),
        }
    }

    /// Like [`Self::new`] with a caller-supplied affinity key — skips
    /// hashing the target, for callers that build many jobs against one
    /// shared cloud (see [`localization_jobs`](super::localization_jobs)).
    pub fn new_keyed(
        id: u64,
        stream: usize,
        source: impl Into<Arc<PointCloud>>,
        target: impl Into<Arc<PointCloud>>,
        target_key: u64,
        initial: Mat4,
    ) -> Self {
        Self {
            id,
            stream,
            target_key,
            source: source.into(),
            target: target.into(),
            initial,
            deadline: None,
            max_retries: None,
            slo: SloClass::Standard,
            submitted: Instant::now(),
        }
    }

    /// Builder: per-job deadline (see the `deadline` field).
    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Builder: per-job retry budget (see the `max_retries` field).
    pub fn with_max_retries(mut self, max_retries: u32) -> Self {
        self.max_retries = Some(max_retries);
        self
    }

    /// Builder: serving class (see [`SloClass`]).
    pub fn with_slo(mut self, slo: SloClass) -> Self {
        self.slo = slo;
        self
    }

    /// Reset the submission timestamp — call immediately before sending
    /// a job that was built ahead of time, so the reported queue wait
    /// measures time *queued*, not time since construction.
    pub fn mark_submitted(&mut self) {
        self.submitted = Instant::now();
    }
}

/// Result of one lane-pool job.
#[derive(Clone, Debug)]
pub struct RegistrationOutcome {
    pub id: u64,
    pub stream: usize,
    /// Which lane served the job (scheduling detail — the transform must
    /// not depend on it; see the `lane_engine` determinism test).
    pub lane: usize,
    pub transform: Mat4,
    pub rmse: f64,
    pub iterations: u32,
    pub stop: StopReason,
    /// Time from submission to a lane picking the job up.
    pub queue_wait_ms: f64,
    /// Time inside `align()` on the lane.
    pub service_ms: f64,
    /// `Some(message)` when the alignment itself errored (or its
    /// deadline expired). A failed job is *contained*: its lane keeps
    /// draining, the outcome carries the job's initial transform and
    /// NaN rmse, and the rest of the batch is unaffected.
    pub error: Option<String>,
    /// Align attempts the job consumed (1 = served first try; larger
    /// values mean transient failures were retried).
    pub attempts: u32,
}

impl RegistrationOutcome {
    /// Did the alignment error (as opposed to merely not converging)?
    pub fn is_failed(&self) -> bool {
        self.error.is_some()
    }
}

/// ICP parameters shared by every lane (per-job overrides travel in the
/// job's `initial` transform only, to keep lane-count invariance).
#[derive(Clone, Copy, Debug)]
pub struct LaneIcpConfig {
    pub max_correspondence_distance: f32,
    pub max_iteration_count: u32,
    pub transformation_epsilon: f64,
    /// Per-class retention of each lane engine's staging-buffer arena
    /// (see [`crate::pool::BufferPool`]); the CLI exposes it as
    /// `--pool-capacity`, run configs as `pool_capacity=`.
    pub pool_capacity: usize,
}

impl Default for LaneIcpConfig {
    fn default() -> Self {
        Self {
            max_correspondence_distance: 1.0,
            max_iteration_count: 50,
            transformation_epsilon: 1e-5,
            pool_capacity: crate::pool::DEFAULT_RETAIN,
        }
    }
}

/// Per-lane execution statistics.
#[derive(Clone, Debug, Default)]
pub struct LaneStats {
    pub lane: usize,
    pub jobs: usize,
    /// Jobs whose alignment errored (contained per-job, see
    /// [`RegistrationOutcome::error`]); included in `jobs`.
    pub failed: usize,
    /// Targets still resident on this lane's backend at the end of the
    /// run (≤ its residency slot count).
    pub resident_targets: usize,
    /// Service latency samples of this lane.
    pub service: TimingStats,
    /// Queue-wait samples of the jobs this lane served (scheduler
    /// pressure as seen from this lane).
    pub queue_wait: TimingStats,
    /// Cumulative backend ("device") time of this lane.
    pub device_ms: f64,
    /// Target uploads this lane's backend actually performed.
    pub target_uploads: usize,
    /// Alignments that found their target already resident (affinity
    /// scheduling + unchanged target = cache hit).
    pub target_hits: usize,
    /// Resident targets this lane's backend LRU-evicted — with pool-wide
    /// residency coordination this stays 0 while any lane has free
    /// slots.
    pub target_evictions: usize,
    /// Transient-failure retries this lane performed (extra align
    /// attempts beyond each job's first).
    pub retries: usize,
    /// Times this lane's backend was respawned from the factory after a
    /// panic.
    pub restarts: usize,
    /// Jobs on this lane contained as [`StopReason::DeadlineExceeded`]
    /// (cooperatively, pre-service, or cut off by the watchdog);
    /// included in `failed`.
    pub deadline_missed: usize,
    /// Failover tier the lane's backend ended the run on (0 = primary;
    /// higher tiers were engaged after repeated restarts, see
    /// [`SupervisorConfig::restarts_per_tier`](super::SupervisorConfig::restarts_per_tier)).
    pub backend_tier: usize,
    /// Name of the backend serving the lane at the end of the run.
    pub backend: String,
}

/// Aggregate report of one lane-pool run.
#[derive(Debug)]
pub struct LaneReport {
    /// All outcomes, sorted by job id (deterministic order).
    pub outcomes: Vec<RegistrationOutcome>,
    /// Per-lane statistics, sorted by lane index.
    pub lanes: Vec<LaneStats>,
    /// Per-lane service stats merged into one aggregate distribution.
    pub service: TimingStats,
    /// Queue-wait distribution across all jobs (backpressure signal).
    pub queue_wait: TimingStats,
    pub wall_ms: f64,
}

/// Throughput over a wall-clock window, `None` when the window is too
/// small (or non-finite) to yield a meaningful finite rate — an empty
/// or instantaneous batch has no throughput, not an infinite one.
fn rate_per_s(count: usize, wall_ms: f64) -> Option<f64> {
    if !wall_ms.is_finite() || wall_ms <= f64::EPSILON {
        return None;
    }
    let rate = count as f64 / (wall_ms / 1e3);
    rate.is_finite().then_some(rate)
}

impl LaneReport {
    /// Aggregate throughput over the whole run; 0.0 (never NaN/inf)
    /// when the wall-clock window is degenerate.
    pub fn jobs_per_s(&self) -> f64 {
        rate_per_s(self.outcomes.len(), self.wall_ms).unwrap_or(0.0)
    }

    /// Render the per-lane breakdown — shared by the `fpps batch` /
    /// `fpps localize` subcommands and the registration-server example.
    /// Queue-wait and jobs/s make scheduler pressure visible: a lane
    /// whose wait grows while its jobs/s stalls is the backpressure
    /// bottleneck.
    pub fn lane_table(&self, title: &str) -> crate::report::Table {
        let mut t = crate::report::Table::new(title).header(&[
            "lane",
            "jobs",
            "fail",
            "mean (ms)",
            "p99 (ms)",
            "wait (ms)",
            "jobs/s",
            "tgt up/hit/ev",
            "rt/rs/ddl",
            "resident",
            "device (ms)",
            "backend",
        ]);
        for l in &self.lanes {
            let jobs_per_s = match rate_per_s(l.jobs, self.wall_ms) {
                Some(rate) => format!("{rate:.2}"),
                None => "-".to_string(), // degenerate window: no rate
            };
            t.row(vec![
                l.lane.to_string(),
                l.jobs.to_string(),
                l.failed.to_string(),
                format!("{:.1}", l.service.mean_ms()),
                format!("{:.1}", l.service.percentile_ms(99.0)),
                format!("{:.1}", l.queue_wait.mean_ms()),
                jobs_per_s,
                format!(
                    "{}/{}/{}",
                    l.target_uploads, l.target_hits, l.target_evictions
                ),
                format!("{}/{}/{}", l.retries, l.restarts, l.deadline_missed),
                l.resident_targets.to_string(),
                format!("{:.1}", l.device_ms),
                format!("{} (tier {})", l.backend, l.backend_tier),
            ]);
        }
        t
    }

    /// Total contained job failures across all lanes.
    pub fn failed_jobs(&self) -> usize {
        self.lanes.iter().map(|l| l.failed).sum()
    }
}
