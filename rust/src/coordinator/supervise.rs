//! Lane supervision and dispatch: the supervised worker-lane pool
//! ([`run_supervised_lane_pool`]), its event-driven dispatcher
//! (`dispatch_supervised` over [`LaneEvent`]s), the deadline watchdog,
//! and the batch entry points ([`run_lane_pool`],
//! [`run_registration_batch`], [`run_registration_batch_supervised`])
//! preserved as thin wrappers around the supervised core.

use super::claim::ClaimSlot;
use super::jobs::{LaneIcpConfig, LaneReport, LaneStats, RegistrationJob, RegistrationOutcome};
use super::router::{AffinityRouter, JobFeedback};
use crate::fpps_api::{CancelToken, FppsIcp, KernelBackend};
use crate::icp::StopReason;
use crate::math::Mat4;
use crate::metrics::TimingStats;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Pool-wide fault-tolerance policy of [`run_supervised_lane_pool`].
/// The defaults are deliberately inert (no deadline, no retries):
/// [`run_lane_pool`] keeps its historical semantics unless a caller
/// opts into supervision.
#[derive(Clone, Copy, Debug)]
pub struct SupervisorConfig {
    /// Default per-job deadline, measured from submission; `None`
    /// disables deadline enforcement (jobs may still opt in via
    /// [`RegistrationJob::with_deadline`]).
    pub deadline: Option<Duration>,
    /// Default transient-failure retry budget per job (0 = first error
    /// is final, matching the historical contained-failure behavior).
    pub max_retries: u32,
    /// First retry backoff; doubles per attempt up to `backoff_cap`.
    pub backoff_base: Duration,
    /// Upper bound on the exponential backoff between retries.
    pub backoff_cap: Duration,
    /// Backend restarts a lane absorbs before advancing one failover
    /// tier (the factory's second argument): `tier = restarts /
    /// restarts_per_tier`, so a backend that keeps panicking walks down
    /// a [`crate::fpps_api::FailoverChain`] instead of thrashing.
    pub restarts_per_tier: u32,
    /// Deadline-watchdog poll interval.
    pub watchdog_poll: Duration,
}

impl Default for SupervisorConfig {
    fn default() -> Self {
        Self {
            deadline: None,
            max_retries: 0,
            backoff_base: Duration::from_millis(1),
            backoff_cap: Duration::from_millis(50),
            restarts_per_tier: 2,
            watchdog_poll: Duration::from_millis(2),
        }
    }
}

impl SupervisorConfig {
    /// Bounded exponential backoff before retry `attempt` (1-based).
    fn backoff(&self, attempt: u32) -> Duration {
        let factor = 1u32 << attempt.min(16);
        self.backoff_base.saturating_mul(factor).min(self.backoff_cap)
    }
}

/// Bounded per-lane job queue: a lock-free single-producer ring
/// ([`crate::pool::ring::SpscRing`]) carrying small job descriptors —
/// clouds travel by `Arc`, so enqueueing moves ~100 bytes and never
/// copies points. The dispatcher is the only pusher; the lane worker
/// and the deadline watchdog race pops on the CAS consumer side, so a
/// third party can still *drain* a wedged lane's queue exactly-once
/// without a lock (the mutex queue this replaces serialized every
/// push/pop across the pool). One semantic difference is handled at
/// the call sites: `close()` + `drain()` is no longer atomic against a
/// concurrent push, so the dispatcher — the sole producer — re-drains
/// a lane's ring when it learns the lane died (see
/// [`dispatch_supervised`]).
type LaneQueue = crate::pool::ring::SpscRing<RegistrationJob>;

/// The lane's currently-served job, published for the deadline
/// watchdog through a [`ClaimSlot`], whose claimed flag is the
/// exactly-once arbiter between the lane and the watchdog: whoever
/// flips it first (under the slot mutex) owns the job's outcome and
/// feedback.
#[derive(Clone)]
struct ActiveJob {
    id: u64,
    stream: usize,
    key: u64,
    initial: Mat4,
    queue_wait_ms: f64,
    started: Instant,
    deadline_at: Option<Instant>,
    attempt: u32,
    generation: u64,
}

/// Shared lane↔watchdog state: the active-job claim slot plus the
/// cancellation token installed into the lane's backend.
struct Heartbeat {
    active: ClaimSlot<ActiveJob>,
    cancel: CancelToken,
}

/// Supervision traffic from lanes and the watchdog to the dispatcher.
enum LaneEvent {
    /// Per-job completion feedback (the mirror-correction protocol).
    Feedback(JobFeedback),
    /// The lane's backend was respawned: un-warm it and bump its
    /// feedback generation.
    Restarted { lane: usize },
    /// The watchdog cut off a wedged lane: route around it.
    Wedged { lane: usize },
    /// A wedged lane came back: it may take new jobs again.
    Recovered { lane: usize },
    /// Jobs drained off a wedged lane's queue, to be re-routed.
    Requeue { lane: usize, jobs: Vec<RegistrationJob> },
    /// The lane failed to start and will never serve: route around it
    /// permanently (its worker error fails the pool after the drain).
    Dead { lane: usize },
}

/// Try to place `job` via the router (first choice, then spill order);
/// hands the job back when every candidate queue is full. Routing state
/// is committed only after a push lands.
fn route_job(
    router: &mut AffinityRouter,
    queues: &[Arc<LaneQueue>],
    mut job: RegistrationJob,
) -> Option<RegistrationJob> {
    let key = job.target_key;
    let mut tried = None;
    if let Some(l) = router.first_choice(key) {
        match queues[l].try_push(job) {
            Ok(()) => {
                router.committed(l, key);
                return None;
            }
            Err(j) => {
                job = j;
                tried = Some(l); // don't re-attempt the full queue
            }
        }
    }
    for l in router.spill_order(tried) {
        match queues[l].try_push(job) {
            Ok(()) => {
                router.committed(l, key);
                return None;
            }
            Err(j) => job = j,
        }
    }
    Some(job)
}

/// Route jobs from the shared intake queue to per-lane queues through
/// the pool-wide residency coordinator ([`AffinityRouter`]): warm keys
/// keep their lane while it keeps up, cold keys fill **free residency
/// slots** anywhere in the pool before any warm lane is made to evict,
/// and only when every slot is occupied does a cold key spill by load.
/// `ev_rx` carries per-job [`JobFeedback`] plus the supervision events
/// (restarts, wedges, re-queues), giving the dispatcher its load
/// estimate, the ground truth that corrects the warm-set mirror, and
/// the restart/un-warm signals — all without locking. Jobs that find
/// every queue full are parked in a deferred list (never blocking the
/// event loop) and placed as soon as feedback frees a slot; intake is
/// only pulled while the deferred list is empty, so producer
/// backpressure is preserved. The dispatcher exits — closing every lane
/// queue — once intake has disconnected and every routed job has fed
/// back. Routing can never change numerics: every job is an independent
/// alignment, so `lanes = 1` and `lanes = K` stay bit-identical
/// regardless of placement.
fn dispatch_supervised(
    rx: Receiver<RegistrationJob>,
    queues: Vec<Arc<LaneQueue>>,
    ev_rx: Receiver<LaneEvent>,
    slots_rx: Receiver<usize>,
) {
    let lanes = queues.len();
    // Mirror the *actual* backends, not an assumed default: every lane
    // reports its backend's residency slot count once it exists (a lane
    // that fails to start just drops its sender). The most conservative
    // (minimum) count drives the warm sets — over-estimating residency
    // would route jobs to lanes whose backend already evicted the key.
    let mut slots: Option<usize> = None;
    for _ in 0..lanes {
        match slots_rx.recv() {
            Ok(s) => slots = Some(slots.map_or(s, |m| m.min(s))),
            Err(_) => break,
        }
    }
    let mut router = AffinityRouter::new(lanes, slots.unwrap_or(1));
    let mut deferred: VecDeque<RegistrationJob> = VecDeque::new();
    let mut dead = vec![false; lanes];
    let mut intake_open = true;

    fn handle_event(
        router: &mut AffinityRouter,
        queues: &[Arc<LaneQueue>],
        deferred: &mut VecDeque<RegistrationJob>,
        dead: &mut [bool],
        ev: LaneEvent,
    ) {
        match ev {
            LaneEvent::Feedback(fb) => router.completed(fb),
            LaneEvent::Restarted { lane } => router.lane_restarted(lane),
            LaneEvent::Wedged { lane } => router.set_down(lane, true),
            LaneEvent::Recovered { lane } => router.set_down(lane, false),
            LaneEvent::Requeue { lane, jobs } => {
                router.requeued(lane, jobs.len());
                deferred.extend(jobs);
            }
            LaneEvent::Dead { lane } => {
                dead[lane] = true;
                router.set_down(lane, true);
                // The ring's close+drain is not atomic against a push
                // already in flight from this thread. As the sole
                // producer we re-drain authoritatively here, so a job
                // that landed after the dead lane's own drain is
                // re-routed instead of rotting in a closed queue.
                let jobs = queues[lane].drain();
                if !jobs.is_empty() {
                    router.requeued(lane, jobs.len());
                    deferred.extend(jobs);
                }
            }
        }
    }

    loop {
        while let Ok(ev) = ev_rx.try_recv() {
            handle_event(&mut router, &queues, &mut deferred, &mut dead, ev);
        }
        if dead.iter().all(|&d| d) {
            // No lane will ever serve again; stop routing so the pool
            // can unwind and report the lane errors.
            break;
        }
        // Place deferred jobs (watchdog re-queues and earlier overflow)
        // before pulling new intake.
        while let Some(job) = deferred.pop_front() {
            if let Some(job) = route_job(&mut router, &queues, job) {
                deferred.push_front(job); // still no room anywhere
                break;
            }
        }
        if intake_open && deferred.is_empty() {
            match rx.recv_timeout(Duration::from_millis(1)) {
                Ok(job) => {
                    if let Some(job) = route_job(&mut router, &queues, job) {
                        deferred.push_back(job);
                    }
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => intake_open = false,
            }
        } else if !intake_open && deferred.is_empty() && router.total_pending() == 0 {
            break; // every job routed and fed back: drain complete
        } else if let Ok(ev) = ev_rx.recv_timeout(Duration::from_millis(2)) {
            handle_event(&mut router, &queues, &mut deferred, &mut dead, ev);
        }
    }
    for q in &queues {
        q.close();
    }
}

/// Deadline watchdog: polls every lane's heartbeat and, when a job's
/// deadline has passed unclaimed, *claims* it — emitting the contained
/// [`StopReason::DeadlineExceeded`] outcome and its feedback itself (so
/// the pool's accounting completes even if the lane never returns),
/// raising the lane's [`CancelToken`] so a cooperative backend abandons
/// the wedged call, marking the lane down, and draining its queue back
/// to the dispatcher for re-routing.
#[allow(clippy::too_many_arguments)]
fn watchdog_loop(
    heartbeats: &[Arc<Heartbeat>],
    queues: &[Arc<LaneQueue>],
    out_tx: Sender<RegistrationOutcome>,
    ev_tx: Sender<LaneEvent>,
    poll: Duration,
    stop: &AtomicBool,
) {
    while !stop.load(Ordering::SeqCst) {
        for (lane, hb) in heartbeats.iter().enumerate() {
            let expired = |a: &ActiveJob| a.deadline_at.is_some_and(|d| Instant::now() >= d);
            let Some(a) = hb.active.try_claim(expired) else { continue };
            // Cut the wedged call off, then take over the job's
            // bookkeeping: one outcome, one feedback, queue re-routed.
            hb.cancel.cancel();
            out_tx
                .send(RegistrationOutcome {
                    id: a.id,
                    stream: a.stream,
                    lane,
                    transform: a.initial,
                    rmse: f64::NAN,
                    iterations: 0,
                    stop: StopReason::DeadlineExceeded,
                    queue_wait_ms: a.queue_wait_ms,
                    service_ms: a.started.elapsed().as_secs_f64() * 1e3,
                    error: Some(format!(
                        "job {} on lane {lane}: deadline exceeded (cut off by watchdog)",
                        a.id
                    )),
                    attempts: a.attempt + 1,
                })
                .ok();
            ev_tx
                .send(LaneEvent::Feedback(JobFeedback {
                    lane,
                    key: a.key,
                    uploaded: false, // conservative: un-warm, never claim
                    hit: false,
                    ok: false,
                    generation: a.generation,
                }))
                .ok();
            ev_tx.send(LaneEvent::Wedged { lane }).ok();
            let drained = queues[lane].drain();
            if !drained.is_empty() {
                ev_tx
                    .send(LaneEvent::Requeue {
                        lane,
                        jobs: drained,
                    })
                    .ok();
            }
        }
        std::thread::sleep(poll);
    }
}

/// How one align attempt on a lane resolved.
enum Attempt {
    Done(crate::fpps_api::FppsResult, bool, bool), // (result, uploaded, hit)
    Failed(String),
    Panicked(String),
}

/// Human-readable panic payload (what `panic!` carried, if a string).
fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Run a pool of `lanes` supervised worker lanes, each with its own
/// bounded queue, fed by a target-affinity dispatcher (see
/// [`dispatch_supervised`]) and overseen by a deadline watchdog (see
/// [`watchdog_loop`]).
///
/// * `make_backend(lane, tier)` is called **on** each lane thread, so
///   backends never cross threads and need not be `Send`. `tier` is the
///   failover rung: 0 on startup, advancing by one per
///   [`SupervisorConfig::restarts_per_tier`] backend restarts, so the
///   factory can hand out progressively more conservative backends
///   (e.g. along a [`crate::fpps_api::FailoverChain`]). A tier-0
///   failure at startup is a pool-level error; a factory failure during
///   a mid-run respawn is contained per job instead.
/// * `produce(tx)` runs on its own thread and feeds the intake queue —
///   it may clone the sender and fan out to per-client producer threads
///   (see `examples/registration_server.rs`). A `send` error means the
///   pool is shutting down; treat it as a stop signal, not a failure.
///
/// Fault containment on a lane, per job: transient align errors (and
/// panics, which additionally respawn the backend from the factory)
/// retry with bounded exponential backoff up to the job's retry budget;
/// a job past its deadline is contained as
/// [`StopReason::DeadlineExceeded`] — cooperatively between ICP
/// iterations when the backend is healthy, or by the watchdog when it
/// is wedged. Every submitted job yields **exactly one** outcome and
/// exactly one feedback, whoever emits them.
///
/// Each job is an independent alignment, so the mapping of jobs to lanes
/// cannot change any transform: `lanes = 1` and `lanes = K` produce
/// bit-identical outcomes for a deterministic backend.
pub fn run_supervised_lane_pool<B, F, P>(
    lanes: usize,
    queue_depth: usize,
    icp_cfg: LaneIcpConfig,
    sup: SupervisorConfig,
    make_backend: F,
    produce: P,
) -> Result<LaneReport>
where
    B: KernelBackend,
    F: Fn(usize, usize) -> Result<B> + Sync,
    P: FnOnce(SyncSender<RegistrationJob>) -> Result<()> + Send,
{
    run_supervised_lane_pool_tapped(lanes, queue_depth, icp_cfg, sup, make_backend, produce, |_| {})
}

/// [`run_supervised_lane_pool`] with a live outcome tap: `on_outcome`
/// runs on a dedicated collector thread the moment each job's outcome
/// is emitted (by a lane or the watchdog), *before* the pool has
/// drained. This is the completion-event source of the serving tier
/// ([`super::serving`]): the tap fulfills per-job completion handles
/// while the pool keeps running, which a post-drain loop over the
/// report could never do. The outcomes still end up in the returned
/// [`LaneReport`], sorted by id, exactly as without the tap.
pub fn run_supervised_lane_pool_tapped<B, F, P, O>(
    lanes: usize,
    queue_depth: usize,
    icp_cfg: LaneIcpConfig,
    sup: SupervisorConfig,
    make_backend: F,
    produce: P,
    mut on_outcome: O,
) -> Result<LaneReport>
where
    B: KernelBackend,
    F: Fn(usize, usize) -> Result<B> + Sync,
    P: FnOnce(SyncSender<RegistrationJob>) -> Result<()> + Send,
    O: FnMut(&RegistrationOutcome) + Send,
{
    let lanes = lanes.max(1);
    let depth = queue_depth.max(1);
    let (job_tx, job_rx) = sync_channel::<RegistrationJob>(depth);
    let queues: Vec<Arc<LaneQueue>> = (0..lanes).map(|_| Arc::new(LaneQueue::new(depth))).collect();
    let heartbeats: Vec<Arc<Heartbeat>> = (0..lanes)
        .map(|_| {
            Arc::new(Heartbeat {
                active: ClaimSlot::new(),
                cancel: CancelToken::new(),
            })
        })
        .collect();
    let (out_tx, out_rx) = channel::<RegistrationOutcome>();
    let (lane_tx, lane_rx) = channel::<LaneStats>();
    let (ev_tx, ev_rx) = channel::<LaneEvent>();
    let (slots_tx, slots_rx) = channel::<usize>();
    let watchdog_stop = AtomicBool::new(false);
    let t0 = Instant::now();

    let mut outcomes = std::thread::scope(|scope| -> Result<Vec<RegistrationOutcome>> {
        // Collector: drains outcomes live (feeding the tap) instead of
        // letting them pile up in the channel until the pool unwinds.
        // It exits when the last `out_tx` clone drops — the watchdog
        // holds one, so it must be joined only after the watchdog.
        let collector = scope.spawn(move || {
            let mut outcomes = Vec::new();
            for o in out_rx {
                on_outcome(&o);
                outcomes.push(o);
            }
            outcomes
        });
        let producer = scope.spawn(move || produce(job_tx));
        let disp_queues = queues.clone();
        let dispatcher =
            scope.spawn(move || dispatch_supervised(job_rx, disp_queues, ev_rx, slots_rx));
        let wd_heartbeats = heartbeats.clone();
        let wd_queues = queues.clone();
        let wd_out = out_tx.clone();
        let wd_ev = ev_tx.clone();
        let wd_stop = &watchdog_stop;
        let watchdog = scope.spawn(move || {
            watchdog_loop(
                &wd_heartbeats,
                &wd_queues,
                wd_out,
                wd_ev,
                sup.watchdog_poll,
                wd_stop,
            )
        });
        let mut workers = Vec::with_capacity(lanes);
        for lane in 0..lanes {
            let queue = Arc::clone(&queues[lane]);
            let hb = Arc::clone(&heartbeats[lane]);
            let out_tx = out_tx.clone();
            let lane_tx = lane_tx.clone();
            let ev_tx = ev_tx.clone();
            let slots_tx = slots_tx.clone();
            let make_backend = &make_backend;
            workers.push(scope.spawn(move || -> Result<()> {
                let make_icp = |tier: usize| -> Result<FppsIcp<B>> {
                    let mut backend = make_backend(lane, tier).with_context(|| {
                        format!("create backend for lane {lane} (failover tier {tier})")
                    })?;
                    backend.set_cancel_token(hb.cancel.clone());
                    let mut icp = FppsIcp::with_backend(backend);
                    icp.set_buffer_pool(crate::pool::BufferPool::new(icp_cfg.pool_capacity));
                    icp.set_max_correspondence_distance(icp_cfg.max_correspondence_distance)
                        .set_max_iteration_count(icp_cfg.max_iteration_count)
                        .set_transformation_epsilon(icp_cfg.transformation_epsilon);
                    Ok(icp)
                };
                // Tier-0 creation failure is a configuration error that
                // fails the pool, exactly as before supervision existed —
                // but the lane must still hand its queue back so the
                // dispatcher can drain and the pool can unwind.
                let mut icp: Option<FppsIcp<B>> = match make_icp(0) {
                    Ok(engine) => Some(engine),
                    Err(e) => {
                        queue.close();
                        let jobs = queue.drain();
                        ev_tx.send(LaneEvent::Dead { lane }).ok();
                        if !jobs.is_empty() {
                            ev_tx.send(LaneEvent::Requeue { lane, jobs }).ok();
                        }
                        return Err(e);
                    }
                };
                // Tell the dispatcher how much residency this lane
                // really has, so its warm-set mirror matches the device.
                let engine0 = icp.as_ref().expect("created above");
                slots_tx.send(engine0.backend().residency_slots()).ok();
                drop(slots_tx);
                let mut stats = LaneStats {
                    lane,
                    backend: engine0.backend().name().to_string(),
                    ..Default::default()
                };
                let mut generation: u64 = 0;
                // Telemetry of backends retired by restarts, folded into
                // the final stats: (device_ms, uploads, hits, evictions).
                let mut retired = (0.0f64, 0u64, 0u64, 0u64);
                let retire = |icp: &mut Option<FppsIcp<B>>, retired: &mut (f64, u64, u64, u64)| {
                    if let Some(old) = icp.take() {
                        retired.0 += old.backend().device_time().as_secs_f64() * 1e3;
                        let (u, h, _) = old.target_cache_stats();
                        retired.1 += u;
                        retired.2 += h;
                        retired.3 += old.backend().target_evictions();
                    }
                };

                // Own queue, no lock contention with other lanes: the
                // dispatcher already routed.
                while let Some(job) = queue.pop() {
                    let queue_wait_ms = job.submitted.elapsed().as_secs_f64() * 1e3;
                    let (id, stream, initial, key) =
                        (job.id, job.stream, job.initial, job.target_key);
                    let deadline_at =
                        job.deadline.or(sup.deadline).map(|d| job.submitted + d);
                    let max_retries = job.max_retries.unwrap_or(sup.max_retries);
                    let t_serve = Instant::now();
                    let mut attempt: u32 = 0;
                    // `None` = the watchdog claimed the job (outcome and
                    // feedback already emitted over there).
                    let mut resolution: Option<(RegistrationOutcome, JobFeedback)> = None;
                    let mut recovered_from_claim = false;
                    loop {
                        // A job past its deadline — expired in the
                        // queue, or between retries — is contained
                        // without touching the backend.
                        if deadline_at.is_some_and(|d| Instant::now() >= d) {
                            stats.deadline_missed += 1;
                            resolution = Some((
                                RegistrationOutcome {
                                    id,
                                    stream,
                                    lane,
                                    transform: initial,
                                    rmse: f64::NAN,
                                    iterations: 0,
                                    stop: StopReason::DeadlineExceeded,
                                    queue_wait_ms,
                                    service_ms: t_serve.elapsed().as_secs_f64() * 1e3,
                                    error: Some(format!(
                                        "job {id} on lane {lane}: deadline exceeded"
                                    )),
                                    attempts: attempt + 1,
                                },
                                JobFeedback {
                                    lane,
                                    key,
                                    uploaded: false,
                                    hit: false,
                                    ok: false,
                                    generation,
                                },
                            ));
                            break;
                        }
                        // Respawn the backend if a panic retired it (or
                        // an earlier respawn failed). A factory failure
                        // here is contained in the job, not the pool.
                        if icp.is_none() {
                            let tier = stats.restarts / sup.restarts_per_tier.max(1) as usize;
                            match make_icp(tier) {
                                Ok(engine) => {
                                    stats.backend_tier = tier;
                                    stats.backend = engine.backend().name().to_string();
                                    icp = Some(engine);
                                }
                                Err(e) => {
                                    resolution = Some((
                                        RegistrationOutcome {
                                            id,
                                            stream,
                                            lane,
                                            transform: initial,
                                            rmse: f64::NAN,
                                            iterations: 0,
                                            stop: StopReason::Failed,
                                            queue_wait_ms,
                                            service_ms: t_serve.elapsed().as_secs_f64() * 1e3,
                                            error: Some(format!("job {id} on lane {lane}: {e:#}")),
                                            attempts: attempt + 1,
                                        },
                                        JobFeedback {
                                            lane,
                                            key,
                                            uploaded: false,
                                            hit: false,
                                            ok: false,
                                            generation,
                                        },
                                    ));
                                    break;
                                }
                            }
                        }
                        // Publish the attempt for the watchdog. If the
                        // watchdog already claimed this job (stall cut
                        // off between our checks), stop touching it.
                        let claimed_already = !hb.active.publish_with(
                            ActiveJob {
                                id,
                                stream,
                                key,
                                initial,
                                queue_wait_ms,
                                started: t_serve,
                                deadline_at,
                                attempt,
                                generation,
                            },
                            // Reset the cancel token under the slot lock
                            // so a claim of this fresh attempt can never
                            // have its cancellation wiped.
                            || hb.cancel.reset(),
                        );
                        if claimed_already {
                            recovered_from_claim = true;
                            break;
                        }
                        let engine = icp.as_mut().expect("respawned above");
                        let (uploads_before, hits_before, _) = engine.target_cache_stats();
                        // Retries re-stage the same shared cloud: every
                        // attempt costs one `Arc` refcount, never a
                        // deep copy of the points.
                        engine.set_input_source(Arc::clone(&job.source));
                        engine.set_input_target(Arc::clone(&job.target));
                        engine.set_transformation_matrix(initial);
                        engine.set_deadline(deadline_at);
                        // A panicking backend must not take the lane
                        // (and with it the whole pool) down: contain the
                        // unwind, respawn, retry.
                        let served = match catch_unwind(AssertUnwindSafe(|| engine.align())) {
                            Ok(Ok(res)) => {
                                let (u1, h1, _) = engine.target_cache_stats();
                                Attempt::Done(res, u1 > uploads_before, h1 > hits_before)
                            }
                            Ok(Err(e)) => Attempt::Failed(format!("{e:#}")),
                            Err(payload) => Attempt::Panicked(panic_message(payload)),
                        };
                        // Resolve the claim race: whoever holds the
                        // claim-slot lock first owns the job's outcome.
                        let claimed = hb.active.finish();
                        if matches!(served, Attempt::Panicked(_)) {
                            // The engine (and its backend) is toast:
                            // retire its telemetry, respawn next loop,
                            // and tell the dispatcher to un-warm us.
                            retire(&mut icp, &mut retired);
                            stats.restarts += 1;
                            generation += 1;
                            ev_tx.send(LaneEvent::Restarted { lane }).ok();
                        }
                        if claimed {
                            recovered_from_claim = true;
                            break;
                        }
                        match served {
                            Attempt::Done(mut res, uploaded, hit) => {
                                // Hand the iteration-stat buffer back to
                                // the engine so the next align reuses its
                                // capacity (part of the zero-alloc path).
                                if let Some(engine) = icp.as_mut() {
                                    engine.recycle_stats(std::mem::take(&mut res.stats));
                                }
                                let deadline_hit = res.stop == StopReason::DeadlineExceeded;
                                if deadline_hit {
                                    stats.deadline_missed += 1;
                                }
                                resolution = Some((
                                    RegistrationOutcome {
                                        id,
                                        stream,
                                        lane,
                                        // A deadline cut mid-alignment
                                        // hands back the initial
                                        // transform: partial progress is
                                        // not a usable pose.
                                        transform: if deadline_hit {
                                            initial
                                        } else {
                                            res.transformation
                                        },
                                        rmse: if deadline_hit { f64::NAN } else { res.rmse },
                                        iterations: res.iterations,
                                        stop: res.stop,
                                        queue_wait_ms,
                                        service_ms: t_serve.elapsed().as_secs_f64() * 1e3,
                                        error: deadline_hit.then(|| {
                                            format!("job {id} on lane {lane}: deadline exceeded")
                                        }),
                                        attempts: attempt + 1,
                                    },
                                    JobFeedback {
                                        lane,
                                        key,
                                        uploaded,
                                        hit,
                                        ok: !deadline_hit,
                                        generation,
                                    },
                                ));
                                break;
                            }
                            Attempt::Failed(msg) | Attempt::Panicked(msg) => {
                                if attempt < max_retries {
                                    attempt += 1;
                                    stats.retries += 1;
                                    std::thread::sleep(sup.backoff(attempt));
                                    continue;
                                }
                                resolution = Some((
                                    RegistrationOutcome {
                                        id,
                                        stream,
                                        lane,
                                        transform: initial,
                                        rmse: f64::NAN,
                                        iterations: 0,
                                        stop: StopReason::Failed,
                                        queue_wait_ms,
                                        service_ms: t_serve.elapsed().as_secs_f64() * 1e3,
                                        error: Some(format!("job {id} on lane {lane}: {msg}")),
                                        attempts: attempt + 1,
                                    },
                                    JobFeedback {
                                        lane,
                                        key,
                                        uploaded: false,
                                        hit: false,
                                        ok: false,
                                        generation,
                                    },
                                ));
                                break;
                            }
                        }
                    }
                    stats.jobs += 1;
                    stats.queue_wait.record_ms(queue_wait_ms);
                    stats.service.record_ms(t_serve.elapsed().as_secs_f64() * 1e3);
                    if recovered_from_claim {
                        // The watchdog already emitted this job's
                        // outcome and feedback; just account it and
                        // report the lane back up.
                        stats.failed += 1;
                        stats.deadline_missed += 1;
                        hb.active.clear();
                        ev_tx.send(LaneEvent::Recovered { lane }).ok();
                        continue;
                    }
                    let (outcome, feedback) = resolution.expect("every unclaimed job resolves");
                    if outcome.is_failed() {
                        stats.failed += 1;
                    }
                    out_tx.send(outcome).ok();
                    ev_tx.send(LaneEvent::Feedback(feedback)).ok();
                }
                if let Some(engine) = icp.as_ref() {
                    stats.resident_targets = engine.backend().resident_epochs().len();
                    stats.device_ms =
                        retired.0 + engine.backend().device_time().as_secs_f64() * 1e3;
                    let (u, h, _) = engine.target_cache_stats();
                    stats.target_uploads = (retired.1 + u) as usize;
                    stats.target_hits = (retired.2 + h) as usize;
                    stats.target_evictions =
                        (retired.3 + engine.backend().target_evictions()) as usize;
                } else {
                    stats.device_ms = retired.0;
                    stats.target_uploads = retired.1 as usize;
                    stats.target_hits = retired.2 as usize;
                    stats.target_evictions = retired.3 as usize;
                }
                lane_tx.send(stats).ok();
                Ok(())
            }));
        }
        // Drop the originals so the collection channels close when the
        // last lane finishes (and the dispatcher's slot wait cannot hang
        // on lanes that never started).
        drop(out_tx);
        drop(lane_tx);
        drop(ev_tx);
        drop(slots_tx);

        match producer.join() {
            Ok(r) => r.context("job producer")?,
            Err(_) => bail!("job producer panicked"),
        }
        if dispatcher.join().is_err() {
            bail!("affinity dispatcher panicked");
        }
        let mut worker_err = None;
        for w in workers {
            match w.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    worker_err.get_or_insert(e);
                }
                Err(_) => {
                    worker_err.get_or_insert(anyhow!("lane worker panicked"));
                }
            }
        }
        watchdog_stop.store(true, Ordering::SeqCst);
        if watchdog.join().is_err() {
            bail!("deadline watchdog panicked");
        }
        // All `out_tx` clones are gone once the watchdog returns, so
        // the collector's loop has terminated; join it even on the
        // worker-error path so partial outcomes are not silently lost.
        let outcomes = match collector.join() {
            Ok(v) => v,
            Err(_) => bail!("outcome collector panicked"),
        };
        match worker_err {
            Some(e) => Err(e),
            None => Ok(outcomes),
        }
    })?;

    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    outcomes.sort_by_key(|o| o.id);
    let mut lane_stats: Vec<LaneStats> = lane_rx.into_iter().collect();
    lane_stats.sort_by_key(|s| s.lane);

    // Merge the per-lane distributions into the aggregate report.
    let mut service = TimingStats::new();
    for l in &lane_stats {
        service.merge(&l.service);
    }
    let mut queue_wait = TimingStats::new();
    for o in &outcomes {
        queue_wait.record_ms(o.queue_wait_ms);
    }

    Ok(LaneReport {
        outcomes,
        lanes: lane_stats,
        service,
        queue_wait,
        wall_ms,
    })
}

/// Run a pool of `lanes` worker lanes with the inert default
/// supervision policy (no deadlines, no retries) and a tier-blind
/// backend factory — the historical entry point; see
/// [`run_supervised_lane_pool`] for the full fault-tolerant form.
pub fn run_lane_pool<B, F, P>(
    lanes: usize,
    queue_depth: usize,
    icp_cfg: LaneIcpConfig,
    make_backend: F,
    produce: P,
) -> Result<LaneReport>
where
    B: KernelBackend,
    F: Fn(usize) -> Result<B> + Sync,
    P: FnOnce(SyncSender<RegistrationJob>) -> Result<()> + Send,
{
    run_supervised_lane_pool(
        lanes,
        queue_depth,
        icp_cfg,
        SupervisorConfig::default(),
        move |lane, _tier| make_backend(lane),
        produce,
    )
}

/// Convenience wrapper: push a prebuilt batch of jobs through a
/// supervised pool with an explicit fault-tolerance policy and a
/// tier-aware backend factory.
pub fn run_registration_batch_supervised<B, F>(
    jobs: Vec<RegistrationJob>,
    lanes: usize,
    queue_depth: usize,
    icp_cfg: LaneIcpConfig,
    sup: SupervisorConfig,
    make_backend: F,
) -> Result<LaneReport>
where
    B: KernelBackend,
    F: Fn(usize, usize) -> Result<B> + Sync,
{
    let expected = jobs.len();
    let report = run_supervised_lane_pool(
        lanes,
        queue_depth,
        icp_cfg,
        sup,
        make_backend,
        move |tx| {
            for mut job in jobs {
                job.mark_submitted(); // queue wait starts at send, not build
                if tx.send(job).is_err() {
                    break; // pool shut down early
                }
            }
            Ok(())
        },
    )?;
    if report.outcomes.len() != expected {
        return Err(anyhow!(
            "lane pool returned {} outcomes for {} jobs",
            report.outcomes.len(),
            expected
        ));
    }
    Ok(report)
}

/// Convenience wrapper: push a prebuilt batch of jobs through the pool.
pub fn run_registration_batch<B, F>(
    jobs: Vec<RegistrationJob>,
    lanes: usize,
    queue_depth: usize,
    icp_cfg: LaneIcpConfig,
    make_backend: F,
) -> Result<LaneReport>
where
    B: KernelBackend,
    F: Fn(usize) -> Result<B> + Sync,
{
    run_registration_batch_supervised(
        jobs,
        lanes,
        queue_depth,
        icp_cfg,
        SupervisorConfig::default(),
        move |lane, _tier| make_backend(lane),
    )
}
