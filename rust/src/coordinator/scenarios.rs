//! Batch scenario builders and drivers on top of the lane pool: frame
//! pair batches ([`sequence_pair_jobs`]), scan-to-map localization
//! ([`run_localization`] / [`run_localization_supervised`]), and the
//! tile-crossing submap scenario ([`run_tiled_localization`] /
//! [`run_tiled_localization_supervised`]).

use super::jobs::{LaneIcpConfig, LaneReport, RegistrationJob};
use super::pipeline::{admit_map, fit_to_capacity, preprocess, AdmissionDecision, PipelineConfig};
use super::supervise::{run_registration_batch, run_registration_batch_supervised};
use super::SupervisorConfig;
use crate::dataset::Sequence;
use crate::fpps_api::KernelBackend;
use crate::math::Mat4;
use crate::pointcloud::PointCloud;
use crate::rng::Pcg32;
use anyhow::{bail, Result};
use std::sync::Arc;

/// Build frame-pair jobs (frame i aligned onto frame i−1) from a
/// synthetic sequence — the shared job generator for the multi-client
/// example, the `fpps batch` subcommand and the lane-scaling bench.
pub fn sequence_pair_jobs(
    seq: &Sequence,
    frames: usize,
    stream: usize,
    cfg: &PipelineConfig,
) -> Result<Vec<RegistrationJob>> {
    let frames = frames.min(seq.len());
    let mut jobs = Vec::new();
    let mut prev: Option<PointCloud> = None;
    for i in 0..frames {
        let cloud = preprocess(&seq.frame(i)?, cfg);
        let mut rng = Pcg32::substream(cfg.seed, i as u64);
        let sample = cloud.random_sample(cfg.source_sample, &mut rng);
        let full = fit_to_capacity(cloud, cfg.target_capacity, cfg.seed);
        if let Some(target) = prev.take() {
            jobs.push(RegistrationJob::new(
                (stream as u64) << 32 | i as u64,
                stream,
                sample,
                target,
                Mat4::IDENTITY,
            ));
        }
        prev = Some(full);
    }
    Ok(jobs)
}

// ---------------------------------------------------------------------------
// Scan-to-map localization (resident-target scenario)
// ---------------------------------------------------------------------------

/// Prebuilt scan-to-map localization workload: one shared map, M scan
/// jobs against it, plus the ground-truth poses to score against.
pub struct LocalizationWorkload {
    /// The map every scan aligns against (frame-0 coordinates). All jobs
    /// share this one `Arc` and one target key, so the lane pool keeps
    /// it device-resident.
    pub map: Arc<PointCloud>,
    pub jobs: Vec<RegistrationJob>,
    /// Ground-truth map←sensor poses, indexed like `jobs`.
    pub truth: Vec<Mat4>,
    /// What admission decided for the map (see [`admit_map`]).
    pub admission: AdmissionDecision,
}

/// Build a localization workload from a synthetic sequence: the map is
/// the union of all preprocessed scans placed into frame-0 coordinates
/// by ground truth (then capacity-bounded), and each scan becomes a job
/// whose prior is the *previous* frame's true pose — the "last known
/// pose" a localization stack would start from.
pub fn localization_jobs(
    seq: &Sequence,
    scans: usize,
    cfg: &PipelineConfig,
) -> Result<LocalizationWorkload> {
    let scans = scans.min(seq.len());
    if scans == 0 {
        bail!("localization needs at least one scan");
    }
    let origin = seq.ground_truth[0].inverse_rigid();
    let mut map = PointCloud::new();
    let mut sources = Vec::with_capacity(scans);
    let mut truth = Vec::with_capacity(scans);
    for i in 0..scans {
        let cloud = preprocess(&seq.frame(i)?, cfg);
        let pose = origin.mul_mat(&seq.ground_truth[i]); // map ← sensor_i
        let world = cloud.transformed(&pose);
        map.xyz.extend_from_slice(&world.xyz);
        let mut rng = Pcg32::substream(cfg.seed, i as u64);
        sources.push(cloud.random_sample(cfg.source_sample, &mut rng));
        truth.push(pose);
    }
    // Residency-aware admission replaces the old silent shrink: an
    // oversized map is rejected or explicitly downsampled per policy.
    let (map, admission) = admit_map(map, cfg)?;
    let map = Arc::new(map);
    let key = map.fingerprint(); // hash the shared map once, not per job

    let mut jobs = Vec::with_capacity(scans);
    for (i, source) in sources.into_iter().enumerate() {
        let prior = match i {
            0 => Mat4::IDENTITY,
            _ => truth[i - 1],
        };
        jobs.push(RegistrationJob::new_keyed(
            i as u64,
            0,
            source,
            Arc::clone(&map),
            key,
            prior,
        ));
    }
    Ok(LocalizationWorkload {
        map,
        jobs,
        truth,
        admission,
    })
}

/// Per-scan translation error vs. `truth` (m), in job order (the job id
/// indexes `truth`). Contained failures ([`RegistrationOutcome::error`](super::RegistrationOutcome))
/// score NaN so a failed job can never masquerade as an accurate
/// localization; [`mean_finite`] / [`max_finite`] skip them.
fn translation_errors_vs_truth(report: &LaneReport, truth: &[Mat4]) -> Vec<f64> {
    report
        .outcomes
        .iter()
        .map(|o| {
            if o.is_failed() {
                f64::NAN
            } else {
                let gt = truth[o.id as usize];
                (o.transform.translation() - gt.translation()).norm()
            }
        })
        .collect()
}

/// Mean over the finite entries (NaN marks contained failures); NaN when
/// nothing finite remains.
fn mean_finite(vals: &[f64]) -> f64 {
    let (mut sum, mut n) = (0.0f64, 0usize);
    for v in vals.iter().copied().filter(|v| v.is_finite()) {
        sum += v;
        n += 1;
    }
    if n == 0 {
        f64::NAN
    } else {
        sum / n as f64
    }
}

/// Max over the finite entries; NaN when nothing finite remains (an
/// all-failure run must not report a perfect 0.0 max error).
fn max_finite(vals: &[f64]) -> f64 {
    let mut max = f64::NAN;
    for v in vals.iter().copied().filter(|v| v.is_finite()) {
        max = if max.is_nan() { v } else { max.max(v) };
    }
    max
}

/// Result of a [`run_localization`] run.
#[derive(Debug)]
pub struct LocalizationResult {
    pub report: LaneReport,
    pub map_points: usize,
    /// Per-scan translation error vs. ground truth (m), in job order;
    /// NaN for contained failures.
    pub translation_errors: Vec<f64>,
    /// What admission decided for the map (see [`admit_map`]).
    pub admission: AdmissionDecision,
}

impl LocalizationResult {
    /// Mean translation error across scans with a finite error (m).
    pub fn mean_translation_error(&self) -> f64 {
        mean_finite(&self.translation_errors)
    }

    /// Worst finite per-scan translation error (m).
    pub fn max_translation_error(&self) -> f64 {
        max_finite(&self.translation_errors)
    }
}

/// Scan-to-map localization: align `scans` frames of `seq` against one
/// shared map over the lane pool. Every job carries the same target key,
/// so the affinity dispatcher keeps the map resident — the kd-tree
/// backend builds its index once for the whole run, and the amortized
/// upload cost drops to zero (see `benches/target_reuse.rs`).
pub fn run_localization<B, F>(
    seq: &Sequence,
    scans: usize,
    cfg: &PipelineConfig,
    lanes: usize,
    queue_depth: usize,
    icp_cfg: LaneIcpConfig,
    make_backend: F,
) -> Result<LocalizationResult>
where
    B: KernelBackend,
    F: Fn(usize) -> Result<B> + Sync,
{
    run_localization_supervised(
        seq,
        scans,
        cfg,
        lanes,
        queue_depth,
        icp_cfg,
        SupervisorConfig::default(),
        move |lane, _tier| make_backend(lane),
    )
}

/// [`run_localization`] with an explicit fault-tolerance policy and a
/// tier-aware backend factory (see [`run_supervised_lane_pool`](super::run_supervised_lane_pool)).
#[allow(clippy::too_many_arguments)]
pub fn run_localization_supervised<B, F>(
    seq: &Sequence,
    scans: usize,
    cfg: &PipelineConfig,
    lanes: usize,
    queue_depth: usize,
    icp_cfg: LaneIcpConfig,
    sup: SupervisorConfig,
    make_backend: F,
) -> Result<LocalizationResult>
where
    B: KernelBackend,
    F: Fn(usize, usize) -> Result<B> + Sync,
{
    let workload = localization_jobs(seq, scans, cfg)?;
    let map_points = workload.map.len();
    let admission = workload.admission;
    let report = run_registration_batch_supervised(
        workload.jobs,
        lanes,
        queue_depth,
        icp_cfg,
        sup,
        make_backend,
    )?;
    let translation_errors = translation_errors_vs_truth(&report, &workload.truth);
    Ok(LocalizationResult {
        report,
        map_points,
        translation_errors,
        admission,
    })
}

// ---------------------------------------------------------------------------
// Tile-crossing localization (multi-target residency scenario)
// ---------------------------------------------------------------------------

/// Prebuilt tile-crossing localization workload: the trajectory is cut
/// into `tiles` contiguous submaps and the job stream *interleaves*
/// them — the submap ping-pong of a vehicle tracking along a tile
/// boundary. On a single-slot backend every job re-uploads (and, on the
/// kd-tree backend, rebuilds); with ≥ `tiles` residency slots each
/// submap uploads once per serving lane and every further job is a
/// cache hit (see `benches/tile_residency.rs`).
pub struct TiledLocalizationWorkload {
    /// One submap per tile (frame-0 coordinates), shared by its jobs.
    pub maps: Vec<Arc<PointCloud>>,
    /// Tile index of each job, in job-id order.
    pub tile_of_job: Vec<usize>,
    pub jobs: Vec<RegistrationJob>,
    /// Ground-truth map←sensor poses, indexed by job id.
    pub truth: Vec<Mat4>,
    /// Per-tile admission decisions, tile order (see [`admit_map`]).
    pub admissions: Vec<AdmissionDecision>,
}

/// Build a tile-crossing workload from a synthetic sequence: scans are
/// assigned to `tiles` contiguous trajectory segments, each segment's
/// union (placed into frame-0 coordinates by ground truth, then
/// capacity-bounded) becomes one submap, and jobs are emitted
/// round-robin across the tiles so consecutive jobs alternate submaps.
pub fn tiled_localization_jobs(
    seq: &Sequence,
    scans: usize,
    tiles: usize,
    cfg: &PipelineConfig,
) -> Result<TiledLocalizationWorkload> {
    let scans = scans.min(seq.len());
    if scans == 0 {
        bail!("localization needs at least one scan");
    }
    let tiles = tiles.clamp(1, scans);
    let tile_of_scan = |i: usize| (i * tiles) / scans;
    let origin = seq.ground_truth[0].inverse_rigid();
    let mut tile_clouds: Vec<PointCloud> = (0..tiles).map(|_| PointCloud::new()).collect();
    let mut sources: Vec<Option<PointCloud>> = Vec::with_capacity(scans);
    let mut poses = Vec::with_capacity(scans);
    for i in 0..scans {
        let cloud = preprocess(&seq.frame(i)?, cfg);
        let pose = origin.mul_mat(&seq.ground_truth[i]); // map ← sensor_i
        let world = cloud.transformed(&pose);
        tile_clouds[tile_of_scan(i)].xyz.extend_from_slice(&world.xyz);
        let mut rng = Pcg32::substream(cfg.seed, i as u64);
        sources.push(Some(cloud.random_sample(cfg.source_sample, &mut rng)));
        poses.push(pose);
    }
    // Each submap passes residency-aware admission on its own.
    let mut maps = Vec::with_capacity(tiles);
    let mut admissions = Vec::with_capacity(tiles);
    for c in tile_clouds {
        let (m, a) = admit_map(c, cfg)?;
        maps.push(Arc::new(m));
        admissions.push(a);
    }
    // Hash each shared submap once, not per job.
    let keys: Vec<u64> = maps.iter().map(|m| m.fingerprint()).collect();

    // Emission order: round-robin over the tiles (A,B,…,A,B,…), the
    // maximal-ping-pong stress an LRU residency set exists for.
    let mut by_tile: Vec<Vec<usize>> = vec![Vec::new(); tiles];
    for i in 0..scans {
        by_tile[tile_of_scan(i)].push(i);
    }
    let deepest = by_tile.iter().map(Vec::len).max().unwrap_or(0);
    let mut jobs = Vec::with_capacity(scans);
    let mut truth = Vec::with_capacity(scans);
    let mut tile_of_job = Vec::with_capacity(scans);
    for r in 0..deepest {
        for (t, scans_of_tile) in by_tile.iter().enumerate() {
            let Some(&i) = scans_of_tile.get(r) else {
                continue;
            };
            // "Last known pose" prior, as in [`localization_jobs`].
            let prior = if i == 0 { Mat4::IDENTITY } else { poses[i - 1] };
            jobs.push(RegistrationJob::new_keyed(
                jobs.len() as u64,
                t,
                sources[i].take().expect("each scan emitted once"),
                Arc::clone(&maps[t]),
                keys[t],
                prior,
            ));
            truth.push(poses[i]);
            tile_of_job.push(t);
        }
    }
    Ok(TiledLocalizationWorkload {
        maps,
        tile_of_job,
        jobs,
        truth,
        admissions,
    })
}

/// Result of a [`run_tiled_localization`] run.
#[derive(Debug)]
pub struct TiledLocalizationResult {
    pub report: LaneReport,
    /// Points per submap, tile order.
    pub map_points: Vec<usize>,
    /// Per-scan translation error vs. ground truth (m), in job order;
    /// NaN for contained failures.
    pub translation_errors: Vec<f64>,
    /// Per-tile admission decisions, tile order (see [`admit_map`]).
    pub admissions: Vec<AdmissionDecision>,
}

impl TiledLocalizationResult {
    /// Mean translation error across scans with a finite error (m).
    pub fn mean_translation_error(&self) -> f64 {
        mean_finite(&self.translation_errors)
    }

    /// Worst finite per-scan translation error (m).
    pub fn max_translation_error(&self) -> f64 {
        max_finite(&self.translation_errors)
    }
}

/// Tile-crossing localization over the lane pool: `scans` frames of
/// `seq` against `tiles` alternating submaps. With multi-target
/// residency the per-lane upload count is bounded by the tile count —
/// not the scan count — which `fpps localize --tiles` prints.
#[allow(clippy::too_many_arguments)]
pub fn run_tiled_localization<B, F>(
    seq: &Sequence,
    scans: usize,
    tiles: usize,
    cfg: &PipelineConfig,
    lanes: usize,
    queue_depth: usize,
    icp_cfg: LaneIcpConfig,
    make_backend: F,
) -> Result<TiledLocalizationResult>
where
    B: KernelBackend,
    F: Fn(usize) -> Result<B> + Sync,
{
    run_tiled_localization_supervised(
        seq,
        scans,
        tiles,
        cfg,
        lanes,
        queue_depth,
        icp_cfg,
        SupervisorConfig::default(),
        move |lane, _tier| make_backend(lane),
    )
}

/// [`run_tiled_localization`] with an explicit fault-tolerance policy
/// and a tier-aware backend factory (see [`run_supervised_lane_pool`](super::run_supervised_lane_pool)).
#[allow(clippy::too_many_arguments)]
pub fn run_tiled_localization_supervised<B, F>(
    seq: &Sequence,
    scans: usize,
    tiles: usize,
    cfg: &PipelineConfig,
    lanes: usize,
    queue_depth: usize,
    icp_cfg: LaneIcpConfig,
    sup: SupervisorConfig,
    make_backend: F,
) -> Result<TiledLocalizationResult>
where
    B: KernelBackend,
    F: Fn(usize, usize) -> Result<B> + Sync,
{
    let workload = tiled_localization_jobs(seq, scans, tiles, cfg)?;
    let map_points = workload.maps.iter().map(|m| m.len()).collect();
    let admissions = workload.admissions.clone();
    let report = run_registration_batch_supervised(
        workload.jobs,
        lanes,
        queue_depth,
        icp_cfg,
        sup,
        make_backend,
    )?;
    let translation_errors = translation_errors_vs_truth(&report, &workload.truth);
    Ok(TiledLocalizationResult {
        report,
        map_points,
        translation_errors,
        admissions,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{lidar::LidarConfig, sequence_specs, Sequence};

    fn tiny_sequence(frames: usize) -> Sequence {
        let spec = sequence_specs()[3].clone(); // residential: gentle
        Sequence::synthetic(spec, frames, 11, LidarConfig::tiny())
    }

    #[test]
    fn localization_workload_shares_one_target() {
        let seq = tiny_sequence(5);
        let cfg = PipelineConfig {
            source_sample: 256,
            target_capacity: 8192,
            ..Default::default()
        };
        let w = localization_jobs(&seq, 5, &cfg).unwrap();
        assert_eq!(w.jobs.len(), 5);
        assert_eq!(w.truth.len(), 5);
        let key = w.jobs[0].target_key;
        for j in &w.jobs {
            assert_eq!(j.target_key, key, "all scans share the map key");
            assert!(Arc::ptr_eq(&j.target, &w.map), "no map copies");
        }
        // First scan's prior is identity (it *is* the map origin).
        assert_eq!(w.jobs[0].initial.m, Mat4::IDENTITY.m);
    }

    #[test]
    fn localization_tracks_ground_truth() {
        let seq = tiny_sequence(5);
        let cfg = PipelineConfig {
            source_sample: 512,
            target_capacity: 8192,
            ..Default::default()
        };
        let res = run_localization(
            &seq,
            5,
            &cfg,
            2,
            8,
            LaneIcpConfig {
                max_iteration_count: 30,
                ..Default::default()
            },
            |_| Ok(crate::fpps_api::KdTreeCpuBackend::new()),
        )
        .unwrap();
        assert_eq!(res.translation_errors.len(), 5);
        assert!(
            res.mean_translation_error() < 0.3,
            "mean localization error {}",
            res.mean_translation_error()
        );
        assert!(res.map_points > 0);
        // Affinity + shared key: the map was uploaded by at most `lanes`
        // backends, never once per scan.
        let uploads: usize = res.report.lanes.iter().map(|l| l.target_uploads).sum();
        assert!(uploads <= 2, "{uploads} uploads for 5 same-map scans");
        let hits: usize = res.report.lanes.iter().map(|l| l.target_hits).sum();
        assert_eq!(uploads + hits, 5, "every job either uploads or hits");
    }

    // --- Tile-crossing workload ---

    #[test]
    fn tiled_workload_interleaves_tiles_and_shares_submaps() {
        let seq = tiny_sequence(6);
        let cfg = PipelineConfig {
            source_sample: 256,
            target_capacity: 8192,
            ..Default::default()
        };
        let w = tiled_localization_jobs(&seq, 6, 2, &cfg).unwrap();
        assert_eq!(w.maps.len(), 2);
        assert_eq!(w.jobs.len(), 6);
        assert_eq!(w.truth.len(), 6);
        // Round-robin emission: consecutive jobs alternate tiles.
        assert_eq!(w.tile_of_job, vec![0, 1, 0, 1, 0, 1]);
        for (job, &t) in w.jobs.iter().zip(&w.tile_of_job) {
            assert_eq!(job.stream, t);
            assert!(Arc::ptr_eq(&job.target, &w.maps[t]), "submaps are shared");
            assert_eq!(job.target_key, w.maps[t].fingerprint());
        }
        // Ids are the emission order (deterministic outcome order).
        for (k, job) in w.jobs.iter().enumerate() {
            assert_eq!(job.id, k as u64);
        }
        // Two tiles → two distinct keys.
        assert_ne!(w.jobs[0].target_key, w.jobs[1].target_key);
        // Degenerate tile counts clamp instead of failing.
        assert_eq!(tiled_localization_jobs(&seq, 6, 0, &cfg).unwrap().maps.len(), 1);
        assert_eq!(tiled_localization_jobs(&seq, 6, 99, &cfg).unwrap().maps.len(), 6);
    }

    #[test]
    fn tiled_localization_tracks_ground_truth_with_bounded_uploads() {
        let seq = tiny_sequence(6);
        let cfg = PipelineConfig {
            source_sample: 512,
            target_capacity: 8192,
            ..Default::default()
        };
        let res = run_tiled_localization(
            &seq,
            6,
            2,
            &cfg,
            1,
            4,
            LaneIcpConfig {
                max_iteration_count: 30,
                ..Default::default()
            },
            |_| Ok(crate::fpps_api::KdTreeCpuBackend::new()),
        )
        .unwrap();
        assert_eq!(res.report.outcomes.len(), 6);
        assert_eq!(res.map_points.len(), 2);
        assert!(
            res.mean_translation_error() < 0.3,
            "mean tile-localization error {}",
            res.mean_translation_error()
        );
        // One lane, two submaps, A,B,A,B,… order: the LRU residency set
        // absorbs the ping-pong — exactly one upload per submap.
        let uploads: usize = res.report.lanes.iter().map(|l| l.target_uploads).sum();
        let hits: usize = res.report.lanes.iter().map(|l| l.target_hits).sum();
        assert_eq!(uploads, 2, "one upload per tile, not per scan");
        assert_eq!(uploads + hits, 6);
        assert_eq!(res.report.lanes[0].resident_targets, 2);
        assert_eq!(res.report.failed_jobs(), 0);
    }
}
