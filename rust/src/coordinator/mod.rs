//! Frame-stream coordinator — the host-side system layer of Fig. 2.
//!
//! The paper's host "is responsible for data transmission and invokes
//! kernel execution according to the instructions from APIs". At system
//! level that means keeping the accelerator fed: while frame i is being
//! aligned, frame i+1 is already being acquired and preprocessed
//! (sampled, padded). This module implements that as a two-stage
//! pipeline over std threads with bounded channels (backpressure), plus
//! the scan-to-scan odometry driver used by the end-to-end example and
//! the Table III / IV benches.
//!
//! On top of the single-stream odometry pipeline sits the **multi-lane
//! registration engine** ([`run_lane_pool`] / [`run_registration_batch`]):
//! K worker lanes, each owning its own [`KernelBackend`] instance, are
//! fed by a **pool-wide residency coordinator** ([`AffinityRouter`]) —
//! jobs sharing a target key route to a lane whose backend already
//! holds that target resident (no re-upload, no kd-tree rebuild), a
//! *cold* key routes to a lane with a **free residency slot** before any
//! warm lane is made to evict, and warm lanes are only stolen from once
//! they have a real backlog ([`STEAL_BACKLOG`] jobs deep) with another
//! lane idle. The coordinator mirrors each lane backend's LRU resident
//! set, and the mirror is **corrected, not guessed**: every job
//! completion reports [`JobFeedback`] `(lane, key, uploaded, hit, ok)`
//! back to the dispatcher, which replays actual uploads and cache hits
//! onto a confirmed resident mirror (including the device's own LRU
//! eviction) and *un-warms* a key whose job failed before ever touching
//! residency — so a poisoned job can never leave a phantom warm entry
//! steering later jobs to a cache that does not exist. Maps that
//! cannot fit a residency slot at all are handled up front by
//! residency-aware admission ([`AdmissionPolicy`]: reject with a
//! structured [`AdmissionError`], or downsample-to-fit) instead of
//! silent shrinking. Per-job failures are contained in their
//! [`RegistrationOutcome`] instead of killing the lane. Per-lane
//! [`TimingStats`] merge into an aggregate [`LaneReport`]. This is how
//! related FPGA registration stacks treat the accelerator — a shared,
//! multi-client resource with batched dispatch and device-resident
//! reference clouds — and it is the scaling substrate every
//! multi-client scenario here builds on: the scan-to-map
//! [`run_localization`] scenario (M scans against one resident map) and
//! the tile-crossing [`run_tiled_localization`] scenario (submap
//! ping-pong across an LRU residency set).

use crate::dataset::Sequence;
use crate::fpps_api::{FppsIcp, KernelBackend};
use crate::icp::StopReason;
use crate::math::Mat4;
use crate::metrics::TimingStats;
use crate::pointcloud::PointCloud;
use crate::rng::Pcg32;
use anyhow::{anyhow, bail, Context, Result};
use std::sync::mpsc::{channel, sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::Instant;

/// Preprocessed frame ready for alignment.
pub struct PreparedFrame {
    pub index: usize,
    /// Sampled source cloud (the paper's 4096-point sample).
    pub source_sample: PointCloud,
    /// Full cloud (becomes the next frame's target).
    pub full: PointCloud,
}

/// Pipeline configuration.
///
/// The preprocessing knobs implement the standard LiDAR-odometry front
/// end (range crop, ground removal, voxel grid) that PCL-based
/// registration pipelines run before ICP. Point-to-point scan-to-scan
/// ICP on raw ring-structured scans is identity-biased (ground rings
/// self-match; see DESIGN.md §3 "dataset realism"), so the front end is
/// not optional for odometry-quality tracking — though the Table III /
/// IV benches can disable pieces of it, as they compare CPU vs device
/// under *identical* preprocessing.
#[derive(Clone, Copy, Debug)]
pub struct PipelineConfig {
    /// Per-frame source sample size (paper: 4096).
    pub source_sample: usize,
    /// Target cap; clouds larger than this are voxel-downsampled to fit
    /// the device target buffer.
    pub target_capacity: usize,
    /// Channel depth between acquisition and alignment (double
    /// buffering = 2, like the device's ping-pong BRAM buffers).
    pub queue_depth: usize,
    pub seed: u64,
    /// Range crop (m); 0 disables.
    pub crop_range: f32,
    /// Drop points below this sensor-frame z (ground removal; the
    /// sensor sits ~1.73 m up, so −1.2 keeps everything ≥ ~0.5 m above
    /// the road). `f32::NEG_INFINITY` disables.
    pub ground_z_min: f32,
    /// Voxel-grid leaf applied to both clouds (m); 0 disables.
    pub voxel_leaf: f32,
    /// Multi-start bootstrap: number of forward-translation seeds tried
    /// on the first frame (and after tracking loss). 0 = identity only.
    pub bootstrap_seeds: usize,
    /// Spacing between bootstrap seeds along +x (m).
    pub bootstrap_step: f32,
    /// How maps whose footprint exceeds one residency slot
    /// (`target_capacity` points) are admitted (see [`admit_map`]).
    pub admission: AdmissionPolicy,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self {
            source_sample: 4096,
            target_capacity: 16_384,
            queue_depth: 2,
            seed: 7,
            crop_range: 40.0,
            ground_z_min: -1.2,
            voxel_leaf: 0.15,
            bootstrap_seeds: 9,
            bootstrap_step: 0.3,
            admission: AdmissionPolicy::DownsampleToFit,
        }
    }
}

impl PipelineConfig {
    /// Paper-parity preprocessing: no front end at all (raw clouds),
    /// as in the paper's "4096 points randomly sampled from the source".
    pub fn raw() -> Self {
        Self {
            crop_range: 0.0,
            ground_z_min: f32::NEG_INFINITY,
            voxel_leaf: 0.0,
            bootstrap_seeds: 0,
            ..Default::default()
        }
    }
}

/// Front-end preprocessing shared by source and target.
pub fn preprocess(cloud: &PointCloud, cfg: &PipelineConfig) -> PointCloud {
    let mut out = PointCloud::with_capacity(cloud.len());
    let r2max = if cfg.crop_range > 0.0 {
        cfg.crop_range * cfg.crop_range
    } else {
        f32::INFINITY
    };
    for p in cloud.iter() {
        let r2 = p[0] * p[0] + p[1] * p[1];
        if r2 <= r2max && p[2] >= cfg.ground_z_min {
            out.push(p);
        }
    }
    if cfg.voxel_leaf > 0.0 {
        out = out.voxel_downsample(cfg.voxel_leaf);
    }
    out
}

/// Per-frame odometry record.
#[derive(Clone, Debug)]
pub struct FrameRecord {
    pub index: usize,
    /// Scan-to-scan transform estimated by ICP.
    pub relative: Mat4,
    /// Accumulated pose (world ← sensor_i).
    pub pose: Mat4,
    pub rmse: f64,
    pub iterations: u32,
    pub stop: StopReason,
    /// Wall time of the alignment (acquisition excluded — it overlaps).
    pub align_ms: f64,
}

/// Odometry run output.
#[derive(Debug)]
pub struct OdometryResult {
    pub records: Vec<FrameRecord>,
    pub poses: Vec<Mat4>,
    pub align_stats: TimingStats,
    /// Time the alignment thread spent blocked waiting for frames — a
    /// measure of how well acquisition hides behind alignment.
    pub starvation_ms: f64,
}

impl OdometryResult {
    /// Mean registration RMSE across frames (Table III row).
    pub fn mean_rmse(&self) -> f64 {
        let vals: Vec<f64> = self
            .records
            .iter()
            .filter(|r| r.rmse.is_finite())
            .map(|r| r.rmse)
            .collect();
        if vals.is_empty() {
            f64::NAN
        } else {
            vals.iter().sum::<f64>() / vals.len() as f64
        }
    }
}

/// Fit a cloud into the device target buffer: voxel-downsample with a
/// growing leaf until it fits (PCL pipelines do exactly this to bound
/// map density). `seed` drives the random-sample fallback, so different
/// pipeline seeds produce different fallback samples (a fixed internal
/// seed would silently make them identical).
pub fn fit_to_capacity(cloud: PointCloud, capacity: usize, seed: u64) -> PointCloud {
    if cloud.len() <= capacity {
        return cloud;
    }
    let mut leaf = 0.1f32;
    for _ in 0..12 {
        let down = cloud.voxel_downsample(leaf);
        if down.len() <= capacity {
            return down;
        }
        leaf *= 1.6;
    }
    // Fall back to random sampling at the last resort (substream keeps
    // it independent of the per-frame source-sampling streams).
    let mut rng = Pcg32::substream(seed, 0xF17);
    cloud.random_sample(capacity, &mut rng)
}

// ---------------------------------------------------------------------------
// Residency-aware admission
// ---------------------------------------------------------------------------

/// What to do with a candidate resident map whose footprint exceeds one
/// residency slot (`target_capacity` points). Parsed from the
/// `admission=` config key and `--admission` CLI option.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Fail the run with a structured [`AdmissionError`] carrying the
    /// `hwmodel` footprint — for serving setups where a silently
    /// degraded map is worse than a loud rejection.
    Reject,
    /// Voxel-downsample (growing leaf, random-sample fallback) until the
    /// map fits the slot, and record the decision — the pre-admission
    /// behavior, made explicit and visible.
    #[default]
    DownsampleToFit,
}

impl std::str::FromStr for AdmissionPolicy {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "reject" => AdmissionPolicy::Reject,
            "downsample" | "downsample-to-fit" => AdmissionPolicy::DownsampleToFit,
            other => bail!("unknown admission policy {other:?} (expected reject | downsample)"),
        })
    }
}

impl std::fmt::Display for AdmissionPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            AdmissionPolicy::Reject => "reject",
            AdmissionPolicy::DownsampleToFit => "downsample-to-fit",
        })
    }
}

/// Structured rejection of a map that does not fit one residency slot —
/// returned (through `anyhow`, downcastable) by [`admit_map`] under
/// [`AdmissionPolicy::Reject`].
#[derive(Clone, Copy, Debug)]
pub struct AdmissionError {
    /// Raw point count of the offending map.
    pub points: usize,
    /// Points after padding to the kernel target block.
    pub padded_points: usize,
    /// HBM bytes the padded map would occupy.
    pub footprint_bytes: u64,
    /// Point capacity of one residency slot (`target_capacity`).
    pub slot_capacity: usize,
    /// HBM bytes one slot provides at that capacity.
    pub slot_bytes: u64,
}

impl std::fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "map of {} points (padded {} = {} B HBM) exceeds the {}-point residency slot \
             ({} B); rerun with `--admission downsample` or raise target_capacity",
            self.points,
            self.padded_points,
            self.footprint_bytes,
            self.slot_capacity,
            self.slot_bytes
        )
    }
}

impl std::error::Error for AdmissionError {}

/// What admission decided for one candidate map (recorded on the
/// localization workloads so the decision is reportable, never silent).
#[derive(Clone, Copy, Debug)]
pub struct AdmissionDecision {
    pub policy: AdmissionPolicy,
    /// Point count before admission.
    pub original_points: usize,
    /// Point count actually admitted to the slot.
    pub admitted_points: usize,
    /// `hwmodel` footprint of the *original* cloud — what was asked of
    /// the slot.
    pub footprint: crate::hwmodel::TargetFootprint,
    /// Point capacity of one residency slot at admission time.
    pub slot_capacity: usize,
}

impl AdmissionDecision {
    /// Did admission have to shrink the map to fit?
    pub fn downsampled(&self) -> bool {
        self.admitted_points < self.original_points
    }
}

/// Residency-aware admission for one candidate resident map: estimate
/// its padded HBM footprint via
/// [`crate::hwmodel::AcceleratorConfig::target_footprint`], admit it
/// unchanged when it fits a `cfg.target_capacity`-point slot, and
/// otherwise apply `cfg.admission` — a structured rejection or an
/// explicit downsample-to-fit — instead of the old silent shrink.
pub fn admit_map(
    cloud: PointCloud,
    cfg: &PipelineConfig,
) -> Result<(PointCloud, AdmissionDecision)> {
    let hw = crate::hwmodel::AcceleratorConfig::default();
    let block_m = crate::nn::KernelConfig::default().block_m;
    let footprint = hw.target_footprint(cloud.len(), block_m);
    let original_points = cloud.len();
    let slot_capacity = cfg.target_capacity;
    if footprint.fits_slot(slot_capacity) {
        return Ok((
            cloud,
            AdmissionDecision {
                policy: cfg.admission,
                original_points,
                admitted_points: original_points,
                footprint,
                slot_capacity,
            },
        ));
    }
    match cfg.admission {
        AdmissionPolicy::Reject => Err(AdmissionError {
            points: original_points,
            padded_points: footprint.padded_points,
            footprint_bytes: footprint.bytes,
            slot_capacity,
            slot_bytes: crate::hwmodel::AcceleratorConfig::resident_target_bytes(slot_capacity),
        }
        .into()),
        AdmissionPolicy::DownsampleToFit => {
            let fitted = fit_to_capacity(cloud, slot_capacity, cfg.seed);
            let admitted_points = fitted.len();
            Ok((
                fitted,
                AdmissionDecision {
                    policy: cfg.admission,
                    original_points,
                    admitted_points,
                    footprint,
                    slot_capacity,
                },
            ))
        }
    }
}

/// Acquisition stage: generates/loads frames, samples the source, and
/// pushes prepared frames downstream. Runs on its own thread.
fn acquisition_thread(
    seq: &Sequence,
    frames: usize,
    cfg: PipelineConfig,
    tx: SyncSender<Result<PreparedFrame>>,
) {
    for i in 0..frames {
        let item = (|| -> Result<PreparedFrame> {
            let cloud = preprocess(&seq.frame(i)?, &cfg);
            let mut rng = Pcg32::substream(cfg.seed, i as u64);
            let source_sample = cloud.random_sample(cfg.source_sample, &mut rng);
            let full = fit_to_capacity(cloud, cfg.target_capacity, cfg.seed);
            Ok(PreparedFrame {
                index: i,
                source_sample,
                full,
            })
        })();
        // Receiver hung up → stop early.
        if tx.send(item).is_err() {
            return;
        }
    }
}

/// Run scan-to-scan odometry over the first `frames` frames of `seq`
/// using the FPPS API with the given backend.
///
/// Frame 0 initialises the map; each subsequent frame aligns its sample
/// against the previous frame's full cloud, seeding ICP with the
/// previous relative motion (constant-velocity prior — standard LiDAR
/// odometry practice that also matches the paper's per-frame "initial
/// transformation matrix" API).
pub fn run_odometry<B: KernelBackend>(
    seq: &Sequence,
    frames: usize,
    cfg: PipelineConfig,
    icp: &mut FppsIcp<B>,
) -> Result<OdometryResult> {
    let frames = frames.min(seq.len());
    let (tx, rx): (_, Receiver<Result<PreparedFrame>>) = sync_channel(cfg.queue_depth);

    std::thread::scope(|scope| {
        scope.spawn(|| acquisition_thread(seq, frames, cfg, tx));

        let mut records = Vec::new();
        let mut poses = vec![Mat4::IDENTITY];
        let mut align_stats = TimingStats::new();
        let mut starvation_ms = 0.0;
        let mut prev_full: Option<PointCloud> = None;
        let mut prev_relative = Mat4::IDENTITY;

        loop {
            let wait0 = std::time::Instant::now();
            let msg = match rx.recv() {
                Ok(m) => m,
                Err(_) => break, // acquisition finished
            };
            starvation_ms += wait0.elapsed().as_secs_f64() * 1e3;
            let frame = msg.context("frame acquisition")?;

            match prev_full.take() {
                None => {
                    // First frame: nothing to align against.
                    prev_full = Some(frame.full);
                }
                Some(target) => {
                    let t0 = std::time::Instant::now();
                    let bootstrap = records.is_empty()
                        || !matches!(
                            records.last().map(|r: &FrameRecord| r.stop),
                            Some(StopReason::Converged) | Some(StopReason::MaxIterations)
                        );
                    let res = if bootstrap && cfg.bootstrap_seeds > 0 {
                        // Multi-start global initialisation: the vehicle
                        // moves dominantly forward, so seed a fan of +x
                        // translations and keep the lowest-RMSE result.
                        let mut best: Option<crate::fpps_api::FppsResult> = None;
                        for k in 0..=cfg.bootstrap_seeds {
                            let seed_t = Mat4::from_rt(
                                crate::math::Mat3::IDENTITY,
                                crate::math::Vec3::new(
                                    (k as f64) * cfg.bootstrap_step as f64,
                                    0.0,
                                    0.0,
                                ),
                            );
                            icp.set_input_source(frame.source_sample.clone());
                            icp.set_input_target(target.clone());
                            icp.set_transformation_matrix(seed_t);
                            let r = icp.align()?;
                            let better = match &best {
                                None => true,
                                Some(b) => {
                                    r.has_converged()
                                        && (!b.has_converged() || r.rmse < b.rmse)
                                }
                            };
                            if better {
                                best = Some(r);
                            }
                        }
                        best.expect("at least one bootstrap attempt")
                    } else {
                        icp.set_input_source(frame.source_sample);
                        icp.set_input_target(target);
                        icp.set_transformation_matrix(prev_relative);
                        icp.align()?
                    };
                    let align_ms = t0.elapsed().as_secs_f64() * 1e3;
                    align_stats.record_ms(align_ms);

                    // T maps source (frame i) into target (frame i−1)
                    // coordinates — i.e. the relative motion.
                    let relative = res.transformation;
                    let pose = poses.last().unwrap().mul_mat(&relative);
                    poses.push(pose);
                    records.push(FrameRecord {
                        index: frame.index,
                        relative,
                        pose,
                        rmse: res.rmse,
                        iterations: res.iterations,
                        stop: res.stop,
                        align_ms,
                    });
                    prev_relative = if res.has_converged() {
                        relative
                    } else {
                        Mat4::IDENTITY
                    };
                    prev_full = Some(frame.full);
                }
            }
        }

        Ok(OdometryResult {
            records,
            poses,
            align_stats,
            starvation_ms,
        })
    })
}

// ---------------------------------------------------------------------------
// Multi-lane batched registration engine
// ---------------------------------------------------------------------------

/// One independent frame-pair registration request.
pub struct RegistrationJob {
    /// Caller-assigned id; results are returned sorted by it, so ids
    /// define the deterministic output order regardless of lane count.
    pub id: u64,
    /// Client/stream the job belongs to (multi-client bookkeeping).
    pub stream: usize,
    /// Target identity for affinity scheduling: jobs with equal keys are
    /// routed to the lane whose backend already holds that target, so
    /// the resident-target cache hits across jobs. [`Self::new`] derives
    /// it from the target's content fingerprint; [`Self::new_keyed`]
    /// takes it from the caller (e.g. one shared map, hashed once).
    pub target_key: u64,
    pub source: PointCloud,
    /// Shared so map-reuse workloads submit M jobs against one cloud
    /// without M copies.
    pub target: Arc<PointCloud>,
    /// Initial transform (`setTransformationMatrix`).
    pub initial: Mat4,
    submitted: Instant,
}

impl RegistrationJob {
    pub fn new(
        id: u64,
        stream: usize,
        source: PointCloud,
        target: impl Into<Arc<PointCloud>>,
        initial: Mat4,
    ) -> Self {
        let target = target.into();
        Self {
            id,
            stream,
            target_key: target.fingerprint(),
            source,
            target,
            initial,
            submitted: Instant::now(),
        }
    }

    /// Like [`Self::new`] with a caller-supplied affinity key — skips
    /// hashing the target, for callers that build many jobs against one
    /// shared cloud (see [`localization_jobs`]).
    pub fn new_keyed(
        id: u64,
        stream: usize,
        source: PointCloud,
        target: impl Into<Arc<PointCloud>>,
        target_key: u64,
        initial: Mat4,
    ) -> Self {
        Self {
            id,
            stream,
            target_key,
            source,
            target: target.into(),
            initial,
            submitted: Instant::now(),
        }
    }

    /// Reset the submission timestamp — call immediately before sending
    /// a job that was built ahead of time, so the reported queue wait
    /// measures time *queued*, not time since construction.
    pub fn mark_submitted(&mut self) {
        self.submitted = Instant::now();
    }
}

/// Result of one lane-pool job.
#[derive(Clone, Debug)]
pub struct RegistrationOutcome {
    pub id: u64,
    pub stream: usize,
    /// Which lane served the job (scheduling detail — the transform must
    /// not depend on it; see the `lane_engine` determinism test).
    pub lane: usize,
    pub transform: Mat4,
    pub rmse: f64,
    pub iterations: u32,
    pub stop: StopReason,
    /// Time from submission to a lane picking the job up.
    pub queue_wait_ms: f64,
    /// Time inside `align()` on the lane.
    pub service_ms: f64,
    /// `Some(message)` when the alignment itself errored. A failed job
    /// is *contained*: its lane keeps draining, the outcome carries the
    /// job's initial transform and NaN rmse, and the rest of the batch
    /// is unaffected.
    pub error: Option<String>,
}

impl RegistrationOutcome {
    /// Did the alignment error (as opposed to merely not converging)?
    pub fn is_failed(&self) -> bool {
        self.error.is_some()
    }
}

/// ICP parameters shared by every lane (per-job overrides travel in the
/// job's `initial` transform only, to keep lane-count invariance).
#[derive(Clone, Copy, Debug)]
pub struct LaneIcpConfig {
    pub max_correspondence_distance: f32,
    pub max_iteration_count: u32,
    pub transformation_epsilon: f64,
}

impl Default for LaneIcpConfig {
    fn default() -> Self {
        Self {
            max_correspondence_distance: 1.0,
            max_iteration_count: 50,
            transformation_epsilon: 1e-5,
        }
    }
}

/// Per-lane execution statistics.
#[derive(Clone, Debug, Default)]
pub struct LaneStats {
    pub lane: usize,
    pub jobs: usize,
    /// Jobs whose alignment errored (contained per-job, see
    /// [`RegistrationOutcome::error`]); included in `jobs`.
    pub failed: usize,
    /// Targets still resident on this lane's backend at the end of the
    /// run (≤ its residency slot count).
    pub resident_targets: usize,
    /// Service latency samples of this lane.
    pub service: TimingStats,
    /// Queue-wait samples of the jobs this lane served (scheduler
    /// pressure as seen from this lane).
    pub queue_wait: TimingStats,
    /// Cumulative backend ("device") time of this lane.
    pub device_ms: f64,
    /// Target uploads this lane's backend actually performed.
    pub target_uploads: usize,
    /// Alignments that found their target already resident (affinity
    /// scheduling + unchanged target = cache hit).
    pub target_hits: usize,
    /// Resident targets this lane's backend LRU-evicted — with pool-wide
    /// residency coordination this stays 0 while any lane has free
    /// slots.
    pub target_evictions: usize,
}

/// Aggregate report of one lane-pool run.
#[derive(Debug)]
pub struct LaneReport {
    /// All outcomes, sorted by job id (deterministic order).
    pub outcomes: Vec<RegistrationOutcome>,
    /// Per-lane statistics, sorted by lane index.
    pub lanes: Vec<LaneStats>,
    /// Per-lane service stats merged into one aggregate distribution.
    pub service: TimingStats,
    /// Queue-wait distribution across all jobs (backpressure signal).
    pub queue_wait: TimingStats,
    pub wall_ms: f64,
}

impl LaneReport {
    /// Aggregate throughput over the whole run.
    pub fn jobs_per_s(&self) -> f64 {
        if self.wall_ms <= 0.0 {
            0.0
        } else {
            self.outcomes.len() as f64 / (self.wall_ms / 1e3)
        }
    }

    /// Render the per-lane breakdown — shared by the `fpps batch` /
    /// `fpps localize` subcommands and the registration-server example.
    /// Queue-wait and jobs/s make scheduler pressure visible: a lane
    /// whose wait grows while its jobs/s stalls is the backpressure
    /// bottleneck.
    pub fn lane_table(&self, title: &str) -> crate::report::Table {
        let mut t = crate::report::Table::new(title).header(&[
            "lane",
            "jobs",
            "fail",
            "mean (ms)",
            "p99 (ms)",
            "wait (ms)",
            "jobs/s",
            "tgt up/hit/ev",
            "resident",
            "device (ms)",
        ]);
        for l in &self.lanes {
            let jobs_per_s = if self.wall_ms > 0.0 {
                l.jobs as f64 / (self.wall_ms / 1e3)
            } else {
                0.0
            };
            t.row(vec![
                l.lane.to_string(),
                l.jobs.to_string(),
                l.failed.to_string(),
                format!("{:.1}", l.service.mean_ms()),
                format!("{:.1}", l.service.percentile_ms(99.0)),
                format!("{:.1}", l.queue_wait.mean_ms()),
                format!("{jobs_per_s:.2}"),
                format!(
                    "{}/{}/{}",
                    l.target_uploads, l.target_hits, l.target_evictions
                ),
                l.resident_targets.to_string(),
                format!("{:.1}", l.device_ms),
            ]);
        }
        t
    }

    /// Total contained job failures across all lanes.
    pub fn failed_jobs(&self) -> usize {
        self.lanes.iter().map(|l| l.failed).sum()
    }
}

/// Steal threshold: a warm lane keeps its key's jobs until it has this
/// many in flight *and* another lane sits idle. One in-flight job is
/// not a backlog — it drains sooner than a redundant target upload
/// pays off — so stealing starts at a queue two deep.
pub const STEAL_BACKLOG: usize = 2;

/// Per-job completion feedback a lane reports to the dispatcher — the
/// ground truth that corrects the [`AffinityRouter`]'s warm-set mirror
/// (see [`AffinityRouter::completed`]).
#[derive(Clone, Copy, Debug)]
pub struct JobFeedback {
    /// Lane that served the job.
    pub lane: usize,
    /// The job's target key.
    pub key: u64,
    /// The backend actually uploaded the target during this job (the
    /// lane diffs its upload counter around `align()`), so the lane now
    /// genuinely holds the key — even if the alignment later errored.
    pub uploaded: bool,
    /// The job re-activated an already-resident target (the cache-hit
    /// counter advanced): the key is device-resident and was just
    /// MRU-touched there — even if a later stage of the alignment
    /// failed, which is why this cannot be inferred from `ok` alone.
    pub hit: bool,
    /// The alignment returned `Ok`.
    pub ok: bool,
}

/// Pool-wide residency coordinator — the routing core of
/// [`dispatch_by_affinity`]: a pure, deterministic state machine over
/// per-lane **warm key sets** (the dispatcher-side mirror of each lane
/// backend's LRU resident-target set) plus a pending-job load estimate
/// and per-lane **slot occupancy** (free vs. warm). Separated from the
/// channel plumbing so the scheduling policy is unit-testable without
/// threads, and public so the property suite can drive it against real
/// backends.
///
/// Invariants the channel loop must uphold:
/// * routing state is committed via [`Self::committed`] only **after** a
///   send succeeds (a failed `try_send` must not poison the warm sets);
/// * every served job reports [`JobFeedback`] through
///   [`Self::completed`], which *corrects* the optimistically committed
///   mirror — replaying uploads and cache hits onto the confirmed
///   resident mirror, and un-warming a key whose job failed before
///   touching residency. The corrected warm sets stay a subset of each
///   backend's [`KernelBackend::resident_epochs`] keys
///   (property-tested).
pub struct AffinityRouter {
    /// Per-lane warm target keys, LRU first / MRU last, each bounded by
    /// `slots` — uploads past capacity evict exactly like the backend.
    warm: Vec<Vec<u64>>,
    /// Keys *confirmed* device-resident per lane (LRU first), updated
    /// only by [`JobFeedback`] — the exact mirror of each backend's
    /// resident set as of its last processed completion. Distinct from
    /// the warm set: `warm` also carries optimistic, not-yet-completed
    /// commits (and drops keys conservatively on failure), while this
    /// list replays the device's own upload/activate transitions, so a
    /// device slot filled by a key the warm mirror later forgot still
    /// counts as occupied.
    resident: Vec<Vec<u64>>,
    /// Jobs sent to each lane minus completions seen.
    pending: Vec<usize>,
    /// Residency slots mirrored per lane.
    slots: usize,
    /// Round-robin cursor for tie-breaking and spill.
    rr: usize,
}

impl AffinityRouter {
    pub fn new(lanes: usize, slots: usize) -> Self {
        Self {
            warm: vec![Vec::new(); lanes],
            resident: vec![Vec::new(); lanes],
            pending: vec![0; lanes],
            slots: slots.max(1),
            rr: 0,
        }
    }

    pub fn lanes(&self) -> usize {
        self.pending.len()
    }

    /// Jobs routed to `lane` and not yet completed.
    pub fn pending(&self, lane: usize) -> usize {
        self.pending[lane]
    }

    /// The mirror's warm keys of `lane`, LRU first / MRU last.
    pub fn warm_keys(&self, lane: usize) -> &[u64] {
        &self.warm[lane]
    }

    /// Does the mirror say `lane` has an unoccupied residency slot — a
    /// place a cold target can land without evicting anything? Uses the
    /// larger of the optimistic warm count (committed, not yet
    /// completed) and the confirmed resident count (a slot filled by a
    /// key the warm mirror later forgot is still filled).
    pub fn has_free_slot(&self, lane: usize) -> bool {
        self.warm[lane].len().max(self.resident[lane].len()) < self.slots
    }

    /// Every lane warm for `key` — after a steal there can be several —
    /// least-loaded first (ties by lane index).
    pub fn warm_lanes(&self, key: u64) -> Vec<usize> {
        let mut v: Vec<usize> = (0..self.lanes())
            .filter(|&l| self.warm[l].contains(&key))
            .collect();
        v.sort_by_key(|&l| self.pending[l]); // stable sort keeps index order on ties
        v
    }

    /// Routing decision, in priority order:
    /// 1. **warm hit** — the least-loaded warm lane, as long as its
    ///    backlog stays under [`STEAL_BACKLOG`];
    /// 2. **steal** — every warm lane is backlogged and a lane sits
    ///    idle: the idle lane (free-slot lanes preferred) pays one extra
    ///    upload rather than serializing a same-target batch;
    /// 3. the least-loaded warm lane when nobody is idle;
    /// 4. **free slot** — a cold key goes to the least-loaded lane with
    ///    an unoccupied residency slot: filling free pool capacity
    ///    always beats evicting a warm lane's LRU key;
    /// 5. `None` — cold key, every slot on every lane occupied: the
    ///    caller spills by load (an eviction is inevitable).
    pub fn first_choice(&self, key: u64) -> Option<usize> {
        let warm = self.warm_lanes(key);
        if let Some(&best) = warm.first() {
            if self.pending[best] < STEAL_BACKLOG {
                return Some(best);
            }
            let idle = (0..self.lanes())
                .filter(|&l| self.pending[l] == 0)
                .min_by_key(|&l| !self.has_free_slot(l));
            if let Some(idle) = idle {
                return Some(idle);
            }
            return Some(best);
        }
        (0..self.lanes())
            .filter(|&l| self.has_free_slot(l))
            .min_by_key(|&l| self.pending[l])
    }

    /// Spill order for non-blocking attempts after [`Self::first_choice`]
    /// found its queue full: everyone except the already-tried lane,
    /// least-loaded first (a cold key must not queue behind a deep
    /// backlog just because a lane's cache is fresh), free-slot lanes
    /// before evicting ones at equal load, rotation order breaking the
    /// remaining ties.
    pub fn spill_order(&self, exclude: Option<usize>) -> Vec<usize> {
        let lanes = self.lanes();
        let mut order: Vec<usize> = (0..lanes)
            .map(|i| (self.rr + i) % lanes)
            .filter(|&l| Some(l) != exclude)
            .collect();
        order.sort_by_key(|&l| (self.pending[l], !self.has_free_slot(l)));
        order
    }

    /// Lane to block on when every queue is full: the least-loaded warm
    /// lane (keeps the cache hot), else the shortest queue — free-slot
    /// lanes first at equal load, rotation order on remaining ties —
    /// never a blind round-robin pick past a shorter queue.
    pub fn blocking_choice(&self, key: u64) -> usize {
        if let Some(&l) = self.warm_lanes(key).first() {
            return l;
        }
        let lanes = self.lanes();
        (0..lanes)
            .map(|i| (self.rr + i) % lanes)
            .min_by_key(|&l| (self.pending[l], !self.has_free_slot(l)))
            .unwrap_or(0)
    }

    /// Touch `key` MRU on `lane`'s mirror, evicting past the slot count
    /// exactly like the backend's LRU set.
    fn touch_warm(&mut self, lane: usize, key: u64) {
        let w = &mut self.warm[lane];
        if let Some(i) = w.iter().position(|&k| k == key) {
            w.remove(i);
        }
        w.push(key);
        while w.len() > self.slots {
            w.remove(0);
        }
    }

    /// A job with `key` was *successfully* sent to `lane`: bump its
    /// load, optimistically mark the key warm (MRU — so back-to-back
    /// same-key jobs keep their affinity before the first completes),
    /// advance the round-robin cursor. The optimism is corrected by
    /// [`Self::completed`] once the job's real outcome is known.
    pub fn committed(&mut self, lane: usize, key: u64) {
        self.pending[lane] += 1;
        self.touch_warm(lane, key);
        self.rr = (lane + 1) % self.lanes();
    }

    /// Replay a confirmed device transition for `key` on `lane`'s
    /// resident mirror — insert/touch MRU, and on capacity pressure
    /// evict the resident LRU exactly like the device did, dropping the
    /// evicted key from the warm mirror too (it is no longer on the
    /// card, whatever the optimistic commits said).
    fn confirm_resident(&mut self, lane: usize, key: u64) {
        let r = &mut self.resident[lane];
        if let Some(i) = r.iter().position(|&k| k == key) {
            r.remove(i);
        }
        r.push(key);
        while self.resident[lane].len() > self.slots {
            let evicted = self.resident[lane].remove(0);
            self.warm[lane].retain(|&k| k != evicted);
        }
        self.touch_warm(lane, key);
    }

    /// Apply one job's [`JobFeedback`]: drop the lane's load estimate,
    /// then correct the mirror from the ground truth instead of keeping
    /// the commit-time guess:
    ///
    /// * **uploaded** (even on a failed alignment — the device holds
    ///   the target regardless) or **cache hit** (the key was resident
    ///   and just MRU-touched, even if a later stage of the job
    ///   failed): replay the transition on the confirmed resident
    ///   mirror, including the device's own LRU eviction when an
    ///   upload ran at capacity — so the mirror never retains a key
    ///   the device dropped.
    /// * **failed without touching residency** (neither uploaded nor
    ///   hit): un-warm the key the optimistic commit guessed — the
    ///   backend never gained it — while leaving the confirmed
    ///   resident set untouched (failure changes no device slot).
    pub fn completed(&mut self, fb: JobFeedback) {
        if fb.lane >= self.lanes() {
            return;
        }
        self.pending[fb.lane] = self.pending[fb.lane].saturating_sub(1);
        if fb.uploaded || fb.hit {
            self.confirm_resident(fb.lane, fb.key);
        } else if !fb.ok {
            self.warm[fb.lane].retain(|&k| k != fb.key);
        }
    }
}

/// Route jobs from the shared intake queue to per-lane queues through
/// the pool-wide residency coordinator ([`AffinityRouter`]): warm keys
/// keep their lane while it keeps up, cold keys fill **free residency
/// slots** anywhere in the pool before any warm lane is made to evict,
/// and only when every slot is occupied does a cold key spill by load.
/// `done_rx` carries per-job [`JobFeedback`], giving the dispatcher
/// both its per-lane load estimate and the ground truth that corrects
/// the warm-set mirror (failed uploads un-warm) without locking.
/// Routing can never change numerics: every job is an independent
/// alignment, so `lanes = 1` and `lanes = K` stay bit-identical
/// regardless of placement.
fn dispatch_by_affinity(
    rx: Receiver<RegistrationJob>,
    lane_txs: Vec<SyncSender<RegistrationJob>>,
    done_rx: Receiver<JobFeedback>,
    slots_rx: Receiver<usize>,
) {
    let lanes = lane_txs.len();
    // Mirror the *actual* backends, not an assumed default: every lane
    // reports its backend's residency slot count once it exists (a lane
    // that fails to start just drops its sender). The most conservative
    // (minimum) count drives the warm sets — over-estimating residency
    // would route jobs to lanes whose backend already evicted the key.
    let mut slots: Option<usize> = None;
    for _ in 0..lanes {
        match slots_rx.recv() {
            Ok(s) => slots = Some(slots.map_or(s, |m| m.min(s))),
            Err(_) => break,
        }
    }
    let mut router = AffinityRouter::new(lanes, slots.unwrap_or(1));
    'jobs: for mut job in rx.iter() {
        while let Ok(fb) = done_rx.try_recv() {
            router.completed(fb);
        }
        let key = job.target_key;
        let mut tried = None;
        if let Some(l) = router.first_choice(key) {
            match lane_txs[l].try_send(job) {
                Ok(()) => {
                    router.committed(l, key);
                    continue 'jobs;
                }
                Err(TrySendError::Full(j)) => {
                    job = j;
                    tried = Some(l); // don't re-attempt the full queue
                }
                Err(TrySendError::Disconnected(_)) => return, // pool shutting down
            }
        }
        for l in router.spill_order(tried) {
            match lane_txs[l].try_send(job) {
                Ok(()) => {
                    router.committed(l, key);
                    continue 'jobs;
                }
                Err(TrySendError::Full(j)) => job = j,
                Err(TrySendError::Disconnected(_)) => return,
            }
        }
        // Every queue is full: drain any fresh completions, then block
        // on the best lane. Routing state is committed only once the
        // send actually lands.
        while let Ok(fb) = done_rx.try_recv() {
            router.completed(fb);
        }
        let l = router.blocking_choice(key);
        if lane_txs[l].send(job).is_err() {
            return;
        }
        router.committed(l, key);
    }
}

/// Run a pool of `lanes` worker lanes, each with its own bounded queue,
/// fed by a target-affinity dispatcher (see `dispatch_by_affinity`).
///
/// * `make_backend(lane)` is called **on** each lane thread, so backends
///   never cross threads and need not be `Send`;
/// * `produce(tx)` runs on its own thread and feeds the intake queue —
///   it may clone the sender and fan out to per-client producer threads
///   (see `examples/registration_server.rs`). A `send` error means the
///   pool is shutting down; treat it as a stop signal, not a failure.
///
/// Each job is an independent alignment, so the mapping of jobs to lanes
/// cannot change any transform: `lanes = 1` and `lanes = K` produce
/// bit-identical outcomes for a deterministic backend.
pub fn run_lane_pool<B, F, P>(
    lanes: usize,
    queue_depth: usize,
    icp_cfg: LaneIcpConfig,
    make_backend: F,
    produce: P,
) -> Result<LaneReport>
where
    B: KernelBackend,
    F: Fn(usize) -> Result<B> + Sync,
    P: FnOnce(SyncSender<RegistrationJob>) -> Result<()> + Send,
{
    let lanes = lanes.max(1);
    let depth = queue_depth.max(1);
    let (job_tx, job_rx) = sync_channel::<RegistrationJob>(depth);
    let mut lane_txs = Vec::with_capacity(lanes);
    let mut lane_rxs = Vec::with_capacity(lanes);
    for _ in 0..lanes {
        let (tx, rx) = sync_channel::<RegistrationJob>(depth);
        lane_txs.push(tx);
        lane_rxs.push(rx);
    }
    let (out_tx, out_rx) = channel::<RegistrationOutcome>();
    let (lane_tx, lane_rx) = channel::<LaneStats>();
    let (done_tx, done_rx) = channel::<JobFeedback>();
    let (slots_tx, slots_rx) = channel::<usize>();
    let t0 = Instant::now();

    std::thread::scope(|scope| -> Result<()> {
        let producer = scope.spawn(move || produce(job_tx));
        let dispatcher =
            scope.spawn(move || dispatch_by_affinity(job_rx, lane_txs, done_rx, slots_rx));
        let mut workers = Vec::with_capacity(lanes);
        for (lane, job_rx) in lane_rxs.into_iter().enumerate() {
            let out_tx = out_tx.clone();
            let lane_tx = lane_tx.clone();
            let done_tx = done_tx.clone();
            let slots_tx = slots_tx.clone();
            let make_backend = &make_backend;
            workers.push(scope.spawn(move || -> Result<()> {
                let backend = make_backend(lane)
                    .with_context(|| format!("create backend for lane {lane}"))?;
                let mut icp = FppsIcp::with_backend(backend);
                // Tell the dispatcher how much residency this lane
                // really has, so its warm-set mirror matches the device.
                slots_tx.send(icp.backend().residency_slots()).ok();
                drop(slots_tx);
                icp.set_max_correspondence_distance(icp_cfg.max_correspondence_distance)
                    .set_max_iteration_count(icp_cfg.max_iteration_count)
                    .set_transformation_epsilon(icp_cfg.transformation_epsilon);
                let mut stats = LaneStats {
                    lane,
                    ..Default::default()
                };
                // Own queue, no lock: the dispatcher already routed.
                for job in job_rx.iter() {
                    let queue_wait_ms = job.submitted.elapsed().as_secs_f64() * 1e3;
                    let (id, stream, initial, key) =
                        (job.id, job.stream, job.initial, job.target_key);
                    // Diffing the upload/hit counters around align()
                    // tells the dispatcher what THIS job did to the
                    // backend's residency — the ground truth of the
                    // mirror-correcting feedback protocol.
                    let (uploads_before, hits_before) = icp.target_cache_stats();
                    icp.set_input_source(job.source);
                    icp.set_input_target(job.target);
                    icp.set_transformation_matrix(initial);
                    let t_align = Instant::now();
                    // A failing job must not take its lane (and with it
                    // the whole pool) down: contain the error in the
                    // outcome and keep draining the queue.
                    let outcome = match icp.align() {
                        Ok(res) => RegistrationOutcome {
                            id,
                            stream,
                            lane,
                            transform: res.transformation,
                            rmse: res.rmse,
                            iterations: res.iterations,
                            stop: res.stop,
                            queue_wait_ms,
                            service_ms: t_align.elapsed().as_secs_f64() * 1e3,
                            error: None,
                        },
                        Err(e) => {
                            stats.failed += 1;
                            RegistrationOutcome {
                                id,
                                stream,
                                lane,
                                transform: initial,
                                rmse: f64::NAN,
                                iterations: 0,
                                stop: StopReason::Failed,
                                queue_wait_ms,
                                service_ms: t_align.elapsed().as_secs_f64() * 1e3,
                                error: Some(format!("job {id} on lane {lane}: {e:#}")),
                            }
                        }
                    };
                    stats.jobs += 1;
                    stats.service.record_ms(outcome.service_ms);
                    stats.queue_wait.record_ms(queue_wait_ms);
                    let ok = !outcome.is_failed();
                    let (uploads_after, hits_after) = icp.target_cache_stats();
                    out_tx.send(outcome).ok();
                    done_tx
                        .send(JobFeedback {
                            lane,
                            key,
                            uploaded: uploads_after > uploads_before,
                            hit: hits_after > hits_before,
                            ok,
                        })
                        .ok();
                }
                stats.device_ms = icp.backend().device_time().as_secs_f64() * 1e3;
                let (uploads, hits) = icp.target_cache_stats();
                stats.target_uploads = uploads as usize;
                stats.target_hits = hits as usize;
                stats.resident_targets = icp.backend().resident_epochs().len();
                stats.target_evictions = icp.backend().target_evictions() as usize;
                lane_tx.send(stats).ok();
                Ok(())
            }));
        }
        // Drop the originals so the collection channels close when the
        // last lane finishes (and the dispatcher's slot wait cannot hang
        // on lanes that never started).
        drop(out_tx);
        drop(lane_tx);
        drop(done_tx);
        drop(slots_tx);

        match producer.join() {
            Ok(r) => r.context("job producer")?,
            Err(_) => bail!("job producer panicked"),
        }
        if dispatcher.join().is_err() {
            bail!("affinity dispatcher panicked");
        }
        for w in workers {
            match w.join() {
                Ok(r) => r?,
                Err(_) => bail!("lane worker panicked"),
            }
        }
        Ok(())
    })?;

    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let mut outcomes: Vec<RegistrationOutcome> = out_rx.into_iter().collect();
    outcomes.sort_by_key(|o| o.id);
    let mut lane_stats: Vec<LaneStats> = lane_rx.into_iter().collect();
    lane_stats.sort_by_key(|s| s.lane);

    // Merge the per-lane distributions into the aggregate report.
    let mut service = TimingStats::new();
    for l in &lane_stats {
        service.merge(&l.service);
    }
    let mut queue_wait = TimingStats::new();
    for o in &outcomes {
        queue_wait.record_ms(o.queue_wait_ms);
    }

    Ok(LaneReport {
        outcomes,
        lanes: lane_stats,
        service,
        queue_wait,
        wall_ms,
    })
}

/// Convenience wrapper: push a prebuilt batch of jobs through the pool.
pub fn run_registration_batch<B, F>(
    jobs: Vec<RegistrationJob>,
    lanes: usize,
    queue_depth: usize,
    icp_cfg: LaneIcpConfig,
    make_backend: F,
) -> Result<LaneReport>
where
    B: KernelBackend,
    F: Fn(usize) -> Result<B> + Sync,
{
    let expected = jobs.len();
    let report = run_lane_pool(lanes, queue_depth, icp_cfg, make_backend, move |tx| {
        for mut job in jobs {
            job.mark_submitted(); // queue wait starts at send, not build
            if tx.send(job).is_err() {
                break; // pool shut down early
            }
        }
        Ok(())
    })?;
    if report.outcomes.len() != expected {
        return Err(anyhow!(
            "lane pool returned {} outcomes for {} jobs",
            report.outcomes.len(),
            expected
        ));
    }
    Ok(report)
}

/// Build frame-pair jobs (frame i aligned onto frame i−1) from a
/// synthetic sequence — the shared job generator for the multi-client
/// example, the `fpps batch` subcommand and the lane-scaling bench.
pub fn sequence_pair_jobs(
    seq: &Sequence,
    frames: usize,
    stream: usize,
    cfg: &PipelineConfig,
) -> Result<Vec<RegistrationJob>> {
    let frames = frames.min(seq.len());
    let mut jobs = Vec::new();
    let mut prev: Option<PointCloud> = None;
    for i in 0..frames {
        let cloud = preprocess(&seq.frame(i)?, cfg);
        let mut rng = Pcg32::substream(cfg.seed, i as u64);
        let sample = cloud.random_sample(cfg.source_sample, &mut rng);
        let full = fit_to_capacity(cloud, cfg.target_capacity, cfg.seed);
        if let Some(target) = prev.take() {
            jobs.push(RegistrationJob::new(
                (stream as u64) << 32 | i as u64,
                stream,
                sample,
                target,
                Mat4::IDENTITY,
            ));
        }
        prev = Some(full);
    }
    Ok(jobs)
}

// ---------------------------------------------------------------------------
// Scan-to-map localization (resident-target scenario)
// ---------------------------------------------------------------------------

/// Prebuilt scan-to-map localization workload: one shared map, M scan
/// jobs against it, plus the ground-truth poses to score against.
pub struct LocalizationWorkload {
    /// The map every scan aligns against (frame-0 coordinates). All jobs
    /// share this one `Arc` and one target key, so the lane pool keeps
    /// it device-resident.
    pub map: Arc<PointCloud>,
    pub jobs: Vec<RegistrationJob>,
    /// Ground-truth map←sensor poses, indexed like `jobs`.
    pub truth: Vec<Mat4>,
    /// What admission decided for the map (see [`admit_map`]).
    pub admission: AdmissionDecision,
}

/// Build a localization workload from a synthetic sequence: the map is
/// the union of all preprocessed scans placed into frame-0 coordinates
/// by ground truth (then capacity-bounded), and each scan becomes a job
/// whose prior is the *previous* frame's true pose — the "last known
/// pose" a localization stack would start from.
pub fn localization_jobs(
    seq: &Sequence,
    scans: usize,
    cfg: &PipelineConfig,
) -> Result<LocalizationWorkload> {
    let scans = scans.min(seq.len());
    if scans == 0 {
        bail!("localization needs at least one scan");
    }
    let origin = seq.ground_truth[0].inverse_rigid();
    let mut map = PointCloud::new();
    let mut sources = Vec::with_capacity(scans);
    let mut truth = Vec::with_capacity(scans);
    for i in 0..scans {
        let cloud = preprocess(&seq.frame(i)?, cfg);
        let pose = origin.mul_mat(&seq.ground_truth[i]); // map ← sensor_i
        let world = cloud.transformed(&pose);
        map.xyz.extend_from_slice(&world.xyz);
        let mut rng = Pcg32::substream(cfg.seed, i as u64);
        sources.push(cloud.random_sample(cfg.source_sample, &mut rng));
        truth.push(pose);
    }
    // Residency-aware admission replaces the old silent shrink: an
    // oversized map is rejected or explicitly downsampled per policy.
    let (map, admission) = admit_map(map, cfg)?;
    let map = Arc::new(map);
    let key = map.fingerprint(); // hash the shared map once, not per job

    let mut jobs = Vec::with_capacity(scans);
    for (i, source) in sources.into_iter().enumerate() {
        let prior = match i {
            0 => Mat4::IDENTITY,
            _ => truth[i - 1],
        };
        jobs.push(RegistrationJob::new_keyed(
            i as u64,
            0,
            source,
            Arc::clone(&map),
            key,
            prior,
        ));
    }
    Ok(LocalizationWorkload {
        map,
        jobs,
        truth,
        admission,
    })
}

/// Per-scan translation error vs. `truth` (m), in job order (the job id
/// indexes `truth`). Contained failures ([`RegistrationOutcome::error`])
/// score NaN so a failed job can never masquerade as an accurate
/// localization; [`mean_finite`] / [`max_finite`] skip them.
fn translation_errors_vs_truth(report: &LaneReport, truth: &[Mat4]) -> Vec<f64> {
    report
        .outcomes
        .iter()
        .map(|o| {
            if o.is_failed() {
                f64::NAN
            } else {
                let gt = truth[o.id as usize];
                (o.transform.translation() - gt.translation()).norm()
            }
        })
        .collect()
}

/// Mean over the finite entries (NaN marks contained failures); NaN when
/// nothing finite remains.
fn mean_finite(vals: &[f64]) -> f64 {
    let (mut sum, mut n) = (0.0f64, 0usize);
    for v in vals.iter().copied().filter(|v| v.is_finite()) {
        sum += v;
        n += 1;
    }
    if n == 0 {
        f64::NAN
    } else {
        sum / n as f64
    }
}

/// Max over the finite entries; NaN when nothing finite remains (an
/// all-failure run must not report a perfect 0.0 max error).
fn max_finite(vals: &[f64]) -> f64 {
    let mut max = f64::NAN;
    for v in vals.iter().copied().filter(|v| v.is_finite()) {
        max = if max.is_nan() { v } else { max.max(v) };
    }
    max
}

/// Result of a [`run_localization`] run.
#[derive(Debug)]
pub struct LocalizationResult {
    pub report: LaneReport,
    pub map_points: usize,
    /// Per-scan translation error vs. ground truth (m), in job order;
    /// NaN for contained failures.
    pub translation_errors: Vec<f64>,
    /// What admission decided for the map (see [`admit_map`]).
    pub admission: AdmissionDecision,
}

impl LocalizationResult {
    pub fn mean_translation_error(&self) -> f64 {
        mean_finite(&self.translation_errors)
    }

    pub fn max_translation_error(&self) -> f64 {
        max_finite(&self.translation_errors)
    }
}

/// Scan-to-map localization: align `scans` frames of `seq` against one
/// shared map over the lane pool. Every job carries the same target key,
/// so the affinity dispatcher keeps the map resident — the kd-tree
/// backend builds its index once for the whole run, and the amortized
/// upload cost drops to zero (see `benches/target_reuse.rs`).
pub fn run_localization<B, F>(
    seq: &Sequence,
    scans: usize,
    cfg: &PipelineConfig,
    lanes: usize,
    queue_depth: usize,
    icp_cfg: LaneIcpConfig,
    make_backend: F,
) -> Result<LocalizationResult>
where
    B: KernelBackend,
    F: Fn(usize) -> Result<B> + Sync,
{
    let workload = localization_jobs(seq, scans, cfg)?;
    let map_points = workload.map.len();
    let admission = workload.admission;
    let report = run_registration_batch(workload.jobs, lanes, queue_depth, icp_cfg, make_backend)?;
    let translation_errors = translation_errors_vs_truth(&report, &workload.truth);
    Ok(LocalizationResult {
        report,
        map_points,
        translation_errors,
        admission,
    })
}

// ---------------------------------------------------------------------------
// Tile-crossing localization (multi-target residency scenario)
// ---------------------------------------------------------------------------

/// Prebuilt tile-crossing localization workload: the trajectory is cut
/// into `tiles` contiguous submaps and the job stream *interleaves*
/// them — the submap ping-pong of a vehicle tracking along a tile
/// boundary. On a single-slot backend every job re-uploads (and, on the
/// kd-tree backend, rebuilds); with ≥ `tiles` residency slots each
/// submap uploads once per serving lane and every further job is a
/// cache hit (see `benches/tile_residency.rs`).
pub struct TiledLocalizationWorkload {
    /// One submap per tile (frame-0 coordinates), shared by its jobs.
    pub maps: Vec<Arc<PointCloud>>,
    /// Tile index of each job, in job-id order.
    pub tile_of_job: Vec<usize>,
    pub jobs: Vec<RegistrationJob>,
    /// Ground-truth map←sensor poses, indexed by job id.
    pub truth: Vec<Mat4>,
    /// Per-tile admission decisions, tile order (see [`admit_map`]).
    pub admissions: Vec<AdmissionDecision>,
}

/// Build a tile-crossing workload from a synthetic sequence: scans are
/// assigned to `tiles` contiguous trajectory segments, each segment's
/// union (placed into frame-0 coordinates by ground truth, then
/// capacity-bounded) becomes one submap, and jobs are emitted
/// round-robin across the tiles so consecutive jobs alternate submaps.
pub fn tiled_localization_jobs(
    seq: &Sequence,
    scans: usize,
    tiles: usize,
    cfg: &PipelineConfig,
) -> Result<TiledLocalizationWorkload> {
    let scans = scans.min(seq.len());
    if scans == 0 {
        bail!("localization needs at least one scan");
    }
    let tiles = tiles.clamp(1, scans);
    let tile_of_scan = |i: usize| (i * tiles) / scans;
    let origin = seq.ground_truth[0].inverse_rigid();
    let mut tile_clouds: Vec<PointCloud> = (0..tiles).map(|_| PointCloud::new()).collect();
    let mut sources: Vec<Option<PointCloud>> = Vec::with_capacity(scans);
    let mut poses = Vec::with_capacity(scans);
    for i in 0..scans {
        let cloud = preprocess(&seq.frame(i)?, cfg);
        let pose = origin.mul_mat(&seq.ground_truth[i]); // map ← sensor_i
        let world = cloud.transformed(&pose);
        tile_clouds[tile_of_scan(i)].xyz.extend_from_slice(&world.xyz);
        let mut rng = Pcg32::substream(cfg.seed, i as u64);
        sources.push(Some(cloud.random_sample(cfg.source_sample, &mut rng)));
        poses.push(pose);
    }
    // Each submap passes residency-aware admission on its own.
    let mut maps = Vec::with_capacity(tiles);
    let mut admissions = Vec::with_capacity(tiles);
    for c in tile_clouds {
        let (m, a) = admit_map(c, cfg)?;
        maps.push(Arc::new(m));
        admissions.push(a);
    }
    // Hash each shared submap once, not per job.
    let keys: Vec<u64> = maps.iter().map(|m| m.fingerprint()).collect();

    // Emission order: round-robin over the tiles (A,B,…,A,B,…), the
    // maximal-ping-pong stress an LRU residency set exists for.
    let mut by_tile: Vec<Vec<usize>> = vec![Vec::new(); tiles];
    for i in 0..scans {
        by_tile[tile_of_scan(i)].push(i);
    }
    let deepest = by_tile.iter().map(Vec::len).max().unwrap_or(0);
    let mut jobs = Vec::with_capacity(scans);
    let mut truth = Vec::with_capacity(scans);
    let mut tile_of_job = Vec::with_capacity(scans);
    for r in 0..deepest {
        for (t, scans_of_tile) in by_tile.iter().enumerate() {
            let Some(&i) = scans_of_tile.get(r) else {
                continue;
            };
            // "Last known pose" prior, as in [`localization_jobs`].
            let prior = if i == 0 { Mat4::IDENTITY } else { poses[i - 1] };
            jobs.push(RegistrationJob::new_keyed(
                jobs.len() as u64,
                t,
                sources[i].take().expect("each scan emitted once"),
                Arc::clone(&maps[t]),
                keys[t],
                prior,
            ));
            truth.push(poses[i]);
            tile_of_job.push(t);
        }
    }
    Ok(TiledLocalizationWorkload {
        maps,
        tile_of_job,
        jobs,
        truth,
        admissions,
    })
}

/// Result of a [`run_tiled_localization`] run.
#[derive(Debug)]
pub struct TiledLocalizationResult {
    pub report: LaneReport,
    /// Points per submap, tile order.
    pub map_points: Vec<usize>,
    /// Per-scan translation error vs. ground truth (m), in job order;
    /// NaN for contained failures.
    pub translation_errors: Vec<f64>,
    /// Per-tile admission decisions, tile order (see [`admit_map`]).
    pub admissions: Vec<AdmissionDecision>,
}

impl TiledLocalizationResult {
    pub fn mean_translation_error(&self) -> f64 {
        mean_finite(&self.translation_errors)
    }

    pub fn max_translation_error(&self) -> f64 {
        max_finite(&self.translation_errors)
    }
}

/// Tile-crossing localization over the lane pool: `scans` frames of
/// `seq` against `tiles` alternating submaps. With multi-target
/// residency the per-lane upload count is bounded by the tile count —
/// not the scan count — which `fpps localize --tiles` prints.
#[allow(clippy::too_many_arguments)]
pub fn run_tiled_localization<B, F>(
    seq: &Sequence,
    scans: usize,
    tiles: usize,
    cfg: &PipelineConfig,
    lanes: usize,
    queue_depth: usize,
    icp_cfg: LaneIcpConfig,
    make_backend: F,
) -> Result<TiledLocalizationResult>
where
    B: KernelBackend,
    F: Fn(usize) -> Result<B> + Sync,
{
    let workload = tiled_localization_jobs(seq, scans, tiles, cfg)?;
    let map_points = workload.maps.iter().map(|m| m.len()).collect();
    let admissions = workload.admissions.clone();
    let report = run_registration_batch(workload.jobs, lanes, queue_depth, icp_cfg, make_backend)?;
    let translation_errors = translation_errors_vs_truth(&report, &workload.truth);
    Ok(TiledLocalizationResult {
        report,
        map_points,
        translation_errors,
        admissions,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{lidar::LidarConfig, sequence_specs, Sequence};
    use crate::metrics::absolute_trajectory_error;

    fn tiny_sequence(frames: usize) -> Sequence {
        let spec = sequence_specs()[3].clone(); // residential: gentle
        Sequence::synthetic(spec, frames, 11, LidarConfig::tiny())
    }

    #[test]
    fn fit_to_capacity_shrinks() {
        let mut rng = Pcg32::new(1);
        let mut c = PointCloud::with_capacity(5000);
        for _ in 0..5000 {
            c.push([rng.range(-40.0, 40.0), rng.range(-40.0, 40.0), rng.range(0.0, 5.0)]);
        }
        let f = fit_to_capacity(c.clone(), 1000, 7);
        assert!(f.len() <= 1000);
        assert!(f.len() > 100, "over-shrunk to {}", f.len());
        // Under capacity → untouched.
        assert_eq!(fit_to_capacity(c.clone(), 10_000, 7).len(), c.len());
    }

    #[test]
    fn fit_to_capacity_fallback_respects_seed() {
        // Force the random-sample fallback with a cloud too spread out
        // for 12 voxel passes to tame, and check the pipeline seed
        // actually reaches it (a fixed internal seed made all fallback
        // samples identical regardless of cfg.seed).
        let mut rng = Pcg32::new(2);
        let mut c = PointCloud::with_capacity(4000);
        for _ in 0..4000 {
            c.push([
                rng.range(-4.0e6, 4.0e6),
                rng.range(-4.0e6, 4.0e6),
                rng.range(-4.0e6, 4.0e6),
            ]);
        }
        let a = fit_to_capacity(c.clone(), 100, 1);
        let b = fit_to_capacity(c.clone(), 100, 1);
        let d = fit_to_capacity(c.clone(), 100, 2);
        assert_eq!(a.len(), 100);
        assert_eq!(a.xyz, b.xyz, "same seed must reproduce the sample");
        assert_ne!(a.xyz, d.xyz, "different seeds must differ");
    }

    #[test]
    fn localization_workload_shares_one_target() {
        let seq = tiny_sequence(5);
        let cfg = PipelineConfig {
            source_sample: 256,
            target_capacity: 8192,
            ..Default::default()
        };
        let w = localization_jobs(&seq, 5, &cfg).unwrap();
        assert_eq!(w.jobs.len(), 5);
        assert_eq!(w.truth.len(), 5);
        let key = w.jobs[0].target_key;
        for j in &w.jobs {
            assert_eq!(j.target_key, key, "all scans share the map key");
            assert!(Arc::ptr_eq(&j.target, &w.map), "no map copies");
        }
        // First scan's prior is identity (it *is* the map origin).
        assert_eq!(w.jobs[0].initial.m, Mat4::IDENTITY.m);
    }

    #[test]
    fn localization_tracks_ground_truth() {
        let seq = tiny_sequence(5);
        let cfg = PipelineConfig {
            source_sample: 512,
            target_capacity: 8192,
            ..Default::default()
        };
        let res = run_localization(
            &seq,
            5,
            &cfg,
            2,
            8,
            LaneIcpConfig {
                max_iteration_count: 30,
                ..Default::default()
            },
            |_| Ok(crate::fpps_api::KdTreeCpuBackend::new()),
        )
        .unwrap();
        assert_eq!(res.translation_errors.len(), 5);
        assert!(
            res.mean_translation_error() < 0.3,
            "mean localization error {}",
            res.mean_translation_error()
        );
        assert!(res.map_points > 0);
        // Affinity + shared key: the map was uploaded by at most `lanes`
        // backends, never once per scan.
        let uploads: usize = res.report.lanes.iter().map(|l| l.target_uploads).sum();
        assert!(uploads <= 2, "{uploads} uploads for 5 same-map scans");
        let hits: usize = res.report.lanes.iter().map(|l| l.target_hits).sum();
        assert_eq!(uploads + hits, 5, "every job either uploads or hits");
    }

    #[test]
    fn odometry_runs_and_tracks() {
        let frames = 6;
        let seq = tiny_sequence(frames);
        let mut icp = FppsIcp::native_sim();
        icp.set_max_iteration_count(30);
        let cfg = PipelineConfig {
            source_sample: 1024,
            target_capacity: 8192,
            ..Default::default()
        };
        let res = run_odometry(&seq, frames, cfg, &mut icp).unwrap();
        assert_eq!(res.records.len(), frames - 1);
        assert_eq!(res.poses.len(), frames);
        // Ground truth relative to frame 0.
        let gt0 = seq.ground_truth[0];
        let gt_rel: Vec<Mat4> = seq
            .ground_truth
            .iter()
            .take(frames)
            .map(|p| gt0.inverse_rigid().mul_mat(p))
            .collect();
        let ate = absolute_trajectory_error(&res.poses, &gt_rel);
        assert!(ate < 0.6, "trajectory error too large: {ate}");
        assert!(res.align_stats.count() == frames - 1);
    }

    #[test]
    fn records_capture_convergence_info() {
        let frames = 4;
        let seq = tiny_sequence(frames);
        let mut icp = FppsIcp::native_sim();
        let res = run_odometry(&seq, frames, PipelineConfig {
            source_sample: 512,
            target_capacity: 4096,
            ..Default::default()
        }, &mut icp)
        .unwrap();
        for r in &res.records {
            assert!(r.iterations >= 1);
            assert!(r.align_ms > 0.0);
            assert!(r.rmse.is_finite());
        }
    }

    #[test]
    fn zero_and_one_frame_edge_cases() {
        let seq = tiny_sequence(2);
        let mut icp = FppsIcp::native_sim();
        let res = run_odometry(&seq, 1, PipelineConfig::default(), &mut icp).unwrap();
        assert!(res.records.is_empty());
        assert_eq!(res.poses.len(), 1);
    }

    // --- AffinityRouter: deterministic scheduling-policy harness ---

    /// Shorthand for completion feedback in the router tests.
    fn fb(lane: usize, key: u64, uploaded: bool, hit: bool, ok: bool) -> JobFeedback {
        JobFeedback {
            lane,
            key,
            uploaded,
            hit,
            ok,
        }
    }

    #[test]
    fn admission_policy_parses_and_displays() {
        assert_eq!("reject".parse::<AdmissionPolicy>().unwrap(), AdmissionPolicy::Reject);
        assert_eq!(
            "downsample".parse::<AdmissionPolicy>().unwrap(),
            AdmissionPolicy::DownsampleToFit
        );
        assert_eq!(AdmissionPolicy::default(), AdmissionPolicy::DownsampleToFit);
        assert!("silent".parse::<AdmissionPolicy>().is_err());
        assert_eq!(AdmissionPolicy::Reject.to_string(), "reject");
        assert_eq!(
            AdmissionPolicy::DownsampleToFit.to_string(),
            "downsample-to-fit"
        );
    }

    #[test]
    fn router_reuses_every_warm_lane_after_a_steal() {
        let mut r = AffinityRouter::new(2, 2);
        // Cold key A: both lanes have free slots — least-loaded wins
        // (tie → lane 0), no spill needed.
        assert_eq!(r.first_choice(0xA), Some(0));
        r.committed(0, 0xA);
        r.committed(0, 0xA); // backlog of 2 on the warm lane
        // Real backlog + idle lane 1 → steal to lane 1.
        assert_eq!(r.first_choice(0xA), Some(1));
        r.committed(1, 0xA);
        // Both lanes are now warm for A. Lane 1 drains first: the
        // dispatcher must see it as a warm candidate — the old
        // `position()` scan only ever found lane 0.
        r.completed(fb(1, 0xA, true, false, true));
        assert_eq!(r.warm_lanes(0xA), vec![1, 0]);
        assert_eq!(r.first_choice(0xA), Some(1), "least-loaded warm lane");
        // Nobody idle: still route to the least-loaded *warm* lane
        // rather than blocking round-robin.
        r.committed(1, 0xA); // pending: lane0=2, lane1=1
        assert_eq!(r.first_choice(0xA), Some(1));
    }

    #[test]
    fn router_steals_only_on_real_backlog() {
        let mut r = AffinityRouter::new(2, 2);
        r.committed(0, 0xA);
        // One in-flight job is NOT a backlog: the old router stole to
        // the idle lane here, paying a redundant target upload.
        assert_eq!(r.first_choice(0xA), Some(0), "no steal at pending 1");
        r.committed(0, 0xA);
        // Two deep with an idle lane → steal.
        assert_eq!(r.first_choice(0xA), Some(1));
        // No idle lane → stay on the least-loaded warm lane.
        r.committed(1, 0xB);
        assert_eq!(r.first_choice(0xA), Some(0));
    }

    #[test]
    fn router_routes_cold_keys_to_free_slots_before_evicting() {
        let mut r = AffinityRouter::new(2, 1);
        r.committed(0, 0xA);
        r.completed(fb(0, 0xA, true, false, true));
        // Cold key B: lane 0 is idle but its only slot is warm; lane 1
        // has the free slot — filling it beats evicting A.
        assert!(!r.has_free_slot(0));
        assert!(r.has_free_slot(1));
        assert_eq!(r.first_choice(0xB), Some(1));
        r.committed(1, 0xB);
        r.completed(fb(1, 0xB, true, false, true));
        // Every slot occupied → None: the channel loop spills by load
        // (an eviction is now inevitable).
        assert_eq!(r.first_choice(0xC), None);
        assert_eq!(r.warm_lanes(0xA), vec![0], "A untouched on its lane");
    }

    #[test]
    fn failed_upload_feedback_unwarms_the_mirror() {
        let mut r = AffinityRouter::new(2, 1);
        r.committed(0, 0xA);
        assert_eq!(r.warm_lanes(0xA), vec![0], "optimistic commit");
        // The job failed before its target upload: the backend never
        // gained A, so the mirror must not keep claiming it.
        r.completed(fb(0, 0xA, false, false, false));
        assert!(r.warm_lanes(0xA).is_empty(), "failed upload un-warms");
        assert!(r.has_free_slot(0), "slot freed for the next cold key");
        // A failed alignment whose upload DID land keeps the key warm —
        // the device holds the target regardless of the ICP error.
        r.committed(1, 0xB);
        r.completed(fb(1, 0xB, true, false, false));
        assert_eq!(r.warm_lanes(0xB), vec![1]);
        // A cache-hit completion confirms warmth.
        r.committed(1, 0xB);
        r.completed(fb(1, 0xB, false, true, true));
        assert_eq!(r.warm_lanes(0xB), vec![1]);
    }

    #[test]
    fn router_warm_sets_are_lru_bounded_like_the_backend() {
        let mut r = AffinityRouter::new(1, 2);
        r.committed(0, 0xA);
        r.committed(0, 0xB);
        assert_eq!(r.warm_lanes(0xA), vec![0]);
        // A third key evicts the LRU key (A), not the MRU one.
        r.committed(0, 0xC);
        assert!(r.warm_lanes(0xA).is_empty(), "A evicted");
        assert_eq!(r.warm_lanes(0xB), vec![0]);
        assert_eq!(r.warm_lanes(0xC), vec![0]);
        // Re-touching B keeps it MRU: D evicts C.
        r.committed(0, 0xB);
        r.committed(0, 0xD);
        assert!(r.warm_lanes(0xC).is_empty());
        assert_eq!(r.warm_lanes(0xB), vec![0]);
    }

    #[test]
    fn router_blocking_choice_prefers_warmth_then_shortest_queue() {
        let mut r = AffinityRouter::new(3, 2);
        r.committed(0, 0xA);
        r.committed(0, 0xA);
        r.committed(1, 0xB);
        // Key A: lane 0 is warm, so block there even though it is the
        // longest queue (the cache hit outweighs one queue slot).
        assert_eq!(r.blocking_choice(0xA), 0);
        // Cold key: shortest queue wins (lane 2 is empty) — the old
        // fall-through blocked on the round-robin cursor regardless.
        assert_eq!(r.blocking_choice(0xF), 2);
        // And among equals the rotation cursor breaks the tie.
        r.committed(2, 0xC); // pending now [2, 1, 1], rr = 0
        assert_eq!(r.blocking_choice(0xF), 1);
    }

    #[test]
    fn router_spill_orders_by_load_and_skips_the_tried_lane() {
        let mut r = AffinityRouter::new(3, 2);
        r.committed(1, 0xA); // pending [0,1,0]
        r.committed(2, 0xB);
        r.committed(2, 0xC); // pending [0,1,2]
        // Load first: a fresh (cache-empty) lane does not excuse a deep
        // backlog — the old order let a cold key queue behind lane 2
        // just because its cache was empty.
        assert_eq!(r.spill_order(None), vec![0, 1, 2]);
        // The lane whose queue already returned Full is skipped, not
        // re-attempted.
        assert_eq!(r.spill_order(Some(0)), vec![1, 2]);
        // At equal load, a free residency slot breaks the tie: spilling
        // where nothing needs evicting beats spilling onto a warm slot.
        let mut r = AffinityRouter::new(2, 1);
        r.committed(0, 0xA);
        r.committed(1, 0xB);
        r.completed(fb(0, 0xA, true, false, true)); // lane 0: idle, slot warm
        r.completed(fb(1, 0xB, false, false, false)); // lane 1: idle, slot free
        assert_eq!(r.spill_order(None), vec![1, 0]);
    }

    // --- Tile-crossing workload ---

    #[test]
    fn tiled_workload_interleaves_tiles_and_shares_submaps() {
        let seq = tiny_sequence(6);
        let cfg = PipelineConfig {
            source_sample: 256,
            target_capacity: 8192,
            ..Default::default()
        };
        let w = tiled_localization_jobs(&seq, 6, 2, &cfg).unwrap();
        assert_eq!(w.maps.len(), 2);
        assert_eq!(w.jobs.len(), 6);
        assert_eq!(w.truth.len(), 6);
        // Round-robin emission: consecutive jobs alternate tiles.
        assert_eq!(w.tile_of_job, vec![0, 1, 0, 1, 0, 1]);
        for (job, &t) in w.jobs.iter().zip(&w.tile_of_job) {
            assert_eq!(job.stream, t);
            assert!(Arc::ptr_eq(&job.target, &w.maps[t]), "submaps are shared");
            assert_eq!(job.target_key, w.maps[t].fingerprint());
        }
        // Ids are the emission order (deterministic outcome order).
        for (k, job) in w.jobs.iter().enumerate() {
            assert_eq!(job.id, k as u64);
        }
        // Two tiles → two distinct keys.
        assert_ne!(w.jobs[0].target_key, w.jobs[1].target_key);
        // Degenerate tile counts clamp instead of failing.
        assert_eq!(tiled_localization_jobs(&seq, 6, 0, &cfg).unwrap().maps.len(), 1);
        assert_eq!(tiled_localization_jobs(&seq, 6, 99, &cfg).unwrap().maps.len(), 6);
    }

    #[test]
    fn tiled_localization_tracks_ground_truth_with_bounded_uploads() {
        let seq = tiny_sequence(6);
        let cfg = PipelineConfig {
            source_sample: 512,
            target_capacity: 8192,
            ..Default::default()
        };
        let res = run_tiled_localization(
            &seq,
            6,
            2,
            &cfg,
            1,
            4,
            LaneIcpConfig {
                max_iteration_count: 30,
                ..Default::default()
            },
            |_| Ok(crate::fpps_api::KdTreeCpuBackend::new()),
        )
        .unwrap();
        assert_eq!(res.report.outcomes.len(), 6);
        assert_eq!(res.map_points.len(), 2);
        assert!(
            res.mean_translation_error() < 0.3,
            "mean tile-localization error {}",
            res.mean_translation_error()
        );
        // One lane, two submaps, A,B,A,B,… order: the LRU residency set
        // absorbs the ping-pong — exactly one upload per submap.
        let uploads: usize = res.report.lanes.iter().map(|l| l.target_uploads).sum();
        let hits: usize = res.report.lanes.iter().map(|l| l.target_hits).sum();
        assert_eq!(uploads, 2, "one upload per tile, not per scan");
        assert_eq!(uploads + hits, 6);
        assert_eq!(res.report.lanes[0].resident_targets, 2);
        assert_eq!(res.report.failed_jobs(), 0);
    }
}
