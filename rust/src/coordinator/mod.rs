//! Frame-stream coordinator — the host-side system layer of Fig. 2.
//!
//! The paper's host "is responsible for data transmission and invokes
//! kernel execution according to the instructions from APIs". At system
//! level that means keeping the accelerator fed: while frame i is being
//! aligned, frame i+1 is already being acquired and preprocessed
//! (sampled, padded). This module implements that as a two-stage
//! pipeline over std threads with bounded channels (backpressure), plus
//! the scan-to-scan odometry driver used by the end-to-end example and
//! the Table III / IV benches.
//!
//! On top of the single-stream odometry pipeline sits the **multi-lane
//! registration engine** ([`run_lane_pool`] / [`run_registration_batch`]):
//! K worker lanes, each owning its own [`KernelBackend`] instance, are
//! fed by a **pool-wide residency coordinator** ([`AffinityRouter`]) —
//! jobs sharing a target key route to a lane whose backend already
//! holds that target resident (no re-upload, no kd-tree rebuild), a
//! *cold* key routes to a lane with a **free residency slot** before any
//! warm lane is made to evict, and warm lanes are only stolen from once
//! they have a real backlog ([`STEAL_BACKLOG`] jobs deep) with another
//! lane idle. The coordinator mirrors each lane backend's LRU resident
//! set, and the mirror is **corrected, not guessed**: every job
//! completion reports [`JobFeedback`] `(lane, key, uploaded, hit, ok)`
//! back to the dispatcher, which replays actual uploads and cache hits
//! onto a confirmed resident mirror (including the device's own LRU
//! eviction) and *un-warms* a key whose job failed before ever touching
//! residency — so a poisoned job can never leave a phantom warm entry
//! steering later jobs to a cache that does not exist. The feedback
//! protocol extends across **lane restarts**: every [`JobFeedback`]
//! carries the lane's *generation* (bumped each time the lane's backend
//! is respawned), and when the dispatcher learns of a restart it bumps
//! its own generation counter and clears both the warm and the
//! confirmed-resident mirror for that lane — a freshly built backend
//! holds nothing, whatever earlier feedback confirmed. Feedback still
//! in flight from the previous backend (a *stale generation*) then only
//! settles the lane's load estimate; it must never resurrect warm keys
//! the restart just invalidated. A lane the watchdog declared wedged is
//! marked *down* (routing avoids it until it reports recovery) and its
//! queued jobs are drained back to the dispatcher and re-routed. Maps
//! that
//! cannot fit a residency slot at all are handled up front by
//! residency-aware admission ([`AdmissionPolicy`]: reject with a
//! structured [`AdmissionError`], or downsample-to-fit) instead of
//! silent shrinking. Per-job failures are contained in their
//! [`RegistrationOutcome`] instead of killing the lane. Per-lane
//! [`TimingStats`] merge into an aggregate [`LaneReport`]. This is how
//! related FPGA registration stacks treat the accelerator — a shared,
//! multi-client resource with batched dispatch and device-resident
//! reference clouds — and it is the scaling substrate every
//! multi-client scenario here builds on: the scan-to-map
//! [`run_localization`] scenario (M scans against one resident map) and
//! the tile-crossing [`run_tiled_localization`] scenario (submap
//! ping-pong across an LRU residency set).
//!
//! The pool is **supervised** ([`run_supervised_lane_pool`]): each job
//! may carry its own deadline and retry budget (with pool-wide defaults
//! from [`SupervisorConfig`]), transient align errors retry with
//! bounded exponential backoff, a watchdog thread cuts off jobs whose
//! deadline passes mid-flight — containing them as
//! [`StopReason::DeadlineExceeded`] outcomes and re-routing the wedged
//! lane's queued jobs — a panicked lane respawns its backend from the
//! factory (advancing down a failover tier ladder after repeated
//! restarts, see [`crate::fpps_api::FailoverChain`]), and the
//! restart/un-warm rules above keep the router's mirror truthful
//! through all of it.
//!
//! The lane **data plane is zero-copy** (see the README "Data plane"
//! section): per-lane queues are lock-free single-producer rings
//! ([`crate::pool::ring::SpscRing`]) carrying small job descriptors,
//! clouds travel by `Arc` (submission and retries re-stage the same
//! shared points), and each lane engine stages into recycled arena
//! buffers ([`crate::pool::BufferPool`], retention set by
//! [`LaneIcpConfig::pool_capacity`]) — so a warm lane serves a job
//! without heap allocation on the alignment hot path (enforced by
//! `tests/alloc_regression.rs`, measured by the `data_plane` bench).

use crate::dataset::Sequence;
use crate::fpps_api::{CancelToken, FppsIcp, KernelBackend};
use crate::icp::StopReason;
use crate::math::Mat4;
use crate::metrics::TimingStats;
use crate::pointcloud::PointCloud;
use crate::rng::Pcg32;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Preprocessed frame ready for alignment.
pub struct PreparedFrame {
    pub index: usize,
    /// Sampled source cloud (the paper's 4096-point sample).
    pub source_sample: PointCloud,
    /// Full cloud (becomes the next frame's target).
    pub full: PointCloud,
}

/// Pipeline configuration.
///
/// The preprocessing knobs implement the standard LiDAR-odometry front
/// end (range crop, ground removal, voxel grid) that PCL-based
/// registration pipelines run before ICP. Point-to-point scan-to-scan
/// ICP on raw ring-structured scans is identity-biased (ground rings
/// self-match; see DESIGN.md §3 "dataset realism"), so the front end is
/// not optional for odometry-quality tracking — though the Table III /
/// IV benches can disable pieces of it, as they compare CPU vs device
/// under *identical* preprocessing.
#[derive(Clone, Copy, Debug)]
pub struct PipelineConfig {
    /// Per-frame source sample size (paper: 4096).
    pub source_sample: usize,
    /// Target cap; clouds larger than this are voxel-downsampled to fit
    /// the device target buffer.
    pub target_capacity: usize,
    /// Channel depth between acquisition and alignment (double
    /// buffering = 2, like the device's ping-pong BRAM buffers).
    pub queue_depth: usize,
    pub seed: u64,
    /// Range crop (m); 0 disables.
    pub crop_range: f32,
    /// Drop points below this sensor-frame z (ground removal; the
    /// sensor sits ~1.73 m up, so −1.2 keeps everything ≥ ~0.5 m above
    /// the road). `f32::NEG_INFINITY` disables.
    pub ground_z_min: f32,
    /// Voxel-grid leaf applied to both clouds (m); 0 disables.
    pub voxel_leaf: f32,
    /// Multi-start bootstrap: number of forward-translation seeds tried
    /// on the first frame (and after tracking loss). 0 = identity only.
    pub bootstrap_seeds: usize,
    /// Spacing between bootstrap seeds along +x (m).
    pub bootstrap_step: f32,
    /// How maps whose footprint exceeds one residency slot
    /// (`target_capacity` points) are admitted (see [`admit_map`]).
    pub admission: AdmissionPolicy,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self {
            source_sample: 4096,
            target_capacity: 16_384,
            queue_depth: 2,
            seed: 7,
            crop_range: 40.0,
            ground_z_min: -1.2,
            voxel_leaf: 0.15,
            bootstrap_seeds: 9,
            bootstrap_step: 0.3,
            admission: AdmissionPolicy::DownsampleToFit,
        }
    }
}

impl PipelineConfig {
    /// Paper-parity preprocessing: no front end at all (raw clouds),
    /// as in the paper's "4096 points randomly sampled from the source".
    pub fn raw() -> Self {
        Self {
            crop_range: 0.0,
            ground_z_min: f32::NEG_INFINITY,
            voxel_leaf: 0.0,
            bootstrap_seeds: 0,
            ..Default::default()
        }
    }
}

/// Front-end preprocessing shared by source and target.
pub fn preprocess(cloud: &PointCloud, cfg: &PipelineConfig) -> PointCloud {
    let mut out = PointCloud::with_capacity(cloud.len());
    let r2max = if cfg.crop_range > 0.0 {
        cfg.crop_range * cfg.crop_range
    } else {
        f32::INFINITY
    };
    for p in cloud.iter() {
        let r2 = p[0] * p[0] + p[1] * p[1];
        if r2 <= r2max && p[2] >= cfg.ground_z_min {
            out.push(p);
        }
    }
    if cfg.voxel_leaf > 0.0 {
        out = out.voxel_downsample(cfg.voxel_leaf);
    }
    out
}

/// Per-frame odometry record.
#[derive(Clone, Debug)]
pub struct FrameRecord {
    pub index: usize,
    /// Scan-to-scan transform estimated by ICP.
    pub relative: Mat4,
    /// Accumulated pose (world ← sensor_i).
    pub pose: Mat4,
    pub rmse: f64,
    pub iterations: u32,
    pub stop: StopReason,
    /// Wall time of the alignment (acquisition excluded — it overlaps).
    pub align_ms: f64,
}

/// Odometry run output.
#[derive(Debug)]
pub struct OdometryResult {
    pub records: Vec<FrameRecord>,
    pub poses: Vec<Mat4>,
    pub align_stats: TimingStats,
    /// Time the alignment thread spent blocked waiting for frames — a
    /// measure of how well acquisition hides behind alignment.
    pub starvation_ms: f64,
}

impl OdometryResult {
    /// Mean registration RMSE across frames (Table III row).
    pub fn mean_rmse(&self) -> f64 {
        let vals: Vec<f64> = self
            .records
            .iter()
            .filter(|r| r.rmse.is_finite())
            .map(|r| r.rmse)
            .collect();
        if vals.is_empty() {
            f64::NAN
        } else {
            vals.iter().sum::<f64>() / vals.len() as f64
        }
    }
}

/// Fit a cloud into the device target buffer: voxel-downsample with a
/// growing leaf until it fits (PCL pipelines do exactly this to bound
/// map density). `seed` drives the random-sample fallback, so different
/// pipeline seeds produce different fallback samples (a fixed internal
/// seed would silently make them identical).
pub fn fit_to_capacity(cloud: PointCloud, capacity: usize, seed: u64) -> PointCloud {
    if cloud.len() <= capacity {
        return cloud;
    }
    let mut leaf = 0.1f32;
    for _ in 0..12 {
        let down = cloud.voxel_downsample(leaf);
        if down.len() <= capacity {
            return down;
        }
        leaf *= 1.6;
    }
    // Fall back to random sampling at the last resort (substream keeps
    // it independent of the per-frame source-sampling streams).
    let mut rng = Pcg32::substream(seed, 0xF17);
    cloud.random_sample(capacity, &mut rng)
}

// ---------------------------------------------------------------------------
// Residency-aware admission
// ---------------------------------------------------------------------------

/// What to do with a candidate resident map whose footprint exceeds one
/// residency slot (`target_capacity` points). Parsed from the
/// `admission=` config key and `--admission` CLI option.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Fail the run with a structured [`AdmissionError`] carrying the
    /// `hwmodel` footprint — for serving setups where a silently
    /// degraded map is worse than a loud rejection.
    Reject,
    /// Voxel-downsample (growing leaf, random-sample fallback) until the
    /// map fits the slot, and record the decision — the pre-admission
    /// behavior, made explicit and visible.
    #[default]
    DownsampleToFit,
}

impl std::str::FromStr for AdmissionPolicy {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "reject" => AdmissionPolicy::Reject,
            "downsample" | "downsample-to-fit" => AdmissionPolicy::DownsampleToFit,
            other => bail!("unknown admission policy {other:?} (expected reject | downsample)"),
        })
    }
}

impl std::fmt::Display for AdmissionPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            AdmissionPolicy::Reject => "reject",
            AdmissionPolicy::DownsampleToFit => "downsample-to-fit",
        })
    }
}

/// Structured rejection of a map that does not fit one residency slot —
/// returned (through `anyhow`, downcastable) by [`admit_map`] under
/// [`AdmissionPolicy::Reject`].
#[derive(Clone, Copy, Debug)]
pub struct AdmissionError {
    /// Raw point count of the offending map.
    pub points: usize,
    /// Points after padding to the kernel target block.
    pub padded_points: usize,
    /// HBM bytes the padded map would occupy.
    pub footprint_bytes: u64,
    /// Point capacity of one residency slot (`target_capacity`).
    pub slot_capacity: usize,
    /// HBM bytes one slot provides at that capacity.
    pub slot_bytes: u64,
}

impl std::fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "map of {} points (padded {} = {} B HBM) exceeds the {}-point residency slot \
             ({} B); rerun with `--admission downsample` or raise target_capacity",
            self.points,
            self.padded_points,
            self.footprint_bytes,
            self.slot_capacity,
            self.slot_bytes
        )
    }
}

impl std::error::Error for AdmissionError {}

/// What admission decided for one candidate map (recorded on the
/// localization workloads so the decision is reportable, never silent).
#[derive(Clone, Copy, Debug)]
pub struct AdmissionDecision {
    pub policy: AdmissionPolicy,
    /// Point count before admission.
    pub original_points: usize,
    /// Point count actually admitted to the slot.
    pub admitted_points: usize,
    /// `hwmodel` footprint of the *original* cloud — what was asked of
    /// the slot.
    pub footprint: crate::hwmodel::TargetFootprint,
    /// Point capacity of one residency slot at admission time.
    pub slot_capacity: usize,
}

impl AdmissionDecision {
    /// Did admission have to shrink the map to fit?
    pub fn downsampled(&self) -> bool {
        self.admitted_points < self.original_points
    }
}

/// Residency-aware admission for one candidate resident map: estimate
/// its padded HBM footprint via
/// [`crate::hwmodel::AcceleratorConfig::target_footprint`], admit it
/// unchanged when it fits a `cfg.target_capacity`-point slot, and
/// otherwise apply `cfg.admission` — a structured rejection or an
/// explicit downsample-to-fit — instead of the old silent shrink.
pub fn admit_map(
    cloud: PointCloud,
    cfg: &PipelineConfig,
) -> Result<(PointCloud, AdmissionDecision)> {
    let hw = crate::hwmodel::AcceleratorConfig::default();
    let block_m = crate::nn::KernelConfig::default().block_m;
    let footprint = hw.target_footprint(cloud.len(), block_m);
    let original_points = cloud.len();
    let slot_capacity = cfg.target_capacity;
    if footprint.fits_slot(slot_capacity) {
        return Ok((
            cloud,
            AdmissionDecision {
                policy: cfg.admission,
                original_points,
                admitted_points: original_points,
                footprint,
                slot_capacity,
            },
        ));
    }
    match cfg.admission {
        AdmissionPolicy::Reject => Err(AdmissionError {
            points: original_points,
            padded_points: footprint.padded_points,
            footprint_bytes: footprint.bytes,
            slot_capacity,
            slot_bytes: crate::hwmodel::AcceleratorConfig::resident_target_bytes(slot_capacity),
        }
        .into()),
        AdmissionPolicy::DownsampleToFit => {
            let fitted = fit_to_capacity(cloud, slot_capacity, cfg.seed);
            let admitted_points = fitted.len();
            Ok((
                fitted,
                AdmissionDecision {
                    policy: cfg.admission,
                    original_points,
                    admitted_points,
                    footprint,
                    slot_capacity,
                },
            ))
        }
    }
}

/// Acquisition stage: generates/loads frames, samples the source, and
/// pushes prepared frames downstream. Runs on its own thread.
fn acquisition_thread(
    seq: &Sequence,
    frames: usize,
    cfg: PipelineConfig,
    tx: SyncSender<Result<PreparedFrame>>,
) {
    for i in 0..frames {
        let item = (|| -> Result<PreparedFrame> {
            let cloud = preprocess(&seq.frame(i)?, &cfg);
            let mut rng = Pcg32::substream(cfg.seed, i as u64);
            let source_sample = cloud.random_sample(cfg.source_sample, &mut rng);
            let full = fit_to_capacity(cloud, cfg.target_capacity, cfg.seed);
            Ok(PreparedFrame {
                index: i,
                source_sample,
                full,
            })
        })();
        // Receiver hung up → stop early.
        if tx.send(item).is_err() {
            return;
        }
    }
}

/// Run scan-to-scan odometry over the first `frames` frames of `seq`
/// using the FPPS API with the given backend.
///
/// Frame 0 initialises the map; each subsequent frame aligns its sample
/// against the previous frame's full cloud, seeding ICP with the
/// previous relative motion (constant-velocity prior — standard LiDAR
/// odometry practice that also matches the paper's per-frame "initial
/// transformation matrix" API).
pub fn run_odometry<B: KernelBackend>(
    seq: &Sequence,
    frames: usize,
    cfg: PipelineConfig,
    icp: &mut FppsIcp<B>,
) -> Result<OdometryResult> {
    let frames = frames.min(seq.len());
    let (tx, rx): (_, Receiver<Result<PreparedFrame>>) = sync_channel(cfg.queue_depth);

    std::thread::scope(|scope| {
        scope.spawn(|| acquisition_thread(seq, frames, cfg, tx));

        let mut records = Vec::new();
        let mut poses = vec![Mat4::IDENTITY];
        let mut align_stats = TimingStats::new();
        let mut starvation_ms = 0.0;
        let mut prev_full: Option<PointCloud> = None;
        let mut prev_relative = Mat4::IDENTITY;

        loop {
            let wait0 = std::time::Instant::now();
            let msg = match rx.recv() {
                Ok(m) => m,
                Err(_) => break, // acquisition finished
            };
            starvation_ms += wait0.elapsed().as_secs_f64() * 1e3;
            let frame = msg.context("frame acquisition")?;

            match prev_full.take() {
                None => {
                    // First frame: nothing to align against.
                    prev_full = Some(frame.full);
                }
                Some(target) => {
                    let t0 = std::time::Instant::now();
                    let bootstrap = records.is_empty()
                        || !matches!(
                            records.last().map(|r: &FrameRecord| r.stop),
                            Some(StopReason::Converged) | Some(StopReason::MaxIterations)
                        );
                    let res = if bootstrap && cfg.bootstrap_seeds > 0 {
                        // Multi-start global initialisation: the vehicle
                        // moves dominantly forward, so seed a fan of +x
                        // translations and keep the lowest-RMSE result.
                        let mut best: Option<crate::fpps_api::FppsResult> = None;
                        for k in 0..=cfg.bootstrap_seeds {
                            let seed_t = Mat4::from_rt(
                                crate::math::Mat3::IDENTITY,
                                crate::math::Vec3::new(
                                    (k as f64) * cfg.bootstrap_step as f64,
                                    0.0,
                                    0.0,
                                ),
                            );
                            icp.set_input_source(frame.source_sample.clone());
                            icp.set_input_target(target.clone());
                            icp.set_transformation_matrix(seed_t);
                            let r = icp.align()?;
                            let better = match &best {
                                None => true,
                                Some(b) => {
                                    r.has_converged()
                                        && (!b.has_converged() || r.rmse < b.rmse)
                                }
                            };
                            if better {
                                best = Some(r);
                            }
                        }
                        best.expect("at least one bootstrap attempt")
                    } else {
                        icp.set_input_source(frame.source_sample);
                        icp.set_input_target(target);
                        icp.set_transformation_matrix(prev_relative);
                        icp.align()?
                    };
                    let align_ms = t0.elapsed().as_secs_f64() * 1e3;
                    align_stats.record_ms(align_ms);

                    // T maps source (frame i) into target (frame i−1)
                    // coordinates — i.e. the relative motion.
                    let relative = res.transformation;
                    let pose = poses.last().unwrap().mul_mat(&relative);
                    poses.push(pose);
                    records.push(FrameRecord {
                        index: frame.index,
                        relative,
                        pose,
                        rmse: res.rmse,
                        iterations: res.iterations,
                        stop: res.stop,
                        align_ms,
                    });
                    prev_relative = if res.has_converged() {
                        relative
                    } else {
                        Mat4::IDENTITY
                    };
                    prev_full = Some(frame.full);
                }
            }
        }

        Ok(OdometryResult {
            records,
            poses,
            align_stats,
            starvation_ms,
        })
    })
}

// ---------------------------------------------------------------------------
// Multi-lane batched registration engine
// ---------------------------------------------------------------------------

/// One independent frame-pair registration request.
pub struct RegistrationJob {
    /// Caller-assigned id; results are returned sorted by it, so ids
    /// define the deterministic output order regardless of lane count.
    pub id: u64,
    /// Client/stream the job belongs to (multi-client bookkeeping).
    pub stream: usize,
    /// Target identity for affinity scheduling: jobs with equal keys are
    /// routed to the lane whose backend already holds that target, so
    /// the resident-target cache hits across jobs. [`Self::new`] derives
    /// it from the target's content fingerprint; [`Self::new_keyed`]
    /// takes it from the caller (e.g. one shared map, hashed once).
    pub target_key: u64,
    /// Shared (like `target`) so the retry path re-stages the same
    /// points by `Arc` clone — a retry never deep-copies the cloud.
    pub source: Arc<PointCloud>,
    /// Shared so map-reuse workloads submit M jobs against one cloud
    /// without M copies.
    pub target: Arc<PointCloud>,
    /// Initial transform (`setTransformationMatrix`).
    pub initial: Mat4,
    /// Per-job deadline override, measured from submission; `None`
    /// falls back to the pool-wide [`SupervisorConfig::deadline`]. A
    /// job past its deadline — queued, between retries, or mid-flight
    /// (cut off cooperatively between ICP iterations, or by the
    /// watchdog when the lane is wedged) — is contained as a
    /// [`StopReason::DeadlineExceeded`] outcome.
    pub deadline: Option<Duration>,
    /// Per-job retry-budget override for transient failures (errors,
    /// panics); `None` falls back to [`SupervisorConfig::max_retries`].
    pub max_retries: Option<u32>,
    submitted: Instant,
}

impl RegistrationJob {
    pub fn new(
        id: u64,
        stream: usize,
        source: impl Into<Arc<PointCloud>>,
        target: impl Into<Arc<PointCloud>>,
        initial: Mat4,
    ) -> Self {
        let target = target.into();
        Self {
            id,
            stream,
            target_key: target.fingerprint(),
            source: source.into(),
            target,
            initial,
            deadline: None,
            max_retries: None,
            submitted: Instant::now(),
        }
    }

    /// Like [`Self::new`] with a caller-supplied affinity key — skips
    /// hashing the target, for callers that build many jobs against one
    /// shared cloud (see [`localization_jobs`]).
    pub fn new_keyed(
        id: u64,
        stream: usize,
        source: impl Into<Arc<PointCloud>>,
        target: impl Into<Arc<PointCloud>>,
        target_key: u64,
        initial: Mat4,
    ) -> Self {
        Self {
            id,
            stream,
            target_key,
            source: source.into(),
            target: target.into(),
            initial,
            deadline: None,
            max_retries: None,
            submitted: Instant::now(),
        }
    }

    /// Builder: per-job deadline (see the `deadline` field).
    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Builder: per-job retry budget (see the `max_retries` field).
    pub fn with_max_retries(mut self, max_retries: u32) -> Self {
        self.max_retries = Some(max_retries);
        self
    }

    /// Reset the submission timestamp — call immediately before sending
    /// a job that was built ahead of time, so the reported queue wait
    /// measures time *queued*, not time since construction.
    pub fn mark_submitted(&mut self) {
        self.submitted = Instant::now();
    }
}

/// Result of one lane-pool job.
#[derive(Clone, Debug)]
pub struct RegistrationOutcome {
    pub id: u64,
    pub stream: usize,
    /// Which lane served the job (scheduling detail — the transform must
    /// not depend on it; see the `lane_engine` determinism test).
    pub lane: usize,
    pub transform: Mat4,
    pub rmse: f64,
    pub iterations: u32,
    pub stop: StopReason,
    /// Time from submission to a lane picking the job up.
    pub queue_wait_ms: f64,
    /// Time inside `align()` on the lane.
    pub service_ms: f64,
    /// `Some(message)` when the alignment itself errored (or its
    /// deadline expired). A failed job is *contained*: its lane keeps
    /// draining, the outcome carries the job's initial transform and
    /// NaN rmse, and the rest of the batch is unaffected.
    pub error: Option<String>,
    /// Align attempts the job consumed (1 = served first try; larger
    /// values mean transient failures were retried).
    pub attempts: u32,
}

impl RegistrationOutcome {
    /// Did the alignment error (as opposed to merely not converging)?
    pub fn is_failed(&self) -> bool {
        self.error.is_some()
    }
}

/// ICP parameters shared by every lane (per-job overrides travel in the
/// job's `initial` transform only, to keep lane-count invariance).
#[derive(Clone, Copy, Debug)]
pub struct LaneIcpConfig {
    pub max_correspondence_distance: f32,
    pub max_iteration_count: u32,
    pub transformation_epsilon: f64,
    /// Per-class retention of each lane engine's staging-buffer arena
    /// (see [`crate::pool::BufferPool`]); the CLI exposes it as
    /// `--pool-capacity`, run configs as `pool_capacity=`.
    pub pool_capacity: usize,
}

impl Default for LaneIcpConfig {
    fn default() -> Self {
        Self {
            max_correspondence_distance: 1.0,
            max_iteration_count: 50,
            transformation_epsilon: 1e-5,
            pool_capacity: crate::pool::DEFAULT_RETAIN,
        }
    }
}

/// Per-lane execution statistics.
#[derive(Clone, Debug, Default)]
pub struct LaneStats {
    pub lane: usize,
    pub jobs: usize,
    /// Jobs whose alignment errored (contained per-job, see
    /// [`RegistrationOutcome::error`]); included in `jobs`.
    pub failed: usize,
    /// Targets still resident on this lane's backend at the end of the
    /// run (≤ its residency slot count).
    pub resident_targets: usize,
    /// Service latency samples of this lane.
    pub service: TimingStats,
    /// Queue-wait samples of the jobs this lane served (scheduler
    /// pressure as seen from this lane).
    pub queue_wait: TimingStats,
    /// Cumulative backend ("device") time of this lane.
    pub device_ms: f64,
    /// Target uploads this lane's backend actually performed.
    pub target_uploads: usize,
    /// Alignments that found their target already resident (affinity
    /// scheduling + unchanged target = cache hit).
    pub target_hits: usize,
    /// Resident targets this lane's backend LRU-evicted — with pool-wide
    /// residency coordination this stays 0 while any lane has free
    /// slots.
    pub target_evictions: usize,
    /// Transient-failure retries this lane performed (extra align
    /// attempts beyond each job's first).
    pub retries: usize,
    /// Times this lane's backend was respawned from the factory after a
    /// panic.
    pub restarts: usize,
    /// Jobs on this lane contained as [`StopReason::DeadlineExceeded`]
    /// (cooperatively, pre-service, or cut off by the watchdog);
    /// included in `failed`.
    pub deadline_missed: usize,
    /// Failover tier the lane's backend ended the run on (0 = primary;
    /// higher tiers were engaged after repeated restarts, see
    /// [`SupervisorConfig::restarts_per_tier`]).
    pub backend_tier: usize,
    /// Name of the backend serving the lane at the end of the run.
    pub backend: String,
}

/// Aggregate report of one lane-pool run.
#[derive(Debug)]
pub struct LaneReport {
    /// All outcomes, sorted by job id (deterministic order).
    pub outcomes: Vec<RegistrationOutcome>,
    /// Per-lane statistics, sorted by lane index.
    pub lanes: Vec<LaneStats>,
    /// Per-lane service stats merged into one aggregate distribution.
    pub service: TimingStats,
    /// Queue-wait distribution across all jobs (backpressure signal).
    pub queue_wait: TimingStats,
    pub wall_ms: f64,
}

/// Throughput over a wall-clock window, `None` when the window is too
/// small (or non-finite) to yield a meaningful finite rate — an empty
/// or instantaneous batch has no throughput, not an infinite one.
fn rate_per_s(count: usize, wall_ms: f64) -> Option<f64> {
    if !wall_ms.is_finite() || wall_ms <= f64::EPSILON {
        return None;
    }
    let rate = count as f64 / (wall_ms / 1e3);
    rate.is_finite().then_some(rate)
}

impl LaneReport {
    /// Aggregate throughput over the whole run; 0.0 (never NaN/inf)
    /// when the wall-clock window is degenerate.
    pub fn jobs_per_s(&self) -> f64 {
        rate_per_s(self.outcomes.len(), self.wall_ms).unwrap_or(0.0)
    }

    /// Render the per-lane breakdown — shared by the `fpps batch` /
    /// `fpps localize` subcommands and the registration-server example.
    /// Queue-wait and jobs/s make scheduler pressure visible: a lane
    /// whose wait grows while its jobs/s stalls is the backpressure
    /// bottleneck.
    pub fn lane_table(&self, title: &str) -> crate::report::Table {
        let mut t = crate::report::Table::new(title).header(&[
            "lane",
            "jobs",
            "fail",
            "mean (ms)",
            "p99 (ms)",
            "wait (ms)",
            "jobs/s",
            "tgt up/hit/ev",
            "rt/rs/ddl",
            "resident",
            "device (ms)",
            "backend",
        ]);
        for l in &self.lanes {
            let jobs_per_s = match rate_per_s(l.jobs, self.wall_ms) {
                Some(rate) => format!("{rate:.2}"),
                None => "-".to_string(), // degenerate window: no rate
            };
            t.row(vec![
                l.lane.to_string(),
                l.jobs.to_string(),
                l.failed.to_string(),
                format!("{:.1}", l.service.mean_ms()),
                format!("{:.1}", l.service.percentile_ms(99.0)),
                format!("{:.1}", l.queue_wait.mean_ms()),
                jobs_per_s,
                format!(
                    "{}/{}/{}",
                    l.target_uploads, l.target_hits, l.target_evictions
                ),
                format!("{}/{}/{}", l.retries, l.restarts, l.deadline_missed),
                l.resident_targets.to_string(),
                format!("{:.1}", l.device_ms),
                format!("{} (tier {})", l.backend, l.backend_tier),
            ]);
        }
        t
    }

    /// Total contained job failures across all lanes.
    pub fn failed_jobs(&self) -> usize {
        self.lanes.iter().map(|l| l.failed).sum()
    }
}

/// Steal threshold: a warm lane keeps its key's jobs until it has this
/// many in flight *and* another lane sits idle. One in-flight job is
/// not a backlog — it drains sooner than a redundant target upload
/// pays off — so stealing starts at a queue two deep.
pub const STEAL_BACKLOG: usize = 2;

/// Per-job completion feedback a lane reports to the dispatcher — the
/// ground truth that corrects the [`AffinityRouter`]'s warm-set mirror
/// (see [`AffinityRouter::completed`]).
#[derive(Clone, Copy, Debug)]
pub struct JobFeedback {
    /// Lane that served the job.
    pub lane: usize,
    /// The job's target key.
    pub key: u64,
    /// The backend actually uploaded the target during this job (the
    /// lane diffs its upload counter around `align()`), so the lane now
    /// genuinely holds the key — even if the alignment later errored.
    pub uploaded: bool,
    /// The job re-activated an already-resident target (the cache-hit
    /// counter advanced): the key is device-resident and was just
    /// MRU-touched there — even if a later stage of the alignment
    /// failed, which is why this cannot be inferred from `ok` alone.
    pub hit: bool,
    /// The alignment returned `Ok`.
    pub ok: bool,
    /// The lane's backend generation the job ran under (0 until the
    /// first restart). Feedback whose generation trails the router's
    /// ([`AffinityRouter::generation`]) is *stale*: the backend it
    /// describes is gone, so it settles only the load estimate and
    /// never touches the warm/resident mirrors (see
    /// [`AffinityRouter::lane_restarted`]).
    pub generation: u64,
}

/// Pool-wide residency coordinator — the routing core of the supervised
/// dispatcher: a pure, deterministic state machine over
/// per-lane **warm key sets** (the dispatcher-side mirror of each lane
/// backend's LRU resident-target set) plus a pending-job load estimate
/// and per-lane **slot occupancy** (free vs. warm). Separated from the
/// channel plumbing so the scheduling policy is unit-testable without
/// threads, and public so the property suite can drive it against real
/// backends.
///
/// Invariants the channel loop must uphold:
/// * routing state is committed via [`Self::committed`] only **after** a
///   send succeeds (a failed `try_send` must not poison the warm sets);
/// * every served job reports [`JobFeedback`] through
///   [`Self::completed`], which *corrects* the optimistically committed
///   mirror — replaying uploads and cache hits onto the confirmed
///   resident mirror, and un-warming a key whose job failed before
///   touching residency. The corrected warm sets stay a subset of each
///   backend's [`KernelBackend::resident_epochs`] keys
///   (property-tested).
pub struct AffinityRouter {
    /// Per-lane warm target keys, LRU first / MRU last, each bounded by
    /// `slots` — uploads past capacity evict exactly like the backend.
    warm: Vec<Vec<u64>>,
    /// Keys *confirmed* device-resident per lane (LRU first), updated
    /// only by [`JobFeedback`] — the exact mirror of each backend's
    /// resident set as of its last processed completion. Distinct from
    /// the warm set: `warm` also carries optimistic, not-yet-completed
    /// commits (and drops keys conservatively on failure), while this
    /// list replays the device's own upload/activate transitions, so a
    /// device slot filled by a key the warm mirror later forgot still
    /// counts as occupied.
    resident: Vec<Vec<u64>>,
    /// Jobs sent to each lane minus completions seen.
    pending: Vec<usize>,
    /// Residency slots mirrored per lane.
    slots: usize,
    /// Round-robin cursor for tie-breaking and spill.
    rr: usize,
    /// Per-lane backend generation: bumped by [`Self::lane_restarted`]
    /// so feedback from a pre-restart backend is recognizably stale.
    gen: Vec<u64>,
    /// Lanes the supervisor declared wedged; routing avoids them until
    /// they recover (unless every lane is down).
    down: Vec<bool>,
}

impl AffinityRouter {
    pub fn new(lanes: usize, slots: usize) -> Self {
        Self {
            warm: vec![Vec::new(); lanes],
            resident: vec![Vec::new(); lanes],
            pending: vec![0; lanes],
            slots: slots.max(1),
            rr: 0,
            gen: vec![0; lanes],
            down: vec![false; lanes],
        }
    }

    pub fn lanes(&self) -> usize {
        self.pending.len()
    }

    /// Jobs routed to `lane` and not yet completed.
    pub fn pending(&self, lane: usize) -> usize {
        self.pending[lane]
    }

    /// The mirror's warm keys of `lane`, LRU first / MRU last.
    pub fn warm_keys(&self, lane: usize) -> &[u64] {
        &self.warm[lane]
    }

    /// Backend generation the router currently expects from `lane`.
    pub fn generation(&self, lane: usize) -> u64 {
        self.gen[lane]
    }

    /// Is `lane` marked wedged/down for routing purposes?
    pub fn is_down(&self, lane: usize) -> bool {
        self.down[lane]
    }

    /// The supervisor respawned `lane`'s backend: the fresh instance
    /// holds *nothing*, so clear both the warm and confirmed-resident
    /// mirrors and bump the generation — feedback still in flight from
    /// the old backend must not resurrect the keys this wipe dropped
    /// (see [`Self::completed`]).
    pub fn lane_restarted(&mut self, lane: usize) {
        if lane >= self.lanes() {
            return;
        }
        self.warm[lane].clear();
        self.resident[lane].clear();
        self.gen[lane] += 1;
    }

    /// Mark `lane` wedged (`down = true`) or recovered: routing skips
    /// down lanes while any lane is still up.
    pub fn set_down(&mut self, lane: usize, down: bool) {
        if lane < self.lanes() {
            self.down[lane] = down;
        }
    }

    /// The supervisor drained `n` queued jobs off a wedged `lane` for
    /// re-routing: they will never feed back from there, so settle the
    /// load estimate now.
    pub fn requeued(&mut self, lane: usize, n: usize) {
        if lane < self.lanes() {
            self.pending[lane] = self.pending[lane].saturating_sub(n);
        }
    }

    /// Total jobs routed and not yet fed back, across all lanes.
    pub fn total_pending(&self) -> usize {
        self.pending.iter().sum()
    }

    /// Does the mirror say `lane` has an unoccupied residency slot — a
    /// place a cold target can land without evicting anything? Uses the
    /// larger of the optimistic warm count (committed, not yet
    /// completed) and the confirmed resident count (a slot filled by a
    /// key the warm mirror later forgot is still filled).
    pub fn has_free_slot(&self, lane: usize) -> bool {
        self.warm[lane].len().max(self.resident[lane].len()) < self.slots
    }

    /// Every *up* lane warm for `key` — after a steal there can be
    /// several — least-loaded first (ties by lane index). Down lanes
    /// are never warm candidates: their queue is not draining.
    pub fn warm_lanes(&self, key: u64) -> Vec<usize> {
        let mut v: Vec<usize> = (0..self.lanes())
            .filter(|&l| !self.down[l] && self.warm[l].contains(&key))
            .collect();
        v.sort_by_key(|&l| self.pending[l]); // stable sort keeps index order on ties
        v
    }

    /// Routing decision, in priority order:
    /// 1. **warm hit** — the least-loaded warm lane, as long as its
    ///    backlog stays under [`STEAL_BACKLOG`];
    /// 2. **steal** — every warm lane is backlogged and a lane sits
    ///    idle: the idle lane (free-slot lanes preferred) pays one extra
    ///    upload rather than serializing a same-target batch;
    /// 3. the least-loaded warm lane when nobody is idle;
    /// 4. **free slot** — a cold key goes to the least-loaded lane with
    ///    an unoccupied residency slot: filling free pool capacity
    ///    always beats evicting a warm lane's LRU key;
    /// 5. `None` — cold key, every slot on every lane occupied: the
    ///    caller spills by load (an eviction is inevitable).
    pub fn first_choice(&self, key: u64) -> Option<usize> {
        let warm = self.warm_lanes(key);
        if let Some(&best) = warm.first() {
            if self.pending[best] < STEAL_BACKLOG {
                return Some(best);
            }
            let idle = (0..self.lanes())
                .filter(|&l| !self.down[l] && self.pending[l] == 0)
                .min_by_key(|&l| !self.has_free_slot(l));
            if let Some(idle) = idle {
                return Some(idle);
            }
            return Some(best);
        }
        (0..self.lanes())
            .filter(|&l| !self.down[l] && self.has_free_slot(l))
            .min_by_key(|&l| self.pending[l])
    }

    /// Spill order for non-blocking attempts after [`Self::first_choice`]
    /// found its queue full: everyone except the already-tried lane,
    /// least-loaded first (a cold key must not queue behind a deep
    /// backlog just because a lane's cache is fresh), free-slot lanes
    /// before evicting ones at equal load, rotation order breaking the
    /// remaining ties.
    pub fn spill_order(&self, exclude: Option<usize>) -> Vec<usize> {
        let lanes = self.lanes();
        let mut order: Vec<usize> = (0..lanes)
            .map(|i| (self.rr + i) % lanes)
            .filter(|&l| Some(l) != exclude && !self.down[l])
            .collect();
        if order.is_empty() {
            // Every other lane is down: spill anywhere rather than
            // nowhere — jobs queue up and drain once a lane recovers.
            order = (0..lanes)
                .map(|i| (self.rr + i) % lanes)
                .filter(|&l| Some(l) != exclude)
                .collect();
        }
        order.sort_by_key(|&l| (self.pending[l], !self.has_free_slot(l)));
        order
    }

    /// Lane to block on when every queue is full: the least-loaded warm
    /// lane (keeps the cache hot), else the shortest queue — free-slot
    /// lanes first at equal load, rotation order on remaining ties —
    /// never a blind round-robin pick past a shorter queue.
    pub fn blocking_choice(&self, key: u64) -> usize {
        if let Some(&l) = self.warm_lanes(key).first() {
            return l;
        }
        let lanes = self.lanes();
        (0..lanes)
            .map(|i| (self.rr + i) % lanes)
            .min_by_key(|&l| (self.down[l], self.pending[l], !self.has_free_slot(l)))
            .unwrap_or(0)
    }

    /// Touch `key` MRU on `lane`'s mirror, evicting past the slot count
    /// exactly like the backend's LRU set.
    fn touch_warm(&mut self, lane: usize, key: u64) {
        let w = &mut self.warm[lane];
        if let Some(i) = w.iter().position(|&k| k == key) {
            w.remove(i);
        }
        w.push(key);
        while w.len() > self.slots {
            w.remove(0);
        }
    }

    /// A job with `key` was *successfully* sent to `lane`: bump its
    /// load, optimistically mark the key warm (MRU — so back-to-back
    /// same-key jobs keep their affinity before the first completes),
    /// advance the round-robin cursor. The optimism is corrected by
    /// [`Self::completed`] once the job's real outcome is known.
    pub fn committed(&mut self, lane: usize, key: u64) {
        self.pending[lane] += 1;
        self.touch_warm(lane, key);
        self.rr = (lane + 1) % self.lanes();
    }

    /// Replay a confirmed device transition for `key` on `lane`'s
    /// resident mirror — insert/touch MRU, and on capacity pressure
    /// evict the resident LRU exactly like the device did, dropping the
    /// evicted key from the warm mirror too (it is no longer on the
    /// card, whatever the optimistic commits said).
    fn confirm_resident(&mut self, lane: usize, key: u64) {
        let r = &mut self.resident[lane];
        if let Some(i) = r.iter().position(|&k| k == key) {
            r.remove(i);
        }
        r.push(key);
        while self.resident[lane].len() > self.slots {
            let evicted = self.resident[lane].remove(0);
            self.warm[lane].retain(|&k| k != evicted);
        }
        self.touch_warm(lane, key);
    }

    /// Apply one job's [`JobFeedback`]: drop the lane's load estimate,
    /// then correct the mirror from the ground truth instead of keeping
    /// the commit-time guess:
    ///
    /// * **uploaded** (even on a failed alignment — the device holds
    ///   the target regardless) or **cache hit** (the key was resident
    ///   and just MRU-touched, even if a later stage of the job
    ///   failed): replay the transition on the confirmed resident
    ///   mirror, including the device's own LRU eviction when an
    ///   upload ran at capacity — so the mirror never retains a key
    ///   the device dropped.
    /// * **failed without touching residency** (neither uploaded nor
    ///   hit): un-warm the key the optimistic commit guessed — the
    ///   backend never gained it — while leaving the confirmed
    ///   resident set untouched (failure changes no device slot).
    ///
    /// Feedback from a *stale generation* (the lane's backend was
    /// respawned since the job ran, see [`Self::lane_restarted`])
    /// settles the load estimate only: the backend it describes is
    /// gone, so replaying it onto the mirror would resurrect keys the
    /// restart wiped.
    pub fn completed(&mut self, fb: JobFeedback) {
        if fb.lane >= self.lanes() {
            return;
        }
        self.pending[fb.lane] = self.pending[fb.lane].saturating_sub(1);
        if fb.generation != self.gen[fb.lane] {
            return;
        }
        if fb.uploaded || fb.hit {
            self.confirm_resident(fb.lane, fb.key);
        } else if !fb.ok {
            self.warm[fb.lane].retain(|&k| k != fb.key);
        }
    }
}

/// Pool-wide fault-tolerance policy of [`run_supervised_lane_pool`].
/// The defaults are deliberately inert (no deadline, no retries):
/// [`run_lane_pool`] keeps its historical semantics unless a caller
/// opts into supervision.
#[derive(Clone, Copy, Debug)]
pub struct SupervisorConfig {
    /// Default per-job deadline, measured from submission; `None`
    /// disables deadline enforcement (jobs may still opt in via
    /// [`RegistrationJob::with_deadline`]).
    pub deadline: Option<Duration>,
    /// Default transient-failure retry budget per job (0 = first error
    /// is final, matching the historical contained-failure behavior).
    pub max_retries: u32,
    /// First retry backoff; doubles per attempt up to `backoff_cap`.
    pub backoff_base: Duration,
    /// Upper bound on the exponential backoff between retries.
    pub backoff_cap: Duration,
    /// Backend restarts a lane absorbs before advancing one failover
    /// tier (the factory's second argument): `tier = restarts /
    /// restarts_per_tier`, so a backend that keeps panicking walks down
    /// a [`crate::fpps_api::FailoverChain`] instead of thrashing.
    pub restarts_per_tier: u32,
    /// Deadline-watchdog poll interval.
    pub watchdog_poll: Duration,
}

impl Default for SupervisorConfig {
    fn default() -> Self {
        Self {
            deadline: None,
            max_retries: 0,
            backoff_base: Duration::from_millis(1),
            backoff_cap: Duration::from_millis(50),
            restarts_per_tier: 2,
            watchdog_poll: Duration::from_millis(2),
        }
    }
}

impl SupervisorConfig {
    /// Bounded exponential backoff before retry `attempt` (1-based).
    fn backoff(&self, attempt: u32) -> Duration {
        let factor = 1u32 << attempt.min(16);
        self.backoff_base.saturating_mul(factor).min(self.backoff_cap)
    }
}

/// Bounded per-lane job queue: a lock-free single-producer ring
/// ([`crate::pool::ring::SpscRing`]) carrying small job descriptors —
/// clouds travel by `Arc`, so enqueueing moves ~100 bytes and never
/// copies points. The dispatcher is the only pusher; the lane worker
/// and the deadline watchdog race pops on the CAS consumer side, so a
/// third party can still *drain* a wedged lane's queue exactly-once
/// without a lock (the mutex queue this replaces serialized every
/// push/pop across the pool). One semantic difference is handled at
/// the call sites: `close()` + `drain()` is no longer atomic against a
/// concurrent push, so the dispatcher — the sole producer — re-drains
/// a lane's ring when it learns the lane died (see
/// [`dispatch_supervised`]).
type LaneQueue = crate::pool::ring::SpscRing<RegistrationJob>;

/// The lane's currently-served job, published for the deadline
/// watchdog. The `claimed` flag is the exactly-once arbiter between the
/// lane and the watchdog: whoever flips it first (under the heartbeat
/// mutex) owns the job's outcome and feedback.
#[derive(Clone)]
struct ActiveJob {
    id: u64,
    stream: usize,
    key: u64,
    initial: Mat4,
    queue_wait_ms: f64,
    started: Instant,
    deadline_at: Option<Instant>,
    attempt: u32,
    generation: u64,
    claimed: bool,
}

/// Shared lane↔watchdog state: the active-job heartbeat plus the
/// cancellation token installed into the lane's backend.
struct Heartbeat {
    active: Mutex<Option<ActiveJob>>,
    cancel: CancelToken,
}

/// Supervision traffic from lanes and the watchdog to the dispatcher.
enum LaneEvent {
    /// Per-job completion feedback (the mirror-correction protocol).
    Feedback(JobFeedback),
    /// The lane's backend was respawned: un-warm it and bump its
    /// feedback generation.
    Restarted { lane: usize },
    /// The watchdog cut off a wedged lane: route around it.
    Wedged { lane: usize },
    /// A wedged lane came back: it may take new jobs again.
    Recovered { lane: usize },
    /// Jobs drained off a wedged lane's queue, to be re-routed.
    Requeue { lane: usize, jobs: Vec<RegistrationJob> },
    /// The lane failed to start and will never serve: route around it
    /// permanently (its worker error fails the pool after the drain).
    Dead { lane: usize },
}

/// Try to place `job` via the router (first choice, then spill order);
/// hands the job back when every candidate queue is full. Routing state
/// is committed only after a push lands.
fn route_job(
    router: &mut AffinityRouter,
    queues: &[Arc<LaneQueue>],
    mut job: RegistrationJob,
) -> Option<RegistrationJob> {
    let key = job.target_key;
    let mut tried = None;
    if let Some(l) = router.first_choice(key) {
        match queues[l].try_push(job) {
            Ok(()) => {
                router.committed(l, key);
                return None;
            }
            Err(j) => {
                job = j;
                tried = Some(l); // don't re-attempt the full queue
            }
        }
    }
    for l in router.spill_order(tried) {
        match queues[l].try_push(job) {
            Ok(()) => {
                router.committed(l, key);
                return None;
            }
            Err(j) => job = j,
        }
    }
    Some(job)
}

/// Route jobs from the shared intake queue to per-lane queues through
/// the pool-wide residency coordinator ([`AffinityRouter`]): warm keys
/// keep their lane while it keeps up, cold keys fill **free residency
/// slots** anywhere in the pool before any warm lane is made to evict,
/// and only when every slot is occupied does a cold key spill by load.
/// `ev_rx` carries per-job [`JobFeedback`] plus the supervision events
/// (restarts, wedges, re-queues), giving the dispatcher its load
/// estimate, the ground truth that corrects the warm-set mirror, and
/// the restart/un-warm signals — all without locking. Jobs that find
/// every queue full are parked in a deferred list (never blocking the
/// event loop) and placed as soon as feedback frees a slot; intake is
/// only pulled while the deferred list is empty, so producer
/// backpressure is preserved. The dispatcher exits — closing every lane
/// queue — once intake has disconnected and every routed job has fed
/// back. Routing can never change numerics: every job is an independent
/// alignment, so `lanes = 1` and `lanes = K` stay bit-identical
/// regardless of placement.
fn dispatch_supervised(
    rx: Receiver<RegistrationJob>,
    queues: Vec<Arc<LaneQueue>>,
    ev_rx: Receiver<LaneEvent>,
    slots_rx: Receiver<usize>,
) {
    let lanes = queues.len();
    // Mirror the *actual* backends, not an assumed default: every lane
    // reports its backend's residency slot count once it exists (a lane
    // that fails to start just drops its sender). The most conservative
    // (minimum) count drives the warm sets — over-estimating residency
    // would route jobs to lanes whose backend already evicted the key.
    let mut slots: Option<usize> = None;
    for _ in 0..lanes {
        match slots_rx.recv() {
            Ok(s) => slots = Some(slots.map_or(s, |m| m.min(s))),
            Err(_) => break,
        }
    }
    let mut router = AffinityRouter::new(lanes, slots.unwrap_or(1));
    let mut deferred: VecDeque<RegistrationJob> = VecDeque::new();
    let mut dead = vec![false; lanes];
    let mut intake_open = true;

    fn handle_event(
        router: &mut AffinityRouter,
        queues: &[Arc<LaneQueue>],
        deferred: &mut VecDeque<RegistrationJob>,
        dead: &mut [bool],
        ev: LaneEvent,
    ) {
        match ev {
            LaneEvent::Feedback(fb) => router.completed(fb),
            LaneEvent::Restarted { lane } => router.lane_restarted(lane),
            LaneEvent::Wedged { lane } => router.set_down(lane, true),
            LaneEvent::Recovered { lane } => router.set_down(lane, false),
            LaneEvent::Requeue { lane, jobs } => {
                router.requeued(lane, jobs.len());
                deferred.extend(jobs);
            }
            LaneEvent::Dead { lane } => {
                dead[lane] = true;
                router.set_down(lane, true);
                // The ring's close+drain is not atomic against a push
                // already in flight from this thread. As the sole
                // producer we re-drain authoritatively here, so a job
                // that landed after the dead lane's own drain is
                // re-routed instead of rotting in a closed queue.
                let jobs = queues[lane].drain();
                if !jobs.is_empty() {
                    router.requeued(lane, jobs.len());
                    deferred.extend(jobs);
                }
            }
        }
    }

    loop {
        while let Ok(ev) = ev_rx.try_recv() {
            handle_event(&mut router, &queues, &mut deferred, &mut dead, ev);
        }
        if dead.iter().all(|&d| d) {
            // No lane will ever serve again; stop routing so the pool
            // can unwind and report the lane errors.
            break;
        }
        // Place deferred jobs (watchdog re-queues and earlier overflow)
        // before pulling new intake.
        while let Some(job) = deferred.pop_front() {
            if let Some(job) = route_job(&mut router, &queues, job) {
                deferred.push_front(job); // still no room anywhere
                break;
            }
        }
        if intake_open && deferred.is_empty() {
            match rx.recv_timeout(Duration::from_millis(1)) {
                Ok(job) => {
                    if let Some(job) = route_job(&mut router, &queues, job) {
                        deferred.push_back(job);
                    }
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => intake_open = false,
            }
        } else if !intake_open && deferred.is_empty() && router.total_pending() == 0 {
            break; // every job routed and fed back: drain complete
        } else if let Ok(ev) = ev_rx.recv_timeout(Duration::from_millis(2)) {
            handle_event(&mut router, &queues, &mut deferred, &mut dead, ev);
        }
    }
    for q in &queues {
        q.close();
    }
}

/// Deadline watchdog: polls every lane's heartbeat and, when a job's
/// deadline has passed unclaimed, *claims* it — emitting the contained
/// [`StopReason::DeadlineExceeded`] outcome and its feedback itself (so
/// the pool's accounting completes even if the lane never returns),
/// raising the lane's [`CancelToken`] so a cooperative backend abandons
/// the wedged call, marking the lane down, and draining its queue back
/// to the dispatcher for re-routing.
#[allow(clippy::too_many_arguments)]
fn watchdog_loop(
    heartbeats: &[Arc<Heartbeat>],
    queues: &[Arc<LaneQueue>],
    out_tx: Sender<RegistrationOutcome>,
    ev_tx: Sender<LaneEvent>,
    poll: Duration,
    stop: &AtomicBool,
) {
    while !stop.load(Ordering::SeqCst) {
        for (lane, hb) in heartbeats.iter().enumerate() {
            let claim = {
                let mut g = hb.active.lock().unwrap();
                let expired = g.as_ref().is_some_and(|a| {
                    !a.claimed && a.deadline_at.is_some_and(|d| Instant::now() >= d)
                });
                if expired {
                    let a = g.as_mut().expect("checked above");
                    a.claimed = true;
                    Some(a.clone())
                } else {
                    None
                }
            };
            let Some(a) = claim else { continue };
            // Cut the wedged call off, then take over the job's
            // bookkeeping: one outcome, one feedback, queue re-routed.
            hb.cancel.cancel();
            out_tx
                .send(RegistrationOutcome {
                    id: a.id,
                    stream: a.stream,
                    lane,
                    transform: a.initial,
                    rmse: f64::NAN,
                    iterations: 0,
                    stop: StopReason::DeadlineExceeded,
                    queue_wait_ms: a.queue_wait_ms,
                    service_ms: a.started.elapsed().as_secs_f64() * 1e3,
                    error: Some(format!(
                        "job {} on lane {lane}: deadline exceeded (cut off by watchdog)",
                        a.id
                    )),
                    attempts: a.attempt + 1,
                })
                .ok();
            ev_tx
                .send(LaneEvent::Feedback(JobFeedback {
                    lane,
                    key: a.key,
                    uploaded: false, // conservative: un-warm, never claim
                    hit: false,
                    ok: false,
                    generation: a.generation,
                }))
                .ok();
            ev_tx.send(LaneEvent::Wedged { lane }).ok();
            let drained = queues[lane].drain();
            if !drained.is_empty() {
                ev_tx
                    .send(LaneEvent::Requeue {
                        lane,
                        jobs: drained,
                    })
                    .ok();
            }
        }
        std::thread::sleep(poll);
    }
}

/// How one align attempt on a lane resolved.
enum Attempt {
    Done(crate::fpps_api::FppsResult, bool, bool), // (result, uploaded, hit)
    Failed(String),
    Panicked(String),
}

/// Human-readable panic payload (what `panic!` carried, if a string).
fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Run a pool of `lanes` supervised worker lanes, each with its own
/// bounded queue, fed by a target-affinity dispatcher (see
/// [`dispatch_supervised`]) and overseen by a deadline watchdog (see
/// [`watchdog_loop`]).
///
/// * `make_backend(lane, tier)` is called **on** each lane thread, so
///   backends never cross threads and need not be `Send`. `tier` is the
///   failover rung: 0 on startup, advancing by one per
///   [`SupervisorConfig::restarts_per_tier`] backend restarts, so the
///   factory can hand out progressively more conservative backends
///   (e.g. along a [`crate::fpps_api::FailoverChain`]). A tier-0
///   failure at startup is a pool-level error; a factory failure during
///   a mid-run respawn is contained per job instead.
/// * `produce(tx)` runs on its own thread and feeds the intake queue —
///   it may clone the sender and fan out to per-client producer threads
///   (see `examples/registration_server.rs`). A `send` error means the
///   pool is shutting down; treat it as a stop signal, not a failure.
///
/// Fault containment on a lane, per job: transient align errors (and
/// panics, which additionally respawn the backend from the factory)
/// retry with bounded exponential backoff up to the job's retry budget;
/// a job past its deadline is contained as
/// [`StopReason::DeadlineExceeded`] — cooperatively between ICP
/// iterations when the backend is healthy, or by the watchdog when it
/// is wedged. Every submitted job yields **exactly one** outcome and
/// exactly one feedback, whoever emits them.
///
/// Each job is an independent alignment, so the mapping of jobs to lanes
/// cannot change any transform: `lanes = 1` and `lanes = K` produce
/// bit-identical outcomes for a deterministic backend.
pub fn run_supervised_lane_pool<B, F, P>(
    lanes: usize,
    queue_depth: usize,
    icp_cfg: LaneIcpConfig,
    sup: SupervisorConfig,
    make_backend: F,
    produce: P,
) -> Result<LaneReport>
where
    B: KernelBackend,
    F: Fn(usize, usize) -> Result<B> + Sync,
    P: FnOnce(SyncSender<RegistrationJob>) -> Result<()> + Send,
{
    let lanes = lanes.max(1);
    let depth = queue_depth.max(1);
    let (job_tx, job_rx) = sync_channel::<RegistrationJob>(depth);
    let queues: Vec<Arc<LaneQueue>> = (0..lanes).map(|_| Arc::new(LaneQueue::new(depth))).collect();
    let heartbeats: Vec<Arc<Heartbeat>> = (0..lanes)
        .map(|_| {
            Arc::new(Heartbeat {
                active: Mutex::new(None),
                cancel: CancelToken::new(),
            })
        })
        .collect();
    let (out_tx, out_rx) = channel::<RegistrationOutcome>();
    let (lane_tx, lane_rx) = channel::<LaneStats>();
    let (ev_tx, ev_rx) = channel::<LaneEvent>();
    let (slots_tx, slots_rx) = channel::<usize>();
    let watchdog_stop = AtomicBool::new(false);
    let t0 = Instant::now();

    std::thread::scope(|scope| -> Result<()> {
        let producer = scope.spawn(move || produce(job_tx));
        let disp_queues = queues.clone();
        let dispatcher =
            scope.spawn(move || dispatch_supervised(job_rx, disp_queues, ev_rx, slots_rx));
        let wd_heartbeats = heartbeats.clone();
        let wd_queues = queues.clone();
        let wd_out = out_tx.clone();
        let wd_ev = ev_tx.clone();
        let wd_stop = &watchdog_stop;
        let watchdog = scope.spawn(move || {
            watchdog_loop(
                &wd_heartbeats,
                &wd_queues,
                wd_out,
                wd_ev,
                sup.watchdog_poll,
                wd_stop,
            )
        });
        let mut workers = Vec::with_capacity(lanes);
        for lane in 0..lanes {
            let queue = Arc::clone(&queues[lane]);
            let hb = Arc::clone(&heartbeats[lane]);
            let out_tx = out_tx.clone();
            let lane_tx = lane_tx.clone();
            let ev_tx = ev_tx.clone();
            let slots_tx = slots_tx.clone();
            let make_backend = &make_backend;
            workers.push(scope.spawn(move || -> Result<()> {
                let make_icp = |tier: usize| -> Result<FppsIcp<B>> {
                    let mut backend = make_backend(lane, tier).with_context(|| {
                        format!("create backend for lane {lane} (failover tier {tier})")
                    })?;
                    backend.set_cancel_token(hb.cancel.clone());
                    let mut icp = FppsIcp::with_backend(backend);
                    icp.set_buffer_pool(crate::pool::BufferPool::new(icp_cfg.pool_capacity));
                    icp.set_max_correspondence_distance(icp_cfg.max_correspondence_distance)
                        .set_max_iteration_count(icp_cfg.max_iteration_count)
                        .set_transformation_epsilon(icp_cfg.transformation_epsilon);
                    Ok(icp)
                };
                // Tier-0 creation failure is a configuration error that
                // fails the pool, exactly as before supervision existed —
                // but the lane must still hand its queue back so the
                // dispatcher can drain and the pool can unwind.
                let mut icp: Option<FppsIcp<B>> = match make_icp(0) {
                    Ok(engine) => Some(engine),
                    Err(e) => {
                        queue.close();
                        let jobs = queue.drain();
                        ev_tx.send(LaneEvent::Dead { lane }).ok();
                        if !jobs.is_empty() {
                            ev_tx.send(LaneEvent::Requeue { lane, jobs }).ok();
                        }
                        return Err(e);
                    }
                };
                // Tell the dispatcher how much residency this lane
                // really has, so its warm-set mirror matches the device.
                let engine0 = icp.as_ref().expect("created above");
                slots_tx.send(engine0.backend().residency_slots()).ok();
                drop(slots_tx);
                let mut stats = LaneStats {
                    lane,
                    backend: engine0.backend().name().to_string(),
                    ..Default::default()
                };
                let mut generation: u64 = 0;
                // Telemetry of backends retired by restarts, folded into
                // the final stats: (device_ms, uploads, hits, evictions).
                let mut retired = (0.0f64, 0u64, 0u64, 0u64);
                let retire = |icp: &mut Option<FppsIcp<B>>, retired: &mut (f64, u64, u64, u64)| {
                    if let Some(old) = icp.take() {
                        retired.0 += old.backend().device_time().as_secs_f64() * 1e3;
                        let (u, h, _) = old.target_cache_stats();
                        retired.1 += u;
                        retired.2 += h;
                        retired.3 += old.backend().target_evictions();
                    }
                };

                // Own queue, no lock contention with other lanes: the
                // dispatcher already routed.
                while let Some(job) = queue.pop() {
                    let queue_wait_ms = job.submitted.elapsed().as_secs_f64() * 1e3;
                    let (id, stream, initial, key) =
                        (job.id, job.stream, job.initial, job.target_key);
                    let deadline_at =
                        job.deadline.or(sup.deadline).map(|d| job.submitted + d);
                    let max_retries = job.max_retries.unwrap_or(sup.max_retries);
                    let t_serve = Instant::now();
                    let mut attempt: u32 = 0;
                    // `None` = the watchdog claimed the job (outcome and
                    // feedback already emitted over there).
                    let mut resolution: Option<(RegistrationOutcome, JobFeedback)> = None;
                    let mut recovered_from_claim = false;
                    loop {
                        // A job past its deadline — expired in the
                        // queue, or between retries — is contained
                        // without touching the backend.
                        if deadline_at.is_some_and(|d| Instant::now() >= d) {
                            stats.deadline_missed += 1;
                            resolution = Some((
                                RegistrationOutcome {
                                    id,
                                    stream,
                                    lane,
                                    transform: initial,
                                    rmse: f64::NAN,
                                    iterations: 0,
                                    stop: StopReason::DeadlineExceeded,
                                    queue_wait_ms,
                                    service_ms: t_serve.elapsed().as_secs_f64() * 1e3,
                                    error: Some(format!(
                                        "job {id} on lane {lane}: deadline exceeded"
                                    )),
                                    attempts: attempt + 1,
                                },
                                JobFeedback {
                                    lane,
                                    key,
                                    uploaded: false,
                                    hit: false,
                                    ok: false,
                                    generation,
                                },
                            ));
                            break;
                        }
                        // Respawn the backend if a panic retired it (or
                        // an earlier respawn failed). A factory failure
                        // here is contained in the job, not the pool.
                        if icp.is_none() {
                            let tier = stats.restarts / sup.restarts_per_tier.max(1) as usize;
                            match make_icp(tier) {
                                Ok(engine) => {
                                    stats.backend_tier = tier;
                                    stats.backend = engine.backend().name().to_string();
                                    icp = Some(engine);
                                }
                                Err(e) => {
                                    resolution = Some((
                                        RegistrationOutcome {
                                            id,
                                            stream,
                                            lane,
                                            transform: initial,
                                            rmse: f64::NAN,
                                            iterations: 0,
                                            stop: StopReason::Failed,
                                            queue_wait_ms,
                                            service_ms: t_serve.elapsed().as_secs_f64() * 1e3,
                                            error: Some(format!("job {id} on lane {lane}: {e:#}")),
                                            attempts: attempt + 1,
                                        },
                                        JobFeedback {
                                            lane,
                                            key,
                                            uploaded: false,
                                            hit: false,
                                            ok: false,
                                            generation,
                                        },
                                    ));
                                    break;
                                }
                            }
                        }
                        // Publish the attempt for the watchdog. If the
                        // watchdog already claimed this job (stall cut
                        // off between our checks), stop touching it.
                        let claimed_already = {
                            let mut g = hb.active.lock().unwrap();
                            if g.as_ref().is_some_and(|a| a.claimed) {
                                true
                            } else {
                                hb.cancel.reset();
                                *g = Some(ActiveJob {
                                    id,
                                    stream,
                                    key,
                                    initial,
                                    queue_wait_ms,
                                    started: t_serve,
                                    deadline_at,
                                    attempt,
                                    generation,
                                    claimed: false,
                                });
                                false
                            }
                        };
                        if claimed_already {
                            recovered_from_claim = true;
                            break;
                        }
                        let engine = icp.as_mut().expect("respawned above");
                        let (uploads_before, hits_before, _) = engine.target_cache_stats();
                        // Retries re-stage the same shared cloud: every
                        // attempt costs one `Arc` refcount, never a
                        // deep copy of the points.
                        engine.set_input_source(Arc::clone(&job.source));
                        engine.set_input_target(Arc::clone(&job.target));
                        engine.set_transformation_matrix(initial);
                        engine.set_deadline(deadline_at);
                        // A panicking backend must not take the lane
                        // (and with it the whole pool) down: contain the
                        // unwind, respawn, retry.
                        let served = match catch_unwind(AssertUnwindSafe(|| engine.align())) {
                            Ok(Ok(res)) => {
                                let (u1, h1, _) = engine.target_cache_stats();
                                Attempt::Done(res, u1 > uploads_before, h1 > hits_before)
                            }
                            Ok(Err(e)) => Attempt::Failed(format!("{e:#}")),
                            Err(payload) => Attempt::Panicked(panic_message(payload)),
                        };
                        // Resolve the claim race: whoever holds the
                        // heartbeat lock first owns the job's outcome.
                        let claimed = {
                            let mut g = hb.active.lock().unwrap();
                            let claimed = g.as_ref().is_some_and(|a| a.claimed);
                            if !claimed {
                                *g = None;
                            }
                            claimed
                        };
                        if matches!(served, Attempt::Panicked(_)) {
                            // The engine (and its backend) is toast:
                            // retire its telemetry, respawn next loop,
                            // and tell the dispatcher to un-warm us.
                            retire(&mut icp, &mut retired);
                            stats.restarts += 1;
                            generation += 1;
                            ev_tx.send(LaneEvent::Restarted { lane }).ok();
                        }
                        if claimed {
                            recovered_from_claim = true;
                            break;
                        }
                        match served {
                            Attempt::Done(mut res, uploaded, hit) => {
                                // Hand the iteration-stat buffer back to
                                // the engine so the next align reuses its
                                // capacity (part of the zero-alloc path).
                                if let Some(engine) = icp.as_mut() {
                                    engine.recycle_stats(std::mem::take(&mut res.stats));
                                }
                                let deadline_hit = res.stop == StopReason::DeadlineExceeded;
                                if deadline_hit {
                                    stats.deadline_missed += 1;
                                }
                                resolution = Some((
                                    RegistrationOutcome {
                                        id,
                                        stream,
                                        lane,
                                        // A deadline cut mid-alignment
                                        // hands back the initial
                                        // transform: partial progress is
                                        // not a usable pose.
                                        transform: if deadline_hit {
                                            initial
                                        } else {
                                            res.transformation
                                        },
                                        rmse: if deadline_hit { f64::NAN } else { res.rmse },
                                        iterations: res.iterations,
                                        stop: res.stop,
                                        queue_wait_ms,
                                        service_ms: t_serve.elapsed().as_secs_f64() * 1e3,
                                        error: deadline_hit.then(|| {
                                            format!("job {id} on lane {lane}: deadline exceeded")
                                        }),
                                        attempts: attempt + 1,
                                    },
                                    JobFeedback {
                                        lane,
                                        key,
                                        uploaded,
                                        hit,
                                        ok: !deadline_hit,
                                        generation,
                                    },
                                ));
                                break;
                            }
                            Attempt::Failed(msg) | Attempt::Panicked(msg) => {
                                if attempt < max_retries {
                                    attempt += 1;
                                    stats.retries += 1;
                                    std::thread::sleep(sup.backoff(attempt));
                                    continue;
                                }
                                resolution = Some((
                                    RegistrationOutcome {
                                        id,
                                        stream,
                                        lane,
                                        transform: initial,
                                        rmse: f64::NAN,
                                        iterations: 0,
                                        stop: StopReason::Failed,
                                        queue_wait_ms,
                                        service_ms: t_serve.elapsed().as_secs_f64() * 1e3,
                                        error: Some(format!("job {id} on lane {lane}: {msg}")),
                                        attempts: attempt + 1,
                                    },
                                    JobFeedback {
                                        lane,
                                        key,
                                        uploaded: false,
                                        hit: false,
                                        ok: false,
                                        generation,
                                    },
                                ));
                                break;
                            }
                        }
                    }
                    stats.jobs += 1;
                    stats.queue_wait.record_ms(queue_wait_ms);
                    stats.service.record_ms(t_serve.elapsed().as_secs_f64() * 1e3);
                    if recovered_from_claim {
                        // The watchdog already emitted this job's
                        // outcome and feedback; just account it and
                        // report the lane back up.
                        stats.failed += 1;
                        stats.deadline_missed += 1;
                        {
                            let mut g = hb.active.lock().unwrap();
                            *g = None;
                        }
                        ev_tx.send(LaneEvent::Recovered { lane }).ok();
                        continue;
                    }
                    let (outcome, feedback) = resolution.expect("every unclaimed job resolves");
                    if outcome.is_failed() {
                        stats.failed += 1;
                    }
                    out_tx.send(outcome).ok();
                    ev_tx.send(LaneEvent::Feedback(feedback)).ok();
                }
                if let Some(engine) = icp.as_ref() {
                    stats.resident_targets = engine.backend().resident_epochs().len();
                    stats.device_ms =
                        retired.0 + engine.backend().device_time().as_secs_f64() * 1e3;
                    let (u, h, _) = engine.target_cache_stats();
                    stats.target_uploads = (retired.1 + u) as usize;
                    stats.target_hits = (retired.2 + h) as usize;
                    stats.target_evictions =
                        (retired.3 + engine.backend().target_evictions()) as usize;
                } else {
                    stats.device_ms = retired.0;
                    stats.target_uploads = retired.1 as usize;
                    stats.target_hits = retired.2 as usize;
                    stats.target_evictions = retired.3 as usize;
                }
                lane_tx.send(stats).ok();
                Ok(())
            }));
        }
        // Drop the originals so the collection channels close when the
        // last lane finishes (and the dispatcher's slot wait cannot hang
        // on lanes that never started).
        drop(out_tx);
        drop(lane_tx);
        drop(ev_tx);
        drop(slots_tx);

        match producer.join() {
            Ok(r) => r.context("job producer")?,
            Err(_) => bail!("job producer panicked"),
        }
        if dispatcher.join().is_err() {
            bail!("affinity dispatcher panicked");
        }
        let mut worker_err = None;
        for w in workers {
            match w.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    worker_err.get_or_insert(e);
                }
                Err(_) => {
                    worker_err.get_or_insert(anyhow!("lane worker panicked"));
                }
            }
        }
        watchdog_stop.store(true, Ordering::SeqCst);
        if watchdog.join().is_err() {
            bail!("deadline watchdog panicked");
        }
        match worker_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    })?;

    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let mut outcomes: Vec<RegistrationOutcome> = out_rx.into_iter().collect();
    outcomes.sort_by_key(|o| o.id);
    let mut lane_stats: Vec<LaneStats> = lane_rx.into_iter().collect();
    lane_stats.sort_by_key(|s| s.lane);

    // Merge the per-lane distributions into the aggregate report.
    let mut service = TimingStats::new();
    for l in &lane_stats {
        service.merge(&l.service);
    }
    let mut queue_wait = TimingStats::new();
    for o in &outcomes {
        queue_wait.record_ms(o.queue_wait_ms);
    }

    Ok(LaneReport {
        outcomes,
        lanes: lane_stats,
        service,
        queue_wait,
        wall_ms,
    })
}

/// Run a pool of `lanes` worker lanes with the inert default
/// supervision policy (no deadlines, no retries) and a tier-blind
/// backend factory — the historical entry point; see
/// [`run_supervised_lane_pool`] for the full fault-tolerant form.
pub fn run_lane_pool<B, F, P>(
    lanes: usize,
    queue_depth: usize,
    icp_cfg: LaneIcpConfig,
    make_backend: F,
    produce: P,
) -> Result<LaneReport>
where
    B: KernelBackend,
    F: Fn(usize) -> Result<B> + Sync,
    P: FnOnce(SyncSender<RegistrationJob>) -> Result<()> + Send,
{
    run_supervised_lane_pool(
        lanes,
        queue_depth,
        icp_cfg,
        SupervisorConfig::default(),
        move |lane, _tier| make_backend(lane),
        produce,
    )
}

/// Convenience wrapper: push a prebuilt batch of jobs through a
/// supervised pool with an explicit fault-tolerance policy and a
/// tier-aware backend factory.
pub fn run_registration_batch_supervised<B, F>(
    jobs: Vec<RegistrationJob>,
    lanes: usize,
    queue_depth: usize,
    icp_cfg: LaneIcpConfig,
    sup: SupervisorConfig,
    make_backend: F,
) -> Result<LaneReport>
where
    B: KernelBackend,
    F: Fn(usize, usize) -> Result<B> + Sync,
{
    let expected = jobs.len();
    let report = run_supervised_lane_pool(
        lanes,
        queue_depth,
        icp_cfg,
        sup,
        make_backend,
        move |tx| {
            for mut job in jobs {
                job.mark_submitted(); // queue wait starts at send, not build
                if tx.send(job).is_err() {
                    break; // pool shut down early
                }
            }
            Ok(())
        },
    )?;
    if report.outcomes.len() != expected {
        return Err(anyhow!(
            "lane pool returned {} outcomes for {} jobs",
            report.outcomes.len(),
            expected
        ));
    }
    Ok(report)
}

/// Convenience wrapper: push a prebuilt batch of jobs through the pool.
pub fn run_registration_batch<B, F>(
    jobs: Vec<RegistrationJob>,
    lanes: usize,
    queue_depth: usize,
    icp_cfg: LaneIcpConfig,
    make_backend: F,
) -> Result<LaneReport>
where
    B: KernelBackend,
    F: Fn(usize) -> Result<B> + Sync,
{
    run_registration_batch_supervised(
        jobs,
        lanes,
        queue_depth,
        icp_cfg,
        SupervisorConfig::default(),
        move |lane, _tier| make_backend(lane),
    )
}

/// Build frame-pair jobs (frame i aligned onto frame i−1) from a
/// synthetic sequence — the shared job generator for the multi-client
/// example, the `fpps batch` subcommand and the lane-scaling bench.
pub fn sequence_pair_jobs(
    seq: &Sequence,
    frames: usize,
    stream: usize,
    cfg: &PipelineConfig,
) -> Result<Vec<RegistrationJob>> {
    let frames = frames.min(seq.len());
    let mut jobs = Vec::new();
    let mut prev: Option<PointCloud> = None;
    for i in 0..frames {
        let cloud = preprocess(&seq.frame(i)?, cfg);
        let mut rng = Pcg32::substream(cfg.seed, i as u64);
        let sample = cloud.random_sample(cfg.source_sample, &mut rng);
        let full = fit_to_capacity(cloud, cfg.target_capacity, cfg.seed);
        if let Some(target) = prev.take() {
            jobs.push(RegistrationJob::new(
                (stream as u64) << 32 | i as u64,
                stream,
                sample,
                target,
                Mat4::IDENTITY,
            ));
        }
        prev = Some(full);
    }
    Ok(jobs)
}

// ---------------------------------------------------------------------------
// Scan-to-map localization (resident-target scenario)
// ---------------------------------------------------------------------------

/// Prebuilt scan-to-map localization workload: one shared map, M scan
/// jobs against it, plus the ground-truth poses to score against.
pub struct LocalizationWorkload {
    /// The map every scan aligns against (frame-0 coordinates). All jobs
    /// share this one `Arc` and one target key, so the lane pool keeps
    /// it device-resident.
    pub map: Arc<PointCloud>,
    pub jobs: Vec<RegistrationJob>,
    /// Ground-truth map←sensor poses, indexed like `jobs`.
    pub truth: Vec<Mat4>,
    /// What admission decided for the map (see [`admit_map`]).
    pub admission: AdmissionDecision,
}

/// Build a localization workload from a synthetic sequence: the map is
/// the union of all preprocessed scans placed into frame-0 coordinates
/// by ground truth (then capacity-bounded), and each scan becomes a job
/// whose prior is the *previous* frame's true pose — the "last known
/// pose" a localization stack would start from.
pub fn localization_jobs(
    seq: &Sequence,
    scans: usize,
    cfg: &PipelineConfig,
) -> Result<LocalizationWorkload> {
    let scans = scans.min(seq.len());
    if scans == 0 {
        bail!("localization needs at least one scan");
    }
    let origin = seq.ground_truth[0].inverse_rigid();
    let mut map = PointCloud::new();
    let mut sources = Vec::with_capacity(scans);
    let mut truth = Vec::with_capacity(scans);
    for i in 0..scans {
        let cloud = preprocess(&seq.frame(i)?, cfg);
        let pose = origin.mul_mat(&seq.ground_truth[i]); // map ← sensor_i
        let world = cloud.transformed(&pose);
        map.xyz.extend_from_slice(&world.xyz);
        let mut rng = Pcg32::substream(cfg.seed, i as u64);
        sources.push(cloud.random_sample(cfg.source_sample, &mut rng));
        truth.push(pose);
    }
    // Residency-aware admission replaces the old silent shrink: an
    // oversized map is rejected or explicitly downsampled per policy.
    let (map, admission) = admit_map(map, cfg)?;
    let map = Arc::new(map);
    let key = map.fingerprint(); // hash the shared map once, not per job

    let mut jobs = Vec::with_capacity(scans);
    for (i, source) in sources.into_iter().enumerate() {
        let prior = match i {
            0 => Mat4::IDENTITY,
            _ => truth[i - 1],
        };
        jobs.push(RegistrationJob::new_keyed(
            i as u64,
            0,
            source,
            Arc::clone(&map),
            key,
            prior,
        ));
    }
    Ok(LocalizationWorkload {
        map,
        jobs,
        truth,
        admission,
    })
}

/// Per-scan translation error vs. `truth` (m), in job order (the job id
/// indexes `truth`). Contained failures ([`RegistrationOutcome::error`])
/// score NaN so a failed job can never masquerade as an accurate
/// localization; [`mean_finite`] / [`max_finite`] skip them.
fn translation_errors_vs_truth(report: &LaneReport, truth: &[Mat4]) -> Vec<f64> {
    report
        .outcomes
        .iter()
        .map(|o| {
            if o.is_failed() {
                f64::NAN
            } else {
                let gt = truth[o.id as usize];
                (o.transform.translation() - gt.translation()).norm()
            }
        })
        .collect()
}

/// Mean over the finite entries (NaN marks contained failures); NaN when
/// nothing finite remains.
fn mean_finite(vals: &[f64]) -> f64 {
    let (mut sum, mut n) = (0.0f64, 0usize);
    for v in vals.iter().copied().filter(|v| v.is_finite()) {
        sum += v;
        n += 1;
    }
    if n == 0 {
        f64::NAN
    } else {
        sum / n as f64
    }
}

/// Max over the finite entries; NaN when nothing finite remains (an
/// all-failure run must not report a perfect 0.0 max error).
fn max_finite(vals: &[f64]) -> f64 {
    let mut max = f64::NAN;
    for v in vals.iter().copied().filter(|v| v.is_finite()) {
        max = if max.is_nan() { v } else { max.max(v) };
    }
    max
}

/// Result of a [`run_localization`] run.
#[derive(Debug)]
pub struct LocalizationResult {
    pub report: LaneReport,
    pub map_points: usize,
    /// Per-scan translation error vs. ground truth (m), in job order;
    /// NaN for contained failures.
    pub translation_errors: Vec<f64>,
    /// What admission decided for the map (see [`admit_map`]).
    pub admission: AdmissionDecision,
}

impl LocalizationResult {
    pub fn mean_translation_error(&self) -> f64 {
        mean_finite(&self.translation_errors)
    }

    pub fn max_translation_error(&self) -> f64 {
        max_finite(&self.translation_errors)
    }
}

/// Scan-to-map localization: align `scans` frames of `seq` against one
/// shared map over the lane pool. Every job carries the same target key,
/// so the affinity dispatcher keeps the map resident — the kd-tree
/// backend builds its index once for the whole run, and the amortized
/// upload cost drops to zero (see `benches/target_reuse.rs`).
pub fn run_localization<B, F>(
    seq: &Sequence,
    scans: usize,
    cfg: &PipelineConfig,
    lanes: usize,
    queue_depth: usize,
    icp_cfg: LaneIcpConfig,
    make_backend: F,
) -> Result<LocalizationResult>
where
    B: KernelBackend,
    F: Fn(usize) -> Result<B> + Sync,
{
    run_localization_supervised(
        seq,
        scans,
        cfg,
        lanes,
        queue_depth,
        icp_cfg,
        SupervisorConfig::default(),
        move |lane, _tier| make_backend(lane),
    )
}

/// [`run_localization`] with an explicit fault-tolerance policy and a
/// tier-aware backend factory (see [`run_supervised_lane_pool`]).
#[allow(clippy::too_many_arguments)]
pub fn run_localization_supervised<B, F>(
    seq: &Sequence,
    scans: usize,
    cfg: &PipelineConfig,
    lanes: usize,
    queue_depth: usize,
    icp_cfg: LaneIcpConfig,
    sup: SupervisorConfig,
    make_backend: F,
) -> Result<LocalizationResult>
where
    B: KernelBackend,
    F: Fn(usize, usize) -> Result<B> + Sync,
{
    let workload = localization_jobs(seq, scans, cfg)?;
    let map_points = workload.map.len();
    let admission = workload.admission;
    let report = run_registration_batch_supervised(
        workload.jobs,
        lanes,
        queue_depth,
        icp_cfg,
        sup,
        make_backend,
    )?;
    let translation_errors = translation_errors_vs_truth(&report, &workload.truth);
    Ok(LocalizationResult {
        report,
        map_points,
        translation_errors,
        admission,
    })
}

// ---------------------------------------------------------------------------
// Tile-crossing localization (multi-target residency scenario)
// ---------------------------------------------------------------------------

/// Prebuilt tile-crossing localization workload: the trajectory is cut
/// into `tiles` contiguous submaps and the job stream *interleaves*
/// them — the submap ping-pong of a vehicle tracking along a tile
/// boundary. On a single-slot backend every job re-uploads (and, on the
/// kd-tree backend, rebuilds); with ≥ `tiles` residency slots each
/// submap uploads once per serving lane and every further job is a
/// cache hit (see `benches/tile_residency.rs`).
pub struct TiledLocalizationWorkload {
    /// One submap per tile (frame-0 coordinates), shared by its jobs.
    pub maps: Vec<Arc<PointCloud>>,
    /// Tile index of each job, in job-id order.
    pub tile_of_job: Vec<usize>,
    pub jobs: Vec<RegistrationJob>,
    /// Ground-truth map←sensor poses, indexed by job id.
    pub truth: Vec<Mat4>,
    /// Per-tile admission decisions, tile order (see [`admit_map`]).
    pub admissions: Vec<AdmissionDecision>,
}

/// Build a tile-crossing workload from a synthetic sequence: scans are
/// assigned to `tiles` contiguous trajectory segments, each segment's
/// union (placed into frame-0 coordinates by ground truth, then
/// capacity-bounded) becomes one submap, and jobs are emitted
/// round-robin across the tiles so consecutive jobs alternate submaps.
pub fn tiled_localization_jobs(
    seq: &Sequence,
    scans: usize,
    tiles: usize,
    cfg: &PipelineConfig,
) -> Result<TiledLocalizationWorkload> {
    let scans = scans.min(seq.len());
    if scans == 0 {
        bail!("localization needs at least one scan");
    }
    let tiles = tiles.clamp(1, scans);
    let tile_of_scan = |i: usize| (i * tiles) / scans;
    let origin = seq.ground_truth[0].inverse_rigid();
    let mut tile_clouds: Vec<PointCloud> = (0..tiles).map(|_| PointCloud::new()).collect();
    let mut sources: Vec<Option<PointCloud>> = Vec::with_capacity(scans);
    let mut poses = Vec::with_capacity(scans);
    for i in 0..scans {
        let cloud = preprocess(&seq.frame(i)?, cfg);
        let pose = origin.mul_mat(&seq.ground_truth[i]); // map ← sensor_i
        let world = cloud.transformed(&pose);
        tile_clouds[tile_of_scan(i)].xyz.extend_from_slice(&world.xyz);
        let mut rng = Pcg32::substream(cfg.seed, i as u64);
        sources.push(Some(cloud.random_sample(cfg.source_sample, &mut rng)));
        poses.push(pose);
    }
    // Each submap passes residency-aware admission on its own.
    let mut maps = Vec::with_capacity(tiles);
    let mut admissions = Vec::with_capacity(tiles);
    for c in tile_clouds {
        let (m, a) = admit_map(c, cfg)?;
        maps.push(Arc::new(m));
        admissions.push(a);
    }
    // Hash each shared submap once, not per job.
    let keys: Vec<u64> = maps.iter().map(|m| m.fingerprint()).collect();

    // Emission order: round-robin over the tiles (A,B,…,A,B,…), the
    // maximal-ping-pong stress an LRU residency set exists for.
    let mut by_tile: Vec<Vec<usize>> = vec![Vec::new(); tiles];
    for i in 0..scans {
        by_tile[tile_of_scan(i)].push(i);
    }
    let deepest = by_tile.iter().map(Vec::len).max().unwrap_or(0);
    let mut jobs = Vec::with_capacity(scans);
    let mut truth = Vec::with_capacity(scans);
    let mut tile_of_job = Vec::with_capacity(scans);
    for r in 0..deepest {
        for (t, scans_of_tile) in by_tile.iter().enumerate() {
            let Some(&i) = scans_of_tile.get(r) else {
                continue;
            };
            // "Last known pose" prior, as in [`localization_jobs`].
            let prior = if i == 0 { Mat4::IDENTITY } else { poses[i - 1] };
            jobs.push(RegistrationJob::new_keyed(
                jobs.len() as u64,
                t,
                sources[i].take().expect("each scan emitted once"),
                Arc::clone(&maps[t]),
                keys[t],
                prior,
            ));
            truth.push(poses[i]);
            tile_of_job.push(t);
        }
    }
    Ok(TiledLocalizationWorkload {
        maps,
        tile_of_job,
        jobs,
        truth,
        admissions,
    })
}

/// Result of a [`run_tiled_localization`] run.
#[derive(Debug)]
pub struct TiledLocalizationResult {
    pub report: LaneReport,
    /// Points per submap, tile order.
    pub map_points: Vec<usize>,
    /// Per-scan translation error vs. ground truth (m), in job order;
    /// NaN for contained failures.
    pub translation_errors: Vec<f64>,
    /// Per-tile admission decisions, tile order (see [`admit_map`]).
    pub admissions: Vec<AdmissionDecision>,
}

impl TiledLocalizationResult {
    pub fn mean_translation_error(&self) -> f64 {
        mean_finite(&self.translation_errors)
    }

    pub fn max_translation_error(&self) -> f64 {
        max_finite(&self.translation_errors)
    }
}

/// Tile-crossing localization over the lane pool: `scans` frames of
/// `seq` against `tiles` alternating submaps. With multi-target
/// residency the per-lane upload count is bounded by the tile count —
/// not the scan count — which `fpps localize --tiles` prints.
#[allow(clippy::too_many_arguments)]
pub fn run_tiled_localization<B, F>(
    seq: &Sequence,
    scans: usize,
    tiles: usize,
    cfg: &PipelineConfig,
    lanes: usize,
    queue_depth: usize,
    icp_cfg: LaneIcpConfig,
    make_backend: F,
) -> Result<TiledLocalizationResult>
where
    B: KernelBackend,
    F: Fn(usize) -> Result<B> + Sync,
{
    run_tiled_localization_supervised(
        seq,
        scans,
        tiles,
        cfg,
        lanes,
        queue_depth,
        icp_cfg,
        SupervisorConfig::default(),
        move |lane, _tier| make_backend(lane),
    )
}

/// [`run_tiled_localization`] with an explicit fault-tolerance policy
/// and a tier-aware backend factory (see [`run_supervised_lane_pool`]).
#[allow(clippy::too_many_arguments)]
pub fn run_tiled_localization_supervised<B, F>(
    seq: &Sequence,
    scans: usize,
    tiles: usize,
    cfg: &PipelineConfig,
    lanes: usize,
    queue_depth: usize,
    icp_cfg: LaneIcpConfig,
    sup: SupervisorConfig,
    make_backend: F,
) -> Result<TiledLocalizationResult>
where
    B: KernelBackend,
    F: Fn(usize, usize) -> Result<B> + Sync,
{
    let workload = tiled_localization_jobs(seq, scans, tiles, cfg)?;
    let map_points = workload.maps.iter().map(|m| m.len()).collect();
    let admissions = workload.admissions.clone();
    let report = run_registration_batch_supervised(
        workload.jobs,
        lanes,
        queue_depth,
        icp_cfg,
        sup,
        make_backend,
    )?;
    let translation_errors = translation_errors_vs_truth(&report, &workload.truth);
    Ok(TiledLocalizationResult {
        report,
        map_points,
        translation_errors,
        admissions,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{lidar::LidarConfig, sequence_specs, Sequence};
    use crate::metrics::absolute_trajectory_error;

    fn tiny_sequence(frames: usize) -> Sequence {
        let spec = sequence_specs()[3].clone(); // residential: gentle
        Sequence::synthetic(spec, frames, 11, LidarConfig::tiny())
    }

    #[test]
    fn fit_to_capacity_shrinks() {
        let mut rng = Pcg32::new(1);
        let mut c = PointCloud::with_capacity(5000);
        for _ in 0..5000 {
            c.push([rng.range(-40.0, 40.0), rng.range(-40.0, 40.0), rng.range(0.0, 5.0)]);
        }
        let f = fit_to_capacity(c.clone(), 1000, 7);
        assert!(f.len() <= 1000);
        assert!(f.len() > 100, "over-shrunk to {}", f.len());
        // Under capacity → untouched.
        assert_eq!(fit_to_capacity(c.clone(), 10_000, 7).len(), c.len());
    }

    #[test]
    fn fit_to_capacity_fallback_respects_seed() {
        // Force the random-sample fallback with a cloud too spread out
        // for 12 voxel passes to tame, and check the pipeline seed
        // actually reaches it (a fixed internal seed made all fallback
        // samples identical regardless of cfg.seed).
        let mut rng = Pcg32::new(2);
        let mut c = PointCloud::with_capacity(4000);
        for _ in 0..4000 {
            c.push([
                rng.range(-4.0e6, 4.0e6),
                rng.range(-4.0e6, 4.0e6),
                rng.range(-4.0e6, 4.0e6),
            ]);
        }
        let a = fit_to_capacity(c.clone(), 100, 1);
        let b = fit_to_capacity(c.clone(), 100, 1);
        let d = fit_to_capacity(c.clone(), 100, 2);
        assert_eq!(a.len(), 100);
        assert_eq!(a.xyz, b.xyz, "same seed must reproduce the sample");
        assert_ne!(a.xyz, d.xyz, "different seeds must differ");
    }

    #[test]
    fn localization_workload_shares_one_target() {
        let seq = tiny_sequence(5);
        let cfg = PipelineConfig {
            source_sample: 256,
            target_capacity: 8192,
            ..Default::default()
        };
        let w = localization_jobs(&seq, 5, &cfg).unwrap();
        assert_eq!(w.jobs.len(), 5);
        assert_eq!(w.truth.len(), 5);
        let key = w.jobs[0].target_key;
        for j in &w.jobs {
            assert_eq!(j.target_key, key, "all scans share the map key");
            assert!(Arc::ptr_eq(&j.target, &w.map), "no map copies");
        }
        // First scan's prior is identity (it *is* the map origin).
        assert_eq!(w.jobs[0].initial.m, Mat4::IDENTITY.m);
    }

    #[test]
    fn localization_tracks_ground_truth() {
        let seq = tiny_sequence(5);
        let cfg = PipelineConfig {
            source_sample: 512,
            target_capacity: 8192,
            ..Default::default()
        };
        let res = run_localization(
            &seq,
            5,
            &cfg,
            2,
            8,
            LaneIcpConfig {
                max_iteration_count: 30,
                ..Default::default()
            },
            |_| Ok(crate::fpps_api::KdTreeCpuBackend::new()),
        )
        .unwrap();
        assert_eq!(res.translation_errors.len(), 5);
        assert!(
            res.mean_translation_error() < 0.3,
            "mean localization error {}",
            res.mean_translation_error()
        );
        assert!(res.map_points > 0);
        // Affinity + shared key: the map was uploaded by at most `lanes`
        // backends, never once per scan.
        let uploads: usize = res.report.lanes.iter().map(|l| l.target_uploads).sum();
        assert!(uploads <= 2, "{uploads} uploads for 5 same-map scans");
        let hits: usize = res.report.lanes.iter().map(|l| l.target_hits).sum();
        assert_eq!(uploads + hits, 5, "every job either uploads or hits");
    }

    #[test]
    fn odometry_runs_and_tracks() {
        let frames = 6;
        let seq = tiny_sequence(frames);
        let mut icp = FppsIcp::native_sim();
        icp.set_max_iteration_count(30);
        let cfg = PipelineConfig {
            source_sample: 1024,
            target_capacity: 8192,
            ..Default::default()
        };
        let res = run_odometry(&seq, frames, cfg, &mut icp).unwrap();
        assert_eq!(res.records.len(), frames - 1);
        assert_eq!(res.poses.len(), frames);
        // Ground truth relative to frame 0.
        let gt0 = seq.ground_truth[0];
        let gt_rel: Vec<Mat4> = seq
            .ground_truth
            .iter()
            .take(frames)
            .map(|p| gt0.inverse_rigid().mul_mat(p))
            .collect();
        let ate = absolute_trajectory_error(&res.poses, &gt_rel);
        assert!(ate < 0.6, "trajectory error too large: {ate}");
        assert!(res.align_stats.count() == frames - 1);
    }

    #[test]
    fn records_capture_convergence_info() {
        let frames = 4;
        let seq = tiny_sequence(frames);
        let mut icp = FppsIcp::native_sim();
        let res = run_odometry(&seq, frames, PipelineConfig {
            source_sample: 512,
            target_capacity: 4096,
            ..Default::default()
        }, &mut icp)
        .unwrap();
        for r in &res.records {
            assert!(r.iterations >= 1);
            assert!(r.align_ms > 0.0);
            assert!(r.rmse.is_finite());
        }
    }

    #[test]
    fn zero_and_one_frame_edge_cases() {
        let seq = tiny_sequence(2);
        let mut icp = FppsIcp::native_sim();
        let res = run_odometry(&seq, 1, PipelineConfig::default(), &mut icp).unwrap();
        assert!(res.records.is_empty());
        assert_eq!(res.poses.len(), 1);
    }

    // --- AffinityRouter: deterministic scheduling-policy harness ---

    /// Shorthand for completion feedback in the router tests.
    fn fb(lane: usize, key: u64, uploaded: bool, hit: bool, ok: bool) -> JobFeedback {
        JobFeedback {
            lane,
            key,
            uploaded,
            hit,
            ok,
            generation: 0,
        }
    }

    #[test]
    fn stale_generation_feedback_does_not_resurrect_warm_keys() {
        let mut r = AffinityRouter::new(2, 2);
        // Lane 0 serves key 7 and the feedback confirms residency.
        r.committed(0, 7);
        r.completed(fb(0, 7, true, false, true));
        assert_eq!(r.warm_keys(0), &[7]);
        // Two more jobs for the key are in flight when the lane's
        // backend is respawned: the restart clears the mirror and bumps
        // the generation...
        r.committed(0, 7);
        r.committed(0, 7);
        r.lane_restarted(0);
        assert_eq!(r.generation(0), 1);
        assert!(r.warm_keys(0).is_empty(), "restart must clear warm keys");
        assert_eq!(r.pending(0), 2);
        // ...so feedback from the old backend (generation 0) settles the
        // load estimate but must NOT mark the key warm — the new backend
        // holds nothing.
        r.completed(fb(0, 7, true, true, true));
        assert_eq!(r.pending(0), 1);
        assert!(
            r.warm_keys(0).is_empty(),
            "stale-generation feedback resurrected a warm key"
        );
        // Current-generation feedback is trusted again.
        let mut current = fb(0, 7, true, false, true);
        current.generation = 1;
        r.completed(current);
        assert_eq!(r.pending(0), 0);
        assert_eq!(r.warm_keys(0), &[7]);
    }

    #[test]
    fn down_lanes_are_routed_around_until_recovery() {
        let mut r = AffinityRouter::new(2, 1);
        // Key 9 is warm on lane 1, which then gets marked down.
        r.committed(1, 9);
        r.completed(fb(1, 9, true, false, true));
        r.set_down(1, true);
        assert!(r.is_down(1));
        // Warm affinity must not route to a down lane...
        let choice = r.first_choice(9);
        assert_ne!(choice, Some(1), "routed a job to a down lane");
        // ...and the spill order skips it while any other lane is up.
        assert!(!r.spill_order(None).contains(&1));
        // Recovery restores warm affinity (the backend kept its cache:
        // down ≠ restarted).
        r.set_down(1, false);
        assert_eq!(r.first_choice(9), Some(1));
    }

    #[test]
    fn admission_policy_parses_and_displays() {
        assert_eq!("reject".parse::<AdmissionPolicy>().unwrap(), AdmissionPolicy::Reject);
        assert_eq!(
            "downsample".parse::<AdmissionPolicy>().unwrap(),
            AdmissionPolicy::DownsampleToFit
        );
        assert_eq!(AdmissionPolicy::default(), AdmissionPolicy::DownsampleToFit);
        assert!("silent".parse::<AdmissionPolicy>().is_err());
        assert_eq!(AdmissionPolicy::Reject.to_string(), "reject");
        assert_eq!(
            AdmissionPolicy::DownsampleToFit.to_string(),
            "downsample-to-fit"
        );
    }

    #[test]
    fn router_reuses_every_warm_lane_after_a_steal() {
        let mut r = AffinityRouter::new(2, 2);
        // Cold key A: both lanes have free slots — least-loaded wins
        // (tie → lane 0), no spill needed.
        assert_eq!(r.first_choice(0xA), Some(0));
        r.committed(0, 0xA);
        r.committed(0, 0xA); // backlog of 2 on the warm lane
        // Real backlog + idle lane 1 → steal to lane 1.
        assert_eq!(r.first_choice(0xA), Some(1));
        r.committed(1, 0xA);
        // Both lanes are now warm for A. Lane 1 drains first: the
        // dispatcher must see it as a warm candidate — the old
        // `position()` scan only ever found lane 0.
        r.completed(fb(1, 0xA, true, false, true));
        assert_eq!(r.warm_lanes(0xA), vec![1, 0]);
        assert_eq!(r.first_choice(0xA), Some(1), "least-loaded warm lane");
        // Nobody idle: still route to the least-loaded *warm* lane
        // rather than blocking round-robin.
        r.committed(1, 0xA); // pending: lane0=2, lane1=1
        assert_eq!(r.first_choice(0xA), Some(1));
    }

    #[test]
    fn router_steals_only_on_real_backlog() {
        let mut r = AffinityRouter::new(2, 2);
        r.committed(0, 0xA);
        // One in-flight job is NOT a backlog: the old router stole to
        // the idle lane here, paying a redundant target upload.
        assert_eq!(r.first_choice(0xA), Some(0), "no steal at pending 1");
        r.committed(0, 0xA);
        // Two deep with an idle lane → steal.
        assert_eq!(r.first_choice(0xA), Some(1));
        // No idle lane → stay on the least-loaded warm lane.
        r.committed(1, 0xB);
        assert_eq!(r.first_choice(0xA), Some(0));
    }

    #[test]
    fn router_routes_cold_keys_to_free_slots_before_evicting() {
        let mut r = AffinityRouter::new(2, 1);
        r.committed(0, 0xA);
        r.completed(fb(0, 0xA, true, false, true));
        // Cold key B: lane 0 is idle but its only slot is warm; lane 1
        // has the free slot — filling it beats evicting A.
        assert!(!r.has_free_slot(0));
        assert!(r.has_free_slot(1));
        assert_eq!(r.first_choice(0xB), Some(1));
        r.committed(1, 0xB);
        r.completed(fb(1, 0xB, true, false, true));
        // Every slot occupied → None: the channel loop spills by load
        // (an eviction is now inevitable).
        assert_eq!(r.first_choice(0xC), None);
        assert_eq!(r.warm_lanes(0xA), vec![0], "A untouched on its lane");
    }

    #[test]
    fn failed_upload_feedback_unwarms_the_mirror() {
        let mut r = AffinityRouter::new(2, 1);
        r.committed(0, 0xA);
        assert_eq!(r.warm_lanes(0xA), vec![0], "optimistic commit");
        // The job failed before its target upload: the backend never
        // gained A, so the mirror must not keep claiming it.
        r.completed(fb(0, 0xA, false, false, false));
        assert!(r.warm_lanes(0xA).is_empty(), "failed upload un-warms");
        assert!(r.has_free_slot(0), "slot freed for the next cold key");
        // A failed alignment whose upload DID land keeps the key warm —
        // the device holds the target regardless of the ICP error.
        r.committed(1, 0xB);
        r.completed(fb(1, 0xB, true, false, false));
        assert_eq!(r.warm_lanes(0xB), vec![1]);
        // A cache-hit completion confirms warmth.
        r.committed(1, 0xB);
        r.completed(fb(1, 0xB, false, true, true));
        assert_eq!(r.warm_lanes(0xB), vec![1]);
    }

    #[test]
    fn router_warm_sets_are_lru_bounded_like_the_backend() {
        let mut r = AffinityRouter::new(1, 2);
        r.committed(0, 0xA);
        r.committed(0, 0xB);
        assert_eq!(r.warm_lanes(0xA), vec![0]);
        // A third key evicts the LRU key (A), not the MRU one.
        r.committed(0, 0xC);
        assert!(r.warm_lanes(0xA).is_empty(), "A evicted");
        assert_eq!(r.warm_lanes(0xB), vec![0]);
        assert_eq!(r.warm_lanes(0xC), vec![0]);
        // Re-touching B keeps it MRU: D evicts C.
        r.committed(0, 0xB);
        r.committed(0, 0xD);
        assert!(r.warm_lanes(0xC).is_empty());
        assert_eq!(r.warm_lanes(0xB), vec![0]);
    }

    #[test]
    fn router_blocking_choice_prefers_warmth_then_shortest_queue() {
        let mut r = AffinityRouter::new(3, 2);
        r.committed(0, 0xA);
        r.committed(0, 0xA);
        r.committed(1, 0xB);
        // Key A: lane 0 is warm, so block there even though it is the
        // longest queue (the cache hit outweighs one queue slot).
        assert_eq!(r.blocking_choice(0xA), 0);
        // Cold key: shortest queue wins (lane 2 is empty) — the old
        // fall-through blocked on the round-robin cursor regardless.
        assert_eq!(r.blocking_choice(0xF), 2);
        // And among equals the rotation cursor breaks the tie.
        r.committed(2, 0xC); // pending now [2, 1, 1], rr = 0
        assert_eq!(r.blocking_choice(0xF), 1);
    }

    #[test]
    fn router_spill_orders_by_load_and_skips_the_tried_lane() {
        let mut r = AffinityRouter::new(3, 2);
        r.committed(1, 0xA); // pending [0,1,0]
        r.committed(2, 0xB);
        r.committed(2, 0xC); // pending [0,1,2]
        // Load first: a fresh (cache-empty) lane does not excuse a deep
        // backlog — the old order let a cold key queue behind lane 2
        // just because its cache was empty.
        assert_eq!(r.spill_order(None), vec![0, 1, 2]);
        // The lane whose queue already returned Full is skipped, not
        // re-attempted.
        assert_eq!(r.spill_order(Some(0)), vec![1, 2]);
        // At equal load, a free residency slot breaks the tie: spilling
        // where nothing needs evicting beats spilling onto a warm slot.
        let mut r = AffinityRouter::new(2, 1);
        r.committed(0, 0xA);
        r.committed(1, 0xB);
        r.completed(fb(0, 0xA, true, false, true)); // lane 0: idle, slot warm
        r.completed(fb(1, 0xB, false, false, false)); // lane 1: idle, slot free
        assert_eq!(r.spill_order(None), vec![1, 0]);
    }

    // --- Tile-crossing workload ---

    #[test]
    fn tiled_workload_interleaves_tiles_and_shares_submaps() {
        let seq = tiny_sequence(6);
        let cfg = PipelineConfig {
            source_sample: 256,
            target_capacity: 8192,
            ..Default::default()
        };
        let w = tiled_localization_jobs(&seq, 6, 2, &cfg).unwrap();
        assert_eq!(w.maps.len(), 2);
        assert_eq!(w.jobs.len(), 6);
        assert_eq!(w.truth.len(), 6);
        // Round-robin emission: consecutive jobs alternate tiles.
        assert_eq!(w.tile_of_job, vec![0, 1, 0, 1, 0, 1]);
        for (job, &t) in w.jobs.iter().zip(&w.tile_of_job) {
            assert_eq!(job.stream, t);
            assert!(Arc::ptr_eq(&job.target, &w.maps[t]), "submaps are shared");
            assert_eq!(job.target_key, w.maps[t].fingerprint());
        }
        // Ids are the emission order (deterministic outcome order).
        for (k, job) in w.jobs.iter().enumerate() {
            assert_eq!(job.id, k as u64);
        }
        // Two tiles → two distinct keys.
        assert_ne!(w.jobs[0].target_key, w.jobs[1].target_key);
        // Degenerate tile counts clamp instead of failing.
        assert_eq!(tiled_localization_jobs(&seq, 6, 0, &cfg).unwrap().maps.len(), 1);
        assert_eq!(tiled_localization_jobs(&seq, 6, 99, &cfg).unwrap().maps.len(), 6);
    }

    #[test]
    fn tiled_localization_tracks_ground_truth_with_bounded_uploads() {
        let seq = tiny_sequence(6);
        let cfg = PipelineConfig {
            source_sample: 512,
            target_capacity: 8192,
            ..Default::default()
        };
        let res = run_tiled_localization(
            &seq,
            6,
            2,
            &cfg,
            1,
            4,
            LaneIcpConfig {
                max_iteration_count: 30,
                ..Default::default()
            },
            |_| Ok(crate::fpps_api::KdTreeCpuBackend::new()),
        )
        .unwrap();
        assert_eq!(res.report.outcomes.len(), 6);
        assert_eq!(res.map_points.len(), 2);
        assert!(
            res.mean_translation_error() < 0.3,
            "mean tile-localization error {}",
            res.mean_translation_error()
        );
        // One lane, two submaps, A,B,A,B,… order: the LRU residency set
        // absorbs the ping-pong — exactly one upload per submap.
        let uploads: usize = res.report.lanes.iter().map(|l| l.target_uploads).sum();
        let hits: usize = res.report.lanes.iter().map(|l| l.target_hits).sum();
        assert_eq!(uploads, 2, "one upload per tile, not per scan");
        assert_eq!(uploads + hits, 6);
        assert_eq!(res.report.lanes[0].resident_targets, 2);
        assert_eq!(res.report.failed_jobs(), 0);
    }
}
