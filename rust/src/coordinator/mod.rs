//! Frame-stream coordinator — the host-side system layer of Fig. 2.
//!
//! The paper's host "is responsible for data transmission and invokes
//! kernel execution according to the instructions from APIs". At system
//! level that means keeping the accelerator fed: while frame i is being
//! aligned, frame i+1 is already being acquired and preprocessed
//! (sampled, padded). This module implements that as a two-stage
//! pipeline over std threads with bounded channels (backpressure), plus
//! the scan-to-scan odometry driver used by the end-to-end example and
//! the Table III / IV benches.

use crate::dataset::Sequence;
use crate::fpps_api::{FppsIcp, KernelBackend};
use crate::icp::StopReason;
use crate::math::Mat4;
use crate::metrics::TimingStats;
use crate::pointcloud::PointCloud;
use crate::rng::Pcg32;
use anyhow::{Context, Result};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};

/// Preprocessed frame ready for alignment.
pub struct PreparedFrame {
    pub index: usize,
    /// Sampled source cloud (the paper's 4096-point sample).
    pub source_sample: PointCloud,
    /// Full cloud (becomes the next frame's target).
    pub full: PointCloud,
}

/// Pipeline configuration.
///
/// The preprocessing knobs implement the standard LiDAR-odometry front
/// end (range crop, ground removal, voxel grid) that PCL-based
/// registration pipelines run before ICP. Point-to-point scan-to-scan
/// ICP on raw ring-structured scans is identity-biased (ground rings
/// self-match; see DESIGN.md §3 "dataset realism"), so the front end is
/// not optional for odometry-quality tracking — though the Table III /
/// IV benches can disable pieces of it, as they compare CPU vs device
/// under *identical* preprocessing.
#[derive(Clone, Copy, Debug)]
pub struct PipelineConfig {
    /// Per-frame source sample size (paper: 4096).
    pub source_sample: usize,
    /// Target cap; clouds larger than this are voxel-downsampled to fit
    /// the device target buffer.
    pub target_capacity: usize,
    /// Channel depth between acquisition and alignment (double
    /// buffering = 2, like the device's ping-pong BRAM buffers).
    pub queue_depth: usize,
    pub seed: u64,
    /// Range crop (m); 0 disables.
    pub crop_range: f32,
    /// Drop points below this sensor-frame z (ground removal; the
    /// sensor sits ~1.73 m up, so −1.2 keeps everything ≥ ~0.5 m above
    /// the road). `f32::NEG_INFINITY` disables.
    pub ground_z_min: f32,
    /// Voxel-grid leaf applied to both clouds (m); 0 disables.
    pub voxel_leaf: f32,
    /// Multi-start bootstrap: number of forward-translation seeds tried
    /// on the first frame (and after tracking loss). 0 = identity only.
    pub bootstrap_seeds: usize,
    /// Spacing between bootstrap seeds along +x (m).
    pub bootstrap_step: f32,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self {
            source_sample: 4096,
            target_capacity: 16_384,
            queue_depth: 2,
            seed: 7,
            crop_range: 40.0,
            ground_z_min: -1.2,
            voxel_leaf: 0.15,
            bootstrap_seeds: 9,
            bootstrap_step: 0.3,
        }
    }
}

impl PipelineConfig {
    /// Paper-parity preprocessing: no front end at all (raw clouds),
    /// as in the paper's "4096 points randomly sampled from the source".
    pub fn raw() -> Self {
        Self {
            crop_range: 0.0,
            ground_z_min: f32::NEG_INFINITY,
            voxel_leaf: 0.0,
            bootstrap_seeds: 0,
            ..Default::default()
        }
    }
}

/// Front-end preprocessing shared by source and target.
pub fn preprocess(cloud: &PointCloud, cfg: &PipelineConfig) -> PointCloud {
    let mut out = PointCloud::with_capacity(cloud.len());
    let r2max = if cfg.crop_range > 0.0 {
        cfg.crop_range * cfg.crop_range
    } else {
        f32::INFINITY
    };
    for p in cloud.iter() {
        let r2 = p[0] * p[0] + p[1] * p[1];
        if r2 <= r2max && p[2] >= cfg.ground_z_min {
            out.push(p);
        }
    }
    if cfg.voxel_leaf > 0.0 {
        out = out.voxel_downsample(cfg.voxel_leaf);
    }
    out
}

/// Per-frame odometry record.
#[derive(Clone, Debug)]
pub struct FrameRecord {
    pub index: usize,
    /// Scan-to-scan transform estimated by ICP.
    pub relative: Mat4,
    /// Accumulated pose (world ← sensor_i).
    pub pose: Mat4,
    pub rmse: f64,
    pub iterations: u32,
    pub stop: StopReason,
    /// Wall time of the alignment (acquisition excluded — it overlaps).
    pub align_ms: f64,
}

/// Odometry run output.
#[derive(Debug)]
pub struct OdometryResult {
    pub records: Vec<FrameRecord>,
    pub poses: Vec<Mat4>,
    pub align_stats: TimingStats,
    /// Time the alignment thread spent blocked waiting for frames — a
    /// measure of how well acquisition hides behind alignment.
    pub starvation_ms: f64,
}

impl OdometryResult {
    /// Mean registration RMSE across frames (Table III row).
    pub fn mean_rmse(&self) -> f64 {
        let vals: Vec<f64> = self
            .records
            .iter()
            .filter(|r| r.rmse.is_finite())
            .map(|r| r.rmse)
            .collect();
        if vals.is_empty() {
            f64::NAN
        } else {
            vals.iter().sum::<f64>() / vals.len() as f64
        }
    }
}

/// Fit a cloud into the device target buffer: voxel-downsample with a
/// growing leaf until it fits (PCL pipelines do exactly this to bound
/// map density).
pub fn fit_to_capacity(cloud: PointCloud, capacity: usize) -> PointCloud {
    if cloud.len() <= capacity {
        return cloud;
    }
    let mut leaf = 0.1f32;
    for _ in 0..12 {
        let down = cloud.voxel_downsample(leaf);
        if down.len() <= capacity {
            return down;
        }
        leaf *= 1.6;
    }
    // Fall back to random sampling at the last resort.
    let mut rng = Pcg32::new(0xF17);
    cloud.random_sample(capacity, &mut rng)
}

/// Acquisition stage: generates/loads frames, samples the source, and
/// pushes prepared frames downstream. Runs on its own thread.
fn acquisition_thread(
    seq: &Sequence,
    frames: usize,
    cfg: PipelineConfig,
    tx: SyncSender<Result<PreparedFrame>>,
) {
    for i in 0..frames {
        let item = (|| -> Result<PreparedFrame> {
            let cloud = preprocess(&seq.frame(i)?, &cfg);
            let mut rng = Pcg32::substream(cfg.seed, i as u64);
            let source_sample = cloud.random_sample(cfg.source_sample, &mut rng);
            let full = fit_to_capacity(cloud, cfg.target_capacity);
            Ok(PreparedFrame {
                index: i,
                source_sample,
                full,
            })
        })();
        // Receiver hung up → stop early.
        if tx.send(item).is_err() {
            return;
        }
    }
}

/// Run scan-to-scan odometry over the first `frames` frames of `seq`
/// using the FPPS API with the given backend.
///
/// Frame 0 initialises the map; each subsequent frame aligns its sample
/// against the previous frame's full cloud, seeding ICP with the
/// previous relative motion (constant-velocity prior — standard LiDAR
/// odometry practice that also matches the paper's per-frame "initial
/// transformation matrix" API).
pub fn run_odometry<B: KernelBackend>(
    seq: &Sequence,
    frames: usize,
    cfg: PipelineConfig,
    icp: &mut FppsIcp<B>,
) -> Result<OdometryResult> {
    let frames = frames.min(seq.len());
    let (tx, rx): (_, Receiver<Result<PreparedFrame>>) = sync_channel(cfg.queue_depth);

    std::thread::scope(|scope| {
        scope.spawn(|| acquisition_thread(seq, frames, cfg, tx));

        let mut records = Vec::new();
        let mut poses = vec![Mat4::IDENTITY];
        let mut align_stats = TimingStats::new();
        let mut starvation_ms = 0.0;
        let mut prev_full: Option<PointCloud> = None;
        let mut prev_relative = Mat4::IDENTITY;

        loop {
            let wait0 = std::time::Instant::now();
            let msg = match rx.recv() {
                Ok(m) => m,
                Err(_) => break, // acquisition finished
            };
            starvation_ms += wait0.elapsed().as_secs_f64() * 1e3;
            let frame = msg.context("frame acquisition")?;

            match prev_full.take() {
                None => {
                    // First frame: nothing to align against.
                    prev_full = Some(frame.full);
                }
                Some(target) => {
                    let t0 = std::time::Instant::now();
                    let bootstrap = records.is_empty()
                        || !matches!(
                            records.last().map(|r: &FrameRecord| r.stop),
                            Some(StopReason::Converged) | Some(StopReason::MaxIterations)
                        );
                    let res = if bootstrap && cfg.bootstrap_seeds > 0 {
                        // Multi-start global initialisation: the vehicle
                        // moves dominantly forward, so seed a fan of +x
                        // translations and keep the lowest-RMSE result.
                        let mut best: Option<crate::fpps_api::FppsResult> = None;
                        for k in 0..=cfg.bootstrap_seeds {
                            let seed_t = Mat4::from_rt(
                                crate::math::Mat3::IDENTITY,
                                crate::math::Vec3::new(
                                    (k as f64) * cfg.bootstrap_step as f64,
                                    0.0,
                                    0.0,
                                ),
                            );
                            icp.set_input_source(frame.source_sample.clone());
                            icp.set_input_target(target.clone());
                            icp.set_transformation_matrix(seed_t);
                            let r = icp.align()?;
                            let better = match &best {
                                None => true,
                                Some(b) => {
                                    r.has_converged()
                                        && (!b.has_converged() || r.rmse < b.rmse)
                                }
                            };
                            if better {
                                best = Some(r);
                            }
                        }
                        best.expect("at least one bootstrap attempt")
                    } else {
                        icp.set_input_source(frame.source_sample);
                        icp.set_input_target(target);
                        icp.set_transformation_matrix(prev_relative);
                        icp.align()?
                    };
                    let align_ms = t0.elapsed().as_secs_f64() * 1e3;
                    align_stats.record_ms(align_ms);

                    // T maps source (frame i) into target (frame i−1)
                    // coordinates — i.e. the relative motion.
                    let relative = res.transformation;
                    let pose = poses.last().unwrap().mul_mat(&relative);
                    poses.push(pose);
                    records.push(FrameRecord {
                        index: frame.index,
                        relative,
                        pose,
                        rmse: res.rmse,
                        iterations: res.iterations,
                        stop: res.stop,
                        align_ms,
                    });
                    prev_relative = if res.has_converged() {
                        relative
                    } else {
                        Mat4::IDENTITY
                    };
                    prev_full = Some(frame.full);
                }
            }
        }

        Ok(OdometryResult {
            records,
            poses,
            align_stats,
            starvation_ms,
        })
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{lidar::LidarConfig, sequence_specs, Sequence};
    use crate::metrics::absolute_trajectory_error;

    fn tiny_sequence(frames: usize) -> Sequence {
        let spec = sequence_specs()[3].clone(); // residential: gentle
        Sequence::synthetic(spec, frames, 11, LidarConfig::tiny())
    }

    #[test]
    fn fit_to_capacity_shrinks() {
        let mut rng = Pcg32::new(1);
        let mut c = PointCloud::with_capacity(5000);
        for _ in 0..5000 {
            c.push([rng.range(-40.0, 40.0), rng.range(-40.0, 40.0), rng.range(0.0, 5.0)]);
        }
        let f = fit_to_capacity(c.clone(), 1000);
        assert!(f.len() <= 1000);
        assert!(f.len() > 100, "over-shrunk to {}", f.len());
        // Under capacity → untouched.
        assert_eq!(fit_to_capacity(c.clone(), 10_000).len(), c.len());
    }

    #[test]
    fn odometry_runs_and_tracks() {
        let frames = 6;
        let seq = tiny_sequence(frames);
        let mut icp = FppsIcp::native_sim();
        icp.set_max_iteration_count(30);
        let cfg = PipelineConfig {
            source_sample: 1024,
            target_capacity: 8192,
            ..Default::default()
        };
        let res = run_odometry(&seq, frames, cfg, &mut icp).unwrap();
        assert_eq!(res.records.len(), frames - 1);
        assert_eq!(res.poses.len(), frames);
        // Ground truth relative to frame 0.
        let gt0 = seq.ground_truth[0];
        let gt_rel: Vec<Mat4> = seq
            .ground_truth
            .iter()
            .take(frames)
            .map(|p| gt0.inverse_rigid().mul_mat(p))
            .collect();
        let ate = absolute_trajectory_error(&res.poses, &gt_rel);
        assert!(ate < 0.6, "trajectory error too large: {ate}");
        assert!(res.align_stats.count() == frames - 1);
    }

    #[test]
    fn records_capture_convergence_info() {
        let frames = 4;
        let seq = tiny_sequence(frames);
        let mut icp = FppsIcp::native_sim();
        let res = run_odometry(&seq, frames, PipelineConfig {
            source_sample: 512,
            target_capacity: 4096,
            ..Default::default()
        }, &mut icp)
        .unwrap();
        for r in &res.records {
            assert!(r.iterations >= 1);
            assert!(r.align_ms > 0.0);
            assert!(r.rmse.is_finite());
        }
    }

    #[test]
    fn zero_and_one_frame_edge_cases() {
        let seq = tiny_sequence(2);
        let mut icp = FppsIcp::native_sim();
        let res = run_odometry(&seq, 1, PipelineConfig::default(), &mut icp).unwrap();
        assert!(res.records.is_empty());
        assert_eq!(res.poses.len(), 1);
    }
}
