//! Host-side coordinator: frame pipeline, multi-lane registration
//! engine, and the event-driven serving tier.
//!
//! The coordinator is split into focused submodules; everything is
//! re-exported here so callers keep using `fpps::coordinator::X`:
//!
//! - [`pipeline`] — frame acquisition/preprocessing, capacity fitting,
//!   residency-aware admission ([`AdmissionPolicy`], [`admit_map`]),
//!   and the single-stream odometry driver ([`run_odometry`]).
//! - [`jobs`] — the work items and results that flow through the lane
//!   pool: [`RegistrationJob`] (now carrying an [`SloClass`]),
//!   [`RegistrationOutcome`], per-lane stats and the merged
//!   [`LaneReport`].
//! - [`router`] — [`AffinityRouter`], the pool-wide residency
//!   coordinator: a warm/resident mirror per lane corrected by per-job
//!   feedback, free-slot-first placement, bounded stealing, and
//!   down-lane rerouting.
//! - [`supervise`] — the supervised lane pool: per-lane SPSC rings,
//!   the dispatcher ([`run_supervised_lane_pool`]), heartbeat watchdog,
//!   deadlines, retries with backoff, backend respawn/failover tiers,
//!   and the batch entry points ([`run_registration_batch`],
//!   [`run_registration_batch_supervised`]) as thin wrappers.
//! - [`serving`] — the event-driven serving tier: non-blocking
//!   [`ServingPool::submit`](ServingPool) returning a
//!   [`CompletionHandle`] (hand-rolled waker-style completion events
//!   off the dispatcher's done channel — no tokio), per-client
//!   [`ClientStream`]s with bounded backpressure (a full stream sheds
//!   or parks the client, never blocks a lane), and SLO-classed
//!   shedding: latency-critical work that would miss its deadline is
//!   resolved immediately with a structured
//!   [`StopReason::Shed`](crate::icp::StopReason) outcome instead of
//!   queueing.
//! - [`scenarios`] — batch scenario builders/drivers on top of the
//!   pool: frame-pair batches, scan-to-map localization, tiled submaps.
//! - [`claim`] — [`claim::ClaimSlot`], the exactly-once worker/watchdog
//!   claim arbitration extracted from the heartbeat protocol and
//!   model-checked under `--cfg loom`.
//! - [`completion`] — [`completion::CompletionCell`], the generic
//!   waker-style completion rendezvous behind [`CompletionHandle`],
//!   also model-checked under `--cfg loom`.
//!
//! Every lane owns one kernel backend (one accelerator context); jobs
//! are routed by target-key affinity so cross-frame map reuse skips the
//! target DMA and kd-tree rebuild. Payloads ride `Arc`s through
//! lock-free rings (zero-copy data plane); outcomes are bit-identical
//! to the sequential path for every Ok result, whichever entry point —
//! batch, localization, or serving — produced them.

pub mod claim;
pub mod completion;
pub mod jobs;
pub mod pipeline;
pub mod router;
pub mod scenarios;
pub mod serving;
pub mod supervise;

pub use jobs::*;
pub use pipeline::*;
pub use router::*;
pub use scenarios::*;
pub use serving::*;
pub use supervise::*;
