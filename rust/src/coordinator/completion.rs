//! Waker-style completion cell backing [`super::serving::CompletionHandle`].
//!
//! A [`CompletionCell`] is a one-shot rendezvous: the producer side
//! calls [`CompletionCell::complete`] exactly once; consumers poll
//! ([`CompletionCell::try_take`]), block ([`CompletionCell::wait`] /
//! [`CompletionCell::wait_timeout`]), or register a callback
//! ([`CompletionCell::set_waker`]) that fires exactly once — on the
//! completing thread, outside the lock, or immediately on the
//! registering thread when the cell already resolved.
//!
//! The cell synchronizes through [`crate::sync`], so the
//! no-missed-wakeup and exactly-once-waker properties are model-checked
//! under `--cfg loom` (see `tests/loom_models.rs`) with the same code
//! the serving tier runs in production.

use crate::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Outcome + waker storage, guarded by one mutex.
struct Slot<T> {
    outcome: Option<T>,
    done: bool,
    waker: Option<Box<dyn FnOnce() + Send>>,
}

/// One-shot completion rendezvous (see the module docs).
pub struct CompletionCell<T> {
    slot: Mutex<Slot<T>>,
    cv: Condvar,
}

impl<T> Default for CompletionCell<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> CompletionCell<T> {
    /// An unresolved cell.
    pub fn new() -> Self {
        Self {
            slot: Mutex::new(Slot {
                outcome: None,
                done: false,
                waker: None,
            }),
            cv: Condvar::new(),
        }
    }

    /// Resolve the cell: store the outcome, wake blocking waiters, and
    /// fire the registered waker (outside the lock — wakers may
    /// re-enter the pool).
    pub fn complete(&self, outcome: T) {
        let waker = {
            let mut slot = self.slot.lock().unwrap();
            slot.outcome = Some(outcome);
            slot.done = true;
            self.cv.notify_all();
            slot.waker.take()
        };
        if let Some(w) = waker {
            w();
        }
    }

    /// Has the cell resolved (even if its outcome was already taken)?
    pub fn is_complete(&self) -> bool {
        self.slot.lock().unwrap().done
    }

    /// Non-blocking: the outcome if the cell resolved and nobody took
    /// it yet.
    pub fn try_take(&self) -> Option<T> {
        self.slot.lock().unwrap().outcome.take()
    }

    /// Block until the cell resolves.
    ///
    /// # Panics
    /// If the outcome was already consumed by [`Self::try_take`] /
    /// [`Self::wait_timeout`].
    pub fn wait(&self) -> T {
        let mut slot = self.slot.lock().unwrap();
        while !slot.done {
            slot = self.cv.wait(slot).unwrap();
        }
        slot.outcome
            .take()
            .expect("completion outcome already consumed")
    }

    /// Block until the cell resolves or `timeout` elapses; `None` on
    /// timeout (or when the outcome was already taken).
    pub fn wait_timeout(&self, timeout: Duration) -> Option<T> {
        let deadline = Instant::now() + timeout;
        let mut slot = self.slot.lock().unwrap();
        while !slot.done {
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, res) = self.cv.wait_timeout(slot, deadline - now).unwrap();
            slot = guard;
            if res.timed_out() && !slot.done {
                return None;
            }
        }
        slot.outcome.take()
    }

    /// Register a callback fired exactly once when the cell resolves —
    /// immediately (on the caller's thread) if it already has, else on
    /// the completing thread. The last registration wins; an earlier
    /// unfired waker is dropped. Wakers must not block: in the serving
    /// tier they run on the thread that fulfills every handle.
    pub fn set_waker(&self, waker: impl FnOnce() + Send + 'static) {
        let mut boxed: Option<Box<dyn FnOnce() + Send>> = Some(Box::new(waker));
        let fire = {
            let mut slot = self.slot.lock().unwrap();
            if slot.done {
                boxed.take()
            } else {
                slot.waker = boxed.take();
                None
            }
        };
        if let Some(w) = fire {
            w();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn outcome_is_taken_exactly_once() {
        let c = CompletionCell::new();
        assert!(!c.is_complete());
        assert_eq!(c.try_take(), None);
        c.complete(41u32);
        assert!(c.is_complete());
        assert_eq!(c.try_take(), Some(41));
        assert_eq!(c.try_take(), None, "second take gets nothing");
        assert!(c.is_complete(), "done survives the take");
    }

    #[test]
    fn waker_fires_once_on_complete() {
        let c = CompletionCell::new();
        let fired = Arc::new(AtomicUsize::new(0));
        let f = Arc::clone(&fired);
        // ordering: Relaxed — single-threaded test counter.
        c.set_waker(move || {
            f.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(fired.load(Ordering::Relaxed), 0, "not before completion");
        c.complete(1u32);
        assert_eq!(fired.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn waker_fires_immediately_after_completion() {
        let c = CompletionCell::new();
        c.complete(1u32);
        let fired = Arc::new(AtomicUsize::new(0));
        let f = Arc::clone(&fired);
        // ordering: Relaxed — single-threaded test counter.
        c.set_waker(move || {
            f.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(fired.load(Ordering::Relaxed), 1, "fires on registration");
    }

    #[test]
    fn wait_timeout_times_out_then_delivers() {
        let c = CompletionCell::new();
        assert_eq!(c.wait_timeout(Duration::from_millis(5)), None);
        c.complete(9u32);
        assert_eq!(c.wait_timeout(Duration::from_millis(5)), Some(9));
        assert_eq!(c.wait_timeout(Duration::from_millis(1)), None, "consumed");
    }
}
